#!/usr/bin/env bash
# Tier-1 CI: import hygiene for the repro.api layering + the full test suite.
#
#   scripts/ci.sh            # run everything
#
# The import checks run each entry point in a FRESH interpreter so
# order-dependent circular imports can't hide behind a warmed sys.modules
# (repro.api sits above repro.core and beside repro.kernels; ops.py shims
# back into repro.api, which is only legal because core never imports api).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== import-cycle lint =="
# layering rule: repro.core must never import repro.api (registry lives in
# core precisely so the dependency points one way).
if grep -rnE "^[^#]*(from|import) +repro\.api" src/repro/core; then
    echo "FAIL: repro.core imports repro.api (layering violation)" >&2
    exit 1
fi
# repro.tune sits above repro.api (the search builds schedules through the
# planner), so api may only reach back into tune lazily inside a function
# body — a module-level import would be a cycle.
if grep -rnE "^(from|import) +repro\.tune" src/repro/api; then
    echo "FAIL: repro.api imports repro.tune at module level (cycle)" >&2
    exit 1
fi
# every entry point must import clean in isolation (both directions of the
# kernels<->api shim seam, plus the consumers).
for m in repro.api repro.core repro.kernels repro.kernels.ops \
         repro.models.sparse_ffn repro.runtime.serve repro.models \
         repro.tune; do
    python -c "import $m" || { echo "FAIL: import $m" >&2; exit 1; }
done
# the seam both ways in one process
python -c "import repro.api, repro.kernels"
python -c "import repro.kernels, repro.api"
# analysis sits between core and api: it must import without either kernels
# or the planner warmed (core-only at module level)
python -c "import repro.analysis"
echo "import lint OK"

echo "== static verification =="
# (1) kernel analyzer: trace every shipped Pallas kernel (Segment spmm/
# spgemm variants x the knob grid, flash_attention, moe_gemm, rg_lru) and
# run the syntactic hazard lint plus the symbolic proofs — index-range,
# parallel-race, ring-slot-war, sem-balance, vmem-budget (see
# repro.analysis: accesses/ranges/races/budget).  (2) plan verifier sweep:
# build plans from the sim pattern corpus across the knob grid (lanes x
# unroll x quantize, spmm + spgemm + degenerates), prove the full
# invariant catalog on each, and emit the machine-readable findings
# artifact (VERIFY_plans.json) for upload/diffing.  Both exit 1 on any
# finding.
python -m repro.analysis.jaxpr_lint -q
python scripts/verify_plans.py --level full -q --json VERIFY_plans.json
python - <<'EOF'
import json
d = json.load(open("VERIFY_plans.json"))
assert d["summary"]["ok"] and d["summary"]["n_findings"] == 0, d["summary"]
assert d["summary"]["n_plans"] > 100, d["summary"]   # the sweep ran fully
# every pattern autotuned under both objectives, each winner checked
assert d["summary"]["n_autotuned"] >= 12, d["summary"]
print(f"verify artifact OK: {d['summary']['n_plans']} plans clean "
      f"({d['summary']['n_autotuned']} autotuned winners) "
      f"at level={d['level']!r}")
EOF

echo "== serve bench smoke =="
# end-to-end continuous-batching engine + throughput tracking from this PR
# on: BENCH_serve.json carries prefill/decode tok/s for the perf trajectory.
python benchmarks/serve_bench.py --smoke --quant-repeats 5 --out BENCH_serve.json
python - <<'EOF'
import json
d = json.load(open("BENCH_serve.json"))
assert d["prefill_tok_s"] > 0 and d["decode_tok_s"] > 0, d
assert not d["retraced_after_warmup"], d["compiled_shapes"]
# quantized serving (fp32/int8/fp8 engines on the block-sparse-FFN
# variant): every quantized plan the bench builds verifies clean at
# level="full", no engine retraces after warmup, greedy drift vs fp32
# stays inside the documented bounds, and int8 decode throughput is no
# worse than fp32 modulo noise — the 0.85 floor is what the tiny smoke
# decode phase (requests x max_new = 16 tokens/pass) supports on a loaded
# runner even with 5 interleaved best-of passes (interpret mode moves the
# same flops either way; the weight-byte win needs real hardware), while
# the full-config artifact tracks the raw ratio for the perf trajectory
q = d["quant"]["modes"]
for mode in ("fp32", "int8", "fp8"):
    assert q[mode]["decode_tok_s"] > 0, (mode, q[mode])
    assert not q[mode]["retraced_after_warmup"], (mode, q[mode])
for mode, drift_bound in (("int8", 0.25), ("fp8", 0.5)):
    assert q[mode]["verify_findings"] == 0, (mode, q[mode])
    assert q[mode]["greedy_drift_fraction"] <= drift_bound, (mode, q[mode])
    # the deterministic form of the quantization win: modeled FFN weight
    # bytes per decode step must drop at least 2x vs fp32 (1-byte payloads
    # + fp32 scales price out near 4x; 2x leaves headroom for rowwise)
    assert q[mode]["ffn_weight_traffic_cut_vs_fp32"] >= 2.0, (mode, q[mode])
assert q["int8"]["decode_tok_s"] >= 0.85 * q["fp32"]["decode_tok_s"], \
    (q["int8"]["decode_tok_s"], q["fp32"]["decode_tok_s"])
print(f"serve bench OK: prefill {d['prefill_tok_s']:.1f} tok/s, "
      f"decode {d['decode_tok_s']:.1f} tok/s; quant decode fp32 "
      f"{q['fp32']['decode_tok_s']:.1f} / int8 "
      f"{q['int8']['decode_tok_s']:.1f} / fp8 "
      f"{q['fp8']['decode_tok_s']:.1f} tok/s, int8 drift "
      f"{q['int8']['greedy_drift_fraction']:.3f}, int8 weight-byte cut "
      f"{q['int8']['ffn_weight_traffic_cut_vs_fp32']:.2f}x")
EOF

echo "== kernel bench smoke =="
# lane-parallel Segment kernels: BENCH_kernels.json carries the traffic
# ratios, interpret wall time, and dense-oracle parity for 1/2/4 lanes.
python -m benchmarks.kernel_bench --repeats 12 --out BENCH_kernels.json
python - <<'EOF'
import json
d = json.load(open("BENCH_kernels.json"))
lanes = d["lanes"]
# structural guard first: the balanced bench case must pack lanes with zero
# padding, so every lane count executes the same grid-step total (interpret
# mode emulates the grid sequentially — lanes can only tie on wall time
# here; the concurrency win needs real hardware)
for n, row in lanes.items():
    assert row["padded_items"] == 0, (n, row)
    assert row["max_err"] < 1e-4, (n, row["max_err"])
single = lanes["1"]["interpret_us_min"]
multi = min(lanes[n]["interpret_us_min"] for n in lanes if n != "1")
# best multi-lane config must not lose to single-lane (min of interleaved
# warm calls — the floor is far more load-stable than the median).  The
# padded_items==0 guard above already pins equal step counts, so this bound
# only catches gross per-step overhead creep; the slack is generous because
# wall time on a loaded runner is noise-vs-noise
assert multi <= single * 1.25, (multi, single)
# segment must stay no worse than the two static built-in baselines (same
# 0.1% tolerance as the test suite; custom-registered policies are reported
# in the JSON but deliberately not gated)
for case, ratios in d["traffic"].items():
    for p in ("gustavson", "outer"):
        r = ratios[f"segment_traffic_saving_vs_{p}"]
        assert r >= 0.999, (case, p, r)
# quantized block storage: the standard weight-bound case must cut modeled
# traffic bytes (int8 payload + per-block scales vs fp32 tiles) by >= 1.67x
# (<= 0.6x fp32) and stay under the documented normalized error bounds
# (docs/API.md: int8 5e-2, fp8 1e-1 vs the dense fp32 oracle)
q = d["quant"]
for mode in ("int8", "fp8"):
    assert q[mode]["traffic_total_bytes"] <= 0.6 * q["fp32"]["traffic_total_bytes"], \
        (mode, q[mode]["traffic_total_bytes"], q["fp32"]["traffic_total_bytes"])
assert q["fp32"]["max_err"] < 1e-4, q["fp32"]
assert q["int8"]["max_err"] < 5e-2, q["int8"]
assert q["fp8"]["max_err"] < 1e-1, q["fp8"]
# DMA pipeline: the bench plans must verify clean under the full static
# invariant catalog (repro.analysis.verify_plan level="full") — which
# includes the traffic-agreement invariant, the exact model-vs-fetch-flag
# count equality this block used to assert inline.  The raw counts stay in
# the JSON for trending; the spgemm case must carry real work (an empty
# triple list would verify vacuously).
p = d["pipeline"]
assert p["verify_findings"] == 0, (p["verify_findings"],
                                   p["verify_finding_ids"])
assert p["spgemm_model_b_fetches"] > 0, p
# verify="full" must stay cheap: < 10% amortized plan-build wall time
# (one template verification per cache miss + an O(1) per-realize check)
assert p["verify_build_overhead"] < 0.10, p["verify_build_overhead"]
assert p["max_err_pipelined"] < 1e-4, p
# static VMEM budgets (repro.analysis.plan_vmem_bytes) must be reported per
# case and fit the per-core limit the planner's vmem_limit_bytes gate uses
from repro.analysis import DEFAULT_VMEM_LIMIT_BYTES
for n, row in lanes.items():
    assert 0 < row["vmem_bytes"] <= DEFAULT_VMEM_LIMIT_BYTES, (n, row)
for mode, row in q.items():
    assert 0 < row["vmem_bytes"] <= DEFAULT_VMEM_LIMIT_BYTES, (mode, row)
for key in ("vmem_bytes_pipelined", "vmem_bytes_legacy",
            "vmem_bytes_spgemm"):
    assert 0 < p[key] <= DEFAULT_VMEM_LIMIT_BYTES, (key, p[key])
# interpret wall time vs the non-pipelined baseline: emulated DMAs could
# regress pathologically without parity breaking — keep the pipelined path
# within a generous factor of the legacy auto-pipeline (it is currently
# ~3x FASTER in interpret mode: two ANY operands emulate cheaper than
# 2*unroll BlockSpec streams)
assert p["pipelined_us_min"] <= 10 * p["legacy_us_min"], p
# cross-pass DMA prefetch: the mode only moves WHEN copies issue, never
# WHICH — so the two modes must agree bit-exactly, the traffic model's
# overlapped-fetch count must equal the independent head-window fetch-flag
# sum EXACTLY, and both modes must certify clean under the full invariant
# catalog plus the happens-before rules (cross-pass-war / sem-carryover /
# prefetch-raw / dma-priority): no prefetch schedule ships uncertified.
pf = d["prefetch"]
assert pf["parity_err"] == 0.0, pf
assert pf["max_err"] < 1e-4, pf
assert pf["n_tiles_n"] >= 2, pf            # cross-pass tail actually ran
assert pf["model_prefetch_fetches"] == pf["flag_prefetch_fetches"] > 0, pf
assert pf["verify_findings"] == 0, pf
assert pf["order_findings"] == 0, pf
# interpret wall ratio: the interpreter replays every DMA inline AND
# evaluates the prefetch tail/prologue guards each grid step, so prefetch
# cannot win here (steady state ~1.25-1.3x; the overlap win needs real
# hardware — cost model prices it via prefetch_step_credit, zero on the
# interpret objective).  Gate generously to catch pathological creep only.
assert pf["interpret_ratio_vs_no_prefetch"] <= 1.5, pf
# autotuner: on every case the searched schedule must match or beat the
# default knobs on modeled traffic bytes (the search objective is exact
# there) and stay within wall-time noise of the default (min of interleaved
# warm calls; the model can only trade bytes for steps it also prices).
# Every winner must verify clean at level="full", fit the static VMEM
# budget, and stay numerically exact; at least one case must dispatch a
# non-segment dataflow (the staircase pattern breaks SELECTA chaining, so
# gustavson wins it statically).
at = d["autotune"]
n_cases = 0
non_segment = []
for case, row in at.items():
    if case == "cost_model":
        continue
    n_cases += 1
    assert row["tuned_traffic_bytes"] <= row["default_traffic_bytes"], \
        (case, row["tuned_traffic_bytes"], row["default_traffic_bytes"])
    assert row["tuned_us_min"] <= row["default_us_min"] * 1.25, \
        (case, row["tuned_us_min"], row["default_us_min"])
    assert row["verify_findings"] == 0, (case, row["verify_findings"])
    assert 0 < row["vmem_bytes"] <= DEFAULT_VMEM_LIMIT_BYTES, \
        (case, row["vmem_bytes"])
    assert row["tuned_max_err"] < 1e-4, (case, row["tuned_max_err"])
    assert row["default_max_err"] < 1e-4, (case, row["default_max_err"])
    if row["policy"] != "segment":
        non_segment.append((case, row["policy"]))
assert n_cases >= 4, n_cases
assert non_segment, {c: r["policy"] for c, r in at.items()
                     if c != "cost_model"}
cm = at["cost_model"]
assert cm["bytes_per_us"] > 0 and cm["step_us"] > 0, cm
saved = sum(r["default_traffic_bytes"] - r["tuned_traffic_bytes"]
            for c, r in at.items() if c != "cost_model")
print(f"kernel bench OK: interpret 1-lane {single:.0f}us, "
      f"best multi-lane {multi:.0f}us, "
      f"max_err {max(r['max_err'] for r in lanes.values()):.2e}, "
      f"int8 traffic {q['int8']['traffic_ratio_vs_fp32']:.2f}x smaller "
      f"(err {q['int8']['max_err']:.2e}), "
      f"pipeline fetch contract exact "
      f"(a={p['flag_a_fetches']}, b={p['flag_b_fetches']}), "
      f"pipelined {p['pipelined_us']:.0f}us vs legacy {p['legacy_us']:.0f}us, "
      f"prefetch certified ({pf['model_prefetch_fetches']} overlapped "
      f"fetches, parity {pf['parity_err']:.1f}, "
      f"{pf['interpret_ratio_vs_no_prefetch']:.2f}x interpret wall), "
      f"autotune {n_cases} cases ({saved} bytes saved, "
      f"non-segment: {non_segment})")
EOF

echo "== tier-1 tests =="
python -m pytest -x -q
