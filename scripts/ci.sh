#!/usr/bin/env bash
# Tier-1 CI: import hygiene for the repro.api layering + the full test suite.
#
#   scripts/ci.sh            # run everything
#
# The import checks run each entry point in a FRESH interpreter so
# order-dependent circular imports can't hide behind a warmed sys.modules
# (repro.api sits above repro.core and beside repro.kernels; ops.py shims
# back into repro.api, which is only legal because core never imports api).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== import-cycle lint =="
# layering rule: repro.core must never import repro.api (registry lives in
# core precisely so the dependency points one way).
if grep -rnE "^[^#]*(from|import) +repro\.api" src/repro/core; then
    echo "FAIL: repro.core imports repro.api (layering violation)" >&2
    exit 1
fi
# every entry point must import clean in isolation (both directions of the
# kernels<->api shim seam, plus the consumers).
for m in repro.api repro.core repro.kernels repro.kernels.ops \
         repro.models.sparse_ffn repro.runtime.serve repro.models; do
    python -c "import $m" || { echo "FAIL: import $m" >&2; exit 1; }
done
# the seam both ways in one process
python -c "import repro.api, repro.kernels"
python -c "import repro.kernels, repro.api"
echo "import lint OK"

echo "== serve bench smoke =="
# end-to-end continuous-batching engine + throughput tracking from this PR
# on: BENCH_serve.json carries prefill/decode tok/s for the perf trajectory.
python benchmarks/serve_bench.py --smoke --out BENCH_serve.json
python - <<'EOF'
import json
d = json.load(open("BENCH_serve.json"))
assert d["prefill_tok_s"] > 0 and d["decode_tok_s"] > 0, d
assert not d["retraced_after_warmup"], d["compiled_shapes"]
print(f"serve bench OK: prefill {d['prefill_tok_s']:.1f} tok/s, "
      f"decode {d['decode_tok_s']:.1f} tok/s")
EOF

echo "== tier-1 tests =="
python -m pytest -x -q
