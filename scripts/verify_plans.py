#!/usr/bin/env python
"""Sweep the static plan verifier over a corpus of planner-built plans.

Builds plans from the ``repro.sim.matrices`` pattern generators (the same
structural families the paper benchmarks run) across the planner knob grid
— lanes × unroll × quantize × prefetch × policy, SpMM and SpGEMM, plus the
degenerate shapes the verifier must tolerate (single-block schedules,
empty symbolic C patterns, unpadded ``n_lanes=1``) — and runs
``repro.analysis.verify_plan`` on each.  Any finding is a bug in either
the planner or the verifier; the process exits 1 so ``scripts/ci.sh`` can
gate on it.  The sweep also autotunes every pattern under both cost-model
objectives (``repro.tune.autotune_matmul``) and pushes each search winner
through the same full-level verifier plus the static VMEM gate — no
schedule the search can emit escapes static checking.

``--json OUT`` additionally writes a machine-readable findings artifact
(per-plan records + per-finding invariant/message + summary) for CI upload
and run-to-run diffing.  ``--fast`` is shorthand for ``--level fast`` —
the structural catalog without the full-level independent traffic-model
count recomputation (the expensive half of a full sweep).

Usage::

    PYTHONPATH=src python scripts/verify_plans.py [--level fast|full]
        [--fast] [--scale 256] [--seed 7] [--json OUT.json] [-q]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro import api, tune
from repro.analysis import check_plan_vmem, verify_plan
from repro.api.executor import pick_bn
from repro.core.formats import BSR
from repro.sim import matrices

BLOCK = (32, 32)

#: (pattern-name, generator) — small dims keep the sweep host-cheap while
#: still exercising banded/power-law/mesh segment structure.
PATTERNS = (
    ("banded", matrices.banded),
    ("mesh2d", matrices.mesh2d),
    ("powerlaw", matrices.powerlaw),
    ("powernet", matrices.powernet),
    ("uniform", matrices.uniform),
    ("blockrand", matrices.blockrand),
)

SPMM_GRID = tuple(
    dict(n_lanes=l, unroll=u, quantize=q, prefetch=p)
    for l in (1, 2, 4) for u in (1, 2)
    for q in (None, "int8", "int8.rowwise")
    for p in (None, "cross_pass"))
SPGEMM_GRID = tuple(
    dict(n_lanes=l, unroll=u, prefetch=p)
    for l in (1, 2) for u in (1, 2) for p in (None, "cross_pass"))


def _pattern_bsr(gen, rng, dim: int, density: float) -> BSR:
    dense = gen(rng, dim, dim, density).to_dense()
    return BSR.from_dense(dense, BLOCK)


def sweep(level: str, scale: int, seed: int, quiet: bool,
          json_out=None) -> int:
    rng = np.random.default_rng(seed)
    records = []
    n_findings = 0
    n_autotuned = 0
    t0 = time.perf_counter()

    def check(label: str, plan) -> None:
        nonlocal n_findings
        res = verify_plan(plan, level=level)
        rec = {"plan": label, "kind": plan.kind, "ok": bool(res.ok),
               "checked": len(res.checked),
               "findings": [{"invariant": f.invariant,
                             "message": f.message,
                             "severity": getattr(f, "severity", "error")}
                            for f in res.findings]}
        records.append(rec)
        if not res.ok:
            n_findings += len(res.findings)
            print(f"FAIL {label}:")
            for f in res.findings:
                print(f"  {f}")
        elif not quiet:
            print(f"  ok {label} ({len(res.checked)} invariants)")

    for name, gen in PATTERNS:
        a = _pattern_bsr(gen, rng, scale, 0.05)
        if a.nblocks == 0:
            print(f"  skip {name}: pattern quantizes to zero blocks")
            continue
        for kw in SPMM_GRID:
            label = (f"spmm/{name} lanes={kw['n_lanes']} "
                     f"unroll={kw['unroll']} quant={kw['quantize']} "
                     f"pf={kw['prefetch']}")
            check(label, api.plan_matmul(a, policy="segment", fold_len=4,
                                         with_grad=kw["quantize"] is None,
                                         cache=False, **kw))
        b = _pattern_bsr(gen, rng, scale, 0.05)
        if b.nblocks:
            for kw in SPGEMM_GRID:
                label = (f"spgemm/{name} lanes={kw['n_lanes']} "
                         f"unroll={kw['unroll']} pf={kw['prefetch']}")
                check(label, api.plan_matmul(a, b, policy="segment",
                                             cache=False, **kw))

    # random BSR patterns (denser than the structural families)
    for density in (0.25, 0.6):
        a = BSR.random(rng, (scale, scale), BLOCK, density)
        for kw in SPMM_GRID:
            label = (f"spmm/random{density} lanes={kw['n_lanes']} "
                     f"unroll={kw['unroll']} quant={kw['quantize']} "
                     f"pf={kw['prefetch']}")
            check(label, api.plan_matmul(a, policy="segment", cache=False,
                                         **kw))

    # --- degenerate regression cases --------------------------------------
    # single stored block: one item, one lane, no pads
    single = BSR.random(rng, BLOCK, BLOCK, 1.0)
    check("degenerate/single-block", api.plan_matmul(single, cache=False))
    check("degenerate/single-block-lanes",
          api.plan_matmul(single, n_lanes=4, cache=False))
    # n_lanes=1 unpadded
    a = BSR.random(rng, (scale, scale), BLOCK, 0.4)
    check("degenerate/one-lane", api.plan_matmul(a, n_lanes=1, cache=False))
    # empty symbolic C: A's columns never meet B's rows
    gb = scale // BLOCK[0]
    a_lo = BSR(shape=(scale, scale), block_shape=BLOCK,
               brow=np.zeros(1, np.int64), bcol=np.zeros(1, np.int64),
               blocks=np.ones((1,) + BLOCK, np.float32))
    b_hi = BSR(shape=(scale, scale), block_shape=BLOCK,
               brow=np.full(1, gb - 1, np.int64),
               bcol=np.zeros(1, np.int64),
               blocks=np.ones((1,) + BLOCK, np.float32))
    check("degenerate/empty-C", api.plan_matmul(a_lo, b_hi, cache=False))

    # --- autotuned winners -------------------------------------------------
    # every schedule the search can emit must pass the same full-level
    # verifier + VMEM gate the hand-built corpus does (ISSUE satellite 3):
    # autotune each pattern under both objectives and check the winner.
    n_cols = 256
    for name, gen in PATTERNS:
        a = _pattern_bsr(gen, rng, scale, 0.05)
        if a.nblocks == 0:
            continue
        for objective in ("interpret", "tpu"):
            res = tune.autotune_matmul(a, n_cols_hint=n_cols,
                                       objective=objective, cache=False)
            kw = res.plan_kwargs()
            plan = api.plan_matmul(a, cache=False, n_cols_hint=n_cols, **kw)
            bn_eff, _ = pick_bn(n_cols, kw["bn_hint"] or 512)
            check_plan_vmem(plan, bn=bn_eff)  # raises over budget
            label = (f"autotuned/{name} obj={objective} "
                     f"policy={kw['policy']} lanes={kw['n_lanes']} "
                     f"unroll={kw['unroll']} fold={kw['fold_len']} "
                     f"pipe={kw['pipeline']} bn={kw['bn_hint']} "
                     f"pf={kw['prefetch']}")
            check(label, plan)
            n_autotuned += 1

    dt = time.perf_counter() - t0
    status = "FAIL" if n_findings else "OK"
    print(f"{status}: verified {len(records)} plans "
          f"({n_autotuned} autotuned winners) at level={level!r} in "
          f"{dt:.1f}s, {n_findings} finding(s)")
    if json_out:
        artifact = {
            "level": level, "scale": scale, "seed": seed,
            "elapsed_s": round(dt, 3),
            "summary": {"n_plans": len(records),
                        "n_autotuned": n_autotuned,
                        "n_findings": n_findings,
                        "ok": n_findings == 0},
            "plans": records,
        }
        with open(json_out, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"wrote {json_out}")
    return 1 if n_findings else 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--level", choices=("fast", "full"), default="full")
    p.add_argument("--fast", action="store_true",
                   help="shorthand for --level fast (skips the full-level "
                        "traffic-agreement recomputation)")
    p.add_argument("--scale", type=int, default=256,
                   help="square matrix dimension for the pattern corpus")
    p.add_argument("--seed", type=int, default=7)
    p.add_argument("--json", metavar="OUT", default=None,
                   help="write a machine-readable findings artifact here")
    p.add_argument("-q", "--quiet", action="store_true",
                   help="only print failures and the summary line")
    args = p.parse_args(argv)
    level = "fast" if args.fast else args.level
    return sweep(level, args.scale, args.seed, args.quiet,
                 json_out=args.json)


if __name__ == "__main__":
    sys.exit(main())
