"""Serving-engine correctness: the mixed-length oracle (headline bug
regression), steady-state retrace flatness, admission/retirement dynamics,
and cache-overflow validation.

The oracle test is the regression for the lockstep server's padding bug:
left-aligned zero-padded prompts with one shared scalar position meant any
request shorter than its group's max sampled its first token from padding
and decoded every later token at a shifted position.  The continuous
engine must make a batched mixed-length run token-for-token identical to
generating each request alone.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import REGISTRY, reduced_config
from repro.models import build_model
from repro.runtime import Engine, Request, Server

KEY = jax.random.PRNGKey(0)


def _setup(arch="granite-3-8b", **over):
    # f32 so greedy argmax is bitwise batch-size invariant on CPU
    cfg = dataclasses.replace(reduced_config(REGISTRY[arch]),
                              dtype="float32", **over)
    model = build_model(cfg)
    return cfg, model, model.init(KEY)


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, l, dtype=np.int32) for l in lens]


# ---------------------------------------------------------------------------
# the headline-bug oracle
# ---------------------------------------------------------------------------


def test_mixed_length_batch_matches_single_request_oracle():
    """Batched mixed-length generation must equal per-request single-slot
    generation token-for-token — no request ever reads padding or a wrong
    position.  slots < requests also exercises retirement + re-admission
    mid-run."""
    cfg, model, params = _setup()
    prompts = _prompts(cfg, (4, 17, 31))

    alone = []
    for p in prompts:
        eng = Engine(model, params, slots=1, max_len=64,
                     prefill_buckets=(16, 8))
        r = Request(prompt=p.copy(), max_new_tokens=6)
        eng.generate([r])
        alone.append(r.out_tokens.tolist())

    eng = Engine(model, params, slots=2, max_len=64, prefill_buckets=(16, 8))
    reqs = [Request(prompt=p.copy(), max_new_tokens=6) for p in prompts]
    eng.generate(reqs)
    batched = [r.out_tokens.tolist() for r in reqs]
    assert batched == alone


def test_mixed_max_new_tokens_no_over_decode():
    """Each request stops at its *own* max_new_tokens (the lockstep server
    decoded everyone to the group max), and shorter budgets are prefixes of
    longer ones from the same prompt."""
    cfg, model, params = _setup()
    prompt = _prompts(cfg, (9,))[0]
    eng = Engine(model, params, slots=2, max_len=64, prefill_buckets=(16, 8))
    reqs = [Request(prompt=prompt.copy(), max_new_tokens=m) for m in (2, 7)]
    eng.generate(reqs)
    a, b = reqs[0].out_tokens.tolist(), reqs[1].out_tokens.tolist()
    assert len(a) == 2 and len(b) == 7
    assert b[:2] == a


def test_eos_frees_slot_early():
    cfg, model, params = _setup()
    prompt = _prompts(cfg, (7,))[0]
    probe = Engine(model, params, slots=1, max_len=64, prefill_buckets=(8,))
    r = Request(prompt=prompt.copy(), max_new_tokens=8)
    probe.generate([r])
    full = r.out_tokens.tolist()
    eos = full[2]
    eng = Engine(model, params, slots=1, max_len=64, prefill_buckets=(8,))
    r2 = Request(prompt=prompt.copy(), max_new_tokens=8, eos_token=eos)
    eng.generate([r2])
    # retired at the first eos occurrence (kept in the output)
    stop = full.index(eos) + 1
    assert r2.out_tokens.tolist() == full[:stop]
    assert eng.completed == 1 and all(s is None for s in eng._slots)


# ---------------------------------------------------------------------------
# steady-state compiled-shape flatness
# ---------------------------------------------------------------------------


def test_no_retrace_across_arrivals_and_retirements():
    """After a warmup wave covering the bucket shapes, further waves of
    different lengths/budgets must not trigger any recompilation."""
    cfg, model, params = _setup()
    eng = Engine(model, params, slots=2, max_len=64, prefill_buckets=(16,))
    # warmup: single-chunk fresh + multi-chunk (fresh + continuation)
    eng.generate([Request(prompt=p, max_new_tokens=3)
                  for p in _prompts(cfg, (5, 20), seed=1)])
    warm = dict(eng.compiled_shapes)
    assert warm["decode"] == 1
    # new arrivals: different lengths, different budgets, queueing + slot
    # churn — all served by the warm shapes
    eng.generate([Request(prompt=p, max_new_tokens=m)
                  for p, m in zip(_prompts(cfg, (3, 21, 13, 16, 30), seed=2),
                                  (2, 5, 1, 4, 3))])
    assert eng.compiled_shapes == warm


def test_persistent_cache_reused_across_generations():
    """The KV cache is allocated once at construction; repeated generate()
    calls reuse the same buffers (no per-batch re-allocation)."""
    cfg, model, params = _setup()
    eng = Engine(model, params, slots=2, max_len=64, prefill_buckets=(16,))
    shapes0 = jax.tree.map(lambda a: a.shape, eng.cache)
    p1, p2 = _prompts(cfg, (6, 12), seed=3)
    eng.generate([Request(prompt=p1, max_new_tokens=2)])
    eng.generate([Request(prompt=p2, max_new_tokens=2)])
    assert jax.tree.map(lambda a: a.shape, eng.cache) == shapes0


def test_slot_reuse_does_not_leak_previous_request():
    """A request admitted into a just-freed slot decodes exactly as it
    would in a fresh engine — admission wipes the previous occupant."""
    cfg, model, params = _setup()
    p_a, p_b = _prompts(cfg, (23, 9), seed=4)
    eng = Engine(model, params, slots=1, max_len=64, prefill_buckets=(16, 8))
    ra = Request(prompt=p_a.copy(), max_new_tokens=5)
    rb = Request(prompt=p_b.copy(), max_new_tokens=5)
    eng.generate([ra, rb])          # rb reuses ra's slot
    fresh = Engine(model, params, slots=1, max_len=64,
                   prefill_buckets=(16, 8))
    rb2 = Request(prompt=p_b.copy(), max_new_tokens=5)
    fresh.generate([rb2])
    assert rb.out_tokens.tolist() == rb2.out_tokens.tolist()


def test_int8_kv_cache_mixed_lengths():
    """The factored-scale int8 KV path is decode-sized (t ≤ 8): the engine
    caps prefill buckets and still matches the single-request oracle."""
    cfg, model, params = _setup(kv_cache_dtype="int8")
    prompts = _prompts(cfg, (4, 17), seed=8)
    alone = []
    for p in prompts:
        e1 = Engine(model, params, slots=1, max_len=64)
        r = Request(prompt=p.copy(), max_new_tokens=4)
        e1.generate([r])
        alone.append(r.out_tokens.tolist())
    eng = Engine(model, params, slots=2, max_len=64)
    assert max(eng.prefill_buckets) <= 8
    reqs = [Request(prompt=p.copy(), max_new_tokens=4) for p in prompts]
    eng.generate(reqs)
    assert [r.out_tokens.tolist() for r in reqs] == alone


def test_recurrent_family_mixed_lengths():
    """Stateful families (hybrid rec + local ring, rwkv) serve mixed
    lengths correctly through the token-wise prefill path."""
    for arch in ("recurrentgemma-9b", "rwkv6-1.6b"):
        cfg, model, params = _setup(arch)
        assert Engine(model, params, slots=1, max_len=64).prefill_buckets \
            == (1,)
        prompts = _prompts(cfg, (3, 14), seed=5)
        alone = []
        for p in prompts:
            e1 = Engine(model, params, slots=1, max_len=64)
            r = Request(prompt=p.copy(), max_new_tokens=4)
            e1.generate([r])
            alone.append(r.out_tokens.tolist())
        eng = Engine(model, params, slots=2, max_len=64)
        reqs = [Request(prompt=p.copy(), max_new_tokens=4) for p in prompts]
        eng.generate(reqs)
        assert [r.out_tokens.tolist() for r in reqs] == alone, arch


# ---------------------------------------------------------------------------
# admission validation (cache-overflow regression)
# ---------------------------------------------------------------------------


def test_overlong_prompt_rejected_not_clamped():
    """Prompt (or prompt + budget) exceeding max_len must raise — the old
    server let dynamic_update_slice clamp the write index, silently
    corrupting the cache tail."""
    cfg, model, params = _setup()
    eng = Engine(model, params, slots=1, max_len=32, prefill_buckets=(16, 8))
    rng = np.random.default_rng(6)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(prompt=rng.integers(0, cfg.vocab, 40,
                                               dtype=np.int32)))
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(prompt=rng.integers(0, cfg.vocab, 30,
                                               dtype=np.int32),
                           max_new_tokens=8))
    with pytest.raises(ValueError, match="empty"):
        eng.submit(Request(prompt=np.zeros((0,), np.int32)))
    # a fitting request on the same engine still serves fine
    ok = Request(prompt=rng.integers(0, cfg.vocab, 24, dtype=np.int32),
                 max_new_tokens=8)
    eng.generate([ok])
    assert ok.out_tokens.shape == (8,)


def test_server_backcompat_surface():
    """The old Server constructor keywords and generate() contract hold."""
    cfg, model, params = _setup()
    srv = Server(model, params, batch_slots=3, max_len=64,
                 prefill_buckets=(16, 8))
    reqs = [Request(prompt=p, max_new_tokens=4)
            for p in _prompts(cfg, (4, 9, 13, 6), seed=7)]
    out = srv.generate(reqs)
    assert out is reqs
    assert all(r.out_tokens.shape == (4,) for r in reqs)


def test_enc_dec_rejected():
    cfg = reduced_config(REGISTRY["whisper-tiny"])
    model = build_model(cfg)
    params = model.init(KEY)
    with pytest.raises(NotImplementedError):
        Engine(model, params)


# ---------------------------------------------------------------------------
# quantized serving (int8/fp8 weight decode through the engine)
# ---------------------------------------------------------------------------


def _sparse_setup(**over):
    return _setup("granite-3-8b", ffn_block_sparse=True, ffn_block=32,
                  ffn_density=0.5, **over)


def test_quantized_engine_matches_single_request_oracle():
    """The mixed-length oracle holds *within* each quantized engine: a
    batched run with slot churn is token-for-token identical to serving
    each request alone on the same quantized weights."""
    cfg, model, params = _sparse_setup()
    prompts = _prompts(cfg, (4, 17, 9), seed=11)
    for mode in ("int8", "fp8"):
        alone = []
        for p in prompts:
            e1 = Engine(model, params, slots=1, max_len=64,
                        prefill_buckets=(16, 8), quantize=mode)
            r = Request(prompt=p.copy(), max_new_tokens=5)
            e1.generate([r])
            alone.append(r.out_tokens.tolist())
        eng = Engine(model, params, slots=2, max_len=64,
                     prefill_buckets=(16, 8), quantize=mode)
        reqs = [Request(prompt=p.copy(), max_new_tokens=5) for p in prompts]
        eng.generate(reqs)
        assert [r.out_tokens.tolist() for r in reqs] == alone, mode


def test_quantized_greedy_drift_bounded():
    """fp32 vs int8 vs fp8 engines on the same mixed-length batch: greedy
    tokens may drift where logits are near-ties, but the drift fraction
    stays small (int8 tighter than fp8)."""
    cfg, model, params = _sparse_setup()
    prompts = _prompts(cfg, (4, 17, 9, 25, 6), seed=12)

    def serve(mode):
        eng = Engine(model, params, slots=2, max_len=64,
                     prefill_buckets=(16, 8), quantize=mode)
        reqs = [Request(prompt=p.copy(), max_new_tokens=6) for p in prompts]
        eng.generate(reqs)
        return [r.out_tokens.tolist() for r in reqs]

    base = serve(None)
    total = sum(len(t) for t in base)
    for mode, bound in (("int8", 0.25), ("int8.rowwise", 0.25),
                        ("fp8", 0.5)):
        out = serve(mode)
        drift = sum(a != b for x, y in zip(base, out) for a, b in zip(x, y))
        assert drift / total <= bound, (mode, drift, total)


def test_quantized_engine_no_retrace():
    """Quantized params keep the engine's retrace-flatness contract: one
    decode trace + the same prefill trace count as the fp32 engine, flat
    across later waves of new lengths/budgets."""
    cfg, model, params = _sparse_setup()

    def warm_counts(mode):
        eng = Engine(model, params, slots=2, max_len=64,
                     prefill_buckets=(16,), quantize=mode)
        eng.generate([Request(prompt=p, max_new_tokens=3)
                      for p in _prompts(cfg, (5, 20), seed=13)])
        return eng, dict(eng.compiled_shapes)

    _, fp32_warm = warm_counts(None)
    eng, warm = warm_counts("int8")
    assert warm["decode"] == 1
    assert warm == fp32_warm
    eng.generate([Request(prompt=p, max_new_tokens=m)
                  for p, m in zip(_prompts(cfg, (3, 21, 13, 30), seed=14),
                                  (2, 5, 1, 3))])
    assert eng.compiled_shapes == warm


def test_quantized_engine_composes_with_int8_kv_cache():
    """int8 weights + int8 KV cache serve together; the bucket cap and the
    single-request oracle both hold."""
    cfg, model, params = _sparse_setup(kv_cache_dtype="int8")
    prompts = _prompts(cfg, (4, 17), seed=15)
    alone = []
    for p in prompts:
        e1 = Engine(model, params, slots=1, max_len=64, quantize="int8")
        r = Request(prompt=p.copy(), max_new_tokens=4)
        e1.generate([r])
        alone.append(r.out_tokens.tolist())
    eng = Engine(model, params, slots=2, max_len=64, quantize="int8")
    assert max(eng.prefill_buckets) <= 8
    reqs = [Request(prompt=p.copy(), max_new_tokens=4) for p in prompts]
    eng.generate(reqs)
    assert [r.out_tokens.tolist() for r in reqs] == alone


def test_engine_quantize_requires_sparse_ffn():
    cfg, model, params = _setup()   # dense SwiGLU FFN
    with pytest.raises(ValueError, match="block-sparse"):
        Engine(model, params, quantize="int8")


def test_int8_kv_long_query_raises_named_error():
    """The decode-size guard on the int8 KV path is a ValueError, not a
    bare assert (serving stacks run under ``python -O``)."""
    import jax.numpy as jnp
    cfg, model, params = _setup(kv_cache_dtype="int8")
    cache = model.init_cache(1, 64)
    with pytest.raises(ValueError, match="decode-sized"):
        model.decode_step(params, cache, jnp.zeros((1, 16), jnp.int32),
                          jnp.int32(0))
