"""Import guard for the optional ``hypothesis`` dependency.

Test modules import the property-testing decorators from here instead of
hard-importing ``hypothesis`` (which killed the whole suite at collection
when it wasn't installed).  With hypothesis present this is a pass-through;
without it, ``@given`` property tests become skips (via
``pytest.importorskip`` at call time, so the skip reason is the standard
missing-module message) while every plain test in the module still runs.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # deliberately NOT functools.wraps: the replacement must expose a
            # zero-arg signature or pytest would treat the strategy kwargs as
            # missing fixtures and error instead of skipping.
            def skipper():
                pytest.importorskip("hypothesis")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Placeholder strategies: inert objects, never drawn from."""

        def __getattr__(self, name):
            return lambda *a, **kw: None

    st = _Strategies()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
