"""repro.api surface: pytree plans, jit transparency, policy registry,
backend parity, the plan cache, N-tiling, and custom-VJP grads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core.formats import BSR

RNG = np.random.default_rng(0)


def _patterns():
    """Square, non-square, and empty-block-row BSR patterns."""
    sq = BSR.random(np.random.default_rng(1), (128, 128), (32, 32), 0.4)
    rect = BSR.random(np.random.default_rng(2), (96, 160), (32, 32), 0.3)
    # empty block rows: zero out two of four row-blocks before tiling
    d = np.random.default_rng(3).standard_normal((128, 96)).astype(np.float32)
    d[0:32] = 0.0
    d[64:96] = 0.0
    holes = BSR.from_dense(d, (32, 32))
    return {"square": sq, "nonsquare": rect, "empty_rows": holes}


# ---------------------------------------------------------------------------
# pytree + jit
# ---------------------------------------------------------------------------


def test_segment_plan_pytree_roundtrip():
    a = _patterns()["nonsquare"]
    plan = api.plan_matmul(a, (a.shape[1], 64), with_grad=True)
    leaves, treedef = jax.tree_util.tree_flatten(plan)
    assert len(leaves) > 0 and all(hasattr(l, "shape") for l in leaves)
    plan2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert plan2.policy == plan.policy
    assert plan2.grid == plan.grid
    assert plan2.fingerprint == plan.fingerprint
    assert plan2.grad_plan is not None
    x = jnp.asarray(RNG.standard_normal((a.shape[1], 64)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(plan2(x)), np.asarray(plan(x)),
                               rtol=1e-5, atol=1e-5)
    # flattening is lossless under tree_map identity too
    plan3 = jax.tree_util.tree_map(lambda l: l, plan)
    assert jax.tree_util.tree_structure(plan3) == treedef


@pytest.mark.parametrize("policy", ["segment", "gustavson", "outer"])
def test_jitted_function_takes_plan_argument(policy):
    for name, a in _patterns().items():
        plan = api.plan_matmul(a, policy=policy)
        x = jnp.asarray(
            RNG.standard_normal((a.shape[1], 64)).astype(np.float32))

        @jax.jit
        def run(p, xx):
            return api.execute_plan(p, xx, bn=64)

        got = np.asarray(run(plan, x))
        want = a.to_dense() @ np.asarray(x)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4,
                                   err_msg=f"{policy}/{name}")


# ---------------------------------------------------------------------------
# policy registry
# ---------------------------------------------------------------------------


def test_unknown_policy_rejected():
    a = _patterns()["square"]
    with pytest.raises(ValueError, match="unknown policy"):
        api.plan_matmul(a, policy="definitely-not-a-policy")
    with pytest.raises(ValueError, match="unknown policy"):
        api.get_policy("nope")


def test_register_custom_policy_roundtrip():
    name = "test-reverse-gustavson"
    api.register_policy(
        name,
        spmm_order=lambda m, k: np.lexsort((k, m))[::-1],
        spgemm_order=lambda m, n, k, c: np.lexsort((k, n, m))[::-1],
        overwrite=True)
    try:
        assert name in api.available_policies()
        a = _patterns()["square"]
        plan = api.plan_matmul(a, policy=name)
        x = jnp.asarray(
            RNG.standard_normal((a.shape[1], 32)).astype(np.float32))
        np.testing.assert_allclose(np.asarray(plan(x, bn=32)),
                                   a.to_dense() @ np.asarray(x),
                                   rtol=1e-4, atol=1e-4)
        with pytest.raises(ValueError, match="already registered"):
            api.register_policy(name, spmm_order=lambda m, k: None,
                                spgemm_order=lambda m, n, k, c: None)
    finally:
        api.unregister_policy(name)
    assert name not in api.available_policies()


def test_reregistered_policy_is_not_served_stale_plans():
    """The cache keys on the policy's registration serial, so redefining a
    name yields a fresh schedule instead of the old definition's."""
    name = "test-volatile"
    a = _patterns()["square"]
    try:
        api.register_policy(
            name, spmm_order=lambda m, k: np.lexsort((k, m)),
            spgemm_order=lambda m, n, k, c: np.lexsort((k, n, m)),
            overwrite=True)
        p1 = api.plan_matmul(a, policy=name)
        api.register_policy(
            name, spmm_order=lambda m, k: np.lexsort((k, m))[::-1],
            spgemm_order=lambda m, n, k, c: np.lexsort((k, n, m))[::-1],
            overwrite=True)
        p2 = api.plan_matmul(a, policy=name)
        assert not np.array_equal(np.asarray(p1.m_idx), np.asarray(p2.m_idx))
        np.testing.assert_array_equal(np.asarray(p1.m_idx),
                                      np.asarray(p2.m_idx)[::-1])
    finally:
        api.unregister_policy(name)


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown backend"):
        api.resolve_backend("tpu-magic")
    a = _patterns()["square"]
    with pytest.raises(ValueError, match="unknown backend"):
        api.plan_matmul(a, backend="tpu-magic")


def test_backend_context_and_default():
    base = api.default_backend()
    assert base in api.available_backends()
    with api.use_backend("reference"):
        assert api.default_backend() == "reference"
    assert api.default_backend() == base


@pytest.mark.parametrize("policy", ["segment", "gustavson", "outer"])
def test_spmm_backend_parity(policy):
    """Pallas-interpret and the jnp reference oracle agree on every
    pattern class (square / non-square / empty block rows)."""
    for name, a in _patterns().items():
        plan = api.plan_matmul(a, policy=policy)
        x = jnp.asarray(
            RNG.standard_normal((a.shape[1], 96)).astype(np.float32))
        y_int = np.asarray(plan(x, bn=32, backend="interpret"))
        y_ref = np.asarray(plan(x, backend="reference"))
        np.testing.assert_allclose(y_int, y_ref, rtol=1e-4, atol=1e-4,
                                   err_msg=f"{policy}/{name}")
        np.testing.assert_allclose(y_ref, a.to_dense() @ np.asarray(x),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("policy", ["segment", "gustavson"])
def test_spgemm_backend_parity(policy):
    a = BSR.random(np.random.default_rng(5), (128, 160), (32, 32), 0.3)
    b = BSR.random(np.random.default_rng(6), (160, 96), (32, 32), 0.3)
    plan = api.plan_matmul(a, b, policy=policy)
    got_int = np.asarray(plan(backend="interpret"))
    got_ref = np.asarray(plan(backend="reference"))
    np.testing.assert_allclose(got_int, got_ref, rtol=1e-4, atol=1e-4)
    want = a.to_dense() @ b.to_dense()
    for i, (r, c) in enumerate(zip(plan.c_brow, plan.c_bcol)):
        np.testing.assert_allclose(
            got_ref[i], want[r * 32:(r + 1) * 32, c * 32:(c + 1) * 32],
            rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# N-tiling (the old ``n % bn == 0`` crash)
# ---------------------------------------------------------------------------


def test_pick_bn_divisor_and_pad():
    bn, pad = api.pick_bn(384, 512)      # divisor path: shrink to N
    assert bn == 384 and pad == 0
    bn, pad = api.pick_bn(384, 256)      # divisor path: largest divisor
    assert bn == 192 and pad == 0
    bn, pad = api.pick_bn(251, 128)      # prime N: pad-and-slice
    assert pad > 0 and (251 + pad) % bn == 0
    bn, pad = api.pick_bn(64, 512)       # bn clamped to N
    assert bn == 64 and pad == 0


@pytest.mark.parametrize("n", [384, 250, 251, 100])
def test_spmm_arbitrary_n(n):
    a = _patterns()["square"]
    plan = api.plan_matmul(a)
    x = jnp.asarray(RNG.standard_normal((a.shape[1], n)).astype(np.float32))
    got = np.asarray(plan(x, bn=512))
    np.testing.assert_allclose(got, a.to_dense() @ np.asarray(x),
                               rtol=1e-4, atol=1e-4)


def test_legacy_shim_arbitrary_n():
    """The deprecated ops.plan_spmm path inherits the N-tiling fix."""
    from repro.kernels import ops
    a = _patterns()["square"]
    with pytest.deprecated_call():
        plan = ops.plan_spmm(a)
    x = jnp.asarray(RNG.standard_normal((a.shape[1], 384)).astype(np.float32))
    got = np.asarray(plan(x, bn=512))
    np.testing.assert_allclose(got, a.to_dense() @ np.asarray(x),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------


def test_plan_cache_hits_and_fresh_values():
    api.clear_plan_cache()
    a = BSR.random(np.random.default_rng(7), (64, 64), (32, 32), 0.9)
    p1 = api.plan_matmul(a)
    s1 = api.plan_cache_stats()
    assert s1["misses"] == 1 and s1["hits"] == 0
    # same pattern, different values: cache hit, values re-realized
    a2 = BSR(a.shape, a.block_shape, a.brow.copy(), a.bcol.copy(),
             a.blocks * 3.0)
    p2 = api.plan_matmul(a2)
    s2 = api.plan_cache_stats()
    assert s2["hits"] == 1 and s2["misses"] == 1
    assert p2.fingerprint == p1.fingerprint
    x = jnp.asarray(RNG.standard_normal((64, 32)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(p2(x, bn=32)),
                               3.0 * np.asarray(p1(x, bn=32)),
                               rtol=1e-4, atol=1e-4)
    # different policy -> different fingerprint, miss
    api.plan_matmul(a, policy="outer")
    assert api.plan_cache_stats()["misses"] == 2
    api.clear_plan_cache()
    assert api.plan_cache_stats()["size"] == 0


def test_plan_cache_counts_uncached_builds_as_misses():
    """Bench runs with cache=False must still report honest miss counts —
    every template build is a miss whether or not the entry is kept."""
    api.clear_plan_cache()
    a = BSR.random(np.random.default_rng(13), (64, 64), (32, 32), 0.9)
    api.plan_matmul(a, cache=False)
    api.plan_matmul(a, cache=False)
    s = api.plan_cache_stats()
    assert s["misses"] == 2 and s["hits"] == 0 and s["size"] == 0
    api.clear_plan_cache()


def test_plan_cache_buckets_dense_widths():
    """The dense-N hint is folded into the cache key *bucketed* to the next
    power of two: nearby widths share one entry (640 and 768 → 1024), but
    widths an order of magnitude apart (64 vs 640) get separate entries —
    the regression the old hint-blind key allowed, where a 640-wide
    caller was served pricing keyed to a 64-wide build."""
    api.clear_plan_cache()
    a = BSR.random(np.random.default_rng(12), (64, 64), (32, 32), 0.9)
    p1 = api.plan_matmul(a, (64, 64))
    p2 = api.plan_matmul(a, (64, 640))
    s = api.plan_cache_stats()
    assert s["misses"] == 2 and s["hits"] == 0   # different buckets
    p3 = api.plan_matmul(a, (64, 768))           # same 1024 bucket as 640
    s = api.plan_cache_stats()
    assert s["misses"] == 2 and s["hits"] == 1
    # traffic still reflects each caller's exact N, bucket-mates included
    assert p2.traffic["total"] > p1.traffic["total"]
    assert p3.traffic["total"] > p2.traffic["total"]
    assert p2.traffic["b_fetches"] == p1.traffic["b_fetches"]
    api.clear_plan_cache()


# ---------------------------------------------------------------------------
# custom VJP
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["interpret", "reference"])
def test_apply_plan_grads_match_dense(backend):
    a = BSR.random(np.random.default_rng(8), (96, 128), (32, 32), 0.4)
    plan = api.plan_matmul(a, with_grad=True)
    x = jnp.asarray(RNG.standard_normal((128, 48)).astype(np.float32))

    def loss(blocks, xx):
        return jnp.sum(api.apply_plan(plan.with_values(blocks), xx,
                                      backend=backend) ** 2)

    gb, gx = jax.grad(loss, argnums=(0, 1))(plan.lhs_blocks, x)

    w = jnp.asarray(a.to_dense())
    gw, gx_d = jax.grad(
        lambda w_, xx: jnp.sum((w_ @ xx) ** 2), argnums=(0, 1))(w, x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_d),
                               rtol=1e-3, atol=1e-3)
    brow, bcol = np.asarray(plan.a_brow), np.asarray(plan.a_bcol)
    gwn = np.asarray(gw)
    gbn = np.asarray(gb)
    for j in range(plan.n_blocks):
        r, c = int(brow[j]), int(bcol[j])
        np.testing.assert_allclose(
            gbn[j], gwn[r * 32:(r + 1) * 32, c * 32:(c + 1) * 32],
            rtol=1e-3, atol=1e-3)


def test_apply_plan_without_grad_plan_raises():
    a = _patterns()["square"]
    plan = api.plan_matmul(a)   # no with_grad
    x = jnp.asarray(RNG.standard_normal((a.shape[1], 32)).astype(np.float32))
    with pytest.raises(ValueError, match="with_grad"):
        jax.grad(lambda xx: jnp.sum(api.apply_plan(plan, xx)))(x)


def test_apply_plan_rejects_spgemm():
    a = BSR.random(np.random.default_rng(9), (64, 64), (32, 32), 0.5)
    b = BSR.random(np.random.default_rng(10), (64, 64), (32, 32), 0.5)
    plan = api.plan_matmul(a, b)
    with pytest.raises(ValueError, match="spmm"):
        api.apply_plan(plan, jnp.zeros((64, 32)))


def test_plan_matmul_shape_validation():
    a = _patterns()["square"]
    with pytest.raises(ValueError, match="does not match"):
        api.plan_matmul(a, (a.shape[1] + 32, 64))
    with pytest.raises(NotImplementedError):
        b = BSR.random(np.random.default_rng(11), (128, 64), (32, 32), 0.5)
        api.plan_matmul(a, b, with_grad=True)
