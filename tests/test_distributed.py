"""Distributed-feature tests on 8 fake devices (subprocess isolation so the
main test process keeps its single-device jax)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, devices: int = 8, timeout: int = 420) -> str:
    script = ("import os\n"
              f"os.environ['XLA_FLAGS'] = "
              f"'--xla_force_host_platform_device_count={devices}'\n"
              + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=timeout, env=env, cwd=ROOT)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_trainer_matches_single_device():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import REGISTRY, reduced_config
    from repro.configs.base import ShapeConfig
    from repro.models import build_model
    from repro.runtime import Trainer, TrainerConfig
    from repro.launch.mesh import make_test_mesh, mesh_context

    cfg = reduced_config(REGISTRY["granite-3-8b"])
    shape = ShapeConfig("t", "train", seq_len=32, global_batch=8)
    tc = TrainerConfig(steps=3, log_every=1, accum_steps=2)
    mesh = make_test_mesh(4, 2)
    t_mesh = Trainer(build_model(cfg), cfg, shape, tc, mesh=mesh)
    with mesh_context(mesh):
        out_mesh = t_mesh.run()
    t_one = Trainer(build_model(cfg), cfg, shape, tc)
    out_one = t_one.run()
    for a, b in zip(out_mesh["history"], out_one["history"]):
        assert abs(a["loss"] - b["loss"]) < 1e-3, (a, b)
    print("MESH_OK", out_mesh["final_loss"])
    """)
    assert "MESH_OK" in out


def test_compressed_dp_allreduce():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.optim import AdamW, constant
    from repro.runtime.compression import init_error_fb, make_compressed_dp_step

    from repro.launch.mesh import make_mesh, mesh_context
    mesh = make_mesh((8,), ("data",))
    w_true = jnp.asarray(np.random.default_rng(0).standard_normal(16),
                         dtype=jnp.float32)

    def loss_fn(params, batch):
        x, y = batch
        pred = x @ params["w"]
        return jnp.mean((pred - y) ** 2)

    opt = AdamW(lr=constant(0.05), weight_decay=0.0)
    params = {"w": jnp.zeros(16)}
    ef = init_error_fb(params, 8)
    assert ef["w"].shape == (8, 16)
    state = (params, opt.init(params), ef)
    step = make_compressed_dp_step(loss_fn, opt, mesh, method="int8")
    rng = np.random.default_rng(1)
    losses = []
    with mesh_context(mesh):
        for i in range(60):
            x = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
            y = x @ w_true
            state, loss = step(state, (x, y))
            losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.05, (losses[0], losses[-1])
    # the residual is genuinely per-device state: each dp rank quantizes a
    # different batch shard, so the carried rows must differ — the old
    # replicated P() out_spec kept one rank's residual for everyone
    ef = np.asarray(state[2]["w"])
    assert ef.shape == (8, 16)
    n_distinct = len({r.tobytes() for r in ef})
    assert n_distinct > 1, "error-feedback rows collapsed to one device"
    print("COMPRESS_OK", losses[0], "->", losses[-1], "rows", n_distinct)
    """)
    assert "COMPRESS_OK" in out


def test_rescale_accum_never_shrinks_effective_batch():
    """Ceil-divide regression: dp 8→6 with 64-token global batch used to
    floor to accum=1 (effective 48); it must round up and report the
    overshoot."""
    from repro.runtime.elastic import rescale_accum

    accum, eff = rescale_accum(64, old_dp=8, new_dp=6, old_accum=1)
    assert accum == 2 and eff == 96          # never below the 64 target
    # exact division stays exact
    accum, eff = rescale_accum(64, old_dp=8, new_dp=4, old_accum=1)
    assert accum == 2 and eff == 64
    accum, eff = rescale_accum(256, old_dp=8, new_dp=8, old_accum=2)
    assert accum == 2 and eff == 256
    # effective batch is always >= the requested global batch
    for gb, od, nd, oa in ((64, 8, 6, 1), (128, 16, 10, 2), (96, 8, 5, 4)):
        accum, eff = rescale_accum(gb, od, nd, oa)
        assert eff >= gb, (gb, od, nd, oa, accum, eff)


def test_pipeline_parallel_matches_sequential():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.runtime.pipeline_parallel import pipeline_forward

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4,), ("stage",))
    rng = np.random.default_rng(0)
    n_stages, n_micro, mb, d = 4, 6, 3, 8
    ws = jnp.asarray(rng.standard_normal((n_stages, d, d)) * 0.3,
                     jnp.float32)
    x = jnp.asarray(rng.standard_normal((n_micro, mb, d)), jnp.float32)

    def stage_fn(w, a):
        return jnp.tanh(a @ w)

    got = pipeline_forward(stage_fn, ws, x, mesh=mesh, n_micro=n_micro)
    want = x
    for s in range(n_stages):
        want = jax.vmap(lambda a: stage_fn(ws[s], a))(want)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    print("PIPELINE_OK")
    """)
    assert "PIPELINE_OK" in out


def test_elastic_restore_onto_smaller_mesh():
    out = _run("""
    import tempfile
    import jax, jax.numpy as jnp, numpy as np
    from repro.checkpoint import CheckpointManager
    from repro.configs import REGISTRY, reduced_config
    from repro.models import build_model
    from repro.optim import AdamW, constant
    from repro.runtime.elastic import make_elastic_mesh, restore_onto_mesh

    cfg = reduced_config(REGISTRY["qwen1.5-4b"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=constant(1e-3))
    state = (params, opt.init(params))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(7, state, wait=True)
        # "lose" half the devices: 8 → 4, keep model_parallel = 2
        survivors = jax.devices()[:4]
        mesh = make_elastic_mesh(survivors, model_parallel=2)
        assert dict(zip(mesh.axis_names, mesh.devices.shape)) == {
            "data": 2, "model": 2}
        restored = restore_onto_mesh(mgr, 7, state, mesh)
        r0 = jax.tree.leaves(restored[0])[0]
        assert len(r0.sharding.device_set) <= 4
        # values intact
        a = np.asarray(jax.tree.leaves(state[0])[0])
        b = np.asarray(jax.tree.leaves(restored[0])[0])
        np.testing.assert_allclose(a, b)
    print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_dryrun_cell_small_mesh():
    """A miniature dry-run on 8 devices: lower+compile a reduced arch on a
    4×2 mesh with the same sharding rules as the production mesh."""
    out = _run("""
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import REGISTRY, reduced_config
    from repro.models import build_model
    from repro.launch.mesh import make_test_mesh, mesh_context
    from repro.sharding import make_shardings, params_pspecs, batch_pspecs

    cfg = reduced_config(REGISTRY["phi3.5-moe-42b-a6.6b"])
    model = build_model(cfg)
    mesh = make_test_mesh(4, 2)
    ap = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    psh = make_shardings(mesh, params_pspecs(ap), ap)
    specs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
             "targets": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    bsh = make_shardings(mesh, batch_pspecs(mesh, specs))

    def loss(params, batch):
        return model.loss_fn(params, batch)[0]

    with mesh_context(mesh):
        c = jax.jit(loss, in_shardings=(psh, bsh)).lower(ap, specs).compile()
    assert c.cost_analysis() is not None
    print("MINI_DRYRUN_OK")
    """)
    assert "MINI_DRYRUN_OK" in out
