"""Double-buffered DMA pipeline: fetch-flag schedule invariants, ring-slot
discipline, kernel parity across lanes × unroll × quantized ×
``transpose_lhs``, the pad-masking regression (masked is derived from the
plan's real pad state, not the lane/unroll shape), and the
``partition_lanes`` accum_prev write-before-read validation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional-dep guard

from repro import api
from repro.core.formats import BSR
from repro.core.schedule import (build_spmm_schedule, fetch_flags,
                                 finalize_schedule, partition_lanes)

RNG = np.random.default_rng(0)


def _patterns():
    rand = BSR.random(np.random.default_rng(1), (128, 160), (32, 32), 0.35)
    d = np.random.default_rng(2).standard_normal((128, 96)).astype(np.float32)
    d[0:32] = 0.0
    d[64:96] = 0.0
    holes = BSR.from_dense(d, (32, 32))
    one_row = BSR.from_dense(
        np.random.default_rng(3).standard_normal((32, 256)).astype(np.float32),
        (32, 32))
    return {"random": rand, "empty_rows": holes, "one_segment": one_row}


# ---------------------------------------------------------------------------
# fetch_flags unit behavior
# ---------------------------------------------------------------------------


def test_fetch_flags_first_item_reuse_and_pads():
    # one lane: [5, 5, 7, 7(pad)] — first fetches, second reuses, third
    # fetches, pad moves nothing
    stream = np.array([5, 5, 7, 7])
    valid = np.array([1, 1, 1, 0])
    fetch, slot = fetch_flags(stream, valid, 1, depth=2)
    np.testing.assert_array_equal(fetch, [1, 0, 1, 0])
    # ring advances one slot per fetch; reuse stays on the resident slot
    np.testing.assert_array_equal(slot, [0, 0, 1, 1])


def test_fetch_flags_lane_boundary_always_fetches():
    # the same index on both sides of a lane cut must still fetch: lanes are
    # independent passes, residency never crosses them
    stream = np.array([3, 3, 3, 3])
    valid = np.ones(4, np.int64)
    fetch, _ = fetch_flags(stream, valid, 2, depth=2)
    np.testing.assert_array_equal(fetch.reshape(2, 2)[:, 0], [1, 1])


def test_fetch_flags_ring_depth():
    stream = np.arange(8)
    valid = np.ones(8, np.int64)
    fetch, slot = fetch_flags(stream, valid, 1, depth=4)
    assert fetch.sum() == 8
    np.testing.assert_array_equal(slot, np.arange(8) % 4)
    with pytest.raises(ValueError, match=r"depth must be >= 2"):
        fetch_flags(stream, valid, 1, depth=1)
    with pytest.raises(ValueError, match=r"matching shapes"):
        fetch_flags(stream, valid[:4], 1)
    with pytest.raises(ValueError, match=r"not divisible by\s+n_lanes=3"):
        fetch_flags(stream, valid, 3)


# ---------------------------------------------------------------------------
# plan-level fetch schedule invariants
# ---------------------------------------------------------------------------


def _check_fetch_schedule(plan, b_stream_leaf):
    """Shared invariant battery for a plan's DMA fetch schedule."""
    n_lanes, lane_len = plan.n_lanes, plan.lane_len
    depth = 2 * plan.unroll
    valid = np.asarray(plan.valid).reshape(n_lanes, lane_len).astype(bool)
    af = np.asarray(plan.a_fetch).reshape(n_lanes, lane_len)
    bf = np.asarray(plan.b_fetch).reshape(n_lanes, lane_len)
    # a lane's first item always fetches both streams
    np.testing.assert_array_equal(af[:, 0], 1)
    np.testing.assert_array_equal(bf[:, 0], 1)
    # pads never fetch
    assert not af[~valid].any() and not bf[~valid].any()
    # flags are the traffic model's revisit deltas (per-item, within-lane)
    b_stream = np.asarray(b_stream_leaf).reshape(n_lanes, lane_len)
    delta = np.ones_like(b_stream, dtype=bool)
    delta[:, 1:] = b_stream[:, 1:] != b_stream[:, :-1]
    np.testing.assert_array_equal(bf.astype(bool), delta & valid)
    # modeled fetch counts ARE the flag sums
    assert plan.traffic["a_fetches"] == int(af.sum())
    assert plan.traffic["b_fetches"] == int(bf.sum())
    # ring slots advance one slot per fetch and stay inside the ring
    for fl, sl in ((af, plan.a_slot), (bf, plan.b_slot)):
        sl = np.asarray(sl).reshape(n_lanes, lane_len)
        assert sl.min() >= 0 and sl.max() < depth
        want = np.maximum(np.cumsum(fl, axis=1) - 1, 0) % depth
        np.testing.assert_array_equal(sl, want)


@pytest.mark.parametrize("n_lanes,unroll", [(1, 1), (2, 1), (2, 2), (4, 2)])
def test_plan_fetch_schedule_invariants(n_lanes, unroll):
    for name, a in _patterns().items():
        plan = api.plan_matmul(a, n_cols_hint=64, n_lanes=n_lanes,
                               unroll=unroll, fold_len=3, cache=False)
        _check_fetch_schedule(plan, plan.k_idx)
        # has_pads reflects the actual schedule, not the lane/unroll shape
        assert plan.has_pads == bool(
            (np.asarray(plan.valid) == 0).any()), name


def test_spgemm_plan_fetch_schedule_invariants():
    a = BSR.random(np.random.default_rng(6), (128, 160), (32, 32), 0.4)
    b = BSR.random(np.random.default_rng(7), (160, 96), (32, 32), 0.4)
    for n_lanes in (1, 3):
        plan = api.plan_matmul(a, b, n_lanes=n_lanes, cache=False)
        _check_fetch_schedule(plan, plan.b_idx)


def test_grad_plan_carries_fetch_schedule():
    a = BSR.random(np.random.default_rng(8), (96, 128), (32, 32), 0.4)
    plan = api.plan_matmul(a, with_grad=True, n_lanes=2, cache=False)
    g = plan.grad_plan
    assert g.a_fetch is not None and g.b_slot is not None
    _check_fetch_schedule(g, g.k_idx)


# ---------------------------------------------------------------------------
# double-buffer parity: lanes × unroll × quantized × transpose_lhs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("quantize", [None, "int8"])
@pytest.mark.parametrize("n_lanes,unroll", [(1, 2), (2, 1), (3, 2)])
def test_pipeline_parity_vs_dense(n_lanes, unroll, quantize):
    for name, a in _patterns().items():
        plan = api.plan_matmul(a, policy="segment", n_lanes=n_lanes,
                               unroll=unroll, fold_len=3, quantize=quantize)
        x = jnp.asarray(
            RNG.standard_normal((a.shape[1], 64)).astype(np.float32))
        want = a.to_dense() @ np.asarray(x)
        got = np.asarray(plan(x, bn=32, backend="interpret"))
        got_ref = np.asarray(plan(x, bn=32, backend="reference"))
        if quantize is None:
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4,
                                       err_msg=f"{name}")
        else:
            norm = max(np.abs(want).max(), 1e-6)
            assert np.abs(got - want).max() / norm < 5e-2, name
        # interpret and reference agree on the *stored* (quantized) values
        np.testing.assert_allclose(got, got_ref, rtol=1e-4, atol=1e-4,
                                   err_msg=f"{name}")


def test_pipeline_parity_fp8():
    a = _patterns()["random"]
    plan = api.plan_matmul(a, n_lanes=2, unroll=2, fold_len=3,
                           quantize="fp8")
    x = jnp.asarray(RNG.standard_normal((a.shape[1], 64)).astype(np.float32))
    got = np.asarray(plan(x, bn=32, backend="interpret"))
    got_ref = np.asarray(plan(x, bn=32, backend="reference"))
    np.testing.assert_allclose(got, got_ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("quantize", [None, "int8"])
def test_transpose_lhs_pipeline_parity(quantize):
    """The backward (transpose_lhs) schedule runs the same DMA pipeline
    against forward storage — dx must match the dense oracle."""
    a = BSR.random(np.random.default_rng(9), (96, 128), (32, 32), 0.5)
    plan = api.plan_matmul(a, with_grad=True, n_lanes=2, unroll=2,
                           fold_len=4, quantize=quantize, cache=False)
    x = jnp.asarray(RNG.standard_normal((128, 48)).astype(np.float32))

    def loss(xx):
        return jnp.sum(api.apply_plan(plan, xx, backend="interpret") ** 2)

    gx = np.asarray(jax.grad(loss)(x))
    dense = (api.dequantize_blocks(
                 api.QuantizedBlocks(np.asarray(plan.lhs_blocks),
                                     np.asarray(plan.lhs_scales), quantize))
             if quantize else np.asarray(plan.lhs_blocks))
    w = np.zeros(a.shape, np.float32)
    for s in range(a.nblocks):
        r, c = int(a.brow[s]), int(a.bcol[s])
        w[r * 32:(r + 1) * 32, c * 32:(c + 1) * 32] = dense[s]
    gx_d = np.asarray(jax.grad(
        lambda xx: jnp.sum((jnp.asarray(w) @ xx) ** 2))(x))
    np.testing.assert_allclose(gx, gx_d, rtol=1e-3, atol=1e-3)


def test_spgemm_pipeline_parity_quantized():
    a = BSR.random(np.random.default_rng(10), (128, 160), (32, 32), 0.35)
    b = BSR.random(np.random.default_rng(11), (160, 96), (32, 32), 0.35)
    want = a.to_dense() @ b.to_dense()
    plan = api.plan_matmul(a, b, n_lanes=2, unroll=2, quantize="int8")
    got = np.asarray(plan(backend="interpret"))
    ref = np.asarray(plan(backend="reference"))
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)
    norm = max(np.abs(want).max(), 1e-6)
    for i, (r, c) in enumerate(zip(plan.c_brow, plan.c_bcol)):
        blk = want[r * 32:(r + 1) * 32, c * 32:(c + 1) * 32]
        assert np.abs(got[i] - blk).max() / norm < 5e-2


def test_pipelined_matches_legacy_kernel():
    """pipeline=True and pipeline=False are the same computation."""
    from repro.kernels.segment_spmm import segment_spmm
    a = _patterns()["random"]
    plan = api.plan_matmul(a, n_lanes=2, unroll=2, fold_len=3)
    x = jnp.asarray(RNG.standard_normal((a.shape[1], 64)).astype(np.float32))
    kw = dict(grid_m=plan.grid[0], n_lanes=plan.n_lanes, bn=32,
              unroll=plan.unroll, masked=True, interpret=True)
    args = (plan.lhs_blocks, plan.slot_idx, plan.m_idx, plan.k_idx,
            plan.seg_start, plan.seg_write, plan.accum_prev, plan.valid, x)
    pip = np.asarray(segment_spmm(
        *args, **kw, a_fetch=plan.a_fetch, b_fetch=plan.b_fetch,
        a_slot=plan.a_slot, b_slot=plan.b_slot))
    leg = np.asarray(segment_spmm(*args, **kw, pipeline=False))
    np.testing.assert_allclose(pip, leg, rtol=1e-5, atol=1e-5)


def test_pipeline_true_requires_fetch_arrays():
    from repro.kernels.segment_spmm import segment_spmm
    a = _patterns()["random"]
    plan = api.plan_matmul(a, n_lanes=2)
    x = jnp.ones((a.shape[1], 32), jnp.float32)
    with pytest.raises(ValueError, match=r"pipeline=True needs"):
        segment_spmm(plan.lhs_blocks, plan.slot_idx, plan.m_idx, plan.k_idx,
                     plan.seg_start, plan.seg_write, plan.accum_prev,
                     plan.valid, x, grid_m=plan.grid[0],
                     n_lanes=plan.n_lanes, bn=32, interpret=True,
                     pipeline=True)


# ---------------------------------------------------------------------------
# masked derivation regression: pads on a single-lane unroll=1 schedule
# (the old executor keyed masking on `n_lanes > 1 or unroll > 1` and would
# silently accumulate pad garbage here)
# ---------------------------------------------------------------------------


def _insert_pad(arr, pos, value):
    arr = np.asarray(arr)
    return jnp.asarray(np.insert(arr, pos, np.asarray(value, arr.dtype)))


def test_padded_single_lane_spmm_masks_pads():
    a = BSR.from_dense(
        np.random.default_rng(12).standard_normal((64, 96)).astype(np.float32),
        (32, 32))
    plan = api.plan_matmul(a, n_cols_hint=64, cache=False)
    assert plan.n_lanes == 1 and plan.unroll == 1 and not plan.has_pads
    # inject a valid=0 item in the middle of the first segment: index leaves
    # repeat the previous item (re-addressing the resident tiles), flag
    # leaves are zero, fetch flags are zero — exactly what a fetch-flag pad
    # or a custom registry policy may produce
    pos = 1
    prev = pos - 1
    padded = plan.replace(
        slot_idx=_insert_pad(plan.slot_idx, pos, plan.slot_idx[prev]),
        m_idx=_insert_pad(plan.m_idx, pos, plan.m_idx[prev]),
        k_idx=_insert_pad(plan.k_idx, pos, plan.k_idx[prev]),
        seg_start=_insert_pad(plan.seg_start, pos, 0),
        seg_write=_insert_pad(plan.seg_write, pos, 0),
        accum_prev=_insert_pad(plan.accum_prev, pos, 0),
        valid=_insert_pad(plan.valid, pos, 0),
        a_fetch=_insert_pad(plan.a_fetch, pos, 0),
        b_fetch=_insert_pad(plan.b_fetch, pos, 0),
        a_slot=_insert_pad(plan.a_slot, pos, plan.a_slot[prev]),
        b_slot=_insert_pad(plan.b_slot, pos, plan.b_slot[prev]),
        has_pads=True)
    x = jnp.asarray(RNG.standard_normal((96, 64)).astype(np.float32))
    want = a.to_dense() @ np.asarray(x)
    got = np.asarray(padded(x, bn=32, backend="interpret"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_padded_single_lane_spgemm_masks_pads():
    a = BSR.from_dense(
        np.random.default_rng(13).standard_normal((64, 64)).astype(np.float32),
        (32, 32))
    b = BSR.from_dense(
        np.random.default_rng(14).standard_normal((64, 64)).astype(np.float32),
        (32, 32))
    plan = api.plan_matmul(a, b, cache=False)
    assert plan.n_lanes == 1 and plan.unroll == 1 and not plan.has_pads
    pos = 1
    prev = pos - 1
    padded = plan.replace(
        a_idx=_insert_pad(plan.a_idx, pos, plan.a_idx[prev]),
        b_idx=_insert_pad(plan.b_idx, pos, plan.b_idx[prev]),
        c_idx=_insert_pad(plan.c_idx, pos, plan.c_idx[prev]),
        seg_start=_insert_pad(plan.seg_start, pos, 0),
        seg_write=_insert_pad(plan.seg_write, pos, 0),
        accum_prev=_insert_pad(plan.accum_prev, pos, 0),
        valid=_insert_pad(plan.valid, pos, 0),
        a_fetch=_insert_pad(plan.a_fetch, pos, 0),
        b_fetch=_insert_pad(plan.b_fetch, pos, 0),
        a_slot=_insert_pad(plan.a_slot, pos, plan.a_slot[prev]),
        b_slot=_insert_pad(plan.b_slot, pos, plan.b_slot[prev]),
        has_pads=True)
    want = a.to_dense() @ b.to_dense()
    got = np.asarray(padded(backend="interpret"))
    for i, (r, c) in enumerate(zip(plan.c_brow, plan.c_bcol)):
        np.testing.assert_allclose(
            got[i], want[r * 32:(r + 1) * 32, c * 32:(c + 1) * 32],
            rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# partition_lanes accum_prev write-before-read validation
# ---------------------------------------------------------------------------


def test_partition_lanes_rejects_accum_prev_without_prior_write():
    # two single-item owner chains; the second claims to continue a partial
    # sum (accum_prev=1) but its tile was never written in any lane
    owner = np.array([0, 1])
    with pytest.raises(ValueError, match=r"accum_prev=1 but no earlier "
                                         r"seg_write"):
        partition_lanes(owner, 2, seg_start=np.array([1, 1]),
                        seg_write=np.array([1, 1]),
                        accum_prev=np.array([0, 1]))


def test_partition_lanes_accepts_folded_schedules():
    a = BSR.random(np.random.default_rng(15), (256, 256), (32, 32), 0.3)
    s = build_spmm_schedule(a, "segment", fold_len=2)
    fin = finalize_schedule(s.seg_start, s.m, n_slots=s.n_m_blocks)
    for n_lanes in (1, 2, 4):
        partition_lanes(s.m, n_lanes, unroll=2, seg_start=s.seg_start,
                        seg_write=s.seg_write, accum_prev=fin.accum_prev)


def test_partition_lanes_validation_shape_mismatch():
    with pytest.raises(ValueError, match=r"seg_write has shape"):
        partition_lanes(np.array([0, 1]), 1, seg_start=np.array([1, 1]),
                        seg_write=np.array([1]),
                        accum_prev=np.array([0, 0]))


# ---------------------------------------------------------------------------
# property sweep: pad-heavy unrolled schedules ≡ dense oracle, flags sane
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=12)
@given(seed=st.integers(0, 10_000), gm=st.integers(1, 6),
       gk=st.integers(1, 6), density=st.floats(0.1, 1.0),
       n_lanes=st.sampled_from([1, 2, 4]),
       quantize=st.sampled_from([None, "int8"]))
def test_pipeline_property_vs_dense(seed, gm, gk, density, n_lanes, quantize):
    rng = np.random.default_rng(seed)
    a = BSR.random(rng, (gm * 16, gk * 16), (16, 16), density)
    x = rng.standard_normal((gk * 16, 32)).astype(np.float32)
    # unroll=2 forces group padding on every odd-length segment chain —
    # the pad-heavy configuration the fetch flags must keep silent
    plan = api.plan_matmul(a, policy="segment", n_lanes=n_lanes, unroll=2,
                           fold_len=3, quantize=quantize, cache=False)
    _check_fetch_schedule(plan, plan.k_idx)
    want = a.to_dense() @ x
    got = np.asarray(plan(jnp.asarray(x), bn=32, backend="interpret"))
    ref = np.asarray(plan(jnp.asarray(x), bn=32, backend="reference"))
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)
    if quantize is None:
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    else:
        norm = max(np.abs(want).max(), 1e-6)
        assert np.abs(got - want).max() / norm < 5e-2
