"""SELECTA (Algorithm 1) invariants — unit + hypothesis property tests."""
import numpy as np
from _hypothesis_compat import given, settings, st  # optional-dep guard

from repro.core.formats import CSC, random_csr
from repro.core.selecta import SelectaState, run_selecta, selecta_stats


def _csc(seed, m=24, k=20, density=0.15):
    rng = np.random.default_rng(seed)
    return CSC.from_csr(random_csr(rng, (m, k), density))


def test_batches_cover_all_pairs_once():
    a = _csc(0)
    batches = run_selecta(a, w_max=4, r_max=8)
    seen = [p for b in batches for p in b]
    assert len(seen) == len(set(seen)) == a.nnz


def test_no_m_conflicts_within_batch():
    a = _csc(1)
    for batch in run_selecta(a, w_max=8, r_max=8):
        ms = [m for m, _ in batch]
        assert len(ms) == len(set(ms)), "same output row twice in one batch"


def test_batch_size_bounded():
    a = _csc(2)
    for batch in run_selecta(a, w_max=8, r_max=5):
        assert 0 < len(batch) <= 5


def test_window_bound_respected():
    a = _csc(3)
    st_ = SelectaState(a=a, w_max=3, r_max=8)
    while not st_.done:
        assert len(st_.window) <= 3
        st_.select()


def test_dynamic_k_increases_sharing():
    """Greedy max-occupancy ordering should share k at least as much as a
    fixed one-k-at-a-time order packs slots."""
    a = _csc(4, m=64, k=48, density=0.2)
    dyn = selecta_stats(run_selecta(a, 32, 16, dynamic_k=True), 16)
    fix = selecta_stats(run_selecta(a, 32, 16, dynamic_k=False), 16)
    assert dyn["occupancy"] >= fix["occupancy"] - 1e-9
    assert dyn["pairs"] == fix["pairs"] == a.nnz


def test_k_filter_skips_inactive():
    a = _csc(5)
    k_active = np.zeros(a.shape[1], dtype=bool)
    k_active[::2] = True
    st_ = SelectaState(a=a, w_max=8, r_max=8, k_active=k_active)
    while not st_.done:
        for _, k in st_.select():
            assert k_active[k]


@settings(deadline=None, max_examples=25)
@given(seed=st.integers(0, 10_000), w=st.integers(1, 16), r=st.integers(1, 16),
       density=st.floats(0.05, 0.6))
def test_selecta_properties(seed, w, r, density):
    rng = np.random.default_rng(seed)
    a = CSC.from_csr(random_csr(rng, (16, 16), density))
    batches = run_selecta(a, w_max=w, r_max=r)
    seen = set()
    for batch in batches:
        assert len(batch) <= r
        ms = [m for m, _ in batch]
        assert len(ms) == len(set(ms))
        for p in batch:
            assert p not in seen
            seen.add(p)
    assert len(seen) == a.nnz
