"""End-to-end behaviour tests for the paper's system: the Segment dataflow
produces correct SpGEMM results end-to-end through every layer (element
reference → block schedule → Pallas kernel) and the simulator reproduces the
paper's headline ordering."""
import numpy as np
import jax.numpy as jnp

from repro.core.formats import BSR, CSC, random_csr
from repro.core.segmentbc import segment_spgemm_elementwise
from repro.kernels import ops
from repro.sim import matrices
from repro.sim.baselines import flexagon_best, spada
from repro.sim.segfold_sim import SegFoldConfig, simulate_segfold


def test_three_layer_consistency():
    """Element-level Segment dataflow, block-level Segment schedule, and
    the Pallas kernel all compute the same product."""
    rng = np.random.default_rng(0)
    a = random_csr(rng, (128, 160), 0.08)
    b = random_csr(rng, (160, 96), 0.08)
    want = a.to_dense() @ b.to_dense()

    # layer 1: faithful element-granularity Segment dataflow
    c1, _ = segment_spgemm_elementwise(CSC.from_csr(a), b, mapping="lut")
    np.testing.assert_allclose(c1, want, atol=1e-4)

    # layer 2+3: block schedule + Pallas kernel (interpret)
    A = BSR.from_dense(a.to_dense(), (32, 32))
    B = BSR.from_dense(b.to_dense(), (32, 32))
    plan = ops.plan_spgemm(A, B, policy="segment")
    blocks = np.asarray(plan())
    got = np.zeros_like(want)
    for i, (r, c) in enumerate(zip(plan.c_brow, plan.c_bcol)):
        got[r * 32:(r + 1) * 32, c * 32:(c + 1) * 32] = blocks[i]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_paper_headline_ordering():
    """SegFold < Spada < best-static on a representative suite matrix
    (Fig. 8's qualitative claim)."""
    rng = np.random.default_rng(1)
    a = matrices.banded(rng, 1024, 1024, 0.01)
    b = a.transpose()
    cfg = SegFoldConfig(cache_bytes=300 * 1024)
    seg = simulate_segfold(a, b, cfg).cycles
    spa = spada(a, b, cfg).cycles
    sta = flexagon_best(a, b, cfg)["cycles"]
    assert seg < spa < sta * 1.2  # static usually worst; allow slack vs spada
