import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional-dep guard

from repro.core.formats import (BSR, CSC, CSR, DCSR, csr_from_coo, random_csr,
                                spgemm_reference)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def test_csr_roundtrip(rng):
    a = random_csr(rng, (37, 53), 0.1)
    d = a.to_dense()
    b = CSR.from_dense(d)
    assert np.allclose(b.to_dense(), d)
    assert b.nnz == a.nnz


def test_transpose(rng):
    a = random_csr(rng, (20, 30), 0.15)
    assert np.allclose(a.transpose().to_dense(), a.to_dense().T)


def test_csc(rng):
    a = random_csr(rng, (20, 30), 0.15)
    c = CSC.from_csr(a)
    assert np.allclose(c.to_dense(), a.to_dense())
    for k in range(30):
        rows, vals = c.col(k)
        assert np.all(np.diff(rows) > 0)  # sorted, unique


def test_dcsr_skips_empty_rows(rng):
    d = np.zeros((10, 8), np.float32)
    d[2, 3] = 1.0
    d[7, 1] = 2.0
    a = CSR.from_dense(d)
    dc = DCSR.from_csr(a)
    assert list(dc.row_ids) == [2, 7]
    assert dc.lookup(2) == 0
    assert dc.lookup(3) == -1


def test_bsr_roundtrip(rng):
    a = rng.standard_normal((64, 96)).astype(np.float32)
    a[a < 0.8] = 0  # sparsify
    b = BSR.from_dense(a, (16, 16))
    assert np.allclose(b.to_dense(), a)


def test_bsr_random_density(rng):
    b = BSR.random(rng, (256, 256), (32, 32), 0.25)
    assert 0 < b.block_density <= 1.0
    assert b.blocks.shape[1:] == (32, 32)


def test_spgemm_reference(rng):
    a = random_csr(rng, (15, 20), 0.2)
    b = random_csr(rng, (20, 12), 0.2)
    c = spgemm_reference(a, b)
    assert np.allclose(c.to_dense(), a.to_dense() @ b.to_dense(), atol=1e-5)


@settings(deadline=None, max_examples=30)
@given(m=st.integers(1, 30), n=st.integers(1, 30),
       density=st.floats(0.01, 0.5), seed=st.integers(0, 1000))
def test_csr_dense_roundtrip_property(m, n, density, seed):
    rng = np.random.default_rng(seed)
    a = random_csr(rng, (m, n), density)
    assert np.allclose(CSR.from_dense(a.to_dense()).to_dense(), a.to_dense())
    # rows sorted by construction
    for i in range(m):
        cols, _ = a.row(i)
        assert np.all(np.diff(cols) > 0)
