"""Quantized BSR block storage: round-trip bounds, three-way backend
parity, quantized vjp, plan-cache dtype keying, and zero-block safety."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional-dep guard

from repro import api
from repro.core.formats import (BSR, QUANT_DTYPES, QuantizedBlocks,
                                dequantize_blocks, quant_error_bound,
                                quantize_blocks)

RNG = np.random.default_rng(0)

#: Normalized (max |got - want| / max |want|) tolerance vs the dense *fp32*
#: oracle on the small test cases here — the documented CI bounds (int8
#: 5e-2, fp8 1e-1) apply to the larger bench case; these are tighter.
REL_TOL = {"int8": 5e-2, "fp8": 1e-1}


def _random_bsr(seed=1, shape=(128, 160), block=(32, 32), density=0.35):
    return BSR.random(np.random.default_rng(seed), shape, block, density)


def _dequant_dense(a: BSR, dtype: str) -> np.ndarray:
    """Dense matrix of ``a`` after a quantize→dequantize round trip — the
    exact value a quantized plan computes (up to fp32 matmul rounding)."""
    q = quantize_blocks(a.blocks, dtype)
    deq = BSR(a.shape, a.block_shape, a.brow, a.bcol, dequantize_blocks(q))
    return deq.to_dense()


# ---------------------------------------------------------------------------
# round-trip helpers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", ["int8", "fp8"])
def test_quantize_roundtrip_error_bound(dtype):
    blocks = RNG.standard_normal((9, 16, 16)).astype(np.float32)
    blocks[3] = 0.0                      # an exactly-zero block
    blocks[5] *= 100.0                   # large-magnitude block
    q = quantize_blocks(blocks, dtype)
    assert q.payload.dtype == QUANT_DTYPES[dtype]
    assert q.scales.dtype == np.float32
    assert (q.scales > 0).all()          # zero block must not zero the scale
    deq = dequantize_blocks(q)
    assert np.isfinite(deq).all()
    amax = np.abs(blocks).max(axis=(1, 2))
    bound = np.maximum(amax, 0.0) * quant_error_bound(dtype) + 1e-7
    assert (np.abs(blocks - deq) <= bound[:, None, None]).all()
    # the zero block round-trips to exactly zero
    np.testing.assert_array_equal(deq[3], 0.0)


def test_quantize_rejects_unknown_dtype():
    blocks = np.zeros((1, 4, 4), np.float32)
    with pytest.raises(ValueError, match="unknown quantized block dtype"):
        quantize_blocks(blocks, "int4")
    with pytest.raises(ValueError, match="unknown quantize dtype"):
        api.plan_matmul(_random_bsr(), quantize="int4")
    with pytest.raises(ValueError, match="blocks must be"):
        quantize_blocks(np.zeros((4, 4), np.float32), "int8")


# ---------------------------------------------------------------------------
# three-way backend parity (pallas-interpret / reference / dense oracle)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", ["int8", "fp8"])
@pytest.mark.parametrize("n_lanes", [1, 2])
def test_spmm_three_way_parity(dtype, n_lanes):
    a = _random_bsr()
    x = jnp.asarray(RNG.standard_normal((a.shape[1], 48)).astype(np.float32))
    plan = api.plan_matmul(a, x.shape, quantize=dtype, n_lanes=n_lanes)
    assert plan.quantized and plan.block_dtype == dtype
    got_i = np.asarray(plan(x, bn=16, backend="interpret"))
    got_r = np.asarray(plan(x, backend="reference"))
    # interpret and reference compute the *same* dequantized product
    np.testing.assert_allclose(got_i, got_r, rtol=1e-4, atol=1e-4)
    # both match the dequantized dense matmul tightly
    want_q = _dequant_dense(a, dtype) @ np.asarray(x)
    np.testing.assert_allclose(got_i, want_q, rtol=1e-3, atol=1e-3)
    # and the original fp32 oracle within the dtype's normalized bound
    want = a.to_dense() @ np.asarray(x)
    rel = np.abs(got_i - want).max() / np.abs(want).max()
    assert rel < REL_TOL[dtype], (dtype, rel)


@pytest.mark.parametrize("dtype", ["int8", "fp8"])
def test_spgemm_three_way_parity(dtype):
    a = _random_bsr(6, (128, 160), (32, 32), 0.3)
    b = _random_bsr(7, (160, 96), (32, 32), 0.3)
    plan = api.plan_matmul(a, b, quantize=dtype, n_lanes=2)
    got_i = np.asarray(plan(backend="interpret"))
    got_r = np.asarray(plan(backend="reference"))
    np.testing.assert_allclose(got_i, got_r, rtol=1e-4, atol=1e-4)
    want_q = _dequant_dense(a, dtype) @ _dequant_dense(b, dtype)
    want = a.to_dense() @ b.to_dense()
    norm = np.abs(want).max()
    for i, (r, c) in enumerate(zip(plan.c_brow, plan.c_bcol)):
        tile_q = want_q[r * 32:(r + 1) * 32, c * 32:(c + 1) * 32]
        np.testing.assert_allclose(got_i[i], tile_q, rtol=1e-3, atol=1e-3)
        tile = want[r * 32:(r + 1) * 32, c * 32:(c + 1) * 32]
        assert np.abs(got_i[i] - tile).max() / norm < REL_TOL[dtype]


def test_quantized_zero_block_produces_finite_output():
    """A block that is exactly zero must not poison the plan with NaN/inf
    (its scale is clamped to 1.0; payload stays zero)."""
    blocks = np.stack([np.zeros((32, 32), np.float32),
                       RNG.standard_normal((32, 32)).astype(np.float32)])
    a = BSR(shape=(64, 32), block_shape=(32, 32),
            brow=np.array([0, 1], np.int32), bcol=np.array([0, 0], np.int32),
            blocks=blocks)
    x = jnp.asarray(RNG.standard_normal((32, 16)).astype(np.float32))
    for dtype in ("int8", "fp8"):
        plan = api.plan_matmul(a, x.shape, quantize=dtype)
        got = np.asarray(plan(x, bn=16, backend="interpret"))
        assert np.isfinite(got).all()
        np.testing.assert_array_equal(got[:32], 0.0)  # zero block row
        want = a.to_dense() @ np.asarray(x)
        assert np.abs(got - want).max() / np.abs(want).max() < REL_TOL[dtype]


# ---------------------------------------------------------------------------
# quantized vjp (transpose_lhs backward against the quantized storage)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["interpret", "reference"])
def test_quantized_vjp_dx_matches_dequantized_dense(backend):
    a = _random_bsr(8, (96, 128), (32, 32), 0.4)
    plan = api.plan_matmul(a, with_grad=True, quantize="int8", n_lanes=2)
    assert plan.grad_plan.transpose_lhs
    assert plan.grad_plan.block_dtype == "int8"
    x = jnp.asarray(RNG.standard_normal((128, 48)).astype(np.float32))

    def loss(xx):
        return jnp.sum(api.apply_plan(plan, xx, backend=backend) ** 2)

    gx = jax.grad(loss)(x)
    w_deq = jnp.asarray(_dequant_dense(a, "int8"))
    gx_d = jax.grad(lambda xx: jnp.sum((w_deq @ xx) ** 2))(x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_d),
                               rtol=1e-3, atol=1e-3)


def test_quantized_payload_cotangent_is_symbolic_zero():
    """int8 payloads are frozen inference storage: the weight leaf gets a
    float0 cotangent while x-gradients flow normally."""
    a = _random_bsr(9, (64, 64), (32, 32), 0.5)
    plan = api.plan_matmul(a, with_grad=True, quantize="int8")
    x = jnp.asarray(RNG.standard_normal((64, 8)).astype(np.float32))
    out, vjp = jax.vjp(
        lambda p, xx: api.apply_plan(p, xx, backend="interpret"), plan, x)
    dplan, dx = vjp(jnp.ones_like(out))
    assert dplan.lhs_blocks.dtype == jax.dtypes.float0
    assert np.isfinite(np.asarray(dx)).all()


# ---------------------------------------------------------------------------
# plan cache: dtype keying + per-dtype stats
# ---------------------------------------------------------------------------


def test_fp32_and_quantized_plans_never_collide():
    api.clear_plan_cache()
    a = _random_bsr(10)
    p32 = api.plan_matmul(a, n_cols_hint=64)
    p8 = api.plan_matmul(a, n_cols_hint=64, quantize="int8")
    pf8 = api.plan_matmul(a, n_cols_hint=64, quantize="fp8")
    assert len({p32.fingerprint, p8.fingerprint, pf8.fingerprint}) == 3
    stats = api.plan_cache_stats()
    assert stats["size"] == 3 and stats["misses"] == 3
    assert stats["by_dtype"] == {"fp32": 1, "int8": 1, "fp8": 1}
    # same pattern + dtype is a hit (and realizes fresh quantized values)
    p8b = api.plan_matmul(a, n_cols_hint=64, quantize="int8")
    assert p8b.fingerprint == p8.fingerprint
    assert api.plan_cache_stats()["hits"] == 1
    api.clear_plan_cache()
    assert api.plan_cache_stats()["by_dtype"] == {}


def test_quantized_traffic_reprices_a_bytes():
    a = _random_bsr(11, (256, 256), (64, 64), 0.25)
    t32 = api.plan_matmul(a, n_cols_hint=64).traffic
    t8 = api.plan_matmul(a, n_cols_hint=64, quantize="int8").traffic
    # payload byte + 4 scale bytes per 64x64 tile vs 4 bytes/elem
    expect = t32["a_bytes"] / (64 * 64 * 4) * (64 * 64 + 4)
    assert t8["a_bytes"] == pytest.approx(expect)
    assert t8["b_bytes"] == t32["b_bytes"] and t8["c_bytes"] == t32["c_bytes"]
    assert t8["total"] < t32["total"]


# ---------------------------------------------------------------------------
# zero-copy realize of pre-quantized payloads + out_dtype plumbing
# ---------------------------------------------------------------------------


def test_prequantized_payload_uploads_verbatim():
    a = _random_bsr(12)
    q = quantize_blocks(a.blocks, "int8")
    qdev = QuantizedBlocks(payload=jnp.asarray(q.payload),
                           scales=jnp.asarray(q.scales), dtype="int8")
    a_q = BSR(a.shape, a.block_shape, a.brow, a.bcol, qdev)
    plan = api.plan_matmul(a_q, quantize="int8", cache=False)
    assert plan.lhs_blocks is qdev.payload      # same device buffers
    assert plan.lhs_scales is qdev.scales
    with pytest.raises(ValueError, match="pre-quantized"):
        api.plan_matmul(a_q, quantize="fp8", cache=False)


def test_out_dtype_plumbed_through_plan_and_overridable():
    a = _random_bsr(13)
    x = jnp.asarray(RNG.standard_normal((a.shape[1], 32)).astype(np.float32))
    plan = api.plan_matmul(a, x.shape, quantize="int8", out_dtype=jnp.bfloat16)
    assert plan.out_dtype == "bfloat16"
    for backend in ("interpret", "reference"):
        assert plan(x, bn=16, backend=backend).dtype == jnp.bfloat16
    # per-call override beats the plan default
    assert plan(x, bn=16, backend="interpret",
                out_dtype=jnp.float32).dtype == jnp.float32
    # default stays float32 when unset
    p2 = api.plan_matmul(a, x.shape)
    assert p2.out_dtype is None
    assert p2(x, bn=16, backend="interpret").dtype == jnp.float32


# ---------------------------------------------------------------------------
# quantized inference layers
# ---------------------------------------------------------------------------


def test_sparse_linear_quantize_matches_and_keeps_config():
    from repro.models.sparse_ffn import SparseLinear
    layer, params = SparseLinear.create(jax.random.PRNGKey(0), 128, 64,
                                        block=32, density=0.4)
    x = jnp.asarray(RNG.standard_normal((8, 128)).astype(np.float32))
    with api.use_backend("interpret"):
        y = layer.apply(params, x)
        qlayer, qparams = layer.quantize(params, "int8")
        yq = qlayer.apply(qparams, x)
    assert qlayer.plan.block_dtype == "int8"
    # lane/unroll config survives the rebuild
    assert qlayer.plan.n_lanes == layer.plan.n_lanes
    assert qlayer.plan.unroll == layer.plan.unroll
    rel = float(jnp.abs(y - yq).max() / jnp.abs(y).max())
    assert rel < REL_TOL["int8"], rel


def test_with_values_rejects_mismatched_storage_dtype():
    """A quantized plan fed fp32 values would apply its stale per-block
    scales to them (silently ~wrong output); the reverse feeds a raw
    payload into an fp32 plan with no scales.  Both must raise."""
    a = _random_bsr(14)
    p32 = api.plan_matmul(a)
    p8 = api.plan_matmul(a, quantize="int8")
    with pytest.raises(ValueError, match="stores int8 payloads"):
        p8.with_values(jnp.asarray(a.blocks))
    with pytest.raises(ValueError, match="stores fp32 blocks"):
        p32.with_values(p8.lhs_blocks)
    # matching dtypes pass through
    assert p8.with_values(p8.lhs_blocks).lhs_blocks is p8.lhs_blocks
    # ...and the layer-level misuse the guard is for:
    from repro.models.sparse_ffn import SparseLinear
    layer, params = SparseLinear.create(jax.random.PRNGKey(2), 64, 64,
                                        block=32, density=0.5)
    qlayer, _qparams = layer.quantize(params, "int8")
    x = jnp.asarray(RNG.standard_normal((4, 64)).astype(np.float32))
    with pytest.raises(ValueError, match="stores int8 payloads"):
        qlayer.apply(params, x)   # stale fp32 params into quantized layer


def test_sparse_linear_rejects_double_quantization():
    """Re-quantizing a quantized layer would read the int8 payload as fp32
    weights and drop the scales — must raise, not corrupt silently."""
    from repro.models.sparse_ffn import SparseLinear
    layer, params = SparseLinear.create(jax.random.PRNGKey(1), 64, 64,
                                        block=32, density=0.5)
    qlayer, qparams = layer.quantize(params, "int8")
    with pytest.raises(ValueError, match="already quantized"):
        qlayer.quantize(qparams, "int8")
    with pytest.raises(ValueError, match="already quantized"):
        layer.quantize(qparams, "fp8")   # quantized params, fp32 layer


# ---------------------------------------------------------------------------
# property sweep: pattern × dtype ≡ dequantized oracle, bounded vs fp32
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 10_000), gm=st.integers(1, 5),
       gk=st.integers(1, 5), density=st.floats(0.15, 1.0),
       dtype=st.sampled_from(["int8", "fp8"]))
def test_quant_property_roundtrip_and_parity(seed, gm, gk, density, dtype):
    rng = np.random.default_rng(seed)
    a = BSR.random(rng, (gm * 16, gk * 16), (16, 16), density)
    # round trip obeys the per-block bound
    q = quantize_blocks(a.blocks, dtype)
    deq = dequantize_blocks(q)
    amax = np.abs(a.blocks).max(axis=(1, 2))
    bound = amax * quant_error_bound(dtype) + 1e-7
    assert (np.abs(a.blocks - deq) <= bound[:, None, None]).all()
    # backend parity on the quantized plan
    x = rng.standard_normal((gk * 16, 32)).astype(np.float32)
    plan = api.plan_matmul(a, x.shape, quantize=dtype)
    got = np.asarray(plan(jnp.asarray(x), bn=16, backend="interpret"))
    got_r = np.asarray(plan(jnp.asarray(x), backend="reference"))
    np.testing.assert_allclose(got, got_r, rtol=1e-4, atol=1e-4)
    deq_bsr = BSR(a.shape, a.block_shape, a.brow, a.bcol, deq)
    want_q = deq_bsr.to_dense() @ x
    np.testing.assert_allclose(got, want_q, rtol=1e-3, atol=1e-3)
    want = a.to_dense() @ x
    norm = max(float(np.abs(want).max()), 1e-3)
    assert np.abs(got - want).max() / norm < REL_TOL[dtype]


# ---------------------------------------------------------------------------
# sub-block (per-row-of-block) scales: "*.rowwise" modes
# ---------------------------------------------------------------------------


def _outlier_bsr(seed=21, shape=(128, 160), block=(32, 32), density=0.35):
    """BSR whose blocks each carry one large-magnitude row — the case
    per-block scales handle worst and per-row scales are built for."""
    a = BSR.random(np.random.default_rng(seed), shape, block, density)
    a.blocks[:, 3, :] *= 50.0
    return a


@pytest.mark.parametrize("mode", ["int8.rowwise", "fp8.rowwise"])
def test_rowwise_roundtrip_error_bound(mode):
    base = mode.split(".", 1)[0]
    blocks = RNG.standard_normal((9, 16, 16)).astype(np.float32)
    blocks[3] = 0.0
    blocks[5, 7] *= 100.0                # one outlier row
    q = quantize_blocks(blocks, mode)
    assert q.dtype == mode
    assert q.payload.dtype == QUANT_DTYPES[base]
    assert q.scales.shape == (9, 16)     # one fp32 scale per block row
    assert (q.scales > 0).all()
    deq = dequantize_blocks(q)
    assert np.isfinite(deq).all()
    # the bound is the *per-row* absmax fraction — strictly tighter than
    # the per-block bound wherever rows differ in magnitude
    amax_row = np.abs(blocks).max(axis=2)
    bound = amax_row * quant_error_bound(mode) + 1e-7
    assert (np.abs(blocks - deq) <= bound[:, :, None]).all()
    np.testing.assert_array_equal(deq[3], 0.0)


@pytest.mark.parametrize("base", ["int8", "fp8"])
def test_rowwise_tightens_outlier_rows(base):
    """On blocks with a magnitude-outlier row, per-row scales beat
    per-block scales: the non-outlier rows keep their own resolution."""
    a = _outlier_bsr()
    err = {m: np.linalg.norm(a.blocks - dequantize_blocks(
        quantize_blocks(a.blocks, m)))
        for m in (base, base + ".rowwise")}
    assert err[base + ".rowwise"] < err[base]


@pytest.mark.parametrize("mode", ["int8.rowwise", "fp8.rowwise"])
@pytest.mark.parametrize("pipeline", [True, False])
def test_rowwise_spmm_three_way_parity(mode, pipeline):
    a = _outlier_bsr()
    x = jnp.asarray(RNG.standard_normal((a.shape[1], 48)).astype(np.float32))
    plan = api.plan_matmul(a, x.shape, quantize=mode, n_lanes=2,
                           pipeline=pipeline, verify="full")
    assert plan.quantized and plan.block_dtype == mode
    assert plan.lhs_scales.shape == (a.nblocks, a.block_shape[0])
    got_i = np.asarray(plan(x, bn=16, backend="interpret"))
    got_r = np.asarray(plan(x, backend="reference"))
    np.testing.assert_allclose(got_i, got_r, rtol=1e-4, atol=1e-4)
    want_q = _dequant_dense(a, mode) @ np.asarray(x)
    np.testing.assert_allclose(got_i, want_q, rtol=1e-3, atol=1e-3)
    want = a.to_dense() @ np.asarray(x)
    rel = np.abs(got_i - want).max() / np.abs(want).max()
    assert rel < REL_TOL[mode.split(".", 1)[0]], (mode, rel)


@pytest.mark.parametrize("mode", ["int8.rowwise", "fp8.rowwise"])
def test_rowwise_spgemm_parity(mode):
    a = _outlier_bsr(22, (128, 160), (32, 32), 0.3)
    b = _outlier_bsr(23, (160, 96), (32, 32), 0.3)
    plan = api.plan_matmul(a, b, quantize=mode, n_lanes=2, verify="full")
    # B-side rowwise scales run over the contraction rows (bk)
    assert plan.rhs_scales.shape == (b.nblocks, 32)
    got_i = np.asarray(plan(backend="interpret"))
    got_r = np.asarray(plan(backend="reference"))
    np.testing.assert_allclose(got_i, got_r, rtol=1e-4, atol=1e-4)
    want_q = _dequant_dense(a, mode) @ _dequant_dense(b, mode)
    for i, (r, c) in enumerate(zip(plan.c_brow, plan.c_bcol)):
        tile_q = want_q[r * 32:(r + 1) * 32, c * 32:(c + 1) * 32]
        np.testing.assert_allclose(got_i[i], tile_q, rtol=1e-3, atol=1e-3)


def test_rowwise_vjp_dx_matches_dequantized_dense():
    """transpose_lhs rowwise kernels dequantize pre-dot, so the backward
    x-gradient matches the dequantized dense oracle."""
    a = _outlier_bsr(24)
    plan = api.plan_matmul(a, with_grad=True, quantize="int8.rowwise",
                           n_lanes=2)
    x = jnp.asarray(RNG.standard_normal((a.shape[1], 24)).astype(np.float32))
    gx = jax.grad(lambda xx: jnp.sum(
        api.apply_plan(plan, xx, backend="interpret") ** 2))(x)
    w_deq = jnp.asarray(_dequant_dense(a, "int8.rowwise"))
    gx_d = jax.grad(lambda xx: jnp.sum((w_deq @ xx) ** 2))(x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_d),
                               rtol=1e-3, atol=1e-3)


def test_rowwise_and_per_block_plans_never_collide():
    """The full mode string is the plan's block_dtype: "int8" and
    "int8.rowwise" plans of the same pattern get distinct fingerprints and
    distinct cache entries."""
    a = _random_bsr(25)
    api.clear_plan_cache()
    p_blk = api.plan_matmul(a, quantize="int8")
    p_row = api.plan_matmul(a, quantize="int8.rowwise")
    assert p_blk.block_dtype == "int8" and p_row.block_dtype == "int8.rowwise"
    assert p_blk.fingerprint != p_row.fingerprint
    assert p_blk.lhs_scales.ndim == 1 and p_row.lhs_scales.ndim == 2
    stats = api.plan_cache_stats()
    assert stats["by_dtype"].get("int8") == 1
    assert stats["by_dtype"].get("int8.rowwise") == 1


def test_rowwise_traffic_prices_scale_rows():
    """Rowwise A-fetch traffic carries bm fp32 scales per block fetch where
    per-block carries one; payload bytes are identical."""
    a = _random_bsr(26)
    t_blk = api.plan_matmul(a, quantize="int8", cache=False).traffic
    t_row = api.plan_matmul(a, quantize="int8.rowwise", cache=False).traffic
    bm = 32
    n_fetch = t_blk["a_bytes"] / (bm * bm * 1 + 4)
    assert t_row["a_bytes"] == pytest.approx(n_fetch * (bm * bm * 1 + bm * 4))


def test_rowwise_scale_agreement_verifier():
    """verify_plan(level="full") passes a healthy rowwise plan and flags a
    scale array of the wrong granularity."""
    a = _random_bsr(27)
    plan = api.plan_matmul(a, quantize="int8.rowwise", cache=False)
    plan.verify(level="full").raise_if_findings()
    bad = plan.replace(lhs_scales=plan.lhs_scales[:, :1])
    findings = bad.verify(level="fast").findings
    assert any(f.invariant == "scale-agreement" and "per block row"
               in f.message for f in findings)


def test_sparse_linear_quantize_carries_full_planner_config():
    """Regression: quantize() used to rebuild the plan with only
    lanes/unroll/backend, silently dropping the pipeline switch and the
    tuned bn_hint."""
    from repro.models.sparse_ffn import SparseLinear
    layer, params = SparseLinear.create(jax.random.PRNGKey(3), 128, 64,
                                        block=32, density=0.4)
    tuned = SparseLinear(plan=layer.plan.replace(pipeline=False, bn_hint=128),
                         d_out=64, d_in=128)
    qlayer, _ = tuned.quantize(params, "int8")
    assert qlayer.plan.pipeline is False
    assert qlayer.plan.bn_hint == 128
    assert qlayer.plan.n_lanes == tuned.plan.n_lanes
    assert qlayer.plan.unroll == tuned.plan.unroll


def test_sparse_linear_quantize_fold_plan_raises():
    """fold_len is not recorded on a plan, so quantize() on a fold-built
    layer must raise instead of silently re-planning without the fold."""
    from repro.models.sparse_ffn import SparseLinear
    a = BSR.random(np.random.default_rng(28), (128, 256), (32, 32), 0.8)
    plan = api.plan_matmul(a, policy="segment", fold_len=2, with_grad=True,
                           cache=False)
    assert np.any(np.asarray(plan.accum_prev))   # the fold actually folded
    layer = SparseLinear(plan=plan, d_out=128, d_in=256)
    with pytest.raises(ValueError, match="fold_len"):
        layer.quantize({"blocks": np.asarray(plan.lhs_blocks)}, "int8")


# ---------------------------------------------------------------------------
# whole-model quantization (Transformer.quantize)
# ---------------------------------------------------------------------------


def _sparse_model():
    import dataclasses
    from repro.configs import REGISTRY, reduced_config
    from repro.models import build_model
    cfg = dataclasses.replace(reduced_config(REGISTRY["phi3-mini-3.8b"]),
                              dtype="float32", ffn_block_sparse=True,
                              ffn_block=32, ffn_density=0.5)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(4))


@pytest.mark.parametrize("mode", ["int8", "int8.rowwise"])
def test_transformer_quantize_param_tree_and_logits(mode):
    cfg, model, params = _sparse_model()
    qmodel, qparams = model.quantize(params, mode)
    assert qmodel.sparse_mlp.up.plan.block_dtype == mode
    # FFN leaves became payload + scales with the layer stacking intact
    for proj in ("up", "gate", "down"):
        leaf32 = params["layers"]["mlp"][proj]
        leaf = qparams["layers"]["mlp"][proj]
        assert leaf["blocks"].dtype == QUANT_DTYPES["int8"]
        assert leaf["blocks"].shape == leaf32["blocks"].shape
        n_layers, n_blocks = leaf32["blocks"].shape[:2]
        want_scales = ((n_layers, n_blocks, 32) if mode.endswith("rowwise")
                       else (n_layers, n_blocks))
        assert leaf["scales"].shape == want_scales
    # non-FFN params pass through untouched
    assert qparams["embed"] is params["embed"]
    assert qparams["layers"]["attn"] is params["layers"]["attn"]
    # forward logits stay close to fp32
    toks = (jnp.arange(2 * 8).reshape(2, 8) * 13) % cfg.vocab
    with api.use_backend("interpret"):
        lo32, _ = model.forward(params, toks)
        loq, _ = qmodel.forward(qparams, toks)
    rel = float(jnp.abs(loq - lo32).max() / jnp.abs(lo32).max())
    assert rel < REL_TOL["int8"], rel
    # the original model+params still serve fp32 (no in-place mutation)
    assert model.sparse_mlp.up.plan.block_dtype == "fp32"


def test_transformer_quantize_rejects_double_and_dense():
    import dataclasses
    from repro.configs import REGISTRY, reduced_config
    from repro.models import build_model
    _, model, params = _sparse_model()
    qmodel, qparams = model.quantize(params, "int8")
    with pytest.raises(ValueError, match="already quantized"):
        qmodel.quantize(qparams, "int8")
    with pytest.raises(ValueError, match="already quantized"):
        model.quantize(qparams, "int8")
    dense_cfg = dataclasses.replace(
        reduced_config(REGISTRY["phi3-mini-3.8b"]), dtype="float32")
    dense = build_model(dense_cfg)
    with pytest.raises(ValueError, match="block-sparse"):
        dense.quantize(dense.init(jax.random.PRNGKey(5)), "int8")
