"""Segment SpMM layer: forward + custom VJP vs dense-masked autodiff oracle."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sparse_ffn import SparseLinear, SparseMLP


def _dense_of(layer, params):
    """Reassemble the dense weight from BSR blocks (original order)."""
    s = layer.fwd_s
    bm, bk = s.bm, s.bk
    w = np.zeros((s.grid_m * bm, s.grid_k * bk), np.float32)
    blocks = np.asarray(params["blocks"], np.float32)
    # fwd_s.m/k are in schedule order over perm'd blocks
    perm = np.asarray(s.perm)
    for j in range(len(perm)):
        r, c = int(np.asarray(s.m)[j]), int(np.asarray(s.k)[j])
        w[r * bm:(r + 1) * bm, c * bk:(c + 1) * bk] = blocks[perm[j]]
    return w[: layer.d_out, : layer.d_in]


def test_sparse_linear_forward():
    key = jax.random.PRNGKey(0)
    layer, params = SparseLinear.create(key, 128, 192, block=32, density=0.4)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 128))
    y = layer.apply(params, x)
    w = _dense_of(layer, params)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) @ w.T,
                               rtol=1e-4, atol=1e-4)


def test_sparse_linear_grads_vs_dense_masked():
    key = jax.random.PRNGKey(2)
    layer, params = SparseLinear.create(key, 64, 96, block=32, density=0.5)
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 64))

    def loss_sparse(p, x_):
        return jnp.sum(layer.apply(p, x_) ** 2)

    gp, gx = jax.grad(loss_sparse, argnums=(0, 1))(params, x)

    w = jnp.asarray(_dense_of(layer, params))

    def loss_dense(w_, x_):
        return jnp.sum((x_ @ w_.T) ** 2)

    gw_dense, gx_dense = jax.grad(loss_dense, argnums=(0, 1))(w, x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_dense),
                               rtol=1e-3, atol=1e-3)
    # block grads must equal the dense grad restricted to the block pattern
    s = layer.fwd_s
    perm = np.asarray(s.perm)
    gw = np.asarray(gw_dense)
    gb = np.asarray(gp["blocks"])
    for j in range(len(perm)):
        r, c = int(np.asarray(s.m)[j]), int(np.asarray(s.k)[j])
        np.testing.assert_allclose(
            gb[perm[j]], gw[r * 32:(r + 1) * 32, c * 32:(c + 1) * 32],
            rtol=1e-3, atol=1e-3)


def test_sparse_mlp_forward_finite_and_trains():
    key = jax.random.PRNGKey(4)
    mlp, params = SparseMLP.create(key, 64, 128, block=32, density=0.5)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 64))
    y = mlp.apply(params, x)
    assert y.shape == (2, 16, 64)
    g = jax.grad(lambda p: jnp.sum(mlp.apply(p, x) ** 2))(params)
    gn = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
