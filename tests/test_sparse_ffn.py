"""Segment SpMM layer: forward + custom VJP vs dense-masked autodiff oracle.

The layer is backed by ``repro.api``: its plan is a pytree and the trainable
blocks live in the params dict in original BSR storage order
(``plan.a_brow``/``a_bcol`` give each stored block's coordinates directly —
the schedule addresses them through ``slot_idx``, never by reordering).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sparse_ffn import SparseLinear, SparseMLP


def _dense_of(layer, params):
    """Reassemble the dense weight from the storage-ordered blocks."""
    p = layer.plan
    bm, bk = p.block_shape
    gm, gk = p.grid
    w = np.zeros((gm * bm, gk * bk), np.float32)
    blocks = np.asarray(params["blocks"], np.float32)
    brow, bcol = np.asarray(p.a_brow), np.asarray(p.a_bcol)
    for j in range(p.n_blocks):
        r, c = int(brow[j]), int(bcol[j])
        w[r * bm:(r + 1) * bm, c * bk:(c + 1) * bk] = blocks[j]
    return w[: layer.d_out, : layer.d_in]


def test_sparse_linear_forward():
    key = jax.random.PRNGKey(0)
    layer, params = SparseLinear.create(key, 128, 192, block=32, density=0.4)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 128))
    y = layer.apply(params, x)
    w = _dense_of(layer, params)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) @ w.T,
                               rtol=1e-4, atol=1e-4)


def test_sparse_linear_grads_vs_dense_masked():
    key = jax.random.PRNGKey(2)
    layer, params = SparseLinear.create(key, 64, 96, block=32, density=0.5)
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 64))

    def loss_sparse(p, x_):
        return jnp.sum(layer.apply(p, x_) ** 2)

    gp, gx = jax.grad(loss_sparse, argnums=(0, 1))(params, x)

    w = jnp.asarray(_dense_of(layer, params))

    def loss_dense(w_, x_):
        return jnp.sum((x_ @ w_.T) ** 2)

    gw_dense, gx_dense = jax.grad(loss_dense, argnums=(0, 1))(w, x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_dense),
                               rtol=1e-3, atol=1e-3)
    # block grads must equal the dense grad restricted to the block pattern
    p = layer.plan
    brow, bcol = np.asarray(p.a_brow), np.asarray(p.a_bcol)
    gw = np.asarray(gw_dense)
    gb = np.asarray(gp["blocks"])
    for j in range(p.n_blocks):
        r, c = int(brow[j]), int(bcol[j])
        np.testing.assert_allclose(
            gb[j], gw[r * 32:(r + 1) * 32, c * 32:(c + 1) * 32],
            rtol=1e-3, atol=1e-3)


def test_sparse_linear_jits_as_pytree():
    """The plan passes through jit as a closed-over pytree without identity
    hacks; a second trace with substituted values reuses the same layer."""
    key = jax.random.PRNGKey(6)
    layer, params = SparseLinear.create(key, 64, 64, block=32, density=0.6)
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 64))

    @jax.jit
    def f(p, x_):
        return layer.apply(p, x_)

    y = f(params, x)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(x) @ _dense_of(layer, params).T,
                               rtol=1e-4, atol=1e-4)
    # new values, same schedule: no retrace needed, numerics follow values
    params2 = {"blocks": params["blocks"] * 2.0}
    y2 = f(params2, x)
    np.testing.assert_allclose(np.asarray(y2), 2.0 * np.asarray(y),
                               rtol=1e-4, atol=1e-4)


def test_sparse_linear_rejects_ragged_dims():
    import pytest
    from repro.models.layers import sparse_dense_init
    with pytest.raises(ValueError, match="multiples of block"):
        SparseLinear.create(jax.random.PRNGKey(0), 100, 128, block=32)
    with pytest.raises(ValueError, match="multiples of block"):
        sparse_dense_init(jax.random.PRNGKey(0), 64, 100, block=32)


def test_sparse_mlp_forward_finite_and_trains():
    key = jax.random.PRNGKey(4)
    mlp, params = SparseMLP.create(key, 64, 128, block=32, density=0.5)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 64))
    y = mlp.apply(params, x)
    assert y.shape == (2, 16, 64)
    g = jax.grad(lambda p: jnp.sum(mlp.apply(p, x) ** 2))(params)
    gn = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
