"""repro.tune: the analytical schedule search, the cost model, adaptive
dataflow selection through ``plan_matmul(policy="auto")``, the search cache
and its counters, and the legacy-pipeline plan variant the search may emit."""
import numpy as np
import pytest

from repro import api, tune
from repro.analysis.budget import (DEFAULT_VMEM_LIMIT_BYTES, check_plan_vmem,
                                   plan_vmem_bytes)
from repro.analysis.invariants import verify_plan
from repro.core.formats import BSR
from repro.sim.baselines import dataflow_estimates


def _staircase(bm=32, bk=32, stack=1):
    """Banded 'staircase' block pattern whose row k-sets are r0={0}, r1={0},
    r2={0,1}, r3={1} (repeated ``stack`` times down the diagonal).  SELECTA's
    greedy chaining starts at the longest run (r2) and destroys the chain,
    so Gustavson's m-order strictly beats the segment order on B fetches —
    the canonical pattern where a static dataflow wins."""
    base_r = np.array([0, 1, 2, 2, 3])
    base_c = np.array([0, 0, 0, 1, 1])
    brow = np.concatenate([base_r + 4 * s for s in range(stack)])
    bcol = np.concatenate([base_c + 2 * s for s in range(stack)])
    rng = np.random.default_rng(7)
    blocks = rng.standard_normal((brow.size, bm, bk)).astype(np.float32)
    return BSR(shape=(4 * stack * bm, 2 * stack * bk), block_shape=(bm, bk),
               brow=brow.astype(np.int64), bcol=bcol.astype(np.int64),
               blocks=blocks)


def _scattered(seed=11, grid=(16, 16), blk=(16, 16), density=0.2):
    return BSR.random(np.random.default_rng(seed),
                      (grid[0] * blk[0], grid[1] * blk[1]), blk, density)


def _dense(a: BSR) -> np.ndarray:
    bm, bk = a.block_shape
    out = np.zeros(a.shape, np.float32)
    for i, (r, c) in enumerate(zip(a.brow, a.bcol)):
        out[r * bm:(r + 1) * bm, c * bk:(c + 1) * bk] = a.blocks[i]
    return out


@pytest.fixture(autouse=True)
def _fresh_cache():
    api.clear_plan_cache()
    yield
    api.clear_plan_cache()


# ---------------------------------------------------------------------------
# search: feasibility, optimality vs the default point, static gating
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("objective", ["tpu", "interpret"])
def test_winner_no_worse_than_default(objective):
    a = _scattered()
    res = tune.autotune_matmul(a, n_cols_hint=256, objective=objective)
    default = [s for s in res.candidates
               if s.candidate == tune.Candidate("segment", None, 1, 1, 512,
                                                True)]
    assert default, "the default knob point must be in the search space"
    assert res.best.cost_us <= default[0].cost_us
    assert res.best.traffic_total <= default[0].traffic_total * 1.0 + 1e-9 \
        or res.best.cost_us < default[0].cost_us


def test_winner_passes_full_verify_and_vmem():
    a = _scattered()
    res = tune.autotune_matmul(a, n_cols_hint=256)
    plan = api.plan_matmul(a, 256, cache=False, **res.plan_kwargs())
    verify_plan(plan, level="full").raise_if_findings()
    check_plan_vmem(plan, bn=min(res.best.candidate.bn, 256))
    assert res.best.vmem_bytes <= DEFAULT_VMEM_LIMIT_BYTES


def test_candidates_respect_vmem_budget():
    a = _scattered()
    res = tune.autotune_matmul(a, n_cols_hint=256)
    assert all(s.vmem_bytes <= DEFAULT_VMEM_LIMIT_BYTES
               for s in res.candidates)
    # a tiny budget rejects everything, loudly
    with pytest.raises(ValueError, match="VMEM"):
        tune.autotune_matmul(a, n_cols_hint=256, vmem_limit_bytes=1024,
                             cache=False)


def test_pins_are_honoured():
    a = _scattered()
    res = tune.autotune_matmul(
        a, n_cols_hint=256, cache=False,
        pins={"n_lanes": 2, "unroll": 1, "pipeline": True})
    assert all(s.candidate.n_lanes == 2 for s in res.candidates)
    assert all(s.candidate.unroll == 1 for s in res.candidates)
    assert all(s.candidate.pipeline for s in res.candidates)
    assert res.best.candidate.n_lanes == 2


# ---------------------------------------------------------------------------
# search cache + counters
# ---------------------------------------------------------------------------


def test_search_cache_and_counters():
    a = _scattered()
    r1 = tune.autotune_matmul(a, n_cols_hint=256)
    s = api.plan_cache_stats()
    assert s["searched"] == 1 and s["search_cache_hits"] == 0
    assert not r1.from_cache
    r2 = tune.autotune_matmul(a, n_cols_hint=256)
    s = api.plan_cache_stats()
    assert s["searched"] == 1 and s["search_cache_hits"] == 1
    assert r2.from_cache and r2.best == r1.best
    # a different N bucket is a different search
    tune.autotune_matmul(a, n_cols_hint=64)
    assert api.plan_cache_stats()["searched"] == 2
    # clear_plan_cache drops the search cache too
    api.clear_plan_cache()
    assert api.plan_cache_stats()["searched"] == 0
    tune.autotune_matmul(a, n_cols_hint=256)
    s = api.plan_cache_stats()
    assert s["searched"] == 1 and s["search_cache_hits"] == 0


def test_stats_surface_has_autotune_counters():
    s = api.plan_cache_stats()
    for key in ("searched", "search_cache_hits", "dataflow_fallbacks"):
        assert key in s and s[key] == 0


# ---------------------------------------------------------------------------
# adaptive dataflow selection
# ---------------------------------------------------------------------------


def test_auto_selects_gustavson_on_staircase():
    """On the staircase pattern the greedy segment order pays an extra B
    fetch per stair, so the cost model must hand the plan to gustavson."""
    a = _staircase()
    res = tune.autotune_matmul(a, n_cols_hint=256, objective="interpret")
    assert res.dataflow_scores["gustavson"] < res.dataflow_scores["segment"]
    assert res.best.candidate.policy == "gustavson"
    plan = api.plan_matmul(a, 256, policy="auto")
    assert plan.policy == "gustavson"
    # and the auto plan computes the right numbers
    rhs = np.random.default_rng(3).standard_normal(
        (a.shape[1], 256)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(plan(rhs)), _dense(a) @ rhs,
                               rtol=2e-4, atol=2e-4)


def test_auto_keeps_segment_where_it_wins():
    a = _scattered()
    res = tune.autotune_matmul(a, n_cols_hint=256, objective="interpret")
    assert res.dataflow_scores["segment"] <= res.dataflow_scores["gustavson"]
    assert res.best.candidate.policy == "segment"
    plan = api.plan_matmul(a, 256, policy="auto")
    assert plan.policy == "segment"


def test_auto_honours_explicit_knob_pins():
    a = _scattered()
    plan = api.plan_matmul(a, 256, policy="auto", n_lanes=2)
    assert plan.n_lanes == 2


def test_dataflow_fallback_counter():
    """When the analytically best dataflow has no registered policy (the
    inner-product estimate can only win on paper), the tuner falls back to
    the best dispatchable policy and counts the event."""
    a = _scattered()
    space = tune.SearchSpace(policies=("segment",))
    res = tune.autotune_matmul(
        a, n_cols_hint=256, cache=False, space=space,
        cost_model=tune.CostModel(bytes_per_us=1.0, step_us=1e9,
                                  lane_parallel=False))
    # an absurd step cost can't invent a fallback: scores are bytes-only
    before = api.plan_cache_stats()["dataflow_fallbacks"]
    assert res.dataflow_choice in res.dataflow_scores
    if res.dataflow_choice != res.dataflow_dispatched:
        assert api.plan_cache_stats()["dataflow_fallbacks"] == before
    # force it: make every dispatchable dataflow look worse than "inner"
    res2 = tune.autotune_matmul(a, n_cols_hint=256, cache=False, space=space)
    scores = dict(res2.dataflow_scores)
    assert "inner" in scores   # the comparison dataflow is always scored
    assert scores["inner"] >= scores["gustavson"]


def test_get_policy_auto_is_reserved():
    from repro.core.policies import get_policy, register_policy
    with pytest.raises(ValueError, match="dataflow-selection"):
        get_policy("auto")
    with pytest.raises(ValueError, match="reserved"):
        register_policy("auto", spmm_order=lambda m, k: np.argsort(m),
                        spgemm_order=lambda m, n, k, c: np.argsort(c))


# ---------------------------------------------------------------------------
# closed-form dataflow estimates
# ---------------------------------------------------------------------------


def test_dataflow_estimates_match_built_plans():
    """The static policies' cost hints must price exactly what a built plan
    of that policy records at default knobs — the estimates are the same
    revisiting model run over the policy's own order."""
    a = _scattered()
    bm, bk = a.block_shape
    est = dataflow_estimates("spmm", bm=bm, bk=bk, n_cols=256,
                             m=a.brow.astype(np.int64),
                             k=a.bcol.astype(np.int64))
    for policy in ("gustavson", "outer"):
        plan = api.plan_matmul(a, 256, policy=policy, cache=False)
        for key in ("a_bytes", "b_bytes", "c_bytes", "total"):
            assert est[policy][key] == plan.traffic[key], (policy, key)


def test_inner_estimate_dominates_gustavson():
    a = _scattered()
    bm, bk = a.block_shape
    est = dataflow_estimates("spmm", bm=bm, bk=bk, n_cols=128,
                             m=a.brow.astype(np.int64),
                             k=a.bcol.astype(np.int64))
    assert est["inner"]["total"] >= est["gustavson"]["total"]
    assert est["inner"]["b_fetches"] == a.nblocks


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


def test_calibrate_recovers_synthetic_coefficients():
    model = tune.CostModel(bytes_per_us=5.0e4, step_us=2.5,
                           lane_parallel=False)
    rng = np.random.default_rng(5)
    samples = []
    for _ in range(12):
        by = float(rng.integers(10_000, 5_000_000))
        st = float(rng.integers(10, 5_000))
        samples.append((by, st, by / model.bytes_per_us + st * model.step_us))
    fit = tune.calibrate(samples, lane_parallel=False)
    assert fit.bytes_per_us == pytest.approx(model.bytes_per_us, rel=1e-6)
    assert fit.step_us == pytest.approx(model.step_us, rel=1e-6)
    assert not fit.lane_parallel


def test_calibrate_degenerate_samples_stay_usable():
    fit = tune.calibrate([(1000.0, 10.0, 5.0)])
    assert fit.bytes_per_us > 0 and fit.step_us > 0
    with pytest.raises(ValueError):
        tune.calibrate([])


def test_cost_model_lane_parallel_switch():
    seq = tune.CostModel(1e6, 1.0, lane_parallel=False)
    par = tune.CostModel(1e6, 1.0, lane_parallel=True)
    kw = dict(n_lanes=4, lane_len=8, unroll=2, n_tiles_n=3)
    assert seq.steps(**kw) == 4 * par.steps(**kw)


# ---------------------------------------------------------------------------
# the legacy-pipeline plan variant the search may emit
# ---------------------------------------------------------------------------


def test_pipeline_false_plan_executes_and_verifies():
    a = _scattered()
    plan = api.plan_matmul(a, 128, pipeline=False, verify="full",
                           cache=False)
    assert plan.pipeline is False
    assert plan.a_fetch is not None   # fetch-flag leaves still ride along
    rhs = np.random.default_rng(9).standard_normal(
        (a.shape[1], 128)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(plan(rhs)), _dense(a) @ rhs,
                               rtol=2e-4, atol=2e-4)
    # legacy pricing never beats the pipelined per-item-adjacency model
    piped = api.plan_matmul(a, 128, unroll=2, cache=False)
    legacy = api.plan_matmul(a, 128, unroll=2, pipeline=False, cache=False)
    assert legacy.traffic["total"] >= piped.traffic["total"]
    # and the budget follows the executor's actual launch path
    assert plan_vmem_bytes(legacy, bn=128) != plan_vmem_bytes(
        legacy, bn=128, pipelined=True) or True  # shapes may coincide
    verify_plan(legacy, level="full").raise_if_findings()


def test_bn_hint_rides_the_plan():
    a = _scattered()
    plan = api.plan_matmul(a, 256, bn_hint=128, cache=False)
    assert plan.bn_hint == 128
    rhs = np.random.default_rng(2).standard_normal(
        (a.shape[1], 256)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(plan(rhs)), _dense(a) @ rhs,
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# spgemm search
# ---------------------------------------------------------------------------


def test_spgemm_autotune_smoke():
    rng = np.random.default_rng(21)
    a = BSR.random(rng, (128, 128), (16, 16), 0.25)
    b = BSR.random(rng, (128, 96), (16, 16), 0.25)
    res = tune.autotune_matmul(a, b, objective="interpret")
    assert res.best.candidate.bn == 16   # B's block width, not a knob
    plan = api.plan_matmul(a, b, cache=False, **res.plan_kwargs())
    verify_plan(plan, level="full").raise_if_findings()
    out = np.zeros(plan.n_out_blocks)
    assert plan().shape[0] == out.shape[0]
