"""Mutation-kill battery for the symbolic kernel analyzer.

Five deliberately buggy toy Pallas kernels, one per analyzer rule:

* cross-lane scratch accumulation   -> ``parallel-race``
* out-of-bounds ``pl.ds``           -> ``index-range``
* ring-buffer slot off-by-one read  -> ``ring-slot-war``
* semaphore waited on one branch    -> ``sem-balance``
* oversized VMEM scratch            -> ``vmem-budget``

Each toy must be caught by *exactly* its targeted rule and none of the
others (including the syntactic linter's rules — ``analyze_callable``
merges both layers, so the set-equality assertions double as a
no-collateral-findings proof).  The ring toy additionally pins the
documented ref-base false negative: the syntactic ``read-before-wait``
rule is provably silent on it, only the slot-granular symbolic rule
fires.

The second half pins the static VMEM budget: the analytic per-variant
formulas must agree byte-for-byte with the budget derived from the traced
kernel IR (scratch + BlockSpec windows), and the planner's
``vmem_limit_bytes`` gate must reject an impossible budget at plan time.
"""
import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis import (
    VmemBudgetError,
    analyze_callable,
    kernel_vmem_bytes,
    lint_callable,
    plan_vmem_bytes,
    spgemm_vmem_bytes,
    spmm_vmem_bytes,
    trace_kernel_irs,
)
from repro.api import execute_plan, plan_matmul
from repro.core.formats import BSR
from repro.kernels.compat import CompilerParams


def _rules(findings):
    return set(f.rule for f in findings)


# ---------------------------------------------------------------------------
# toy kernels
# ---------------------------------------------------------------------------


def _cross_lane_scratch(x):
    """BUG: scratch accumulator initialized only on lane 0 but accumulated
    on every grid point — lane 1 reads lane 0's leftover partial sums."""

    def kernel(in_ref, out_ref, acc_ref):
        lane = pl.program_id(0)
        step = pl.program_id(1)

        @pl.when((lane == 0) & (step == 0))
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += in_ref[...]
        out_ref[...] = acc_ref[...]

    return pl.pallas_call(
        kernel, grid=(2, 2),
        in_specs=[pl.BlockSpec((8, 128), lambda l, s: (l * 2 + s, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda l, s: (l * 2 + s, 0)),
        scratch_shapes=[pltpu.VMEM((8, 128), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((32, 128), jnp.float32),
        interpret=True,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(x)


def _oob_dynamic_slice(x):
    """BUG: grid point 3 reads ``[24, 32)`` from a 24-element ref."""

    def kernel(in_ref, out_ref):
        i = pl.program_id(0)
        out_ref[...] = in_ref[pl.ds(i * 8, 8)]

    return pl.pallas_call(
        kernel, grid=(4,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((8,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((32,), jnp.float32),
        interpret=True,
    )(x)


def _ring_toy(x, *, read_next_slot):
    """Depth-2 DMA ring.  Correct when reading the waited slot
    (``s % 2``); ``read_next_slot=True`` plants the off-by-one — reading
    the slot whose fetch was just issued.  The semaphore accounting stays
    perfectly balanced either way, so only the slot-granular WAR rule can
    tell the two apart."""
    n = 2

    def kernel(hbm_ref, out_ref, buf_ref, sem_ref):
        s = pl.program_id(0)
        slot = s % 2
        nxt = (s + 1) % 2

        @pl.when(s == 0)
        def _prologue():
            pltpu.make_async_copy(hbm_ref.at[pl.ds(0, 8)], buf_ref.at[0],
                                  sem_ref.at[0]).start()

        @pl.when(s + 1 < n)
        def _issue_ahead():
            pltpu.make_async_copy(hbm_ref.at[pl.ds((s + 1) * 8, 8)],
                                  buf_ref.at[nxt], sem_ref.at[nxt]).start()

        pltpu.make_async_copy(hbm_ref.at[pl.ds(s * 8, 8)],
                              buf_ref.at[slot], sem_ref.at[slot]).wait()
        out_ref[...] = buf_ref[nxt if read_next_slot else slot]

    return pl.pallas_call(
        kernel, grid=(n,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((8,), lambda s: (s,)),
        scratch_shapes=[pltpu.VMEM((2, 8), jnp.float32),
                        pltpu.SemaphoreType.DMA((2,))],
        out_shape=jax.ShapeDtypeStruct((n * 8,), jnp.float32),
        interpret=True,
    )(x)


def _one_branch_wait(x):
    """BUG: a DMA start on every step but the wait sits under
    ``pl.when(s % 2 == 0)`` — odd steps leak an un-waited start."""
    n = 4

    def kernel(hbm_ref, out_ref, buf_ref, sem_ref):
        s = pl.program_id(0)
        pltpu.make_async_copy(hbm_ref.at[pl.ds(s * 8, 8)], buf_ref.at[0],
                              sem_ref.at[0]).start()

        @pl.when(s % 2 == 0)
        def _even_only():
            pltpu.make_async_copy(hbm_ref.at[pl.ds(s * 8, 8)],
                                  buf_ref.at[0], sem_ref.at[0]).wait()

        out_ref[...] = jnp.ones_like(out_ref)

    return pl.pallas_call(
        kernel, grid=(n,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((8,), lambda s: (s,)),
        scratch_shapes=[pltpu.VMEM((1, 8), jnp.float32),
                        pltpu.SemaphoreType.DMA((1,))],
        out_shape=jax.ShapeDtypeStruct((n * 8,), jnp.float32),
        interpret=True,
    )(x)


def _vmem_hog(x):
    """BUG: a 32 MiB f32 scratch — double the 16 MiB per-core VMEM."""

    def kernel(in_ref, out_ref, big_ref):
        out_ref[...] = in_ref[...]

    return pl.pallas_call(
        kernel, grid=(1,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
        scratch_shapes=[pltpu.VMEM((2048, 4096), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
        interpret=True,
    )(x)


# ---------------------------------------------------------------------------
# mutation-kill assertions: exactly one rule each
# ---------------------------------------------------------------------------


def test_cross_lane_scratch_is_killed_by_parallel_race_only():
    x = jnp.zeros((32, 128), jnp.float32)
    findings = analyze_callable(_cross_lane_scratch, x, label="toy-race")
    assert _rules(findings) == {"parallel-race"}, findings
    assert any("scratch" in f.message for f in findings)


def test_oob_ds_is_killed_by_index_range_only():
    x = jnp.zeros((24,), jnp.float32)
    findings = analyze_callable(_oob_dynamic_slice, x, label="toy-oob")
    assert _rules(findings) == {"index-range"}, findings
    # the message names the proven bad footprint
    assert any("[24, 32)" in f.message or "24" in f.message
               for f in findings)


def test_ring_off_by_one_is_killed_by_ring_slot_war_only():
    x = jnp.zeros((16,), jnp.float32)
    buggy = lambda xx: _ring_toy(xx, read_next_slot=True)
    findings = analyze_callable(buggy, x, label="toy-ring")
    assert _rules(findings) == {"ring-slot-war"}, findings
    # the documented ref-base false negative: the syntactic linter sees a
    # wait on the buffer before the read and stays silent
    assert lint_callable(buggy, x, label="toy-ring-syntactic") == []


def test_correct_ring_proves_clean():
    x = jnp.zeros((16,), jnp.float32)
    good = lambda xx: _ring_toy(xx, read_next_slot=False)
    assert analyze_callable(good, x, label="toy-ring-good") == []


def test_one_branch_wait_is_killed_by_sem_balance_only():
    x = jnp.zeros((32,), jnp.float32)
    findings = analyze_callable(_one_branch_wait, x, label="toy-sem")
    assert _rules(findings) == {"sem-balance"}, findings
    assert any("never waited" in f.message for f in findings)


def test_vmem_hog_is_killed_by_vmem_budget_only():
    x = jnp.zeros((8, 128), jnp.float32)
    findings = analyze_callable(_vmem_hog, x, label="toy-vmem")
    assert _rules(findings) == {"vmem-budget"}, findings
    # and a raised limit clears it — the rule reads the knob, not a
    # hard-coded constant
    assert analyze_callable(_vmem_hog, x, label="toy-vmem-big",
                            vmem_limit=64 * 2 ** 20) == []


def test_data_dependent_guard_is_unprovable_not_silent():
    """A wait under a guard the interpreter cannot resolve must produce an
    explicit sem-balance "unprovable" finding, never a silent pass."""

    def fn(x):
        def kernel(hbm_ref, gate_ref, out_ref, buf_ref, sem_ref):
            s = pl.program_id(0)
            pltpu.make_async_copy(hbm_ref.at[pl.ds(s * 8, 8)],
                                  buf_ref.at[0], sem_ref.at[0]).start()

            @pl.when(gate_ref[0] > 0)       # data-dependent
            def _maybe():
                pltpu.make_async_copy(hbm_ref.at[pl.ds(s * 8, 8)],
                                      buf_ref.at[0], sem_ref.at[0]).wait()

            out_ref[...] = jnp.ones_like(out_ref)

        return pl.pallas_call(
            kernel, grid=(2,),
            in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                      pl.BlockSpec((4,), lambda s: (0,))],
            out_specs=pl.BlockSpec((8,), lambda s: (s,)),
            scratch_shapes=[pltpu.VMEM((1, 8), jnp.float32),
                            pltpu.SemaphoreType.DMA((1,))],
            out_shape=jax.ShapeDtypeStruct((16,), jnp.float32),
            interpret=True,
        )(x, jnp.ones((4,), jnp.float32))

    findings = analyze_callable(fn, jnp.zeros((16,), jnp.float32),
                                label="toy-datadep")
    assert _rules(findings) == {"sem-balance"}, findings
    assert any("unprovable" in f.message for f in findings)


# ---------------------------------------------------------------------------
# VMEM budget: analytic formulas == traced-IR accounting, planner gate
# ---------------------------------------------------------------------------


def _traced_total(fn, *args, label):
    irs = trace_kernel_irs(fn, *args, label=label)
    return max(kernel_vmem_bytes(ir)["total"] for ir in irs)


@pytest.fixture(scope="module")
def small_plans():
    a = BSR.random(np.random.default_rng(0), (128, 128), (32, 32), 0.5)
    b = BSR.random(np.random.default_rng(1), (128, 128), (32, 32), 0.5)
    spmm = plan_matmul(a, policy="segment", n_lanes=2, unroll=2, cache=False)
    quant = plan_matmul(a, policy="segment", n_lanes=2, unroll=2,
                        quantize="int8", cache=False)
    spgemm = plan_matmul(a, b, policy="segment", n_lanes=2, unroll=2,
                         cache=False)
    return spmm, quant, spgemm


def test_spmm_budget_matches_traced_kernel(small_plans):
    spmm, _, _ = small_plans
    x = jnp.zeros((128, 64), jnp.float32)
    traced = _traced_total(
        lambda xx: execute_plan(spmm, xx, bn=64, backend="interpret"),
        x, label="budget-spmm")
    analytic = spmm_vmem_bytes(bm=32, bk=32, bn=64, unroll=2,
                               pipelined=True)
    assert traced == analytic == plan_vmem_bytes(spmm, bn=64)


def test_quantized_spmm_budget_matches_traced_kernel(small_plans):
    _, quant, _ = small_plans
    x = jnp.zeros((128, 64), jnp.float32)
    traced = _traced_total(
        lambda xx: execute_plan(quant, xx, bn=64, backend="interpret"),
        x, label="budget-quant")
    analytic = spmm_vmem_bytes(bm=32, bk=32, bn=64, unroll=2,
                               block_dtype="int8", quantized=True,
                               pipelined=True)
    assert traced == analytic == plan_vmem_bytes(quant, bn=64)


@pytest.mark.parametrize("pipelined", [True, False])
def test_rowwise_spmm_budget_matches_traced_kernel(pipelined):
    """Rowwise scales are VMEM-resident on both executor paths (windowed
    operand pipelined, per-item windows legacy) — the closed form must
    track the traced kernels byte-for-byte like the per-block pin above."""
    a = BSR.random(np.random.default_rng(3), (128, 128), (32, 32), 0.5)
    plan = plan_matmul(a, policy="segment", n_lanes=2, unroll=2,
                       quantize="int8.rowwise", pipeline=pipelined,
                       cache=False)
    x = jnp.zeros((128, 64), jnp.float32)
    traced = _traced_total(
        lambda xx: execute_plan(plan, xx, bn=64, backend="interpret"),
        x, label=f"budget-rowwise-{pipelined}")
    analytic = spmm_vmem_bytes(bm=32, bk=32, bn=64, unroll=2,
                               block_dtype="int8", quantized=True,
                               rowwise=True, pipelined=pipelined)
    assert traced == analytic == plan_vmem_bytes(plan, bn=64)


def test_rowwise_spgemm_budget_matches_traced_kernel():
    a = BSR.random(np.random.default_rng(4), (128, 128), (32, 32), 0.5)
    b = BSR.random(np.random.default_rng(5), (128, 128), (32, 32), 0.5)
    plan = plan_matmul(a, b, policy="segment", n_lanes=2, unroll=2,
                       quantize="fp8.rowwise", cache=False)
    traced = _traced_total(
        lambda: execute_plan(plan, backend="interpret"),
        label="budget-rowwise-spgemm")
    analytic = spgemm_vmem_bytes(bm=32, bk=32, bn=32, unroll=2,
                                 block_dtype="float8_e4m3fn",
                                 rhs_dtype="float8_e4m3fn",
                                 quant_a=True, quant_b=True, rowwise=True,
                                 pipelined=True)
    assert traced == analytic == plan_vmem_bytes(plan)


def test_spgemm_budget_matches_traced_kernel(small_plans):
    _, _, spgemm = small_plans
    traced = _traced_total(
        lambda: execute_plan(spgemm, backend="interpret"),
        label="budget-spgemm")
    analytic = spgemm_vmem_bytes(bm=32, bk=32, bn=32, unroll=2,
                                 pipelined=True)
    assert traced == analytic == plan_vmem_bytes(spgemm)


def test_planner_vmem_gate(small_plans):
    a = BSR.random(np.random.default_rng(2), (128, 128), (32, 32), 0.5)
    # a budget no kernel instance fits: named error at plan time
    with pytest.raises(VmemBudgetError, match="VMEM working set"):
        plan_matmul(a, policy="segment", n_lanes=2, unroll=2, cache=False,
                    vmem_limit_bytes=64 * 1024)
    # the default 16 MiB budget admits every shipped knob point
    plan = plan_matmul(a, policy="segment", n_lanes=2, unroll=2,
                       cache=False, vmem_limit_bytes=16 * 2 ** 20)
    assert 0 < plan_vmem_bytes(plan, bn=64) <= 16 * 2 ** 20


def test_shipped_spmm_variant_proves_clean(small_plans):
    """Representative end-to-end proof on a real shipped kernel (the full
    variant grid runs in scripts/ci.sh via `python -m
    repro.analysis.jaxpr_lint`)."""
    spmm, _, _ = small_plans
    x = jnp.zeros((128, 64), jnp.float32)
    findings = analyze_callable(
        lambda xx: execute_plan(spmm, xx, bn=64, backend="interpret"),
        x, label="shipped-spmm")
    assert findings == []


# ---------------------------------------------------------------------------
# verify_plans artifact
# ---------------------------------------------------------------------------


def test_verify_plans_json_artifact(tmp_path):
    out = tmp_path / "verify.json"
    repo = Path(__file__).resolve().parents[1]
    proc = subprocess.run(
        [sys.executable, str(repo / "scripts" / "verify_plans.py"),
         "--fast", "--scale", "64", "-q", "--json", str(out)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    d = json.loads(out.read_text())
    assert d["level"] == "fast"
    assert d["summary"]["ok"] and d["summary"]["n_findings"] == 0
    assert d["summary"]["n_plans"] == len(d["plans"]) > 0
    for rec in d["plans"]:
        assert rec["ok"] and rec["findings"] == []
        assert rec["kind"] in ("spmm", "spgemm")
        assert rec["checked"] > 0
