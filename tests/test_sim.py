"""Simulator behaviour tests: mechanism ablations must move the right way."""
import dataclasses

import numpy as np
import pytest

from repro.sim import matrices
from repro.sim.baselines import (flexagon_best, flexagon_gust, flexagon_ip,
                                 flexagon_op, spada)
from repro.sim.segfold_sim import SegFoldConfig, simulate_segfold


@pytest.fixture(scope="module")
def mats():
    rng = np.random.default_rng(0)
    a = matrices.banded(rng, 512, 512, 0.02)
    return a, a.transpose()


def test_sim_runs_and_counts_macs(mats):
    a, b = mats
    res = simulate_segfold(a, b)
    # MACs must equal the exact SpGEMM multiply count
    import scipy.sparse as sp
    A = sp.csr_matrix((np.ones_like(a.data, np.int8), a.indices, a.indptr),
                      shape=a.shape)
    b_lens = np.diff(b.indptr)
    want = int((A @ b_lens.reshape(-1, 1)).sum())
    assert res.macs == want
    assert res.cycles > 0


def test_mapping_ablation_direction(mats):
    a, b = mats
    cfg = SegFoldConfig()
    zero = simulate_segfold(a, b, dataclasses.replace(cfg, mapping="zero"))
    lut = simulate_segfold(a, b, dataclasses.replace(cfg, mapping="lut"))
    ideal = simulate_segfold(a, b, dataclasses.replace(cfg, mapping="ideal"))
    assert ideal.cycles <= lut.cycles <= zero.cycles * 1.001


def test_window_monotone_small(mats):
    a, b = mats
    cfg = SegFoldConfig()
    c1 = simulate_segfold(a, b, dataclasses.replace(cfg, window=1)).cycles
    c32 = simulate_segfold(a, b, dataclasses.replace(cfg, window=32)).cycles
    assert c32 <= c1


def test_folding_helps_on_long_rows():
    rng = np.random.default_rng(3)
    a = matrices.powerlaw(rng, 384, 384, 8e-3)
    b = a.transpose()
    cfg = SegFoldConfig()
    on = simulate_segfold(a, b, dataclasses.replace(cfg, spatial_folding=True))
    off = simulate_segfold(a, b, dataclasses.replace(cfg, spatial_folding=False))
    assert on.cycles <= off.cycles * 1.001


def test_segfold_beats_baselines_on_suite_matrix():
    rng = np.random.default_rng(4)
    a = matrices.banded(rng, 768, 768, 0.012)
    b = a.transpose()
    cfg = SegFoldConfig(cache_bytes=256 * 1024)
    seg = simulate_segfold(a, b, cfg)
    sp_ = spada(a, b, cfg)
    fb = flexagon_best(a, b, cfg)
    assert seg.cycles < sp_.cycles
    assert seg.cycles < fb["cycles"]


def test_baselines_compute_same_workload(mats):
    a, b = mats
    cfg = SegFoldConfig(cache_bytes=256 * 1024)
    macs = {f.__name__: f(a, b, cfg).macs
            for f in (flexagon_gust, flexagon_op, flexagon_ip)}
    assert len(set(macs.values())) == 1, macs
