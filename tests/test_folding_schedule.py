"""Folding + TPU block-schedule tests."""
import numpy as np
from _hypothesis_compat import given, settings, st  # optional-dep guard

from repro.core.folding import (balance_bins, fold_segments, round_robin_bins,
                                spatial_fold, temporal_fold_spills)
from repro.core.formats import BSR
from repro.core.schedule import (build_spgemm_schedule, build_spmm_schedule,
                                 finalize_schedule, spgemm_schedule_traffic,
                                 spmm_schedule_traffic, symbolic_spgemm)


def test_spatial_fold_reduces_spills():
    rng = np.random.default_rng(0)
    lengths = rng.integers(1, 64, size=16)   # some rows overflow P=16
    on = spatial_fold(lengths, R=16, P=16, enabled=True)
    off = spatial_fold(lengths, R=16, P=16, enabled=False)
    assert on["spills"] <= off["spills"]
    assert on["utilization"] >= off["utilization"] - 1e-9


def test_temporal_fold_spills():
    assert temporal_fold_spills(np.array([10, 20, 5]), capacity=8) == (2 + 12)


def test_fold_segments_conserves_work():
    sizes = np.array([5, 130, 7, 300])
    seg, chunk = fold_segments(sizes, fold_len=64)
    assert chunk.sum() == sizes.sum()
    assert chunk.max() <= 64
    for i, s in enumerate(sizes):
        assert chunk[seg == i].sum() == s


def test_lpt_beats_round_robin():
    rng = np.random.default_rng(1)
    sizes = (rng.pareto(1.5, size=200) * 10 + 1).astype(np.int64)
    _, lpt = balance_bins(sizes, 16)
    _, rr = round_robin_bins(sizes, 16)
    assert lpt["imbalance"] <= rr["imbalance"] + 1e-9


def test_shard_schedule_dispatches_on_registry_not_name():
    """A custom-registered fold-capable policy must get LPT balancing, not
    the round-robin fallback the old ``policy == "segment"`` string compare
    handed everything non-built-in; unknown names raise instead of
    silently degrading."""
    import pytest
    from repro.core.policies import register_policy, unregister_policy
    from repro.core.schedule import shard_schedule

    rng = np.random.default_rng(2)
    sizes = (rng.pareto(1.5, size=200) * 10 + 1).astype(np.int64)
    register_policy("custom-dynamic", spmm_order=lambda m, k: np.argsort(m),
                    spgemm_order=lambda m, n, k, c: np.argsort(c),
                    supports_fold=True)
    register_policy("custom-static", spmm_order=lambda m, k: np.argsort(k),
                    spgemm_order=lambda m, n, k, c: np.argsort(k),
                    supports_fold=False)
    try:
        asn_dyn, _ = shard_schedule(sizes, 16, policy="custom-dynamic")
        asn_lpt, _ = balance_bins(sizes, 16)
        np.testing.assert_array_equal(asn_dyn, asn_lpt)
        asn_sta, _ = shard_schedule(sizes, 16, policy="custom-static")
        asn_rr, _ = round_robin_bins(sizes, 16)
        np.testing.assert_array_equal(asn_sta, asn_rr)
        with pytest.raises(ValueError, match="unknown policy"):
            shard_schedule(sizes, 16, policy="no-such-policy")
    finally:
        unregister_policy("custom-dynamic")
        unregister_policy("custom-static")


# --- schedule finalization (accum_prev / row_mask derivation) ----------------


def test_finalize_schedule_accum_prev_marks_revisits():
    # segments at items 0, 2, 4; owner 1 re-started at item 4 must accumulate
    seg_start = np.array([1, 0, 1, 0, 1, 0], np.int32)
    owner = np.array([1, 1, 3, 3, 1, 1], np.int32)
    fin = finalize_schedule(seg_start, owner, n_slots=5)
    np.testing.assert_array_equal(fin.accum_prev, [0, 0, 0, 0, 1, 0])
    np.testing.assert_array_equal(fin.row_mask, [0.0, 1.0, 0.0, 1.0, 0.0])


def test_finalize_schedule_no_revisits_without_refolds():
    seg_start = np.array([1, 0, 1, 1], np.int32)
    owner = np.array([0, 0, 1, 2], np.int32)
    fin = finalize_schedule(seg_start, owner)
    assert fin.accum_prev.sum() == 0
    assert fin.row_mask is None


def test_finalize_schedule_empty_and_mismatch():
    fin = finalize_schedule(np.zeros(0, np.int32), np.zeros(0, np.int32),
                            n_slots=3)
    assert fin.accum_prev.size == 0
    np.testing.assert_array_equal(fin.row_mask, [0.0, 0.0, 0.0])
    try:
        finalize_schedule(np.zeros(3, np.int32), np.zeros(2, np.int32))
    except ValueError:
        pass
    else:
        raise AssertionError("shape mismatch must raise")


def test_finalize_schedule_matches_folded_spmm():
    """On a real folded schedule, every accum_prev item re-visits an owner
    that an earlier segment already wrote."""
    a = BSR.random(np.random.default_rng(7), (256, 256), (16, 16), 0.6)
    s = build_spmm_schedule(a, "segment", fold_len=4)
    fin = finalize_schedule(s.seg_start, s.m, n_slots=s.n_m_blocks)
    heads = np.nonzero(s.seg_start)[0]
    seen = set()
    for h in heads:
        m = int(s.m[h])
        assert fin.accum_prev[h] == (1 if m in seen else 0)
        seen.add(m)
    assert fin.accum_prev[~s.seg_start.astype(bool)].sum() == 0


# --- block schedules ---------------------------------------------------------


def _bsr(seed, shape=(256, 320), block=(32, 32), density=0.3):
    return BSR.random(np.random.default_rng(seed), shape, block, density)


def test_spmm_schedule_covers_blocks_once():
    a = _bsr(0)
    for policy in ("segment", "gustavson", "outer"):
        s = build_spmm_schedule(a, policy)
        assert sorted(s.a_idx.tolist()) == list(range(a.nblocks))
        assert s.seg_start[0] == 1 and s.seg_write[-1] == 1


def test_segment_schedule_segments_contiguous():
    a = _bsr(1)
    s = build_spmm_schedule(a, "segment")
    # within a segment (between starts) m must be constant
    cur = None
    for i in range(s.n_items):
        if s.seg_start[i]:
            cur = s.m[i]
        assert s.m[i] == cur


def test_segment_traffic_no_worse_than_static():
    for seed in range(5):
        a = _bsr(seed, density=0.25)
        t = {p: spmm_schedule_traffic(build_spmm_schedule(a, p), 32, 32, 512)
             for p in ("segment", "gustavson", "outer")}
        assert t["segment"]["total"] <= min(t["gustavson"]["total"],
                                            t["outer"]["total"]) * 1.001


def test_symbolic_spgemm_matches_dense():
    a, b = _bsr(2), _bsr(3, shape=(320, 192))
    brow, bcol = symbolic_spgemm(a.block_mask(), b.block_mask())
    want = (a.block_mask().astype(int) @ b.block_mask().astype(int)) > 0
    got = np.zeros_like(want)
    got[brow, bcol] = True
    assert np.array_equal(got, want)


def test_spgemm_schedule_triples_complete():
    a, b = _bsr(4), _bsr(5, shape=(320, 192))
    s = build_spgemm_schedule(a, b, "segment")
    # every (m,k)×(k,n) contributing pair appears exactly once
    amask, bmask = a.block_mask(), b.block_mask()
    expect = int(sum(amask[m, k] and bmask[k, n]
                     for m in range(amask.shape[0])
                     for k in range(amask.shape[1])
                     for n in range(bmask.shape[1])))
    assert s.n_items == expect
    tr = {p: spgemm_schedule_traffic(build_spgemm_schedule(a, b, p), 32, 32, 32)
          for p in ("segment", "gustavson", "outer")}
    assert tr["segment"]["total"] <= min(tr["gustavson"]["total"],
                                         tr["outer"]["total"]) * 1.05


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 1000), gm=st.integers(2, 8), gk=st.integers(2, 8),
       density=st.floats(0.1, 0.9))
def test_spmm_schedule_property(seed, gm, gk, density):
    rng = np.random.default_rng(seed)
    a = BSR.random(rng, (gm * 16, gk * 16), (16, 16), density)
    s = build_spmm_schedule(a, "segment")
    assert sorted(s.a_idx.tolist()) == list(range(a.nblocks))
    # seg_write marks exactly the last item of every segment
    for i in range(s.n_items - 1):
        assert s.seg_write[i] == s.seg_start[i + 1]
    assert s.seg_write[-1] == 1
