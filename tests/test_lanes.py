"""Lane-parallel Segment execution: partitioning invariants, backend parity
across lane counts, the zero-copy realize contract, and the transposed
backward path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional-dep guard

from repro import api
from repro.api import planner
from repro.core.formats import BSR
from repro.core.schedule import build_spmm_schedule, partition_lanes

RNG = np.random.default_rng(0)


def _patterns():
    """Pattern classes the lane partitioner must not corrupt."""
    rand = BSR.random(np.random.default_rng(1), (128, 160), (32, 32), 0.35)
    # empty block rows
    d = np.random.default_rng(2).standard_normal((128, 96)).astype(np.float32)
    d[0:32] = 0.0
    d[64:96] = 0.0
    holes = BSR.from_dense(d, (32, 32))
    # one giant segment: a single output row holding every k block — with
    # n_lanes > 1 the whole chain must stay in one lane (extra lanes clamp)
    one_row = BSR.from_dense(
        np.random.default_rng(3).standard_normal((32, 256)).astype(np.float32),
        (32, 32))
    return {"random": rand, "empty_rows": holes, "one_segment": one_row}


# ---------------------------------------------------------------------------
# partition_lanes invariants
# ---------------------------------------------------------------------------


def test_partition_lanes_covers_items_and_keeps_owners_atomic():
    a = BSR.random(np.random.default_rng(4), (256, 256), (32, 32), 0.3)
    s = build_spmm_schedule(a, "segment", fold_len=3)
    for n_lanes in (1, 2, 4, 8):
        lay = partition_lanes(s.m, n_lanes, unroll=2)
        real = lay.perm[lay.perm >= 0]
        assert sorted(real.tolist()) == list(range(s.n_items))
        # owner chains (incl. folded continuations) never span lanes
        owner_lane = {}
        for li in range(lay.n_lanes):
            for it in lay.perm[li][lay.perm[li] >= 0]:
                o = int(s.m[it])
                assert owner_lane.setdefault(o, li) == li
        # unroll alignment: every grid step's items share one owner
        for li in range(lay.n_lanes):
            owners = np.where(lay.perm[li] >= 0,
                              s.m[lay.filled[li]], -1)
            for j0 in range(0, lay.lane_len, 2):
                step = [o for o in owners[j0:j0 + 2] if o >= 0]
                assert len(set(step)) <= 1


def test_partition_lanes_clamps_to_segment_count():
    lay = partition_lanes(np.array([7, 7, 7, 7]), 4)
    assert lay.n_lanes == 1          # one owner group → one lane
    lay = partition_lanes(np.array([0, 0, 1, 2]), 16)
    assert lay.n_lanes == 3


def test_lane_traffic_unroll_models_pipeline_vs_legacy():
    """The explicit-DMA kernels fetch per *item*, so revisit credit spans
    every consecutive pair — unroll included (the default model).  The
    legacy BlockSpec auto-pipeline bound each of the G step items to an
    independent stream (index maps strided by G), so its model only credits
    position g of consecutive steps."""
    from repro.core.schedule import lane_traffic_spmm
    # two chains of two items; k = [0, 5, 5, 7]
    m = np.array([0, 0, 1, 1])
    k = np.array([0, 5, 5, 7])
    seg_start = np.array([1, 0, 1, 0])
    valid = np.ones(4, bool)
    t1 = lane_traffic_spmm(m, k, seg_start, valid, 1, 8, 8, 1)
    # per-item model: items 1->2 share k=5 across the chain boundary
    assert t1["b_fetches"] == 3
    t2 = lane_traffic_spmm(m, k, seg_start, valid, 1, 8, 8, 1, unroll=2)
    # the pipelined kernel's fetch flags don't change with unroll
    assert t2["b_fetches"] == 3
    t3 = lane_traffic_spmm(m, k, seg_start, valid, 1, 8, 8, 1, unroll=2,
                           pipeline=False)
    # legacy stream model: stream 0 compares k[0]=0 vs k[2]=5, stream 1
    # k[1]=5 vs k[3]=7 — the within-step adjacency carries nothing
    assert t3["b_fetches"] == 4


def test_unrolled_plan_traffic_matches_fetch_flags():
    """Plan traffic is priced from the same fetch flags the kernel's DMA
    pipeline is gated by — predicted counts ARE the schedule's counts."""
    a = _patterns()["random"]
    plan = api.plan_matmul(a, n_cols_hint=64, n_lanes=2, unroll=2,
                           fold_len=3, cache=False)
    k = np.asarray(plan.k_idx)
    valid = np.asarray(plan.valid).astype(bool)
    k2 = k.reshape(plan.n_lanes, -1)
    delta = np.ones_like(k2, dtype=bool)
    delta[:, 1:] = k2[:, 1:] != k2[:, :-1]
    n_fetch = int((delta.reshape(-1) & valid).sum())
    assert plan.traffic["b_fetches"] == n_fetch
    assert int(np.asarray(plan.b_fetch).sum()) == n_fetch
    assert plan.traffic["a_fetches"] == int(np.asarray(plan.a_fetch).sum())


def test_lane_traffic_accounts_boundary_breaks():
    """Cutting the schedule into lanes re-fetches B at every lane start —
    modeled traffic must not claim cross-lane boundary reuse."""
    a = BSR.random(np.random.default_rng(5), (512, 512), (64, 64), 0.25)
    t1 = api.plan_matmul(a, n_cols_hint=256, n_lanes=1).traffic
    t4 = api.plan_matmul(a, n_cols_hint=256, n_lanes=4).traffic
    assert t4["b_fetches"] >= t1["b_fetches"]
    assert t4["total"] >= t1["total"]
    assert t4["imbalance"] >= 1.0


# ---------------------------------------------------------------------------
# numeric parity across lane counts / folding / backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_lanes", [1, 2, 4])
@pytest.mark.parametrize("fold_len", [None, 2])
def test_lane_parity_vs_dense_oracle(n_lanes, fold_len):
    for name, a in _patterns().items():
        plan = api.plan_matmul(a, policy="segment", n_lanes=n_lanes,
                               fold_len=fold_len)
        x = jnp.asarray(
            RNG.standard_normal((a.shape[1], 64)).astype(np.float32))
        want = a.to_dense() @ np.asarray(x)
        got = np.asarray(plan(x, bn=32, backend="interpret"))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4,
                                   err_msg=f"{name}/lanes={n_lanes}")
        got_ref = np.asarray(plan(x, backend="reference"))
        np.testing.assert_allclose(got_ref, want, rtol=1e-4, atol=1e-4)


def test_unroll_parity():
    a = _patterns()["random"]
    x = jnp.asarray(RNG.standard_normal((a.shape[1], 64)).astype(np.float32))
    want = a.to_dense() @ np.asarray(x)
    plan = api.plan_matmul(a, n_lanes=2, unroll=2, fold_len=3)
    assert plan.unroll == 2 and plan.n_items % (2 * plan.n_lanes) == 0
    got = np.asarray(plan(x, bn=32, backend="interpret"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_spgemm_lane_parity():
    a = BSR.random(np.random.default_rng(6), (128, 160), (32, 32), 0.3)
    b = BSR.random(np.random.default_rng(7), (160, 96), (32, 32), 0.3)
    want = a.to_dense() @ b.to_dense()
    for n_lanes in (1, 3):
        plan = api.plan_matmul(a, b, n_lanes=n_lanes)
        got = np.asarray(plan(backend="interpret"))
        for i, (r, c) in enumerate(zip(plan.c_brow, plan.c_bcol)):
            np.testing.assert_allclose(
                got[i], want[r * 32:(r + 1) * 32, c * 32:(c + 1) * 32],
                rtol=1e-4, atol=1e-4, err_msg=f"lanes={n_lanes}")


@pytest.mark.parametrize("backend", ["interpret", "reference"])
def test_lane_vjp_matches_dense(backend):
    a = BSR.random(np.random.default_rng(8), (96, 128), (32, 32), 0.4)
    plan = api.plan_matmul(a, with_grad=True, n_lanes=2)
    assert plan.grad_plan.transpose_lhs
    x = jnp.asarray(RNG.standard_normal((128, 48)).astype(np.float32))

    def loss(blocks, xx):
        return jnp.sum(api.apply_plan(plan.with_values(blocks), xx,
                                      backend=backend) ** 2)

    gb, gx = jax.grad(loss, argnums=(0, 1))(plan.lhs_blocks, x)
    w = jnp.asarray(a.to_dense())
    gw, gx_d = jax.grad(
        lambda w_, xx: jnp.sum((w_ @ xx) ** 2), argnums=(0, 1))(w, x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_d),
                               rtol=1e-3, atol=1e-3)
    brow, bcol = np.asarray(plan.a_brow), np.asarray(plan.a_bcol)
    for s in range(plan.n_blocks):
        r, c = int(brow[s]), int(bcol[s])
        np.testing.assert_allclose(
            np.asarray(gb)[s],
            np.asarray(gw)[r * 32:(r + 1) * 32, c * 32:(c + 1) * 32],
            rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# edge cases: empty symbolic output pattern, single-block matrices
# ---------------------------------------------------------------------------


def test_spgemm_empty_output_pattern():
    """No A column meets a B row → the symbolic phase yields zero C blocks;
    the plan must build and execute (empty C array) on every backend."""
    a = BSR(shape=(64, 64), block_shape=(32, 32),
            brow=np.array([0, 1], np.int32), bcol=np.array([0, 0], np.int32),
            blocks=np.ones((2, 32, 32), np.float32))
    b = BSR(shape=(64, 64), block_shape=(32, 32),
            brow=np.array([1], np.int32), bcol=np.array([0], np.int32),
            blocks=np.ones((1, 32, 32), np.float32))
    assert not (a.block_mask() @ b.block_mask()).any()
    for quantize in (None, "int8"):
        plan = api.plan_matmul(a, b, quantize=quantize)
        assert plan.n_out_blocks == 0 and plan.n_items == 0
        for backend in ("interpret", "reference"):
            out = plan(backend=backend)
            assert out.shape == (0, 32, 32)


def test_single_block_matrix_spmm_and_spgemm():
    rng = np.random.default_rng(20)
    one = BSR(shape=(32, 32), block_shape=(32, 32),
              brow=np.array([0], np.int32), bcol=np.array([0], np.int32),
              blocks=rng.standard_normal((1, 32, 32)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    plan = api.plan_matmul(one, x.shape, n_lanes=4)   # clamps to 1 chain
    assert plan.n_lanes == 1 and plan.n_items == 1
    got = np.asarray(plan(x, bn=16, backend="interpret"))
    np.testing.assert_allclose(got, one.to_dense() @ np.asarray(x),
                               rtol=1e-5, atol=1e-5)
    gplan = api.plan_matmul(one, one)
    assert gplan.n_out_blocks == 1
    gotg = np.asarray(gplan(backend="interpret"))
    np.testing.assert_allclose(gotg[0], one.to_dense() @ one.to_dense(),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# validate_schedule_args error paths (named ValueErrors with shapes)
# ---------------------------------------------------------------------------


def _spmm_args(n_items=2):
    """Minimal hand-built schedule: n_items same-row items of one block."""
    i32 = lambda *v: jnp.asarray(np.array(v, np.int32))
    return dict(
        a_blocks=jnp.ones((1, 8, 8), jnp.float32),
        slot_idx=i32(*([0] * n_items)), m_idx=i32(*([0] * n_items)),
        k_idx=i32(*range(n_items)),
        seg_start=i32(1, *([0] * (n_items - 1))),
        seg_write=i32(*([0] * (n_items - 1)), 1),
        accum_prev=i32(*([0] * n_items)), valid=i32(*([1] * n_items)),
        b_dense=jnp.ones((8, 16), jnp.float32))


def test_segment_spmm_rejects_bad_bn():
    from repro.kernels.segment_spmm import segment_spmm
    with pytest.raises(ValueError, match=r"N=16 .* not divisible by the "
                                         r"N-tile width bn=12"):
        segment_spmm(**_spmm_args(), grid_m=1, bn=12)


def test_segment_spmm_rejects_mismatched_schedule_arrays():
    from repro.kernels.segment_spmm import segment_spmm
    args = _spmm_args()
    args["seg_write"] = jnp.asarray(np.array([0, 1, 1], np.int32))
    with pytest.raises(ValueError, match=r"seg_write has shape \(3,\), "
                                         r"expected \(2,\)"):
        segment_spmm(**args, grid_m=1, bn=16)


def test_segment_spmm_rejects_bad_lane_and_unroll_combos():
    from repro.kernels.segment_spmm import segment_spmm
    with pytest.raises(ValueError, match=r"n_items=2 is not divisible by "
                                         r"n_lanes=3"):
        segment_spmm(**_spmm_args(), grid_m=1, bn=16, n_lanes=3)
    with pytest.raises(ValueError, match=r"lane length 1 is not divisible "
                                         r"by unroll=2"):
        segment_spmm(**_spmm_args(), grid_m=1, bn=16, n_lanes=2, unroll=2)


def test_segment_spmm_rejects_bad_rhs_k():
    from repro.kernels.segment_spmm import segment_spmm
    args = _spmm_args()
    args["b_dense"] = jnp.ones((12, 16), jnp.float32)
    with pytest.raises(ValueError, match=r"rhs K=12 is not a multiple"):
        segment_spmm(**args, grid_m=1, bn=16)


def test_segment_kernels_reject_bad_scale_shapes():
    from repro.kernels.segment_spmm import segment_spmm
    args = _spmm_args()
    with pytest.raises(ValueError, match=r"a_scales has shape \(2,\), "
                                         r"expected one fp32 scale"):
        segment_spmm(**args, grid_m=1, bn=16,
                     a_scales=jnp.ones((2,), jnp.float32))


# ---------------------------------------------------------------------------
# zero-copy realize (the killed O(nnz) gather)
# ---------------------------------------------------------------------------


def test_realize_does_not_copy_blocks():
    """Realizing a plan hands the caller's block buffer through untouched —
    no schedule-order gather of the values, forward or backward."""
    a = BSR.random(np.random.default_rng(9), (128, 128), (32, 32), 0.4)
    a_dev = BSR(a.shape, a.block_shape, a.brow, a.bcol,
                jnp.asarray(a.blocks))
    plan = api.plan_matmul(a_dev, with_grad=True, cache=False)
    assert plan.lhs_blocks is a_dev.blocks          # same device buffer
    # the template carries no permutation to apply at realize time
    field_names = {f.name for f in dataclasses.fields(planner._PlanTemplate)}
    assert "fwd_perm" not in field_names
    # the backward plan addresses the same storage via slot_idx + transpose
    g = plan.grad_plan
    assert g.lhs_blocks is None and g.transpose_lhs
    slot = np.asarray(g.slot_idx)[np.asarray(g.valid) == 1]
    assert sorted(set(slot.tolist())) == list(range(a.nblocks))


def test_schedule_indexes_storage_through_slot_idx():
    a = BSR.random(np.random.default_rng(10), (128, 160), (32, 32), 0.35)
    plan = api.plan_matmul(a, n_lanes=2)
    slot = np.asarray(plan.slot_idx)
    valid = np.asarray(plan.valid).astype(bool)
    m_idx, k_idx = np.asarray(plan.m_idx), np.asarray(plan.k_idx)
    # every valid item addresses the stored block with its coordinates
    np.testing.assert_array_equal(np.asarray(plan.a_brow)[slot[valid]],
                                  m_idx[valid])
    np.testing.assert_array_equal(np.asarray(plan.a_bcol)[slot[valid]],
                                  k_idx[valid])


# ---------------------------------------------------------------------------
# property test: pattern × lanes × fold × backend ≡ dense oracle
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=12)
@given(seed=st.integers(0, 10_000), gm=st.integers(1, 6),
       gk=st.integers(1, 6), density=st.floats(0.1, 1.0),
       n_lanes=st.sampled_from([1, 2, 4]),
       fold_len=st.sampled_from([None, 2]))
def test_lane_property_vs_dense(seed, gm, gk, density, n_lanes, fold_len):
    rng = np.random.default_rng(seed)
    a = BSR.random(rng, (gm * 16, gk * 16), (16, 16), density)
    x = rng.standard_normal((gk * 16, 32)).astype(np.float32)
    plan = api.plan_matmul(a, policy="segment", n_lanes=n_lanes,
                           fold_len=fold_len)
    want = a.to_dense() @ x
    got = np.asarray(plan(jnp.asarray(x), bn=32, backend="interpret"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    got_ref = np.asarray(plan(jnp.asarray(x), backend="reference"))
    np.testing.assert_allclose(got_ref, want, rtol=1e-4, atol=1e-4)
