"""SEGMENTBC / V-space invariants (paper §III-B) + correctness."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional-dep guard

from repro.core.formats import CSC, random_csr
from repro.core.segmentbc import VSpace, segment_spgemm_elementwise


def test_vspace_invariants_after_routing():
    vs = VSpace(mapping="lut")
    rng = np.random.default_rng(0)
    for _ in range(200):
        m = int(rng.integers(0, 5))
        n = int(rng.integers(0, 40))
        vs.route(m, n, float(rng.standard_normal()))
        vs.tick()
    vs.check_invariants()          # column ordering per virtual row
    rows, cols, vals = vs.to_coo()
    # injectivity: distinct (m, n) → distinct coordinates
    assert len(set(zip(rows.tolist(), cols.tolist()))) == rows.size


def test_accumulate_vs_insert():
    vs = VSpace(mapping="ideal")
    vs.route(0, 5, 1.0)
    vs.route(0, 5, 2.0)       # accumulate
    vs.route(0, 3, 4.0)       # insert before
    rows, cols, vals = vs.to_coo()
    assert cols.tolist() == [3, 5]
    assert vals.tolist() == [4.0, 3.0]


@pytest.mark.parametrize("mapping", ["zero", "lut", "ideal"])
def test_segment_spgemm_correct(mapping):
    rng = np.random.default_rng(1)
    a = random_csr(rng, (24, 30), 0.12)
    b = random_csr(rng, (30, 20), 0.12)
    c, tel = segment_spgemm_elementwise(CSC.from_csr(a), b, mapping=mapping)
    assert np.allclose(c, a.to_dense() @ b.to_dense(), atol=1e-4)
    assert tel["elements_routed"] > 0


def test_displacement_ordering():
    """zero-offset walks furthest; the stale LUT sits between zero and the
    oracle (paper §VI-C.2)."""
    rng = np.random.default_rng(2)
    a = random_csr(rng, (32, 40), 0.15)
    b = random_csr(rng, (40, 32), 0.15)
    disps = {}
    for mapping in ("zero", "lut", "ideal"):
        _, tel = segment_spgemm_elementwise(CSC.from_csr(a), b, mapping=mapping)
        disps[mapping] = tel["mean_displacement"]
    assert disps["ideal"] == 0.0
    assert disps["ideal"] <= disps["lut"] <= disps["zero"] + 1e-9


def test_stale_lut_never_overshoots():
    """Time-ascending property: a stale LUT start is always ≤ the true
    legal start (left of it), never beyond the match position."""
    vs = VSpace(mapping="lut", lut_write_ports=1)
    rng = np.random.default_rng(3)
    for i in range(100):
        n = int(rng.integers(0, 50))
        s = vs.start_position(0, n)
        true_s = int(np.searchsorted(
            np.asarray(vs.rows[0].cols if 0 in vs.rows else [], dtype=np.int64), n))
        assert s <= true_s
        vs.route(0, n, 1.0)
        if i % 3 == 0:
            vs.tick()


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 10_000),
       mapping=st.sampled_from(["zero", "lut", "ideal"]))
def test_spgemm_property(seed, mapping):
    rng = np.random.default_rng(seed)
    a = random_csr(rng, (12, 14), 0.2)
    b = random_csr(rng, (14, 10), 0.2)
    c, _ = segment_spgemm_elementwise(CSC.from_csr(a), b, mapping=mapping)
    assert np.allclose(c, a.to_dense() @ b.to_dense(), atol=1e-4)
