"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs (the full configs are exercised only via the
dry-run)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, REGISTRY, reduced_config
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, t=64):
    batch = {"tokens": jnp.arange(b * t).reshape(b, t) % cfg.vocab}
    batch["targets"] = jnp.roll(batch["tokens"], -1, axis=1)
    if cfg.family == "vlm":
        batch["vis_embeds"] = jnp.ones(
            (b, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "enc_dec":
        batch["enc_embeds"] = jnp.ones(
            (b, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_loss(arch):
    cfg = reduced_config(REGISTRY[arch])
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    logits, aux = jax.jit(model.forward)(
        params, batch["tokens"], vis_embeds=batch.get("vis_embeds"),
        enc_embeds=batch.get("enc_embeds"))
    t_total = batch["tokens"].shape[1] + (
        cfg.n_frontend_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, t_total, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    loss, _ = jax.jit(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss))
    # random-init loss ≈ ln(padded_vocab) sanity band
    assert 0.5 * np.log(cfg.padded_vocab) < float(loss) < 2.5 * np.log(cfg.padded_vocab)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode(arch):
    cfg = reduced_config(REGISTRY[arch])
    model = build_model(cfg)
    params = model.init(KEY)
    cache = model.init_cache(2, 128)
    tok = jnp.zeros((2, 1), jnp.int32)
    step = jax.jit(model.decode_step)
    logits, cache = step(params, cache, tok, jnp.int32(0))
    logits2, cache = step(params, cache, tok, jnp.int32(1))
    assert logits.shape == (2, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


@pytest.mark.parametrize("arch", ["granite-3-8b", "llama4-maverick-400b-a17b",
                                  "recurrentgemma-9b", "rwkv6-1.6b",
                                  "whisper-tiny"])
def test_arch_gradients(arch):
    cfg = reduced_config(REGISTRY[arch])
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    grads = jax.jit(jax.grad(lambda p, b: model.loss_fn(p, b)[0]))(params, batch)
    gnorm = float(jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                               for g in jax.tree.leaves(grads))))
    assert np.isfinite(gnorm) and gnorm > 0


def test_decode_matches_forward():
    """Greedy decode over a prompt must produce the same last-token logits
    as a full forward pass (cache correctness)."""
    cfg = dataclasses.replace(reduced_config(REGISTRY["granite-3-8b"]),
                              attn_chunk=32)
    model = build_model(cfg)
    params = model.init(KEY)
    b, t = 2, 16
    toks = (jnp.arange(b * t).reshape(b, t) * 7) % cfg.vocab
    logits_full, _ = model.forward(params, toks)
    cache = model.init_cache(b, 64)
    # feed tokens one by one
    for i in range(t):
        logits_dec, cache = model.decode_step(
            params, cache, toks[:, i:i + 1], jnp.int32(i))
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full[:, -1], np.float32), rtol=2e-2, atol=2e-2)


def test_chunked_prefill_matches_stepwise():
    cfg = reduced_config(REGISTRY["qwen1.5-4b"])
    model = build_model(cfg)
    params = model.init(KEY)
    b, t = 1, 24
    toks = (jnp.arange(b * t).reshape(b, t) * 11) % cfg.vocab
    cache = model.init_cache(b, 64)
    logits_chunk, _ = model.decode_step(params, cache, toks, jnp.int32(0))
    cache2 = model.init_cache(b, 64)
    for i in range(t):
        logits_step, cache2 = model.decode_step(
            params, cache2, toks[:, i:i + 1], jnp.int32(i))
    np.testing.assert_allclose(np.asarray(logits_chunk, np.float32),
                               np.asarray(logits_step, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_sparse_ffn_variant_trains():
    cfg = dataclasses.replace(reduced_config(REGISTRY["phi3-mini-3.8b"]),
                              ffn_block_sparse=True, ffn_block=32,
                              ffn_density=0.5)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    loss, _ = jax.jit(model.loss_fn)(params, batch)
    grads = jax.jit(jax.grad(lambda p, b: model.loss_fn(p, b)[0]))(params, batch)
    gn = float(jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                            for g in jax.tree.leaves(grads))))
    assert np.isfinite(float(loss)) and np.isfinite(gn) and gn > 0


def test_int8_kv_cache_close_to_bf16():
    """Beyond-paper int8 KV cache: greedy-decode logits stay within 5% of
    the bf16 cache path (see EXPERIMENTS.md §Perf cell C4)."""
    base = reduced_config(REGISTRY["granite-3-8b"])
    q8 = dataclasses.replace(base, kv_cache_dtype="int8")
    m_bf, m_q8 = build_model(base), build_model(q8)
    params = m_bf.init(KEY)
    b, t = 2, 16
    toks = (jnp.arange(b * t).reshape(b, t) * 7) % base.vocab
    c_bf = m_bf.init_cache(b, 64)
    c_q8 = m_q8.init_cache(b, 64)
    for i in range(t):
        lo_bf, c_bf = m_bf.decode_step(params, c_bf, toks[:, i:i + 1],
                                       jnp.int32(i))
        lo_q8, c_q8 = m_q8.decode_step(params, c_q8, toks[:, i:i + 1],
                                       jnp.int32(i))
    a = np.asarray(lo_bf, np.float32)
    b_ = np.asarray(lo_q8, np.float32)
    assert np.abs(a - b_).max() / (np.abs(a).max() + 1e-9) < 0.05
