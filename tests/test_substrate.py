"""Data pipeline, optimizer, checkpoint, trainer fault-tolerance tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import REGISTRY, reduced_config
from repro.configs.base import ShapeConfig
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.optim import AdamW, constant, cosine_with_warmup
from repro.runtime import Trainer, TrainerConfig


CFG = reduced_config(REGISTRY["granite-3-8b"])
SHAPE = ShapeConfig("tiny", "train", seq_len=32, global_batch=4)


# --- data ---------------------------------------------------------------


def test_data_deterministic():
    d1 = SyntheticDataset(CFG, SHAPE, seed=7)
    d2 = SyntheticDataset(CFG, SHAPE, seed=7)
    b1, b2 = d1.batch(13), d2.batch(13)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch(14)["tokens"], b1["tokens"])


def test_data_shard_consistency():
    """Any host computing any shard gets exactly the global batch rows —
    the property elastic re-assignment and straggler duplication rely on."""
    d = SyntheticDataset(CFG, SHAPE, seed=3)
    full = d.batch(5)
    part0 = d.batch(5, shard=slice(0, 2))
    part1 = d.batch(5, shard=slice(2, 4))
    assert np.array_equal(np.concatenate([part0["tokens"], part1["tokens"]]),
                          full["tokens"])
    assert full["targets"].shape == full["tokens"].shape
    assert np.array_equal(full["targets"][:, :-1], full["tokens"][:, 1:])


# --- optimizer ------------------------------------------------------------


def test_adamw_converges_quadratic():
    opt = AdamW(lr=constant(0.1), weight_decay=0.0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_grad_clip():
    opt = AdamW(lr=constant(0.0), clip_norm=1.0)
    params = {"x": jnp.zeros(3)}
    state = opt.init(params)
    _, _, m = opt.update({"x": jnp.full(3, 100.0)}, state, params)
    assert float(m["grad_norm"]) > 1.0   # reported pre-clip norm


def test_cosine_schedule_shape():
    lr = cosine_with_warmup(1.0, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert float(lr(jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(lr(jnp.int32(100))) == pytest.approx(0.1, rel=1e-2)


# --- checkpoint -------------------------------------------------------------


def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        state = {"a": jnp.arange(10, dtype=jnp.float32),
                 "b": {"c": jnp.ones((3, 3), jnp.bfloat16)}}
        for step in (5, 10, 15):
            mgr.save(step, state, wait=True)
        assert mgr.all_steps() == [10, 15]          # gc keeps last 2
        restored = mgr.restore(15, state)
        assert np.array_equal(np.asarray(restored["a"]), np.arange(10))
        assert restored["b"]["c"].dtype == jnp.bfloat16
        # no stale tmp dirs (atomicity)
        assert not [n for n in os.listdir(d) if n.endswith(".tmp")]


# --- trainer fault tolerance ---------------------------------------------


class _Crash(Exception):
    pass


def test_crash_resume_matches_uninterrupted():
    tc = dict(steps=8, ckpt_every=4, log_every=1, accum_steps=2,
              peak_lr=1e-3, warmup=2)
    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        # uninterrupted
        t_ref = Trainer(build_model(CFG), CFG, SHAPE,
                        TrainerConfig(ckpt_dir=d1, **tc))
        ref = t_ref.run()

        # crash at step 4 (after the step-4 checkpoint), then resume
        t1 = Trainer(build_model(CFG), CFG, SHAPE,
                     TrainerConfig(ckpt_dir=d2, **tc))

        def boom(step):
            if step == 4:
                t1.ckpt.wait()
                raise _Crash()

        with pytest.raises(_Crash):
            t1.run(failure_hook=boom)
        t2 = Trainer(build_model(CFG), CFG, SHAPE,
                     TrainerConfig(ckpt_dir=d2, **tc))
        assert t2.start_step == 4
        out = t2.run()
        assert out["final_loss"] == pytest.approx(ref["final_loss"], rel=1e-4)
