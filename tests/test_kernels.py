"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret mode)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st  # optional-dep guard

from repro.core.formats import BSR
from repro.kernels import ops, ref


RNG = np.random.default_rng(0)


@pytest.mark.parametrize("policy", ["segment", "gustavson"])
@pytest.mark.parametrize("m,k,bm,bk,density", [
    (256, 384, 64, 64, 0.3),
    (128, 256, 32, 64, 0.15),
    (512, 512, 128, 128, 0.2),
    (64, 64, 8, 8, 0.5),
])
def test_spmm_vs_oracle(policy, m, k, bm, bk, density):
    a = BSR.random(RNG, (m, k), (bm, bk), density)
    bd = RNG.standard_normal((k, 256)).astype(np.float32)
    out = np.asarray(ops.plan_spmm(a, policy=policy)(jnp.asarray(bd), bn=128))
    want = a.to_dense() @ bd
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_spmm_dtypes(dtype):
    a = BSR.random(RNG, (128, 128), (32, 32), 0.4)
    a.blocks = a.blocks.astype(dtype)
    bd = RNG.standard_normal((128, 64)).astype(np.float32)
    out = np.asarray(ops.plan_spmm(a)(jnp.asarray(bd).astype(dtype), bn=64),
                     dtype=np.float32)
    want = np.asarray(a.blocks, np.float32)
    dense = BSR(a.shape, a.block_shape, a.brow, a.bcol, want).to_dense() @ bd
    tol = 1e-4 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(out, dense, rtol=tol, atol=tol)


@pytest.mark.parametrize("policy", ["segment", "gustavson"])
def test_spgemm_vs_oracle(policy):
    a = BSR.random(RNG, (256, 320), (64, 64), 0.3)
    b = BSR.random(RNG, (320, 192), (64, 64), 0.3)
    plan = ops.plan_spgemm(a, b, policy=policy)
    blocks = np.asarray(plan())
    want = a.to_dense() @ b.to_dense()
    for i, (r, c) in enumerate(zip(plan.c_brow, plan.c_bcol)):
        np.testing.assert_allclose(
            blocks[i], want[r * 64:(r + 1) * 64, c * 64:(c + 1) * 64],
            rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,tq,tk,h,hkv,d,causal,window", [
    (2, 128, 128, 4, 2, 64, True, None),
    (1, 64, 256, 4, 1, 64, True, None),
    (2, 128, 128, 4, 4, 64, True, 64),
    (2, 128, 128, 4, 2, 64, True, 64),      # GQA × window (q_period wrap)
    (1, 1, 96, 8, 2, 64, True, None),       # decode shape
    (2, 48, 48, 2, 2, 32, False, None),     # bidirectional, ragged sizes
    (1, 32, 512, 2, 2, 128, True, 128),     # long kv + window
])
def test_flash_attention_vs_oracle(b, tq, tk, h, hkv, d, causal, window):
    q = RNG.standard_normal((b, tq, h, d)).astype(np.float32) * 0.5
    k = RNG.standard_normal((b, tk, hkv, d)).astype(np.float32) * 0.5
    v = RNG.standard_normal((b, tk, hkv, d)).astype(np.float32) * 0.5
    out = ops.flash_mha(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                        causal=causal, window=window)
    want = ref.mha_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=2e-5)


@pytest.mark.parametrize("b,t,d,ct", [(2, 128, 64, 32), (1, 64, 128, 64),
                                      (3, 96, 32, 16)])
def test_rg_lru_vs_oracle(b, t, d, ct):
    x = RNG.standard_normal((b, t, d)).astype(np.float32)
    ag = RNG.standard_normal((b, t, d)).astype(np.float32)
    xg = RNG.standard_normal((b, t, d)).astype(np.float32)
    ap = RNG.standard_normal(d).astype(np.float32)
    out, hT = ops.rg_lru_scan(*map(jnp.asarray, (x, ag, xg, ap)), ct=ct)
    want, wT = ref.rg_lru_ref(x, ag, xg, ap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(wT), atol=1e-5)


def test_rwkv_ref_state_continuity():
    """Chunked evaluation with carried state equals one-shot evaluation."""
    b, t, h, d = 1, 32, 2, 16
    r, k, v = (RNG.standard_normal((b, t, h, d)).astype(np.float32) * 0.3
               for _ in range(3))
    w = -np.abs(RNG.standard_normal((b, t, h, d))).astype(np.float32) - 0.1
    u = RNG.standard_normal((h, d)).astype(np.float32) * 0.1
    full, _ = ref.rwkv6_ref(*map(jnp.asarray, (r, k, v, w, u)))
    half1, s = ref.rwkv6_ref(*map(jnp.asarray,
                                  (r[:, :16], k[:, :16], v[:, :16], w[:, :16], u)))
    half2, _ = ref.rwkv6_ref(jnp.asarray(r[:, 16:]), jnp.asarray(k[:, 16:]),
                             jnp.asarray(v[:, 16:]), jnp.asarray(w[:, 16:]),
                             jnp.asarray(u), state0=s)
    np.testing.assert_allclose(np.asarray(full[:, 16:]), np.asarray(half2),
                               rtol=1e-4, atol=1e-5)


def test_moe_apply_vs_dense_oracle():
    t, dm, dff, e, topk = 128, 32, 64, 4, 2
    x = RNG.standard_normal((t, dm)).astype(np.float32) * 0.3
    wu = RNG.standard_normal((e, dm, dff)).astype(np.float32) * 0.1
    wd = RNG.standard_normal((e, dff, dm)).astype(np.float32) * 0.1
    logits = RNG.standard_normal((t, e)).astype(np.float32)
    out = np.asarray(ops.moe_apply(
        jnp.asarray(x), jnp.asarray(wu), jnp.asarray(wd), jnp.asarray(logits),
        top_k=topk, chunk_rows=16, capacity_factor=8.0, interpret=True))
    tv, ti = jax.lax.top_k(jnp.asarray(logits), topk)
    g = np.asarray(jax.nn.softmax(tv, -1))
    want = np.zeros((t, dm), np.float32)
    for tok in range(t):
        for j in range(topk):
            ex = int(ti[tok, j])
            want[tok] += g[tok, j] * np.asarray(
                jax.nn.silu(x[tok] @ wu[ex]) @ wd[ex])
    np.testing.assert_allclose(out, want, rtol=1e-3, atol=1e-4)


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 10_000), gm=st.integers(1, 6), gk=st.integers(1, 6),
       density=st.floats(0.1, 1.0),
       policy=st.sampled_from(["segment", "gustavson"]))
def test_spmm_property(seed, gm, gk, density, policy):
    rng = np.random.default_rng(seed)
    a = BSR.random(rng, (gm * 16, gk * 16), (16, 16), density)
    bd = rng.standard_normal((gk * 16, 32)).astype(np.float32)
    out = np.asarray(ops.plan_spmm(a, policy=policy)(jnp.asarray(bd), bn=32))
    np.testing.assert_allclose(out, a.to_dense() @ bd, rtol=1e-4, atol=1e-4)
