"""Roofline machinery: HLO collective parsing + term math."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.analysis import (collective_bytes, model_flops,
                                     roofline_terms)
from repro.configs import get_config
from repro.configs.base import SHAPES


def test_collective_parser_on_real_hlo():
    import os
    import subprocess, sys, textwrap
    # psum inside shard_map must surface as all-reduce bytes
    script = textwrap.dedent("""
        import os
        os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
        import jax, jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.roofline.analysis import collective_bytes
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ('d',))
        def f(x):
            return jax.lax.psum(x, 'd')
        g = shard_map(f, mesh=mesh, in_specs=P('d'), out_specs=P())
        c = jax.jit(g).lower(jax.ShapeDtypeStruct((64, 128), jnp.float32)).compile()
        cb = collective_bytes(c.as_text())
        assert cb.get('all-reduce', 0) > 0, cb
        print('PARSER_OK', cb)
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300, env=env)
    assert r.returncode == 0 and "PARSER_OK" in r.stdout, r.stderr


def test_collective_parser_text():
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), dimensions={0}
  %ar.1 = f32[256]{0} all-reduce-start(%y), to_apply=%sum
  %rs = (f32[64]{0}, f32[64]{0}) reduce-scatter(%a, %b), dimensions={0}
  %cp = u32[16]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    cb = collective_bytes(hlo)
    assert cb["all-gather"] == 8 * 128 * 2
    assert cb["all-reduce"] == 256 * 4
    assert cb["reduce-scatter"] == 2 * 64 * 4
    assert cb["collective-permute"] == 16 * 4


def test_roofline_terms_dominance():
    t = roofline_terms(flops=197e12 * 256, bytes_accessed=1.0,
                       coll_bytes=1.0, chips=256)
    assert t["dominant"] == "compute_s"
    assert abs(t["compute_s"] - 1.0) < 1e-9


def test_model_flops_sane():
    cfg = get_config("granite-3-8b")
    mf_train = model_flops(cfg, SHAPES["train_4k"])
    mf_prefill = model_flops(cfg, SHAPES["prefill_32k"])
    n = cfg.param_count()
    toks = 256 * 4096
    assert mf_train > 3 * 2 * n * toks * 0.9       # ≥ 6·N·D
    assert mf_prefill > 2 * n * 32 * 32768 * 0.9
    # MoE active < total
    moe = get_config("phi3.5-moe-42b-a6.6b")
    assert moe.active_param_count() < 0.5 * moe.param_count()
