"""Static-analysis battery: plan-verifier mutation kills + kernel linter.

Every invariant in ``repro.analysis.INVARIANTS`` gets a mutation-kill
test: take a clean planner-built plan, apply ONE targeted corruption, and
assert the verifier reports exactly that invariant (after its specificity
suppression).  Clean plans across the knob grid must verify with zero
findings, degenerate plans must not crash, and the jaxpr linter must flag
deliberately hazardous toy kernels while passing the shipped ones.
"""
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from _hypothesis_compat import given, settings, st
from repro import api
from repro.analysis import (INVARIANTS, PlanVerificationError, lint_callable,
                            lint_segment_kernels, verify_plan)
from repro.core.formats import BSR


def _spmm_plan(**kw):
    a = BSR.random(np.random.default_rng(2), (256, 256), (32, 32), 0.5)
    kw.setdefault("policy", "segment")
    kw.setdefault("cache", False)
    return api.plan_matmul(a, **kw)


def _ids(plan, **kw):
    """Reported invariant ids (post-suppression) at level='full'."""
    return sorted({f.invariant
                   for f in verify_plan(plan, level="full", **kw).findings})


@pytest.fixture(scope="module")
def plan():
    p = _spmm_plan(n_lanes=2, unroll=2)
    assert p.has_pads, "mutation battery expects a padded schedule"
    assert verify_plan(p, level="full").ok
    return p


# ---------------------------------------------------------------------------
# mutation kills — one targeted corruption per invariant class
# ---------------------------------------------------------------------------


def test_kill_shape_agreement(plan):
    bad = plan.replace(seg_write=np.asarray(plan.seg_write)[:-1])
    assert _ids(bad) == ["shape-agreement"]


def test_kill_lane_divisibility(plan):
    # a non-divisible lane count over the same arrays
    bad = plan.replace(n_lanes=3)
    assert _ids(bad) == ["lane-divisibility"]


def test_kill_lane_divisibility_unroll(plan):
    bad = plan.replace(unroll=plan.lane_len * 2)
    assert _ids(bad) == ["lane-divisibility"]


def test_kill_index_bounds(plan):
    slot = np.asarray(plan.slot_idx).copy()
    slot[0] = plan.n_blocks + 7
    assert _ids(plan.replace(slot_idx=slot)) == ["index-bounds"]


def test_kill_slot_out_of_ring(plan):
    s = np.asarray(plan.a_slot).copy()
    s[0] = 2 * plan.unroll   # one past the ring
    assert "index-bounds" in _ids(plan.replace(a_slot=s))


def test_kill_segment_structure(plan):
    m = np.asarray(plan.m_idx)
    v = np.asarray(plan.valid)
    ss = np.asarray(plan.seg_start).copy()
    lane_len = plan.lane_len
    i = next(i for i in range(1, plan.n_items)
             if v[i] and v[i - 1] and m[i] != m[i - 1] and ss[i] == 1
             and i % lane_len != 0)
    ss[i] = 0   # owner changes without a segment head
    assert _ids(plan.replace(seg_start=ss)) == ["segment-structure"]


def test_kill_accum_prev_order(plan):
    v = np.asarray(plan.valid)
    ss = np.asarray(plan.seg_start)
    ap = np.asarray(plan.accum_prev).copy()
    heads = [i for i in range(plan.n_items)
             if v[i] and ss[i] == 1 and ap[i] == 0]
    ap[heads[0]] = 1   # RMW-read a tile nothing wrote earlier in the lane
    assert _ids(plan.replace(accum_prev=ap)) == ["accum-prev-order"]


def test_kill_pads_fetch_nothing(plan):
    pads = np.nonzero(np.asarray(plan.valid) == 0)[0]
    f = np.asarray(plan.a_fetch).copy()
    f[pads[0]] = 1   # a pad that issues a DMA
    assert _ids(plan.replace(a_fetch=f)) == ["pads-fetch-nothing"]


def test_kill_lane_first_fetch(plan):
    f = np.asarray(plan.b_fetch).copy()
    f[0] = 0   # lane head inheriting residency it cannot have
    assert _ids(plan.replace(b_fetch=f)) == ["lane-first-fetch"]


def test_kill_fetch_on_change(plan):
    v = np.asarray(plan.valid)
    f = np.asarray(plan.b_fetch).copy()
    i = next(i for i in range(plan.n_items)
             if v[i] and f[i] == 0 and i % plan.lane_len != 0)
    f[i] = 1   # spurious re-fetch of the resident tile
    assert _ids(plan.replace(b_fetch=f)) == ["fetch-on-change"]


def test_kill_slot_advance(plan):
    f = np.asarray(plan.a_fetch)
    s = np.asarray(plan.a_slot).copy()
    fi = np.nonzero(f == 1)[0]
    i1, i2 = int(fi[1]), int(fi[2])
    assert s[i1] != s[i2]
    s[i1], s[i2] = s[i2], s[i1]   # ring advances out of order
    assert _ids(plan.replace(a_slot=s)) == ["slot-advance"]


def test_kill_ring_war(plan):
    # Redirect a fetch onto the slot whose tile is still being read at the
    # fetch's issue step.  Any such corruption also breaks slot-advance's
    # exact cumsum contract (which subsumes WAR safety on planner-built
    # rings), so the liveness property is judged in isolation via the
    # invariants filter — the documented use of that parameter.
    f = np.asarray(plan.a_fetch)
    s = np.asarray(plan.a_slot).copy()
    lane_len, unroll = plan.lane_len, plan.unroll
    for j in np.nonzero(f == 1)[0]:
        j = int(j)
        if j % lane_len == 0:
            continue
        lane = j // lane_len
        issue_step = max(j // unroll - 1, 0)
        live = s[lane * lane_len + issue_step * unroll]
        if s[j] != live:
            s[j] = live
            break
    else:
        pytest.skip("no redirectable fetch in this schedule")
    mutated = plan.replace(a_slot=s)
    assert _ids(mutated, invariants=("ring-war",)) == ["ring-war"]
    # the default run roots the same corruption at the slot contract
    assert _ids(mutated) == ["slot-advance"]


def test_kill_scale_agreement():
    q = _spmm_plan(n_lanes=2, quantize="int8")
    bad = q.replace(lhs_scales=jnp.ones((3,), jnp.float32))
    assert _ids(bad) == ["scale-agreement"]
    # fp32 plan carrying scales is the inverse corruption
    p = _spmm_plan(n_lanes=2)
    bad = p.replace(lhs_scales=jnp.ones((p.n_blocks,), jnp.float32))
    assert _ids(bad) == ["scale-agreement"]


def test_kill_traffic_agreement(plan):
    items = tuple((k, v + 1 if k == "a_fetches" else v)
                  for k, v in plan.traffic_items)
    bad = plan.replace(traffic_items=items)
    assert _ids(bad) == ["traffic-agreement"]
    # fast level deliberately skips the model recomputation
    assert verify_plan(bad, level="fast").ok


def test_every_invariant_has_a_kill():
    """The catalog and this file's kill coverage must not drift apart."""
    covered = {
        "shape-agreement", "lane-divisibility", "index-bounds",
        "segment-structure", "accum-prev-order", "pads-fetch-nothing",
        "lane-first-fetch", "fetch-on-change", "slot-advance", "ring-war",
        "scale-agreement", "traffic-agreement",
    }
    assert covered == set(INVARIANTS)


# ---------------------------------------------------------------------------
# clean plans verify clean — knob grid + hypothesis sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", [
    dict(),
    dict(n_lanes=2),
    dict(n_lanes=4, unroll=2),
    dict(n_lanes=2, unroll=2, quantize="int8"),
    dict(n_lanes=2, unroll=2, quantize="fp8"),
    dict(n_lanes=3, unroll=2, fold_len=3, with_grad=True),
])
def test_knob_grid_verifies_clean(kw):
    res = verify_plan(_spmm_plan(**kw), level="full")
    assert res.ok, res.summary()
    assert set(res.checked) == set(INVARIANTS)


def test_spgemm_verifies_clean():
    a = BSR.random(np.random.default_rng(4), (256, 256), (32, 32), 0.5)
    b = BSR.random(np.random.default_rng(5), (256, 256), (32, 32), 0.5)
    for kw in (dict(), dict(n_lanes=2, unroll=2)):
        res = verify_plan(api.plan_matmul(a, b, cache=False, **kw),
                          level="full")
        assert res.ok, res.summary()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), n_lanes=st.integers(1, 4),
       unroll=st.sampled_from([1, 2]),
       quantize=st.sampled_from([None, "int8"]))
def test_verifies_clean_hypothesis(seed, n_lanes, unroll, quantize):
    a = BSR.random(np.random.default_rng(seed), (160, 160), (32, 32), 0.4)
    if a.nblocks == 0:
        return
    plan = api.plan_matmul(a, policy="segment", n_lanes=n_lanes,
                           unroll=unroll, fold_len=3, quantize=quantize,
                           cache=False)
    res = verify_plan(plan, level="full")
    assert res.ok, res.summary()


# ---------------------------------------------------------------------------
# degenerate plans — must verify clean, not crash
# ---------------------------------------------------------------------------


def test_degenerate_single_block():
    a = BSR.random(np.random.default_rng(0), (32, 32), (32, 32), 1.0)
    for kw in (dict(), dict(n_lanes=4, unroll=1)):
        res = verify_plan(api.plan_matmul(a, cache=False, **kw),
                          level="full")
        assert res.ok, res.summary()


def test_degenerate_one_lane_unpadded():
    p = _spmm_plan(n_lanes=1)
    assert not p.has_pads
    assert verify_plan(p, level="full").ok


def test_degenerate_empty_symbolic_c():
    # A's only column never meets B's only row: zero symbolic C blocks
    blk = (32, 32)
    a = BSR(shape=(128, 128), block_shape=blk,
            brow=np.zeros(1, np.int64), bcol=np.zeros(1, np.int64),
            blocks=np.ones((1,) + blk, np.float32))
    b = BSR(shape=(128, 128), block_shape=blk,
            brow=np.full(1, 3, np.int64), bcol=np.zeros(1, np.int64),
            blocks=np.ones((1,) + blk, np.float32))
    plan = api.plan_matmul(a, b, cache=False)
    assert plan.n_out_blocks == 0
    res = verify_plan(plan, level="full")
    assert res.ok, res.summary()
    # the executor short-circuit stays intact under verify=
    out = api.execute_plan(plan, backend="reference", verify="full")
    assert out.shape[0] == 0


# ---------------------------------------------------------------------------
# verifier API surface
# ---------------------------------------------------------------------------


def test_verify_rejects_bad_level_and_ids(plan):
    with pytest.raises(ValueError, match="level must be"):
        verify_plan(plan, level="paranoid")
    with pytest.raises(ValueError, match="unknown invariant"):
        verify_plan(plan, invariants=("no-such-check",))


def test_plan_verify_method(plan):
    res = plan.verify(level="full")
    assert res.ok
    bad = plan.replace(seg_write=np.asarray(plan.seg_write)[:-1])
    with pytest.raises(PlanVerificationError, match="shape-agreement"):
        bad.verify().raise_if_findings()


def test_grad_plan_findings_carry_path():
    p = _spmm_plan(n_lanes=2, with_grad=True)
    g = p.grad_plan
    f = np.asarray(g.a_fetch).copy()
    f[0] = 0
    bad = p.replace(grad_plan=g.replace(a_fetch=f))
    findings = verify_plan(bad).findings
    assert findings and all(x.path == "plan.grad_plan" for x in findings)
    assert {x.invariant for x in findings} == {"lane-first-fetch"}


def test_plan_matmul_verify_hook_and_template_cache():
    api.clear_plan_cache()
    a = BSR.random(np.random.default_rng(6), (128, 128), (32, 32), 0.5)
    p1 = api.plan_matmul(a, n_lanes=2, verify="full")
    assert verify_plan(p1, level="full").ok
    # cache hit: the template's verified level is remembered, and the
    # realized plan still passes the per-call scale check
    p2 = api.plan_matmul(a, n_lanes=2, verify="full")
    assert p2.fingerprint == p1.fingerprint
    with pytest.raises(ValueError, match="verify must be"):
        api.plan_matmul(a, verify="paranoid")
    api.clear_plan_cache()


def test_execute_plan_verify_rejects_corrupt(plan):
    pads = np.nonzero(np.asarray(plan.valid) == 0)[0]
    f = np.asarray(plan.a_fetch).copy()
    f[pads[0]] = 1
    bad = plan.replace(a_fetch=f)
    x = jnp.zeros((256, 32), jnp.float32)
    with pytest.raises(PlanVerificationError, match="pads-fetch-nothing"):
        api.execute_plan(bad, x, backend="reference", verify=True)


def test_partition_lanes_accum_check_routes_through_verifier():
    """The planner-path validation and the verifier share one
    implementation (repro.analysis.check_lane_accum) — same message."""
    from repro.core.schedule import partition_lanes
    owner = np.array([0, 1])
    with pytest.raises(ValueError,
                       match=r"accum_prev=1 but no earlier seg_write"):
        partition_lanes(owner, 1, seg_start=np.array([1, 1]),
                        seg_write=np.array([0, 1]),
                        accum_prev=np.array([0, 1]))


# ---------------------------------------------------------------------------
# spgemm validation battery (satellite: named ValueErrors)
# ---------------------------------------------------------------------------


def _spgemm_args(plan):
    return (plan.lhs_blocks, plan.rhs_blocks, plan.a_idx, plan.b_idx,
            plan.c_idx, plan.seg_start, plan.seg_write, plan.accum_prev,
            plan.valid)


@pytest.fixture(scope="module")
def gplan():
    a = BSR.random(np.random.default_rng(7), (128, 128), (32, 32), 0.5)
    b = BSR.random(np.random.default_rng(8), (128, 128), (32, 32), 0.5)
    return api.plan_matmul(a, b, n_lanes=2, cache=False)


def test_spgemm_rejects_contraction_mismatch(gplan):
    from repro.kernels.segment_spgemm import segment_spgemm
    args = list(_spgemm_args(gplan))
    args[1] = jnp.zeros((gplan.rhs_blocks.shape[0], 16, 32), jnp.float32)
    with pytest.raises(ValueError, match=r"contraction blocks disagree"):
        segment_spgemm(*args, n_c_blocks=gplan.n_out_blocks,
                       n_lanes=gplan.n_lanes, interpret=True)


def test_spgemm_rejects_empty_output_with_work(gplan):
    from repro.kernels.segment_spgemm import segment_spgemm
    with pytest.raises(ValueError, match=r"n_c_blocks=0 with a non-empty"):
        segment_spgemm(*_spgemm_args(gplan), n_c_blocks=0,
                       n_lanes=gplan.n_lanes, interpret=True)


def test_spgemm_rejects_length_mismatch(gplan):
    from repro.kernels.segment_spgemm import segment_spgemm
    args = list(_spgemm_args(gplan))
    args[3] = jnp.asarray(np.asarray(gplan.b_idx)[:-1])
    with pytest.raises(ValueError, match=r"b_idx has shape"):
        segment_spgemm(*args, n_c_blocks=gplan.n_out_blocks,
                       n_lanes=gplan.n_lanes, interpret=True)


def test_spgemm_rejects_pipeline_without_flags(gplan):
    from repro.kernels.segment_spgemm import segment_spgemm
    with pytest.raises(ValueError, match=r"pipeline=True needs"):
        segment_spgemm(*_spgemm_args(gplan), n_c_blocks=gplan.n_out_blocks,
                       n_lanes=gplan.n_lanes, interpret=True, pipeline=True)


# ---------------------------------------------------------------------------
# jaxpr linter — toy hazards flagged, shipped kernels clean
# ---------------------------------------------------------------------------


_X = jnp.zeros((8, 128), jnp.float32)


def _toy_pid_call(x):
    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

        @pl.when(pl.program_id(0) == 0)
        def _():
            # deliberately reintroduced hazard: program_id read inside when
            o_ref[...] = x_ref[...] * pl.program_id(0)

    return pl.pallas_call(
        kernel, grid=(2,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
        interpret=True)(x)


def _toy_dma_call(mode, x):
    def kernel(hbm_ref, o_ref, buf, sem):
        cp = pltpu.make_async_copy(hbm_ref, buf, sem)
        cp.start()
        if mode == "clean":
            cp.wait()
            o_ref[...] = buf[...]
        elif mode == "no-wait":
            o_ref[...] = jnp.zeros_like(o_ref)
        elif mode == "read-early":
            o_ref[...] = buf[...]
            cp.wait()

    return pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((8, 128), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
        scratch_shapes=[pltpu.VMEM((8, 128), jnp.float32),
                        pltpu.SemaphoreType.DMA],
        interpret=True)(x)


def test_lint_flags_program_id_in_when():
    findings = lint_callable(_toy_pid_call, _X, label="toy")
    assert [f.rule for f in findings] == ["program-id-in-when"]


def test_lint_flags_dma_start_without_wait():
    findings = lint_callable(functools.partial(_toy_dma_call, "no-wait"), _X)
    assert [f.rule for f in findings] == ["dma-start-without-wait"]


def test_lint_flags_read_before_wait():
    findings = lint_callable(functools.partial(_toy_dma_call, "read-early"),
                             _X)
    assert [f.rule for f in findings] == ["read-before-wait"]


def test_lint_clean_toy_kernel():
    assert lint_callable(functools.partial(_toy_dma_call, "clean"), _X) == []


def test_lint_requires_a_pallas_call():
    with pytest.raises(ValueError, match="no pallas_call"):
        lint_callable(lambda x: x + 1, _X)


def test_shipped_kernels_lint_clean():
    findings = lint_segment_kernels()
    assert findings == [], [str(f) for f in findings]
