"""Mutation-kill battery for the inter-pass ordering analyzer.

The first half drives the four ``ORDER_RULES`` with toy Pallas kernels
built around a two-pass, two-step grid (both axes ``"arbitrary"``, so the
outer axis is a pass axis) and an end-of-body cross-pass tail prefetch —
the toy analog of the SpMM kernels' ``prefetch="cross_pass"`` schedule:

* wrong-slot first wait of pass 1        -> ``cross-pass-war``
* re-issued prologue start over the
  still-outstanding prefetch (with a
  paired extra wait, so whole-chain
  semaphore totals stay balanced)       -> ``sem-carryover``
* pass-1 waits with swapped semaphores  -> ``prefetch-raw``
* small copy issued before a bulky one  -> ``dma-priority``

Each mutation must be caught by *exactly* its targeted rule — the
set-equality assertions double as a no-collateral proof against the
whole merged rule set (syntactic linter + symbolic analyzer + ordering
rules), and the unmutated toys must prove clean, which exercises the
non-trivial paths (a wait legitimately discharging a copy issued in the
previous pass's tail).

The second half certifies the shipped ``prefetch="cross_pass"`` mode:
bit-exact numerical parity against the drained schedule across lanes ×
unroll × quantization × the transposed backward pass, a clean ordering
proof over the traced kernels with a non-vacuous (two-pass) model, the
``prefetch_fetches`` traffic accounting and its verifier agreement
check, and the knob's plumbing through plan aux / planner validation /
cost model / autotuner.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis import (
    ORDER_RULES,
    analyze_callable,
    build_order,
    pass_local_chains,
    trace_kernel_irs,
    verify_plan,
)
from repro.api import apply_plan, plan_matmul
from repro.core.formats import BSR
from repro.core.schedule import (PREFETCH_MODES, fetch_flags,
                                 lane_traffic_spgemm, lane_traffic_spmm)
from repro.kernels.compat import CompilerParams
from repro.tune import Candidate, autotune_matmul
from repro.tune.cost import DEFAULT_INTERPRET, DEFAULT_TPU, CostModel


def _rules(findings):
    return set(f.rule for f in findings)


_N_PASS, _N_STEP = 2, 2


# ---------------------------------------------------------------------------
# toy kernels: a two-pass ring with an end-of-body cross-pass tail
# ---------------------------------------------------------------------------


def _xpass_toy(x, *, mutate=None):
    """Two passes x two steps over a depth-2 DMA ring; the last step of
    pass ``j`` issues pass ``j+1``'s first copy (slot 0, sem 0) after its
    own read — exactly the kernels' cross-pass prefetch contract.

    ``mutate="clobber"``: pass 1's first wait discharges ring slot 1
    instead of slot 0 (sem slot kept correct), leaving the prefetched
    copy in flight over the slot-0 read.
    ``mutate="carryover"``: pass 1 re-issues the prologue start while the
    prefetch is still outstanding on the same (sem, slot); a paired extra
    wait keeps whole-chain start/wait totals balanced, so only the
    boundary-granular rule can see it.
    """

    def kernel(hbm_ref, out_ref, buf_ref, sem_ref):
        j = pl.program_id(0)            # pass axis (the N-tile analog)
        s = pl.program_id(1)            # step axis
        slot = s % 2
        nxt = (s + 1) % 2

        def start(step, sl, sem_sl):
            pltpu.make_async_copy(hbm_ref.at[pl.ds(step * 8, 8)],
                                  buf_ref.at[sl], sem_ref.at[sem_sl]).start()

        def wait(sl, sem_sl):
            pltpu.make_async_copy(hbm_ref.at[pl.ds((j * _N_STEP + s) * 8, 8)],
                                  buf_ref.at[sl], sem_ref.at[sem_sl]).wait()

        @pl.when((j == 0) & (s == 0))
        def _prologue():
            start(0, 0, 0)

        @pl.when(s + 1 < _N_STEP)
        def _ahead():
            start(j * _N_STEP + s + 1, nxt, nxt)

        if mutate == "carryover":
            @pl.when((j == 1) & (s == 0))
            def _double_start():
                start(j * _N_STEP + s, 0, 0)

        if mutate == "clobber":
            @pl.when((j == 1) & (s == 0))
            def _wrong_slot():
                wait(1, 0)

            @pl.when((j == 0) | (s == 1))
            def _right_slot():
                wait(slot, slot)
        else:
            wait(slot, slot)

        if mutate == "carryover":
            @pl.when((j == 1) & (s == 0))
            def _double_wait():
                wait(0, 0)

        out_ref[...] = buf_ref[slot]

        # the cross-pass tail: issued after this pass's last read, waited
        # by the next pass's first step
        @pl.when((s + 1 == _N_STEP) & (j + 1 < _N_PASS))
        def _tail():
            start((j + 1) * _N_STEP, 0, 0)

    return pl.pallas_call(
        kernel, grid=(_N_PASS, _N_STEP),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((8,), lambda j, s: (j * _N_STEP + s,)),
        scratch_shapes=[pltpu.VMEM((2, 8), jnp.float32),
                        pltpu.SemaphoreType.DMA((2,))],
        out_shape=jax.ShapeDtypeStruct((_N_PASS * _N_STEP * 8,), jnp.float32),
        interpret=True,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
    )(x)


def _twin_ring_toy(xa, xb, *, swap_pass1_sems=False):
    """Two equal-size depth-2 rings (equal so ``dma-priority`` stays
    vacuous), each with its own semaphore pair and a cross-pass tail.
    ``swap_pass1_sems`` makes pass 1's first waits discharge each buffer
    with the *other* buffer's semaphore — every (sem, slot) FIFO stays
    balanced at the boundary, but neither first consumption waits on its
    filler."""

    def kernel(ha_ref, hb_ref, out_ref, bufa_ref, bufb_ref,
               sema_ref, semb_ref):
        j = pl.program_id(0)
        s = pl.program_id(1)
        slot = s % 2
        nxt = (s + 1) % 2

        def start(hbm, buf, sem, step, sl):
            pltpu.make_async_copy(hbm.at[pl.ds(step * 8, 8)],
                                  buf.at[sl], sem.at[sl]).start()

        def wait(hbm, buf, sem, sl, sem_sl):
            pltpu.make_async_copy(hbm.at[pl.ds((j * _N_STEP + s) * 8, 8)],
                                  buf.at[sl], sem.at[sem_sl]).wait()

        @pl.when((j == 0) & (s == 0))
        def _prologue():
            start(ha_ref, bufa_ref, sema_ref, 0, 0)
            start(hb_ref, bufb_ref, semb_ref, 0, 0)

        @pl.when(s + 1 < _N_STEP)
        def _ahead():
            start(ha_ref, bufa_ref, sema_ref, j * _N_STEP + s + 1, nxt)
            start(hb_ref, bufb_ref, semb_ref, j * _N_STEP + s + 1, nxt)

        if swap_pass1_sems:
            @pl.when((j == 1) & (s == 0))
            def _swapped():
                wait(ha_ref, bufa_ref, semb_ref, 0, 0)
                wait(hb_ref, bufb_ref, sema_ref, 0, 0)

            @pl.when((j == 0) | (s == 1))
            def _straight():
                wait(ha_ref, bufa_ref, sema_ref, slot, slot)
                wait(hb_ref, bufb_ref, semb_ref, slot, slot)
        else:
            wait(ha_ref, bufa_ref, sema_ref, slot, slot)
            wait(hb_ref, bufb_ref, semb_ref, slot, slot)

        out_ref[...] = bufa_ref[slot] + bufb_ref[slot]

        @pl.when((s + 1 == _N_STEP) & (j + 1 < _N_PASS))
        def _tail():
            start(ha_ref, bufa_ref, sema_ref, (j + 1) * _N_STEP, 0)
            start(hb_ref, bufb_ref, semb_ref, (j + 1) * _N_STEP, 0)

    return pl.pallas_call(
        kernel, grid=(_N_PASS, _N_STEP),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((8,), lambda j, s: (j * _N_STEP + s,)),
        scratch_shapes=[pltpu.VMEM((2, 8), jnp.float32),
                        pltpu.VMEM((2, 8), jnp.float32),
                        pltpu.SemaphoreType.DMA((2,)),
                        pltpu.SemaphoreType.DMA((2,))],
        out_shape=jax.ShapeDtypeStruct((_N_PASS * _N_STEP * 8,), jnp.float32),
        interpret=True,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
    )(xa, xb)


def _priority_toy(x_small, x_big, *, small_first):
    """A 4096-byte and an 8192-byte copy at every grid step.  The clean
    variant issues the bulky one first (the kernels' convention); the
    mutation swaps the issue order."""
    n = 2

    def kernel(hs_ref, hb_ref, out_ref, small_ref, big_ref,
               s_sem, b_sem):
        s = pl.program_id(0)

        def start_small():
            pltpu.make_async_copy(hs_ref.at[pl.ds(s * 8, 8)],
                                  small_ref.at[0], s_sem.at[0]).start()

        def start_big():
            pltpu.make_async_copy(hb_ref.at[pl.ds(s * 8, 8)],
                                  big_ref.at[0], b_sem.at[0]).start()

        if small_first:
            start_small()
            start_big()
        else:
            start_big()
            start_small()

        pltpu.make_async_copy(hb_ref.at[pl.ds(s * 8, 8)],
                              big_ref.at[0], b_sem.at[0]).wait()
        pltpu.make_async_copy(hs_ref.at[pl.ds(s * 8, 8)],
                              small_ref.at[0], s_sem.at[0]).wait()
        out_ref[...] = small_ref[0] + big_ref[0][:, :128]

    return pl.pallas_call(
        kernel, grid=(n,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((8, 128), lambda s: (s, 0)),
        scratch_shapes=[pltpu.VMEM((1, 8, 128), jnp.float32),
                        pltpu.VMEM((1, 8, 256), jnp.float32),
                        pltpu.SemaphoreType.DMA((1,)),
                        pltpu.SemaphoreType.DMA((1,))],
        out_shape=jax.ShapeDtypeStruct((n * 8, 128), jnp.float32),
        interpret=True,
    )(x_small, x_big)


# ---------------------------------------------------------------------------
# mutation-kill assertions: exactly one rule each
# ---------------------------------------------------------------------------


def test_clean_cross_pass_prefetch_proves_clean_and_runs():
    x = jnp.arange(_N_PASS * _N_STEP * 8, dtype=jnp.float32)
    good = lambda xx: _xpass_toy(xx, mutate=None)
    assert analyze_callable(good, x, label="toy-xpass-good") == []
    # and the schedule it certifies is actually correct
    np.testing.assert_array_equal(np.asarray(good(x)), np.asarray(x))


def test_toy_happens_before_model_is_two_passes():
    x = jnp.zeros((_N_PASS * _N_STEP * 8,), jnp.float32)
    irs = trace_kernel_irs(lambda xx: _xpass_toy(xx), x, label="toy-hb")
    assert len(irs) == 1
    hb = build_order(irs[0])
    assert hb.n_passes == _N_PASS
    # no parallel axis: one chain of 4 points, split at the pass boundary
    assert len(hb.chains) == 1 and len(hb.chains[0]) == _N_PASS * _N_STEP
    locals_ = pass_local_chains(irs[0])
    assert [len(c) for c in locals_] == [_N_STEP, _N_STEP]
    # program edges: ordered within the chain, never across equal points
    assert hb.ordered(0, 3) and not hb.ordered(3, 0) and not hb.ordered(1, 1)


def test_wrong_slot_wait_is_killed_by_cross_pass_war_only():
    x = jnp.zeros((_N_PASS * _N_STEP * 8,), jnp.float32)
    findings = analyze_callable(lambda xx: _xpass_toy(xx, mutate="clobber"),
                                x, label="toy-xpass-clobber")
    assert _rules(findings) == {"cross-pass-war"}, findings
    assert any("still in flight" in f.message for f in findings)


def test_boundary_double_start_is_killed_by_sem_carryover_only():
    x = jnp.zeros((_N_PASS * _N_STEP * 8,), jnp.float32)
    findings = analyze_callable(lambda xx: _xpass_toy(xx, mutate="carryover"),
                                x, label="toy-xpass-carryover")
    assert _rules(findings) == {"sem-carryover"}, findings
    assert any("pass boundary" in f.message for f in findings)


def test_clean_twin_ring_proves_clean():
    xa = jnp.arange(32, dtype=jnp.float32)
    xb = jnp.arange(32, dtype=jnp.float32) * 2
    good = lambda a, b: _twin_ring_toy(a, b, swap_pass1_sems=False)
    assert analyze_callable(good, xa, xb, label="toy-twin-good") == []
    np.testing.assert_array_equal(np.asarray(good(xa, xb)),
                                  np.asarray(xa + xb))


def test_swapped_sems_are_killed_by_prefetch_raw_only():
    xa = jnp.zeros((32,), jnp.float32)
    xb = jnp.zeros((32,), jnp.float32)
    findings = analyze_callable(
        lambda a, b: _twin_ring_toy(a, b, swap_pass1_sems=True),
        xa, xb, label="toy-twin-swapped")
    assert _rules(findings) == {"prefetch-raw"}, findings
    # both buffers' first consumptions wait on the wrong filler
    assert len(findings) == 2
    assert any("does not wait on its filler" in f.message for f in findings)


def test_big_copy_first_proves_clean():
    xs = jnp.ones((16, 128), jnp.float32)
    xb = jnp.ones((16, 256), jnp.float32)
    good = lambda a, b: _priority_toy(a, b, small_first=False)
    assert analyze_callable(good, xs, xb, label="toy-prio-good") == []
    np.testing.assert_array_equal(np.asarray(good(xs, xb)),
                                  np.full((16, 128), 2.0, np.float32))


def test_small_copy_first_is_killed_by_dma_priority_only():
    xs = jnp.zeros((16, 128), jnp.float32)
    xb = jnp.zeros((16, 256), jnp.float32)
    findings = analyze_callable(
        lambda a, b: _priority_toy(a, b, small_first=True),
        xs, xb, label="toy-prio-bad")
    assert _rules(findings) == {"dma-priority"}, findings
    assert any("8192" in f.message and "4096" in f.message for f in findings)


def test_order_rule_catalog():
    assert set(ORDER_RULES) == {"cross-pass-war", "sem-carryover",
                                "prefetch-raw", "dma-priority"}


# ---------------------------------------------------------------------------
# shipped kernels: prefetch-on == prefetch-off, bit for bit
# ---------------------------------------------------------------------------


def _matrix(seed=7):
    return BSR.random(np.random.default_rng(seed), (96, 128), (32, 32), 0.4)


def _rhs(seed=1, n=64):
    return jnp.asarray(np.random.default_rng(seed)
                       .standard_normal((128, n)).astype(np.float32))


@pytest.mark.parametrize("n_lanes,unroll", [(1, 1), (2, 1), (2, 2)])
@pytest.mark.parametrize("quantize", [None, "int8", "fp8"])
def test_prefetch_numerical_parity(n_lanes, unroll, quantize):
    a = _matrix()
    x = _rhs()
    kw = dict(policy="segment", n_lanes=n_lanes, unroll=unroll, fold_len=3,
              quantize=quantize, cache=False)
    base = plan_matmul(a, **kw)
    pf = plan_matmul(a, prefetch="cross_pass", **kw)
    assert pf.prefetch == "cross_pass" and base.prefetch is None
    # bn=32 over 64 columns -> two N-tile passes, so the cross-pass tail
    # really executes; the mode re-times copies and must change nothing
    want = np.asarray(base(x, bn=32, backend="interpret"))
    got = np.asarray(pf(x, bn=32, backend="interpret"))
    np.testing.assert_array_equal(got, want)


def test_prefetch_parity_through_transposed_backward_pass():
    a = BSR.random(np.random.default_rng(8), (96, 128), (32, 32), 0.4)
    x = _rhs(2, 64)

    def grad_of(plan):
        def loss(xx):
            return jnp.sum(apply_plan(plan, xx, bn=32,
                                      backend="interpret") ** 2)
        return np.asarray(jax.grad(loss)(x))

    base = plan_matmul(a, with_grad=True, n_lanes=2, unroll=2, cache=False)
    pf = plan_matmul(a, with_grad=True, n_lanes=2, unroll=2, cache=False,
                     prefetch="cross_pass")
    # the knob propagates into the transposed (transpose_lhs) grad plan
    assert pf.grad_plan.prefetch == "cross_pass"
    assert pf.grad_plan.transpose_lhs
    np.testing.assert_array_equal(grad_of(pf), grad_of(base))


def test_shipped_prefetch_kernel_is_certified_non_vacuously():
    a = _matrix()
    x = _rhs()
    pf = plan_matmul(a, n_lanes=2, unroll=2, cache=False,
                     prefetch="cross_pass")
    fn = lambda xx: pf(xx, bn=32, backend="interpret")
    assert analyze_callable(fn, x, label="spmm-prefetch-cert") == []
    # the proof is about a real two-pass model: prefetch demotes the
    # N-tile axis to "arbitrary", so the ordering rules are not vacuous
    irs = trace_kernel_irs(fn, x, label="spmm-prefetch-cert")
    assert any(build_order(ir).n_passes == 2 for ir in irs)
    # the drained schedule keeps the N-tile axis parallel: single pass
    base = plan_matmul(a, n_lanes=2, unroll=2, cache=False)
    base_irs = trace_kernel_irs(lambda xx: base(xx, bn=32,
                                                backend="interpret"), x)
    assert all(build_order(ir).n_passes == 1 for ir in base_irs)


# ---------------------------------------------------------------------------
# traffic accounting + verifier agreement
# ---------------------------------------------------------------------------


def test_prefetch_traffic_recorded_and_verifier_agrees():
    a = _matrix()
    base = plan_matmul(a, n_lanes=2, unroll=2, cache=False)
    pf = plan_matmul(a, n_lanes=2, unroll=2, cache=False,
                     prefetch="cross_pass")
    t_base, t_pf = dict(base.traffic_items), dict(pf.traffic_items)
    # re-timing copies moves no extra bytes and drops none
    for key in ("a_bytes", "b_bytes", "c_bytes", "total",
                "a_fetches", "b_fetches"):
        assert t_base[key] == t_pf[key], key
    assert t_base["prefetch_fetches"] == 0
    assert t_pf["prefetch_fetches"] > 0
    verify_plan(pf, level="full").raise_if_findings()
    # a plan lying about its overlapped-fetch count is rejected
    bad_items = tuple((k, v + 1 if k == "prefetch_fetches" else v)
                      for k, v in pf.traffic_items)
    res = verify_plan(pf.replace(traffic_items=bad_items), level="full")
    assert any(f.invariant == "traffic-agreement" and "prefetch" in f.message
               for f in res.findings)


def test_fetch_flags_identical_under_prefetch():
    stream = np.array([5, 5, 7, 7, 3, 3, 3, 9])
    valid = np.array([1, 1, 1, 0, 1, 1, 1, 1])
    f0, s0 = fetch_flags(stream, valid, 2)
    f1, s1 = fetch_flags(stream, valid, 2, prefetch="cross_pass")
    np.testing.assert_array_equal(f0, f1)
    np.testing.assert_array_equal(s0, s1)
    with pytest.raises(ValueError, match="prefetch"):
        fetch_flags(stream, valid, 2, prefetch="bogus")


def test_lane_traffic_prefetch_fetch_counts():
    m = np.zeros(4, np.int64)
    k = np.array([0, 0, 1, 1])
    seg = np.array([1, 0, 0, 0])
    valid = np.ones(4, bool)
    base = lane_traffic_spmm(m, k, seg, valid, 1, 32, 32, 64, unroll=1)
    assert base["prefetch_fetches"] == 0
    # one lane, unroll=1 head window: one A fetch + one B fetch
    pf1 = lane_traffic_spmm(m, k, seg, valid, 1, 32, 32, 64, unroll=1,
                            prefetch="cross_pass")
    assert pf1["prefetch_fetches"] == 2
    # unroll=2 widens the window to [0, 0]: two A fetches, one B fetch
    pf2 = lane_traffic_spmm(m, k, seg, valid, 1, 32, 32, 64, unroll=2,
                            prefetch="cross_pass")
    assert pf2["prefetch_fetches"] == 3
    # two lanes: each lane's first item fetches A and B
    pf3 = lane_traffic_spmm(m, k, np.array([1, 0, 1, 0]), valid, 2,
                            32, 32, 64, unroll=1, prefetch="cross_pass")
    assert pf3["prefetch_fetches"] == 4
    # byte totals never move
    for key in ("a_bytes", "b_bytes", "c_bytes", "total"):
        assert base[key] == pf1[key] == pf2[key]
    with pytest.raises(ValueError, match="prefetch"):
        lane_traffic_spmm(m, k, seg, valid, 1, 32, 32, 64, prefetch="eager")
    # spgemm has no N-tile pass axis: the knob is a validated no-op
    two = lane_traffic_spgemm(np.array([0, 1]), np.array([0, 1]),
                              np.array([0, 0]), np.array([1, 0]),
                              np.ones(2, bool), 1, 32, 32, 32,
                              prefetch="cross_pass")
    assert two["prefetch_fetches"] == 0
    with pytest.raises(ValueError, match="prefetch"):
        lane_traffic_spgemm(np.array([0]), np.array([0]), np.array([0]),
                            np.array([1]), np.ones(1, bool), 1, 32, 32, 32,
                            prefetch="now")


# ---------------------------------------------------------------------------
# plumbing: plan aux, planner validation, cost model, autotuner
# ---------------------------------------------------------------------------


def test_prefetch_survives_pytree_roundtrip_and_fingerprints():
    a = _matrix()
    pf = plan_matmul(a, cache=False, prefetch="cross_pass")
    leaves, treedef = jax.tree_util.tree_flatten(pf)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.prefetch == "cross_pass"
    # a different schedule mode is a different cached plan
    assert pf.fingerprint != plan_matmul(a, cache=False).fingerprint


def test_plan_matmul_validates_prefetch():
    a = _matrix()
    assert None in PREFETCH_MODES and "cross_pass" in PREFETCH_MODES
    with pytest.raises(ValueError, match="prefetch"):
        plan_matmul(a, cache=False, prefetch="bogus")
    with pytest.raises(ValueError, match="pipeline"):
        plan_matmul(a, cache=False, pipeline=False, prefetch="cross_pass")


def test_cost_model_prefetch_credit():
    m = CostModel(bytes_per_us=1.0, step_us=2.0, prefetch_step_credit=1.0)
    kw = dict(traffic_bytes=0.0, n_lanes=1, lane_len=4, unroll=1)
    # one hidden boundary drain per N-tile transition
    off = m.cost_us(n_tiles_n=3, **kw)
    on = m.cost_us(n_tiles_n=3, prefetch=True, **kw)
    assert off - on == pytest.approx(2 * 2.0)
    # a single tile has no boundary to hide
    assert m.cost_us(n_tiles_n=1, prefetch=True, **kw) \
        == m.cost_us(n_tiles_n=1, **kw)
    # the legacy path never earns the credit
    assert m.cost_us(n_tiles_n=3, pipelined=False, prefetch=True, **kw) \
        == m.cost_us(n_tiles_n=3, pipelined=False, **kw)
    # shipped defaults: hardware overlaps the drain, the interpreter
    # replays copies inline and must not prefer prefetch on phantom credit
    assert DEFAULT_TPU.prefetch_step_credit == 1.0
    assert DEFAULT_INTERPRET.prefetch_step_credit == 0.0


def test_autotune_sweeps_and_pins_prefetch():
    a = _matrix()
    res = autotune_matmul(a, n_cols_hint=256, cache=False)
    swept = {s.candidate.prefetch for s in res.candidates}
    assert swept == {None, "cross_pass"}
    # cross-pass prefetch only exists on the explicit DMA pipeline
    assert all(s.candidate.prefetch is None
               for s in res.candidates if not s.candidate.pipeline)
    # the default knob point still exists (Candidate defaults prefetch=None)
    assert any(s.candidate == Candidate("segment", None, 1, 1, 512, True)
               for s in res.candidates)
    # interpret objective: zero credit + tie-break keep the drained mode
    res_i = autotune_matmul(a, n_cols_hint=256, objective="interpret",
                            cache=False)
    assert res_i.best.candidate.prefetch is None
    # a pinned knob flows through plan_kwargs into a verified plan
    pinned = autotune_matmul(a, n_cols_hint=256, cache=False,
                             pins={"pipeline": True,
                                   "prefetch": "cross_pass"})
    assert pinned.best.candidate.prefetch == "cross_pass"
    kw = pinned.plan_kwargs()
    assert kw["prefetch"] == "cross_pass"
    plan = plan_matmul(a, 256, cache=False, **kw)
    assert plan.prefetch == "cross_pass"
    verify_plan(plan, level="full").raise_if_findings()
