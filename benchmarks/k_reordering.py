"""§VI-C.1: fixed-k-order ablation (paper: 0.670±0.065 of baseline)."""
import dataclasses

import numpy as np

from repro.sim.segfold_sim import simulate_segfold

from .common import Csv, load_suite, timed


def run(csv: Csv, scale_cap: int = 1536) -> dict:
    ratios = []
    for name, a, b, cfg in load_suite(scale_cap, with_extra=True)[:12]:
        dyn, us = timed(simulate_segfold, a, b, cfg)
        fixed = simulate_segfold(a, b, dataclasses.replace(cfg, dynamic_k=False))
        ratios.append(dyn.cycles / fixed.cycles)
        csv.add(f"k_reorder/{name}", us, f"fixed_k_norm_perf={ratios[-1]:.3f}")
    m, s = float(np.mean(ratios)), float(np.std(ratios))
    csv.add("k_reorder/MEAN", 0.0, f"{m:.3f}±{s:.3f}(paper:0.670±0.065)")
    return {"mean": m, "std": s}
