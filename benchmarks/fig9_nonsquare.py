"""Fig. 9: non-square matrices — (a) SegFold vs Spada; (b) multiplication
direction: wide matrices recover several-fold by swapping operands."""
import numpy as np

from repro.sim import matrices
from repro.sim.baselines import spada
from repro.sim.segfold_sim import SegFoldConfig, simulate_segfold

from .common import Csv, geomean, load_suite, timed

NONSQUARE = ("gemat1", "lp_woodw", "pcb3000", "Franz6", "Franz8", "psse1")


def run(csv: Csv, scale_cap: int = 2048) -> dict:
    sus, ratios = [], []
    for name, a, b, cfg in load_suite(scale_cap):
        if name not in NONSQUARE:
            continue
        seg, us = timed(simulate_segfold, a, b, cfg)
        sp = spada(a, b, cfg)
        su = sp.cycles / seg.cycles
        sus.append(su)
        csv.add(f"fig9a/{name}", us, f"vs_spada={su:.2f}")
        # direction experiment: A·Aᵀ (dir1) vs Aᵀ·A (dir2 — swapped operands)
        if a.shape[1] > a.shape[0]:          # wide matrices
            d1 = seg.cycles
            d2 = simulate_segfold(b, a, cfg).cycles
            ratios.append(d1 / d2)
            csv.add(f"fig9b/{name}", 0.0,
                    f"dir1_over_dir2={d1 / d2:.2f}(paper:2.4-3.0x_for_wide)")
    csv.add("fig9a/GEOMEAN", 0.0, f"vs_spada={geomean(sus):.2f}(paper:1.42_tall)")
    return {"geomean": geomean(sus), "direction_ratios": ratios}
