"""Serving throughput benchmark: prefill + steady-state decode tok/s
through the continuous-batching engine.

Emits ``BENCH_serve.json`` (CI smoke target — the perf trajectory of the
serving substrate is tracked from this file):

    PYTHONPATH=src python benchmarks/serve_bench.py --smoke
    PYTHONPATH=src python benchmarks/serve_bench.py --arch granite-3-8b \\
        --slots 8 --requests 32 --max-new 32

Prefill tok/s counts prompt tokens pushed through the chunked bucketed
prefill; decode tok/s counts generated tokens over the batched decode
steps (both exclude compile time: a warmup request covers every compiled
shape first, and the report asserts the measured phase didn't retrace).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np


def run(arch: str, *, slots: int, max_len: int, requests: int, max_new: int,
        prompt_lo: int, prompt_hi: int, backend=None, seed: int = 0) -> dict:
    from repro.configs import get_config, reduced_config
    from repro.models import build_model
    from repro.runtime import Engine, Request

    cfg = reduced_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, slots=slots, max_len=max_len, backend=backend)

    rng = np.random.default_rng(seed)

    def mk(n):
        return [Request(prompt=rng.integers(0, cfg.vocab,
                                            int(rng.integers(prompt_lo,
                                                             prompt_hi)),
                                            dtype=np.int32),
                        max_new_tokens=max_new)
                for _ in range(n)]

    # warmup: compile every steady-state shape — each prefill bucket in
    # both its fresh (first chunk) and continuation role, plus the decode
    # shape.  A 2·bucket prompt covers both roles of one bucket.
    cap = max(1, max_len - 2)
    eng.generate([Request(prompt=rng.integers(0, cfg.vocab,
                                              min(2 * b, cap),
                                              dtype=np.int32),
                          max_new_tokens=2)
                  for b in eng.prefill_buckets])
    shapes_warm = dict(eng.compiled_shapes)

    reqs = mk(requests)
    prompt_tokens = int(sum(r.prompt.size for r in reqs))

    # phase 1 — prefill: admit up to `slots` requests, timed
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    admitted = eng.admit_pending()
    jax.block_until_ready(jax.tree.leaves(eng.cache)[0])
    prefill_s = time.perf_counter() - t0
    prefill_done = int(sum(r.prompt.size for r in reqs[:admitted]))

    # phase 2 — decode to drain (includes the remaining admissions, as
    # continuous batching interleaves them; decode tok/s = generated/total)
    t1 = time.perf_counter()
    eng.run()
    decode_s = time.perf_counter() - t1
    gen_tokens = int(sum(r.out_tokens.size for r in reqs))

    return {
        "arch": arch,
        "slots": slots,
        "max_len": max_len,
        "requests": requests,
        "max_new_tokens": max_new,
        "prompt_tokens": prompt_tokens,
        "generated_tokens": gen_tokens,
        "prefill_tok_s": prefill_done / max(prefill_s, 1e-9),
        "decode_tok_s": gen_tokens / max(decode_s, 1e-9),
        "prefill_buckets": list(eng.prefill_buckets),
        "compiled_shapes": eng.compiled_shapes,
        "retraced_after_warmup": eng.compiled_shapes != shapes_warm,
        "backend": eng.backend,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-lo", type=int, default=4)
    ap.add_argument("--prompt-hi", type=int, default=96)
    ap.add_argument("--backend", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration (fast, still end-to-end)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()

    if args.smoke:
        args.slots, args.max_len = 2, 64
        args.requests, args.max_new = 4, 4
        args.prompt_lo, args.prompt_hi = 4, 32

    result = run(args.arch, slots=args.slots, max_len=args.max_len,
                 requests=args.requests, max_new=args.max_new,
                 prompt_lo=args.prompt_lo, prompt_hi=args.prompt_hi,
                 backend=args.backend)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result, indent=2))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
