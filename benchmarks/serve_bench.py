"""Serving throughput benchmark: prefill + steady-state decode tok/s
through the continuous-batching engine.

Emits ``BENCH_serve.json`` (CI smoke target — the perf trajectory of the
serving substrate is tracked from this file):

    PYTHONPATH=src python benchmarks/serve_bench.py --smoke
    PYTHONPATH=src python benchmarks/serve_bench.py --arch granite-3-8b \\
        --slots 8 --requests 32 --max-new 32

Prefill tok/s counts prompt tokens pushed through the chunked bucketed
prefill; decode tok/s counts generated tokens over the batched decode
steps (both exclude compile time: a warmup request covers every compiled
shape first, and the report asserts the measured phase didn't retrace).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np


def run(arch: str, *, slots: int, max_len: int, requests: int, max_new: int,
        prompt_lo: int, prompt_hi: int, backend=None, seed: int = 0) -> dict:
    from repro.configs import get_config, reduced_config
    from repro.models import build_model
    from repro.runtime import Engine, Request

    cfg = reduced_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, slots=slots, max_len=max_len, backend=backend)

    rng = np.random.default_rng(seed)

    def mk(n):
        return [Request(prompt=rng.integers(0, cfg.vocab,
                                            int(rng.integers(prompt_lo,
                                                             prompt_hi)),
                                            dtype=np.int32),
                        max_new_tokens=max_new)
                for _ in range(n)]

    # warmup: compile every steady-state shape — each prefill bucket in
    # both its fresh (first chunk) and continuation role, plus the decode
    # shape.  A 2·bucket prompt covers both roles of one bucket.
    cap = max(1, max_len - 2)
    eng.generate([Request(prompt=rng.integers(0, cfg.vocab,
                                              min(2 * b, cap),
                                              dtype=np.int32),
                          max_new_tokens=2)
                  for b in eng.prefill_buckets])
    shapes_warm = dict(eng.compiled_shapes)

    reqs = mk(requests)
    prompt_tokens = int(sum(r.prompt.size for r in reqs))

    # phase 1 — prefill: admit up to `slots` requests, timed
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    admitted = eng.admit_pending()
    jax.block_until_ready(jax.tree.leaves(eng.cache)[0])
    prefill_s = time.perf_counter() - t0
    prefill_done = int(sum(r.prompt.size for r in reqs[:admitted]))

    # phase 2 — decode to drain (includes the remaining admissions, as
    # continuous batching interleaves them; decode tok/s = generated/total)
    t1 = time.perf_counter()
    eng.run()
    decode_s = time.perf_counter() - t1
    gen_tokens = int(sum(r.out_tokens.size for r in reqs))

    return {
        "arch": arch,
        "slots": slots,
        "max_len": max_len,
        "requests": requests,
        "max_new_tokens": max_new,
        "prompt_tokens": prompt_tokens,
        "generated_tokens": gen_tokens,
        "prefill_tok_s": prefill_done / max(prefill_s, 1e-9),
        "decode_tok_s": gen_tokens / max(decode_s, 1e-9),
        "prefill_buckets": list(eng.prefill_buckets),
        "compiled_shapes": eng.compiled_shapes,
        "retraced_after_warmup": eng.compiled_shapes != shapes_warm,
        "backend": eng.backend,
    }


def run_quant(arch: str, *, slots: int, max_len: int, requests: int,
              max_new: int, prompt_lo: int, prompt_hi: int, backend=None,
              repeats: int = 3, seed: int = 0) -> dict:
    """fp32 vs int8 vs fp8 serving on a block-sparse-FFN variant of
    ``arch``: prefill/decode tok/s per mode (best of ``repeats``
    interleaved passes — interleaving cancels machine-load drift between
    the engines being compared) plus the greedy-token drift of each
    quantized engine against the fp32 engine on the same mixed-length
    batch.  Every quantized plan the bench builds is verified at
    ``level="full"`` and the finding count is reported (CI gates it at 0).
    """
    from repro.analysis import verify_plan
    from repro.configs import get_config, reduced_config
    from repro.models import build_model
    from repro.runtime import Engine, Request

    cfg = dataclasses.replace(reduced_config(get_config(arch)),
                              dtype="float32", ffn_block_sparse=True,
                              ffn_block=32, ffn_density=0.5)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(seed)
    lens = [int(rng.integers(prompt_lo, prompt_hi)) for _ in range(requests)]
    prompts = [rng.integers(0, cfg.vocab, l, dtype=np.int32) for l in lens]

    modes = (None, "int8", "fp8")
    engines = {}
    for mode in modes:
        eng = Engine(model, params, slots=slots, max_len=max_len,
                     backend=backend, quantize=mode)
        # warmup covers every steady-state shape (see run())
        cap = max(1, max_len - 2)
        eng.generate([Request(prompt=rng.integers(0, cfg.vocab,
                                                  min(2 * b, cap),
                                                  dtype=np.int32),
                              max_new_tokens=2)
                      for b in eng.prefill_buckets])
        engines[mode] = (eng, dict(eng.compiled_shapes))

    n_findings = {}
    for mode in modes[1:]:
        eng, _ = engines[mode]
        sm = eng.model.sparse_mlp
        n_findings[mode] = sum(
            len(verify_plan(lin.plan, level="full").findings)
            for lin in (sm.up, sm.gate, sm.down))

    # modeled FFN weight traffic per decode step (sum of the three
    # SparseLinear plans' A-side bytes) — the deterministic form of the
    # quantization win: interpret-mode wall clock moves the same flops
    # either way, but the operand bytes a real device would fetch drop
    # ~4x for 1-byte payloads, and the lane-aware traffic model prices
    # that exactly (scales included).
    weight_bytes = {}
    for mode in modes:
        sm = engines[mode][0].model.sparse_mlp
        weight_bytes[mode] = float(sum(lin.plan.traffic["a_bytes"]
                                       for lin in (sm.up, sm.gate, sm.down)))

    stats = {mode: {"prefill_tok_s": 0.0, "decode_tok_s": 0.0}
             for mode in modes}
    outputs = {}
    for _ in range(max(1, repeats)):
        for mode in modes:                  # interleaved: one pass per mode
            eng, _ = engines[mode]
            reqs = [Request(prompt=p.copy(), max_new_tokens=max_new)
                    for p in prompts]
            for r in reqs:
                eng.submit(r)
            t0 = time.perf_counter()
            admitted = eng.admit_pending()
            jax.block_until_ready(jax.tree.leaves(eng.cache)[0])
            prefill_s = time.perf_counter() - t0
            t1 = time.perf_counter()
            eng.run()
            decode_s = time.perf_counter() - t1
            done = int(sum(r.prompt.size for r in reqs[:admitted]))
            gen = int(sum(r.out_tokens.size for r in reqs))
            s = stats[mode]
            s["prefill_tok_s"] = max(s["prefill_tok_s"],
                                     done / max(prefill_s, 1e-9))
            s["decode_tok_s"] = max(s["decode_tok_s"],
                                    gen / max(decode_s, 1e-9))
            # greedy decode is deterministic per engine — any pass works
            outputs[mode] = [r.out_tokens.tolist() for r in reqs]

    base = outputs[None]
    total = sum(len(t) for t in base)
    out = {"arch": arch, "slots": slots, "max_len": max_len,
           "requests": requests, "max_new_tokens": max_new,
           "repeats": repeats, "modes": {}}
    for mode in modes:
        eng, warm = engines[mode]
        row = dict(stats[mode])
        row["compiled_shapes"] = eng.compiled_shapes
        row["retraced_after_warmup"] = eng.compiled_shapes != warm
        row["ffn_weight_traffic_bytes"] = weight_bytes[mode]
        if mode is not None:
            row["ffn_weight_traffic_cut_vs_fp32"] = (
                weight_bytes[None] / max(weight_bytes[mode], 1e-9))
            row["verify_findings"] = n_findings[mode]
            row["greedy_drift_fraction"] = sum(
                a != b for x, y in zip(base, outputs[mode])
                for a, b in zip(x, y)) / max(total, 1)
        out["modes"][mode or "fp32"] = row
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-lo", type=int, default=4)
    ap.add_argument("--prompt-hi", type=int, default=96)
    ap.add_argument("--backend", default=None)
    ap.add_argument("--quant-repeats", type=int, default=3,
                    help="interleaved timing passes per mode in the "
                         "quantized-serving comparison")
    ap.add_argument("--no-quant", action="store_true",
                    help="skip the fp32/int8/fp8 quantized-serving section")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration (fast, still end-to-end)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()

    if args.smoke:
        args.slots, args.max_len = 2, 64
        args.requests, args.max_new = 4, 4
        args.prompt_lo, args.prompt_hi = 4, 32

    result = run(args.arch, slots=args.slots, max_len=args.max_len,
                 requests=args.requests, max_new=args.max_new,
                 prompt_lo=args.prompt_lo, prompt_hi=args.prompt_hi,
                 backend=args.backend)
    if not args.no_quant:
        result["quant"] = run_quant(
            args.arch, slots=args.slots, max_len=args.max_len,
            requests=args.requests, max_new=args.max_new,
            prompt_lo=args.prompt_lo, prompt_hi=args.prompt_hi,
            backend=args.backend, repeats=args.quant_repeats)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result, indent=2))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
