"""Fig. 13: density sweep — cycles/MAC vs density for SegFold, Spada and
static Flexagon OP/Gustavson (paper: SegFold flat, Spada degrades > 0.4,
OP improves with density, SegFold wins even fully dense)."""
import numpy as np

from repro.sim import matrices
from repro.sim.baselines import flexagon_gust, flexagon_op, spada
from repro.sim.segfold_sim import SegFoldConfig, simulate_segfold

from .common import Csv, timed


def run(csv: Csv, sizes=(256,), densities=(0.05, 0.1, 0.2, 0.4, 0.7, 1.0)) -> dict:
    out = {}
    for n in sizes:
        for d in densities:
            rng = np.random.default_rng(int(n * d * 100))
            a = matrices.synthetic(rng, n, d)
            b = matrices.synthetic(rng, n, d)
            cfg = SegFoldConfig()
            seg, us = timed(simulate_segfold, a, b, cfg)
            rows = {
                "segfold": seg.cycles_per_mac,
                "spada": spada(a, b, cfg).cycles_per_mac,
                "flex_op": flexagon_op(a, b, cfg).cycles_per_mac,
                "flex_gust": flexagon_gust(a, b, cfg).cycles_per_mac,
            }
            out[(n, d)] = rows
            csv.add(f"fig13/N{n}_d{d}", us,
                    ";".join(f"{k}_cpm={v:.4f}" for k, v in rows.items()))
    return out
