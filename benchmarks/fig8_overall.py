"""Fig. 8: SegFold speedup over Spada and static Flexagon configs on the
SuiteSparse-like matrix suite (synthetic stand-ins, DESIGN.md §8)."""
from repro.sim.baselines import flexagon_best, spada
from repro.sim.segfold_sim import simulate_segfold

from .common import Csv, geomean, load_suite, timed


def run(csv: Csv, scale_cap: int = 2048) -> dict:
    v_spada, v_static = [], []
    for name, a, b, cfg in load_suite(scale_cap):
        seg, us = timed(simulate_segfold, a, b, cfg)
        sp = spada(a, b, cfg)
        fb = flexagon_best(a, b, cfg)
        su_sp = sp.cycles / seg.cycles
        su_fb = fb["cycles"] / seg.cycles
        v_spada.append(su_sp)
        v_static.append(su_fb)
        csv.add(f"fig8/{name}", us,
                f"speedup_vs_spada={su_sp:.2f};vs_static={su_fb:.2f}"
                f"[{fb['config']}]")
    g_sp, g_fb = geomean(v_spada), geomean(v_static)
    csv.add("fig8/GEOMEAN", 0.0,
            f"vs_spada={g_sp:.2f}(paper:1.95);vs_static={g_fb:.2f}(paper:5.3)")
    return {"geomean_vs_spada": g_sp, "geomean_vs_static": g_fb}
