"""Benchmark harness entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # full suite
    PYTHONPATH=src python -m benchmarks.run --quick    # reduced scale
    PYTHONPATH=src python -m benchmarks.run --only fig8,fig10
Prints ``name,us_per_call,derived`` CSV (the harness contract).
"""
import argparse
import sys
import time

from .common import Csv
from . import (fig8_overall, fig9_nonsquare, fig10_mapping, fig11_breakdown,
               fig12_sensitivity, fig13_density, fig14_asymmetric,
               k_reordering, kernel_bench, roofline_report)

ALL = {
    "fig8": lambda csv, q: fig8_overall.run(csv, scale_cap=1024 if q else 2048),
    "fig9": lambda csv, q: fig9_nonsquare.run(csv, scale_cap=1024 if q else 2048),
    "fig10": lambda csv, q: fig10_mapping.run(csv, scale_cap=1024 if q else 2048),
    "fig11": lambda csv, q: fig11_breakdown.run(csv, scale_cap=1024 if q else 1536),
    "fig12": lambda csv, q: fig12_sensitivity.run(
        csv, sizes=(256,) if q else (256, 512)),
    "fig13": lambda csv, q: fig13_density.run(
        csv, densities=(0.05, 0.2, 1.0) if q else (0.05, 0.1, 0.2, 0.4, 0.7, 1.0)),
    "fig14": lambda csv, q: fig14_asymmetric.run(
        csv, densities=(0.01, 0.05, 0.2) if q else (0.002, 0.01, 0.05, 0.2, 0.5)),
    "k_reordering": lambda csv, q: k_reordering.run(
        csv, scale_cap=1024 if q else 1536),
    "kernels": lambda csv, q: kernel_bench.run(csv),
    "roofline": lambda csv, q: roofline_report.run(csv),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(ALL)
    csv = Csv()
    for name in names:
        t0 = time.time()
        ALL[name](csv, args.quick)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    print(csv.emit())


if __name__ == "__main__":
    main()
