"""Fig. 10: mapping ablation — Zero-Offset vs SegFold LUT vs Ideal oracle."""
import dataclasses

from repro.sim.segfold_sim import simulate_segfold

from .common import Csv, geomean, load_suite, timed


def run(csv: Csv, scale_cap: int = 2048) -> dict:
    lut_vs_zero, lut_vs_ideal = [], []
    for name, a, b, cfg in load_suite(scale_cap, with_extra=True):
        lut, us = timed(simulate_segfold, a, b,
                        dataclasses.replace(cfg, mapping="lut"))
        zero = simulate_segfold(a, b, dataclasses.replace(cfg, mapping="zero"))
        ideal = simulate_segfold(a, b, dataclasses.replace(cfg, mapping="ideal"))
        r_z = zero.cycles / lut.cycles
        r_i = lut.cycles / ideal.cycles
        lut_vs_zero.append(r_z)
        lut_vs_ideal.append(r_i)
        csv.add(f"fig10/{name}", us,
                f"lut_speedup_over_zero={r_z:.3f};overhead_vs_ideal="
                f"{(r_i - 1) * 100:.2f}%")
    csv.add("fig10/GEOMEAN", 0.0,
            f"lut_vs_zero={geomean(lut_vs_zero):.3f}(paper:1.20);"
            f"lut_overhead_vs_ideal={(geomean(lut_vs_ideal)-1)*100:.2f}%(paper:1.2%)")
    return {"lut_vs_zero": geomean(lut_vs_zero),
            "lut_vs_ideal": geomean(lut_vs_ideal)}
