"""§Roofline: aggregate the dry-run artifacts into the per-cell table."""
import glob
import json
import os

from .common import Csv

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def run(csv: Csv) -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        d = json.load(open(path))
        if "roofline" not in d:
            continue
        r = d["roofline"]
        ratio = d.get("useful_flops_ratio")
        rows.append(d)
        csv.add(f"roofline/{d['arch']}__{d['shape']}__{d['mesh']}",
                d.get("compile_s", 0) * 1e6,
                f"compute_s={r['compute_s']:.3e};memory_s={r['memory_s']:.3e};"
                f"collective_s={r['collective_s']:.3e};dominant={r['dominant']};"
                f"useful_flops_ratio={ratio if ratio is None else round(ratio, 3)}")
    return rows
