"""Fig. 14: asymmetric sparsity — swap ratio cyc(dA,dB)/cyc(dB,dA); blue
(<1) favors the sparser matrix as operand A, red corner at extreme ratios."""
import numpy as np

from repro.sim import matrices
from repro.sim.segfold_sim import SegFoldConfig, simulate_segfold

from .common import Csv, timed


def run(csv: Csv, n: int = 256,
        densities=(0.002, 0.01, 0.05, 0.2, 0.5)) -> dict:
    out = {}
    cfg = SegFoldConfig()
    rng = np.random.default_rng(0)
    mats = {d: (matrices.synthetic(rng, n, d), matrices.synthetic(rng, n, d))
            for d in densities}
    for i, da in enumerate(densities):
        for db in densities[i:]:
            a = mats[da][0]
            b = mats[db][1]
            c_ab, us = timed(simulate_segfold, a, b, cfg)
            c_ba = simulate_segfold(b, a, cfg)
            ratio = c_ab.cycles / c_ba.cycles
            out[(da, db)] = ratio
            csv.add(f"fig14/dA{da}_dB{db}", us, f"swap_ratio={ratio:.3f}")
    return out
