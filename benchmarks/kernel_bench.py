"""Kernel-level benchmarks: Segment-schedule traffic savings (the TPU reuse
metric) + interpret-mode wall time vs the jnp oracle."""
import numpy as np
import jax.numpy as jnp

from repro.core.formats import BSR
from repro.core.schedule import build_spmm_schedule, spmm_schedule_traffic
from repro.kernels import ops

from .common import Csv, timed


def run(csv: Csv) -> dict:
    rng = np.random.default_rng(0)
    out = {}
    for (m, k, blk, dens) in [(1024, 1024, 128, 0.25), (2048, 1024, 128, 0.1),
                              (512, 2048, 64, 0.3)]:
        a = BSR.random(rng, (m, k), (blk, blk), dens)
        tr = {p: spmm_schedule_traffic(build_spmm_schedule(a, p), blk, blk, 1024)
              for p in ("segment", "gustavson", "outer")}
        save_g = tr["gustavson"]["total"] / tr["segment"]["total"]
        save_o = tr["outer"]["total"] / tr["segment"]["total"]
        out[(m, k, blk, dens)] = (save_g, save_o)
        csv.add(f"kernel/spmm_traffic_M{m}K{k}b{blk}d{dens}", 0.0,
                f"segment_traffic_saving_vs_gustavson={save_g:.3f}"
                f";vs_outer={save_o:.3f}")
    # interpret-mode numeric check timing (CPU; TPU wall-time N/A here)
    a = BSR.random(rng, (512, 512), (64, 64), 0.25)
    bd = jnp.asarray(rng.standard_normal((512, 256)).astype(np.float32))
    plan = ops.plan_spmm(a)
    _, us1 = timed(lambda: np.asarray(plan(bd, bn=128)))
    _, us2 = timed(lambda: np.asarray(plan(bd, bn=128)))  # warm
    want = a.to_dense() @ np.asarray(bd)
    err = float(np.abs(np.asarray(plan(bd, bn=128)) - want).max())
    csv.add("kernel/spmm_interpret_512", us2, f"max_err={err:.2e}")
    return out
