"""Kernel-level benchmarks: Segment-schedule traffic savings (the TPU reuse
metric) + interpret-mode wall time vs the jnp oracle.

Policies are enumerated from the registry (``repro.api.available_policies``)
so newly registered dataflows show up in the sweep without editing this file.
"""
import numpy as np
import jax.numpy as jnp

from repro import api
from repro.core.formats import BSR

from .common import Csv, timed


def run(csv: Csv) -> dict:
    rng = np.random.default_rng(0)
    out = {}
    policies = api.available_policies()
    for (m, k, blk, dens) in [(1024, 1024, 128, 0.25), (2048, 1024, 128, 0.1),
                              (512, 2048, 64, 0.3)]:
        a = BSR.random(rng, (m, k), (blk, blk), dens)
        tr = {p: api.plan_matmul(a, n_cols_hint=1024, policy=p).traffic
              for p in policies}
        base = {p: t["total"] for p, t in tr.items() if p != "segment"}
        ratios = {p: base[p] / tr["segment"]["total"] for p in base}
        out[(m, k, blk, dens)] = ratios
        csv.add(f"kernel/spmm_traffic_M{m}K{k}b{blk}d{dens}", 0.0,
                ";".join(f"segment_traffic_saving_vs_{p}={r:.3f}"
                         for p, r in sorted(ratios.items())))
    # interpret-mode numeric check timing (CPU; TPU wall-time N/A here)
    a = BSR.random(rng, (512, 512), (64, 64), 0.25)
    bd = jnp.asarray(rng.standard_normal((512, 256)).astype(np.float32))
    plan = api.plan_matmul(a, bd.shape)
    _, us1 = timed(lambda: np.asarray(plan(bd, bn=128)))
    _, us2 = timed(lambda: np.asarray(plan(bd, bn=128)))  # warm
    want = a.to_dense() @ np.asarray(bd)
    err = float(np.abs(np.asarray(plan(bd, bn=128)) - want).max())
    csv.add("kernel/spmm_interpret_512", us2, f"max_err={err:.2e}")
    # reference-backend parity on the same plan (backend dispatch smoke)
    err_ref = float(np.abs(np.asarray(plan(bd, backend="reference")) - want).max())
    csv.add("kernel/spmm_reference_512", 0.0, f"max_err={err_ref:.2e}")
    return out
