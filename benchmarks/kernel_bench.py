"""Kernel-level benchmarks: Segment-schedule traffic savings (the TPU reuse
metric) + lane-parallel interpret wall time vs the dense oracle.

Emits ``BENCH_kernels.json`` (CI smoke target — the kernel perf trajectory
is tracked from this file, alongside ``BENCH_serve.json`` for serving):

    PYTHONPATH=src python -m benchmarks.kernel_bench --out BENCH_kernels.json

Policies are enumerated from the registry (``repro.api.available_policies``)
so newly registered dataflows show up in the sweep without editing this file.
The lane sweep runs the 512×512 SpMM case at 1/2/4 lanes and reports
interpret-mode wall time (median of ``--repeats`` interleaved warm calls),
max error vs the dense oracle, modeled HBM traffic, and the LPT load
imbalance.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np
import jax.numpy as jnp

from repro import api
from repro.core.formats import BSR

from .common import Csv

LANE_CASE = dict(shape=(512, 512), block=(64, 64), blocks_per_row=2,
                 n_cols=256, bn=128)
LANES = (1, 2, 4)


def traffic_sweep() -> dict:
    """Schedule-traffic ratios of every registered policy vs ``segment``."""
    rng = np.random.default_rng(0)
    policies = api.available_policies()
    out = {}
    for (m, k, blk, dens) in [(1024, 1024, 128, 0.25), (2048, 1024, 128, 0.1),
                              (512, 2048, 64, 0.3)]:
        a = BSR.random(rng, (m, k), (blk, blk), dens)
        tr = {p: api.plan_matmul(a, n_cols_hint=1024, policy=p).traffic
              for p in policies}
        base = {p: t["total"] for p, t in tr.items() if p != "segment"}
        key = f"M{m}_K{k}_b{blk}_d{dens}"
        out[key] = {f"segment_traffic_saving_vs_{p}": base[p] / tr["segment"]["total"]
                    for p in base}
    return out


def _balanced_bsr(rng) -> BSR:
    """Uniform blocks-per-row 512×512 pattern (0.25 block density).

    Load-balanced sparsity is the lane feature's target configuration:
    chains pack into lanes with zero padding, so the interpret-mode wall
    time (which emulates the grid *sequentially* — lanes can only tie, the
    concurrency win needs real hardware) compares equal step counts.
    """
    m, k = LANE_CASE["shape"]
    bm, bk = LANE_CASE["block"]
    gm, gk = m // bm, k // bk
    brow, bcol = [], []
    for r in range(gm):
        cols = rng.choice(gk, size=LANE_CASE["blocks_per_row"], replace=False)
        for c in sorted(cols.tolist()):
            brow.append(r)
            bcol.append(c)
    return BSR(shape=(m, k), block_shape=(bm, bk),
               brow=np.asarray(brow, np.int32),
               bcol=np.asarray(bcol, np.int32),
               blocks=rng.standard_normal(
                   (len(brow), bm, bk)).astype(np.float32))


def lane_sweep(repeats: int = 12) -> dict:
    """Interpret wall time + dense-oracle parity for 1/2/4 lanes.

    Timing is interleaved round-robin across lane counts (kills drift bias)
    and reported as min/median of ``repeats`` warm calls.
    """
    rng = np.random.default_rng(1)
    a = _balanced_bsr(rng)
    bd = jnp.asarray(rng.standard_normal(
        (LANE_CASE["shape"][1], LANE_CASE["n_cols"])).astype(np.float32))
    want = a.to_dense() @ np.asarray(bd)

    runs = {}
    for lanes in LANES:
        plan = api.plan_matmul(a, bd.shape, n_lanes=lanes)
        fn = jax.jit(lambda p, x: api.execute_plan(
            p, x, bn=LANE_CASE["bn"], backend="interpret"))
        got = np.asarray(fn(plan, bd))                 # compile + warm
        runs[lanes] = (plan, fn, float(np.abs(got - want).max()))
    times = {lanes: [] for lanes in LANES}
    for _ in range(repeats):
        for lanes, (plan, fn, _err) in runs.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(plan, bd))
            times[lanes].append((time.perf_counter() - t0) * 1e6)

    out = {}
    for lanes, (plan, _fn, err) in runs.items():
        ts = sorted(times[lanes])
        tr = plan.traffic
        out[str(lanes)] = {
            "effective_lanes": plan.n_lanes,
            "interpret_us": ts[len(ts) // 2],          # median
            "interpret_us_min": ts[0],
            "max_err": err,
            "traffic_total_bytes": tr["total"],
            "b_fetches": tr["b_fetches"],
            "lane_imbalance": tr.get("imbalance", 1.0),
            "padded_items": tr.get("padded_items", 0),
        }
    return out


def run(csv: Csv) -> dict:
    """CSV entry point for ``benchmarks.run`` (the figure-suite driver)."""
    ratios = traffic_sweep()
    for key, r in ratios.items():
        csv.add(f"kernel/spmm_traffic_{key}", 0.0,
                ";".join(f"{name}={v:.3f}" for name, v in sorted(r.items())))
    lanes = lane_sweep()
    for n, row in lanes.items():
        csv.add(f"kernel/spmm_interpret_512_lanes{n}", row["interpret_us"],
                f"max_err={row['max_err']:.2e};"
                f"imbalance={row['lane_imbalance']:.3f}")
    return {"traffic": ratios, "lanes": lanes}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=12)
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args()

    result = {"traffic": traffic_sweep(), "lanes": lane_sweep(args.repeats),
              "lane_case": {k: str(v) for k, v in LANE_CASE.items()},
              "plan_cache": api.plan_cache_stats()}
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result["lanes"], indent=2))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
