"""Kernel-level benchmarks: Segment-schedule traffic savings (the TPU reuse
metric) + lane-parallel interpret wall time vs the dense oracle.

Emits ``BENCH_kernels.json`` (CI smoke target — the kernel perf trajectory
is tracked from this file, alongside ``BENCH_serve.json`` for serving):

    PYTHONPATH=src python -m benchmarks.kernel_bench --out BENCH_kernels.json

Policies are enumerated from the registry (``repro.api.available_policies``)
so newly registered dataflows show up in the sweep without editing this file.
The lane sweep runs the 512×512 SpMM case at 1/2/4 lanes and reports
interpret-mode wall time (median of ``--repeats`` interleaved warm calls),
max error vs the dense oracle, modeled HBM traffic, and the LPT load
imbalance.  The quant sweep runs the standard weight-bound case at
fp32/int8/fp8 block storage and reports traffic-bytes ratios vs fp32 plus
normalized max error vs the dense fp32 oracle (CI gates both).  The
pipeline sweep checks the DMA-pipeline fetch contract (modeled fetch count
== schedule fetch-flag count, exactly, both kernels) and tracks interpret
wall time vs the non-pipelined baseline.  The prefetch sweep runs the lane
case with ``prefetch="cross_pass"`` across a two-N-tile grid and gates the
mode end to end: bit-exact parity vs the drained schedule, the traffic
model's ``prefetch_fetches`` against an independent head-window fetch-flag
sum, a clean full-level verify, zero inter-pass ordering findings from
``repro.analysis.order`` over the traced kernels, and an interpret
wall-time ratio (the overlap win itself needs real hardware — interpret
replays every copy inline, so CI only gates against regressions).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np
import jax.numpy as jnp

from repro import api
from repro.analysis import (analyze_callable, check_scale_agreement,
                            plan_vmem_bytes, verify_plan)
from repro.core.formats import BSR
from repro.kernels.segment_spmm import segment_spmm

from .common import Csv

LANE_CASE = dict(shape=(512, 512), block=(64, 64), blocks_per_row=2,
                 n_cols=256, bn=128)
LANES = (1, 2, 4)

# Standard quantization case: a weight-bound SpMM (decode-like narrow rhs)
# where A-tile bytes dominate the modeled traffic — the configuration
# quantized block storage targets.
QUANT_CASE = dict(shape=(1024, 2048), block=(128, 128), density=0.25,
                  n_cols=32, bn=32)
QUANT_MODES = ("fp32", "int8", "fp8")


def traffic_sweep() -> dict:
    """Schedule-traffic ratios of every registered policy vs ``segment``."""
    rng = np.random.default_rng(0)
    policies = api.available_policies()
    out = {}
    for (m, k, blk, dens) in [(1024, 1024, 128, 0.25), (2048, 1024, 128, 0.1),
                              (512, 2048, 64, 0.3)]:
        a = BSR.random(rng, (m, k), (blk, blk), dens)
        tr = {p: api.plan_matmul(a, n_cols_hint=1024, policy=p).traffic
              for p in policies}
        base = {p: t["total"] for p, t in tr.items() if p != "segment"}
        key = f"M{m}_K{k}_b{blk}_d{dens}"
        out[key] = {f"segment_traffic_saving_vs_{p}": base[p] / tr["segment"]["total"]
                    for p in base}
    return out


def _balanced_bsr(rng) -> BSR:
    """Uniform blocks-per-row 512×512 pattern (0.25 block density).

    Load-balanced sparsity is the lane feature's target configuration:
    chains pack into lanes with zero padding, so the interpret-mode wall
    time (which emulates the grid *sequentially* — lanes can only tie, the
    concurrency win needs real hardware) compares equal step counts.
    """
    m, k = LANE_CASE["shape"]
    bm, bk = LANE_CASE["block"]
    gm, gk = m // bm, k // bk
    brow, bcol = [], []
    for r in range(gm):
        cols = rng.choice(gk, size=LANE_CASE["blocks_per_row"], replace=False)
        for c in sorted(cols.tolist()):
            brow.append(r)
            bcol.append(c)
    return BSR(shape=(m, k), block_shape=(bm, bk),
               brow=np.asarray(brow, np.int32),
               bcol=np.asarray(bcol, np.int32),
               blocks=rng.standard_normal(
                   (len(brow), bm, bk)).astype(np.float32))


def lane_sweep(repeats: int = 12) -> dict:
    """Interpret wall time + dense-oracle parity for 1/2/4 lanes.

    Timing is interleaved round-robin across lane counts (kills drift bias)
    and reported as min/median of ``repeats`` warm calls.
    """
    rng = np.random.default_rng(1)
    a = _balanced_bsr(rng)
    bd = jnp.asarray(rng.standard_normal(
        (LANE_CASE["shape"][1], LANE_CASE["n_cols"])).astype(np.float32))
    want = a.to_dense() @ np.asarray(bd)

    runs = {}
    for lanes in LANES:
        plan = api.plan_matmul(a, bd.shape, n_lanes=lanes)
        fn = jax.jit(lambda p, x: api.execute_plan(
            p, x, bn=LANE_CASE["bn"], backend="interpret"))
        got = np.asarray(fn(plan, bd))                 # compile + warm
        runs[lanes] = (plan, fn, float(np.abs(got - want).max()))
    times = {lanes: [] for lanes in LANES}
    for _ in range(repeats):
        for lanes, (plan, fn, _err) in runs.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(plan, bd))
            times[lanes].append((time.perf_counter() - t0) * 1e6)

    out = {}
    for lanes, (plan, _fn, err) in runs.items():
        ts = sorted(times[lanes])
        tr = plan.traffic
        out[str(lanes)] = {
            "effective_lanes": plan.n_lanes,
            "interpret_us": ts[len(ts) // 2],          # median
            "interpret_us_min": ts[0],
            "max_err": err,
            "traffic_total_bytes": tr["total"],
            "b_fetches": tr["b_fetches"],
            "lane_imbalance": tr.get("imbalance", 1.0),
            "padded_items": tr.get("padded_items", 0),
            # static analyzer's VMEM working set at this case's bn (the
            # budget the planner's vmem_limit_bytes knob would enforce)
            "vmem_bytes": plan_vmem_bytes(plan, bn=LANE_CASE["bn"]),
        }
    return out


def quant_sweep() -> dict:
    """Quantized block storage: traffic bytes + dense-fp32-oracle parity.

    Runs the standard quant case (``QUANT_CASE``) at fp32 / int8 / fp8
    block storage and reports the modeled HBM traffic (quantized payload +
    per-block scales vs fp32 tiles) and ``max_err`` — the max absolute
    deviation from the dense fp32 oracle, normalized by the oracle's max
    magnitude (so the bound is scale-free and K-independent enough to gate
    in CI; see docs/API.md for the documented bounds).
    """
    rng = np.random.default_rng(3)
    m, k = QUANT_CASE["shape"]
    a = BSR.random(rng, (m, k), QUANT_CASE["block"], QUANT_CASE["density"])
    x = jnp.asarray(rng.standard_normal(
        (k, QUANT_CASE["n_cols"])).astype(np.float32))
    want = a.to_dense() @ np.asarray(x)
    norm = float(np.abs(want).max())
    out = {}
    for mode in QUANT_MODES:
        plan = api.plan_matmul(a, x.shape,
                               quantize=None if mode == "fp32" else mode)
        got = np.asarray(plan(x, bn=QUANT_CASE["bn"], backend="interpret"))
        tr = plan.traffic
        out[mode] = {
            "traffic_total_bytes": tr["total"],
            "a_bytes": tr["a_bytes"],
            "max_err": float(np.abs(got - want).max() / norm),
            "vmem_bytes": plan_vmem_bytes(plan, bn=QUANT_CASE["bn"]),
        }
    for mode in QUANT_MODES[1:]:
        out[mode]["traffic_ratio_vs_fp32"] = (
            out["fp32"]["traffic_total_bytes"]
            / out[mode]["traffic_total_bytes"])
    return out


def pipeline_sweep(repeats: int = 12) -> dict:
    """DMA-pipeline contract + wall time vs the non-pipelined baseline.

    Three gates ride this section in CI:

    * **static verification** — ``repro.analysis.verify_plan(level="full")``
      must report zero findings on both kernels' bench plans
      (``verify_findings``).  The full level includes the
      ``traffic-agreement`` invariant — the model-vs-fetch-flag exact count
      equality this bench used to assert inline, now one catalog entry
      among twelve (the flags gate the in-kernel ``make_async_copy``
      issues, so the model's byte pricing is kernel reality, not an
      estimate); the raw model/flag counts stay in the JSON for trending;
    * **verification overhead** — ``verify_build_overhead`` is the
      amortized wall-time cost of ``plan_matmul(..., verify="full")`` over
      a cache-miss build plus warm realizes of this case's plan, gated
      < 10% (verification runs once per cached template);
    * **wall time** — interpret-mode medians for the pipelined executor
      path vs the legacy BlockSpec auto-pipeline (``pipeline=False``).
      Interpret mode *emulates* every DMA and semaphore op sequentially, so
      the pipelined path pays emulation overhead and the overlap win needs
      real hardware — the ratio is tracked to catch pathological blowups,
      not as a speedup claim.
    """
    rng = np.random.default_rng(2)
    a = _balanced_bsr(rng)
    bd = jnp.asarray(rng.standard_normal(
        (LANE_CASE["shape"][1], LANE_CASE["n_cols"])).astype(np.float32))
    want = a.to_dense() @ np.asarray(bd)
    plan = api.plan_matmul(a, bd.shape, n_lanes=2)
    tr = plan.traffic
    out = {
        "model_a_fetches": int(tr["a_fetches"]),
        "flag_a_fetches": int(np.asarray(plan.a_fetch).sum()),
        "model_b_fetches": int(tr["b_fetches"]),
        "flag_b_fetches": int(np.asarray(plan.b_fetch).sum()),
    }
    # spgemm fetch contract (A and B block streams both flag-gated); dense
    # enough that the symbolic intersection is guaranteed non-empty — an
    # empty triple list would gate 0 == 0 and check nothing
    ga = BSR.random(np.random.default_rng(4), (256, 256), (32, 32), 0.5)
    gb = BSR.random(np.random.default_rng(5), (256, 256), (32, 32), 0.5)
    gplan = api.plan_matmul(ga, gb, n_lanes=2)
    gtr = gplan.traffic
    out.update(
        spgemm_model_a_fetches=int(gtr["a_fetches"]),
        spgemm_flag_a_fetches=int(np.asarray(gplan.a_fetch).sum()),
        spgemm_model_b_fetches=int(gtr["b_fetches"]),
        spgemm_flag_b_fetches=int(np.asarray(gplan.b_fetch).sum()))

    # static verification of both bench plans (the full level subsumes the
    # fetch contract via the traffic-agreement invariant)
    findings = (verify_plan(plan, level="full").findings
                + verify_plan(gplan, level="full").findings)
    out["verify_findings"] = len(findings)
    out["verify_finding_ids"] = sorted({f.invariant for f in findings})

    # analyzer VMEM budgets for the three kernel instances this sweep
    # exercises (pipelined / legacy SpMM at this bn, pipelined SpGEMM)
    out["vmem_bytes_pipelined"] = plan_vmem_bytes(plan, bn=LANE_CASE["bn"])
    out["vmem_bytes_legacy"] = plan_vmem_bytes(plan, bn=LANE_CASE["bn"],
                                               pipelined=False)
    out["vmem_bytes_spgemm"] = plan_vmem_bytes(gplan)

    # amortized cost of verify="full": the hook adds exactly two things to
    # plan_matmul — one full-catalog template verification per cache miss
    # and one O(1) scale check per realize — so the overhead over a
    # cache-miss build plus 24 warm realizes is measured component-wise
    # ((verify + 25*scale) / (miss + 24*hit), each term min-of-many) rather
    # than by differencing whole cycles, which on a loaded runner buries
    # the ~6% signal in run-to-run variance.  The 24:1 hit:miss ratio is
    # the conservative end of steady state: any serving or training loop
    # realizes one fingerprint thousands of times per miss.
    def _min_t(fn, repeats, inner=1):
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            for _ in range(inner):
                fn()
            best = min(best, (time.perf_counter() - t0) / inner)
        return best

    def _miss():
        api.clear_plan_cache()
        api.plan_matmul(a, bd.shape, n_lanes=2)

    verify_plan(plan, level="full")   # warm the verifier's dispatch caches
    t_miss = _min_t(_miss, 30)
    t_hit = _min_t(lambda: api.plan_matmul(a, bd.shape, n_lanes=2), 5,
                   inner=50)
    t_verify = _min_t(lambda: verify_plan(plan, level="full"), 5, inner=20)
    t_scale = _min_t(lambda: check_scale_agreement(plan), 5, inner=200)
    out["verify_build_overhead"] = ((t_verify + 25 * t_scale)
                                    / (t_miss + 24 * t_hit))

    bn = LANE_CASE["bn"]
    pip = jax.jit(lambda p, x: api.execute_plan(
        p, x, bn=bn, backend="interpret"))

    def legacy_call(p, x):
        return segment_spmm(
            p.lhs_blocks, p.slot_idx, p.m_idx, p.k_idx, p.seg_start,
            p.seg_write, p.accum_prev, p.valid, x, grid_m=p.grid[0],
            n_lanes=p.n_lanes, bn=bn, unroll=p.unroll, masked=p.has_pads,
            interpret=True, pipeline=False)

    leg = jax.jit(legacy_call)
    out["max_err_pipelined"] = float(
        np.abs(np.asarray(pip(plan, bd)) - want).max())
    out["max_err_legacy"] = float(
        np.abs(np.asarray(leg(plan, bd)) - want).max())
    times = {"pipelined": [], "legacy": []}
    for _ in range(repeats):
        for name, fn in (("pipelined", pip), ("legacy", leg)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(plan, bd))
            times[name].append((time.perf_counter() - t0) * 1e6)
    for name, ts in times.items():
        ts = sorted(ts)
        out[f"{name}_us"] = ts[len(ts) // 2]
        out[f"{name}_us_min"] = ts[0]
    out["interpret_slowdown_vs_legacy"] = (
        out["pipelined_us_min"] / out["legacy_us_min"])
    return out


def prefetch_sweep(repeats: int = 12) -> dict:
    """Cross-pass DMA prefetch vs the drained schedule, end to end.

    Runs the lane case (256 columns at ``bn=128`` — two N-tile passes, so
    the cross-pass tail actually executes) with and without
    ``prefetch="cross_pass"`` and reports everything CI gates:

    * ``parity_err`` — max abs difference between the two modes' outputs
      (the fetch flags are identical under both, so this must be 0.0);
    * ``model_prefetch_fetches`` / ``flag_prefetch_fetches`` — the traffic
      model's overlapped-fetch count vs an independent sum of the
      schedule's fetch flags over each lane's first-``unroll`` head window
      (the copies the kernel issues from the previous pass's tail) —
      gated exactly equal;
    * ``verify_findings`` / ``order_findings`` — full-level plan
      verification plus the :mod:`repro.analysis.order` happens-before
      rules (``cross-pass-war``/``sem-carryover``/``prefetch-raw``/
      ``dma-priority``) over the traced kernels of both modes, all gated
      at zero — no prefetch schedule ships uncertified;
    * wall time — interleaved interpret medians for both modes.  The
      interpreter replays every DMA inline and additionally evaluates the
      prefetch schedule's extra tail/prologue guards every grid step, so
      prefetch cannot win here (steady state measures ~1.25-1.3x); the
      ratio is gated ≤ 1.5 to catch pathological regressions, and the
      overlap win itself is a real-TPU follow-up.
    """
    rng = np.random.default_rng(7)
    a = _balanced_bsr(rng)
    bd = jnp.asarray(rng.standard_normal(
        (LANE_CASE["shape"][1], LANE_CASE["n_cols"])).astype(np.float32))
    want = a.to_dense() @ np.asarray(bd)
    bn = LANE_CASE["bn"]

    plans = {
        "no_prefetch": api.plan_matmul(a, bd.shape, n_lanes=2, unroll=2,
                                       cache=False),
        "prefetch": api.plan_matmul(a, bd.shape, n_lanes=2, unroll=2,
                                    cache=False, prefetch="cross_pass"),
    }
    fn = jax.jit(lambda p, x: api.execute_plan(
        p, x, bn=bn, backend="interpret"))
    got = {label: np.asarray(fn(p, bd)) for label, p in plans.items()}

    pf = plans["prefetch"]
    tr = pf.traffic
    n_lanes, unroll = pf.n_lanes, pf.unroll
    head = slice(0, unroll)
    flag_sum = int(
        np.asarray(pf.a_fetch).reshape(n_lanes, -1)[:, head].sum()
        + np.asarray(pf.b_fetch).reshape(n_lanes, -1)[:, head].sum())
    out = {
        "n_tiles_n": LANE_CASE["n_cols"] // bn,
        "parity_err": float(
            np.abs(got["prefetch"] - got["no_prefetch"]).max()),
        "max_err": float(np.abs(got["prefetch"] - want).max()),
        "model_prefetch_fetches": int(tr["prefetch_fetches"]),
        "flag_prefetch_fetches": flag_sum,
        "verify_findings": len(verify_plan(pf, level="full").findings),
    }

    # inter-pass ordering certification of both executions (the merged
    # analyzer includes ORDER_RULES; prefetch's traced grid carries the
    # demoted N-tile axis, so the cross-pass rules are non-vacuous)
    n_order = 0
    for label, p in plans.items():
        n_order += len(analyze_callable(
            lambda x: api.execute_plan(p, x, bn=bn, backend="interpret"),
            bd, label=f"bench-{label}"))
    out["order_findings"] = n_order

    times = {label: [] for label in plans}
    for _ in range(repeats):
        for label, p in plans.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(p, bd))
            times[label].append((time.perf_counter() - t0) * 1e6)
    for label, ts in times.items():
        ts = sorted(ts)
        out[f"{label}_us"] = ts[len(ts) // 2]
        out[f"{label}_us_min"] = ts[0]
    out["interpret_ratio_vs_no_prefetch"] = (
        out["prefetch_us_min"] / out["no_prefetch_us_min"])
    return out


AUTOTUNE_N_COLS = 256

# pattern generators for the autotune sweep: the three traffic-sweep cases
# plus the banded "staircase" pattern whose row k-sets (r0={0}, r1={0},
# r2={0,1}, r3={1}, repeated down the diagonal) defeat SELECTA's greedy
# longest-run-first chaining — the canonical case where the cost model must
# hand the plan to a static dataflow (gustavson's m-order chains perfectly)


def _staircase_bsr(rng, bm=32, bk=32, stack=4) -> BSR:
    base_r = np.array([0, 1, 2, 2, 3])
    base_c = np.array([0, 0, 0, 1, 1])
    brow = np.concatenate([base_r + 4 * s for s in range(stack)])
    bcol = np.concatenate([base_c + 2 * s for s in range(stack)])
    return BSR(shape=(4 * stack * bm, 2 * stack * bk), block_shape=(bm, bk),
               brow=brow.astype(np.int64), bcol=bcol.astype(np.int64),
               blocks=rng.standard_normal(
                   (brow.size, bm, bk)).astype(np.float32))


def autotune_sweep(repeats: int = 12) -> dict:
    """Tuned vs default-knob schedules: traffic bytes + interpret wall time.

    For each case the :mod:`repro.tune` search runs under the interpret
    objective (the backend this bench times), the winner is rebuilt and
    statically verified, and both plans execute jitted/warm with interleaved
    repeats.  CI gates every case on ``tuned_traffic_bytes <=
    default_traffic_bytes`` and ``tuned_us_min <= default_us_min * 1.25``
    (interpret emulates the grid sequentially, so the tuner's wins here are
    step-count and traffic wins; lane concurrency needs real hardware), and
    asserts the staircase case dispatches a non-segment dataflow.  The
    measured ``(bytes, steps, us)`` triples re-fit the cost-model
    coefficients (``repro.tune.calibrate``) on every run, so drift between
    the shipped ``DEFAULT_INTERPRET`` model and reality stays visible in
    the JSON."""
    from repro import tune
    from repro.api.executor import pick_bn
    rng = np.random.default_rng(6)
    cases = {}
    for (m, k, blk, dens) in [(1024, 1024, 128, 0.25), (2048, 1024, 128, 0.1),
                              (512, 2048, 64, 0.3)]:
        cases[f"M{m}_K{k}_b{blk}_d{dens}"] = BSR.random(
            rng, (m, k), (blk, blk), dens)
    cases["staircase_4x"] = _staircase_bsr(rng)

    n = AUTOTUNE_N_COLS
    out = {}
    samples = []
    for name, a in cases.items():
        res = tune.autotune_matmul(a, n_cols_hint=n, objective="interpret",
                                   cache=False)
        default = api.plan_matmul(a, n, cache=False)
        tuned = api.plan_matmul(a, n, cache=False, **res.plan_kwargs())
        findings = (verify_plan(default, level="full").findings
                    + verify_plan(tuned, level="full").findings)

        variants = {}
        for label, plan in (("default", default), ("tuned", tuned)):
            bn_req = plan.bn_hint or 512
            bn_eff, pad = pick_bn(n, bn_req)
            bd = jnp.asarray(rng.standard_normal(
                (a.shape[1], n)).astype(np.float32))
            fn = jax.jit(lambda p, x: api.execute_plan(
                p, x, backend="interpret"))
            got = np.asarray(fn(plan, bd))              # compile + warm
            err = float(np.abs(got - a.to_dense() @ np.asarray(bd)).max())
            variants[label] = dict(plan=plan, fn=fn, bd=bd, err=err,
                                   n_tiles=(n + pad) // bn_eff,
                                   bn_eff=bn_eff)
        times = {label: [] for label in variants}
        for _ in range(repeats):
            for label, v in variants.items():
                t0 = time.perf_counter()
                jax.block_until_ready(v["fn"](v["plan"], v["bd"]))
                times[label].append((time.perf_counter() - t0) * 1e6)

        row = {
            "policy": tuned.policy,
            "knobs": dict(fold_len=res.best.candidate.fold_len,
                          n_lanes=tuned.n_lanes, unroll=tuned.unroll,
                          bn=res.best.candidate.bn, pipeline=tuned.pipeline),
            "dataflow_choice": res.dataflow_choice,
            "dataflow_scores": {k: float(v)
                                for k, v in res.dataflow_scores.items()},
            "model_cost_us": res.best.cost_us,
            "verify_findings": len(findings),
            "vmem_bytes": plan_vmem_bytes(tuned,
                                          bn=variants["tuned"]["bn_eff"]),
        }
        seq = tune.DEFAULT_INTERPRET
        for label, v in variants.items():
            ts = sorted(times[label])
            plan = v["plan"]
            row[f"{label}_traffic_bytes"] = plan.traffic["total"]
            row[f"{label}_us"] = ts[len(ts) // 2]
            row[f"{label}_us_min"] = ts[0]
            row[f"{label}_max_err"] = v["err"]
            samples.append((plan.traffic["total"],
                            seq.steps(n_lanes=plan.n_lanes,
                                      lane_len=plan.lane_len,
                                      unroll=plan.unroll,
                                      n_tiles_n=v["n_tiles"]),
                            ts[0]))
        out[name] = row

    fit = tune.calibrate(samples, lane_parallel=False)
    out["cost_model"] = {
        "objective": "interpret",
        "bytes_per_us": fit.bytes_per_us,
        "step_us": fit.step_us,
        "shipped_bytes_per_us": tune.DEFAULT_INTERPRET.bytes_per_us,
        "shipped_step_us": tune.DEFAULT_INTERPRET.step_us,
        "n_samples": len(samples),
    }
    return out


def run(csv: Csv) -> dict:
    """CSV entry point for ``benchmarks.run`` (the figure-suite driver)."""
    ratios = traffic_sweep()
    for key, r in ratios.items():
        csv.add(f"kernel/spmm_traffic_{key}", 0.0,
                ";".join(f"{name}={v:.3f}" for name, v in sorted(r.items())))
    lanes = lane_sweep()
    for n, row in lanes.items():
        csv.add(f"kernel/spmm_interpret_512_lanes{n}", row["interpret_us"],
                f"max_err={row['max_err']:.2e};"
                f"imbalance={row['lane_imbalance']:.3f}")
    quant = quant_sweep()
    for mode, row in quant.items():
        csv.add(f"kernel/spmm_quant_{mode}", row["traffic_total_bytes"],
                f"max_err={row['max_err']:.2e}")
    pipe = pipeline_sweep()
    csv.add("kernel/spmm_pipeline_interpret", pipe["pipelined_us"],
            f"legacy={pipe['legacy_us']:.0f}us;"
            f"max_err={pipe['max_err_pipelined']:.2e}")
    pf = prefetch_sweep()
    csv.add("kernel/spmm_prefetch_interpret", pf["prefetch_us"],
            f"baseline={pf['no_prefetch_us']:.0f}us;"
            f"parity_err={pf['parity_err']:.2e};"
            f"order_findings={pf['order_findings']}")
    tuned = autotune_sweep()
    for name, row in tuned.items():
        if name == "cost_model":
            continue
        csv.add(f"kernel/spmm_autotune_{name}", row["tuned_us"],
                f"policy={row['policy']};"
                f"bytes_ratio={row['default_traffic_bytes'] / max(1, row['tuned_traffic_bytes']):.3f}")
    return {"traffic": ratios, "lanes": lanes, "quant": quant,
            "pipeline": pipe, "prefetch": pf, "autotune": tuned}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=12)
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args()

    result = {"traffic": traffic_sweep(), "lanes": lane_sweep(args.repeats),
              "quant": quant_sweep(), "pipeline": pipeline_sweep(args.repeats),
              "prefetch": prefetch_sweep(args.repeats),
              "autotune": autotune_sweep(args.repeats),
              # case configs as native JSON types (tuples become arrays) so
              # trend tooling can compare run-to-run numerically — str(v)
              # used to turn (512, 512) into an unparseable "(512, 512)"
              "lane_case": LANE_CASE,
              "quant_case": QUANT_CASE,
              "plan_cache": api.plan_cache_stats()}
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result["lanes"], indent=2))
    print(json.dumps(result["quant"], indent=2))
    print(json.dumps(result["pipeline"], indent=2))
    print(json.dumps(result["prefetch"], indent=2))
    print(json.dumps(result["autotune"], indent=2))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
