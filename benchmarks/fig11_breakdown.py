"""Fig. 11: incremental attribution — base → +SELECTA → +SEGMENTBC →
+folding → +IPM (paper: full stack ≈ 3.1× over base)."""
import dataclasses

from repro.sim.segfold_sim import SegFoldConfig, simulate_segfold

from .common import Csv, geomean, load_suite, timed

STAGES = [
    ("base", dict(schedule_mode="static_rr", segmentbc_enabled=False,
                  spatial_folding=False, mapping="zero")),
    ("+selecta", dict(schedule_mode="selecta", segmentbc_enabled=False,
                      spatial_folding=False, mapping="zero")),
    ("+segmentbc", dict(schedule_mode="selecta", segmentbc_enabled=True,
                        spatial_folding=False, mapping="zero")),
    ("+folding", dict(schedule_mode="selecta", segmentbc_enabled=True,
                      spatial_folding=True, mapping="zero")),
    ("+ipm_lut", dict(schedule_mode="selecta", segmentbc_enabled=True,
                      spatial_folding=True, mapping="lut")),
]


def run(csv: Csv, scale_cap: int = 1536, n_matrices: int = 12) -> dict:
    gains = {name: [] for name, _ in STAGES[1:]}
    total = []
    for name, a, b, cfg in load_suite(scale_cap)[:n_matrices]:
        prev = None
        base_c = None
        for sname, over in STAGES:
            res, us = timed(simulate_segfold, a, b,
                            dataclasses.replace(cfg, **over))
            if sname == "base":
                base_c = res.cycles
            else:
                gains[sname].append(prev / res.cycles)
            prev = res.cycles
        total.append(base_c / prev)
        csv.add(f"fig11/{name}", us, f"full_over_base={base_c / prev:.2f}")
    per = {k: geomean(v) for k, v in gains.items()}
    csv.add("fig11/GEOMEAN", 0.0,
            "full_over_base=%.2f(paper:3.1);" % geomean(total)
            + ";".join(f"{k}={v:.2f}x" for k, v in per.items()))
    return {"full_over_base": geomean(total), "stages": per}
