"""Fig. 12: hardware-parameter sensitivity — (a) multicast width 1–16,
(b) active-window size 1–64, on synthetic square matrices."""
import dataclasses

import numpy as np

from repro.sim import matrices
from repro.sim.segfold_sim import SegFoldConfig, simulate_segfold

from .common import Csv, timed


def run(csv: Csv, sizes=(256, 512), densities=(0.05, 0.1)) -> dict:
    out = {"width": {}, "window": {}}
    for n in sizes:
        for d in densities:
            rng = np.random.default_rng(n + int(d * 100))
            a = matrices.synthetic(rng, n, d)
            b = matrices.synthetic(rng, n, d)
            cfg = SegFoldConfig()
            c4 = simulate_segfold(a, b, dataclasses.replace(
                cfg, multicast_width=4)).cycles
            for w in (1, 2, 4, 8, 16):
                res, us = timed(simulate_segfold, a, b,
                                dataclasses.replace(cfg, multicast_width=w))
                rel = res.cycles / c4
                out["width"][(n, d, w)] = rel
                csv.add(f"fig12a/N{n}_d{d}_BRL{w}", us, f"norm_to_BRL4={rel:.3f}")
            c32 = simulate_segfold(a, b, dataclasses.replace(
                cfg, window=32)).cycles
            for w in (1, 2, 4, 8, 16, 32, 64):
                res, us = timed(simulate_segfold, a, b,
                                dataclasses.replace(cfg, window=w))
                rel = res.cycles / c32
                out["window"][(n, d, w)] = rel
                csv.add(f"fig12b/N{n}_d{d}_W{w}", us, f"norm_to_W32={rel:.3f}")
    return out
