"""Shared benchmark utilities: suite loading, cache scaling, CSV output."""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, List, Tuple

import numpy as np

from repro.sim import matrices
from repro.sim.segfold_sim import SegFoldConfig

CACHE_FULL = int(1.5 * 1024 * 1024)


def load_suite(scale_cap: int = 2048, with_extra: bool = False):
    """(name, A, B=Aᵀ, SegFoldConfig-with-scaled-cache) for the 15-matrix
    suite (§V).  The cache scales with the matrix scale-down factor so the
    cache-to-working-set ratio matches the original experiment."""
    out = []
    for name, (a, spec) in matrices.suite(scale_cap=scale_cap).items():
        if name == "olm5000" and not with_extra:
            continue
        cache = max(int(CACHE_FULL * spec.scale), 64 * 1024)
        out.append((name, a, a.transpose(), SegFoldConfig(cache_bytes=cache)))
    return out


def geomean(xs: Iterable[float]) -> float:
    xs = list(xs)
    return float(np.exp(np.mean(np.log(xs)))) if xs else float("nan")


class Csv:
    """Collects ``name,us_per_call,derived`` rows (one per measurement)."""

    def __init__(self):
        self.rows: List[Tuple[str, float, str]] = []

    def add(self, name: str, us_per_call: float, derived: str):
        self.rows.append((name, us_per_call, derived))

    def emit(self) -> str:
        lines = ["name,us_per_call,derived"]
        for n, u, d in self.rows:
            lines.append(f"{n},{u:.1f},{d}")
        return "\n".join(lines)


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6
