"""End-to-end training driver: a ~100M-parameter LM with the Segment
block-sparse FFN (the paper's technique as a first-class training feature).

    PYTHONPATH=src python examples/train_sparse_lm.py --steps 300
    PYTHONPATH=src python examples/train_sparse_lm.py --steps 5 --smoke
"""
import argparse
import dataclasses
import time

from repro.configs import get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import build_model
from repro.runtime import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny dims for CI-speed verification")
    ap.add_argument("--sparse", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.smoke:
        cfg = ModelConfig(name="sparse-lm-smoke", family="dense", n_layers=2,
                          d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=512,
                          ffn_block_sparse=args.sparse, ffn_block=32,
                          ffn_density=0.5, remat=False)
        shape = ShapeConfig("smoke", "train", seq_len=64, global_batch=4)
    else:
        # ~100M params: 10L × d640 (attn 1.6M + sparse-ffn ~2.5M active) +
        # 50k vocab embedding
        cfg = ModelConfig(name="sparse-lm-100m", family="dense", n_layers=10,
                          d_model=640, n_heads=10, n_kv=5, d_ff=2560,
                          vocab=50048, ffn_block_sparse=args.sparse,
                          ffn_block=64, ffn_density=0.5)
        shape = ShapeConfig("train", "train", seq_len=256, global_batch=8)

    model = build_model(cfg)
    print(f"training {cfg.name}: ~{cfg.param_count()/1e6:.0f}M params, "
          f"sparse_ffn={cfg.ffn_block_sparse} (density {cfg.ffn_density})")
    tcfg = TrainerConfig(steps=args.steps, peak_lr=3e-4,
                         warmup=max(args.steps // 20, 2),
                         ckpt_dir=args.ckpt_dir, ckpt_every=100,
                         log_every=max(args.steps // 20, 1))
    t0 = time.time()
    out = Trainer(model, cfg, shape, tcfg).run()
    for h in out["history"]:
        print(f"  step {h['step']:5d} loss {h['loss']:.4f} "
              f"gnorm {h['grad_norm']:.3f}")
    print(f"final loss {out['final_loss']:.4f} in {time.time()-t0:.0f}s "
          f"(loss must fall from ~ln(V)={__import__('math').log(cfg.padded_vocab):.2f})")


if __name__ == "__main__":
    main()
