"""Quickstart: the Segment dataflow end-to-end in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. element-granularity Segment dataflow (paper Alg. 1 + §III-B) on a small
   sparse product — the faithful reference;
2. TPU block-level Segment schedule + Pallas kernel (interpret on CPU);
3. cycle-approximate simulator: SegFold vs Spada-like vs best-static.
"""
import numpy as np
import jax.numpy as jnp

from repro import api
from repro.core.formats import BSR, CSC, random_csr
from repro.core.segmentbc import segment_spgemm_elementwise
from repro.core.selecta import run_selecta, selecta_stats
from repro.sim import matrices
from repro.sim.baselines import flexagon_best, spada
from repro.sim.segfold_sim import SegFoldConfig, simulate_segfold

rng = np.random.default_rng(0)

# --- 1. the dataflow itself -------------------------------------------------
a = random_csr(rng, (96, 128), 0.08)
b = random_csr(rng, (128, 80), 0.08)
c, telemetry = segment_spgemm_elementwise(CSC.from_csr(a), b, mapping="lut")
assert np.allclose(c, a.to_dense() @ b.to_dense(), atol=1e-4)
stats = selecta_stats(run_selecta(CSC.from_csr(a)), r_max=16)
print(f"[1] Segment SpGEMM correct | SELECTA occupancy={stats['occupancy']:.2f} "
      f"k-sharing={stats['k_sharing']:.2f} "
      f"mean displacement={telemetry['mean_displacement']:.2f}")

# --- 2. the unified repro.api: plan → execute → compare policies -----------
# plan_matmul is the front door: it orders A's nonzero blocks under a policy
# from the registry, caches the plan by pattern fingerprint, and returns a
# SegmentPlan — a JAX pytree that passes through jit/vmap/grad as-is.
A = BSR.random(rng, (512, 768), (64, 64), 0.25)
x = jnp.asarray(rng.standard_normal((768, 256)).astype(np.float32))
plan = api.plan_matmul(A, x.shape, policy="segment")
y = plan(x, bn=128)                       # default backend (interpret on CPU)
y_ref = plan(x, backend="reference")      # pure-jnp oracle, same plan
assert np.allclose(np.asarray(y), A.to_dense() @ np.asarray(x), atol=1e-3)
assert np.allclose(np.asarray(y), np.asarray(y_ref), atol=1e-3)
traffic = {p: api.plan_matmul(A, x.shape, policy=p).traffic["total"]
           for p in api.available_policies()}
t = plan.traffic
print(f"[2] repro.api Segment-SpMM correct on {api.default_backend()!r} | "
      f"traffic {t['total']/1e6:.1f} MB (B fetches: {t['b_fetches']}, "
      f"C segments: {t['c_segments']}) | "
      + " ".join(f"{p}={traffic[p]/1e6:.1f}MB" for p in traffic))

# --- 3. the accelerator simulator ------------------------------------------
m = matrices.banded(rng, 1024, 1024, 0.01)
mt = m.transpose()
cfg = SegFoldConfig(cache_bytes=300 * 1024)
seg = simulate_segfold(m, mt, cfg)
sp = spada(m, mt, cfg)
fb = flexagon_best(m, mt, cfg)
print(f"[3] simulator: SegFold {seg.cycles:.0f} cyc | "
      f"Spada {sp.cycles:.0f} ({sp.cycles/seg.cycles:.2f}x) | "
      f"best static [{fb['config']}] {fb['cycles']:.0f} "
      f"({fb['cycles']/seg.cycles:.2f}x)")
