"""Continuous-batching serving example: mixed-length requests stream
through per-slot prefill and batched per-position decode — a finished
request frees its slot immediately and the next queued request takes it.

    PYTHONPATH=src python examples/serve_batched.py --arch granite-3-8b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import REGISTRY, get_config, reduced_config
from repro.models import build_model
from repro.runtime import Engine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list(REGISTRY))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--backend", default=None,
                    help="repro.api backend for sparse layers "
                         "(pallas|interpret|reference; default: autodetect)")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, slots=3, max_len=128,
                    backend=args.backend)
    rng = np.random.default_rng(1)
    # mixed lengths AND mixed budgets: the engine retires each request at
    # its own limit instead of decoding everyone to the group max
    reqs = [Request(prompt=rng.integers(0, cfg.vocab,
                                        int(rng.integers(4, 24)),
                                        dtype=np.int32),
                    max_new_tokens=int(rng.integers(
                        min(4, args.max_new), args.max_new + 1)))
            for _ in range(args.requests)]
    t0 = time.time()
    engine.generate(reqs)
    dt = time.time() - t0
    tok = sum(r.out_tokens.size for r in reqs)
    print(f"{args.arch} (reduced): {len(reqs)} requests, {tok} tokens, "
          f"{dt:.2f}s → {tok/dt:.1f} tok/s; "
          f"compiled shapes {engine.compiled_shapes}")
    for i, r in enumerate(reqs):
        print(f"  req{i}: prompt[{len(r.prompt)}] +{r.max_new_tokens} "
              f"→ {r.out_tokens.tolist()}")


if __name__ == "__main__":
    main()
