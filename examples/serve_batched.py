"""Batched serving example: requests through prefill + lockstep decode.

    PYTHONPATH=src python examples/serve_batched.py --arch granite-3-8b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import REGISTRY, get_config, reduced_config
from repro.models import build_model
from repro.runtime import Request, Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list(REGISTRY))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--backend", default=None,
                    help="repro.api backend for sparse layers "
                         "(pallas|interpret|reference; default: autodetect)")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    server = Server(model, params, batch_slots=3, max_len=128,
                    backend=args.backend)
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab,
                                        int(rng.integers(4, 24)),
                                        dtype=np.int32),
                    max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    server.generate(reqs)
    dt = time.time() - t0
    tok = sum(r.max_new_tokens for r in reqs)
    print(f"{args.arch} (reduced): {len(reqs)} requests, {tok} tokens, "
          f"{dt:.2f}s → {tok/dt:.1f} tok/s")
    for i, r in enumerate(reqs):
        print(f"  req{i}: prompt[{len(r.prompt)}] → {r.out_tokens.tolist()}")


if __name__ == "__main__":
    main()
