"""Scientific-workload demo: the paper's matrix suite through the
SegFold simulator (reproduces the Fig. 8 comparison at demo scale).

    PYTHONPATH=src python examples/spgemm_suite.py
"""
import numpy as np

from repro.sim import matrices
from repro.sim.baselines import flexagon_best, spada
from repro.sim.segfold_sim import SegFoldConfig, simulate_segfold

rows = []
for name, (a, spec) in matrices.suite(scale_cap=1024).items():
    if name == "olm5000":
        continue
    b = a.transpose()
    cfg = SegFoldConfig(cache_bytes=max(int(1.5 * 2**20 * spec.scale), 65536))
    seg = simulate_segfold(a, b, cfg)
    sp = spada(a, b, cfg)
    fb = flexagon_best(a, b, cfg)
    rows.append((name, sp.cycles / seg.cycles, fb["cycles"] / seg.cycles,
                 fb["config"], seg.mean_occupancy))
    print(f"{name:14s} ({spec.family:9s}) vs_spada={rows[-1][1]:5.2f}x "
          f"vs_static={rows[-1][2]:5.2f}x [{fb['config']:4s}] "
          f"PE-occupancy={seg.mean_occupancy:.2f}")
g1 = np.exp(np.mean([np.log(r[1]) for r in rows]))
g2 = np.exp(np.mean([np.log(r[2]) for r in rows]))
print(f"\ngeomean: {g1:.2f}x vs Spada (paper 1.95x), "
      f"{g2:.2f}x vs best static (paper 5.3x)")
