"""Cycle-approximate simulator of the SegFold microarchitecture (§IV-V).

One unified *wave engine* times every accelerator model, so that performance
differences come **only from scheduling/mapping mechanisms** — the same logic
the paper's incremental ablation (Fig. 11) uses.  A *wave* is one scheduling
step across the PE rows; its latency is the max of decoupled pipelines:

``wave = max(compute, multicast, dram, 1)``

* **compute** — per-pair merge cost ``ceil(blen/P) + disp`` (the row shifter
  injects one P-wide vector of a B row per cycle, §IV-C; ``disp`` is the
  merge-network displacement, §III-B), times a *folding serialization factor*
  (active virtual-row footprints beyond the physical array serialize
  sub-waves; without spatial folding, long rows pay per-chunk spad swaps
  instead, §IV-D).
* **multicast** — the vector crossbar issues ``multicast_width`` row-vectors
  per cycle; SELECTA's k-sharing needs few distinct rows per wave, static
  round-robin needs up to R distinct rows (a structural reuse gap).
* **dram** — bytes moved this wave (A stream + B LRU misses + spills +
  phase-separated partial traffic when SEGMENTBC is disabled) over the HBM
  bytes/cycle.

Scheduling modes:

* ``selecta``        — Algorithm 1 (dynamic window, greedy k-sharing,
                       m-conflict avoidance); ``dynamic_k=False`` gives the
                       §VI-C.1 fixed-k ablation.
* ``static_rr``      — MatRaptor/Flexagon-Gustavson-like: R row lanes, each
                       streaming its own A row's pairs in static order.
* ``static_kmajor``  — OuterSPACE/Flexagon-OP-like: k-major cross products;
                       combined with ``segmentbc_enabled=False`` it pays the
                       multiply/merge phase separation (2× partial traffic
                       plus a merge pass).

Mapping modes (§VI-C.2): ``zero`` | ``lut`` (stale IPM) | ``ideal`` (oracle).

The per-pair C-row evolution is tracked exactly (sorted unions) while rows
are small, switching to a uniform-occupancy estimate once rows grow dense
(exact regime covers the SuiteSparse-like suite; the estimate is exact in
expectation for the uniform synthetic matrices of the density sweeps).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.formats import CSC, CSR
from repro.core.selecta import SelectaState


@dataclasses.dataclass
class SegFoldConfig:
    pe_rows: int = 16
    pe_cols: int = 16
    window: int = 32
    multicast_width: int = 4
    mapping: str = "lut"            # zero | lut | ideal
    dynamic_k: bool = True          # False = fixed-k ablation (§VI-C.1)
    spatial_folding: bool = True
    schedule_mode: str = "selecta"  # selecta | static_rr | static_kmajor
    segmentbc_enabled: bool = True  # False = phase-separated partials (OP)
    element_bytes: int = 8          # value + index
    cache_bytes: int = int(1.5 * 1024 * 1024)
    dram_bytes_per_cycle: int = 256  # HBM2 @2Gbps, 1 GHz core
    dram_latency: int = 96          # cycles; hidden by window prefetch lead
    lut_write_ports: int = 1
    exact_row_limit: int = 1024     # switch to occupancy estimate beyond this
    swap_cost: int = 2              # spad chunk-swap cycles (no-folding mode)
    spad_factor: int = 4            # per-row spad capacity in PE-row widths
    tail_cap: Optional[int] = None  # cap per-pair spad-tail cost (Spada-like
                                    # multi-lane row splitting); None = uncapped
    vector_injection: bool = True   # SegFold row shifter injects P-wide
                                    # vectors (§IV-C); scalar comparator-queue
                                    # designs (MatRaptor/Flexagon) stream one
                                    # element per lane per cycle

    @property
    def r_max(self) -> int:
        return self.pe_rows


@dataclasses.dataclass
class SimResult:
    cycles: float
    macs: int
    dram_bytes: float
    batches: int
    compute_cycles: float
    multicast_cycles: float
    dram_cycles: float
    spill_elements: int
    mean_occupancy: float
    mean_displacement: float

    @property
    def cycles_per_mac(self) -> float:
        return self.cycles / max(self.macs, 1)


class _LRUCache:
    """Fully-associative LRU byte cache (B rows)."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.used = 0
        self.entries: "OrderedDict[int, int]" = OrderedDict()

    def access(self, key: int, nbytes: int) -> bool:
        if key in self.entries:
            self.entries.move_to_end(key)
            return True
        while self.used + nbytes > self.capacity and self.entries:
            _, sz = self.entries.popitem(last=False)
            self.used -= sz
        if nbytes <= self.capacity:
            self.entries[key] = nbytes
            self.used += nbytes
        return False


class _CRowTracker:
    """Evolving C-row occupancy: exact sorted sets → estimate when large."""

    def __init__(self, n_cols: int, exact_limit: int):
        self.n = n_cols
        self.exact_limit = exact_limit
        self.exact: Dict[int, np.ndarray] = {}
        self.approx_len: Dict[int, float] = {}

    def merge(self, m: int, b_cols: np.ndarray) -> dict:
        blen = int(b_cols.size)
        if blen == 0:
            return dict(inserts=0, rank_first=0, rank_last=0, c_len=int(self.length(m)))
        if m in self.approx_len or blen + self.length(m) > self.exact_limit:
            c_len = self.approx_len.pop(m, None)
            if c_len is None:
                c_len = float(len(self.exact.pop(m, np.zeros(0))))
            overlap = min(c_len * blen / self.n, float(min(c_len, blen)))
            inserts = blen - overlap
            new_len = min(c_len + inserts, float(self.n))
            self.approx_len[m] = new_len
            return dict(inserts=int(round(inserts)),
                        rank_first=int(float(b_cols[0]) / self.n * new_len),
                        rank_last=int(float(b_cols[-1]) / self.n * new_len),
                        c_len=int(new_len))
        cur = self.exact.get(m)
        if cur is None:
            self.exact[m] = np.asarray(b_cols, dtype=np.int64)
            return dict(inserts=blen, rank_first=0, rank_last=blen - 1, c_len=blen)
        union = np.union1d(cur, b_cols)
        res = dict(inserts=int(union.size - cur.size),
                   rank_first=int(np.searchsorted(union, b_cols[0])),
                   rank_last=int(np.searchsorted(union, b_cols[-1])),
                   c_len=int(union.size))
        self.exact[m] = union
        return res

    def length(self, m: int) -> float:
        if m in self.approx_len:
            return self.approx_len[m]
        if m in self.exact:
            return float(self.exact[m].size)
        return 0.0

    def total_nnz(self) -> int:
        return int(sum(a.size for a in self.exact.values())
                   + sum(self.approx_len.values()))


# ---------------------------------------------------------------------------
# batch generators (the scheduling mechanisms)
# ---------------------------------------------------------------------------


def _selecta_batches(st: SelectaState) -> Iterable[List[Tuple[int, int]]]:
    guard, limit = 0, 10 * (st.a.nnz + st.a.shape[1] + 1)
    while not st.done:
        yield st.select()
        guard += 1
        if guard > limit:  # pragma: no cover
            raise RuntimeError("SELECTA stalled")


def _static_rr_batches(a: CSR, k_active: np.ndarray, r_max: int):
    """R row lanes, each streaming its own A row's pairs in static order."""
    queues: List[List[int]] = []
    rows = [m for m in range(a.shape[0]) if a.indptr[m + 1] > a.indptr[m]]
    next_row = 0
    lanes: List[Optional[Tuple[int, List[int]]]] = [None] * r_max
    while True:
        batch = []
        for i in range(r_max):
            if lanes[i] is None and next_row < len(rows):
                m = rows[next_row]
                next_row += 1
                ks = [int(k) for k in a.indices[a.indptr[m]:a.indptr[m + 1]]
                      if k_active[int(k)]]
                lanes[i] = (m, ks)
            if lanes[i] is not None:
                m, ks = lanes[i]
                if ks:
                    batch.append((m, ks.pop(0)))
                if not ks:
                    lanes[i] = None
        if not batch:
            if next_row >= len(rows) and all(l is None for l in lanes):
                return
            continue
        yield batch


def _static_kmajor_batches(a: CSR, k_active: np.ndarray, r_max: int):
    """k-major static order (outer-product-like): chunk each column's rows."""
    a_csc = CSC.from_csr(a)
    for k in range(a_csc.shape[1]):
        if not k_active[k]:
            continue
        rows, _ = a_csc.col(k)
        for i in range(0, rows.size, r_max):
            yield [(int(m), k) for m in rows[i:i + r_max]]


# ---------------------------------------------------------------------------
# the wave engine
# ---------------------------------------------------------------------------


def estimate_n_tiles(a: CSR, b: CSR, cfg: SegFoldConfig) -> int:
    """Static N-tiling choice (§V Tiling): tile C so the *expected* virtual
    row roughly fits the physical PE row (spad is the safety margin). Long-
    tail rows still overflow — exactly the spills the paper calls infrequent.
    Tiling costs an A re-stream per tile, which the engine charges."""
    import scipy.sparse as sp
    A = sp.csr_matrix((np.ones_like(a.data, np.int8), a.indices, a.indptr), shape=a.shape)
    B = sp.csr_matrix((np.ones_like(b.data, np.int8), b.indices, b.indptr), shape=b.shape)
    C = A @ B
    lens = np.diff(C.tocsr().indptr)
    lens = lens[lens > 0]
    if lens.size == 0:
        return 1
    cap = cfg.pe_cols * 2
    return max(1, int(np.ceil(float(lens.mean()) / cap)))


class _WaveEngine:
    """Shared cost semantics for one SpGEMM execution."""

    def __init__(self, b: CSR, cfg: SegFoldConfig, n_tiles: int = 1,
                 entry_batch: Optional[Dict[int, int]] = None):
        self.b = b
        self.cfg = cfg
        self.n_tiles = max(1, n_tiles)
        self.b_lens = b.row_lengths()
        self.cache = _LRUCache(cfg.cache_bytes)
        self.tracker = _CRowTracker(b.shape[1], cfg.exact_row_limit)
        self.pending_lut: Dict[int, int] = {}
        # DRAM-latency model (Little's law): with `window` outstanding B-row
        # prefetch slots and `dram_latency` cycles per fetch, sustained new-
        # row throughput is window/dram_latency rows per cycle. The active
        # window is SegFold's outstanding-request structure (§III-A k-level
        # pipelining); static dataflows get an equal-depth stream prefetcher
        # (same memory system, §V).
        self.prefetch_depth = max(1, cfg.window)
        self.entry_batch = entry_batch  # retained for telemetry
        # telemetry
        self.cycles = 0.0
        self.macs = 0
        self.dram_bytes = 0.0
        self.batches = 0
        self.sum_compute = 0.0
        self.sum_mc = 0.0
        self.sum_dram = 0.0
        self.spills = 0
        self.occ_acc = 0.0
        self.disp_acc = 0.0
        self.disp_cnt = 0

    def wave(self, batch: List[Tuple[int, int]]) -> float:
        cfg, eb = self.cfg, self.cfg.element_bytes
        P = cfg.pe_cols
        self.batches += 1
        # ---- multicast ----
        ks = sorted({k for _, k in batch})
        lens = [int(self.b_lens[k]) for k in ks]
        total_vectors = sum((ln + P - 1) // P for ln in lens)
        mc_cycles = (total_vectors + cfg.multicast_width - 1) // cfg.multicast_width
        # ---- memory: A stream (once per N-tile pass) + B rows through LRU ----
        batch_bytes = len(batch) * eb * self.n_tiles
        new_rows = 0
        for k, ln in zip(ks, lens):
            if ln and not self.cache.access(k, ln * eb):
                batch_bytes += ln * eb
                new_rows += 1
        # ---- per-pair merge/compute ----
        pair_cycles = []
        tails = []          # spad-tail serialization per pair (beyond array)
        spad_cap = P * cfg.spad_factor
        for (m, k) in batch:
            b_cols = self.b.indices[self.b.indptr[k]:self.b.indptr[k + 1]]
            info = self.tracker.merge(m, np.asarray(b_cols, dtype=np.int64))
            blen = int(b_cols.size)
            self.macs += blen
            if cfg.segmentbc_enabled:
                if cfg.mapping == "zero":
                    disp = info["rank_first"]
                elif cfg.mapping == "ideal":
                    disp = 0
                else:  # stale LUT
                    disp = min(self.pending_lut.get(m, 0), info["c_len"])
                self.pending_lut[m] = info["inserts"]
            else:
                disp = 0
                batch_bytes += 2 * blen * eb  # phase-separated partials
            # N-tiling (§V) bounds the virtual row width seen per tile
            c_len = max(1, info["c_len"] // self.n_tiles)
            disp = disp // self.n_tiles
            blen_t = max(1, blen // self.n_tiles)  # per-tile B row slice
            if cfg.vector_injection:
                cyc = (blen_t + P - 1) // P + (disp + P - 1) // P
            else:
                cyc = blen_t + disp   # scalar comparator-queue stream
            # elements landing beyond the physical row need the per-row spad
            # (one port → serialized access), unless spatial folding placed
            # them on a free neighbor PE row (handled at batch level below)
            if c_len > P:
                frac_beyond = 1.0 - P / c_len
                tail = int(round(blen_t * frac_beyond))
                if cfg.tail_cap is not None:
                    tail = min(tail, cfg.tail_cap)
                tails.append(tail)
                if c_len > spad_cap:
                    # true overflow: partials round-trip DRAM
                    over = int(round(info["inserts"] / self.n_tiles
                                     * (1.0 - spad_cap / c_len)))
                    batch_bytes += 2 * over * eb
                    self.spills += over
            else:
                tails.append(0)
            pair_cycles.append(cyc)
            self.disp_acc += disp
            self.disp_cnt += 1
        if cfg.mapping == "lut":
            for m in list(self.pending_lut):
                self.pending_lut[m] = max(0, self.pending_lut[m] - cfg.lut_write_ports)
                if self.pending_lut[m] == 0:
                    del self.pending_lut[m]
        # spatial folding: free PE rows absorb the largest tails in parallel
        if cfg.spatial_folding:
            free = cfg.pe_rows - len(batch)
            if free > 0 and tails:
                for i in np.argsort(tails)[::-1][:free]:
                    tails[i] = 0
        compute = max((pc + t) for pc, t in zip(pair_cycles, tails)) if batch else 0
        dram_cyc = batch_bytes / cfg.dram_bytes_per_cycle
        # DRAM-latency throughput bound (Little's law over prefetch slots).
        # The coalescing unit (§IV-B) merges fine-grain row requests into
        # cache-line fetches, giving each window slot ~4 lines in flight.
        lat_cyc = new_rows * cfg.dram_latency / (self.prefetch_depth * 4)
        wave = max(compute, mc_cycles, dram_cyc, lat_cyc, 1.0)
        self.cycles += wave
        self.dram_bytes += batch_bytes
        self.sum_compute += compute
        self.sum_mc += mc_cycles
        self.sum_dram += dram_cyc
        self.occ_acc += len(batch) / cfg.r_max
        return wave

    def finish(self, merge_pass: bool = False) -> SimResult:
        cfg, eb = self.cfg, self.cfg.element_bytes
        c_nnz = self.tracker.total_nnz()
        wb = c_nnz * eb
        self.dram_bytes += wb
        self.cycles += wb / cfg.dram_bytes_per_cycle
        if merge_pass:
            # phase-separated designs re-read all partials and merge them
            t_bytes = self.macs * eb
            self.dram_bytes += t_bytes
            self.cycles += max(self.macs / cfg.pe_rows,
                               t_bytes / cfg.dram_bytes_per_cycle)
        return SimResult(
            cycles=float(self.cycles), macs=int(self.macs),
            dram_bytes=float(self.dram_bytes), batches=self.batches,
            compute_cycles=float(self.sum_compute),
            multicast_cycles=float(self.sum_mc),
            dram_cycles=float(self.sum_dram), spill_elements=int(self.spills),
            mean_occupancy=self.occ_acc / max(self.batches, 1),
            mean_displacement=self.disp_acc / max(self.disp_cnt, 1),
        )


def simulate_segfold(a: CSR, b: CSR, cfg: Optional[SegFoldConfig] = None) -> SimResult:
    """Simulate SpGEMM C = A @ B; scheduling per ``cfg.schedule_mode``."""
    cfg = cfg or SegFoldConfig()
    b_lens = b.row_lengths()
    k_active = b_lens > 0
    entry_batch = None
    if cfg.schedule_mode == "selecta":
        st = SelectaState(a=CSC.from_csr(a), w_max=cfg.window, r_max=cfg.r_max,
                          dynamic_k=cfg.dynamic_k, k_active=k_active)
        batches = _selecta_batches(st)
        entry_batch = st.entry_batch   # live dict: filled as the window slides
    elif cfg.schedule_mode == "static_rr":
        batches = _static_rr_batches(a, k_active, cfg.r_max)
    elif cfg.schedule_mode == "static_kmajor":
        batches = _static_kmajor_batches(a, k_active, cfg.r_max)
    else:
        raise ValueError(cfg.schedule_mode)
    eng = _WaveEngine(b, cfg, n_tiles=estimate_n_tiles(a, b, cfg),
                      entry_batch=entry_batch)
    for batch in batches:
        if batch:
            eng.wave(batch)
    return eng.finish(merge_pass=not cfg.segmentbc_enabled)
