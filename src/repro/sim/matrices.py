"""Synthetic stand-ins for the paper's SuiteSparse evaluation matrices.

The container is offline, so the 15 Table-III matrices are replaced by
pattern-matched synthetic generators with the same aspect ratio, density and
structural family (banded/stencil, planar mesh, power-law graph, power
network, LP/combinatorial).  Dimensions are scaled down by ``SCALE`` (default
keeps max dim ≈ 2048) so the full figure suite runs on one CPU core; density
and pattern statistics are preserved, which is what the dataflow comparison
is sensitive to.  Every substitution is recorded in ``describe()``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import numpy as np

from repro.core.formats import CSR, csr_from_coo


def banded(rng, m, n, density, spread=0.02) -> CSR:
    """Stencil/CFD-like: entries concentrated near the diagonal."""
    nnz = max(1, int(density * m * n))
    rows = rng.integers(0, m, size=nnz)
    # diagonal position + gaussian spread
    diag = rows * (n / m)
    cols = np.clip(np.round(diag + rng.normal(0, max(spread * n, 1.5), size=nnz)), 0, n - 1)
    return csr_from_coo((m, n), rows, cols.astype(np.int64),
                        rng.standard_normal(nnz).astype(np.float32))


def mesh2d(rng, m, n, density) -> CSR:
    """Planar-mesh graph (delaunay-like): ~constant degree, local links."""
    side = int(np.sqrt(m))
    deg = max(2, int(density * n))
    rows, cols = [], []
    for r in range(m):
        x, y = r % side, r // side
        for _ in range(deg):
            dx, dy = rng.integers(-2, 3), rng.integers(-2, 3)
            c = (x + dx) % side + ((y + dy) % side) * side
            if c < n:
                rows.append(r)
                cols.append(c)
    nnz = len(rows)
    return csr_from_coo((m, n), np.asarray(rows), np.asarray(cols),
                        rng.standard_normal(nnz).astype(np.float32))


def powerlaw(rng, m, n, density, alpha=1.8) -> CSR:
    """Scale-free graph (ca-GrQc-like): few very dense rows/cols."""
    target = max(1, int(density * m * n))
    pr = (np.arange(1, m + 1, dtype=np.float64)) ** (-alpha)
    pr /= pr.sum()
    pc = (np.arange(1, n + 1, dtype=np.float64)) ** (-alpha)
    pc /= pc.sum()
    seen = set()
    rows, cols = [], []
    # top-up sampling: head pairs collide heavily under Zipf, so draw in
    # rounds until the target nnz is reached (bounded rounds)
    for _ in range(12):
        need = target - len(seen)
        if need <= 0:
            break
        rs = rng.choice(m, size=2 * need, p=pr)
        cs = rng.choice(n, size=2 * need, p=pc)
        for r, c in zip(rs, cs):
            key = int(r) * n + int(c)
            if key not in seen:
                seen.add(key)
                rows.append(int(r))
                cols.append(int(c))
                if len(seen) >= target:
                    break
    perm_r = rng.permutation(m)
    perm_c = rng.permutation(n)
    rows = perm_r[np.asarray(rows)]
    cols = perm_c[np.asarray(cols)]
    return csr_from_coo((m, n), rows, cols,
                        rng.standard_normal(rows.size).astype(np.float32))


def powernet(rng, m, n, density) -> CSR:
    """Power-network-like: banded backbone + a few hub rows."""
    base = banded(rng, m, n, density * 0.8, spread=0.01)
    hub_nnz = max(1, int(density * m * n * 0.2))
    hubs = rng.choice(m, size=max(1, m // 200), replace=False)
    rows = rng.choice(hubs, size=hub_nnz)
    cols = rng.integers(0, n, size=hub_nnz)
    all_rows = np.concatenate([np.repeat(np.arange(m), base.row_lengths()), rows])
    all_cols = np.concatenate([base.indices.astype(np.int64), cols])
    all_vals = np.concatenate([base.data, rng.standard_normal(hub_nnz).astype(np.float32)])
    return csr_from_coo((m, n), all_rows, all_cols, all_vals)


def uniform(rng, m, n, density) -> CSR:
    """LP / combinatorial-like: near-uniform random pattern."""
    nnz = max(1, int(density * m * n))
    flat = rng.choice(m * n, size=min(nnz, m * n), replace=False)
    return csr_from_coo((m, n), flat // n, flat % n,
                        rng.standard_normal(flat.size).astype(np.float32))


def blockrand(rng, m, n, density, blocks=16) -> CSR:
    """Combinatorial block structure (Franz-like): dense-ish random blocks."""
    bm, bn = max(1, m // blocks), max(1, n // blocks)
    n_active = max(1, int(density * blocks * blocks * 6))
    rows, cols = [], []
    for _ in range(n_active):
        br, bc = rng.integers(blocks), rng.integers(blocks)
        cnt = max(1, int(density * m * n / n_active))
        rows.append(br * bm + rng.integers(0, bm, size=cnt))
        cols.append(bc * bn + rng.integers(0, bn, size=cnt))
    rows = np.clip(np.concatenate(rows), 0, m - 1)
    cols = np.clip(np.concatenate(cols), 0, n - 1)
    return csr_from_coo((m, n), rows, cols,
                        rng.standard_normal(rows.size).astype(np.float32))


@dataclasses.dataclass
class MatrixSpec:
    name: str
    m: int
    n: int
    density: float
    family: str
    generator: Callable
    domain: str
    scale: float = 1.0   # linear scale-down vs the original SuiteSparse matrix


# Table III of the paper, with the original (M, N, density) recorded.
_TABLE_III = [
    ("fv1",          9604, 9064, 9.79e-4, "banded",   banded,   "2D/3D problem"),
    ("flowmeter0",   9669, 9669, 7.21e-4, "banded",   banded,   "Model reduction"),
    ("delaunay_n13", 8192, 8192, 7.32e-4, "mesh",     mesh2d,   "Undirected graph"),
    ("ca-GrQc",      5242, 5242, 1.05e-3, "powerlaw", powerlaw, "Undirected graph"),
    ("ca-CondMat",  23133, 23133, 3.49e-4, "powerlaw", powerlaw, "Undirected graph"),
    ("poisson3Da",  13514, 13514, 1.93e-3, "banded",   banded,   "CFD"),
    ("bcspwr06",     1454, 1454, 2.51e-3, "powernet", powernet, "Power network"),
    ("tols4000",     4000, 4000, 5.49e-4, "banded",   banded,   "CFD"),
    ("rdb5000",      5000, 5000, 1.18e-3, "banded",   banded,   "CFD"),
    ("gemat1",       4929, 10595, 8.92e-4, "powernet", powernet, "Power network"),
    ("lp_woodw",     1098, 8418, 4.06e-3, "uniform",  uniform,  "Linear programming"),
    ("pcb3000",      3960, 7732, 1.88e-3, "uniform",  uniform,  "Circuit simulation"),
    ("Franz6",       7576, 3016, 1.99e-3, "block",    blockrand, "Combinatorial"),
    ("Franz8",      16728, 7176, 8.36e-4, "block",    blockrand, "Combinatorial"),
    ("psse1",       14318, 11028, 3.63e-4, "powernet", powernet, "Power network"),
]

# additional matrices referenced by the ablation figures
_ABLATION_EXTRA = [
    ("olm5000", 5000, 5000, 7.9e-4, "banded", banded, "Model reduction"),
]

MAX_DIM = 2048   # scaled-down stand-in size cap (documented deviation)


def suite(scale_cap: int = MAX_DIM, seed: int = 7) -> Dict[str, Tuple[CSR, MatrixSpec]]:
    """Generate the 15-matrix benchmark suite (+ ablation extras).

    When a matrix is scaled below its original dimensions, the *density is
    scaled up* so the mean nonzeros-per-row (the quantity the dataflow
    comparison is sensitive to: B-row lengths, intersection sizes, merge
    widths) is preserved; total nnz then scales linearly with the dimension.
    The harness scales the on-chip cache by the same linear factor so the
    cache-to-working-set ratio matches the original experiment.
    """
    out = {}
    for name, m, n, density, family, gen, domain in _TABLE_III + _ABLATION_EXTRA:
        rng = np.random.default_rng(abs(hash((name, seed))) % (2 ** 31))
        s = min(1.0, scale_cap / max(m, n))
        ms, ns = max(128, int(m * s)), max(128, int(n * s))
        d_scaled = min(density * (n / ns), 0.5)  # preserve nnz-per-row
        mat = gen(rng, ms, ns, d_scaled)
        out[name] = (mat, MatrixSpec(name, ms, ns, d_scaled, family, gen, domain,
                                     scale=s))
    return out


def describe() -> str:
    lines = ["matrix,orig_M,orig_N,density,family,domain (synthetic stand-ins)"]
    for name, m, n, density, family, _, domain in _TABLE_III:
        lines.append(f"{name},{m},{n},{density:.2e},{family},{domain}")
    return "\n".join(lines)


def synthetic(rng, n: int, density: float) -> CSR:
    """Square uniform synthetic matrix (sensitivity studies, Figs. 12-14)."""
    return uniform(rng, n, n, density)
