"""Baseline accelerator models on the shared wave engine (§V Methodology).

Every baseline runs on the *same* :class:`~repro.sim.segfold_sim._WaveEngine`
timing machinery as SegFold, with its scheduling/mapping mechanisms swapped —
so performance differences are attributable purely to dataflow mechanisms
(the logic of the paper's Fig. 11 incremental ablation):

* ``flexagon_gust`` — MatRaptor/Flexagon-Gustavson: independent row lanes in
  static order (``static_rr``), zero-offset merge starts (no IPM), no
  folding (long C rows pay spad chunk swaps).  Generous distribution network
  (16 row-vectors/cycle, matching the paper's 128-elem/cycle scaling).
* ``flexagon_op``   — OuterSPACE/Flexagon-OP: k-major static cross products
  with multiply/merge **phase separation**: every partial is written to and
  re-read from the intermediate store, plus a final merge pass.
* ``flexagon_ip``   — ExTensor-like inner product, analytical: streams both
  fibers for every candidate output (control-dominated at low density).
* ``spada``         — window-adaptive Gustavson: k-synchronous waves inside
  row windows (all lanes process the same k → perfect in-window B reuse,
  exactly Spada's window dataflow), window height adapted per tile,
  neighbor-lane stealing compresses the tail, but the in-window k order is
  static, so sparse column slices yield low-occupancy waves — the sub-tile
  opportunity SegFold's SELECTA exploits.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.formats import CSR
from .segfold_sim import (SegFoldConfig, SimResult, _WaveEngine,
                          simulate_segfold)


def _k_synchronous_run(a: CSR, b: CSR, run: SegFoldConfig,
                       window_candidates, adapt: bool, steal: bool) -> SimResult:
    """Tiled Gustavson executed as k-synchronous waves.

    All lanes in a row-window process the same k each wave (the windowed /
    tiled loop structure of Spada and of Flexagon's per-tile static
    dataflows).  Sparse column slices therefore yield low-occupancy waves —
    this static loop overhead is exactly what SELECTA's dynamic work
    selection removes.
    """
    from .segfold_sim import estimate_n_tiles
    eng = _WaveEngine(b, run, n_tiles=estimate_n_tiles(a, b, run))
    b_lens = b.row_lengths()
    k_active = b_lens > 0
    m_dim = a.shape[0]
    lanes = run.pe_rows
    r = 0
    while r < m_dim:
        if adapt:
            best_h, best_score = window_candidates[0], None
            for h in window_candidates:
                hi = min(r + h, m_dim)
                ks = a.indices[a.indptr[r]:a.indptr[hi]]
                ks = ks[k_active[ks]]
                if ks.size == 0:
                    score = 0.0
                else:
                    distinct = np.unique(ks).size
                    groups = max(1, (hi - r + lanes - 1) // lanes)
                    score = distinct * groups / max(hi - r, 1)
                if best_score is None or score < best_score:
                    best_score, best_h = score, h
            h = best_h
        else:
            h = lanes
        hi = min(r + h, m_dim)
        for g in range(r, hi, lanes):
            ghi = min(g + lanes, hi)
            cols = {}
            for m in range(g, ghi):
                for k in a.indices[a.indptr[m]:a.indptr[m + 1]]:
                    k = int(k)
                    if k_active[k]:
                        cols.setdefault(k, []).append(m)
            for k in sorted(cols):   # static in-window k order
                batch = [(m, k) for m in cols[k]]
                if steal and len(batch) <= lanes // 2:
                    # neighbor-lane stealing: idle lanes split the busiest
                    # rows' elements → wave cost halves (bounded by 2×)
                    before = eng.cycles
                    eng.wave(batch)
                    eng.cycles = before + max((eng.cycles - before) / 2.0, 1.0)
                else:
                    eng.wave(batch)
        r = hi
    return eng.finish()


def flexagon_gust(a: CSR, b: CSR, cfg: Optional[SegFoldConfig] = None) -> SimResult:
    base = cfg or SegFoldConfig()
    run = dataclasses.replace(
        base, schedule_mode="static_rr", mapping="zero",
        spatial_folding=False, multicast_width=16, segmentbc_enabled=True,
        vector_injection=False)  # scalar comparator-queue lanes
    return _k_synchronous_run(a, b, run, (16,), adapt=False, steal=False)


def flexagon_op(a: CSR, b: CSR, cfg: Optional[SegFoldConfig] = None) -> SimResult:
    base = cfg or SegFoldConfig()
    run = dataclasses.replace(
        base, schedule_mode="static_kmajor", mapping="ideal",
        spatial_folding=False, swap_cost=0, multicast_width=16,
        segmentbc_enabled=False, tail_cap=0,  # no in-place merge: partials
        vector_injection=False)               # pay 2× traffic + merge pass
    return simulate_segfold(a, b, run)


def flexagon_ip(a: CSR, b: CSR, cfg: Optional[SegFoldConfig] = None) -> SimResult:
    """Analytical inner product: streams both fibers per candidate output."""
    base = cfg or SegFoldConfig()
    eb = base.element_bytes
    pes = base.pe_rows * base.pe_cols
    a_lens = np.diff(a.indptr).astype(np.int64)
    bt = b.transpose()
    b_col_lens = np.diff(bt.indptr).astype(np.int64)
    nonempty_rows = int((a_lens > 0).sum())
    nonempty_cols = int((b_col_lens > 0).sum())
    stream = float(a_lens.sum()) * nonempty_cols + float(b_col_lens.sum()) * nonempty_rows
    compute = stream / pes
    import scipy.sparse as sp
    A = sp.csr_matrix((np.ones_like(a.data, np.int8), a.indices, a.indptr), shape=a.shape)
    B = sp.csr_matrix((np.ones_like(b.data, np.int8), b.indices, b.indptr), shape=b.shape)
    macs = int((A @ np.diff(b.indptr).reshape(-1, 1)).sum())
    c_nnz = int((A @ B).nnz)
    b_bytes_once = b.nnz * eb
    if b_bytes_once <= base.cache_bytes:
        b_traffic = b_bytes_once
    else:
        b_traffic = float(b_col_lens.sum()) * nonempty_rows * eb
    dram_bytes = a.nnz * eb + b_traffic + c_nnz * eb
    dram = dram_bytes / base.dram_bytes_per_cycle
    cycles = max(compute, dram)
    return SimResult(cycles=float(cycles), macs=macs, dram_bytes=float(dram_bytes),
                     batches=0, compute_cycles=float(compute),
                     multicast_cycles=0.0, dram_cycles=float(dram),
                     spill_elements=0, mean_occupancy=0.0, mean_displacement=0.0)


def flexagon_best(a: CSR, b: CSR, cfg: Optional[SegFoldConfig] = None) -> dict:
    """Best static configuration per matrix (Fig. 8's strongest baseline)."""
    results = {
        "ip": flexagon_ip(a, b, cfg),
        "op": flexagon_op(a, b, cfg),
        "gust": flexagon_gust(a, b, cfg),
    }
    best = min(results, key=lambda k: results[k].cycles)
    return dict(result=results[best], config=best,
                all={k: v.cycles for k, v in results.items()},
                cycles=results[best].cycles, macs=results[best].macs)


def spada(a: CSR, b: CSR, cfg: Optional[SegFoldConfig] = None,
          window_candidates=(8, 16, 32, 64), steal: bool = True) -> SimResult:
    """Window-adaptive Gustavson with k-synchronous in-window waves."""
    base = cfg or SegFoldConfig()
    run = dataclasses.replace(
        base, schedule_mode="static_rr", mapping="ideal",
        spatial_folding=False, swap_cost=0, multicast_width=16,
        tail_cap=base.pe_cols)  # tile-level adaptation splits dense rows
    return _k_synchronous_run(a, b, run, window_candidates, adapt=True,
                              steal=steal)


# ---------------------------------------------------------------------------
# closed-form dataflow traffic estimates over a BSR block pattern
# ---------------------------------------------------------------------------


def _inner_product_estimate(kind: str, *, bm: int, bk: int,
                            n_cols: Optional[int] = None,
                            bn: Optional[int] = None,
                            bytes_per_el: int = 4, **coords) -> dict:
    """ExTensor-like inner-product traffic over a block pattern.

    Inner product enumerates candidate outputs and streams both operand
    fibers per output with no inter-item operand reuse: every work item
    re-fetches its A block and B stripe, and each output tile is written
    exactly once.  This is a lower bound on what a real inner-product
    machine moves (intersection misses would add fiber traffic), yet it is
    already never below Gustavson's adjacency-reuse counts — which is the
    point: it exists as a comparison dataflow for the tuner's scoring, not
    as a dispatch target (no registered policy executes it)."""
    if kind == "spmm":
        m = np.asarray(coords["m"])
        items = int(m.size)
        n = 1 if n_cols is None else int(n_cols)
        a_bytes = items * bm * bk * bytes_per_el
        b_bytes = items * bk * n * bytes_per_el
        n_out = int(np.unique(m).size)
        c_bytes = n_out * bm * n * bytes_per_el
    elif kind == "spgemm":
        c = np.asarray(coords["c"])
        items = int(c.size)
        bn_eff = bk if bn is None else int(bn)
        a_bytes = items * bm * bk * bytes_per_el
        b_bytes = items * bk * bn_eff * bytes_per_el
        n_out = int(np.unique(c).size)
        c_bytes = n_out * bm * bn_eff * bytes_per_el
    else:
        raise ValueError(f"unknown kind {kind!r}")
    return dict(a_bytes=a_bytes, b_bytes=b_bytes, c_bytes=c_bytes,
                total=a_bytes + b_bytes + c_bytes,
                a_fetches=items, b_fetches=items, c_segments=n_out)


def dataflow_estimates(kind: str, *, bm: int, bk: int,
                       n_cols: Optional[int] = None,
                       bn: Optional[int] = None,
                       bytes_per_el: int = 4, **coords) -> dict:
    """Closed-form traffic estimates per dataflow for one block pattern.

    Walks the policy registry and calls each policy's ``cost_hint`` (see
    :class:`repro.core.policies.SchedulePolicy`) — exact revisiting-model
    counts for the static orders that carry one (``gustavson``, ``outer``)
    — then adds the analytic ``"inner"`` inner-product estimate, which has
    no registered policy and exists for comparison only.  Policies without
    a hint (``segment``: its order *is* the schedule) are skipped; the
    tuner scores those by building the schedule.

    ``coords`` carries the pattern: ``m``/``k`` block coordinates for
    ``kind="spmm"``; ``m``/``n``/``k``/``c``/``a_idx``/``b_idx`` for
    ``kind="spgemm"``.  Returns ``{name: traffic_dict}`` with
    :func:`repro.core.schedule.lane_traffic_spmm`-shaped dicts priced at
    default knobs (one lane, pipelined), so entries are directly comparable
    with each other and with a built plan's recorded traffic."""
    from repro.core.policies import available_policies, get_policy
    tiles = dict(bm=bm, bk=bk, bytes_per_el=bytes_per_el)
    if kind == "spmm":
        tiles["n_cols"] = 1 if n_cols is None else int(n_cols)
    else:
        tiles["bn"] = bk if bn is None else int(bn)
    out = {}
    for name in available_policies():
        hint = get_policy(name).cost_hint
        if hint is None:
            continue
        est = hint(kind, **coords, **tiles)
        if est is not None:
            out[name] = est
    out["inner"] = _inner_product_estimate(
        kind, bm=bm, bk=bk, n_cols=n_cols, bn=bn,
        bytes_per_el=bytes_per_el, **coords)
    return out
