"""Cycle-approximate evaluation substrate for the paper's figures."""
from .segfold_sim import SegFoldConfig, SimResult, simulate_segfold
from .baselines import (dataflow_estimates, flexagon_best, flexagon_gust,
                        flexagon_ip, flexagon_op, spada)
from . import matrices

ACCELERATORS = {
    "flexagon_ip": flexagon_ip,
    "flexagon_op": flexagon_op,
    "flexagon_gust": flexagon_gust,
    "spada": spada,
}

__all__ = [
    "SegFoldConfig", "SimResult", "simulate_segfold",
    "ACCELERATORS", "dataflow_estimates", "flexagon_best", "flexagon_gust",
    "flexagon_ip", "flexagon_op", "spada", "matrices",
]
