"""SELECTA — dynamic (m, k) selection over an active window (paper Alg. 1).

This is the *element-granularity* faithful implementation used by the
simulator and the reference Segment dataflow.  The TPU block-granularity
adaptation lives in :mod:`repro.core.schedule`.

The selector keeps a sliding window of up to ``w_max`` K-columns of A.  Each
invocation returns up to ``r_max`` (m, k) pairs such that:

* pairs greedily share the same ``k`` (maximizes reuse of the B row ``k``),
* no two pairs share the same ``m`` (avoids C-row reduction conflicts),
* exhausted ``k`` columns retire from the window and new ones slide in
  (inter-tile reordering / k-level pipelining).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from .formats import CSC


@dataclasses.dataclass
class SelectaState:
    """Mutable scheduler state: consumption bitmask + window membership."""

    a: CSC
    w_max: int
    r_max: int
    dynamic_k: bool = True           # False => fixed k order (§VI-C.1 ablation)
    k_active: Optional[np.ndarray] = None  # bool mask: B row k non-empty
    next_k: int = 0                  # next column to slide into the window
    window: List[int] = dataclasses.field(default_factory=list)
    # per-column cursor into the remaining (unconsumed) row ids
    remaining: dict = dataclasses.field(default_factory=dict)
    # batch index at which each k entered the window (prefetch lead time —
    # the simulator uses it to model DRAM-latency hiding, §III-A inter-tile
    # reordering / k-level pipelining)
    entry_batch: dict = dataclasses.field(default_factory=dict)
    batch_idx: int = 0

    def __post_init__(self):
        self._refill()

    # -- window management ---------------------------------------------------
    def _refill(self) -> None:
        k_dim = self.a.shape[1]
        while len(self.window) < self.w_max and self.next_k < k_dim:
            k = self.next_k
            self.next_k += 1
            if self.k_active is not None and not self.k_active[k]:
                continue  # intersection filter: B row k is empty (§IV-B)
            rows, _ = self.a.col(k)
            if rows.size == 0:
                continue  # DCSR-style O(1) skip of empty columns
            self.window.append(k)
            # (row-id array, cursor, deferred-conflict list) — O(taken) scans
            self.remaining[k] = [rows.astype(np.int64), 0, []]
            self.entry_batch[k] = self.batch_idx

    def _col_remaining(self, k: int) -> int:
        arr, pos, deferred = self.remaining[k]
        return (arr.size - pos) + len(deferred)

    @property
    def done(self) -> bool:
        return not self.window and self.next_k >= self.a.shape[1]

    # -- one SELECTA invocation ----------------------------------------------
    def select(self) -> List[Tuple[int, int]]:
        """Return up to ``r_max`` (m, k) pairs per Algorithm 1."""
        selected: List[Tuple[int, int]] = []
        used_m = set()
        self.batch_idx += 1

        if self.dynamic_k:
            # Greedy: visit window columns in order of most remaining work so
            # the batch concentrates on few k (max B-row reuse).
            order = sorted(self.window, key=self._col_remaining, reverse=True)
        else:
            # §VI-C.1 ablation: ks processed in a predetermined sequence —
            # the batch draws only from the oldest live k (a "constrained
            # outer-product scheme"), forgoing cross-k batch packing.
            order = list(self.window[:1])

        for k in order:
            if len(selected) >= self.r_max:
                break
            arr, pos, deferred = self.remaining[k]
            new_deferred = []
            for m in deferred:
                if len(selected) < self.r_max and m not in used_m:
                    selected.append((m, k))
                    used_m.add(m)
                else:
                    new_deferred.append(m)
            while pos < arr.size and len(selected) < self.r_max:
                m = int(arr[pos])
                pos += 1
                if m in used_m:
                    new_deferred.append(m)  # conflict: defer to a later batch
                else:
                    selected.append((m, k))
                    used_m.add(m)
            self.remaining[k] = [arr, pos, new_deferred]

        # retire completed ks, slide new ones in
        done_ks = [k for k in self.window if self._col_remaining(k) == 0]
        for k in done_ks:
            self.window.remove(k)
            del self.remaining[k]
        self._refill()
        return selected


def run_selecta(a: CSC, w_max: int = 32, r_max: int = 16,
                dynamic_k: bool = True) -> List[List[Tuple[int, int]]]:
    """Drain matrix A through SELECTA; returns the batch list."""
    st = SelectaState(a=a, w_max=w_max, r_max=r_max, dynamic_k=dynamic_k)
    batches = []
    guard = 0
    limit = 10 * (a.nnz + a.shape[1] + 1)
    while not st.done:
        batch = st.select()
        if batch:
            batches.append(batch)
        guard += 1
        if guard > limit:  # pragma: no cover - safety net
            raise RuntimeError("SELECTA failed to make progress")
    return batches


def selecta_stats(batches: List[List[Tuple[int, int]]], r_max: int) -> dict:
    """Reuse / occupancy statistics over a SELECTA trace."""
    if not batches:
        return {"batches": 0, "occupancy": 0.0, "k_sharing": 0.0, "pairs": 0}
    sizes = np.array([len(b) for b in batches], dtype=np.float64)
    # k-sharing: mean pairs per distinct k within a batch (B-row reuse factor)
    shares = []
    for b in batches:
        ks = [k for _, k in b]
        shares.append(len(ks) / max(len(set(ks)), 1))
    return {
        "batches": len(batches),
        "pairs": int(sizes.sum()),
        "occupancy": float(sizes.mean() / r_max),
        "k_sharing": float(np.mean(shares)),
    }
