"""TPU block-level Segment scheduler.

This is the paper's dynamic dataflow re-grounded at the granularity a TPU can
exploit (see DESIGN.md §2).  A *work item* is a nonzero-block multiply; the
scheduler orders the one-dimensional Pallas grid so that **consecutive items
share operands**, because Pallas only re-fetches a block from HBM when its
``index_map`` result changes between sequential grid steps (revisiting rule).
Schedule order therefore *is* the reuse mechanism.

Policies live in the :mod:`repro.core.policies` registry (all compute
identical results — only traffic/balance differ):

* ``"gustavson"`` — m-major static order (the best classic static dataflow
  for SpMM on TPU; paper §II baseline).
* ``"outer"``     — k-major static order (outer-product-like; B reuse, C
  thrash).
* ``"segment"``   — the paper's dynamic order, adapted: output-segment runs
  (C tile accumulates in VMEM) + SELECTA-style run chaining that greedily
  matches boundary k's between consecutive runs (B reuse) + serpentine k
  direction inside runs + :mod:`repro.core.folding` splitting of oversized
  runs for load balance.

:func:`schedule_traffic` evaluates a schedule under the revisiting model so
benchmarks can report bytes saved — the TPU analogue of the paper's reuse
metrics.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .folding import balance_bins, fold_segments
from .formats import BSR
from .policies import available_policies, get_policy, register_policy


@dataclasses.dataclass
class SpmmSchedule:
    """Work list for BSR(A) × dense(B): one item per nonzero A block.

    Arrays all have length ``n_items`` (+1 sentinel where noted):

    * ``a_idx``   — index into ``BSR.blocks`` for the item's A tile
    * ``m``/``k`` — block coordinates of the item
    * ``seg_start`` — 1 where the item begins a new output segment (C tile
      must be zero-initialized), else 0 (accumulate into resident tile)
    * ``seg_write`` — 1 where the item is the last of its segment (C tile is
      complete; kernels may use it for fused epilogues)
    """

    m: np.ndarray
    k: np.ndarray
    a_idx: np.ndarray
    seg_start: np.ndarray
    seg_write: np.ndarray
    n_m_blocks: int
    n_k_blocks: int
    policy: str

    @property
    def n_items(self) -> int:
        return int(self.m.shape[0])


def _runs_from_sorted(m_sorted: np.ndarray) -> np.ndarray:
    """seg_start flags for a list whose equal-m items are contiguous."""
    if m_sorted.size == 0:
        return np.zeros(0, dtype=np.int32)
    starts = np.ones(m_sorted.size, dtype=np.int32)
    starts[1:] = (m_sorted[1:] != m_sorted[:-1]).astype(np.int32)
    return starts


def _seg_write_from_starts(seg_start: np.ndarray) -> np.ndarray:
    if seg_start.size == 0:
        return np.zeros(0, dtype=np.int32)
    w = np.zeros(seg_start.size, dtype=np.int32)
    w[:-1] = seg_start[1:]
    w[-1] = 1
    return w


def _segment_order(m: np.ndarray, k: np.ndarray) -> np.ndarray:
    """SELECTA-adapted ordering for a bipartite (m,k) item set.

    1. Group items into output runs (same m) — C stationarity.
    2. Serpentine the k direction inside alternate runs.
    3. Chain runs greedily: after finishing a run ending at boundary block
       ``k_end``, pick the unvisited run whose k-set contains ``k_end``
       (boundary B-block carries over for free), preferring the run with the
       largest k-overlap with the current one; fall back to the run with the
       most items (greedy max-occupancy — SELECTA's intra-tile rule).

    Returns a permutation of item indices.
    """
    n = m.size
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    base = np.lexsort((k, m))
    m_s, k_s = m[base], k[base]
    # run boundaries over sorted-by-m items
    starts = np.nonzero(_runs_from_sorted(m_s))[0]
    ends = np.append(starts[1:], n)
    runs = []  # (item_indices_ascending_k, kset)
    for s, e in zip(starts, ends):
        idx = base[s:e]
        runs.append((idx, set(int(x) for x in k_s[s:e])))
    n_runs = len(runs)
    visited = np.zeros(n_runs, dtype=bool)
    order = []
    # start from the longest run (greedy max-occupancy)
    cur = int(np.argmax([len(r[0]) for r in runs]))
    flip = False
    for _ in range(n_runs):
        visited[cur] = True
        idx, kset = runs[cur]
        idx_seq = idx[::-1] if flip else idx
        order.append(idx_seq)
        k_end = int(k[idx_seq[-1]])
        # choose the next run: boundary-k match first, largest overlap wins
        best, best_score = -1, (-1, -1)
        for j in range(n_runs):
            if visited[j]:
                continue
            _, ks = runs[j]
            boundary = 1 if k_end in ks else 0
            overlap = len(kset & ks)
            score = (boundary, overlap + len(ks) * 1e-9)
            if score > best_score:
                best_score, best = score, j
        if best < 0:
            # no runs left reachable — pick the biggest remaining
            rem = np.nonzero(~visited)[0]
            if rem.size == 0:
                break
            best = int(rem[np.argmax([len(runs[j][0]) for j in rem])])
        nxt_kset = runs[best][1]
        # serpentine: enter the next run from the matching end
        nxt_idx = runs[best][0]
        if k_end in nxt_kset:
            # flip so the next run *starts* near k_end
            k_first = int(k[nxt_idx[0]])
            k_last = int(k[nxt_idx[-1]])
            flip = abs(k_last - k_end) < abs(k_first - k_end)
        else:
            flip = not flip
        cur = best
    return np.concatenate(order) if order else np.zeros(0, dtype=np.int64)


# ---------------------------------------------------------------------------
# Built-in policies.  ``segment`` reuses the SELECTA-adapted run chaining for
# SpGEMM by treating the C slot as the "row" and k as the shared operand.
# ---------------------------------------------------------------------------

register_policy(
    "segment",
    spmm_order=_segment_order,
    spgemm_order=lambda m, n, k, c: _segment_order(c, k),
    supports_fold=True,
    description="Paper's dynamic order: output-segment runs + SELECTA run "
                "chaining + serpentine k + temporal folding",
    overwrite=True)
register_policy(
    "gustavson",
    spmm_order=lambda m, k: np.lexsort((k, m)),
    spgemm_order=lambda m, n, k, c: np.lexsort((k, n, m)),
    description="m-major static order (best classic static dataflow on TPU)",
    overwrite=True)
register_policy(
    "outer",
    spmm_order=lambda m, k: np.lexsort((m, k)),
    spgemm_order=lambda m, n, k, c: np.lexsort((n, m, k)),
    description="k-major static order (outer-product-like; B reuse, C thrash)",
    overwrite=True)


def _apply_fold(seg_start: np.ndarray, fold_len: Optional[int]) -> np.ndarray:
    """Temporal folding: cap run length so no single output tile serializes
    the pipeline; folded continuations re-start a segment (the kernel
    read-modify-writes C on non-first sub-segments)."""
    if fold_len is None or fold_len <= 0:
        return seg_start
    run_pos = np.zeros(seg_start.size, dtype=np.int64)
    cnt = 0
    for i in range(seg_start.size):
        cnt = 0 if seg_start[i] else cnt + 1
        run_pos[i] = cnt
    refold = (run_pos > 0) & (run_pos % fold_len == 0)
    return (seg_start.astype(bool) | refold).astype(np.int32)


# ---------------------------------------------------------------------------
# Schedule finalization (accum_prev / row_mask) — the one place where the
# kernel-facing revisit bookkeeping is derived from seg_start flags
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SegmentFinalization:
    """Kernel-facing revisit bookkeeping derived from a finished schedule.

    ``accum_prev[i]`` is 1 exactly when item ``i`` starts a segment whose
    output tile was already written by an earlier segment (folded
    continuation or non-contiguous revisit) — the kernel must read-modify-
    write C instead of zero-initializing.  ``row_mask`` (when ``n_slots`` is
    given) is 1.0 for output slots that receive any work; slots never visited
    by the grid hold undefined memory and must be masked to zero.
    """

    accum_prev: np.ndarray              # (n_items,) int32
    row_mask: Optional[np.ndarray]      # (n_slots,) float32 or None


def finalize_schedule(seg_start: np.ndarray, owner: np.ndarray,
                      n_slots: Optional[int] = None) -> SegmentFinalization:
    """Derive ``accum_prev`` (+ optional ``row_mask``) for a schedule.

    ``owner[i]`` is the output-tile id of item ``i`` — the block row ``m``
    for SpMM, the C slot ``c_idx`` for SpGEMM.  This is the single
    implementation of the derivation previously copy-pasted across
    ``plan_spmm``/``plan_spgemm``/``sparse_ffn``.
    """
    seg_start = np.asarray(seg_start)
    owner = np.asarray(owner)
    if seg_start.shape != owner.shape:
        raise ValueError(f"seg_start {seg_start.shape} and owner "
                         f"{owner.shape} must have matching shapes")
    accum_prev = np.zeros(owner.size, dtype=np.int32)
    seen = set()
    for i in np.nonzero(seg_start)[0]:
        o = int(owner[i])
        accum_prev[i] = 1 if o in seen else 0
        seen.add(o)
    row_mask = None
    if n_slots is not None:
        row_mask = np.zeros(n_slots, dtype=np.float32)
        if owner.size:
            row_mask[np.unique(owner)] = 1.0
    return SegmentFinalization(accum_prev=accum_prev, row_mask=row_mask)


def build_spmm_schedule(a: BSR, policy: str = "segment",
                        fold_len: Optional[int] = None) -> SpmmSchedule:
    """Order the nonzero blocks of A into a kernel work list.

    ``policy`` names any entry in the :mod:`repro.core.policies` registry.
    """
    pol = get_policy(policy)
    m, k = a.brow.astype(np.int64), a.bcol.astype(np.int64)
    idx = np.arange(a.nblocks, dtype=np.int64)
    order = pol.spmm_order(m, k)
    m_o, k_o, idx_o = m[order], k[order], idx[order]
    seg_start = _runs_from_sorted(m_o)
    if pol.supports_fold:
        seg_start = _apply_fold(seg_start, fold_len)
    gm, gk = a.grid
    return SpmmSchedule(m=m_o.astype(np.int32), k=k_o.astype(np.int32),
                        a_idx=idx_o.astype(np.int32),
                        seg_start=seg_start.astype(np.int32),
                        seg_write=_seg_write_from_starts(seg_start),
                        n_m_blocks=gm, n_k_blocks=gk, policy=policy)


# ---------------------------------------------------------------------------
# SpGEMM (BSR × BSR → BSR): symbolic pattern + triple schedule
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SpgemmSchedule:
    """Work list of (m, k, n) block triples + the symbolic C pattern.

    ``c_idx[i]`` maps item i to its output block slot in the C block array;
    ``a_idx``/``b_idx`` map into the A/B block arrays.  Triples are ordered in
    output segments (same C slot contiguous) with k ascending inside, runs
    chained by the Segment policy on their (c, k) structure.
    """

    m: np.ndarray
    n: np.ndarray
    k: np.ndarray
    a_idx: np.ndarray
    b_idx: np.ndarray
    c_idx: np.ndarray
    seg_start: np.ndarray
    seg_write: np.ndarray
    # symbolic output pattern
    c_brow: np.ndarray
    c_bcol: np.ndarray
    policy: str

    @property
    def n_items(self) -> int:
        return int(self.m.shape[0])

    @property
    def n_c_blocks(self) -> int:
        return int(self.c_brow.shape[0])


def symbolic_spgemm(a_mask: np.ndarray, b_mask: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Block-pattern of C = A@B via boolean matmul. Returns (brow, bcol)."""
    c_mask = (a_mask.astype(np.int64) @ b_mask.astype(np.int64)) > 0
    brow, bcol = np.nonzero(c_mask)
    return brow.astype(np.int32), bcol.astype(np.int32)


def build_spgemm_schedule(a: BSR, b: BSR, policy: str = "segment",
                          fold_len: Optional[int] = None) -> SpgemmSchedule:
    get_policy(policy)   # fail fast before the symbolic phase
    a_mask, b_mask = a.block_mask(), b.block_mask()
    c_brow, c_bcol = symbolic_spgemm(a_mask, b_mask)
    gn = b.grid[1]
    c_slot = {(int(r), int(c)): i for i, (r, c) in enumerate(zip(c_brow, c_bcol))}
    a_slot = {(int(r), int(c)): i for i, (r, c) in enumerate(zip(a.brow, a.bcol))}
    # B indexed by (k, n)
    b_slot = {(int(r), int(c)): i for i, (r, c) in enumerate(zip(b.brow, b.bcol))}
    # enumerate triples: for each A block (m,k), each B block (k,n)
    b_rows = {}
    for (k_, n_), bi in b_slot.items():
        b_rows.setdefault(k_, []).append((n_, bi))
    for k_ in b_rows:
        b_rows[k_].sort()
    ms, ns, ks, ais, bis, cis = [], [], [], [], [], []
    for (m_, k_), ai in a_slot.items():
        for (n_, bi) in b_rows.get(k_, ()):
            ms.append(m_); ns.append(n_); ks.append(k_)
            ais.append(ai); bis.append(bi)
            cis.append(c_slot[(m_, n_)])
    m_arr = np.asarray(ms, dtype=np.int64)
    n_arr = np.asarray(ns, dtype=np.int64)
    k_arr = np.asarray(ks, dtype=np.int64)
    a_arr = np.asarray(ais, dtype=np.int64)
    b_arr = np.asarray(bis, dtype=np.int64)
    c_arr = np.asarray(cis, dtype=np.int64)

    pol = get_policy(policy)
    order = pol.spgemm_order(m_arr, n_arr, k_arr, c_arr)

    c_o = c_arr[order]
    seg_start = _runs_from_sorted(c_o)
    if pol.supports_fold:
        seg_start = _apply_fold(seg_start, fold_len)

    return SpgemmSchedule(
        m=m_arr[order].astype(np.int32), n=n_arr[order].astype(np.int32),
        k=k_arr[order].astype(np.int32), a_idx=a_arr[order].astype(np.int32),
        b_idx=b_arr[order].astype(np.int32), c_idx=c_o.astype(np.int32),
        seg_start=seg_start.astype(np.int32),
        seg_write=_seg_write_from_starts(seg_start.astype(np.int32)),
        c_brow=c_brow, c_bcol=c_bcol, policy=policy)


# ---------------------------------------------------------------------------
# Traffic model under Pallas revisiting semantics
# ---------------------------------------------------------------------------


def spmm_schedule_traffic(sched: SpmmSchedule, bm: int, bk: int, n_cols: int,
                          bytes_per_el: int = 4) -> dict:
    """HBM bytes for a 1-D grid SpMM kernel under revisiting semantics.

    Per step: A tile always fetched (distinct blocks); B row-block fetched iff
    ``k`` differs from the previous step; C row written at the end of each
    segment, and read back (accumulated) when a segment re-starts a C row that
    was already written (folding continuation or non-contiguous revisit).
    """
    a_bytes = sched.n_items * bm * bk * bytes_per_el
    k_delta = np.ones(sched.n_items, dtype=bool)
    if sched.n_items > 1:
        k_delta[1:] = sched.k[1:] != sched.k[:-1]
    b_bytes = int(k_delta.sum()) * bk * n_cols * bytes_per_el
    seg_heads = np.nonzero(sched.seg_start)[0]
    c_writes = seg_heads.size
    seen = set()
    c_reads = 0
    for h in seg_heads:
        mm = int(sched.m[h])
        if mm in seen:
            c_reads += 1
        seen.add(mm)
    c_bytes = (c_writes + c_reads) * bm * n_cols * bytes_per_el
    total = a_bytes + b_bytes + c_bytes
    return dict(a_bytes=a_bytes, b_bytes=b_bytes, c_bytes=c_bytes, total=total,
                b_fetches=int(k_delta.sum()), c_segments=int(c_writes))


def spgemm_schedule_traffic(sched: SpgemmSchedule, bm: int, bk: int, bn: int,
                            bytes_per_el: int = 4) -> dict:
    """Same revisiting model for the BSR×BSR kernel (tiles all block-sized)."""
    n_items = sched.n_items
    a_delta = np.ones(n_items, dtype=bool)
    b_delta = np.ones(n_items, dtype=bool)
    if n_items > 1:
        a_delta[1:] = sched.a_idx[1:] != sched.a_idx[:-1]
        b_delta[1:] = sched.b_idx[1:] != sched.b_idx[:-1]
    a_bytes = int(a_delta.sum()) * bm * bk * bytes_per_el
    b_bytes = int(b_delta.sum()) * bk * bn * bytes_per_el
    seg_heads = np.nonzero(sched.seg_start)[0]
    seen = set()
    c_reads = 0
    for h in seg_heads:
        ci = int(sched.c_idx[h])
        if ci in seen:
            c_reads += 1
        seen.add(ci)
    c_bytes = (seg_heads.size + c_reads) * bm * bn * bytes_per_el
    total = a_bytes + b_bytes + c_bytes
    return dict(a_bytes=a_bytes, b_bytes=b_bytes, c_bytes=c_bytes, total=total,
                b_fetches=int(b_delta.sum()), c_segments=int(seg_heads.size))


def shard_schedule(sizes: np.ndarray, n_shards: int, policy: str = "segment"):
    """Partition per-item work across devices/lanes.

    Dispatches on the policy registry's ``supports_fold`` attribute:
    fold-capable (dynamic) policies use folding's LPT balancing, static
    orders use round-robin — so a custom-registered dynamic policy gets
    LPT too, instead of silently falling back to round-robin as the old
    ``policy == "segment"`` string compare did.  Unknown names raise
    ``ValueError`` (listing the registry) rather than degrading.
    Returns (assignment, imbalance stats) — see :mod:`repro.core.folding`.
    """
    from .folding import round_robin_bins
    if get_policy(policy).supports_fold:
        return balance_bins(sizes, n_shards)
    return round_robin_bins(sizes, n_shards)
