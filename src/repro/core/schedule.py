"""TPU block-level Segment scheduler.

This is the paper's dynamic dataflow re-grounded at the granularity a TPU can
exploit (see DESIGN.md §2).  A *work item* is a nonzero-block multiply; the
scheduler orders the one-dimensional Pallas grid so that **consecutive items
share operands**, because Pallas only re-fetches a block from HBM when its
``index_map`` result changes between sequential grid steps (revisiting rule).
Schedule order therefore *is* the reuse mechanism.

Policies live in the :mod:`repro.core.policies` registry (all compute
identical results — only traffic/balance differ):

* ``"gustavson"`` — m-major static order (the best classic static dataflow
  for SpMM on TPU; paper §II baseline).
* ``"outer"``     — k-major static order (outer-product-like; B reuse, C
  thrash).
* ``"segment"``   — the paper's dynamic order, adapted: output-segment runs
  (C tile accumulates in VMEM) + SELECTA-style run chaining that greedily
  matches boundary k's between consecutive runs (B reuse) + serpentine k
  direction inside runs + :mod:`repro.core.folding` splitting of oversized
  runs for load balance.

:func:`partition_lanes` realizes the paper's dynamic remapping across PEs:
the finished 1-D schedule is cut into load-balanced parallel lanes at
segment-chain boundaries, which the kernels run as a "parallel" grid axis
(megacore / multi-core).  :func:`lane_traffic_spmm` /
:func:`lane_traffic_spgemm` evaluate a (possibly lane-cut) schedule under
the revisiting model so benchmarks can report bytes saved — the TPU
analogue of the paper's reuse metrics.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .folding import balance_bins, fold_segments
from .formats import BSR
from .policies import available_policies, get_policy, register_policy


@dataclasses.dataclass
class SpmmSchedule:
    """Work list for BSR(A) × dense(B): one item per nonzero A block.

    Arrays all have length ``n_items`` (+1 sentinel where noted):

    * ``a_idx``   — index into ``BSR.blocks`` for the item's A tile
    * ``m``/``k`` — block coordinates of the item
    * ``seg_start`` — 1 where the item begins a new output segment (C tile
      must be zero-initialized), else 0 (accumulate into resident tile)
    * ``seg_write`` — 1 where the item is the last of its segment (C tile is
      complete; kernels may use it for fused epilogues)
    """

    m: np.ndarray
    k: np.ndarray
    a_idx: np.ndarray
    seg_start: np.ndarray
    seg_write: np.ndarray
    n_m_blocks: int
    n_k_blocks: int
    policy: str

    @property
    def n_items(self) -> int:
        return int(self.m.shape[0])


def _runs_from_sorted(m_sorted: np.ndarray) -> np.ndarray:
    """seg_start flags for a list whose equal-m items are contiguous."""
    if m_sorted.size == 0:
        return np.zeros(0, dtype=np.int32)
    starts = np.ones(m_sorted.size, dtype=np.int32)
    starts[1:] = (m_sorted[1:] != m_sorted[:-1]).astype(np.int32)
    return starts


def _seg_write_from_starts(seg_start: np.ndarray) -> np.ndarray:
    if seg_start.size == 0:
        return np.zeros(0, dtype=np.int32)
    w = np.zeros(seg_start.size, dtype=np.int32)
    w[:-1] = seg_start[1:]
    w[-1] = 1
    return w


def _segment_order(m: np.ndarray, k: np.ndarray) -> np.ndarray:
    """SELECTA-adapted ordering for a bipartite (m,k) item set.

    1. Group items into output runs (same m) — C stationarity.
    2. Serpentine the k direction inside alternate runs.
    3. Chain runs greedily: after finishing a run ending at boundary block
       ``k_end``, pick the unvisited run whose k-set contains ``k_end``
       (boundary B-block carries over for free), preferring the run with the
       largest k-overlap with the current one; fall back to the run with the
       most items (greedy max-occupancy — SELECTA's intra-tile rule).

    Returns a permutation of item indices.
    """
    n = m.size
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    base = np.lexsort((k, m))
    m_s, k_s = m[base], k[base]
    # run boundaries over sorted-by-m items
    starts = np.nonzero(_runs_from_sorted(m_s))[0]
    ends = np.append(starts[1:], n)
    runs = []  # (item_indices_ascending_k, kset)
    for s, e in zip(starts, ends):
        idx = base[s:e]
        runs.append((idx, set(int(x) for x in k_s[s:e])))
    n_runs = len(runs)
    visited = np.zeros(n_runs, dtype=bool)
    order = []
    # start from the longest run (greedy max-occupancy)
    cur = int(np.argmax([len(r[0]) for r in runs]))
    flip = False
    for _ in range(n_runs):
        visited[cur] = True
        idx, kset = runs[cur]
        idx_seq = idx[::-1] if flip else idx
        order.append(idx_seq)
        k_end = int(k[idx_seq[-1]])
        # choose the next run: boundary-k match first, largest overlap wins
        best, best_score = -1, (-1, -1)
        for j in range(n_runs):
            if visited[j]:
                continue
            _, ks = runs[j]
            boundary = 1 if k_end in ks else 0
            overlap = len(kset & ks)
            score = (boundary, overlap + len(ks) * 1e-9)
            if score > best_score:
                best_score, best = score, j
        if best < 0:
            # no runs left reachable — pick the biggest remaining
            rem = np.nonzero(~visited)[0]
            if rem.size == 0:
                break
            best = int(rem[np.argmax([len(runs[j][0]) for j in rem])])
        nxt_kset = runs[best][1]
        # serpentine: enter the next run from the matching end
        nxt_idx = runs[best][0]
        if k_end in nxt_kset:
            # flip so the next run *starts* near k_end
            k_first = int(k[nxt_idx[0]])
            k_last = int(k[nxt_idx[-1]])
            flip = abs(k_last - k_end) < abs(k_first - k_end)
        else:
            flip = not flip
        cur = best
    return np.concatenate(order) if order else np.zeros(0, dtype=np.int64)


# ---------------------------------------------------------------------------
# Built-in policies.  ``segment`` reuses the SELECTA-adapted run chaining for
# SpGEMM by treating the C slot as the "row" and k as the shared operand.
# ---------------------------------------------------------------------------


def _static_cost_hint(policy_name: str):
    """Closed-form ``SchedulePolicy.cost_hint`` for a *static* order.

    A static policy's schedule is fully determined by its order function, so
    its default-knob traffic (one lane, fp32, pipelined) can be priced
    exactly by applying the order and evaluating the revisiting model over
    the *lane-major* item order — :func:`partition_lanes` round-robins
    segments even at one lane, so the hint runs the same layout the planner
    builds, not the raw static order.  No fetch-flag compilation or device
    upload happens.  This is what :mod:`repro.tune` and
    :func:`repro.sim.baselines.dataflow_estimates` score dataflows with
    before any candidate plan is built.  Exactness is pinned by
    ``tests/test_autotune.py`` against the built plans' recorded traffic.
    """

    def _lane_order(owner_o: np.ndarray, seg_start: np.ndarray):
        fin = finalize_schedule(seg_start, owner_o)
        layout = partition_lanes(owner_o, 1, policy=policy_name,
                                 seg_start=seg_start,
                                 seg_write=_seg_write_from_starts(seg_start),
                                 accum_prev=fin.accum_prev)
        return layout, lane_select(layout, seg_start, zero_pads=True)

    def hint(kind: str, **kw) -> Optional[dict]:
        pol = get_policy(policy_name)
        if kind == "spmm":
            m = np.asarray(kw["m"], dtype=np.int64)
            k = np.asarray(kw["k"], dtype=np.int64)
            order = pol.spmm_order(m, k)
            m_o, k_o = m[order], k[order]
            layout, ss = _lane_order(m_o, _runs_from_sorted(m_o))
            return lane_traffic_spmm(
                lane_select(layout, m_o), lane_select(layout, k_o), ss,
                layout.valid.reshape(-1), layout.n_lanes, kw["bm"], kw["bk"],
                kw["n_cols"], bytes_per_el=kw.get("bytes_per_el", 4))
        if kind == "spgemm":
            m = np.asarray(kw["m"], dtype=np.int64)
            n = np.asarray(kw["n"], dtype=np.int64)
            k = np.asarray(kw["k"], dtype=np.int64)
            c = np.asarray(kw["c"], dtype=np.int64)
            order = pol.spgemm_order(m, n, k, c)
            c_o = c[order]
            layout, ss = _lane_order(c_o, _runs_from_sorted(c_o))
            return lane_traffic_spgemm(
                lane_select(layout, np.asarray(kw["a_idx"])[order]),
                lane_select(layout, np.asarray(kw["b_idx"])[order]),
                lane_select(layout, c_o), ss,
                layout.valid.reshape(-1), layout.n_lanes,
                kw["bm"], kw["bk"], kw["bn"],
                bytes_per_el=kw.get("bytes_per_el", 4))
        return None

    return hint


register_policy(
    "segment",
    spmm_order=_segment_order,
    spgemm_order=lambda m, n, k, c: _segment_order(c, k),
    supports_fold=True,
    description="Paper's dynamic order: output-segment runs + SELECTA run "
                "chaining + serpentine k + temporal folding",
    overwrite=True)
register_policy(
    "gustavson",
    spmm_order=lambda m, k: np.lexsort((k, m)),
    spgemm_order=lambda m, n, k, c: np.lexsort((k, n, m)),
    description="m-major static order (best classic static dataflow on TPU)",
    cost_hint=_static_cost_hint("gustavson"),
    overwrite=True)
register_policy(
    "outer",
    spmm_order=lambda m, k: np.lexsort((m, k)),
    spgemm_order=lambda m, n, k, c: np.lexsort((n, m, k)),
    description="k-major static order (outer-product-like; B reuse, C thrash)",
    cost_hint=_static_cost_hint("outer"),
    overwrite=True)


def _apply_fold(seg_start: np.ndarray, fold_len: Optional[int]) -> np.ndarray:
    """Temporal folding: cap run length so no single output tile serializes
    the pipeline; folded continuations re-start a segment (the kernel
    read-modify-writes C on non-first sub-segments)."""
    if fold_len is None or fold_len <= 0:
        return seg_start
    run_pos = np.zeros(seg_start.size, dtype=np.int64)
    cnt = 0
    for i in range(seg_start.size):
        cnt = 0 if seg_start[i] else cnt + 1
        run_pos[i] = cnt
    refold = (run_pos > 0) & (run_pos % fold_len == 0)
    return (seg_start.astype(bool) | refold).astype(np.int32)


# ---------------------------------------------------------------------------
# Schedule finalization (accum_prev / row_mask) — the one place where the
# kernel-facing revisit bookkeeping is derived from seg_start flags
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SegmentFinalization:
    """Kernel-facing revisit bookkeeping derived from a finished schedule.

    ``accum_prev[i]`` is 1 exactly when item ``i`` starts a segment whose
    output tile was already written by an earlier segment (folded
    continuation or non-contiguous revisit) — the kernel must read-modify-
    write C instead of zero-initializing.  ``row_mask`` (when ``n_slots`` is
    given) is 1.0 for output slots that receive any work; slots never visited
    by the grid hold undefined memory and must be masked to zero.
    """

    accum_prev: np.ndarray              # (n_items,) int32
    row_mask: Optional[np.ndarray]      # (n_slots,) float32 or None


def finalize_schedule(seg_start: np.ndarray, owner: np.ndarray,
                      n_slots: Optional[int] = None) -> SegmentFinalization:
    """Derive ``accum_prev`` (+ optional ``row_mask``) for a schedule.

    ``owner[i]`` is the output-tile id of item ``i`` — the block row ``m``
    for SpMM, the C slot ``c_idx`` for SpGEMM.  This is the single
    implementation of the derivation previously copy-pasted across
    ``plan_spmm``/``plan_spgemm``/``sparse_ffn``.
    """
    seg_start = np.asarray(seg_start)
    owner = np.asarray(owner)
    if seg_start.shape != owner.shape:
        raise ValueError(f"seg_start {seg_start.shape} and owner "
                         f"{owner.shape} must have matching shapes")
    accum_prev = np.zeros(owner.size, dtype=np.int32)
    seen = set()
    for i in np.nonzero(seg_start)[0]:
        o = int(owner[i])
        accum_prev[i] = 1 if o in seen else 0
        seen.add(o)
    row_mask = None
    if n_slots is not None:
        row_mask = np.zeros(n_slots, dtype=np.float32)
        if owner.size:
            row_mask[np.unique(owner)] = 1.0
    return SegmentFinalization(accum_prev=accum_prev, row_mask=row_mask)


def build_spmm_schedule(a: BSR, policy: str = "segment",
                        fold_len: Optional[int] = None) -> SpmmSchedule:
    """Order the nonzero blocks of A into a kernel work list.

    ``policy`` names any entry in the :mod:`repro.core.policies` registry.
    """
    pol = get_policy(policy)
    m, k = a.brow.astype(np.int64), a.bcol.astype(np.int64)
    idx = np.arange(a.nblocks, dtype=np.int64)
    order = pol.spmm_order(m, k)
    m_o, k_o, idx_o = m[order], k[order], idx[order]
    seg_start = _runs_from_sorted(m_o)
    if pol.supports_fold:
        seg_start = _apply_fold(seg_start, fold_len)
    gm, gk = a.grid
    return SpmmSchedule(m=m_o.astype(np.int32), k=k_o.astype(np.int32),
                        a_idx=idx_o.astype(np.int32),
                        seg_start=seg_start.astype(np.int32),
                        seg_write=_seg_write_from_starts(seg_start),
                        n_m_blocks=gm, n_k_blocks=gk, policy=policy)


# ---------------------------------------------------------------------------
# SpGEMM (BSR × BSR → BSR): symbolic pattern + triple schedule
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SpgemmSchedule:
    """Work list of (m, k, n) block triples + the symbolic C pattern.

    ``c_idx[i]`` maps item i to its output block slot in the C block array;
    ``a_idx``/``b_idx`` map into the A/B block arrays.  Triples are ordered in
    output segments (same C slot contiguous) with k ascending inside, runs
    chained by the Segment policy on their (c, k) structure.
    """

    m: np.ndarray
    n: np.ndarray
    k: np.ndarray
    a_idx: np.ndarray
    b_idx: np.ndarray
    c_idx: np.ndarray
    seg_start: np.ndarray
    seg_write: np.ndarray
    # symbolic output pattern
    c_brow: np.ndarray
    c_bcol: np.ndarray
    policy: str

    @property
    def n_items(self) -> int:
        return int(self.m.shape[0])

    @property
    def n_c_blocks(self) -> int:
        return int(self.c_brow.shape[0])


def symbolic_spgemm(a_mask: np.ndarray, b_mask: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Block-pattern of C = A@B via boolean matmul. Returns (brow, bcol)."""
    c_mask = (a_mask.astype(np.int64) @ b_mask.astype(np.int64)) > 0
    brow, bcol = np.nonzero(c_mask)
    return brow.astype(np.int32), bcol.astype(np.int32)


def build_spgemm_schedule(a: BSR, b: BSR, policy: str = "segment",
                          fold_len: Optional[int] = None) -> SpgemmSchedule:
    get_policy(policy)   # fail fast before the symbolic phase
    a_mask, b_mask = a.block_mask(), b.block_mask()
    c_brow, c_bcol = symbolic_spgemm(a_mask, b_mask)
    gn = b.grid[1]
    c_slot = {(int(r), int(c)): i for i, (r, c) in enumerate(zip(c_brow, c_bcol))}
    a_slot = {(int(r), int(c)): i for i, (r, c) in enumerate(zip(a.brow, a.bcol))}
    # B indexed by (k, n)
    b_slot = {(int(r), int(c)): i for i, (r, c) in enumerate(zip(b.brow, b.bcol))}
    # enumerate triples: for each A block (m,k), each B block (k,n)
    b_rows = {}
    for (k_, n_), bi in b_slot.items():
        b_rows.setdefault(k_, []).append((n_, bi))
    for k_ in b_rows:
        b_rows[k_].sort()
    ms, ns, ks, ais, bis, cis = [], [], [], [], [], []
    for (m_, k_), ai in a_slot.items():
        for (n_, bi) in b_rows.get(k_, ()):
            ms.append(m_); ns.append(n_); ks.append(k_)
            ais.append(ai); bis.append(bi)
            cis.append(c_slot[(m_, n_)])
    m_arr = np.asarray(ms, dtype=np.int64)
    n_arr = np.asarray(ns, dtype=np.int64)
    k_arr = np.asarray(ks, dtype=np.int64)
    a_arr = np.asarray(ais, dtype=np.int64)
    b_arr = np.asarray(bis, dtype=np.int64)
    c_arr = np.asarray(cis, dtype=np.int64)

    pol = get_policy(policy)
    order = pol.spgemm_order(m_arr, n_arr, k_arr, c_arr)

    c_o = c_arr[order]
    seg_start = _runs_from_sorted(c_o)
    if pol.supports_fold:
        seg_start = _apply_fold(seg_start, fold_len)

    return SpgemmSchedule(
        m=m_arr[order].astype(np.int32), n=n_arr[order].astype(np.int32),
        k=k_arr[order].astype(np.int32), a_idx=a_arr[order].astype(np.int32),
        b_idx=b_arr[order].astype(np.int32), c_idx=c_o.astype(np.int32),
        seg_start=seg_start.astype(np.int32),
        seg_write=_seg_write_from_starts(seg_start.astype(np.int32)),
        c_brow=c_brow, c_bcol=c_bcol, policy=policy)


# ---------------------------------------------------------------------------
# Lane partitioning — the load-balance half of the paper's dynamic remapping.
# A finished schedule is split into ``n_lanes`` independent work streams at
# segment-chain boundaries; lanes run concurrently as a "parallel" Pallas
# grid axis (megacore / multi-core).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LaneLayout:
    """Lane-parallel realization of a finished 1-D schedule.

    ``perm[l, j]`` is the original schedule-item index executed at step ``j``
    of lane ``l``, or ``-1`` for a padding no-op (lanes are equal-length so
    the kernel grid is rectangular).  ``filled`` replaces every ``-1`` with
    the most recent real item of the same lane, so *index* arrays (block
    slots, coordinates) stay valid on pads — a pad re-addresses the resident
    blocks and is masked in the kernel; *flag* arrays must be zeroed on pads
    instead.  All items of one output tile (a segment chain, including folded
    continuations and non-contiguous revisits) live in exactly one lane, in
    schedule order — lanes never race on an output block and the
    ``accum_prev`` read-modify-write flags stay valid verbatim.
    """

    perm: np.ndarray        # (n_lanes, lane_len) int64, -1 = pad
    filled: np.ndarray      # (n_lanes, lane_len) int64, pads forward-filled
    valid: np.ndarray       # (n_lanes, lane_len) bool
    n_lanes: int
    lane_len: int
    stats: dict             # load-balance stats from shard_schedule

    @property
    def n_padded_items(self) -> int:
        return int(self.perm.size)


def partition_lanes(owner: np.ndarray, n_lanes: int, *, unroll: int = 1,
                    policy: str = "segment", seg_start=None, seg_write=None,
                    accum_prev=None) -> LaneLayout:
    """Split a schedule's item list into ``n_lanes`` balanced lanes.

    ``owner[i]`` is the output-tile id of schedule item ``i`` (block row for
    SpMM, C slot for SpGEMM).  Items are grouped per owner (a whole segment
    chain is atomic — folded continuations included), the groups are packed
    into lanes by :func:`shard_schedule`'s cost model (LPT for fold-capable
    policies, round-robin for static ones), and each lane keeps its groups in
    first-appearance order so SELECTA boundary chaining survives wherever two
    adjacent runs land in the same lane.

    ``unroll > 1`` additionally pads every group to a multiple of ``unroll``
    so a kernel that executes ``unroll`` items per grid step never straddles
    two output tiles within one step.

    ``n_lanes`` is clamped to the number of owner groups — a lane with no
    real work would flush an undefined output buffer.

    When the schedule's flag arrays (``seg_start``/``seg_write``/
    ``accum_prev``, in original schedule order) are passed, the partition is
    additionally validated: every ``accum_prev=1`` item read-modify-writes
    its output tile, so a ``seg_write`` to that tile must already have
    happened *earlier in the same lane* — otherwise the kernel reads an
    output buffer nothing ever wrote, a silent-wrong-answer class this turns
    into a named ``ValueError``.  Built-in policies always satisfy the
    invariant (owner groups are atomic per lane, in schedule order); the
    check guards custom-registered policies and hand-built schedules.
    """
    if n_lanes < 1:
        raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
    if unroll < 1:
        raise ValueError(f"unroll must be >= 1, got {unroll}")
    owner = np.asarray(owner, dtype=np.int64)
    n = owner.size
    if n == 0:
        z = np.zeros((1, 0), dtype=np.int64)
        return LaneLayout(perm=z, filled=z.copy(), valid=z.astype(bool),
                          n_lanes=1, lane_len=0,
                          stats={"imbalance": 1.0, "max_load": 0,
                                 "mean_load": 0.0})
    first: dict = {}
    groups: list = []
    for i, o in enumerate(owner.tolist()):
        gi = first.get(o)
        if gi is None:
            first[o] = len(groups)
            groups.append([i])
        else:
            groups[gi].append(i)
    sizes = np.asarray([len(g) for g in groups], dtype=np.int64)
    eff = max(1, min(n_lanes, len(groups)))
    assign, stats = shard_schedule(sizes, eff, policy=policy)
    lanes: list = [[] for _ in range(eff)]
    for gi, g in enumerate(groups):
        lane = lanes[int(assign[gi])]
        lane.extend(g)
        lane.extend([-1] * ((-len(g)) % unroll))
    lane_len = max(len(l) for l in lanes)
    perm = np.full((eff, lane_len), -1, dtype=np.int64)
    for li, l in enumerate(lanes):
        perm[li, :len(l)] = l
    if accum_prev is not None:
        _validate_lane_accum(perm, owner, seg_start, seg_write, accum_prev)
    # forward-fill pads with the last real item of their lane (every lane
    # starts with a real item: pads only follow groups)
    pos = np.maximum.accumulate(
        np.where(perm >= 0, np.arange(lane_len)[None, :], -1), axis=1)
    filled = np.take_along_axis(perm, np.maximum(pos, 0), axis=1)
    filled = np.where(pos >= 0, filled, 0)
    stats = dict(stats, n_lanes=eff,
                 padded_items=int((perm < 0).sum()))
    stats.pop("loads", None)
    return LaneLayout(perm=perm, filled=filled, valid=perm >= 0,
                      n_lanes=eff, lane_len=lane_len, stats=stats)


def _validate_lane_accum(perm: np.ndarray, owner: np.ndarray, seg_start,
                         seg_write, accum_prev) -> None:
    """Every ``accum_prev=1`` item must find its output tile already written
    (``seg_write=1``) earlier in the *same* lane — the kernel's ``_load``
    branch reads the C buffer, and an unwritten slot holds garbage.

    The check itself lives in :func:`repro.analysis.check_lane_accum` —
    one implementation shared with the plan verifier's ``accum-prev-order``
    invariant; this wrapper gathers the schedule-order arrays into lane
    layout and turns the first finding into the planner's ``ValueError``.
    The import is lazy: ``core`` stays importable without ``analysis``.
    """
    accum_prev = np.asarray(accum_prev)
    seg_start = (np.ones_like(accum_prev) if seg_start is None
                 else np.asarray(seg_start))
    seg_write = (np.zeros_like(accum_prev) if seg_write is None
                 else np.asarray(seg_write))
    for arr, name in ((seg_start, "seg_start"), (seg_write, "seg_write"),
                      (accum_prev, "accum_prev")):
        if arr.shape != owner.shape:
            raise ValueError(f"{name} has shape {arr.shape}, expected "
                             f"{owner.shape} to match owner")
    from repro.analysis.invariants import check_lane_accum
    filled = np.where(perm >= 0, perm, 0)
    findings = check_lane_accum(
        owner[filled], seg_start[filled], seg_write[filled],
        accum_prev[filled], perm >= 0, perm.shape[0],
        item_ids=perm)
    if findings:
        raise ValueError(findings[0].message)


#: valid ``prefetch=`` schedule modes: ``None`` drains the DMA pipeline at
#: every (lane, N-tile) pass boundary; ``"cross_pass"`` issues the next
#: pass's first copies during the current pass's tail step (the kernels'
#: certified overlap mode — every shipped variant is proven hazard-free by
#: ``repro.analysis.order`` before CI lets it execute).
PREFETCH_MODES = (None, "cross_pass")


def fetch_flags(stream: np.ndarray, valid: np.ndarray, n_lanes: int,
                depth: int = 2, prefetch: Optional[str] = None
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-item DMA fetch flags + ring-buffer slots for one operand stream.

    ``stream`` is a flattened lane-major array of operand indices (block
    slot for A, contraction block row ``k`` or B block slot for B);
    ``valid`` marks real items.  Returns ``(fetch, slot)`` int32 arrays:

    * ``fetch[i]`` is 1 exactly when the pipelined kernel must issue an
      async copy for item ``i``'s tile: the item is valid AND its operand
      index differs from the previous item's *within the same lane* (a
      lane's first item always fetches — lane cuts and grid-pass restarts
      never inherit residency; pads fetch nothing, their forward-filled
      index re-addresses the resident tile);
    * ``slot[i]`` is the ring-buffer slot where item ``i``'s tile resides:
      the ``depth``-slot ring advances one slot per fetch, so a reused tile
      is always the most recently fetched one.  ``depth`` must be at least
      ``2 * unroll`` for a kernel that issues one grid step ahead while
      ``unroll`` items compute per step (2 for the plain double buffer).

    The kernels gate every async copy on these flags; the traffic model
    implements the same change-detection contract independently
    (:func:`_revisit_traffic`), and CI asserts the two counts agree exactly
    — a drift bug in either implementation trips the gate.

    ``prefetch`` (one of :data:`PREFETCH_MODES`) selects the schedule mode
    the flags will drive.  ``"cross_pass"`` changes *when* a pass's
    lane-first copies are issued (the previous pass's tail step), never
    *which* items fetch — the flags and slots returned here are identical
    under both modes, which is what guarantees bit-exact numerical parity
    between the two.
    """
    if prefetch not in PREFETCH_MODES:
        raise ValueError(f"prefetch={prefetch!r} not in {PREFETCH_MODES}")
    if depth < 2:
        raise ValueError(f"ring-buffer depth must be >= 2, got {depth}")
    stream = np.asarray(stream)
    valid = np.asarray(valid).astype(bool)
    if stream.shape != valid.shape:
        raise ValueError(f"stream {stream.shape} and valid {valid.shape} "
                         f"must have matching shapes")
    if stream.size % max(n_lanes, 1) != 0:
        raise ValueError(f"n_items={stream.size} is not divisible by "
                         f"n_lanes={n_lanes}")
    s2 = stream.reshape(n_lanes, -1)
    v2 = valid.reshape(n_lanes, -1)
    delta = np.ones_like(s2, dtype=bool)
    if s2.shape[1] > 1:
        delta[:, 1:] = s2[:, 1:] != s2[:, :-1]
    fetch = delta & v2
    slot = np.maximum(np.cumsum(fetch, axis=1) - 1, 0) % depth
    return (fetch.reshape(-1).astype(np.int32),
            slot.reshape(-1).astype(np.int32))


def lane_select(layout: LaneLayout, arr: np.ndarray,
                zero_pads: bool = False) -> np.ndarray:
    """Gather a per-item schedule array into flattened lane-major order.

    Index arrays (block slots/coordinates) keep the previous real item's
    value on pads (``zero_pads=False``: no spurious Pallas re-fetch, no
    output-buffer flush of an unvisited tile); flag arrays
    (``seg_start``/``seg_write``/``accum_prev``) are zeroed on pads so a
    padding step neither initializes nor writes anything.
    """
    arr = np.asarray(arr)
    out = arr[layout.filled.reshape(-1)]
    if zero_pads:
        out = np.where(layout.valid.reshape(-1), out, 0).astype(arr.dtype)
    return out


# ---------------------------------------------------------------------------
# Traffic model under Pallas revisiting semantics
# ---------------------------------------------------------------------------


def _revisit_traffic(fetch_streams, owner, seg_start, valid, n_lanes,
                     c_tile_bytes, unroll: int = 1, pipeline: bool = True):
    """Shared revisiting-model core over flattened lane-major arrays.

    ``fetch_streams`` is a list of ``(arr, tile_bytes, always)`` operand
    streams: an operand tile is fetched when its index differs from the
    previous item's *within the same lane* (lane boundaries always re-fetch:
    the SELECTA boundary-reuse chain is broken where a schedule is cut into
    lanes), or on every valid item when ``always``.

    ``pipeline=True`` (the default — matching the kernels' explicit DMA
    pipeline) counts a fetch wherever an operand index differs from the
    previous item's within the lane, exactly the contract
    :func:`fetch_flags` compiles into the kernels' copy-gating flags.  The
    two are deliberately *independent implementations* of that contract —
    CI asserts their counts agree exactly, so a drift bug in either one
    (pad handling, lane starts, unroll) trips the gate instead of
    cancelling out.  Per-item adjacency carries reuse across every
    consecutive pair, ``unroll`` included.  ``pipeline=False`` models the
    legacy BlockSpec auto-pipeline, where each of the G items of an
    unrolled grid step binds an *independent* stream (index maps strided by
    ``unroll``): revisit credit only exists between position ``g`` of
    consecutive steps, never across the items inside one step.

    Counts are per (lane, output-tile) pass: B/C bytes stay exact across a
    multi-N-tile SpMM grid (each pass copies one ``bn``-wide slice; summed
    over passes that is the priced row-block), while A-tile bytes are
    priced once per item even though the kernel re-issues A copies each
    pass — the same N-independent idealization the auto-pipeline model
    used.  C tiles are written once per segment head and read back on
    owner revisits (folded continuations / non-contiguous re-starts).
    Pads (``valid == 0``) move no data.
    """
    valid = np.asarray(valid, dtype=bool)
    fetches = []
    for arr, tile_bytes, always in fetch_streams:
        if always:
            n_fetch = int(valid.sum())
        elif pipeline:
            a2 = np.asarray(arr).reshape(n_lanes, -1)
            delta = np.ones_like(a2, dtype=bool)
            if a2.shape[1] > 1:
                delta[:, 1:] = a2[:, 1:] != a2[:, :-1]
            n_fetch = int((delta.reshape(-1) & valid).sum())
        else:
            a3 = np.asarray(arr).reshape(n_lanes, -1, unroll)
            delta = np.ones_like(a3, dtype=bool)
            if a3.shape[1] > 1:
                delta[:, 1:, :] = a3[:, 1:, :] != a3[:, :-1, :]
            n_fetch = int((delta.reshape(-1) & valid).sum())
        fetches.append((n_fetch, n_fetch * tile_bytes))
    seg_heads = np.nonzero(np.asarray(seg_start) & valid)[0]
    seen = set()
    c_reads = 0
    owner = np.asarray(owner)
    for h in seg_heads:
        o = int(owner[h])
        if o in seen:
            c_reads += 1
        seen.add(o)
    c_bytes = (seg_heads.size + c_reads) * c_tile_bytes
    return fetches, int(seg_heads.size), c_bytes


def _head_window_fetches(k, valid, n_lanes: int, unroll: int) -> int:
    """Fetches that land in each lane's first-``unroll`` head window.

    Under ``prefetch="cross_pass"`` the kernels issue exactly the copies of
    a pass's *first grid step* (``unroll`` items per lane) during the
    previous pass's tail, so these are the fetches that overlap compute at
    each pass boundary.  A tiles fetch on every valid head item; B
    row-blocks fetch where ``k`` changes within the lane (a lane's first
    item always fetches).
    """
    k2 = np.asarray(k).reshape(n_lanes, -1)
    v2 = np.asarray(valid, dtype=bool).reshape(n_lanes, -1)
    w = min(unroll, k2.shape[1])
    delta = np.ones_like(k2, dtype=bool)
    if k2.shape[1] > 1:
        delta[:, 1:] = k2[:, 1:] != k2[:, :-1]
    a_head = int(v2[:, :w].sum())
    b_head = int((delta[:, :w] & v2[:, :w]).sum())
    return a_head + b_head


def lane_traffic_spmm(m, k, seg_start, valid, n_lanes: int, bm: int, bk: int,
                      n_cols: int, bytes_per_el: int = 4,
                      unroll: int = 1, pipeline: bool = True,
                      prefetch: Optional[str] = None) -> dict:
    """Revisiting-model HBM bytes for the lane-parallel SpMM kernel.

    Arrays are flattened lane-major (``n_lanes * lane_len``).  A tiles are
    fetched once per valid item (every item is a distinct nonzero block); a
    B row-block is fetched when ``k`` changes within a lane (and always at
    a lane start — lane cuts break the boundary-k chaining the Segment
    order set up); C tiles follow the segment write/revisit rule, with
    owners confined to single lanes.  ``pipeline`` selects the explicit-DMA
    fetch-flag accounting (default, matching the kernels) vs the legacy
    per-BlockSpec-stream model (see :func:`_revisit_traffic`).

    ``prefetch`` never changes byte totals or fetch counts — cross-pass
    prefetch re-times copies, it does not add or drop any (see
    :func:`fetch_flags`).  It adds a ``prefetch_fetches`` key: the number
    of copies per (lane, N-tile) pass that the ``"cross_pass"`` mode
    overlaps with the previous pass's tail step — the A + B fetches landing
    in each lane's first-``unroll`` head window (0 when ``prefetch`` is
    off).  The cost model credits that much pipeline-drain latency per
    pass boundary; CI asserts the count against the kernels' actual flags.
    """
    if prefetch not in PREFETCH_MODES:
        raise ValueError(f"prefetch={prefetch!r} not in {PREFETCH_MODES}")
    fetches, c_segments, c_bytes = _revisit_traffic(
        [(k, 0, True), (k, bk * n_cols * bytes_per_el, False)],
        m, seg_start, valid, n_lanes, bm * n_cols * bytes_per_el,
        unroll=unroll, pipeline=pipeline)
    a_fetches = fetches[0][0]
    a_bytes = a_fetches * bm * bk * bytes_per_el
    b_fetches, b_bytes = fetches[1]
    total = a_bytes + b_bytes + c_bytes
    prefetch_fetches = (_head_window_fetches(k, valid, n_lanes, unroll)
                        if prefetch == "cross_pass" else 0)
    return dict(a_bytes=a_bytes, b_bytes=b_bytes, c_bytes=c_bytes, total=total,
                a_fetches=a_fetches, b_fetches=b_fetches,
                c_segments=c_segments, prefetch_fetches=prefetch_fetches)


def lane_traffic_spgemm(a_idx, b_idx, c_idx, seg_start, valid, n_lanes: int,
                        bm: int, bk: int, bn: int, bytes_per_el: int = 4,
                        unroll: int = 1, pipeline: bool = True,
                        prefetch: Optional[str] = None) -> dict:
    """Revisiting-model HBM bytes for the lane-parallel SpGEMM kernel.

    ``prefetch_fetches`` is always 0 here: the SpGEMM grid has no N-tile
    pass axis, so ``prefetch="cross_pass"`` degenerates to the drained
    schedule (the knob is accepted for knob-grid uniformity only).
    """
    if prefetch not in PREFETCH_MODES:
        raise ValueError(f"prefetch={prefetch!r} not in {PREFETCH_MODES}")
    fetches, c_segments, c_bytes = _revisit_traffic(
        [(a_idx, bm * bk * bytes_per_el, False),
         (b_idx, bk * bn * bytes_per_el, False)],
        c_idx, seg_start, valid, n_lanes, bm * bn * bytes_per_el,
        unroll=unroll, pipeline=pipeline)
    a_fetches, a_bytes = fetches[0]
    b_fetches, b_bytes = fetches[1]
    total = a_bytes + b_bytes + c_bytes
    return dict(a_bytes=a_bytes, b_bytes=b_bytes, c_bytes=c_bytes, total=total,
                a_fetches=a_fetches, b_fetches=b_fetches,
                c_segments=c_segments, prefetch_fetches=0)


def spmm_schedule_traffic(sched: SpmmSchedule, bm: int, bk: int, n_cols: int,
                          bytes_per_el: int = 4) -> dict:
    """HBM bytes for the single-lane SpMM schedule (see lane_traffic_spmm).

    Per step: A tile always fetched (distinct blocks); B row-block fetched iff
    ``k`` differs from the previous step; C row written at the end of each
    segment, and read back (accumulated) when a segment re-starts a C row that
    was already written (folding continuation or non-contiguous revisit).
    """
    valid = np.ones(sched.n_items, dtype=bool)
    return lane_traffic_spmm(sched.m, sched.k, sched.seg_start, valid, 1,
                             bm, bk, n_cols, bytes_per_el)


def spgemm_schedule_traffic(sched: SpgemmSchedule, bm: int, bk: int, bn: int,
                            bytes_per_el: int = 4) -> dict:
    """Same revisiting model for the BSR×BSR kernel (tiles all block-sized)."""
    valid = np.ones(sched.n_items, dtype=bool)
    return lane_traffic_spgemm(sched.a_idx, sched.b_idx, sched.c_idx,
                               sched.seg_start, valid, 1, bm, bk, bn,
                               bytes_per_el)


def shard_schedule(sizes: np.ndarray, n_shards: int, policy: str = "segment"):
    """Partition per-item work across devices/lanes.

    Dispatches on the policy registry's ``supports_fold`` attribute:
    fold-capable (dynamic) policies use folding's LPT balancing, static
    orders use round-robin — so a custom-registered dynamic policy gets
    LPT too, instead of silently falling back to round-robin as the old
    ``policy == "segment"`` string compare did.  Unknown names raise
    ``ValueError`` (listing the registry) rather than degrading.
    Returns (assignment, imbalance stats) — see :mod:`repro.core.folding`.
    """
    from .folding import round_robin_bins
    if get_policy(policy).supports_fold:
        return balance_bins(sizes, n_shards)
    return round_robin_bins(sizes, n_shards)
