"""SEGMENTBC — the virtual coordinate space (V-space) and merge routing.

Implements the paper's §III-B / §IV-A at functional granularity:

* a :class:`VSpace` holding one virtual row per non-empty output row of C,
  maintaining the four mapping invariants (injectivity, row saturation,
  column ordering, time ascending);
* merge routing of an incoming B element (compare, forward, insert,
  accumulate) with *segment displacement* accounting (Eq. 5);
* three index-to-PE mappers (§VI-C.2): ``zero`` (always start at 0), ``ideal``
  (oracle binary search on up-to-date state), ``lut`` (binary search on a
  bounded-write-bandwidth, possibly *stale* copy — SegFold's IPM).

Correctness does not depend on the mapper: a stale LUT can only start a
segment *left* of its true legal start (time-ascending property), lengthening
the traversal but never missing the match — mirrored here and verified by
property tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np


@dataclasses.dataclass
class VirtualRow:
    """One virtual row: sorted column indices + partial sums."""

    cols: List[int] = dataclasses.field(default_factory=list)
    vals: List[float] = dataclasses.field(default_factory=list)

    def check_invariants(self) -> None:
        # a named error, not a bare assert: this must hold under python -O
        # too (the simulator's routing correctness rests on it)
        if any(self.cols[i] >= self.cols[i + 1]
               for i in range(len(self.cols) - 1)):
            raise ValueError(
                f"virtual-row column ordering violated: cols={self.cols} "
                f"must be strictly increasing (SEGMENTBC keeps every "
                f"virtual row sorted so shift-based insertion stays exact)")


class StaleLUT:
    """IPM model: a lagging copy of a virtual row's column indices.

    Real hardware has a limited number of LUT write ports; updates queue and
    apply serially (``write_ports`` per ``tick``).  Staleness only under-
    estimates legal start positions (time-ascending ⇒ entries only move right),
    which is safe.
    """

    def __init__(self, write_ports: int = 1):
        self.snapshot: List[int] = []
        self.pending: List[List[int]] = []   # queue of full-row snapshots
        self.write_ports = write_ports
        self._credit = 0

    def notify(self, cols: List[int]) -> None:
        """A PE updated its c value → enqueue the new state."""
        self.pending.append(list(cols))

    def tick(self) -> None:
        """Apply up to ``write_ports`` queued updates (one per port)."""
        self._credit += self.write_ports
        while self.pending and self._credit > 0:
            self.snapshot = self.pending.pop(0)
            self._credit -= 1
        self._credit = min(self._credit, self.write_ports)

    def lookup(self, b: int) -> int:
        """Rightmost legal start: #entries with c < b in the (stale) snapshot."""
        return int(np.searchsorted(np.asarray(self.snapshot, dtype=np.int64), b, side="left"))


class VSpace:
    """The evolving compressed coordinate space for C (one matrix tile)."""

    def __init__(self, mapping: str = "lut", lut_write_ports: int = 1):
        if mapping not in ("zero", "ideal", "lut"):
            raise ValueError(f"unknown V-space mapping {mapping!r}; "
                             f"expected 'zero', 'ideal' or 'lut'")
        self.mapping = mapping
        self.rows: Dict[int, VirtualRow] = {}
        self.luts: Dict[int, StaleLUT] = {}
        self.lut_write_ports = lut_write_ports
        # telemetry
        self.total_displacement = 0
        self.total_shifts = 0
        self.elements_routed = 0

    # -- mapping f_t ----------------------------------------------------------
    def _row(self, m: int) -> VirtualRow:
        if m not in self.rows:
            self.rows[m] = VirtualRow()
            self.luts[m] = StaleLUT(self.lut_write_ports)
        return self.rows[m]

    def start_position(self, m: int, b: int) -> int:
        """f_t_in from the configured mapper."""
        row = self._row(m)
        if self.mapping == "zero":
            return 0
        if self.mapping == "ideal":
            return int(np.searchsorted(np.asarray(row.cols, dtype=np.int64), b, side="left"))
        # lut: stale binary search, clamped to legal range
        s = self.luts[m].lookup(b)
        # A stale LUT may only be *behind* (entries moved right since the
        # snapshot) => s can only be <= the true start. Clamp defensively.
        true_s = int(np.searchsorted(np.asarray(row.cols, dtype=np.int64), b, side="left"))
        return min(s, true_s)

    # -- merge routing ----------------------------------------------------------
    def route(self, m: int, n: int, value: float) -> Tuple[int, int]:
        """Route one B/T element into row ``m`` with column index ``n``.

        Returns ``(displacement, shifts)``: PE hops traversed and entries
        shifted right (insert cost).  Implements Fig. 6 cases.
        """
        row = self._row(m)
        s = self.start_position(m, n)
        cols = row.cols
        # walk right from s: b > c → forward; b < c → insert; b == c → accumulate
        pos = s
        while pos < len(cols) and cols[pos] < n:
            pos += 1
        displacement = pos - s
        shifts = 0
        if pos < len(cols) and cols[pos] == n:
            row.vals[pos] += value                      # Fig. 6(c) accumulate
        else:
            cols.insert(pos, n)                         # Fig. 6(b) insert
            row.vals.insert(pos, value)
            shifts = len(cols) - 1 - pos                # entries shifted right
        self.total_displacement += displacement
        self.total_shifts += shifts
        self.elements_routed += 1
        if self.mapping == "lut":
            self.luts[m].notify(cols)
        return displacement, shifts

    def tick(self) -> None:
        """Advance LUT write queues one cycle."""
        if self.mapping == "lut":
            for lut in self.luts.values():
                lut.tick()

    # -- extraction -------------------------------------------------------------
    def to_coo(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        rows, cols, vals = [], [], []
        for m, row in sorted(self.rows.items()):
            rows.extend([m] * len(row.cols))
            cols.extend(row.cols)
            vals.extend(row.vals)
        return (np.asarray(rows, dtype=np.int64),
                np.asarray(cols, dtype=np.int64),
                np.asarray(vals, dtype=np.float32))

    def check_invariants(self) -> None:
        for row in self.rows.values():
            row.check_invariants()

    @property
    def mean_displacement(self) -> float:
        return self.total_displacement / max(self.elements_routed, 1)


def segment_spgemm_elementwise(a_csc, b_csr, *, w_max: int = 32, r_max: int = 16,
                               mapping: str = "lut", dynamic_k: bool = True):
    """Reference Segment-dataflow SpGEMM: SELECTA batches × SEGMENTBC routing.

    Functional model (no timing): used as the paper-faithful algorithmic
    oracle.  Returns (dense C, telemetry dict).
    """
    from .selecta import SelectaState

    m_dim, k_dim = a_csc.shape
    n_dim = b_csr.shape[1]
    vspace = VSpace(mapping=mapping)
    st = SelectaState(a=a_csc, w_max=w_max, r_max=r_max, dynamic_k=dynamic_k)
    a_dense = a_csc.to_dense()
    batches = 0
    while not st.done:
        batch = st.select()
        if not batch:
            continue
        batches += 1
        for (m, k) in batch:
            b_cols, b_vals = b_csr.row(k)
            a_val = a_dense[m, k]
            for n, bv in zip(b_cols, b_vals):
                vspace.route(m, int(n), float(a_val * bv))
        vspace.tick()
    rows, cols, vals = vspace.to_coo()
    c = np.zeros((m_dim, n_dim), dtype=np.float32)
    if rows.size:
        c[rows, cols] = vals
    telemetry = {
        "batches": batches,
        "mean_displacement": vspace.mean_displacement,
        "total_shifts": vspace.total_shifts,
        "elements_routed": vspace.elements_routed,
    }
    return c, telemetry
