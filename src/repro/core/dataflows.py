"""Reference static SpGEMM dataflows (paper §II): inner / outer / Gustavson.

Functional element-granularity implementations that return C plus *work and
traffic counters* — the quantities whose imbalance the paper's Fig. 1
illustrates.  The cycle/bandwidth timing interpretation of these counters
lives in :mod:`repro.sim.baselines`.

Counter semantics (per dataflow):

* ``mults`` / ``adds``          — arithmetic work (identical across dataflows
                                  up to insert-vs-add bookkeeping).
* ``a_fetch`` / ``b_fetch``     — operand elements fetched assuming the
                                  dataflow's natural stationarity (an operand
                                  held stationary by the loop order is fetched
                                  once; a streamed operand is re-fetched per
                                  use).
* ``c_traffic``                 — partial-sum elements moved to/from the
                                  intermediate store (OP's scatter cost).
* ``iter_work``                 — work per outermost iteration (load-balance
                                  distribution; its variance is the imbalance).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from .formats import CSC, CSR


def inner_product(a: CSR, b_csc: CSC) -> Tuple[np.ndarray, Dict]:
    """IP: order M·N·K — dot(A[m,:], B[:,n]) per output; C reuse only."""
    m_dim, k_dim = a.shape
    n_dim = b_csc.shape[1]
    c = np.zeros((m_dim, n_dim), dtype=np.float32)
    mults = adds = 0
    a_fetch = b_fetch = 0
    iter_work = []
    for m in range(m_dim):
        a_cols, a_vals = a.row(m)
        for n in range(n_dim):
            b_rows, b_vals = b_csc.col(n)
            # sorted intersection of a_cols and b_rows
            inter, ia, ib = np.intersect1d(a_cols, b_rows, return_indices=True)
            w = inter.size
            if w:
                c[m, n] = np.dot(a_vals[ia], b_vals[ib])
            mults += w
            adds += max(w - 1, 0)
            # IP streams both vectors to compute the intersection
            a_fetch += a_cols.size
            b_fetch += b_rows.size
            iter_work.append(w)
    stats = dict(mults=mults, adds=adds, a_fetch=a_fetch, b_fetch=b_fetch,
                 c_traffic=0, iter_work=np.asarray(iter_work, dtype=np.int64))
    return c, stats


def outer_product(a_csc: CSC, b: CSR) -> Tuple[np.ndarray, Dict]:
    """OP: order K·M·N — cross product per k; A,B reuse, C scatter traffic."""
    m_dim, k_dim = a_csc.shape
    n_dim = b.shape[1]
    c = np.zeros((m_dim, n_dim), dtype=np.float32)
    touched = np.zeros((m_dim, n_dim), dtype=bool)
    mults = adds = 0
    c_traffic = 0
    iter_work = []
    for k in range(k_dim):
        a_rows, a_vals = a_csc.col(k)
        b_cols, b_vals = b.row(k)
        w = a_rows.size * b_cols.size
        iter_work.append(w)
        if w == 0:
            continue
        partial = np.outer(a_vals, b_vals)
        adds += int(touched[np.ix_(a_rows, b_cols)].sum())
        touched[np.ix_(a_rows, b_cols)] = True
        c[np.ix_(a_rows, b_cols)] += partial
        mults += w
        # every partial product is written to (and later merged from) the
        # intermediate T store: the OP merge-phase traffic
        c_traffic += 2 * w
    stats = dict(mults=mults, adds=adds, a_fetch=a_csc.nnz, b_fetch=b.nnz,
                 c_traffic=c_traffic, iter_work=np.asarray(iter_work, dtype=np.int64))
    return c, stats


def gustavson(a: CSR, b: CSR) -> Tuple[np.ndarray, Dict]:
    """Gust: order M·K·N — row products; A fully reused, B re-fetched per use."""
    m_dim, k_dim = a.shape
    n_dim = b.shape[1]
    c = np.zeros((m_dim, n_dim), dtype=np.float32)
    mults = adds = 0
    b_fetch = 0
    iter_work = []
    for m in range(m_dim):
        a_cols, a_vals = a.row(m)
        acc: Dict[int, float] = {}
        w = 0
        for k, av in zip(a_cols, a_vals):
            b_cols, b_vals = b.row(int(k))
            b_fetch += b_cols.size
            for n, bv in zip(b_cols, b_vals):
                n = int(n)
                w += 1
                if n in acc:
                    acc[n] += av * bv
                    adds += 1
                else:
                    acc[n] = av * bv
        mults += w
        iter_work.append(w)
        for n, v in acc.items():
            c[m, n] = v
    stats = dict(mults=mults, adds=adds, a_fetch=a.nnz, b_fetch=b_fetch,
                 c_traffic=0, iter_work=np.asarray(iter_work, dtype=np.int64))
    return c, stats


DATAFLOWS = {
    "inner": lambda a_csr, b_csr: inner_product(a_csr, CSC.from_csr(b_csr)),
    "outer": lambda a_csr, b_csr: outer_product(CSC.from_csr(a_csr), b_csr),
    "gustavson": gustavson,
}
