"""Segment dataflow core: the paper's contribution as a composable library.

Public surface:

* formats:   :class:`CSR`, :class:`DCSR`, :class:`CSC`, :class:`BSR`
* dataflow:  :func:`run_selecta`, :func:`segment_spgemm_elementwise`,
             static references in :mod:`repro.core.dataflows`
* folding:   :func:`spatial_fold`, :func:`fold_segments`, :func:`balance_bins`
* schedules: :func:`build_spmm_schedule`, :func:`build_spgemm_schedule`,
             :func:`partition_lanes` (lane-parallel realization)
* policies:  :func:`register_policy`, :func:`get_policy`,
             :func:`available_policies` (the dataflow configuration space)
"""
from .formats import BSR, CSC, CSR, DCSR, csr_from_coo, random_csr, spgemm_reference
from .selecta import SelectaState, run_selecta, selecta_stats
from .segmentbc import VSpace, segment_spgemm_elementwise
from .folding import balance_bins, fold_segments, round_robin_bins, spatial_fold, temporal_fold_spills
from .policies import (SchedulePolicy, available_policies, get_policy,
                       register_policy, unregister_policy)
from .schedule import (LaneLayout, SegmentFinalization, SpgemmSchedule,
                       SpmmSchedule, build_spgemm_schedule,
                       build_spmm_schedule, fetch_flags, finalize_schedule,
                       lane_select, lane_traffic_spgemm, lane_traffic_spmm,
                       partition_lanes, shard_schedule,
                       spgemm_schedule_traffic, spmm_schedule_traffic,
                       symbolic_spgemm)

__all__ = [
    "BSR", "CSC", "CSR", "DCSR", "csr_from_coo", "random_csr", "spgemm_reference",
    "SelectaState", "run_selecta", "selecta_stats",
    "VSpace", "segment_spgemm_elementwise",
    "balance_bins", "fold_segments", "round_robin_bins", "spatial_fold",
    "temporal_fold_spills",
    "SchedulePolicy", "available_policies", "get_policy", "register_policy",
    "unregister_policy",
    "LaneLayout", "SegmentFinalization", "SpgemmSchedule", "SpmmSchedule",
    "build_spgemm_schedule", "build_spmm_schedule", "fetch_flags",
    "finalize_schedule",
    "lane_select", "lane_traffic_spgemm", "lane_traffic_spmm",
    "partition_lanes", "shard_schedule", "spgemm_schedule_traffic",
    "spmm_schedule_traffic", "symbolic_spgemm",
]
