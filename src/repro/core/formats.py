"""Sparse matrix containers used across the Segment dataflow stack.

Three formats, mirroring the paper's storage choices (§IV-B):

* ``CSR``   — row-major compressed rows (matrix ``B`` is processed at row
  granularity and stored row-major).
* ``DCSR``  — doubly compressed sparse rows (paper's choice for ``B``): a second
  compression level skips empty rows in O(1), which matters for hyper-sparse
  matrices where most rows in the active window are empty.
* ``CSC``   — column-major (matrix ``A`` is consumed column-wise by SELECTA, so
  it is stored column-major).
* ``BSR``   — block-sparse rows: the TPU-native granularity. A BSR nonzero is a
  dense ``(bm, bk)`` tile destined for the MXU.

All containers are host-side numpy (schedules are built on host / traced into
jit via static structure); ``BSR.device()`` returns jnp arrays for kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import ml_dtypes
import numpy as np


# ---------------------------------------------------------------------------
# Element-granularity formats (simulator + reference dataflows)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CSR:
    """Compressed sparse row. ``indptr`` has length ``M+1``."""

    shape: Tuple[int, int]
    indptr: np.ndarray   # int32 (M+1,)
    indices: np.ndarray  # int32 (nnz,) column ids, sorted within a row
    data: np.ndarray     # float32 (nnz,)

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @property
    def density(self) -> float:
        m, n = self.shape
        return self.nnz / float(max(m * n, 1))

    def row(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        lo, hi = int(self.indptr[i]), int(self.indptr[i + 1])
        return self.indices[lo:hi], self.data[lo:hi]

    def row_lengths(self) -> np.ndarray:
        return np.diff(self.indptr)

    def to_dense(self) -> np.ndarray:
        m, n = self.shape
        out = np.zeros((m, n), dtype=self.data.dtype)
        rows = np.repeat(np.arange(m), self.row_lengths())
        out[rows, self.indices] = self.data
        return out

    def transpose(self) -> "CSR":
        return csr_from_coo(
            self.shape[::-1],
            self.indices,
            np.repeat(np.arange(self.shape[0]), self.row_lengths()),
            self.data,
        )

    @staticmethod
    def from_dense(a: np.ndarray) -> "CSR":
        m, n = a.shape
        rows, cols = np.nonzero(a)
        return csr_from_coo((m, n), rows, cols, a[rows, cols])


def csr_from_coo(shape, rows, cols, vals) -> CSR:
    """Build a CSR with rows ascending and columns sorted within each row."""
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals)
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    # merge duplicates (sum semantics)
    if rows.size:
        key = rows * shape[1] + cols
        uniq, inv = np.unique(key, return_inverse=True)
        if uniq.size != key.size:
            merged = np.zeros(uniq.size, dtype=np.float64)
            np.add.at(merged, inv, vals.astype(np.float64))
            rows = (uniq // shape[1]).astype(np.int64)
            cols = (uniq % shape[1]).astype(np.int64)
            vals = merged.astype(vals.dtype)
    indptr = np.zeros(shape[0] + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    return CSR(
        shape=tuple(shape),
        indptr=indptr.astype(np.int64),
        indices=cols.astype(np.int32),
        data=vals.astype(np.float32),
    )


@dataclasses.dataclass
class DCSR:
    """Doubly compressed sparse rows — only non-empty rows are materialized.

    ``row_ids[i]`` is the Cartesian row index of compressed row ``i``;
    ``indptr`` has length ``len(row_ids)+1``.  The paper stores ``B`` this way
    so that the scheduler skips empty rows in O(1) (§IV-B).
    """

    shape: Tuple[int, int]
    row_ids: np.ndarray  # int32 (nrows_nonempty,)
    indptr: np.ndarray   # int64 (nrows_nonempty+1,)
    indices: np.ndarray  # int32 (nnz,)
    data: np.ndarray     # float32 (nnz,)

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @staticmethod
    def from_csr(a: CSR) -> "DCSR":
        lengths = a.row_lengths()
        nonempty = np.nonzero(lengths > 0)[0]
        indptr = np.concatenate([[0], np.cumsum(lengths[nonempty])])
        # gather nnz in non-empty-row order (CSR already contiguous per row)
        chunks_idx = []
        chunks_val = []
        for r in nonempty:
            lo, hi = a.indptr[r], a.indptr[r + 1]
            chunks_idx.append(a.indices[lo:hi])
            chunks_val.append(a.data[lo:hi])
        indices = np.concatenate(chunks_idx) if chunks_idx else np.zeros(0, np.int32)
        data = np.concatenate(chunks_val) if chunks_val else np.zeros(0, np.float32)
        return DCSR(
            shape=a.shape,
            row_ids=nonempty.astype(np.int32),
            indptr=indptr.astype(np.int64),
            indices=indices.astype(np.int32),
            data=data.astype(np.float32),
        )

    def lookup(self, r: int) -> int:
        """Compressed index of Cartesian row ``r`` or -1 (O(log nrows))."""
        pos = np.searchsorted(self.row_ids, r)
        if pos < self.row_ids.size and self.row_ids[pos] == r:
            return int(pos)
        return -1


@dataclasses.dataclass
class CSC:
    """Compressed sparse column (A's storage; SELECTA scans columns of A)."""

    shape: Tuple[int, int]
    indptr: np.ndarray   # (K+1,) column pointers
    indices: np.ndarray  # (nnz,) row ids, sorted within a column
    data: np.ndarray

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def col(self, k: int) -> Tuple[np.ndarray, np.ndarray]:
        lo, hi = int(self.indptr[k]), int(self.indptr[k + 1])
        return self.indices[lo:hi], self.data[lo:hi]

    def col_lengths(self) -> np.ndarray:
        return np.diff(self.indptr)

    @staticmethod
    def from_csr(a: CSR) -> "CSC":
        t = a.transpose()  # CSR of A^T == CSC of A
        return CSC(shape=a.shape, indptr=t.indptr, indices=t.indices, data=t.data)

    def to_dense(self) -> np.ndarray:
        m, k = self.shape
        out = np.zeros((m, k), dtype=self.data.dtype)
        cols = np.repeat(np.arange(k), self.col_lengths())
        out[self.indices, cols] = self.data
        return out


# ---------------------------------------------------------------------------
# Block-granularity format (TPU kernels)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BSR:
    """Block-sparse rows: nonzero dense tiles of shape ``(bm, bk)``.

    ``blocks[i]`` is the dense tile for the i-th stored block; block
    coordinates are ``(brow[i], bcol[i])`` in block units.  Blocks are sorted
    row-major ``(brow, bcol)``.
    """

    shape: Tuple[int, int]          # logical (M, K)
    block_shape: Tuple[int, int]    # (bm, bk)
    brow: np.ndarray                # int32 (nblocks,)
    bcol: np.ndarray                # int32 (nblocks,)
    blocks: np.ndarray              # float32 (nblocks, bm, bk)

    @property
    def nblocks(self) -> int:
        return int(self.brow.shape[0])

    @property
    def grid(self) -> Tuple[int, int]:
        bm, bk = self.block_shape
        return (self.shape[0] + bm - 1) // bm, (self.shape[1] + bk - 1) // bk

    @property
    def block_density(self) -> float:
        gm, gk = self.grid
        return self.nblocks / float(max(gm * gk, 1))

    def block_mask(self) -> np.ndarray:
        gm, gk = self.grid
        m = np.zeros((gm, gk), dtype=bool)
        m[self.brow, self.bcol] = True
        return m

    def to_dense(self) -> np.ndarray:
        bm, bk = self.block_shape
        gm, gk = self.grid
        out = np.zeros((gm * bm, gk * bk), dtype=self.blocks.dtype)
        for i in range(self.nblocks):
            r, c = int(self.brow[i]), int(self.bcol[i])
            out[r * bm:(r + 1) * bm, c * bk:(c + 1) * bk] = self.blocks[i]
        return out[: self.shape[0], : self.shape[1]]

    @staticmethod
    def from_dense(a: np.ndarray, block_shape: Tuple[int, int],
                   keep_threshold: float = 0.0) -> "BSR":
        """Tile a dense matrix; keep blocks whose max-abs exceeds threshold."""
        m, k = a.shape
        bm, bk = block_shape
        gm, gk = (m + bm - 1) // bm, (k + bk - 1) // bk
        pad = np.zeros((gm * bm, gk * bk), dtype=np.float32)
        pad[:m, :k] = a
        tiles = pad.reshape(gm, bm, gk, bk).transpose(0, 2, 1, 3)
        mask = np.abs(tiles).max(axis=(2, 3)) > keep_threshold
        brow, bcol = np.nonzero(mask)
        order = np.lexsort((bcol, brow))
        brow, bcol = brow[order], bcol[order]
        return BSR(
            shape=(m, k),
            block_shape=(bm, bk),
            brow=brow.astype(np.int32),
            bcol=bcol.astype(np.int32),
            blocks=tiles[brow, bcol].astype(np.float32),
        )

    @staticmethod
    def random(key: np.random.Generator, shape, block_shape, block_density: float,
               dtype=np.float32) -> "BSR":
        m, k = shape
        bm, bk = block_shape
        gm, gk = (m + bm - 1) // bm, (k + bk - 1) // bk
        mask = key.random((gm, gk)) < block_density
        if not mask.any():  # ensure at least one block
            mask[key.integers(gm), key.integers(gk)] = True
        brow, bcol = np.nonzero(mask)
        blocks = key.standard_normal((brow.size, bm, bk)).astype(dtype)
        return BSR(shape=(m, k), block_shape=(bm, bk),
                   brow=brow.astype(np.int32), bcol=bcol.astype(np.int32),
                   blocks=blocks)

    def row_major_order(self) -> "BSR":
        order = np.lexsort((self.bcol, self.brow))
        return BSR(self.shape, self.block_shape, self.brow[order],
                   self.bcol[order], self.blocks[order])

    def col_major_order(self) -> "BSR":
        order = np.lexsort((self.brow, self.bcol))
        return BSR(self.shape, self.block_shape, self.brow[order],
                   self.bcol[order], self.blocks[order])


# ---------------------------------------------------------------------------
# Quantized block storage (int8 / fp8-e4m3 payloads + per-block fp32 scales)
# ---------------------------------------------------------------------------

#: Supported quantized payload dtypes.  ``"fp32"`` is the unquantized
#: sentinel used by plans; it never appears as a :class:`QuantizedBlocks`
#: dtype.  fp8 is e4m3 (the inference-standard variant: 4 exponent bits,
#: max finite value 448) via ml_dtypes, so ``core`` stays jax-free.
QUANT_DTYPES = {
    "int8": np.dtype(np.int8),
    "fp8": np.dtype(ml_dtypes.float8_e4m3fn),
}

#: Largest representable magnitude of each payload dtype — the per-block
#: absmax maps onto this value, so the full quantization range is used.
QUANT_MAX = {"int8": 127.0, "fp8": 448.0}

#: Scale granularity suffix.  A quantization *mode* is either a bare payload
#: dtype (``"int8"`` — one fp32 scale per block) or ``"<dtype>.rowwise"``
#: (``"int8.rowwise"`` — one fp32 scale per *row of each block*, shape
#: ``(nblocks, bm)``, for outlier-heavy weights where a single hot row
#: would otherwise crush the whole block's resolution).
ROWWISE_SUFFIX = ".rowwise"

#: Every accepted quantization mode string.
QUANT_MODES = tuple(QUANT_DTYPES) + tuple(
    d + ROWWISE_SUFFIX for d in QUANT_DTYPES)


def quant_base_dtype(mode: str) -> str:
    """Payload dtype name of a quantization mode (``"int8.rowwise"`` → ``"int8"``).

    ``"fp32"`` (the unquantized plan sentinel) passes through unchanged so
    callers can feed ``plan.block_dtype`` directly.
    """
    base = mode.split(".", 1)[0]
    return base


def quant_is_rowwise(mode: str) -> bool:
    """True when ``mode`` carries per-row-of-block scales."""
    return mode.endswith(ROWWISE_SUFFIX)


def _check_quant_dtype(dtype: str) -> str:
    """Validate a quantization *mode* string; returns it unchanged.

    Accepts bare payload dtypes and their ``.rowwise`` variants."""
    if quant_base_dtype(dtype) not in QUANT_DTYPES or (
            "." in dtype and not quant_is_rowwise(dtype)):
        raise ValueError(f"unknown quantized block dtype {dtype!r}; "
                         f"available: {QUANT_MODES}")
    return dtype


@dataclasses.dataclass
class QuantizedBlocks:
    """Quantized BSR block values: low-precision payload + fp32 scales.

    ``payload[i]`` holds block ``i``'s tile in
    ``QUANT_DTYPES[quant_base_dtype(dtype)]``.  Scale granularity follows
    the mode string in ``dtype``:

    * per-block (``"int8"``, ``"fp8"``): ``scales`` is ``(nblocks,)`` and
      ``dequant = payload.astype(f32) * scales[i]``;
    * per-row-of-block (``"int8.rowwise"``, ``"fp8.rowwise"``): ``scales``
      is ``(nblocks, bm)`` and ``dequant = payload.astype(f32) *
      scales[i][:, None]``.

    Block order is the carrier BSR's storage order — quantization never
    reorders, so realizing a quantized plan uploads both arrays verbatim
    (the zero-copy contract).
    """

    payload: np.ndarray   # (nblocks, bm, bk) int8 or float8_e4m3fn
    scales: np.ndarray    # (nblocks,) or (nblocks, bm) float32, positive
    dtype: str            # quantization mode (key into QUANT_MODES)

    @property
    def nblocks(self) -> int:
        return int(self.payload.shape[0])

    @property
    def block_shape(self) -> Tuple[int, int]:
        return tuple(self.payload.shape[1:])

    @property
    def nbytes(self) -> int:
        """Total storage bytes: quantized payload + the fp32 scales."""
        return int(self.payload.size * self.payload.itemsize
                   + self.scales.size * self.scales.itemsize)


def quantize_blocks(blocks, dtype: str = "int8") -> QuantizedBlocks:
    """Absmax quantization of a ``(nblocks, bm, bk)`` tile array.

    Per-block modes scale each block by ``absmax / QUANT_MAX`` so the
    block's largest element lands exactly on the dtype's largest magnitude;
    ``.rowwise`` modes do the same per block *row*, so one hot row no
    longer crushes the resolution of the other ``bm - 1`` rows.  An
    all-zero block (or row) gets ``scale = 1.0`` (payload is all zeros
    anyway) — the scale is never zero, so dequantization can never produce
    NaN/inf.
    """
    _check_quant_dtype(dtype)
    base = quant_base_dtype(dtype)
    blocks = np.asarray(blocks, dtype=np.float32)
    if blocks.ndim != 3:
        raise ValueError(f"blocks must be (nblocks, bm, bk), got shape "
                         f"{blocks.shape}")
    if quant_is_rowwise(dtype):
        amax = np.abs(blocks).max(axis=2)                 # (nblocks, bm)
        scales = np.where(amax > 0, amax / QUANT_MAX[base],
                          1.0).astype(np.float32)
        scaled = blocks / scales[:, :, None]
    else:
        amax = np.abs(blocks).max(axis=(1, 2))            # (nblocks,)
        scales = np.where(amax > 0, amax / QUANT_MAX[base],
                          1.0).astype(np.float32)
        scaled = blocks / scales[:, None, None]
    if base == "int8":
        payload = np.clip(np.rint(scaled), -127.0, 127.0).astype(np.int8)
    else:
        payload = scaled.astype(QUANT_DTYPES[base])  # RTNE cast (ml_dtypes)
    return QuantizedBlocks(payload=payload, scales=scales, dtype=dtype)


def dequantize_blocks(q: QuantizedBlocks) -> np.ndarray:
    """fp32 reconstruction of quantized blocks (round-trip helper)."""
    payload = np.asarray(q.payload, dtype=np.float32)
    scales = np.asarray(q.scales, dtype=np.float32)
    if scales.ndim == 2:                                  # rowwise
        return payload * scales[:, :, None]
    return payload * scales[:, None, None]


def quant_error_bound(dtype: str) -> float:
    """Per-element round-trip bound as a fraction of the scale group's absmax.

    int8: half an integer step of the 254-step range → ``amax / 254``.
    fp8-e4m3 (3 mantissa bits): relative error ≤ 2⁻⁴ of the element, which
    is ≤ ``amax / 16``; subnormal payloads only tighten the bound.
    Rowwise modes obey the same fraction of the per-*row* absmax, which is
    never larger than the block absmax — the bound only tightens.
    """
    _check_quant_dtype(dtype)
    return {"int8": 1.0 / 254.0, "fp8": 1.0 / 16.0}[quant_base_dtype(dtype)]


def random_csr(rng: np.random.Generator, shape, density: float) -> CSR:
    """Uniform random sparse matrix (iid Bernoulli pattern)."""
    m, n = shape
    nnz = max(1, int(round(density * m * n)))
    # sample without replacement in flat index space
    flat = rng.choice(m * n, size=min(nnz, m * n), replace=False)
    rows, cols = flat // n, flat % n
    vals = rng.standard_normal(rows.size).astype(np.float32)
    return csr_from_coo((m, n), rows, cols, vals)


def spgemm_reference(a: CSR, b: CSR) -> CSR:
    """Ground-truth C = A @ B via dense numpy (for tests and small sims)."""
    c = a.to_dense() @ b.to_dense()
    return CSR.from_dense(c)
