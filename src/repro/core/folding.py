"""Folding — mapping irregular virtual rows onto regular hardware (§IV-D).

Two views of the same idea:

* **Microarchitectural (simulator)**: :func:`spatial_fold` places virtual rows
  of C onto an ``R×P`` PE occupancy grid with the paper's neighbor priority
  {right, up, down, left}; overflow beyond the array spills to the per-row
  scratchpad (**temporal folding**, :func:`temporal_fold_spills`).

* **TPU (scheduler)**: a "PE row" becomes a Pallas grid slot / device lane.
  :func:`fold_segments` splits oversized reduction segments into bounded
  chunks, and :func:`balance_bins` packs work into lanes minimizing the
  makespan (greedy LPT) — the load-balance objective of spatial folding at the
  granularity a TPU can exploit.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Microarchitectural folding (paper-faithful placement model)
# ---------------------------------------------------------------------------

_NEIGHBOR_PRIORITY = ((0, 1), (-1, 0), (1, 0), (0, -1))  # right, up, down, left


def spatial_fold(row_lengths: np.ndarray, R: int, P: int,
                 enabled: bool = True) -> dict:
    """Place virtual rows of the given lengths onto an R×P occupancy grid.

    Rows are anchored at their home PE row (``x % R``, column 0) and grow
    following the paper's priority order.  With ``enabled=False`` a virtual row
    may only use its home physical row (the no-folding baseline): the rest
    spills.

    Returns occupancy/utilization/spill telemetry.
    """
    occ = np.zeros((R, P), dtype=bool)
    spills = 0
    placed = 0
    for x, length in enumerate(row_lengths):
        r0 = x % R
        # anchor: first free cell in the home row, else home cell conflicts
        cur = None
        for p in range(P):
            if not occ[r0, p]:
                cur = (r0, p)
                break
        if cur is None:
            spills += int(length)
            continue
        remaining = int(length)
        while remaining > 0:
            r, p = cur
            occ[r, p] = True
            placed += 1
            remaining -= 1
            if remaining == 0:
                break
            nxt = None
            for dr, dp in _NEIGHBOR_PRIORITY:
                rr, pp = r + dr, p + dp
                if not enabled and rr != r0:
                    continue
                if 0 <= rr < R and 0 <= pp < P and not occ[rr, pp]:
                    nxt = (rr, pp)
                    break
            if nxt is None:
                spills += remaining        # temporal fold: overflow to spad
                remaining = 0
            else:
                cur = nxt
    total = int(np.sum(row_lengths))
    return {
        "placed": placed,
        "spills": spills,
        "utilization": placed / float(R * P),
        "spill_fraction": spills / float(max(total, 1)),
        "occupancy": occ,
    }


def temporal_fold_spills(row_lengths: np.ndarray, capacity: int) -> int:
    """Entries beyond per-row capacity that go to the scratchpad."""
    lengths = np.asarray(row_lengths, dtype=np.int64)
    return int(np.maximum(lengths - capacity, 0).sum())


# ---------------------------------------------------------------------------
# TPU-grain folding: segment splitting + lane balancing
# ---------------------------------------------------------------------------


def fold_segments(seg_sizes: np.ndarray, fold_len: int) -> Tuple[np.ndarray, np.ndarray]:
    """Split segments longer than ``fold_len`` into chunks.

    Returns ``(chunk_seg, chunk_size)``: for each resulting chunk, the index of
    its parent segment and its size.  Chunks of the same parent must be
    reduced together afterwards (temporal folding's partial-sum merge).
    """
    chunk_seg: List[int] = []
    chunk_size: List[int] = []
    for i, s in enumerate(np.asarray(seg_sizes, dtype=np.int64)):
        s = int(s)
        while s > fold_len:
            chunk_seg.append(i)
            chunk_size.append(fold_len)
            s -= fold_len
        if s > 0:
            chunk_seg.append(i)
            chunk_size.append(s)
    return np.asarray(chunk_seg, dtype=np.int64), np.asarray(chunk_size, dtype=np.int64)


def balance_bins(work_sizes: np.ndarray, n_bins: int) -> Tuple[np.ndarray, dict]:
    """Greedy LPT makespan packing: assign each work item to the least-loaded bin.

    Returns (assignment, stats) where stats reports the load imbalance
    ``max_load / mean_load`` — the quantity spatial folding drives toward 1.
    """
    sizes = np.asarray(work_sizes, dtype=np.int64)
    order = np.argsort(-sizes)
    loads = np.zeros(n_bins, dtype=np.int64)
    assign = np.zeros(sizes.size, dtype=np.int64)
    for i in order:
        b = int(np.argmin(loads))
        assign[i] = b
        loads[b] += sizes[i]
    mean = loads.mean() if n_bins else 0.0
    stats = {
        "max_load": int(loads.max(initial=0)),
        "mean_load": float(mean),
        "imbalance": float(loads.max(initial=0) / mean) if mean > 0 else 1.0,
        "loads": loads,
    }
    return assign, stats


def round_robin_bins(work_sizes: np.ndarray, n_bins: int) -> Tuple[np.ndarray, dict]:
    """Static round-robin baseline (what a static dataflow would do)."""
    sizes = np.asarray(work_sizes, dtype=np.int64)
    assign = np.arange(sizes.size, dtype=np.int64) % max(n_bins, 1)
    loads = np.zeros(n_bins, dtype=np.int64)
    np.add.at(loads, assign, sizes)
    mean = loads.mean() if n_bins else 0.0
    stats = {
        "max_load": int(loads.max(initial=0)),
        "mean_load": float(mean),
        "imbalance": float(loads.max(initial=0) / mean) if mean > 0 else 1.0,
        "loads": loads,
    }
    return assign, stats
