"""Schedule-policy registry: dataflows as a pluggable configuration space.

The paper's thesis is that one *dynamic* dataflow (``segment``) subsumes the
static ones; Flexagon (PAPERS.md) frames dataflows as configurations to be
selected per workload.  This registry is the code form of that framing: a
policy is a named pair of ordering functions — one for SpMM work items, one
for SpGEMM triples — and everything downstream (schedule builders, the
``repro.api`` planner, benchmarks) enumerates or looks up policies here
instead of hard-coding ``if/elif`` string chains.

Built-in policies (``segment``, ``gustavson``, ``outer``) are registered by
:mod:`repro.core.schedule` when it defines their ordering functions; user
policies register via :func:`register_policy` (re-exported as
``repro.api.register_policy``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

# (m, k) per-item block coordinates -> permutation of item indices
SpmmOrderFn = Callable[[np.ndarray, np.ndarray], np.ndarray]
# (m, n, k, c) per-triple coordinates + C slot -> permutation of triple indices
SpgemmOrderFn = Callable[[np.ndarray, np.ndarray, np.ndarray, np.ndarray],
                         np.ndarray]
# kind ("spmm"/"spgemm") + keyword coordinate/tile args -> traffic dict | None
CostHintFn = Callable[..., Optional[dict]]


@dataclasses.dataclass(frozen=True)
class SchedulePolicy:
    """A named work-item ordering for both Segment kernels.

    ``spmm_order(m, k)`` and ``spgemm_order(m, n, k, c)`` return permutations;
    ``supports_fold`` marks policies whose output runs may be split by
    temporal folding (static orders have fixed run structure, so folding them
    is meaningless and is ignored by the builders).

    ``cost_hint`` is an optional closed-form traffic estimator the autotuner
    (:mod:`repro.tune`) and :func:`repro.sim.baselines.dataflow_estimates`
    use to score this dataflow against others *without* building a full
    plan.  The call convention is keyword-based::

        cost_hint("spmm",   m=brow, k=bcol, bm=..., bk=..., n_cols=...)
        cost_hint("spgemm", m=..., n=..., k=..., c=..., a_idx=..., b_idx=...,
                  bm=..., bk=..., bn=...)

    returning a dict shaped like :func:`repro.core.schedule.lane_traffic_spmm`
    output (``a_bytes``/``b_bytes``/``c_bytes``/``total``/fetch counts) at
    default knobs (one lane, fp32, pipelined), or ``None`` when the policy
    cannot estimate that kind analytically.  Dynamic policies whose order
    *is* the schedule (``segment``) leave this unset — the tuner evaluates
    them by building the schedule.
    """

    name: str
    spmm_order: SpmmOrderFn
    spgemm_order: SpgemmOrderFn
    supports_fold: bool = False
    description: str = ""
    # monotone registration serial: plan caches key on (name, serial) so a
    # re-registered policy can never be served another definition's schedule
    serial: int = 0
    cost_hint: Optional[CostHintFn] = None


_REGISTRY: Dict[str, SchedulePolicy] = {}
_SERIAL = 0


def register_policy(name: str, *, spmm_order: SpmmOrderFn,
                    spgemm_order: SpgemmOrderFn, supports_fold: bool = False,
                    description: str = "",
                    cost_hint: Optional[CostHintFn] = None,
                    overwrite: bool = False) -> SchedulePolicy:
    """Register a schedule policy under ``name``.

    Raises ``ValueError`` on duplicate names unless ``overwrite=True`` —
    silent replacement of a built-in would change numerics-by-traffic
    behaviour everywhere at once.  ``"auto"`` is reserved: it names the
    planner's adaptive dataflow-selection mode, not a policy.
    """
    if not name or not isinstance(name, str):
        raise ValueError(f"policy name must be a non-empty string, got {name!r}")
    if name == "auto":
        raise ValueError("policy name 'auto' is reserved for "
                         "plan_matmul(policy='auto') dataflow selection")
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"policy {name!r} is already registered "
                         f"(pass overwrite=True to replace it)")
    global _SERIAL
    _SERIAL += 1
    policy = SchedulePolicy(name=name, spmm_order=spmm_order,
                            spgemm_order=spgemm_order,
                            supports_fold=supports_fold,
                            description=description, serial=_SERIAL,
                            cost_hint=cost_hint)
    _REGISTRY[name] = policy
    return policy


def unregister_policy(name: str) -> None:
    """Remove a policy (primarily for tests registering throwaway policies)."""
    _REGISTRY.pop(name, None)


def get_policy(name: str) -> SchedulePolicy:
    try:
        return _REGISTRY[name]
    except KeyError:
        if name == "auto":
            raise ValueError(
                "'auto' is not a registered policy — it is the planner's "
                "dataflow-selection mode; pass policy='auto' to "
                "repro.api.plan_matmul (which dispatches to the winning "
                "registered policy) instead of resolving it here") from None
        raise ValueError(
            f"unknown policy {name!r}; available: {available_policies()}"
        ) from None


def available_policies() -> Tuple[str, ...]:
    """Registered policy names, registration order (built-ins first)."""
    return tuple(_REGISTRY)
