from .rules import (act_constrain, batch_pspec, batch_pspecs, cache_pspec,
                    constrain, constrain_like_params, dp_axes, make_shardings,
                    param_pspec, params_pspecs, sanitize_pspec)

__all__ = ["act_constrain", "batch_pspec", "batch_pspecs", "cache_pspec",
           "constrain", "dp_axes", "make_shardings", "param_pspec",
           "params_pspecs", "sanitize_pspec", "constrain_like_params"]
