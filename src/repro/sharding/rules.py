"""Logical sharding rules: parameter/batch PartitionSpecs for any mesh.

Strategy (DESIGN.md §5):

* **TP** on ``model``: attention heads, FFN hidden, vocab, experts;
* **FSDP** on ``data``: the *other* dimension of every large matrix is
  sharded too, so params + optimizer state scale down with the full slice
  count (104B × 12 B/param ÷ 256 ≈ 4.9 GB/chip);
* **DP** on ``pod`` (multi-pod): pure replication — gradients all-reduce
  across the DCN; FSDP stays *within* a pod so param all-gathers ride ICI.

Rules are name/shape heuristics over the parameter pytree — the same table
MaxText-style frameworks encode, kept in one place.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# parameter-name classes
_COL_PARALLEL = {"up", "gate", "wq", "wk", "wv", "wg", "wr", "in_x", "in_g",
                 "a_gate", "x_gate", "cm_k", "w_lora_a", "router"}
_ROW_PARALLEL = {"down", "wo", "out", "cm_v", "w_lora_b"}
_REPLICATED = {"scale", "b", "a_param", "mix", "cm_mix", "u", "conv",
               "w_bias"}


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "name"):
            names.append(str(p.name))
    return tuple(names)


def param_pspec(path, leaf, *, dp: str = "data", tp: str = "model") -> P:
    names = _path_names(path)
    name = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""
    ndim = np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim
    # stacked-layer leading dim (scan stacks) is never sharded; detect via
    # ndim relative to the logical rank below.

    if name == "blocks":
        # Segment-kernel BSR blocks: schedule indexes the full block list —
        # replicate (sparse layers are small; device-level sharding goes
        # through core.schedule.shard_schedule instead)
        return P()
    if name == "table":                      # (vocab, d) embedding
        return P(tp, dp)
    if name in _REPLICATED:
        return P()
    if name == "w" and parent in _COL_PARALLEL:
        return _last2(ndim, dp, tp)
    if name == "w" and parent in _ROW_PARALLEL:
        return _last2(ndim, tp, dp)
    if parent in ("moe",) or name in ("gate", "up", "down"):
        pass
    if name in ("gate", "up") and ndim >= 3:   # (E, d, ff) expert weights
        return _expert(ndim, tp, dp)
    if name == "down" and ndim >= 3:           # (E, ff, d)
        return _expert(ndim, tp, dp, swap=True)
    if ndim >= 2:
        return _last2(ndim, dp, tp)
    return P()


def _last2(ndim, a, b) -> P:
    """Shard the last two dims as (a, b); leading (stack) dims unsharded."""
    pad = [None] * (ndim - 2)
    return P(*pad, a, b)


def _expert(ndim, tp, dp, swap=False) -> P:
    pad = [None] * (ndim - 3)
    if swap:
        return P(*pad, tp, None, dp)
    return P(*pad, tp, dp, None)


def params_pspecs(params, fsdp="data"):
    """Pytree of PartitionSpecs matching a parameter pytree.

    ``fsdp`` may be ``("data", "pod")`` for cross-pod ZeRO-3 (giants whose
    state exceeds one pod's HBM); sanitize drops absent axes."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_pspec(path, leaf, dp=fsdp), params)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Batch-sharding axes: ('pod','data') multi-pod, ('data',) single."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_pspec(mesh: Mesh, ndim: int) -> P:
    dp = dp_axes(mesh)
    axes = [dp] + [None] * (ndim - 1)
    return P(*axes)


def batch_pspecs(mesh: Mesh, batch):
    return jax.tree.map(
        lambda leaf: batch_pspec(mesh, np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim),
        batch)


def cache_pspec(mesh: Mesh, leaf) -> P:
    """Decode-state sharding: batch on dp, axis-2 on model.

    KV caches are (layers, B, T, n_kv, hd) → **sequence-parallel decode**:
    the 32k KV timeline shards over the model axis (1.1 TB of command-r
    cache → 2.1 GB/chip); attention reductions over T psum across shards.
    RWKV state (layers, B, H, hd, hd) shards heads on the same rule.
    """
    dp = dp_axes(mesh)
    ndim = leaf.ndim
    if ndim >= 5:
        tp = "model" if (leaf.shape[2] % mesh.shape["model"] == 0) else None
        return P(None, dp, tp, *([None] * (ndim - 3)))
    if ndim == 4 and leaf.shape[2] >= 1024 \
            and leaf.shape[2] % mesh.shape["model"] == 0:
        # int8-KV scale arrays (layers, B, T, n_kv): T-shard to match the
        # quantized cache (otherwise every layer reshards them — §Perf C4)
        return P(None, dp, "model", None)
    if ndim >= 2:
        return P(None, dp, *([None] * (ndim - 2)))
    return P()


def sanitize_pspec(mesh: Mesh, spec: P, shape) -> P:
    """Drop sharding on dims the mesh doesn't divide (Megatron pads vocab;
    everything else falls back to replication on that dim)."""
    dims = tuple(shape)
    new = []
    for i, axes in enumerate(spec):
        if axes is None or i >= len(dims):
            new.append(None)
            continue
        ax_tuple = axes if isinstance(axes, tuple) else (axes,)
        keep = []
        size = 1
        for a in ax_tuple:
            if a in mesh.axis_names and dims[i] % (size * mesh.shape[a]) == 0:
                keep.append(a)
                size *= mesh.shape[a]
        new.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    return P(*new)


def make_shardings(mesh: Mesh, pspecs, leaves=None):
    """NamedShardings from specs; with ``leaves`` given, specs are sanitized
    against the actual shapes first."""
    if leaves is None:
        return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                            is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(
        lambda s, l: NamedSharding(mesh, sanitize_pspec(mesh, s, l.shape)),
        pspecs, leaves, is_leaf=lambda x: isinstance(x, P))


def constrain(x, mesh: Mesh, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_like_params(grads, fsdp="data"):
    """Pin gradient shardings to the parameter rules (inside an abstract
    mesh context).  Forces XLA to reduce-scatter per-layer weight grads into
    the FSDP layout instead of materializing them replicated."""
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:
        return grads
    if m is None or not m.axis_names or "model" not in m.axis_names:
        return grads
    def fix(path, g):
        spec = sanitize_pspec(m, param_pspec(path, g, dp=fsdp), g.shape)
        return jax.lax.with_sharding_constraint(g, spec)
    return jax.tree_util.tree_map_with_path(fix, grads)


def act_constrain(x, kind: str):
    """Mesh-aware activation constraint; no-op outside a mesh context.

    kinds: ``hidden`` (B, T, D) batch-sharded; ``logits`` (B, T, V) batch +
    vocab(model)-sharded (padded vocab is always divisible).
    """
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:
        return x
    if m is None or not m.axis_names or "model" not in m.axis_names:
        return x
    dp = tuple(a for a in ("pod", "data") if a in m.axis_names)
    if not dp:
        return x
    if x.shape[0] % int(np.prod([m.shape[a] for a in dp])) != 0:
        return x
    tp_ok = lambda dim: dim % m.shape["model"] == 0
    if kind == "logits":
        spec = P(dp, *([None] * (x.ndim - 2)), "model")
    elif kind == "seq" and x.ndim >= 2 and tp_ok(x.shape[1]):
        # sequence parallelism: residuals shard T over the model axis —
        # saved-activation memory drops by the TP degree
        spec = P(dp, "model", *([None] * (x.ndim - 2)))
    elif kind == "ffn" and tp_ok(x.shape[-1]):
        # FFN hidden sharded on model — keeps the bwd dW contraction
        # partial-per-shard (reduce-scatter, not replicate)
        spec = P(dp, *([None] * (x.ndim - 2)), "model")
    elif kind == "heads" and x.ndim == 4 and tp_ok(x.shape[2]):
        spec = P(dp, None, "model", None)
    elif kind == "scores_t" and x.ndim == 4 and tp_ok(x.shape[-1]):
        # decode attention scores (B, H, Tq, Tk): keep the KV timeline
        # sharded on model — softmax/PV reduce via psum instead of
        # resharding the whole cache slice every layer
        spec = P(dp, None, None, "model")
    elif kind == "expert" and x.ndim == 4 and tp_ok(x.shape[1]):
        # expert-parallel dispatch buffers: batch on dp, experts on model
        spec = P(dp, "model", None, None)
    elif kind == "expert" and x.ndim == 3 and tp_ok(x.shape[0]):
        spec = P("model", None, None)
    else:
        spec = P(dp, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)
