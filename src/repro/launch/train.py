"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b \
        --reduced --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/run1

``--reduced`` trains the smoke-scale config (CPU-friendly); full-scale runs
use the production mesh on real hardware (the dry-run proves the lowering).
``--sparse-ffn`` switches the FFN to the Segment block-sparse kernel path
(the paper's technique as a training feature).
"""
from __future__ import annotations

import argparse
import dataclasses
import json

from repro.configs import REGISTRY, get_config, reduced_config
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.runtime import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list(REGISTRY))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--sparse-ffn", action="store_true")
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--layers", type=int, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    over = {}
    if args.sparse_ffn:
        over.update(ffn_block_sparse=True, ffn_block=32, ffn_density=0.5)
    if args.d_model:
        over["d_model"] = args.d_model
    if args.layers:
        over["n_layers"] = args.layers
    if over:
        cfg = dataclasses.replace(cfg, **over)

    shape = ShapeConfig("cli", "train", seq_len=args.seq,
                        global_batch=args.batch, accum_steps=args.accum)
    tcfg = TrainerConfig(steps=args.steps, peak_lr=args.lr,
                         accum_steps=args.accum, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every,
                         log_every=max(1, args.steps // 20))
    trainer = Trainer(build_model(cfg), cfg, shape, tcfg)
    out = trainer.run()
    for h in out["history"]:
        print(f"step {h['step']:6d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.3f}")
    print(json.dumps({"final_loss": out["final_loss"],
                      "params": cfg.param_count()}))


if __name__ == "__main__":
    main()
