"""Production mesh construction + small cross-version jax.sharding shims.

Functions (not module-level constants) so importing this module never
touches jax device state — the dry-run pins the device count via XLA_FLAGS
*before* any jax initialization.

``jax.sharding.AxisType`` / ``jax.set_mesh`` only exist in newer JAX; on
older versions every mesh axis is implicitly Auto and the ``Mesh`` object
itself is the context manager, so the helpers degrade gracefully.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` with explicit-Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` on new JAX; the mesh's own resource-env
    context manager on old JAX."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False, n_pods: int = 2):
    """16×16 = 256 chips per pod; ``n_pods``×16×16 multi-pod (default 2 =
    512 chips, the assignment's production mesh; larger pod counts are used
    to size state-dominated giants)."""
    shape = (n_pods, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(n_data: int = 4, n_model: int = 2):
    """Small mesh for fake-device subprocess tests."""
    return make_mesh((n_data, n_model), ("data", "model"))
