"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run pins the device count via XLA_FLAGS
*before* any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, n_pods: int = 2):
    """16×16 = 256 chips per pod; ``n_pods``×16×16 multi-pod (default 2 =
    512 chips, the assignment's production mesh; larger pod counts are used
    to size state-dominated giants)."""
    shape = (n_pods, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=auto)


def make_test_mesh(n_data: int = 4, n_model: int = 2):
    """Small mesh for fake-device subprocess tests."""
    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
