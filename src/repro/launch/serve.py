"""Continuous-batching serving driver.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
        --reduced --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import REGISTRY, get_config, reduced_config
from repro.models import build_model
from repro.runtime import Engine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list(REGISTRY))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--eos", type=int, default=None,
                    help="retire a request early when it emits this token")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, rng.integers(4, 32),
                                        dtype=np.int32).astype(np.int32),
                    max_new_tokens=args.max_new, eos_token=args.eos)
            for _ in range(args.requests)]
    t0 = time.time()
    engine.generate(reqs)
    dt = time.time() - t0
    total = sum(r.out_tokens.size for r in reqs)
    print(f"{len(reqs)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s) — compiled shapes: "
          f"{engine.compiled_shapes}")
    for i, r in enumerate(reqs[:4]):
        print(f"req{i}: prompt_len={len(r.prompt)} out={r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
