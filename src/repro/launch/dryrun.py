import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This proves the distribution config is coherent without hardware: for the
16×16 single-pod mesh and the 2×16×16 multi-pod mesh, the train / prefill /
decode step of every assigned architecture must ``.lower().compile()``
under the production shardings, fit per-device memory, and yield the
cost/collective numbers the roofline analysis (§Roofline) consumes.

Usage:
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k
  python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
  python -m repro.launch.dryrun --all          # every live cell, subprocesses
Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import REGISTRY, SHAPES, cell_is_live, get_config
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.models import build_model, cache_specs, input_specs
from repro.optim import AdamW, constant
from repro.roofline.analysis import (collective_bytes, model_flops,
                                     roofline_terms)
from repro.runtime.train_loop import make_train_step
from repro.sharding import (batch_pspecs, cache_pspec, dp_axes,
                            make_shardings, params_pspecs)

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")

# grad-accumulation microbatching per arch (memory fitting, DESIGN.md §5);
# values verified against compiled memory_analysis.
TRAIN_ACCUM = {
    "internvl2-2b": 2, "whisper-tiny": 1, "phi3-mini-3.8b": 4,
    "qwen1.5-4b": 4, "granite-3-8b": 8, "command-r-plus-104b": 16,
    "recurrentgemma-9b": 4, "llama4-maverick-400b-a17b": 16,
    "phi3.5-moe-42b-a6.6b": 8, "rwkv6-1.6b": 2,
}
# low-memory (bf16) optimizer state for the largest models
BF16_OPT = {"command-r-plus-104b", "llama4-maverick-400b-a17b"}
BF16_ACCUM = {"llama4-maverick-400b-a17b"}
# cross-pod ZeRO-3 for state-dominated giants (DCN all-gathers amortized by
# the grad-accumulation microbatch loop)
CROSS_POD_FSDP = {"llama4-maverick-400b-a17b"}
# cells whose *state alone* exceeds the mesh's HBM: the dry-run proves the
# infeasibility (that is its job); compile must still succeed. llama4 400B
# AdamW state = 400e9·(4+2+2)B / 256 chips = 12.5 GiB/chip before a single
# activation — training this architecture requires the 512-chip multi-pod
# mesh (which fits).
EXPECTED_OVER_HBM = {
    ("llama4-maverick-400b-a17b", "train_4k", "pod_16x16"),
    ("llama4-maverick-400b-a17b", "train_4k", "multipod_2x16x16"),
}  # 397B AdamW state needs ≥4 pods; the 4-pod sizing run
   # (multipod_4x16x16 artifact) shows 16.79 GiB/chip — see EXPERIMENTS.md
# per-arch model overrides for the production cells
CELL_OVERRIDES = {
    "command-r-plus-104b": {"seq_shard": True},
}


def _mesh_tag(multi_pod: bool) -> str:
    return "multipod_2x16x16" if multi_pod else "pod_16x16"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = ART_DIR, overrides: Optional[dict] = None,
             serve_params_dtype=None, fsdp_override=None,
             accum_override: Optional[int] = None,
             tag: str = "") -> dict:
    """Lower + compile one cell. Hillclimb levers: ``serve_params_dtype``
    (bf16 serving checkpoints), ``fsdp_override`` (None axis = TP-only
    serving layout), ``accum_override``, plus any ModelConfig overrides."""
    cfg = get_config(arch)
    merged = dict(CELL_OVERRIDES.get(arch, {}))
    # sequence-parallel activations only pay off under training remat
    # (§Perf cell A: SP at prefill costs +67% collective for nothing)
    if SHAPES[shape_name].kind != "train":
        merged.setdefault("seq_shard", False)
        merged["seq_shard"] = merged.get("seq_shard", False) and False
    if overrides:
        merged.update(overrides)
    if merged:
        import dataclasses
        cfg = dataclasses.replace(cfg, **merged)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    t0 = time.time()

    abstract_params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    if serve_params_dtype is not None and shape.kind != "train":
        abstract_params = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, serve_params_dtype),
            abstract_params)
    fsdp = ("data", "pod") if arch in CROSS_POD_FSDP else "data"
    if fsdp_override is not None:
        fsdp = fsdp_override
    pspecs = params_pspecs(abstract_params, fsdp=fsdp)
    param_sh = make_shardings(mesh, pspecs, abstract_params)
    specs = input_specs(cfg, shape)

    accum = 1
    if shape.kind == "train":
        accum = accum_override or TRAIN_ACCUM.get(arch, 4)
        # microbatch must stay divisible by the total dp degree
        dp_total = 1
        for ax in ("pod", "data"):
            if ax in mesh.axis_names:
                dp_total *= mesh.shape[ax]
        while accum > 1 and (shape.global_batch // accum) % dp_total != 0:
            accum //= 2
        opt = AdamW(lr=constant(3e-4),
                    state_dtype=jnp.bfloat16 if arch in BF16_OPT else jnp.float32)
        opt_abs = jax.eval_shape(opt.init, abstract_params)
        opt_sh = type(opt_abs)(step=NamedSharding(mesh, P()),
                               m=make_shardings(mesh, pspecs, opt_abs.m),
                               v=make_shardings(mesh, pspecs, opt_abs.v))
        step = make_train_step(
            model, opt, accum, mesh=mesh,
            accum_dtype=jnp.bfloat16 if arch in BF16_ACCUM else jnp.float32,
            fsdp=fsdp)
        batch_sh = make_shardings(mesh, batch_pspecs(mesh, specs))
        metrics_sh = jax.tree.map(
            lambda _: NamedSharding(mesh, P()),
            {"loss": 0, "grad_norm": 0, "lr": 0})
        with mesh_context(mesh):
            lowered = jax.jit(
                step, donate_argnums=(0,),
                in_shardings=((param_sh, opt_sh), batch_sh),
                out_shardings=((param_sh, opt_sh), metrics_sh),
            ).lower((abstract_params, opt_abs), specs)
            compiled = lowered.compile()
    elif shape.kind == "prefill":
        def prefill(params, batch):
            logits, _ = model.forward(params, batch["tokens"],
                                      vis_embeds=batch.get("vis_embeds"),
                                      enc_embeds=batch.get("enc_embeds"))
            return logits[:, -1].astype(jnp.float32)   # last-position logits
        batch_sh = make_shardings(mesh, batch_pspecs(mesh, specs))
        dp = dp_axes(mesh)
        with mesh_context(mesh):
            lowered = jax.jit(
                prefill, in_shardings=(param_sh, batch_sh),
                out_shardings=NamedSharding(mesh, P(dp, "model")),
            ).lower(abstract_params, specs)
            compiled = lowered.compile()
    else:  # decode
        from repro.sharding import sanitize_pspec
        cache_abs = cache_specs(cfg, shape)
        cache_sh = jax.tree.map(
            lambda leaf: NamedSharding(
                mesh, sanitize_pspec(mesh, cache_pspec(mesh, leaf), leaf.shape)),
            cache_abs)
        dp = dp_axes(mesh)

        def decode(params, cache, token, pos):
            if cfg.family == "enc_dec":
                b = token.shape[0]
                enc = jnp.zeros((b, cfg.n_frontend_tokens, cfg.d_model),
                                jnp.bfloat16)
                return model.decode_step(params, cache, token, pos, enc_out=enc)
            return model.decode_step(params, cache, token, pos)

        tok_spec = sanitize_pspec(mesh, P(dp, None), specs["token"].shape)
        tok_sh = NamedSharding(mesh, tok_spec)
        pos_sh = NamedSharding(mesh, P())
        logits_sh = NamedSharding(mesh, sanitize_pspec(
            mesh, P(dp, "model"),
            (specs["token"].shape[0], cfg.padded_vocab)))
        with mesh_context(mesh):
            lowered = jax.jit(
                decode, donate_argnums=(1,),
                in_shardings=(param_sh, cache_sh, tok_sh, pos_sh),
                out_shardings=(logits_sh, cache_sh),
            ).lower(abstract_params, cache_abs, specs["token"], specs["pos"])
            compiled = lowered.compile()

    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    # CPU-backend artifact accounting (decode cells): XLA's bf16-dot
    # emulation hoists f32 converts of the KV cache out of the layer scan
    # and carries full f32 cache copies in the while tuple. Native-bf16 TPUs
    # never materialize these; we detect f32 buffers exactly matching the
    # per-device bf16 cache shapes and report a TPU-corrected fit.
    cpu_artifact_bytes = 0
    if shape.kind == "decode":
        dp_size = 1
        for ax in ("pod", "data"):
            if ax in mesh.axis_names:
                dp_size *= mesh.shape[ax]
        tp = mesh.shape["model"]
        for leaf in jax.tree.leaves(cache_abs):
            if leaf.ndim >= 5 and leaf.dtype == jnp.bfloat16:
                d = list(leaf.shape)
                if d[1] % dp_size == 0:
                    d[1] //= dp_size
                if d[2] % tp == 0:
                    d[2] //= tp
                sig = "f32[" + ",".join(map(str, d)) + "]"
                if sig in hlo:
                    n_els = 1
                    for dd in d:
                        n_els *= dd
                    cpu_artifact_bytes += n_els * 4  # one live f32 copy/leaf
    chips = mesh.size
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll_total = float(sum(coll.values()))
    terms = roofline_terms(flops_dev * chips, bytes_dev * chips,
                           coll_total * chips, chips)
    mf = model_flops(cfg, shape)
    # --- trip-count correction -------------------------------------------
    # XLA cost_analysis counts while-loop bodies ONCE (verified:
    # useful_flops_ratio >> 1). The layer scan runs n_layers times and the
    # grad-accumulation scan `accum` times, so HLO-counted terms are scaled
    # by M = n_layers × accum (kind-dependent). Inner scans (chunked
    # attention, SSM time scans) make corrected terms for hybrid/ssm cells
    # LOWER BOUNDS — noted per cell. The analytic compute term (6·N·D
    # MFU accounting) is exact and reported alongside.
    n_l = (cfg.enc_layers + cfg.dec_layers) if cfg.family == "enc_dec" \
        else cfg.n_layers
    m_trips = n_l * (accum if shape.kind == "train" else 1)
    terms_corr = roofline_terms(flops_dev * chips * m_trips,
                                bytes_dev * chips * m_trips,
                                coll_total * chips * m_trips, chips)
    from repro.roofline.analysis import PEAK_FLOPS
    compute_analytic_s = mf / (chips * PEAK_FLOPS)
    lower_bound = cfg.family in ("hybrid", "ssm")
    result = {
        "arch": arch, "shape": shape_name, "mesh": _mesh_tag(multi_pod),
        "chips": chips, "ok": True, "compile_s": round(compile_s, 1),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0)),
            "fit_bytes": int(getattr(mem, "argument_size_in_bytes", 0))
            + int(getattr(mem, "temp_size_in_bytes", 0)),
            "cpu_artifact_bytes": int(cpu_artifact_bytes),
            "fit_bytes_tpu": int(getattr(mem, "argument_size_in_bytes", 0))
            + int(getattr(mem, "temp_size_in_bytes", 0))
            - int(cpu_artifact_bytes),
        },
        "cost": {"flops_per_device": flops_dev,
                 "bytes_per_device": bytes_dev},
        "collectives_bytes_per_device": coll,
        "roofline": terms,
        "roofline_corrected": {**terms_corr, "m_trips": m_trips,
                               "compute_analytic_s": compute_analytic_s,
                               "inner_scan_lower_bound": lower_bound},
        "model_flops": mf,
        "useful_flops_ratio": (mf / (flops_dev * chips * m_trips)
                               if flops_dev > 0 else None),
    }
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(
        out_dir, f"{arch}__{shape_name}__{_mesh_tag(multi_pod)}{suffix}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=ART_DIR)
    args = ap.parse_args()

    if args.all:
        failures = []
        for arch in REGISTRY:
            for shape in SHAPES:
                if not cell_is_live(arch, shape):
                    continue
                for mp in (False, True):
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape]
                    if mp:
                        cmd.append("--multi-pod")
                    t0 = time.time()
                    r = subprocess.run(cmd, capture_output=True, text=True)
                    tag = f"{arch} × {shape} × {_mesh_tag(mp)}"
                    if r.returncode == 0:
                        print(f"PASS {tag} ({time.time()-t0:.0f}s)")
                    else:
                        print(f"FAIL {tag}\n{r.stdout[-2000:]}\n{r.stderr[-2000:]}")
                        failures.append(tag)
        if failures:
            print(f"\n{len(failures)} FAILURES:", *failures, sep="\n  ")
            sys.exit(1)
        print("\nALL DRY-RUN CELLS PASS")
        return

    assert args.arch and args.shape
    try:
        res = run_cell(args.arch, args.shape, args.multi_pod, args.out)
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    mem_gb = res["memory"]["fit_bytes_tpu"] / 2 ** 30
    raw_gb = res["memory"]["fit_bytes"] / 2 ** 30
    print(f"{res['arch']} {res['shape']} {res['mesh']}: compile={res['compile_s']}s "
          f"mem={mem_gb:.2f}GiB (raw_cpu={raw_gb:.2f}) "
          f"flops/dev={res['cost']['flops_per_device']:.3g} "
          f"dominant={res['roofline']['dominant']}")
    if mem_gb > 16.0:
        key = (res["arch"], res["shape"], res["mesh"])
        if key in EXPECTED_OVER_HBM:
            print(f"NOTE: exceeds single-pod HBM as expected "
                  f"({mem_gb:.1f} GiB) — multi-pod mesh required; "
                  f"compile + analysis succeeded.")
        else:
            print(f"WARNING: exceeds 16 GiB/chip HBM ({mem_gb:.1f})")
            sys.exit(2)


if __name__ == "__main__":
    main()
