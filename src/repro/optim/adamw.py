"""AdamW + gradient clipping, pure-JAX pytrees (no optax dependency).

Optimizer state lives in the same sharding as the parameters (FSDP-friendly:
m/v inherit param PartitionSpecs), master weights are fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array]      # schedule: step → lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32   # bf16 = low-memory (8-bit-Adam-style)

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros_like(p, dtype=self.state_dtype), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                          v=jax.tree.map(jnp.copy, zeros))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

        sd = self.state_dtype
        m = jax.tree.map(
            lambda m_, g: (self.b1 * m_.astype(jnp.float32)
                           + (1 - self.b1) * g).astype(sd), state.m, grads)
        v = jax.tree.map(
            lambda v_, g: (self.b2 * v_.astype(jnp.float32)
                           + (1 - self.b2) * g * g).astype(sd), state.v, grads)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self.lr(step)

        def upd(p, m_, v_):
            mh = m_.astype(jnp.float32) / bc1
            vh = v_.astype(jnp.float32) / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step=step, m=m, v=v), {
            "grad_norm": gnorm, "lr": lr}
