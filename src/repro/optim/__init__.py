from .adamw import AdamW, AdamWState
from .schedule import constant, cosine_with_warmup

__all__ = ["AdamW", "AdamWState", "constant", "cosine_with_warmup"]
