"""LR schedules (step → lr), jit-safe."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(peak_lr: float, warmup: int, total: int,
                       floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def constant(lr_value: float):
    def lr(step):
        return jnp.full((), lr_value, jnp.float32)
    return lr
