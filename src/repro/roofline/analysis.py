"""Roofline terms from compiled dry-run artifacts (TPU v5e constants).

    compute    = HLO_FLOPs   / (chips × 197e12 FLOP/s)
    memory     = HLO_bytes   / (chips × 819e9  B/s)
    collective = coll_bytes  / (chips × 50e9   B/s per ICI link)

``cost_analysis`` supplies FLOPs/bytes; collective bytes are parsed from the
compiled HLO text (operand sizes of all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute, including async start forms).  Cross-pod
("pod"-axis) collectives ride DCN and are reported separately at 25 GB/s
per host link.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
DCN_BW = 25e9                # bytes/s per host (cross-pod)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# result shape of an HLO op: `%name = <shape-or-tuple> opcode(`
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9_]+\[[^\]]*\][^\s]*))\s+"
    r"((?:all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?)\(", re.IGNORECASE)

_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,\s]*)\]")

_GROUPS_RE = re.compile(r"replica_groups=\{?([^}]*)\}?")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            d = d.strip()
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Total result bytes per collective opcode in an HLO module."""
    out: Dict[str, int] = {}
    for m in _OP_RE.finditer(hlo_text):
        shape_txt, opcode = m.group(1), m.group(2).lower()
        opcode = opcode.replace("-start", "")
        out[opcode] = out.get(opcode, 0) + _shape_bytes(shape_txt)
    return out


def roofline_terms(flops: float, bytes_accessed: float, coll_bytes: float,
                   chips: int, *, dcn_bytes: float = 0.0,
                   dcn_links: int = 1) -> Dict[str, float]:
    compute_s = flops / (chips * PEAK_FLOPS)
    memory_s = bytes_accessed / (chips * HBM_BW)
    collective_s = coll_bytes / (chips * ICI_BW)
    dcn_s = dcn_bytes / (max(dcn_links, 1) * DCN_BW)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s, "dcn_s": dcn_s}
    dominant = max(("compute_s", "memory_s", "collective_s", "dcn_s"),
                   key=lambda k: terms[k])
    terms["dominant"] = dominant
    terms["bound_s"] = terms[dominant]
    return terms


def model_flops(cfg, shape, n_tokens: float = None) -> float:
    """Analytic 2·N·tokens (dense) / 2·N_active·tokens (MoE) + attention
    quadratic term, ×3 for train (fwd+bwd)."""
    if n_tokens is None:
        n_tokens = shape.global_batch * (shape.seq_len if shape.kind == "train"
                                         else (shape.seq_len if shape.kind == "prefill" else 1))
    n = cfg.active_param_count()
    flops = 2.0 * n * n_tokens
    # attention: 4·tokens·ctx·(H·hd) per attn layer, ×0.5 causal
    if cfg.n_heads:
        n_attn = sum(1 for i in range(cfg.n_layers)
                     if cfg.layer_kind(i) in ("attn", "moe", "local", "cross"))
        ctx = shape.seq_len
        if cfg.layer_pattern and "local" in cfg.layer_pattern:
            ctx = min(ctx, cfg.local_window)
        flops += 0.5 * 4.0 * n_tokens * ctx * cfg.n_heads * cfg.hd * n_attn
    mult = 3.0 if shape.kind == "train" else 1.0
    return flops * mult
