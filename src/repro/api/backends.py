"""Backend dispatch for plan execution — replaces the ``ops.INTERPRET`` global.

Three backends, one switch:

* ``"pallas"``    — compiled Pallas kernels (TPU).
* ``"interpret"`` — the same Pallas kernels in interpret mode (CPU-correct;
  the default off-TPU so tests and laptops just work).
* ``"reference"`` — the pure-jnp oracles from :mod:`repro.kernels.ref`
  (differentiable everywhere; the parity baseline).

The default resolves from the JAX platform once, can be overridden globally
(:func:`set_default_backend`) or lexically (:func:`use_backend`).  Backend
choice is resolved at trace time: functions jitted under ``use_backend`` bake
the choice into their compiled executable.
"""
from __future__ import annotations

import contextlib
from typing import Iterator, Optional, Tuple

import jax

BACKENDS: Tuple[str, ...] = ("pallas", "interpret", "reference")

_default_backend: Optional[str] = None


def _platform_default() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "interpret"


def available_backends() -> Tuple[str, ...]:
    return BACKENDS


def default_backend() -> str:
    """The backend used when none is passed explicitly."""
    return _default_backend if _default_backend is not None else _platform_default()


def set_default_backend(name: Optional[str]) -> None:
    """Set the process-wide default backend (``None`` restores autodetect)."""
    global _default_backend
    if name is not None:
        resolve_backend(name)
    _default_backend = name


def resolve_backend(name: Optional[str]) -> str:
    """Validate ``name`` (or resolve the default when ``None``)."""
    if name is None:
        return default_backend()
    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; available: {BACKENDS}")
    return name


@contextlib.contextmanager
def use_backend(name: str) -> Iterator[str]:
    """Lexically scope the default backend (e.g. force ``reference`` in a
    parity test, or ``interpret`` while tracing a serving function on CPU)."""
    global _default_backend
    name = resolve_backend(name)
    prev = _default_backend
    _default_backend = name
    try:
        yield name
    finally:
        _default_backend = prev


def backend_interpret_flag(name: str) -> bool:
    """Map a pallas-family backend to the kernel ``interpret`` flag."""
    if name == "reference":
        raise ValueError("reference backend does not run Pallas kernels")
    return name == "interpret"
