"""``SegmentPlan`` — the one plan abstraction, registered as a JAX pytree.

A plan freezes everything a Segment-dataflow matmul needs at run time:

* **leaves** (device arrays): the block values (fp32, or a quantized
  payload plus per-block fp32 ``lhs_scales``/``rhs_scales``), the
  scalar-prefetch schedule arrays (``seg_start``/``seg_write``/
  ``accum_prev`` plus the DMA-pipeline ``a_fetch``/``b_fetch``/``a_slot``/
  ``b_slot`` fetch schedule), per-item block coordinates, the row liveness
  mask, and — when the plan was built with ``with_grad=True`` — a nested
  backward plan for the transposed schedule;
* **static aux data** (hashable python values): grid sizes, block shape,
  policy name, kind, the traffic estimate, and the pattern fingerprint.

Because the plan is a pytree, it passes through ``jax.jit`` (as a traced
argument), donation, and sharding like any other array container — this
replaces the identity-hash ``_Static`` workaround the trainable layers used
to need.  Aux data is hashable, so jit caches correctly key on the static
schedule structure while the arrays stay dynamic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import QUANT_DTYPES, quant_base_dtype

SPMM = "spmm"
SPGEMM = "spgemm"

# Leaf fields, flattening order.  ``grad_plan`` is itself a SegmentPlan (a
# child pytree); None fields flatten to zero leaves.
_LEAF_FIELDS = (
    "lhs_blocks", "rhs_blocks",
    "lhs_scales", "rhs_scales",
    "m_idx", "k_idx",
    "a_idx", "b_idx", "c_idx",
    "slot_idx", "valid",
    "seg_start", "seg_write", "accum_prev",
    "a_fetch", "b_fetch", "a_slot", "b_slot",
    "row_mask",
    "a_brow", "a_bcol", "b_brow", "b_bcol", "c_brow_arr", "c_bcol_arr",
    "grad_plan",
)
_AUX_FIELDS = ("kind", "policy", "block_shape", "grid", "rhs_grid",
               "n_out_blocks", "traffic_items", "fingerprint", "backend",
               "n_lanes", "unroll", "transpose_lhs", "block_dtype",
               "out_dtype", "has_pads", "pipeline", "bn_hint", "prefetch")


@dataclasses.dataclass(eq=False)   # array fields make generated __eq__ ambiguous
class SegmentPlan:
    """Frozen Segment schedule + block values for one sparse matmul.

    ``kind == "spmm"``: ``lhs_blocks`` are the A tiles in **original BSR
    storage order** (``a_brow``/``a_bcol`` give each stored block's
    coordinates); the lane-major schedule addresses them through
    ``slot_idx``, so realizing a plan never gathers block values.  Calling
    the plan with a dense ``(K, N)`` right-hand side returns the dense
    ``(M, N)`` product.

    ``kind == "spgemm"``: ``lhs_blocks``/``rhs_blocks`` are the A/B tiles in
    original BSR order, ``a_idx``/``b_idx``/``c_idx`` map schedule items to
    block slots, and calling the plan returns the ``(n_out_blocks, bm, bn)``
    C blocks at the symbolic pattern positions (``c_brow``/``c_bcol``).
    """

    # --- static aux data (hashable; part of the jit cache key) ---
    kind: str
    policy: str
    block_shape: Tuple[int, int]                  # (bm, bk) of A tiles
    grid: Tuple[int, int]                         # A's (grid_m, grid_k)
    rhs_grid: Optional[Tuple[int, int]]           # B's (grid_k, grid_n) | None
    n_out_blocks: int                             # spgemm: |C blocks|; spmm: grid_m
    traffic_items: Tuple[Tuple[str, float], ...]  # frozen traffic estimate
    fingerprint: str                              # pattern+policy hash
    backend: Optional[str] = None                 # preferred backend | None=default
    n_lanes: int = 1                              # parallel lanes in the grid
    unroll: int = 1                               # items per grid step
    transpose_lhs: bool = False                   # kernel contracts Aᵀ (bwd)
    block_dtype: str = "fp32"                     # "fp32" | "int8" | "fp8"
    out_dtype: Optional[str] = None               # dtype name | None=float32
    # True when the lane-major schedule carries any valid=0 padding item —
    # the executor masks pad contributions exactly when this is set (the
    # conservative default keeps hand-built plans safe)
    has_pads: bool = True
    # False selects the legacy BlockSpec auto-pipeline instead of the
    # explicit DMA pipeline; the fetch-flag leaves still ride along (their
    # contract is pipeline-independent) but the executor and the traffic
    # pricing both follow this switch
    pipeline: bool = True
    # preferred executor N-tile width (set by the repro.tune search; the
    # executor uses it when the caller passes no explicit bn)
    bn_hint: Optional[int] = None
    # DMA schedule mode (see core.schedule.PREFETCH_MODES): "cross_pass"
    # makes the kernels issue the next (lane, N-tile) pass's first copies
    # during the current pass's tail step instead of draining the pipeline
    # at the boundary; None keeps the drained schedule.  Certified
    # hazard-free per kernel variant by repro.analysis.order.
    prefetch: Optional[str] = None

    # --- pytree leaves (device arrays; None where not applicable) ---
    lhs_blocks: Optional[jax.Array] = None
    rhs_blocks: Optional[jax.Array] = None
    lhs_scales: Optional[jax.Array] = None        # (n_blocks,) fp32 | None
    rhs_scales: Optional[jax.Array] = None
    m_idx: Optional[jax.Array] = None
    k_idx: Optional[jax.Array] = None
    a_idx: Optional[jax.Array] = None
    b_idx: Optional[jax.Array] = None
    c_idx: Optional[jax.Array] = None
    slot_idx: Optional[jax.Array] = None
    valid: Optional[jax.Array] = None
    seg_start: Optional[jax.Array] = None
    seg_write: Optional[jax.Array] = None
    accum_prev: Optional[jax.Array] = None
    # DMA pipeline schedule: per-item fetch flags + resident ring-buffer
    # slots for the A and B operand streams (see core.schedule.fetch_flags)
    a_fetch: Optional[jax.Array] = None
    b_fetch: Optional[jax.Array] = None
    a_slot: Optional[jax.Array] = None
    b_slot: Optional[jax.Array] = None
    row_mask: Optional[jax.Array] = None
    a_brow: Optional[jax.Array] = None
    a_bcol: Optional[jax.Array] = None
    b_brow: Optional[jax.Array] = None
    b_bcol: Optional[jax.Array] = None
    c_brow_arr: Optional[jax.Array] = None
    c_bcol_arr: Optional[jax.Array] = None
    grad_plan: Optional["SegmentPlan"] = None

    # ------------------------------------------------------------------
    # pytree protocol
    # ------------------------------------------------------------------

    def tree_flatten(self):
        children = tuple(getattr(self, f) for f in _LEAF_FIELDS)
        aux = tuple(getattr(self, f) for f in _AUX_FIELDS)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        kw: Dict[str, Any] = dict(zip(_AUX_FIELDS, aux))
        kw.update(zip(_LEAF_FIELDS, children))
        return cls(**kw)

    # ------------------------------------------------------------------
    # convenience surface
    # ------------------------------------------------------------------

    @property
    def n_items(self) -> int:
        """Padded schedule length (``n_lanes * lane_len``, pads included)."""
        return int(self.seg_start.shape[0])

    @property
    def lane_len(self) -> int:
        return self.n_items // self.n_lanes

    @property
    def n_blocks(self) -> int:
        """Number of *stored* lhs blocks (original BSR order)."""
        src = self.lhs_blocks if self.lhs_blocks is not None else self.a_brow
        return int(src.shape[0])

    @property
    def traffic(self) -> Dict[str, float]:
        """Revisiting-model HBM traffic estimate (see ``schedule_traffic``)."""
        return dict(self.traffic_items)

    @property
    def grid_m(self) -> int:
        return self.grid[0]

    @property
    def grid_k(self) -> int:
        return self.grid[1]

    @property
    def n_c_blocks(self) -> int:
        if self.kind != SPGEMM:
            raise AttributeError("n_c_blocks is only defined for spgemm plans")
        return self.n_out_blocks

    @property
    def c_brow(self) -> np.ndarray:
        """Symbolic C pattern rows (spgemm), as host numpy."""
        return np.asarray(self.c_brow_arr)

    @property
    def c_bcol(self) -> np.ndarray:
        return np.asarray(self.c_bcol_arr)

    def replace(self, **kw) -> "SegmentPlan":
        return dataclasses.replace(self, **kw)

    def verify(self, level: str = "fast", **kw):
        """Run the static schedule verifier over this plan.

        Delegates to :func:`repro.analysis.verify_plan` (``level`` is
        ``"fast"`` or ``"full"``; keyword args — ``invariants``, ``bn``,
        ``n_cols`` — pass through) and returns its
        :class:`~repro.analysis.VerifyResult`; call
        ``.raise_if_findings()`` on it to turn findings into a
        :class:`~repro.analysis.PlanVerificationError`.
        """
        from repro.analysis.invariants import verify_plan
        return verify_plan(self, level=level, **kw)

    @property
    def quantized(self) -> bool:
        """True when block values are stored quantized (+ per-block scales)."""
        return self.block_dtype != "fp32"

    def with_values(self, lhs_blocks, rhs_blocks=None, *, lhs_scales=None,
                    rhs_scales=None) -> "SegmentPlan":
        """Same schedule, new block values (e.g. the current train params).

        ``lhs_blocks`` must match the plan's storage layout: original BSR
        (row-major) block order for both plan kinds.  Quantized plans take
        the low-precision payload plus the matching per-block ``*_scales``
        (``None`` keeps the plan's current scales).
        """
        self._check_value_dtype("lhs_blocks", lhs_blocks)
        kw: Dict[str, Any] = {"lhs_blocks": lhs_blocks}
        if rhs_blocks is not None:
            self._check_value_dtype("rhs_blocks", rhs_blocks)
            kw["rhs_blocks"] = rhs_blocks
        if lhs_scales is not None:
            kw["lhs_scales"] = lhs_scales
        if rhs_scales is not None:
            kw["rhs_scales"] = rhs_scales
        return dataclasses.replace(self, **kw)

    def _check_value_dtype(self, name: str, blocks) -> None:
        """New block values must match the plan's storage format: a
        quantized plan silently applying its per-block scales to fp32
        values (or an fp32 plan fed a raw payload) is numerically wrong in
        a way no shape check catches."""
        got = np.dtype(jnp.result_type(blocks))
        if self.quantized:
            expect = QUANT_DTYPES[quant_base_dtype(self.block_dtype)]
            if got != expect:
                raise ValueError(
                    f"{name} has dtype {got}, but this plan stores "
                    f"{self.block_dtype} payloads ({expect}) — quantize the "
                    f"values (repro.core.formats.quantize_blocks) or use the "
                    f"fp32 plan of this pattern")
        elif got in QUANT_DTYPES.values():
            raise ValueError(
                f"{name} has quantized payload dtype {got}, but this plan "
                f"stores fp32 blocks — build it with plan_matmul(..., "
                f"quantize=...) to carry the matching scales")

    def __call__(self, rhs=None, *, bn: Optional[int] = None,
                 backend: Optional[str] = None,
                 interpret: Optional[bool] = None, out_dtype=None):
        """Execute the plan.

        spmm: ``plan(b_dense)`` → dense ``(M, N)``.
        spgemm: ``plan()`` → ``(n_out_blocks, bm, bn)`` C blocks.

        ``bn=None`` defers to the plan's tuned ``bn_hint`` (when the plan
        came out of the :mod:`repro.tune` search) and otherwise to the
        executor default (512).  ``interpret`` is a deprecated alias for
        ``backend`` kept for the old ``ops.SpmmPlan``/``ops.SpgemmPlan``
        call signature.
        """
        from . import executor  # local import: executor imports this module
        if interpret is not None:
            backend = "interpret" if interpret else "pallas"
        return executor.execute_plan(self, rhs, bn=bn, backend=backend,
                                     out_dtype=out_dtype)


jax.tree_util.register_pytree_node(
    SegmentPlan, SegmentPlan.tree_flatten, SegmentPlan.tree_unflatten)
