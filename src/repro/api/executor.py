"""Plan execution: backend dispatch + the one differentiable matmul path.

``execute_plan`` runs a :class:`~repro.api.plan.SegmentPlan` on any backend
(compiled Pallas, Pallas interpret, or the pure-jnp reference oracle).
``apply_plan`` is the trainable entry point: a ``custom_vjp`` lifted out of
the old ``models/sparse_ffn.py`` so serving and training share one executor —

* forward:  ``y = W @ x``   (lane-parallel Segment SpMM under the plan's
  schedule; block values read in original BSR storage order via the
  ``slot_idx`` prefetch array);
* ``dx = Wᵀ @ dy``          — another Segment SpMM under the plan's nested
  transposed schedule (``plan.grad_plan``, built once, static), executed in
  the kernel's ``transpose_lhs`` mode against the *forward* weight array —
  no transposed or gathered copy of W is ever materialized;
* ``dW[s] = dy[rowₛ] @ x[colₛ]ᵀ`` — block-sampled SDDMM, pure jnp, emitted
  directly in storage order via ``a_brow``/``a_bcol``.

The N-tile width is normalized in one place (:func:`pick_bn`): the executor
either shrinks ``bn`` to the largest divisor of N or pads N up to a tile
multiple and slices the result — arbitrary N is legal (the old
``SpmmPlan.__call__`` crashed on any N not divisible by the tile width).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.segment_spgemm import segment_spgemm
from repro.kernels.segment_spmm import segment_spmm

from .backends import backend_interpret_flag, resolve_backend
from .plan import SPGEMM, SPMM, SegmentPlan


def pick_bn(n: int, bn: int) -> Tuple[int, int]:
    """Normalize the N-tile width for an ``(…, N)`` right-hand side.

    Returns ``(bn_eff, pad)`` with ``(n + pad) % bn_eff == 0``.  Prefers the
    largest divisor of ``n`` that is ≤ ``bn`` when it keeps tiles reasonably
    wide (at least half the request, or the full lane width); otherwise keeps
    the requested width and zero-pads N (padded C columns are sliced off).
    """
    bn = max(1, min(bn, n))
    if n % bn == 0:
        return bn, 0
    div = max(d for d in range(1, bn + 1) if n % d == 0)
    if div >= max(bn // 2, min(128, n)):
        return div, 0
    return bn, (-n) % bn


def _resolve_bn(plan: SegmentPlan, bn: Optional[int]) -> int:
    """Executor N-tile width: explicit argument > the plan's tuned
    ``bn_hint`` (recorded by the ``repro.tune`` search) > the default 512."""
    if bn is not None:
        return bn
    hint = getattr(plan, "bn_hint", None)
    return int(hint) if hint else 512


def _mask_dead_rows(plan: SegmentPlan, out: jax.Array) -> jax.Array:
    # block rows with no nonzero A blocks are never visited by the grid —
    # their output is undefined (may be NaN); zero them via where.
    row_blk = plan.block_shape[0]
    live = jnp.repeat(plan.row_mask > 0, row_blk)[:, None]
    return jnp.where(live, out, jnp.zeros((), out.dtype))


def _run_spmm(plan: SegmentPlan, x: jax.Array, *, backend: str,
              blocks: Optional[jax.Array] = None,
              scales: Optional[jax.Array] = None, bn: int = 512,
              out_dtype=jnp.float32) -> jax.Array:
    """Execute an spmm plan (optionally with substituted block values).

    ``blocks`` are always the *stored* tiles (original BSR order); a
    ``transpose_lhs`` plan (the nested backward schedule) contracts along
    their row axis instead of copying a transposed array.  ``scales`` are
    the per-block dequantization factors when ``blocks`` is a quantized
    payload (the nested backward plan carries none of its own — the caller
    threads the forward plan's).
    """
    blocks = plan.lhs_blocks if blocks is None else blocks
    scales = plan.lhs_scales if scales is None else scales
    gm, gk = plan.grid
    bm, bk = blocks.shape[1], blocks.shape[2]
    contract_blk = bm if plan.transpose_lhs else bk
    if x.ndim != 2 or x.shape[0] != gk * contract_blk:
        raise ValueError(f"rhs must be (K={gk * contract_blk}, N) dense, "
                         f"got {x.shape}")
    if backend == "reference":
        if plan.transpose_lhs:
            # a_brow/a_bcol describe the *forward* storage; its grid is the
            # plan's grid reversed.
            out = ref.spmm_ref(blocks, plan.a_brow, plan.a_bcol,
                               plan.grid[1], plan.grid[0], x,
                               transpose_lhs=True, scales=scales)
        else:
            out = ref.spmm_ref(blocks, plan.a_brow, plan.a_bcol, gm, gk, x,
                               scales=scales)
        return out.astype(out_dtype)
    n = x.shape[1]
    bn_eff, pad = pick_bn(n, bn)
    xp = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    out = segment_spmm(
        blocks, plan.slot_idx, plan.m_idx, plan.k_idx, plan.seg_start,
        plan.seg_write, plan.accum_prev, plan.valid, xp, grid_m=gm,
        n_lanes=plan.n_lanes, bn=bn_eff, unroll=plan.unroll,
        transpose_lhs=plan.transpose_lhs,
        # mask exactly when the schedule carries valid=0 items — lane count
        # and unroll are the wrong proxy: a single-lane unroll=1 schedule
        # can legally carry pads (custom policies, hand-extended plans),
        # and a multi-lane schedule that packs perfectly has none
        masked=plan.has_pads,
        interpret=backend_interpret_flag(backend), out_dtype=out_dtype,
        a_scales=scales, a_fetch=plan.a_fetch, b_fetch=plan.b_fetch,
        a_slot=plan.a_slot, b_slot=plan.b_slot,
        pipeline=bool(getattr(plan, "pipeline", True)),
        prefetch=getattr(plan, "prefetch", None))
    if pad:
        out = out[:, :n]
    return _mask_dead_rows(plan, out)


def _run_spgemm(plan: SegmentPlan, *, backend: str,
                out_dtype=jnp.float32) -> jax.Array:
    if plan.n_out_blocks == 0:
        # all-masked symbolic pattern (no A column meets a B row): the grid
        # would be empty — return the empty C block array directly.
        bm = plan.block_shape[0]
        bn = plan.rhs_blocks.shape[2]
        return jnp.zeros((0, bm, bn), out_dtype)
    if backend == "reference":
        out = ref.spgemm_ref(
            plan.lhs_blocks, plan.a_brow, plan.a_bcol, plan.grid,
            plan.rhs_blocks, plan.b_brow, plan.b_bcol, plan.rhs_grid,
            plan.c_brow_arr, plan.c_bcol_arr,
            a_scales=plan.lhs_scales, b_scales=plan.rhs_scales)
        return out.astype(out_dtype)
    return segment_spgemm(
        plan.lhs_blocks, plan.rhs_blocks, plan.a_idx, plan.b_idx, plan.c_idx,
        plan.seg_start, plan.seg_write, plan.accum_prev, plan.valid,
        n_c_blocks=plan.n_out_blocks, n_lanes=plan.n_lanes,
        unroll=plan.unroll,
        masked=plan.has_pads,   # see _run_spmm: pads, not lanes/unroll
        interpret=backend_interpret_flag(backend), out_dtype=out_dtype,
        a_scales=plan.lhs_scales, b_scales=plan.rhs_scales,
        a_fetch=plan.a_fetch, b_fetch=plan.b_fetch,
        a_slot=plan.a_slot, b_slot=plan.b_slot,
        pipeline=bool(getattr(plan, "pipeline", True)),
        prefetch=getattr(plan, "prefetch", None))


def execute_plan(plan: SegmentPlan, rhs=None, *, bn: Optional[int] = None,
                 backend: Optional[str] = None, out_dtype=None,
                 verify=None) -> jax.Array:
    """Forward-only plan execution (``plan(...)`` delegates here).

    ``bn`` resolution order: explicit argument > the plan's tuned
    ``bn_hint`` (set by the :mod:`repro.tune` search) > 512.
    Backend resolution order: explicit argument > ``plan.backend`` > the
    process default (:func:`repro.api.backends.default_backend`).
    ``out_dtype`` resolves the same way: explicit argument >
    ``plan.out_dtype`` (set via ``plan_matmul(..., out_dtype=...)``) >
    float32.  Accumulation is always fp32; the dtype only affects the
    written output tiles.

    ``verify`` (``True``/``"fast"``/``"full"``) runs the static schedule
    verifier before any kernel launches and raises
    :class:`~repro.analysis.PlanVerificationError` on a finding — the
    debug hook for hand-edited or externally-deserialized plans (planner
    output is better verified once via ``plan_matmul(..., verify=...)``,
    which amortizes through the plan cache).
    """
    if verify:
        from repro.analysis.invariants import verify_plan
        level = "fast" if verify is True else verify
        verify_plan(plan, level=level).raise_if_findings()
    backend = resolve_backend(backend if backend is not None else plan.backend)
    bn = _resolve_bn(plan, bn)
    if out_dtype is None:
        out_dtype = plan.out_dtype
    out_dtype = jnp.float32 if out_dtype is None else jnp.dtype(out_dtype)
    if plan.kind == SPMM:
        if rhs is None:
            raise ValueError("spmm plan needs a dense right-hand side")
        return _run_spmm(plan, rhs, backend=backend, bn=bn, out_dtype=out_dtype)
    if plan.kind == SPGEMM:
        if rhs is not None:
            raise ValueError("spgemm plan takes no right-hand side "
                             "(B is frozen into the plan)")
        return _run_spgemm(plan, backend=backend, out_dtype=out_dtype)
    raise ValueError(f"unknown plan kind {plan.kind!r}")


# ---------------------------------------------------------------------------
# Differentiable path (custom VJP over the plan pytree)
# ---------------------------------------------------------------------------


def _zero_cotangent(tree):
    """Structure-matching zero cotangent: float0 for integer leaves."""
    def z(leaf):
        if jnp.issubdtype(jnp.result_type(leaf), jnp.inexact):
            return jnp.zeros_like(leaf)
        return np.zeros(np.shape(leaf), jax.dtypes.float0)
    return jax.tree_util.tree_map(z, tree)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _apply(backend: str, bn: int, plan: SegmentPlan, x: jax.Array):
    out = _run_spmm(plan, x, backend=backend, bn=bn, out_dtype=jnp.float32)
    return out.astype(x.dtype)


def _apply_fwd(backend, bn, plan, x):
    return _apply(backend, bn, plan, x), (plan, x)


def _apply_bwd(backend, bn, res, dy):
    plan, x = res
    g = plan.grad_plan
    if g is None:
        raise ValueError("plan was built without with_grad=True; "
                         "no transposed schedule available for the backward "
                         "pass — rebuild via plan_matmul(..., with_grad=True)")
    dyf = dy.astype(jnp.float32)
    # dx = Wᵀ @ dy under the transposed schedule; the grad plan's slot_idx
    # addresses the forward weight storage (payload + scales for quantized
    # plans) and the kernel contracts along block rows (transpose_lhs) —
    # zero copies of W.
    dx = _run_spmm(g, dyf, backend=backend, blocks=plan.lhs_blocks,
                   scales=plan.lhs_scales, bn=bn,
                   out_dtype=jnp.float32).astype(x.dtype)
    dplan = _zero_cotangent(plan)
    if not plan.quantized:
        # dW[s] = dy[brow_s·bm:(brow_s+1)·bm] @ x[bcol_s·bk:(bcol_s+1)·bk]ᵀ —
        # block SDDMM, emitted directly in the plan's (original BSR) storage
        # order via the stored block coordinates.  Quantized payloads are
        # frozen inference storage: their cotangent stays the symbolic zero
        # (float0 for int8) — gradients still flow to x.
        bm, bk = plan.block_shape
        gm, gk = plan.grid
        dyb = dyf.reshape(gm, bm, -1)
        xb = x.astype(jnp.float32).reshape(gk, bk, -1)
        dW = jnp.einsum("imn,ikn->imk", dyb[plan.a_brow], xb[plan.a_bcol])
        dplan = dplan.replace(lhs_blocks=dW.astype(plan.lhs_blocks.dtype))
    return dplan, dx


_apply.defvjp(_apply_fwd, _apply_bwd)


def apply_plan(plan: SegmentPlan, x: jax.Array, *, bn: Optional[int] = None,
               backend: Optional[str] = None) -> jax.Array:
    """Differentiable ``y = W @ x`` for an spmm plan (``x``: ``(K, N)``).

    Gradients flow to ``plan.lhs_blocks`` (the trainable block values, in
    original BSR storage order) and to ``x``; all schedule/index leaves get
    symbolic-zero cotangents.  Requires the plan to carry a ``grad_plan``
    (built by ``plan_matmul(..., with_grad=True)``).  ``bn=None`` resolves
    like :func:`execute_plan`: the plan's tuned ``bn_hint``, else 512.
    """
    if plan.kind != SPMM:
        raise ValueError("apply_plan supports spmm plans; execute spgemm "
                         "plans via plan() / execute_plan")
    backend = resolve_backend(backend if backend is not None else plan.backend)
    return _apply(backend, _resolve_bn(plan, bn), plan, x)
