"""``repro.api`` — the one way to run Segment-dataflow matmuls.

The paper's thesis is that a single *dynamic* dataflow subsumes the static
ones; this package is the code form of that thesis: one plan abstraction
(:class:`SegmentPlan`, a JAX pytree), one policy registry (dataflows as a
configuration space), one backend switch (compiled / interpret / reference),
and one differentiable executor shared by serving and training.

Typical lifecycle::

    from repro import api

    plan = api.plan_matmul(A, x.shape, policy="segment")   # build (cached)
    y = plan(x)                                            # execute
    y = jax.jit(lambda p, x: api.apply_plan(p, x))(plan, x)  # jit'd + grads

See ``docs/API.md`` for the full plan lifecycle, the policy registry
contract, and the deprecation shims (``repro.kernels.ops.plan_spmm`` /
``plan_spgemm`` now delegate here).
"""
from repro.analysis.invariants import (Finding, PlanVerificationError,
                                       VerifyResult, verify_plan)
from repro.core.formats import (QUANT_DTYPES, QuantizedBlocks,
                                dequantize_blocks, quant_error_bound,
                                quantize_blocks)
from repro.core.policies import (SchedulePolicy, available_policies,
                                 get_policy, register_policy,
                                 unregister_policy)

from .backends import (BACKENDS, available_backends, default_backend,
                       resolve_backend, set_default_backend, use_backend)
from .executor import apply_plan, execute_plan, pick_bn
from .plan import SPGEMM, SPMM, SegmentPlan
from .planner import (clear_plan_cache, pattern_fingerprint, plan_cache_stats,
                      plan_matmul)

__all__ = [
    # plans
    "SegmentPlan", "SPMM", "SPGEMM",
    "plan_matmul", "execute_plan", "apply_plan", "pick_bn",
    "clear_plan_cache", "plan_cache_stats", "pattern_fingerprint",
    # static verification (full surface lives in repro.analysis)
    "verify_plan", "Finding", "VerifyResult", "PlanVerificationError",
    # quantized block storage
    "QUANT_DTYPES", "QuantizedBlocks", "quantize_blocks",
    "dequantize_blocks", "quant_error_bound",
    # policy registry
    "SchedulePolicy", "register_policy", "unregister_policy", "get_policy",
    "available_policies",
    # backends
    "BACKENDS", "available_backends", "default_backend", "set_default_backend",
    "resolve_backend", "use_backend",
]
