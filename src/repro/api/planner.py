"""``plan_matmul`` — the front door: pattern → :class:`SegmentPlan`.

Planning is host-side numpy work (ordering, folding, lane partitioning,
finalization) that only depends on the *sparsity pattern*, not the block
values — so plans are cached by a pattern fingerprint and re-realized with
fresh values per call.  Static weight sparsity amortizes the scheduling cost
exactly as DESIGN.md §2 argues; the cache makes that amortization automatic
instead of manual.  Realization is **zero-copy**: block values ride along in
original BSR storage order and the schedule addresses them through a
``slot_idx`` scalar-prefetch array, so a cache hit never gathers O(nnz)
data on the host.

``plan_matmul(A, B_or_shape)`` dispatches on the right-hand side:

* ``BSR``                    → SpGEMM plan (B frozen into the plan);
* dense array / shape / int  → SpMM plan (the dense N is only a traffic
  hint; any dense rhs with matching K can be passed at execution time);
* ``with_grad=True``         → the plan additionally carries the transposed
  schedule (``grad_plan``) so :func:`repro.api.executor.apply_plan` can run
  the backward pass against the *forward* weight storage (the kernel's
  ``transpose_lhs`` mode — no transposed copy of W exists);
* ``n_lanes > 1``            → the schedule is split into load-balanced
  parallel lanes at segment-chain boundaries (see
  :func:`repro.core.schedule.partition_lanes`); ``unroll`` additionally
  groups items per grid step;
* ``quantize="int8"|"fp8"``  → block values are stored as a quantized
  payload + per-block fp32 scales (dequantized in-kernel at the fp32
  accumulator); the fingerprint carries the storage dtype, so quantized
  and fp32 plans of one pattern never collide in the cache.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.analysis.invariants import (PlanVerificationError, VerifyResult,
                                       check_scale_agreement, verify_plan)
from repro.core.formats import (BSR, QUANT_DTYPES, QUANT_MODES,
                                QuantizedBlocks, quant_base_dtype,
                                quant_is_rowwise, quantize_blocks)
from repro.core.policies import get_policy
from repro.core.schedule import (PREFETCH_MODES, LaneLayout,
                                 build_spgemm_schedule, build_spmm_schedule,
                                 fetch_flags, finalize_schedule, lane_select,
                                 lane_traffic_spgemm, lane_traffic_spmm,
                                 partition_lanes)

from .backends import resolve_backend
from .plan import SPGEMM, SPMM, SegmentPlan


def _freeze_traffic(traffic: dict) -> Tuple[Tuple[str, float], ...]:
    return tuple(sorted(traffic.items()))


def _scale_spmm_traffic(basis: dict, n_cols: int) -> dict:
    """Re-price a unit-N traffic basis for a concrete dense width.

    A-tile bytes are N-independent; B and C bytes scale linearly with the
    dense column count (the basis is evaluated at ``n_cols=1``), so the
    *schedule* — and therefore the plan cache entry — never depends on N.
    """
    out = dict(basis)
    out["b_bytes"] = basis["b_bytes"] * n_cols
    out["c_bytes"] = basis["c_bytes"] * n_cols
    out["total"] = basis["a_bytes"] + out["b_bytes"] + out["c_bytes"]
    return out


def _pattern_bytes(h, m: BSR) -> None:
    h.update(np.asarray(m.shape, np.int64).tobytes())
    h.update(np.asarray(m.block_shape, np.int64).tobytes())
    h.update(np.ascontiguousarray(m.brow, np.int64).tobytes())
    h.update(np.ascontiguousarray(m.bcol, np.int64).tobytes())


def _bucket_hint(n: Optional[int]) -> Optional[int]:
    """Power-of-two ceiling bucket for the dense-N traffic hint.

    The *schedule* never depends on N, but the cached unit-N traffic basis
    is re-priced per realize and downstream consumers (the ``repro.tune``
    cost model, the plan-time VMEM gate's ``pick_bn`` clamp) read the
    realized numbers — so plans for wildly different widths must not share
    a cache identity.  Bucketing to the next power of two keeps nearby
    widths (e.g. 640 and 768 → 1024) on one entry while separating 64 from
    640."""
    if n is None:
        return None
    n = int(n)
    return 1 << max(0, (n - 1).bit_length())


def pattern_fingerprint(kind: str, policy_key: str, fold_len: Optional[int],
                        with_grad: bool, *mats: BSR, n_lanes: int = 1,
                        unroll: int = 1, block_dtype: str = "fp32",
                        n_bucket: Optional[int] = None, pipeline: bool = True,
                        bn_hint: Optional[int] = None,
                        prefetch: Optional[str] = None) -> str:
    """Digest of everything the *schedule* and the cached pricing depend on
    (never block values).  ``policy_key`` should include the policy's
    registration serial so re-registering a name under a different ordering
    can't be served a stale schedule.  ``block_dtype`` is part of the
    digest: a quantized plan carries scale leaves and dtype-scaled traffic
    that an fp32 plan of the same pattern must never be served.
    ``n_bucket`` is the *bucketed* dense-N hint (see :func:`_bucket_hint`)
    — the raw hint stays out so nearby widths share one template, but
    orders-of-magnitude-different widths no longer collide.  ``pipeline``
    and ``bn_hint`` are part of the key because they change the recorded
    traffic pricing and the executor behaviour baked into the template."""
    h = hashlib.sha1()
    h.update(f"{kind}|{policy_key}|{fold_len}|{with_grad}"
             f"|lanes={n_lanes}|unroll={unroll}"
             f"|dtype={block_dtype}|nbkt={n_bucket}"
             f"|pipe={pipeline}|bn={bn_hint}|pf={prefetch}".encode())
    for m in mats:
        _pattern_bytes(h, m)
    return h.hexdigest()


def _scale_fetch_bytes(block_dtype: str, rows: int) -> int:
    """fp32 scale bytes a quantized tile fetch drags along: one scalar per
    block, or one per block row in rowwise mode."""
    return (rows if quant_is_rowwise(block_dtype) else 1) * 4


def _quantize_a_traffic(basis: dict, block_dtype: str, bm: int,
                        bk: int) -> dict:
    """Re-price a traffic estimate's A-tile bytes for a quantized payload.

    An A fetch moves ``bm·bk`` payload bytes plus the fp32 scales (one per
    block, or ``bm`` per block in rowwise mode) instead of ``bm·bk`` fp32
    elements; B/C stay fp32 (the dense rhs and the fp32 accumulator output
    are not quantized)."""
    if block_dtype == "fp32":
        return basis
    itemsize = QUANT_DTYPES[quant_base_dtype(block_dtype)].itemsize
    out = dict(basis)
    out["a_bytes"] = basis["a_fetches"] * (
        bm * bk * itemsize + _scale_fetch_bytes(block_dtype, bm))
    out["total"] = out["a_bytes"] + out["b_bytes"] + out["c_bytes"]
    return out


def _quantize_spgemm_traffic(traffic: dict, block_dtype: str, bm: int,
                             bk: int, bn: int) -> dict:
    """Same re-pricing for SpGEMM, where both operands are quantized
    (B's rowwise scales run over its ``bk`` rows)."""
    if block_dtype == "fp32":
        return traffic
    itemsize = QUANT_DTYPES[quant_base_dtype(block_dtype)].itemsize
    out = dict(traffic)
    out["a_bytes"] = traffic["a_fetches"] * (
        bm * bk * itemsize + _scale_fetch_bytes(block_dtype, bm))
    out["b_bytes"] = traffic["b_fetches"] * (
        bk * bn * itemsize + _scale_fetch_bytes(block_dtype, bk))
    out["total"] = out["a_bytes"] + out["b_bytes"] + out["c_bytes"]
    return out


def _realize_values(blocks, block_dtype: str):
    """Device ``(payload, scales)`` for a plan's value leaves.

    fp32 plans upload the caller's buffer as-is (identity when it already
    lives on device).  Quantized plans accept either a pre-quantized
    :class:`~repro.core.formats.QuantizedBlocks` — payload + scales upload
    verbatim, the zero-copy path for weights quantized once at load time —
    or an fp32 array, quantized here per block (elementwise, storage order
    preserved: still no schedule-order gather)."""
    if isinstance(blocks, QuantizedBlocks):
        if blocks.dtype != block_dtype:
            raise ValueError(
                f"pre-quantized blocks are {blocks.dtype!r} but the plan "
                f"was requested with quantize={block_dtype!r}")
        return jnp.asarray(blocks.payload), jnp.asarray(blocks.scales)
    if block_dtype == "fp32":
        return jnp.asarray(blocks), None
    q = quantize_blocks(np.asarray(blocks), block_dtype)
    return jnp.asarray(q.payload), jnp.asarray(q.scales)


@dataclasses.dataclass
class _PlanTemplate:
    """A value-free plan; realization attaches fresh block values verbatim.

    There is deliberately no permutation here: the schedule addresses block
    storage through ``slot_idx``, so ``realize`` is a device upload of the
    caller's arrays (identity when they already live on device) — never an
    O(nnz) gather.  Traffic is stored as a unit-N basis and re-priced per
    realize so one template serves every dense width of the same pattern."""

    plan: SegmentPlan                           # lhs/rhs_blocks are None
    traffic_basis: Optional[dict] = None        # spmm fwd, at n_cols=1
    grad_traffic_basis: Optional[dict] = None   # spmm bwd, at n_cols=1
    verified_level: Optional[str] = None        # deepest verify_plan run yet

    def realize(self, a: BSR, b: Optional[BSR], backend: Optional[str],
                n_cols_hint: int, out_dtype: Optional[str]) -> SegmentPlan:
        dtype = self.plan.block_dtype
        lhs_blocks, lhs_scales = _realize_values(a.blocks, dtype)
        if self.plan.kind == SPMM:
            grad = self.plan.grad_plan
            if grad is not None and self.grad_traffic_basis is not None:
                grad = grad.replace(traffic_items=_freeze_traffic(
                    _scale_spmm_traffic(self.grad_traffic_basis, n_cols_hint)))
            return self.plan.replace(
                lhs_blocks=lhs_blocks, lhs_scales=lhs_scales,
                traffic_items=_freeze_traffic(
                    _scale_spmm_traffic(self.traffic_basis, n_cols_hint)),
                grad_plan=grad, backend=backend, out_dtype=out_dtype)
        rhs_blocks, rhs_scales = _realize_values(b.blocks, dtype)
        return self.plan.replace(lhs_blocks=lhs_blocks, lhs_scales=lhs_scales,
                                 rhs_blocks=rhs_blocks, rhs_scales=rhs_scales,
                                 backend=backend, out_dtype=out_dtype)


_CACHE: Dict[str, _PlanTemplate] = {}
# hits/misses: template cache; searched/search_cache_hits/dataflow_fallbacks:
# autotune counters incremented by repro.tune.search (kept here so
# plan_cache_stats is the one stats surface and clear_plan_cache the one
# reset)
_STATS = {"hits": 0, "misses": 0,
          "searched": 0, "search_cache_hits": 0, "dataflow_fallbacks": 0}


def clear_plan_cache() -> None:
    """Drop every cached template — all ``block_dtype`` variants included
    (fp32 and quantized plans of one pattern are distinct entries) — and
    the :mod:`repro.tune` schedule-search cache alongside it."""
    import sys
    _CACHE.clear()
    for k in _STATS:
        _STATS[k] = 0
    # only if the tuner was ever imported — never import it from here (the
    # tune package imports this module at top level)
    ts = sys.modules.get("repro.tune.search")
    if ts is not None:
        ts._SEARCH_CACHE.clear()


def plan_cache_stats() -> Dict[str, int]:
    """Hit/miss counters + cache size, with entries broken out per
    ``block_dtype`` (``by_dtype``) — quantized plans of a pattern are
    separate cache entries from the fp32 plan of the same pattern.

    Also carries the autotune counters: ``searched`` (schedule searches
    actually run), ``search_cache_hits`` (searches answered from the tuned
    fingerprint cache at zero cost), and ``dataflow_fallbacks`` (times the
    analytically best dataflow had no registered policy and the tuner fell
    back to the best dispatchable one)."""
    by_dtype: Dict[str, int] = {}
    for tpl in _CACHE.values():
        d = tpl.plan.block_dtype
        by_dtype[d] = by_dtype.get(d, 0) + 1
    return dict(_STATS, size=len(_CACHE), by_dtype=by_dtype)


def _lane_flags(layout: LaneLayout, seg_start, seg_write, accum_prev) -> dict:
    """Lane-major schedule flag arrays — host numpy; the build path feeds
    them to the traffic model before :func:`_flag_leaves` uploads them."""
    return dict(
        seg_start=lane_select(layout, seg_start, zero_pads=True),
        seg_write=lane_select(layout, seg_write, zero_pads=True),
        accum_prev=lane_select(layout, accum_prev, zero_pads=True),
        valid=layout.valid.reshape(-1).astype(np.int32))


def _fetch_schedule(layout: LaneLayout, a_stream: np.ndarray,
                    b_stream: np.ndarray, unroll: int) -> dict:
    """DMA-pipeline fetch flags + ring-buffer slots for both operand streams.

    ``a_stream``/``b_stream`` are the *lane-major* operand index arrays the
    kernel addresses HBM with (A block slot, and ``k`` / B block slot).
    The ring depth is ``2·unroll`` — one slot set computing, one filling —
    matching the kernels' scratch allocation.
    """
    valid = layout.valid.reshape(-1)
    depth = 2 * unroll
    a_f, a_s = fetch_flags(a_stream, valid, layout.n_lanes, depth=depth)
    b_f, b_s = fetch_flags(b_stream, valid, layout.n_lanes, depth=depth)
    return dict(a_fetch=a_f, b_fetch=b_f, a_slot=a_s, b_slot=b_s)


def _flag_leaves(flags: dict) -> dict:
    """jnp device leaves for a plan's flag arrays (one upload, at the end of
    the build — never a device→host round trip on the build path)."""
    return {k: jnp.asarray(v) for k, v in flags.items()}


def _build_spmm_template(a: BSR, policy: str, fold_len: Optional[int],
                         with_grad: bool, n_lanes: int, unroll: int,
                         fingerprint: str, block_dtype: str = "fp32",
                         pipeline: bool = True,
                         bn_hint: Optional[int] = None,
                         prefetch: Optional[str] = None) -> _PlanTemplate:
    sched = build_spmm_schedule(a, policy=policy, fold_len=fold_len)
    fin = finalize_schedule(sched.seg_start, sched.m, n_slots=sched.n_m_blocks)
    bm, bk = a.block_shape
    layout = partition_lanes(sched.m, n_lanes, unroll=unroll, policy=policy,
                             seg_start=sched.seg_start,
                             seg_write=sched.seg_write,
                             accum_prev=fin.accum_prev)
    lane_m = lane_select(layout, sched.m)
    lane_k = lane_select(layout, sched.k)
    lane_slot = lane_select(layout, sched.a_idx)
    flags = _lane_flags(layout, sched.seg_start, sched.seg_write,
                        fin.accum_prev)
    fetch = _fetch_schedule(layout, lane_slot, lane_k, unroll)
    basis = _quantize_a_traffic(lane_traffic_spmm(
        lane_m, lane_k, flags["seg_start"],
        layout.valid.reshape(-1), layout.n_lanes, bm, bk, 1, unroll=unroll,
        pipeline=pipeline, prefetch=prefetch),
        block_dtype, bm, bk)
    basis.update(layout.stats)

    grad_plan = None
    grad_basis = None
    if with_grad:
        # Transposed matrix Wᵀ: same stored blocks, coords swapped, re-sorted
        # row-major; schedule it independently, then address each item's
        # block in the *forward storage order* via slot_idx — the kernel's
        # transpose_lhs mode contracts along block rows, so the backward
        # pass reads the forward weight array with no transposed copy.
        t_order = np.lexsort((a.brow, a.bcol)).astype(np.int64)
        wt = BSR(shape=(a.shape[1], a.shape[0]), block_shape=(bk, bm),
                 brow=a.bcol[t_order].copy(), bcol=a.brow[t_order].copy(),
                 blocks=np.empty((a.nblocks, 1, 1), np.float32))
        t_sched = build_spmm_schedule(wt, policy=policy, fold_len=fold_len)
        t_fin = finalize_schedule(t_sched.seg_start, t_sched.m,
                                  n_slots=t_sched.n_m_blocks)
        t_layout = partition_lanes(t_sched.m, n_lanes, unroll=unroll,
                                   policy=policy,
                                   seg_start=t_sched.seg_start,
                                   seg_write=t_sched.seg_write,
                                   accum_prev=t_fin.accum_prev)
        t_slot = t_order[t_sched.a_idx.astype(np.int64)]
        t_lane_m = lane_select(t_layout, t_sched.m)
        t_lane_k = lane_select(t_layout, t_sched.k)
        t_lane_slot = lane_select(t_layout, t_slot)
        t_flags = _lane_flags(t_layout, t_sched.seg_start, t_sched.seg_write,
                              t_fin.accum_prev)
        t_fetch = _fetch_schedule(t_layout, t_lane_slot, t_lane_k, unroll)
        grad_basis = _quantize_a_traffic(lane_traffic_spmm(
            t_lane_m, t_lane_k, t_flags["seg_start"],
            t_layout.valid.reshape(-1), t_layout.n_lanes, bk, bm, 1,
            unroll=unroll, pipeline=pipeline, prefetch=prefetch),
            block_dtype, bk, bm)
        grad_basis.update(t_layout.stats)
        grad_plan = SegmentPlan(
            kind=SPMM, policy=policy, block_shape=(bk, bm),
            grid=(t_sched.n_m_blocks, t_sched.n_k_blocks), rhs_grid=None,
            n_out_blocks=t_sched.n_m_blocks,
            traffic_items=(),   # re-priced per realize from grad_basis
            fingerprint=fingerprint + ":grad",
            block_dtype=block_dtype,
            n_lanes=t_layout.n_lanes, unroll=unroll, transpose_lhs=True,
            pipeline=pipeline, bn_hint=bn_hint, prefetch=prefetch,
            has_pads=bool(not t_layout.valid.all()),
            m_idx=jnp.asarray(t_lane_m.astype(np.int32)),
            k_idx=jnp.asarray(t_lane_k.astype(np.int32)),
            slot_idx=jnp.asarray(t_lane_slot.astype(np.int32)),
            row_mask=jnp.asarray(t_fin.row_mask),
            a_brow=jnp.asarray(a.brow), a_bcol=jnp.asarray(a.bcol),
            **_flag_leaves(t_flags), **_flag_leaves(t_fetch))

    plan = SegmentPlan(
        kind=SPMM, policy=policy, block_shape=(bm, bk),
        grid=(sched.n_m_blocks, sched.n_k_blocks), rhs_grid=None,
        n_out_blocks=sched.n_m_blocks,
        traffic_items=(),   # re-priced per realize from traffic_basis
        fingerprint=fingerprint, block_dtype=block_dtype,
        n_lanes=layout.n_lanes, unroll=unroll,
        pipeline=pipeline, bn_hint=bn_hint, prefetch=prefetch,
        has_pads=bool(not layout.valid.all()),
        m_idx=jnp.asarray(lane_m.astype(np.int32)),
        k_idx=jnp.asarray(lane_k.astype(np.int32)),
        slot_idx=jnp.asarray(lane_slot.astype(np.int32)),
        row_mask=jnp.asarray(fin.row_mask),
        a_brow=jnp.asarray(a.brow), a_bcol=jnp.asarray(a.bcol),
        grad_plan=grad_plan, **_flag_leaves(flags), **_flag_leaves(fetch))
    return _PlanTemplate(plan=plan, traffic_basis=basis,
                         grad_traffic_basis=grad_basis)


def _build_spgemm_template(a: BSR, b: BSR, policy: str,
                           fold_len: Optional[int], n_lanes: int, unroll: int,
                           fingerprint: str, block_dtype: str = "fp32",
                           pipeline: bool = True,
                           bn_hint: Optional[int] = None,
                           prefetch: Optional[str] = None) -> _PlanTemplate:
    sched = build_spgemm_schedule(a, b, policy=policy, fold_len=fold_len)
    fin = finalize_schedule(sched.seg_start, sched.c_idx)
    bm, bk = a.block_shape
    bn = b.block_shape[1]
    layout = partition_lanes(sched.c_idx, n_lanes, unroll=unroll,
                             policy=policy, seg_start=sched.seg_start,
                             seg_write=sched.seg_write,
                             accum_prev=fin.accum_prev)
    lane_a = lane_select(layout, sched.a_idx)
    lane_b = lane_select(layout, sched.b_idx)
    lane_c = lane_select(layout, sched.c_idx)
    flags = _lane_flags(layout, sched.seg_start, sched.seg_write,
                        fin.accum_prev)
    fetch = _fetch_schedule(layout, lane_a, lane_b, unroll)
    traffic = _quantize_spgemm_traffic(lane_traffic_spgemm(
        lane_a, lane_b, lane_c, flags["seg_start"],
        layout.valid.reshape(-1), layout.n_lanes, bm, bk, bn, unroll=unroll,
        pipeline=pipeline, prefetch=prefetch),
        block_dtype, bm, bk, bn)
    traffic.update(layout.stats)
    plan = SegmentPlan(
        kind=SPGEMM, policy=policy, block_shape=(bm, bk),
        grid=a.grid, rhs_grid=b.grid, n_out_blocks=sched.n_c_blocks,
        traffic_items=_freeze_traffic(traffic),
        fingerprint=fingerprint, block_dtype=block_dtype,
        n_lanes=layout.n_lanes, unroll=unroll,
        pipeline=pipeline, bn_hint=bn_hint, prefetch=prefetch,
        has_pads=bool(not layout.valid.all()),
        a_idx=jnp.asarray(lane_a.astype(np.int32)),
        b_idx=jnp.asarray(lane_b.astype(np.int32)),
        c_idx=jnp.asarray(lane_c.astype(np.int32)),
        a_brow=jnp.asarray(a.brow), a_bcol=jnp.asarray(a.bcol),
        b_brow=jnp.asarray(b.brow), b_bcol=jnp.asarray(b.bcol),
        c_brow_arr=jnp.asarray(sched.c_brow),
        c_bcol_arr=jnp.asarray(sched.c_bcol),
        **_flag_leaves(flags), **_flag_leaves(fetch))
    return _PlanTemplate(plan=plan)


def _resolve_verify(verify) -> Optional[str]:
    """Normalize the ``verify`` knob: None/False off, True → "fast"."""
    if verify is None or verify is False:
        return None
    if verify is True:
        return "fast"
    if verify in ("fast", "full"):
        return verify
    raise ValueError(f"verify must be None/False/True/'fast'/'full', "
                     f"got {verify!r}")


def _rhs_to_hint(a: BSR, b) -> Tuple[Optional[BSR], int]:
    """Normalize ``B_or_shape`` → (BSR | None, n_cols_hint)."""
    if b is None:
        return None, 1024
    if isinstance(b, BSR):
        return b, b.shape[1]
    if isinstance(b, int):
        shape: Tuple[int, ...] = (a.shape[1], b)
    elif isinstance(b, tuple):
        shape = b
    elif hasattr(b, "shape"):
        shape = tuple(b.shape)
    else:
        raise TypeError(f"B_or_shape must be a BSR, dense array, shape tuple "
                        f"or int N, got {type(b).__name__}")
    if len(shape) != 2:
        raise ValueError(f"dense rhs must be 2-D (K, N), got shape {shape}")
    if shape[0] != a.shape[1]:
        raise ValueError(f"rhs K={shape[0]} does not match A K={a.shape[1]}")
    return None, int(shape[1])


def plan_matmul(a: BSR, b_or_shape=None, *, policy: str = "segment",
                backend: Optional[str] = None, fold_len: Optional[int] = None,
                with_grad: bool = False, n_cols_hint: Optional[int] = None,
                n_lanes: int = 1, unroll: int = 1, cache: bool = True,
                quantize: Optional[str] = None,
                out_dtype=None, verify=None,
                vmem_limit_bytes: Optional[int] = None,
                pipeline: bool = True,
                bn_hint: Optional[int] = None,
                prefetch: Optional[str] = None) -> SegmentPlan:
    """Plan a Segment-dataflow matmul for the sparsity pattern of ``a``.

    Args:
      a: the BSR left operand (pattern + values).
      b_or_shape: ``BSR`` (SpGEMM), or the dense rhs / its ``(K, N)`` shape /
        ``N`` (SpMM; only used as a traffic hint), or None.
      policy: any name in the policy registry, or ``"auto"`` — run the
        :mod:`repro.tune` schedule search over the knob grid and the
        registered dataflows and plan with the winning (policy, fold_len,
        n_lanes, unroll, pipeline, bn) combination.  Knobs passed
        explicitly alongside ``policy="auto"`` are treated as pins the
        search must honour.  Winning schedules are cached by pattern
        fingerprint, so repeat patterns pay zero search cost.
      backend: preferred execution backend recorded on the plan (resolvable
        later; ``None`` defers to the process default).
      fold_len: temporal-fold cap on segment length (fold-capable policies).
      with_grad: also build the transposed schedule so ``apply_plan`` can run
        the backward pass (SpMM only).
      n_cols_hint: overrides the traffic model's dense-N estimate.
      n_lanes: split the schedule into this many load-balanced parallel
        lanes (clamped to the number of output segments).
      unroll: schedule items executed per kernel grid step (aligned at
        plan time; amortizes grid overhead on small blocks).
      cache: reuse the pattern-fingerprint plan cache.
      quantize: ``"int8"`` / ``"fp8"`` store block values as a quantized
        payload + per-block fp32 scales, dequantized in-kernel at the fp32
        accumulator (both operands for SpGEMM; the dense rhs stays fp32).
        ``"int8.rowwise"`` / ``"fp8.rowwise"`` carry one fp32 scale per
        *block row* instead — better resolution on outlier-heavy weights,
        dequantized before the MXU dot.  ``None`` keeps fp32 storage.
        Quantized and fp32 plans of one pattern never share a cache entry
        or fingerprint (the mode string is the plan's ``block_dtype``).
      out_dtype: default dtype of the written output tiles (resolved at
        execution; overridable per call).  Accumulation stays fp32.
      verify: run the static schedule verifier
        (:func:`repro.analysis.verify_plan`) and raise
        :class:`~repro.analysis.PlanVerificationError` on any finding.
        ``True``/``"fast"`` runs the structural catalog, ``"full"`` adds
        the independent traffic-model count recomputation.  The expensive
        pass runs once per cached *template* (remembered on the cache
        entry), so per-call overhead on a cache hit is a single O(1)
        scale-agreement check on the realized values.
      vmem_limit_bytes: when set, check the plan's worst-case kernel VMEM
        working set (forward and, with ``with_grad``, the transposed
        backward instance; see :func:`repro.analysis.plan_vmem_bytes`)
        against this per-core byte limit and raise
        :class:`~repro.analysis.VmemBudgetError` at plan time — a bad
        (block, bn, unroll) knob combination fails here, not as an OOM at
        launch.  The N-tile width is taken as the executor default
        (``bn_hint`` or 512) clamped by ``pick_bn`` to the traffic hint's N.
      pipeline: ``False`` builds the plan for the legacy BlockSpec
        auto-pipeline instead of the explicit DMA pipeline; the recorded
        traffic estimate follows the same switch.
      bn_hint: preferred executor N-tile width, used when the caller passes
        no explicit ``bn`` at execution time (set by the :mod:`repro.tune`
        search; ``None`` keeps the executor default of 512).
      prefetch: DMA schedule mode (:data:`repro.core.schedule
        .PREFETCH_MODES`).  ``"cross_pass"`` makes the SpMM kernel issue
        the next (lane, N-tile) pass's first copies — B row-tiles before A
        tiles — during the current pass's tail step instead of draining
        the pipeline at the boundary; numerically identical (the mode
        re-times copies, it never changes which items fetch).  Requires
        the explicit DMA pipeline.  The recorded traffic gains a
        ``prefetch_fetches`` entry pricing the overlapped copies, and
        every shipped kernel variant with prefetch enabled is proven
        hazard-free by :mod:`repro.analysis.order` in CI.
    """
    if backend is not None:
        resolve_backend(backend)   # fail fast on typos
    if quantize is not None and quantize not in QUANT_MODES:
        raise ValueError(f"unknown quantize dtype {quantize!r}; "
                         f"available: {QUANT_MODES} or None")
    if prefetch not in PREFETCH_MODES:
        raise ValueError(f"prefetch={prefetch!r} not in {PREFETCH_MODES}")
    if prefetch is not None and not pipeline:
        raise ValueError(
            "prefetch='cross_pass' requires the explicit DMA pipeline "
            "(pipeline=True); the legacy BlockSpec path has no cross-pass "
            "copy timing to overlap")
    block_dtype = quantize if quantize is not None else "fp32"
    out_dtype = None if out_dtype is None else jnp.dtype(out_dtype).name
    if policy == "auto":
        # dataflow selection + knob search live in repro.tune; import
        # lazily so the plain build path never pays for (or cycles with)
        # the tuner.  Explicit knobs become pins the search must honour.
        from repro.tune.search import select_schedule
        b0, hint0 = _rhs_to_hint(a, b_or_shape)
        if n_cols_hint is not None:
            hint0 = n_cols_hint
        pins: Dict[str, object] = {}
        if fold_len is not None:
            pins["fold_len"] = fold_len
        if n_lanes != 1:
            pins["n_lanes"] = n_lanes
        if unroll != 1:
            pins["unroll"] = unroll
        if pipeline is not True:
            pins["pipeline"] = pipeline
        if bn_hint is not None:
            pins["bn"] = bn_hint
        if prefetch is not None:
            pins["prefetch"] = prefetch
        # tune for the backend the plan will actually run on: the compiled
        # model prices lanes as concurrent grid dimensions, the interpret
        # model prices the grid sequentially
        objective = ("tpu" if resolve_backend(backend) == "pallas"
                     else "interpret")
        best = select_schedule(a, b0, n_cols_hint=hint0, with_grad=with_grad,
                               quantize=quantize, objective=objective,
                               vmem_limit_bytes=vmem_limit_bytes, pins=pins)
        return plan_matmul(
            a, b_or_shape, policy=best.policy, backend=backend,
            fold_len=best.fold_len, with_grad=with_grad,
            n_cols_hint=n_cols_hint, n_lanes=best.n_lanes,
            unroll=best.unroll, cache=cache, quantize=quantize,
            out_dtype=out_dtype, verify=verify,
            vmem_limit_bytes=vmem_limit_bytes, pipeline=best.pipeline,
            bn_hint=best.bn, prefetch=best.prefetch)
    pol = get_policy(policy)       # fail fast + serial for the cache key
    b, hint = _rhs_to_hint(a, b_or_shape)
    if n_cols_hint is not None:
        hint = n_cols_hint
    if b is not None and with_grad:
        raise NotImplementedError("with_grad is only supported for SpMM plans")

    kind = SPGEMM if b is not None else SPMM
    mats = (a, b) if b is not None else (a,)
    key = pattern_fingerprint(kind, f"{policy}#{pol.serial}", fold_len,
                              with_grad, *mats, n_lanes=n_lanes,
                              unroll=unroll, block_dtype=block_dtype,
                              n_bucket=_bucket_hint(hint) if b is None
                              else None,
                              pipeline=pipeline, bn_hint=bn_hint,
                              prefetch=prefetch)
    level = _resolve_verify(verify)
    tpl = _CACHE.get(key) if cache else None
    if tpl is None:
        if kind == SPMM:
            tpl = _build_spmm_template(a, policy, fold_len, with_grad,
                                       n_lanes, unroll, key, block_dtype,
                                       pipeline=pipeline, bn_hint=bn_hint,
                                       prefetch=prefetch)
        else:
            tpl = _build_spgemm_template(a, b, policy, fold_len, n_lanes,
                                         unroll, key, block_dtype,
                                         pipeline=pipeline, bn_hint=bn_hint,
                                         prefetch=prefetch)
        _STATS["misses"] += 1   # a build is a miss whether or not it's kept
        if cache:
            _CACHE[key] = tpl
    else:
        _STATS["hits"] += 1
    if level is not None:
        covered = ("fast", "full") if level == "fast" else ("full",)
        if tpl.verified_level not in covered:
            # verify the value-free template once; the result is remembered
            # on the cache entry so repeated realizations stay O(1)
            verify_plan(tpl.plan, level=level).raise_if_findings()
            tpl.verified_level = level
    plan = tpl.realize(a, b, backend, hint, out_dtype)
    if level is not None:
        # the only per-realize degree of freedom is the value leaves —
        # check just their dtype/shape agreement on every call (the direct
        # single-invariant call keeps the cache-hit path O(1))
        findings = check_scale_agreement(plan)
        if findings:
            raise PlanVerificationError(VerifyResult(
                findings=tuple(findings), level=level,
                checked=("scale-agreement",)))
    if vmem_limit_bytes is not None:
        # lazy imports: the executor for bn clamping, the analyzer for the
        # budget — neither belongs on the plain plan-build path
        from repro.analysis.budget import check_plan_vmem

        from .executor import pick_bn
        bn_eff, _ = pick_bn(max(1, hint), bn_hint or 512)
        check_plan_vmem(plan, bn=bn_eff, limit=vmem_limit_bytes,
                        label=f"plan_matmul[{kind}]")
    return plan
