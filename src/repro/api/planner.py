"""``plan_matmul`` — the front door: pattern → :class:`SegmentPlan`.

Planning is host-side numpy work (ordering, folding, finalization) that only
depends on the *sparsity pattern*, not the block values — so plans are cached
by a pattern fingerprint and re-realized with fresh values per call.  Static
weight sparsity amortizes the scheduling cost exactly as DESIGN.md §2 argues;
the cache makes that amortization automatic instead of manual.

``plan_matmul(A, B_or_shape)`` dispatches on the right-hand side:

* ``BSR``                    → SpGEMM plan (B frozen into the plan);
* dense array / shape / int  → SpMM plan (the dense N is only a traffic
  hint; any dense rhs with matching K can be passed at execution time);
* ``with_grad=True``         → the plan additionally carries the transposed
  schedule (``grad_plan``) so :func:`repro.api.executor.apply_plan` can run
  the backward pass.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core.formats import BSR
from repro.core.policies import get_policy
from repro.core.schedule import (build_spgemm_schedule, build_spmm_schedule,
                                 finalize_schedule, spgemm_schedule_traffic,
                                 spmm_schedule_traffic)

from .backends import resolve_backend
from .plan import SPGEMM, SPMM, SegmentPlan


def _freeze_traffic(traffic: dict) -> Tuple[Tuple[str, float], ...]:
    return tuple(sorted(traffic.items()))


def _scale_spmm_traffic(basis: dict, n_cols: int) -> dict:
    """Re-price a unit-N traffic basis for a concrete dense width.

    A-tile bytes are N-independent; B and C bytes scale linearly with the
    dense column count (the basis is evaluated at ``n_cols=1``), so the
    *schedule* — and therefore the plan cache entry — never depends on N.
    """
    b = basis["b_bytes"] * n_cols
    c = basis["c_bytes"] * n_cols
    return dict(a_bytes=basis["a_bytes"], b_bytes=b, c_bytes=c,
                total=basis["a_bytes"] + b + c,
                b_fetches=basis["b_fetches"], c_segments=basis["c_segments"])


def _pattern_bytes(h, m: BSR) -> None:
    h.update(np.asarray(m.shape, np.int64).tobytes())
    h.update(np.asarray(m.block_shape, np.int64).tobytes())
    h.update(np.ascontiguousarray(m.brow, np.int64).tobytes())
    h.update(np.ascontiguousarray(m.bcol, np.int64).tobytes())


def pattern_fingerprint(kind: str, policy_key: str, fold_len: Optional[int],
                        with_grad: bool, *mats: BSR) -> str:
    """Digest of everything the *schedule* depends on (never block values,
    never the dense-N traffic hint).  ``policy_key`` should include the
    policy's registration serial so re-registering a name under a different
    ordering can't be served a stale schedule."""
    h = hashlib.sha1()
    h.update(f"{kind}|{policy_key}|{fold_len}|{with_grad}".encode())
    for m in mats:
        _pattern_bytes(h, m)
    return h.hexdigest()


@dataclasses.dataclass
class _PlanTemplate:
    """A value-free plan + the gather needed to fill fresh values.

    Traffic is stored as a unit-N basis and re-priced per realize so one
    template serves every dense width of the same pattern."""

    plan: SegmentPlan                       # lhs/rhs_blocks are None
    fwd_perm: Optional[np.ndarray]          # spmm: original → schedule order
    traffic_basis: Optional[dict] = None        # spmm fwd, at n_cols=1
    grad_traffic_basis: Optional[dict] = None   # spmm bwd, at n_cols=1

    def realize(self, a: BSR, b: Optional[BSR], backend: Optional[str],
                n_cols_hint: int) -> SegmentPlan:
        if self.plan.kind == SPMM:
            grad = self.plan.grad_plan
            if grad is not None and self.grad_traffic_basis is not None:
                grad = grad.replace(traffic_items=_freeze_traffic(
                    _scale_spmm_traffic(self.grad_traffic_basis, n_cols_hint)))
            return self.plan.replace(
                lhs_blocks=jnp.asarray(a.blocks[self.fwd_perm]),
                traffic_items=_freeze_traffic(
                    _scale_spmm_traffic(self.traffic_basis, n_cols_hint)),
                grad_plan=grad, backend=backend)
        return self.plan.replace(lhs_blocks=jnp.asarray(a.blocks),
                                 rhs_blocks=jnp.asarray(b.blocks),
                                 backend=backend)


_CACHE: Dict[str, _PlanTemplate] = {}
_STATS = {"hits": 0, "misses": 0}


def clear_plan_cache() -> None:
    _CACHE.clear()
    _STATS["hits"] = _STATS["misses"] = 0


def plan_cache_stats() -> Dict[str, int]:
    return dict(_STATS, size=len(_CACHE))


def _build_spmm_template(a: BSR, policy: str, fold_len: Optional[int],
                         with_grad: bool, fingerprint: str) -> _PlanTemplate:
    sched = build_spmm_schedule(a, policy=policy, fold_len=fold_len)
    fin = finalize_schedule(sched.seg_start, sched.m, n_slots=sched.n_m_blocks)
    bm, bk = a.block_shape
    fwd_perm = sched.a_idx.astype(np.int64)

    grad_plan = None
    gather_idx = None
    grad_basis = None
    if with_grad:
        # transposed matrix Wᵀ: same blocks, coords swapped, re-sorted
        # row-major; schedule it independently, then express its per-item
        # block gather in the *forward plan's storage order* so the backward
        # pass reads the same weight array (no duplicate copy).
        t_order = np.lexsort((a.brow, a.bcol)).astype(np.int64)
        wt = BSR(shape=(a.shape[1], a.shape[0]), block_shape=(bk, bm),
                 brow=a.bcol[t_order].copy(), bcol=a.brow[t_order].copy(),
                 blocks=np.empty((a.nblocks, bk, bm), np.float32))
        t_sched = build_spmm_schedule(wt, policy=policy, fold_len=fold_len)
        t_fin = finalize_schedule(t_sched.seg_start, t_sched.m,
                                  n_slots=t_sched.n_m_blocks)
        inv_fwd = np.zeros_like(fwd_perm)
        inv_fwd[fwd_perm] = np.arange(fwd_perm.size)
        gather_idx = inv_fwd[t_order[t_sched.a_idx.astype(np.int64)]]
        grad_basis = spmm_schedule_traffic(t_sched, bk, bm, 1)
        grad_plan = SegmentPlan(
            kind=SPMM, policy=policy, block_shape=(bk, bm),
            grid=(t_sched.n_m_blocks, t_sched.n_k_blocks), rhs_grid=None,
            n_out_blocks=t_sched.n_m_blocks,
            traffic_items=(),   # re-priced per realize from grad_basis
            fingerprint=fingerprint + ":grad",
            m_idx=jnp.asarray(t_sched.m), k_idx=jnp.asarray(t_sched.k),
            seg_start=jnp.asarray(t_sched.seg_start),
            seg_write=jnp.asarray(t_sched.seg_write),
            accum_prev=jnp.asarray(t_fin.accum_prev),
            row_mask=jnp.asarray(t_fin.row_mask),
            gather_idx=jnp.asarray(gather_idx, jnp.int32))

    plan = SegmentPlan(
        kind=SPMM, policy=policy, block_shape=(bm, bk),
        grid=(sched.n_m_blocks, sched.n_k_blocks), rhs_grid=None,
        n_out_blocks=sched.n_m_blocks,
        traffic_items=(),   # re-priced per realize from traffic_basis
        fingerprint=fingerprint,
        m_idx=jnp.asarray(sched.m), k_idx=jnp.asarray(sched.k),
        seg_start=jnp.asarray(sched.seg_start),
        seg_write=jnp.asarray(sched.seg_write),
        accum_prev=jnp.asarray(fin.accum_prev),
        row_mask=jnp.asarray(fin.row_mask),
        grad_plan=grad_plan)
    return _PlanTemplate(plan=plan, fwd_perm=fwd_perm,
                         traffic_basis=spmm_schedule_traffic(sched, bm, bk, 1),
                         grad_traffic_basis=grad_basis)


def _build_spgemm_template(a: BSR, b: BSR, policy: str,
                           fold_len: Optional[int],
                           fingerprint: str) -> _PlanTemplate:
    sched = build_spgemm_schedule(a, b, policy=policy, fold_len=fold_len)
    fin = finalize_schedule(sched.seg_start, sched.c_idx)
    bm, bk = a.block_shape
    bn = b.block_shape[1]
    plan = SegmentPlan(
        kind=SPGEMM, policy=policy, block_shape=(bm, bk),
        grid=a.grid, rhs_grid=b.grid, n_out_blocks=sched.n_c_blocks,
        traffic_items=_freeze_traffic(
            spgemm_schedule_traffic(sched, bm, bk, bn)),
        fingerprint=fingerprint,
        a_idx=jnp.asarray(sched.a_idx), b_idx=jnp.asarray(sched.b_idx),
        c_idx=jnp.asarray(sched.c_idx),
        seg_start=jnp.asarray(sched.seg_start),
        seg_write=jnp.asarray(sched.seg_write),
        accum_prev=jnp.asarray(fin.accum_prev),
        a_brow=jnp.asarray(a.brow), a_bcol=jnp.asarray(a.bcol),
        b_brow=jnp.asarray(b.brow), b_bcol=jnp.asarray(b.bcol),
        c_brow_arr=jnp.asarray(sched.c_brow),
        c_bcol_arr=jnp.asarray(sched.c_bcol))
    return _PlanTemplate(plan=plan, fwd_perm=None)


def _rhs_to_hint(a: BSR, b) -> Tuple[Optional[BSR], int]:
    """Normalize ``B_or_shape`` → (BSR | None, n_cols_hint)."""
    if b is None:
        return None, 1024
    if isinstance(b, BSR):
        return b, b.shape[1]
    if isinstance(b, int):
        shape: Tuple[int, ...] = (a.shape[1], b)
    elif isinstance(b, tuple):
        shape = b
    elif hasattr(b, "shape"):
        shape = tuple(b.shape)
    else:
        raise TypeError(f"B_or_shape must be a BSR, dense array, shape tuple "
                        f"or int N, got {type(b).__name__}")
    if len(shape) != 2:
        raise ValueError(f"dense rhs must be 2-D (K, N), got shape {shape}")
    if shape[0] != a.shape[1]:
        raise ValueError(f"rhs K={shape[0]} does not match A K={a.shape[1]}")
    return None, int(shape[1])


def plan_matmul(a: BSR, b_or_shape=None, *, policy: str = "segment",
                backend: Optional[str] = None, fold_len: Optional[int] = None,
                with_grad: bool = False, n_cols_hint: Optional[int] = None,
                cache: bool = True) -> SegmentPlan:
    """Plan a Segment-dataflow matmul for the sparsity pattern of ``a``.

    Args:
      a: the BSR left operand (pattern + values).
      b_or_shape: ``BSR`` (SpGEMM), or the dense rhs / its ``(K, N)`` shape /
        ``N`` (SpMM; only used as a traffic hint), or None.
      policy: any name in the policy registry.
      backend: preferred execution backend recorded on the plan (resolvable
        later; ``None`` defers to the process default).
      fold_len: temporal-fold cap on segment length (fold-capable policies).
      with_grad: also build the transposed schedule so ``apply_plan`` can run
        the backward pass (SpMM only).
      n_cols_hint: overrides the traffic model's dense-N estimate.
      cache: reuse the pattern-fingerprint plan cache.
    """
    if backend is not None:
        resolve_backend(backend)   # fail fast on typos
    pol = get_policy(policy)       # fail fast + serial for the cache key
    b, hint = _rhs_to_hint(a, b_or_shape)
    if n_cols_hint is not None:
        hint = n_cols_hint
    if b is not None and with_grad:
        raise NotImplementedError("with_grad is only supported for SpMM plans")

    kind = SPGEMM if b is not None else SPMM
    mats = (a, b) if b is not None else (a,)
    key = pattern_fingerprint(kind, f"{policy}#{pol.serial}", fold_len,
                              with_grad, *mats)
    tpl = _CACHE.get(key) if cache else None
    if tpl is None:
        if kind == SPMM:
            tpl = _build_spmm_template(a, policy, fold_len, with_grad, key)
        else:
            tpl = _build_spgemm_template(a, b, policy, fold_len, key)
        if cache:
            _CACHE[key] = tpl
            _STATS["misses"] += 1
    else:
        _STATS["hits"] += 1
    return tpl.realize(a, b, backend, hint)
