from .pipeline import SyntheticDataset

__all__ = ["SyntheticDataset"]
