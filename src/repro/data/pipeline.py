"""Deterministic synthetic data pipeline — sharded, checkpointable, elastic.

Every token is a pure function of ``(seed, step, batch_index, position)``
via a counter-based hash, which gives the fault-tolerance properties the
runtime relies on:

* **checkpointable** — the pipeline state is just the step counter;
* **straggler/elastic-safe** — any host can (re)compute any shard of any
  step without coordination, so work can be re-assigned freely after a
  failure or a re-mesh (DESIGN.md §5).

For the VLM/audio stubs the frontend embeddings are generated with the same
counter hashing (deterministic float stand-ins for patch/frame features).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _hash_u32(x: np.ndarray) -> np.ndarray:
    """splitmix-ish counter hash, vectorized, uint64 → uint32."""
    x = x.astype(np.uint64)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    x = x ^ (x >> np.uint64(31))
    return (x & np.uint64(0xFFFFFFFF)).astype(np.uint32)


@dataclasses.dataclass
class SyntheticDataset:
    cfg: ModelConfig
    shape: ShapeConfig
    seed: int = 1234

    def _tokens(self, step: int, rows: np.ndarray, t: int) -> np.ndarray:
        pos = np.arange(t, dtype=np.uint64)[None, :]
        ctr = (np.uint64(self.seed) * np.uint64(1_000_003)
               + np.uint64(step) * np.uint64(1 << 40)
               + rows[:, None].astype(np.uint64) * np.uint64(1 << 20) + pos)
        return (_hash_u32(ctr) % np.uint32(self.cfg.vocab)).astype(np.int32)

    def batch(self, step: int, *, shard: Optional[slice] = None) -> Dict[str, np.ndarray]:
        """Full (or row-sliced) global batch for ``step``."""
        b = self.shape.global_batch
        rows = np.arange(b, dtype=np.int64)
        if shard is not None:
            rows = rows[shard]
        t = self.shape.seq_len
        n_front = self.cfg.n_frontend_tokens if self.cfg.family in ("vlm",) else 0
        t_text = t - n_front
        toks = self._tokens(step, rows, t_text + 1)
        out: Dict[str, np.ndarray] = {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
        }
        if self.cfg.family == "vlm":
            out["vis_embeds"] = self._embeds(step, rows, self.cfg.n_frontend_tokens)
        if self.cfg.family == "enc_dec":
            out["enc_embeds"] = self._embeds(step, rows, self.cfg.n_frontend_tokens)
        return out

    def _embeds(self, step: int, rows: np.ndarray, n: int) -> np.ndarray:
        d = self.cfg.d_model
        ctr = (np.uint64(self.seed) ^ np.uint64(0xE5)) + \
            np.uint64(step) * np.uint64(1 << 34) + \
            (rows[:, None, None].astype(np.uint64) * np.uint64(n * d)
             + np.arange(n, dtype=np.uint64)[None, :, None] * np.uint64(d)
             + np.arange(d, dtype=np.uint64)[None, None, :])
        u = _hash_u32(ctr).astype(np.float32) / np.float32(2 ** 32)
        return ((u - 0.5) * 0.2).astype(np.float32)
