"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each function is the mathematical ground truth the kernels are validated
against (interpret mode on CPU, shape/dtype sweeps in tests).  No Pallas, no
fancy control flow — just jnp.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Block-sparse matmuls
# ---------------------------------------------------------------------------


def bsr_to_dense(blocks, brow, bcol, grid_m, grid_k):
    """Scatter BSR blocks into a dense matrix (jnp)."""
    nb, bm, bk = blocks.shape
    out = jnp.zeros((grid_m * bm, grid_k * bk), dtype=blocks.dtype)
    def body(i, acc):
        r, c = brow[i], bcol[i]
        return jax.lax.dynamic_update_slice(
            acc,
            (jax.lax.dynamic_slice(acc, (r * bm, c * bk), (bm, bk))
             + blocks[i]).astype(acc.dtype),
            (r * bm, c * bk))
    return jax.lax.fori_loop(0, nb, body, out)


def dequant_blocks_ref(blocks, scales):
    """fp32 blocks from a quantized payload + scales (no-op for
    ``scales=None``) — the oracle-side mirror of the kernels' in-kernel
    dequantization.  1-D scales are per block, 2-D are per block row
    (rowwise mode)."""
    blocks = blocks.astype(jnp.float32)
    if scales is None:
        return blocks
    scales = scales.astype(jnp.float32)
    if scales.ndim == 2:
        return blocks * scales[:, :, None]
    return blocks * scales[:, None, None]


def spmm_ref(blocks, brow, bcol, grid_m, grid_k, b_dense,
             transpose_lhs: bool = False, scales=None):
    """C = BSR(A) @ B (or BSR(A)ᵀ @ B), computed densely.

    ``brow``/``bcol``/``grid_m``/``grid_k`` always describe the *stored* A;
    ``transpose_lhs`` contracts along its rows instead (the backward-pass
    oracle reads the forward storage, mirroring the kernel's zero-copy
    transpose mode).  ``scales`` dequantizes a quantized block payload.
    """
    a = bsr_to_dense(dequant_blocks_ref(blocks, scales), brow, bcol,
                     grid_m, grid_k)
    if transpose_lhs:
        a = a.T
    return (a.astype(jnp.float32) @ b_dense.astype(jnp.float32))


def spgemm_ref(a_blocks, a_brow, a_bcol, a_grid, b_blocks, b_brow, b_bcol,
               b_grid, c_brow, c_bcol, a_scales=None, b_scales=None):
    """C blocks (at the symbolic pattern positions) of BSR(A) @ BSR(B)."""
    gm, gk = a_grid
    gk2, gn = b_grid
    bm = a_blocks.shape[1]
    bk = a_blocks.shape[2]
    bn = b_blocks.shape[2]
    a = bsr_to_dense(dequant_blocks_ref(a_blocks, a_scales), a_brow, a_bcol,
                     gm, gk)
    b = bsr_to_dense(dequant_blocks_ref(b_blocks, b_scales), b_brow, b_bcol,
                     gk2, gn)
    c = a.astype(jnp.float32) @ b.astype(jnp.float32)
    def gather(i):
        return jax.lax.dynamic_slice(c, (c_brow[i] * bm, c_bcol[i] * bn), (bm, bn))
    return jax.vmap(gather)(jnp.arange(c_brow.shape[0]))


def moe_gemm_ref(x, w, chunk_expert, chunk_rows):
    """Grouped GEMM: rows of x are chunked; chunk c uses expert weight
    w[chunk_expert[c]].  x: (C*rows, d_in), w: (E, d_in, d_out)."""
    n_chunks = chunk_expert.shape[0]
    d_out = w.shape[-1]
    def per_chunk(c):
        xs = jax.lax.dynamic_slice(x, (c * chunk_rows, 0), (chunk_rows, x.shape[1]))
        return xs.astype(jnp.float32) @ w[chunk_expert[c]].astype(jnp.float32)
    out = jax.vmap(per_chunk)(jnp.arange(n_chunks))
    return out.reshape(n_chunks * chunk_rows, d_out)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def mha_ref(q, k, v, *, causal: bool = True, window: int | None = None,
            scale: float | None = None):
    """Multi-head attention oracle.

    q: (B, Tq, H, D); k/v: (B, Tk, Hkv, D) with H % Hkv == 0 (GQA).
    ``window`` masks keys further than `window` positions behind the query
    (local attention). Query positions are assumed to be the last Tq
    positions of the Tk-long context (decode/prefill consistent).
    """
    b, tq, h, d = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(tq)[:, None] + (tk - tq)
    k_pos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma recurrence)
# ---------------------------------------------------------------------------


def rg_lru_ref(x, a_gate, x_gate, a_param, h0=None, c: float = 8.0):
    """RG-LRU oracle:  h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

    a_t = exp(-c · softplus(a_param) ⊙ σ(a_gate_t)),  i_t = σ(x_gate_t).
    x, a_gate, x_gate: (B, T, D); a_param: (D,). Returns (out, h_T).
    """
    log_a = -c * jax.nn.softplus(a_param)[None, None, :] * jax.nn.sigmoid(a_gate)
    a = jnp.exp(log_a.astype(jnp.float32))
    gated_x = (jax.nn.sigmoid(x_gate) * x).astype(jnp.float32)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    xb = beta * gated_x
    if h0 is None:
        h0 = jnp.zeros((x.shape[0], x.shape[2]), jnp.float32)
    def step(h, inp):
        a_t, xb_t = inp
        h = a_t * h + xb_t
        return h, h
    hT, hs = jax.lax.scan(step, h0, (a.transpose(1, 0, 2), xb.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2), hT


# ---------------------------------------------------------------------------
# RWKV6-style time mix (data-dependent decay linear attention)
# ---------------------------------------------------------------------------


def rwkv6_ref(r, k, v, w, u, state0=None):
    """RWKV-6 (Finch) time-mix oracle.

    r,k,v: (B, T, H, D); w: (B, T, H, D) data-dependent log-decay (<0);
    u: (H, D) bonus. State S: (B, H, D, D). Returns (out (B,T,H,D), S_T).
    out_t = r_t · (S + u ⊙ (k_tᵀ v_t));  S ← diag(e^{w_t}) S + k_tᵀ v_t.
    """
    b, t, h, d = r.shape
    if state0 is None:
        state0 = jnp.zeros((b, h, d, d), jnp.float32)
    rf = r.astype(jnp.float32).transpose(1, 0, 2, 3)
    kf = k.astype(jnp.float32).transpose(1, 0, 2, 3)
    vf = v.astype(jnp.float32).transpose(1, 0, 2, 3)
    wf = w.astype(jnp.float32).transpose(1, 0, 2, 3)
    uf = u.astype(jnp.float32)
    def step(S, inp):
        r_t, k_t, v_t, w_t = inp           # (B,H,D)
        kv = jnp.einsum("bhi,bhj->bhij", k_t, v_t)
        out = jnp.einsum("bhi,bhij->bhj", r_t, S + uf[None, :, :, None] * kv)
        S = jnp.exp(w_t)[..., None] * S + kv
        return S, out
    S_T, outs = jax.lax.scan(step, state0, (rf, kf, vf, wf))
    return outs.transpose(1, 0, 2, 3), S_T
