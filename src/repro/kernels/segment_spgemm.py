"""Segment-scheduled BSR × BSR → BSR SpGEMM — Pallas TPU.

Two-phase TPU adaptation of SEGMENTBC (§III-B): the *symbolic* phase
(``repro.core.schedule.symbolic_spgemm``) computes C's block pattern ahead of
time — the V-space becomes a static compressed coordinate list at block
granularity — and this *numeric* kernel executes the (m, k, n) block triples
in Segment order:

* triples of the same C block form contiguous segments (ordered accumulation
  in VMEM, written back once — the merge network's in-place reduction);
* segment-to-segment chaining reuses boundary B blocks (SELECTA);
* folded continuations (``accum_prev``) read-modify-write their C block —
  temporal folding's partial-sum merge.

Grid: ``(n_lanes, lane_len // unroll)`` — the lane axis is **parallel**:
the triple list is cut into load-balanced lanes at C-segment boundaries
(``repro.core.schedule.partition_lanes``; a C slot never spans lanes), so
independent output chains run concurrently.  Every operand is selected by
scalar-prefetched index arrays (the ahead-of-time IPM) directly in original
BSR storage order; ``unroll`` executes several same-C-slot triples per grid
step.  ``valid=0`` marks lane-padding no-ops (contribution masked out).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams
from .segment_spmm import validate_schedule_args


def _make_kernel(lane_len: int, unroll: int, masked: bool, quant_a: bool,
                 quant_b: bool):
    def _kernel(a_idx, b_idx, c_idx, seg_start, seg_write, accum_prev,
                valid, *refs):
        if quant_a:
            a_scales, refs = refs[0], refs[1:]
        if quant_b:
            b_scales, refs = refs[0], refs[1:]
        a_refs = refs[:unroll]
        b_refs = refs[unroll:2 * unroll]
        out = refs[2 * unroll]
        acc = refs[2 * unroll + 1]
        base = pl.program_id(0) * lane_len + pl.program_id(1) * unroll
        for g in range(unroll):
            i = base + g

            @pl.when(seg_start[i] == 1)
            def _init(i=i):
                @pl.when(accum_prev[i] == 1)
                def _load():
                    acc[...] = out[0].astype(jnp.float32)

                @pl.when(accum_prev[i] == 0)
                def _zero():
                    acc[...] = jnp.zeros_like(acc)

            contrib = jax.lax.dot_general(
                a_refs[g][0].astype(jnp.float32),
                b_refs[g][0].astype(jnp.float32),
                dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            # per-block scales are scalar tile factors — applying them to
            # the fp32 product (after the dot, before accumulation) is exact
            if quant_a:
                contrib = contrib * a_scales[a_idx[i]]
            if quant_b:
                contrib = contrib * b_scales[b_idx[i]]
            if masked:
                contrib = jnp.where(valid[i] == 1, contrib, 0.0)
            acc[...] += contrib

            @pl.when(seg_write[i] == 1)
            def _write(i=i):
                out[0] = acc[...].astype(out.dtype)

    return _kernel


@functools.partial(jax.jit, static_argnames=(
    "n_c_blocks", "n_lanes", "unroll", "masked", "interpret", "out_dtype"))
def segment_spgemm(a_blocks, b_blocks, a_idx, b_idx, c_idx, seg_start,
                   seg_write, accum_prev, valid, *, n_c_blocks: int,
                   n_lanes: int = 1, unroll: int = 1, masked: bool = True,
                   interpret: bool = False, out_dtype=jnp.float32,
                   a_scales=None, b_scales=None):
    """Numeric SpGEMM phase.

    Args:
      a_blocks: (na, bm, bk) BSR A tiles (original order; fp32 or a
        quantized payload — pass ``a_scales``).
      b_blocks: (nb, bk, bn) BSR B tiles (original order; ditto
        ``b_scales``).
      a_idx/b_idx/c_idx: (n_items,) int32 — triple → block-slot maps,
        flattened lane-major schedule order.
      seg_start/seg_write/accum_prev/valid: (n_items,) int32 schedule flags.
      n_c_blocks: number of symbolic C blocks.
      n_lanes/unroll: lane-parallel grid shape (see module docstring).
      a_scales/b_scales: per-block fp32 dequantization scales
        (``(na,)`` / ``(nb,)``) riding the scalar-prefetch path; applied to
        the fp32 accumulator via the same ``a_idx``/``b_idx`` indirection.
    Returns:
      (n_c_blocks, bm, bn) C blocks, ordered as the symbolic pattern.
    """
    n_items = seg_start.shape[0]
    bm, bk = a_blocks.shape[1:]
    bn = b_blocks.shape[2]
    if a_scales is not None and a_scales.shape != (a_blocks.shape[0],):
        raise ValueError(
            f"a_scales has shape {a_scales.shape}, expected one fp32 scale "
            f"per stored block ({a_blocks.shape[0]},)")
    if b_scales is not None and b_scales.shape != (b_blocks.shape[0],):
        raise ValueError(
            f"b_scales has shape {b_scales.shape}, expected one fp32 scale "
            f"per stored block ({b_blocks.shape[0]},)")
    validate_schedule_args(
        n_items, n_lanes, unroll,
        {"a_idx": a_idx, "b_idx": b_idx, "c_idx": c_idx,
         "seg_write": seg_write, "accum_prev": accum_prev, "valid": valid})
    lane_len = n_items // n_lanes
    quant_a = a_scales is not None
    quant_b = b_scales is not None

    # index maps absorb the variable scalar-prefetch tail (*rest) so the
    # optional scale operands don't change their arity
    def sel(ref_pick, g):
        return lambda l, s, ai, bi, *rest: (
            ref_pick(ai, bi)[l * lane_len + s * unroll + g], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7 + int(quant_a) + int(quant_b),
        grid=(n_lanes, lane_len // unroll),
        in_specs=(
            [pl.BlockSpec((1, bm, bk), sel(lambda ai, bi: ai, g))
             for g in range(unroll)]
            + [pl.BlockSpec((1, bk, bn), sel(lambda ai, bi: bi, g))
               for g in range(unroll)]),
        out_specs=pl.BlockSpec(
            (1, bm, bn),
            lambda l, s, ai, bi, ci, *rest: (
                ci[l * lane_len + s * unroll], 0, 0)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    kernel = _make_kernel(lane_len, unroll, masked, quant_a, quant_b)
    prefetch = ((a_idx, b_idx, c_idx, seg_start, seg_write, accum_prev, valid)
                + ((a_scales,) if quant_a else ())
                + ((b_scales,) if quant_b else ()))
    operands = [a_blocks] * unroll + [b_blocks] * unroll
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_c_blocks, bm, bn), out_dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(*prefetch, *operands)
