"""Segment-scheduled BSR × BSR → BSR SpGEMM — Pallas TPU.

Two-phase TPU adaptation of SEGMENTBC (§III-B): the *symbolic* phase
(``repro.core.schedule.symbolic_spgemm``) computes C's block pattern ahead of
time — the V-space becomes a static compressed coordinate list at block
granularity — and this *numeric* kernel executes the (m, k, n) block triples
in Segment order:

* triples of the same C block form contiguous segments (ordered accumulation
  in VMEM, written back once — the merge network's in-place reduction);
* segment-to-segment chaining reuses boundary B blocks (SELECTA);
* folded continuations (``accum_prev``) read-modify-write their C block —
  temporal folding's partial-sum merge.

Grid: ``(n_items,)``; every operand is a single block per step, selected by
scalar-prefetched index arrays (the ahead-of-time IPM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _kernel(a_idx, b_idx, c_idx, seg_start, seg_write, accum_prev,
            a_blocks, b_blocks, out, acc):
    i = pl.program_id(0)

    @pl.when(seg_start[i] == 1)
    def _init():
        @pl.when(accum_prev[i] == 1)
        def _load():
            acc[...] = out[0].astype(jnp.float32)

        @pl.when(accum_prev[i] == 0)
        def _zero():
            acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        a_blocks[0].astype(jnp.float32), b_blocks[0].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(seg_write[i] == 1)
    def _write():
        out[0] = acc[...].astype(out.dtype)


@functools.partial(jax.jit, static_argnames=("n_c_blocks", "interpret", "out_dtype"))
def segment_spgemm(a_blocks, b_blocks, a_idx, b_idx, c_idx, seg_start,
                   seg_write, accum_prev, *, n_c_blocks: int,
                   interpret: bool = False, out_dtype=jnp.float32):
    """Numeric SpGEMM phase.

    Args:
      a_blocks: (na, bm, bk) BSR A tiles (original order).
      b_blocks: (nb, bk, bn) BSR B tiles (original order).
      a_idx/b_idx/c_idx: (n_items,) int32 — triple → block-slot maps.
      seg_start/seg_write/accum_prev: (n_items,) int32 schedule flags.
      n_c_blocks: number of symbolic C blocks.
    Returns:
      (n_c_blocks, bm, bn) C blocks, ordered as the symbolic pattern.
    """
    n_items = a_idx.shape[0]
    bm, bk = a_blocks.shape[1:]
    bn = b_blocks.shape[2]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(n_items,),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda i, ai, bi, ci, s, w, p: (ai[i], 0, 0)),
            pl.BlockSpec((1, bk, bn), lambda i, ai, bi, ci, s, w, p: (bi[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda i, ai, bi, ci, s, w, p: (ci[i], 0, 0)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_c_blocks, bm, bn), out_dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(a_idx, b_idx, c_idx, seg_start, seg_write, accum_prev, a_blocks, b_blocks)
