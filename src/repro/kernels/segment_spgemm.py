"""Segment-scheduled BSR × BSR → BSR SpGEMM — Pallas TPU.

Two-phase TPU adaptation of SEGMENTBC (§III-B): the *symbolic* phase
(``repro.core.schedule.symbolic_spgemm``) computes C's block pattern ahead of
time — the V-space becomes a static compressed coordinate list at block
granularity — and this *numeric* kernel executes the (m, k, n) block triples
in Segment order through an **explicit double-buffered DMA pipeline**: both
operand block arrays live in HBM (``pltpu.ANY`` refs) and the kernel issues
``pltpu.make_async_copy`` for triple *i+1*'s A/B tiles into ``2·unroll``-slot
VMEM ring buffers while triple *i* runs on the MXU, waiting only at
consumption:

* per-item ``a_fetch``/``b_fetch`` flags (``repro.core.schedule.fetch_flags``
  — the same arrays the traffic model prices, so predicted fetch counts are
  kernel reality) gate every copy: segment-to-segment chaining that reuses
  boundary B blocks (SELECTA) skips the copy and reads the resident ring
  slot (``a_slot``/``b_slot``), pads move no data, a lane's first triple
  always fetches;
* triples of the same C block form contiguous segments (ordered accumulation
  in VMEM, written back once — the merge network's in-place reduction);
* folded continuations (``accum_prev``) read-modify-write their C block —
  temporal folding's partial-sum merge.

Grid: ``(n_lanes, lane_len // unroll)`` — the lane axis is **parallel**:
the triple list is cut into load-balanced lanes at C-segment boundaries
(``repro.core.schedule.partition_lanes``; a C slot never spans lanes), so
independent output chains run concurrently.  Every operand is selected by
scalar-prefetched index arrays (the ahead-of-time IPM) directly in original
BSR storage order; each grid step executes ``unroll`` same-C-slot triples
against the resident ring slots.  ``valid=0`` marks lane-padding no-ops
(contribution masked out).  Quantized per-block scales are gathered per item
and stream as per-step VMEM vectors (one vector load per step instead of
``unroll`` serialized SMEM scalar reads).  ``pipeline=False`` keeps the
legacy BlockSpec auto-pipeline as a benchmark baseline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams
from .segment_spmm import resolve_pipeline, validate_schedule_args


def _make_legacy_kernel(lane_len: int, unroll: int, masked: bool,
                        quant_a, quant_b):
    def _kernel(a_idx, b_idx, c_idx, seg_start, seg_write, accum_prev,
                valid, *refs):
        if quant_a == "block":
            a_scales, refs = refs[0], refs[1:]
        if quant_b == "block":
            b_scales, refs = refs[0], refs[1:]
        a_refs = refs[:unroll]
        b_refs = refs[unroll:2 * unroll]
        refs = refs[2 * unroll:]
        if quant_a == "rowwise":
            as_refs, refs = refs[:unroll], refs[unroll:]
        if quant_b == "rowwise":
            bs_refs, refs = refs[:unroll], refs[unroll:]
        out, acc = refs
        base = pl.program_id(0) * lane_len + pl.program_id(1) * unroll
        for g in range(unroll):
            i = base + g

            @pl.when(seg_start[i] == 1)
            def _init(i=i):
                @pl.when(accum_prev[i] == 1)
                def _load():
                    acc[...] = out[0].astype(jnp.float32)

                @pl.when(accum_prev[i] == 0)
                def _zero():
                    acc[...] = jnp.zeros_like(acc)

            a_tile = a_refs[g][0].astype(jnp.float32)
            b_tile = b_refs[g][0].astype(jnp.float32)
            # Rowwise scales (A rows → output rows, B rows → the contraction
            # axis) do not factor out of the dot, so those tiles dequantize
            # *before* the MXU contraction.
            if quant_a == "rowwise":
                a_tile = a_tile * as_refs[g][0][:, None]
            if quant_b == "rowwise":
                b_tile = b_tile * bs_refs[g][0][:, None]
            contrib = jax.lax.dot_general(
                a_tile, b_tile,
                dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            # per-block scales are scalar tile factors — applying them to
            # the fp32 product (after the dot, before accumulation) is exact
            if quant_a == "block":
                contrib = contrib * a_scales[a_idx[i]]
            if quant_b == "block":
                contrib = contrib * b_scales[b_idx[i]]
            if masked:
                contrib = jnp.where(valid[i] == 1, contrib, 0.0)
            acc[...] += contrib

            @pl.when(seg_write[i] == 1)
            def _write(i=i):
                out[0] = acc[...].astype(out.dtype)

    return _kernel


def _make_pipeline_kernel(lane_len: int, unroll: int, masked: bool,
                          quant_a, quant_b):
    def _kernel(a_idx, b_idx, c_idx, seg_start, seg_write, accum_prev,
                valid, a_fetch, b_fetch, a_slot, b_slot, *refs):
        a_hbm, b_hbm, refs = refs[0], refs[1], refs[2:]
        if quant_a is not None:
            a_scale_ref, refs = refs[0], refs[1:]
        if quant_b is not None:
            b_scale_ref, refs = refs[0], refs[1:]
        out, acc, a_buf, b_buf, a_sem, b_sem = refs
        # grid coordinates are read once here: pl.program_id must not be
        # bound inside a pl.when branch (interpret mode only substitutes it
        # in the top-level kernel jaxpr)
        s = pl.program_id(1)
        n_steps = pl.num_programs(1)
        lane_base = pl.program_id(0) * lane_len
        base = lane_base + s * unroll

        def a_copy(i, slot):
            return pltpu.make_async_copy(
                a_hbm.at[a_idx[i]], a_buf.at[slot], a_sem.at[slot])

        def b_copy(i, slot):
            return pltpu.make_async_copy(
                b_hbm.at[b_idx[i]], b_buf.at[slot], b_sem.at[slot])

        def issue_a(i):
            @pl.when(a_fetch[i] == 1)
            def _():
                a_copy(i, a_slot[i]).start()

        def issue_b(i):
            @pl.when(b_fetch[i] == 1)
            def _():
                b_copy(i, b_slot[i]).start()

        # pass prologue + issue-one-step-ahead pipeline (see segment_spmm).
        # Issue order is the DMA priority mechanism: the bulky B tiles go on
        # the queue before the A tiles at every grid step
        # (repro.analysis.order's dma-priority rule asserts this order; for
        # square tiles the rule is vacuous and either order is fine, but
        # the kernels keep one convention).
        @pl.when(s == 0)
        def _prologue_b():
            for g in range(unroll):
                issue_b(lane_base + g)

        @pl.when(s + 1 < n_steps)
        def _pipeline_b():
            for g in range(unroll):
                issue_b(base + unroll + g)

        @pl.when(s == 0)
        def _prologue_a():
            for g in range(unroll):
                issue_a(lane_base + g)

        @pl.when(s + 1 < n_steps)
        def _pipeline_a():
            for g in range(unroll):
                issue_a(base + unroll + g)

        for g in range(unroll):
            i = base + g

            @pl.when(seg_start[i] == 1)
            def _init(i=i):
                @pl.when(accum_prev[i] == 1)
                def _load():
                    acc[...] = out[0].astype(jnp.float32)

                @pl.when(accum_prev[i] == 0)
                def _zero():
                    acc[...] = jnp.zeros_like(acc)

            @pl.when(a_fetch[i] == 1)
            def _wait_a(i=i):
                a_copy(i, a_slot[i]).wait()

            @pl.when(b_fetch[i] == 1)
            def _wait_b(i=i):
                b_copy(i, b_slot[i]).wait()

            a_tile = a_buf[a_slot[i]].astype(jnp.float32)
            b_tile = b_buf[b_slot[i]].astype(jnp.float32)
            # Rowwise scales (A rows → output rows, B rows → the contraction
            # axis) do not factor out of the dot, so those tiles dequantize
            # *before* the MXU contraction; the step's scale rows arrive as
            # one (unroll, rows) VMEM window each.
            if quant_a == "rowwise":
                a_tile = a_tile * a_scale_ref[0, g][:, None]
            if quant_b == "rowwise":
                b_tile = b_tile * b_scale_ref[0, g][:, None]
            contrib = jax.lax.dot_general(
                a_tile, b_tile,
                dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            # per-block scales are scalar tile factors — applying them to
            # the fp32 product (after the dot, before accumulation) is
            # exact; the step's scales arrive as one VMEM vector each
            if quant_a == "block":
                contrib = contrib * a_scale_ref[0, g]
            if quant_b == "block":
                contrib = contrib * b_scale_ref[0, g]
            if masked:
                contrib = jnp.where(valid[i] == 1, contrib, 0.0)
            acc[...] += contrib

            @pl.when(seg_write[i] == 1)
            def _write(i=i):
                out[0] = acc[...].astype(out.dtype)

    return _kernel


@functools.partial(jax.jit, static_argnames=(
    "n_c_blocks", "n_lanes", "unroll", "masked", "interpret", "out_dtype",
    "pipeline", "prefetch"))
def segment_spgemm(a_blocks, b_blocks, a_idx, b_idx, c_idx, seg_start,
                   seg_write, accum_prev, valid, *, n_c_blocks: int,
                   n_lanes: int = 1, unroll: int = 1, masked: bool = True,
                   interpret: bool = False, out_dtype=jnp.float32,
                   a_scales=None, b_scales=None, a_fetch=None, b_fetch=None,
                   a_slot=None, b_slot=None, pipeline=None,
                   prefetch: str | None = None):
    """Numeric SpGEMM phase.

    Args:
      a_blocks: (na, bm, bk) BSR A tiles (original order; fp32 or a
        quantized payload — pass ``a_scales``).
      b_blocks: (nb, bk, bn) BSR B tiles (original order; ditto
        ``b_scales``).
      a_idx/b_idx/c_idx: (n_items,) int32 — triple → block-slot maps,
        flattened lane-major schedule order.
      seg_start/seg_write/accum_prev/valid: (n_items,) int32 schedule flags.
      n_c_blocks: number of symbolic C blocks.
      n_lanes/unroll: lane-parallel grid shape (see module docstring).
      a_scales/b_scales: fp32 dequantization scales — per-block
        (``(na,)`` / ``(nb,)``, applied to the fp32 product) or per block
        row (``(na, bm)`` / ``(nb, bk)``, rowwise mode: tiles dequantize
        before the dot since B-row scales ride the contraction axis).
        Gathered per item and streamed as per-step VMEM windows
        (pipelined) or read per item (legacy).
      a_fetch/b_fetch: (n_items,) int32 DMA fetch flags — 1 where the item
        must copy its A/B tile from HBM, 0 where the resident ring slot is
        reused (see ``repro.core.schedule.fetch_flags``).
      a_slot/b_slot: (n_items,) int32 resident ring-buffer slot per item.
      pipeline: True = explicit DMA pipeline (requires the four fetch
        arrays), False = legacy BlockSpec auto-pipeline, None = auto.
      prefetch: accepted for knob-grid uniformity with ``segment_spmm``;
        the SpGEMM grid has no N-tile pass axis, so ``"cross_pass"``
        degenerates to the drained schedule (validated, kernel-side no-op).
    Returns:
      (n_c_blocks, bm, bn) C blocks, ordered as the symbolic pattern.
    """
    if prefetch not in (None, "cross_pass"):
        raise ValueError(
            f"prefetch={prefetch!r}: expected None or 'cross_pass' "
            f"(see repro.core.schedule.PREFETCH_MODES)")
    n_items = seg_start.shape[0]
    bm, bk = a_blocks.shape[1:]
    bn = b_blocks.shape[2]
    if b_blocks.shape[1] != bk:
        raise ValueError(
            f"contraction blocks disagree: a_blocks {tuple(a_blocks.shape)} "
            f"contracts over bk={bk} but b_blocks {tuple(b_blocks.shape)} "
            f"has row blocks of {b_blocks.shape[1]} — A tiles are (bm, bk), "
            f"so B tiles must be (bk, bn)")
    if n_c_blocks < 1 and n_items > 0:
        raise ValueError(
            f"n_c_blocks={n_c_blocks} with a non-empty schedule "
            f"(n_items={n_items}): every schedule item accumulates into a "
            f"symbolic C block, so the output needs at least one "
            f"(all-masked patterns short-circuit before the kernel — see "
            f"repro.api.executor)")
    if a_scales is not None and a_scales.shape not in (
            (a_blocks.shape[0],), (a_blocks.shape[0], bm)):
        raise ValueError(
            f"a_scales has shape {a_scales.shape}, expected one fp32 scale "
            f"per stored block ({a_blocks.shape[0]},) or per block row "
            f"({a_blocks.shape[0]}, {bm})")
    if b_scales is not None and b_scales.shape not in (
            (b_blocks.shape[0],), (b_blocks.shape[0], bk)):
        raise ValueError(
            f"b_scales has shape {b_scales.shape}, expected one fp32 scale "
            f"per stored block ({b_blocks.shape[0]},) or per block row "
            f"({b_blocks.shape[0]}, {bk})")
    pipeline = resolve_pipeline(pipeline, (a_fetch, b_fetch, a_slot, b_slot))
    if prefetch is not None and not pipeline:
        raise ValueError(
            "prefetch='cross_pass' requires the explicit DMA pipeline "
            "(pipeline=True)")
    validate_schedule_args(
        n_items, n_lanes, unroll,
        {"a_idx": a_idx, "b_idx": b_idx, "c_idx": c_idx,
         "seg_write": seg_write, "accum_prev": accum_prev, "valid": valid,
         "a_fetch": a_fetch, "b_fetch": b_fetch, "a_slot": a_slot,
         "b_slot": b_slot})
    lane_len = n_items // n_lanes
    quant_a = None if a_scales is None else (
        "rowwise" if a_scales.ndim == 2 else "block")
    quant_b = None if b_scales is None else (
        "rowwise" if b_scales.ndim == 2 else "block")
    out_shape = jax.ShapeDtypeStruct((n_c_blocks, bm, bn), out_dtype)

    if not pipeline:
        return _legacy_spgemm_call(
            a_blocks, b_blocks, a_idx, b_idx, c_idx, seg_start, seg_write,
            accum_prev, valid, a_scales, b_scales, out_shape, lane_len,
            n_lanes, bm, bk, bn, unroll, masked, quant_a, quant_b, interpret)

    depth = 2 * unroll
    n_steps = lane_len // unroll
    scalars = (a_idx, b_idx, c_idx, seg_start, seg_write, accum_prev,
               valid, a_fetch, b_fetch, a_slot, b_slot)
    in_specs = [pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY)]
    operands = [a_blocks, b_blocks]
    scale_spec = pl.BlockSpec(
        (1, unroll), lambda l, s, *rest: (l * n_steps + s, 0))

    def row_spec(rows):
        return pl.BlockSpec(
            (1, unroll, rows), lambda l, s, *rest: (l * n_steps + s, 0, 0))

    if quant_a == "block":
        in_specs.append(scale_spec)
        operands.append(jnp.take(a_scales, a_idx).reshape(-1, unroll))
    elif quant_a == "rowwise":
        in_specs.append(row_spec(bm))
        operands.append(
            jnp.take(a_scales, a_idx, axis=0).reshape(-1, unroll, bm))
    if quant_b == "block":
        in_specs.append(scale_spec)
        operands.append(jnp.take(b_scales, b_idx).reshape(-1, unroll))
    elif quant_b == "rowwise":
        in_specs.append(row_spec(bk))
        operands.append(
            jnp.take(b_scales, b_idx, axis=0).reshape(-1, unroll, bk))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalars),
        grid=(n_lanes, n_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, bm, bn),
            lambda l, s, ai, bi, ci, *rest: (
                ci[l * lane_len + s * unroll], 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((depth, bm, bk), a_blocks.dtype),
            pltpu.VMEM((depth, bk, bn), b_blocks.dtype),
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
    )
    kernel = _make_pipeline_kernel(lane_len, unroll, masked, quant_a, quant_b)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(*scalars, *operands)


def _legacy_spgemm_call(a_blocks, b_blocks, a_idx, b_idx, c_idx, seg_start,
                        seg_write, accum_prev, valid, a_scales, b_scales,
                        out_shape, lane_len, n_lanes, bm, bk, bn, unroll,
                        masked, quant_a, quant_b, interpret):
    """BlockSpec auto-pipeline baseline (see ``_legacy_spmm_call``)."""
    # index maps absorb the variable scalar-prefetch tail (*rest) so the
    # optional scale operands don't change their arity
    def sel(ref_pick, g):
        return lambda l, s, ai, bi, *rest: (
            ref_pick(ai, bi)[l * lane_len + s * unroll + g], 0, 0)

    def sel2(ref_pick, g):
        return lambda l, s, ai, bi, *rest: (
            ref_pick(ai, bi)[l * lane_len + s * unroll + g], 0)

    in_specs = (
        [pl.BlockSpec((1, bm, bk), sel(lambda ai, bi: ai, g))
         for g in range(unroll)]
        + [pl.BlockSpec((1, bk, bn), sel(lambda ai, bi: bi, g))
           for g in range(unroll)])
    if quant_a == "rowwise":
        in_specs += [pl.BlockSpec((1, bm), sel2(lambda ai, bi: ai, g))
                     for g in range(unroll)]
    if quant_b == "rowwise":
        in_specs += [pl.BlockSpec((1, bk), sel2(lambda ai, bi: bi, g))
                     for g in range(unroll)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=(7 + int(quant_a == "block")
                             + int(quant_b == "block")),
        grid=(n_lanes, lane_len // unroll),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, bm, bn),
            lambda l, s, ai, bi, ci, *rest: (
                ci[l * lane_len + s * unroll], 0, 0)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    kernel = _make_legacy_kernel(lane_len, unroll, masked, quant_a, quant_b)
    prefetch = ((a_idx, b_idx, c_idx, seg_start, seg_write, accum_prev, valid)
                + ((a_scales,) if quant_a == "block" else ())
                + ((b_scales,) if quant_b == "block" else ()))
    operands = [a_blocks] * unroll + [b_blocks] * unroll
    if quant_a == "rowwise":
        operands += [a_scales] * unroll
    if quant_b == "rowwise":
        operands += [b_scales] * unroll
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(*prefetch, *operands)
