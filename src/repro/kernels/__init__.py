"""Pallas TPU kernels for the Segment dataflow + architecture hot spots.

Each kernel module pairs with a pure-jnp oracle in :mod:`repro.kernels.ref`;
:mod:`repro.kernels.ops` exposes the jit'd public wrappers (interpret mode
auto-selected on CPU).
"""
from . import ops, ref
from .ops import (INTERPRET, SpgemmPlan, SpmmPlan, flash_mha, moe_apply,
                  plan_spgemm, plan_spmm, rg_lru_scan)

__all__ = [
    "ops", "ref", "INTERPRET", "SpgemmPlan", "SpmmPlan", "flash_mha",
    "moe_apply", "plan_spgemm", "plan_spmm", "rg_lru_scan",
]
