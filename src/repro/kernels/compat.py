"""Small shims across jax.experimental.pallas API renames.

``pltpu.TPUCompilerParams`` became ``pltpu.CompilerParams`` in newer JAX;
the kernels target the new name and fall back here so the same source runs
on the container's pinned JAX.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
