"""Segment-scheduled block-sparse × dense matmul (BSR(A) @ B) — Pallas TPU.

The TPU realization of the paper's dynamic dataflow for sparse-weight
layers.  The kernel runs a **lane-parallel work list** of nonzero A-block
multiplies whose *order is the reuse mechanism*: Pallas re-fetches a block
from HBM only when its ``index_map`` result changes between sequential grid
steps, so the Segment schedule (``repro.core.schedule.build_spmm_schedule``)
directly converts schedule locality into HBM-traffic savings:

* consecutive items with the same output block row ``m`` accumulate the C
  tile in VMEM and write it back once per segment (output revisiting);
* consecutive items sharing ``k`` (SELECTA's row-wise intersection,
  boundary-chained between segments) reuse the resident B row-block;
* folded segments (long output rows split for load balance, §IV-D) re-enter
  with ``accum_prev=1`` and read-modify-write the C tile — the temporal-fold
  partial-sum merge.

Grid: ``(n_lanes, n_tiles_n, lane_len // unroll)``.  The lane axis is
**parallel** — the schedule is cut into load-balanced lanes at segment-chain
boundaries (``repro.core.schedule.partition_lanes``), so independent output
chains run concurrently (megacore / multi-core) and the merge network no
longer degenerates to one PE.  The item axis stays innermost/sequential so
segment accumulation is ordered; ``unroll`` executes several items per grid
step (all sharing one output tile, the scheduler guarantees it) to amortize
grid overhead on small blocks.

A blocks stay in **original BSR storage order**: the scalar-prefetched
``slot_idx`` addresses each item's tile directly (the IPM analogue — exact
positions ahead of time), so no schedule-order gather of the block values
ever happens.  ``transpose_lhs`` contracts along the block's row axis
instead, computing ``Aᵀ`` tiles from the same storage — the backward pass
reads the forward weight array with zero copies.

Scalar-prefetch operands (``PrefetchScalarGridSpec``) carry the schedule:
``slot_idx, m_idx, k_idx, seg_start, seg_write, accum_prev, valid``
(``valid=0`` marks lane-padding no-ops whose contribution is masked out),
plus — for quantized block storage — the per-block fp32 ``a_scales``,
applied to the fp32 accumulator via the same ``slot_idx`` indirection
(dequantization is a kernel-local concern; storage format never leaks into
the schedule).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _make_kernel(lane_len: int, unroll: int, transpose_lhs: bool,
                 masked: bool, quantized: bool):
    contract = (((0,), (0,)), ((), ())) if transpose_lhs \
        else (((1,), (0,)), ((), ()))

    def _kernel(slot_idx, m_idx, k_idx, seg_start, seg_write, accum_prev,
                valid, *refs):
        if quantized:
            a_scales, refs = refs[0], refs[1:]
        a_refs = refs[:unroll]
        b_refs = refs[unroll:2 * unroll]
        out = refs[2 * unroll]
        acc = refs[2 * unroll + 1]
        base = pl.program_id(0) * lane_len + pl.program_id(2) * unroll
        for g in range(unroll):
            i = base + g

            @pl.when(seg_start[i] == 1)
            def _init(i=i):
                @pl.when(accum_prev[i] == 1)
                def _load():    # folded continuation: merge with prior partial
                    acc[...] = out[...].astype(jnp.float32)

                @pl.when(accum_prev[i] == 0)
                def _zero():
                    acc[...] = jnp.zeros_like(acc)

            contrib = jax.lax.dot_general(
                a_refs[g][0].astype(jnp.float32),
                b_refs[g][...].astype(jnp.float32),
                dimension_numbers=contract,
                preferred_element_type=jnp.float32)
            if quantized:
                # Per-block scale is a scalar factor of the whole tile, so
                # applying it to the fp32 product (after the MXU dot) is
                # algebraically exact: (s·Aq) @ B == s · (Aq @ B).  The scale
                # is fetched from SMEM via the prefetched block slot — the
                # same indirection the payload uses, transpose included.
                contrib = contrib * a_scales[slot_idx[i]]
            if masked:
                contrib = jnp.where(valid[i] == 1, contrib, 0.0)
            acc[...] += contrib

            @pl.when(seg_write[i] == 1)
            def _write(i=i):
                out[...] = acc[...].astype(out.dtype)

    return _kernel


def validate_schedule_args(n_items, n_lanes, unroll, arrays):
    """Shared scalar-prefetch schedule validation for both Segment kernels."""
    for name, arr in arrays.items():
        if arr.shape != (n_items,):
            raise ValueError(
                f"{name} has shape {arr.shape}, expected ({n_items},) to "
                f"match the schedule's n_items (seg_start length)")
    if n_items % n_lanes != 0:
        raise ValueError(f"n_items={n_items} is not divisible by "
                         f"n_lanes={n_lanes}; lanes must be equal length "
                         f"(pad via partition_lanes)")
    if (n_items // n_lanes) % unroll != 0:
        raise ValueError(f"lane length {n_items // n_lanes} is not divisible "
                         f"by unroll={unroll}")


@functools.partial(
    jax.jit,
    static_argnames=("grid_m", "n_lanes", "bn", "unroll", "transpose_lhs",
                     "masked", "interpret", "out_dtype"))
def segment_spmm(a_blocks, slot_idx, m_idx, k_idx, seg_start, seg_write,
                 accum_prev, valid, b_dense, *, grid_m: int, n_lanes: int = 1,
                 bn: int = 512, unroll: int = 1, transpose_lhs: bool = False,
                 masked: bool = True, interpret: bool = False,
                 out_dtype=jnp.float32, a_scales=None):
    """Compute ``C = BSR(A) @ B`` (or ``BSR(A)ᵀ @ B``) under a lane-parallel
    Segment schedule.

    Args:
      a_blocks: (n_blocks, bm, bk) A tiles in **original BSR storage order**.
        May be a quantized payload (int8 / fp8) — pass ``a_scales``.
      slot_idx: (n_items,) int32 — per-item index into ``a_blocks``.
      m_idx/k_idx: (n_items,) int32 output/contraction block coordinates,
        flattened lane-major schedule order.
      seg_start/seg_write/accum_prev/valid: (n_items,) int32 schedule flags
        (``valid=0`` on lane-padding no-ops).
      b_dense: (K, N) dense right-hand side; K = grid_k * bk (bm when
        ``transpose_lhs``).
      grid_m: number of output block rows.
      n_lanes: parallel lanes; ``n_items`` must be ``n_lanes * lane_len``.
      bn: N-tile width (VMEM working set: row·bn + contract·bn + bm·bk).
      unroll: items executed per grid step (scheduler must have aligned
        segment chains to ``unroll``).
      transpose_lhs: contract along each A tile's row axis (``Aᵀ @ B``) —
        the backward pass reads forward storage directly.
      masked: skip the validity mask when the schedule has no pads.
      a_scales: (n_blocks,) fp32 per-block dequantization scales, or None
        for fp32 blocks.  Scales ride the scalar-prefetch path (SMEM) and
        are applied to the fp32 accumulator, addressed by the same
        ``slot_idx`` indirection as the payload.
    Returns:
      (grid_m * row_block, N) dense output.
    """
    _, bm, bk = a_blocks.shape
    if a_scales is not None and a_scales.shape != (a_blocks.shape[0],):
        raise ValueError(
            f"a_scales has shape {a_scales.shape}, expected one fp32 scale "
            f"per stored block ({a_blocks.shape[0]},)")
    row_blk, contract_blk = (bk, bm) if transpose_lhs else (bm, bk)
    k_dim, n_dim = b_dense.shape
    if k_dim % contract_blk != 0:
        raise ValueError(f"rhs K={k_dim} is not a multiple of the "
                         f"contraction block {contract_blk} "
                         f"(a_blocks {a_blocks.shape}, "
                         f"transpose_lhs={transpose_lhs})")
    if n_dim % bn != 0:
        raise ValueError(
            f"dense rhs width N={n_dim} (b_dense shape {b_dense.shape}) is "
            f"not divisible by the N-tile width bn={bn}; pad N or pick a "
            f"divisor (see repro.api.pick_bn)")
    validate_schedule_args(
        seg_start.shape[0], n_lanes, unroll,
        {"slot_idx": slot_idx, "m_idx": m_idx, "k_idx": k_idx,
         "seg_write": seg_write, "accum_prev": accum_prev, "valid": valid})
    n_items = seg_start.shape[0]
    lane_len = n_items // n_lanes
    n_tiles_n = n_dim // bn
    quantized = a_scales is not None

    # index maps absorb the variable scalar-prefetch tail (*rest) so the
    # optional a_scales operand doesn't change their arity
    def a_map(g):
        return lambda l, j, s, slot, *rest: (
            slot[l * lane_len + s * unroll + g], 0, 0)

    def b_map(g):
        return lambda l, j, s, slot, m, k, *rest: (
            k[l * lane_len + s * unroll + g], j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=8 if quantized else 7,
        grid=(n_lanes, n_tiles_n, lane_len // unroll),
        in_specs=(
            [pl.BlockSpec((1, bm, bk), a_map(g)) for g in range(unroll)]
            + [pl.BlockSpec((contract_blk, bn), b_map(g))
               for g in range(unroll)]),
        out_specs=pl.BlockSpec(
            (row_blk, bn),
            lambda l, j, s, slot, m, *rest: (
                m[l * lane_len + s * unroll], j)),
        scratch_shapes=[pltpu.VMEM((row_blk, bn), jnp.float32)],
    )
    kernel = _make_kernel(lane_len, unroll, transpose_lhs, masked, quantized)
    prefetch = (slot_idx, m_idx, k_idx, seg_start, seg_write, accum_prev,
                valid) + ((a_scales,) if quantized else ())
    operands = [a_blocks] * unroll + [b_dense] * unroll
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((grid_m * row_blk, n_dim), out_dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(*prefetch, *operands)
