"""Segment-scheduled block-sparse × dense matmul (BSR(A) @ B) — Pallas TPU.

The TPU realization of the paper's dynamic dataflow for sparse-weight
layers.  The kernel runs a **one-dimensional work list** of nonzero A-block
multiplies whose *order is the reuse mechanism*: Pallas re-fetches a block
from HBM only when its ``index_map`` result changes between sequential grid
steps, so the Segment schedule (``repro.core.schedule.build_spmm_schedule``)
directly converts schedule locality into HBM-traffic savings:

* consecutive items with the same output block row ``m`` accumulate the C
  tile in VMEM and write it back once per segment (output revisiting);
* consecutive items sharing ``k`` (SELECTA's row-wise intersection,
  boundary-chained between segments) reuse the resident B row-block;
* folded segments (long output rows split for load balance, §IV-D) re-enter
  with ``accum_prev=1`` and read-modify-write the C tile — the temporal-fold
  partial-sum merge.

Grid: ``(n_tiles_n, n_items)`` — the item axis is innermost so segment
accumulation is sequential; the N axis is outermost (A blocks are re-fetched
once per N tile, the cost tiling always pays).

Scalar-prefetch operands (``PrefetchScalarGridSpec``) carry the schedule:
``m_idx, k_idx, seg_start, seg_write, accum_prev`` (the IPM analogue — exact
start positions computed ahead of time instead of a stale LUT).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _kernel(m_idx, k_idx, seg_start, seg_write, accum_prev,
            a_blocks, b, out, acc):
    i = pl.program_id(1)

    @pl.when(seg_start[i] == 1)
    def _init():
        @pl.when(accum_prev[i] == 1)
        def _load():        # folded continuation: merge with prior partial
            acc[...] = out[...].astype(jnp.float32)

        @pl.when(accum_prev[i] == 0)
        def _zero():
            acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        a_blocks[0].astype(jnp.float32), b[...].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(seg_write[i] == 1)
    def _write():
        out[...] = acc[...].astype(out.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("grid_m", "bn", "interpret", "out_dtype"))
def segment_spmm(a_blocks, m_idx, k_idx, seg_start, seg_write, accum_prev,
                 b_dense, *, grid_m: int, bn: int = 512,
                 interpret: bool = False, out_dtype=jnp.float32):
    """Compute ``C = BSR(A) @ B`` under a Segment schedule.

    Args:
      a_blocks: (n_items, bm, bk) A tiles **pre-gathered in schedule order**.
      m_idx/k_idx: (n_items,) int32 block coordinates, schedule order.
      seg_start/seg_write/accum_prev: (n_items,) int32 schedule flags.
      b_dense: (K, N) dense right-hand side; K = grid_k * bk.
      grid_m: number of output block rows (M = grid_m * bm).
      bn: N-tile width (VMEM working set: bm*bn + bk*bn + bm*bk floats).
    Returns:
      (grid_m * bm, N) dense output.
    """
    n_items, bm, bk = a_blocks.shape
    k_dim, n_dim = b_dense.shape
    assert n_dim % bn == 0, (n_dim, bn)
    n_tiles_n = n_dim // bn

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(n_tiles_n, n_items),
        in_specs=[
            # A tile for item i (already schedule-ordered)
            pl.BlockSpec((1, bm, bk), lambda j, i, m, k, s, w, p: (i, 0, 0)),
            # B row-block k_idx[i], N-tile j
            pl.BlockSpec((bk, bn), lambda j, i, m, k, s, w, p: (k[i], j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda j, i, m, k, s, w, p: (m[i], j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((grid_m * bm, n_dim), out_dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(m_idx, k_idx, seg_start, seg_write, accum_prev, a_blocks, b_dense)
