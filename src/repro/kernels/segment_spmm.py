"""Segment-scheduled block-sparse × dense matmul (BSR(A) @ B) — Pallas TPU.

The TPU realization of the paper's dynamic dataflow for sparse-weight
layers.  The kernel runs a **lane-parallel work list** of nonzero A-block
multiplies whose *order is the reuse mechanism*, and moves its operands
through an **explicit double-buffered DMA pipeline**: A and B live in HBM
(``pltpu.ANY`` refs) and the kernel issues ``pltpu.make_async_copy`` for
item *i+1*'s tiles into a ``2·unroll``-slot VMEM ring buffer while item *i*
runs on the MXU, waiting on a copy only at consumption — the SpArch-style
fetch/merge overlap, scheduled ahead of time instead of reactively:

* per-item ``a_fetch``/``b_fetch`` flags (precomputed by
  ``repro.core.schedule.fetch_flags`` from the same schedule the traffic
  model prices — predicted fetch counts are kernel reality by construction)
  gate every copy: consecutive items sharing ``k`` (SELECTA's row-wise
  intersection, boundary-chained between segments) skip the B re-fetch and
  read the resident ring slot, lane-padding no-ops move no data, and a
  lane's first item always fetches (lane cuts break residency);
* ``a_slot``/``b_slot`` give each item's resident ring slot — the ring
  advances one slot per *fetch*, so a reused tile is always the most
  recently copied one and an in-flight copy never lands on a slot that is
  still being read;
* consecutive items with the same output block row ``m`` accumulate the C
  tile in VMEM and write it back once per segment (output revisiting);
  folded segments re-enter with ``accum_prev=1`` and read-modify-write the
  C tile — the temporal-fold partial-sum merge.

Grid: ``(n_lanes, n_tiles_n, lane_len // unroll)``.  The lane axis is
**parallel** — the schedule is cut into load-balanced lanes at segment-chain
boundaries (``repro.core.schedule.partition_lanes``), so independent output
chains run concurrently (megacore / multi-core).  The item axis stays
innermost/sequential so segment accumulation is ordered and the pipeline's
issue-one-step-ahead discipline holds; each grid step executes ``unroll``
items against the resident ring slots.

A blocks stay in **original BSR storage order**: the scalar-prefetched
``slot_idx`` addresses each item's tile directly in HBM (the IPM analogue —
exact positions ahead of time), so no schedule-order gather of the block
values ever happens.  ``transpose_lhs`` contracts along the block's row axis
instead, computing ``Aᵀ`` tiles from the same storage — the backward pass
reads the forward weight array with zero copies.

Scalar-prefetch operands (``PrefetchScalarGridSpec``) carry the schedule:
``slot_idx, m_idx, k_idx, seg_start, seg_write, accum_prev, valid,
a_fetch, b_fetch, a_slot, b_slot`` (``valid=0`` marks lane-padding no-ops
whose contribution is masked out).  For quantized block storage the
per-block fp32 scales are gathered per item and ride a regular VMEM operand
blocked per grid step — one vector load per step instead of ``unroll``
serialized SMEM scalar reads — and are applied to the fp32 accumulator
(dequantization is a kernel-local concern; storage format never leaks into
the schedule).

``pipeline=False`` keeps the legacy BlockSpec auto-pipeline (operand
re-fetch decided by Pallas' index-map revisiting rule, scales on the
scalar-prefetch path) as a baseline for benchmarks and debugging.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _make_legacy_kernel(lane_len: int, unroll: int, transpose_lhs: bool,
                        masked: bool, quant: str | None):
    contract = (((0,), (0,)), ((), ())) if transpose_lhs \
        else (((1,), (0,)), ((), ()))

    def _kernel(slot_idx, m_idx, k_idx, seg_start, seg_write, accum_prev,
                valid, *refs):
        if quant == "block":
            a_scales, refs = refs[0], refs[1:]
        a_refs = refs[:unroll]
        b_refs = refs[unroll:2 * unroll]
        if quant == "rowwise":
            s_refs = refs[2 * unroll:3 * unroll]
            out = refs[3 * unroll]
            acc = refs[3 * unroll + 1]
        else:
            out = refs[2 * unroll]
            acc = refs[2 * unroll + 1]
        base = pl.program_id(0) * lane_len + pl.program_id(2) * unroll
        for g in range(unroll):
            i = base + g

            @pl.when(seg_start[i] == 1)
            def _init(i=i):
                @pl.when(accum_prev[i] == 1)
                def _load():    # folded continuation: merge with prior partial
                    acc[...] = out[...].astype(jnp.float32)

                @pl.when(accum_prev[i] == 0)
                def _zero():
                    acc[...] = jnp.zeros_like(acc)

            a_tile = a_refs[g][0].astype(jnp.float32)
            if quant == "rowwise":
                # Per-row scales do NOT commute with a contraction over the
                # tile's row axis (transpose_lhs), so the tile is dequantized
                # *before* the dot — exact in both orientations.
                a_tile = a_tile * s_refs[g][0][:, None]
            contrib = jax.lax.dot_general(
                a_tile,
                b_refs[g][...].astype(jnp.float32),
                dimension_numbers=contract,
                preferred_element_type=jnp.float32)
            if quant == "block":
                # Per-block scale is a scalar factor of the whole tile, so
                # applying it to the fp32 product (after the MXU dot) is
                # algebraically exact: (s·Aq) @ B == s · (Aq @ B).
                contrib = contrib * a_scales[slot_idx[i]]
            if masked:
                contrib = jnp.where(valid[i] == 1, contrib, 0.0)
            acc[...] += contrib

            @pl.when(seg_write[i] == 1)
            def _write(i=i):
                out[...] = acc[...].astype(out.dtype)

    return _kernel


def _make_pipeline_kernel(lane_len: int, unroll: int, transpose_lhs: bool,
                          masked: bool, quant: str | None, contract_blk: int,
                          bn: int, prefetch: str | None = None):
    contract = (((0,), (0,)), ((), ())) if transpose_lhs \
        else (((1,), (0,)), ((), ()))

    def _kernel(slot_idx, m_idx, k_idx, seg_start, seg_write, accum_prev,
                valid, a_fetch, b_fetch, a_slot, b_slot, *refs):
        a_hbm, b_hbm, refs = refs[0], refs[1], refs[2:]
        if quant is not None:
            scale_ref, refs = refs[0], refs[1:]
        out, acc, a_buf, b_buf, a_sem, b_sem = refs
        # grid coordinates are read once here: pl.program_id must not be
        # bound inside a pl.when branch (interpret mode only substitutes it
        # in the top-level kernel jaxpr) — statically enforced by
        # repro.analysis.jaxpr_lint's program-id-in-when rule in CI
        j = pl.program_id(1)
        s = pl.program_id(2)
        n_tiles_n = pl.num_programs(1)
        n_steps = pl.num_programs(2)
        lane_base = pl.program_id(0) * lane_len
        base = lane_base + s * unroll

        # The copy descriptors are reconstructed identically at issue and
        # wait time — Pallas pairs them through the per-slot DMA semaphore.
        def a_copy(i, slot):
            return pltpu.make_async_copy(
                a_hbm.at[slot_idx[i]], a_buf.at[slot], a_sem.at[slot])

        def b_copy(i, slot, jj):
            # jj is the N-tile the copy serves: the grid's own j everywhere
            # except the cross-pass tail, which fills for tile j + 1.  Waits
            # always run in the target pass, so the descriptor reconstructed
            # there (with jj == j) matches the one started here.
            return pltpu.make_async_copy(
                b_hbm.at[pl.ds(k_idx[i] * contract_blk, contract_blk),
                         pl.ds(jj * bn, bn)],
                b_buf.at[slot], b_sem.at[slot])

        def issue_a(i):
            @pl.when(a_fetch[i] == 1)
            def _():
                a_copy(i, a_slot[i]).start()

        def issue_b(i, jj):
            @pl.when(b_fetch[i] == 1)
            def _():
                b_copy(i, b_slot[i], jj).start()

        # Every step issues the *next* step's copies before touching its own
        # tiles: the DMA engine fills the other ring slots while the MXU
        # contracts the resident ones.  Issue order is the DMA priority
        # mechanism — the bulky B row-tiles (contract_blk × bn) are put on
        # the queue before the small A tiles at every grid step, so the
        # copies on the critical path start first
        # (repro.analysis.order's dma-priority rule asserts this order).
        # The pass prologue fetches the first step's own items; a lane's
        # first item always has its fetch flags set, so nothing stale
        # survives a pass restart.  Under cross-pass prefetch the tail of
        # the previous pass already issued those copies, so the prologue
        # only runs for the very first pass (j == 0).
        first_step = (s == 0) & (j == 0) if prefetch == "cross_pass" \
            else (s == 0)

        @pl.when(first_step)
        def _prologue_b():
            for g in range(unroll):
                issue_b(lane_base + g, j)

        @pl.when(s + 1 < n_steps)
        def _pipeline_b():
            for g in range(unroll):
                issue_b(base + unroll + g, j)

        @pl.when(first_step)
        def _prologue_a():
            for g in range(unroll):
                issue_a(lane_base + g)

        @pl.when(s + 1 < n_steps)
        def _pipeline_a():
            for g in range(unroll):
                issue_a(base + unroll + g)

        for g in range(unroll):
            i = base + g

            @pl.when(seg_start[i] == 1)
            def _init(i=i):
                @pl.when(accum_prev[i] == 1)
                def _load():    # folded continuation: merge with prior partial
                    acc[...] = out[...].astype(jnp.float32)

                @pl.when(accum_prev[i] == 0)
                def _zero():
                    acc[...] = jnp.zeros_like(acc)

            # Wait only at consumption, only when this item actually fetched
            # — a reused tile's copy was already awaited by the item that
            # brought it in.
            @pl.when(a_fetch[i] == 1)
            def _wait_a(i=i):
                a_copy(i, a_slot[i]).wait()

            @pl.when(b_fetch[i] == 1)
            def _wait_b(i=i):
                b_copy(i, b_slot[i], j).wait()

            a_tile = a_buf[a_slot[i]].astype(jnp.float32)
            if quant == "rowwise":
                # Per-row scales do NOT commute with a contraction over the
                # tile's row axis (transpose_lhs), so the tile is dequantized
                # *before* the dot — exact in both orientations.  The step's
                # (unroll, bm) scale rows arrive as one VMEM window (gathered
                # through slot_idx at call time).
                a_tile = a_tile * scale_ref[0, g][:, None]
            contrib = jax.lax.dot_general(
                a_tile,
                b_buf[b_slot[i]].astype(jnp.float32),
                dimension_numbers=contract,
                preferred_element_type=jnp.float32)
            if quant == "block":
                # Per-block scale is a scalar factor of the whole tile, so
                # applying it to the fp32 product (after the MXU dot) is
                # algebraically exact: (s·Aq) @ B == s · (Aq @ B).  The
                # step's scales arrive as one VMEM vector (gathered through
                # slot_idx at call time) — no per-item SMEM scalar loads.
                contrib = contrib * scale_ref[0, g]
            if masked:
                contrib = jnp.where(valid[i] == 1, contrib, 0.0)
            acc[...] += contrib

            @pl.when(seg_write[i] == 1)
            def _write(i=i):
                out[...] = acc[...].astype(out.dtype)

        if prefetch == "cross_pass":
            # Cross-pass tail: the last step of pass j issues pass j + 1's
            # first copies while this pass's final contractions retire, so
            # the next pass never drains the pipeline.  Placement at the
            # *end* of the body matters — the lane-first ring slots may
            # still be read by this very step (an all-same-k lane reuses
            # slot 0 throughout), so the overwriting copies must start
            # after this step's consumption.  B row-tiles first (DMA
            # priority), for tile j + 1; A tiles are N-independent but
            # their ring slots were recycled during this pass, so they are
            # re-fetched exactly as a drained prologue would.
            # repro.analysis.order's cross-pass-war / sem-carryover /
            # prefetch-raw rules certify this tail hazard-free for every
            # shipped variant before CI lets it execute.
            tail = (s + 1 == n_steps) & (j + 1 < n_tiles_n)

            @pl.when(tail)
            def _tail_b():
                for g in range(unroll):
                    issue_b(lane_base + g, j + 1)

            @pl.when(tail)
            def _tail_a():
                for g in range(unroll):
                    issue_a(lane_base + g)

    return _kernel


def validate_schedule_args(n_items, n_lanes, unroll, arrays):
    """Shared scalar-prefetch schedule validation for both Segment kernels."""
    for name, arr in arrays.items():
        if arr is None:
            continue
        if arr.shape != (n_items,):
            raise ValueError(
                f"{name} has shape {arr.shape}, expected ({n_items},) to "
                f"match the schedule's n_items (seg_start length)")
    if n_items % n_lanes != 0:
        raise ValueError(f"n_items={n_items} is not divisible by "
                         f"n_lanes={n_lanes}; lanes must be equal length "
                         f"(pad via partition_lanes)")
    if (n_items // n_lanes) % unroll != 0:
        raise ValueError(f"lane length {n_items // n_lanes} is not divisible "
                         f"by unroll={unroll}")


def resolve_pipeline(pipeline, fetch_arrays) -> bool:
    """Resolve the ``pipeline`` switch against the fetch-flag arrays.

    ``None`` auto-selects: pipelined iff the flags were supplied (plans
    built by ``repro.api`` always carry them; hand-built schedules without
    flags fall back to the BlockSpec auto-pipeline).  An explicit ``True``
    without the arrays is an error, not a silent downgrade.
    """
    have = [a is not None for a in fetch_arrays]
    if pipeline is None:
        pipeline = all(have)
    if pipeline and not all(have):
        raise ValueError(
            "pipeline=True needs the a_fetch/b_fetch/a_slot/b_slot schedule "
            "arrays (precompute them via repro.core.schedule.fetch_flags, "
            "or build the schedule through repro.api.plan_matmul)")
    return pipeline


@functools.partial(
    jax.jit,
    static_argnames=("grid_m", "n_lanes", "bn", "unroll", "transpose_lhs",
                     "masked", "interpret", "out_dtype", "pipeline",
                     "prefetch"))
def segment_spmm(a_blocks, slot_idx, m_idx, k_idx, seg_start, seg_write,
                 accum_prev, valid, b_dense, *, grid_m: int, n_lanes: int = 1,
                 bn: int = 512, unroll: int = 1, transpose_lhs: bool = False,
                 masked: bool = True, interpret: bool = False,
                 out_dtype=jnp.float32, a_scales=None, a_fetch=None,
                 b_fetch=None, a_slot=None, b_slot=None, pipeline=None,
                 prefetch: str | None = None):
    """Compute ``C = BSR(A) @ B`` (or ``BSR(A)ᵀ @ B``) under a lane-parallel
    Segment schedule with an explicit double-buffered DMA pipeline.

    Args:
      a_blocks: (n_blocks, bm, bk) A tiles in **original BSR storage order**.
        May be a quantized payload (int8 / fp8) — pass ``a_scales``.
      slot_idx: (n_items,) int32 — per-item index into ``a_blocks``.
      m_idx/k_idx: (n_items,) int32 output/contraction block coordinates,
        flattened lane-major schedule order.
      seg_start/seg_write/accum_prev/valid: (n_items,) int32 schedule flags
        (``valid=0`` on lane-padding no-ops).
      b_dense: (K, N) dense right-hand side; K = grid_k * bk (bm when
        ``transpose_lhs``).
      grid_m: number of output block rows.
      n_lanes: parallel lanes; ``n_items`` must be ``n_lanes * lane_len``.
      bn: N-tile width.  The VMEM working set this implies is computed by
        :func:`repro.analysis.spmm_vmem_bytes` (the analyzer's budget is
        pinned byte-for-byte to this kernel's scratch + block windows by
        ``tests/test_kernel_analysis.py``, so consult it rather than a
        hand-derived formula; the planner's ``vmem_limit_bytes`` knob
        enforces it at plan time).
      unroll: items executed per grid step (scheduler must have aligned
        segment chains to ``unroll``).
      transpose_lhs: contract along each A tile's row axis (``Aᵀ @ B``) —
        the backward pass reads forward storage directly.
      masked: skip the validity mask when the schedule has no pads.
      a_scales: fp32 dequantization scales, or None for fp32 blocks.
        ``(n_blocks,)`` applies one scale per block to the fp32 product;
        ``(n_blocks, bm)`` (rowwise mode) dequantizes each A tile row
        *before* the dot, which stays exact under ``transpose_lhs``.
        Gathered per item and streamed as a per-step VMEM window
        (pipelined) or read via ``slot_idx`` (legacy).
      a_fetch/b_fetch: (n_items,) int32 DMA fetch flags — 1 where the item
        must copy its A tile / B row-tile from HBM, 0 where the resident
        ring slot is reused (see ``repro.core.schedule.fetch_flags``).
      a_slot/b_slot: (n_items,) int32 resident ring-buffer slot per item.
      pipeline: True = explicit DMA pipeline (requires the four fetch
        arrays), False = legacy BlockSpec auto-pipeline, None = auto
        (pipelined iff the arrays are present).
      prefetch: ``None`` drains the DMA pipeline at every (lane, N-tile)
        pass boundary; ``"cross_pass"`` issues pass ``j+1``'s first copies
        (B row-tiles before A tiles) during pass ``j``'s tail step, so a
        multi-N-tile grid never stalls on a pass restart.  Requires the
        explicit pipeline; the mode changes only *when* lane-first copies
        issue, never which items fetch, so results are bit-identical.
        Certified hazard-free per variant by ``repro.analysis.order``.
    Returns:
      (grid_m * row_block, N) dense output.
    """
    if prefetch not in (None, "cross_pass"):
        raise ValueError(
            f"prefetch={prefetch!r}: expected None or 'cross_pass' "
            f"(see repro.core.schedule.PREFETCH_MODES)")
    _, bm, bk = a_blocks.shape
    if a_scales is not None and a_scales.shape not in (
            (a_blocks.shape[0],), (a_blocks.shape[0], bm)):
        raise ValueError(
            f"a_scales has shape {a_scales.shape}, expected one fp32 scale "
            f"per stored block ({a_blocks.shape[0]},) or per block row "
            f"({a_blocks.shape[0]}, {bm})")
    row_blk, contract_blk = (bk, bm) if transpose_lhs else (bm, bk)
    k_dim, n_dim = b_dense.shape
    if k_dim % contract_blk != 0:
        raise ValueError(f"rhs K={k_dim} is not a multiple of the "
                         f"contraction block {contract_blk} "
                         f"(a_blocks {a_blocks.shape}, "
                         f"transpose_lhs={transpose_lhs})")
    if n_dim % bn != 0:
        raise ValueError(
            f"dense rhs width N={n_dim} (b_dense shape {b_dense.shape}) is "
            f"not divisible by the N-tile width bn={bn}; pad N or pick a "
            f"divisor (see repro.api.pick_bn)")
    pipeline = resolve_pipeline(pipeline, (a_fetch, b_fetch, a_slot, b_slot))
    if prefetch is not None and not pipeline:
        raise ValueError(
            "prefetch='cross_pass' requires the explicit DMA pipeline "
            "(pipeline=True); the legacy BlockSpec path has no cross-pass "
            "copy timing to overlap")
    validate_schedule_args(
        seg_start.shape[0], n_lanes, unroll,
        {"slot_idx": slot_idx, "m_idx": m_idx, "k_idx": k_idx,
         "seg_write": seg_write, "accum_prev": accum_prev, "valid": valid,
         "a_fetch": a_fetch, "b_fetch": b_fetch, "a_slot": a_slot,
         "b_slot": b_slot})
    n_items = seg_start.shape[0]
    lane_len = n_items // n_lanes
    n_tiles_n = n_dim // bn
    quant = None if a_scales is None else (
        "rowwise" if a_scales.ndim == 2 else "block")
    out_shape = jax.ShapeDtypeStruct((grid_m * row_blk, n_dim), out_dtype)

    if not pipeline:
        return _legacy_spmm_call(
            a_blocks, slot_idx, m_idx, k_idx, seg_start, seg_write,
            accum_prev, valid, b_dense, a_scales, out_shape, lane_len,
            n_lanes, n_tiles_n, bm, bk, row_blk, contract_blk, bn, unroll,
            transpose_lhs, masked, quant, interpret)

    depth = 2 * unroll
    n_steps = lane_len // unroll
    scalars = (slot_idx, m_idx, k_idx, seg_start, seg_write, accum_prev,
               valid, a_fetch, b_fetch, a_slot, b_slot)
    in_specs = [pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY)]
    operands = [a_blocks, b_dense]
    if quant == "block":
        # one fp32 scale per item, laid out per grid step — the kernel reads
        # its step's scales as a single VMEM vector
        scale_items = jnp.take(a_scales, slot_idx).reshape(-1, unroll)
        in_specs.append(pl.BlockSpec(
            (1, unroll), lambda l, j, s, *rest: (l * n_steps + s, 0)))
        operands.append(scale_items)
    elif quant == "rowwise":
        # one (bm,) scale row per item — the step's window is (unroll, bm)
        scale_items = jnp.take(a_scales, slot_idx,
                               axis=0).reshape(-1, unroll, bm)
        in_specs.append(pl.BlockSpec(
            (1, unroll, bm), lambda l, j, s, *rest: (l * n_steps + s, 0, 0)))
        operands.append(scale_items)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalars),
        grid=(n_lanes, n_tiles_n, n_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (row_blk, bn),
            lambda l, j, s, slot, m, *rest: (
                m[l * lane_len + s * unroll], j)),
        scratch_shapes=[
            pltpu.VMEM((row_blk, bn), jnp.float32),
            pltpu.VMEM((depth, bm, bk), a_blocks.dtype),
            pltpu.VMEM((depth, contract_blk, bn), b_dense.dtype),
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
    )
    kernel = _make_pipeline_kernel(lane_len, unroll, transpose_lhs, masked,
                                   quant, contract_blk, bn, prefetch)
    # Under cross-pass prefetch the N-tile axis carries live DMA state
    # across its boundary (the tail's in-flight copies), so it must be
    # declared sequential — only the lane axis stays parallel.
    semantics = ("parallel", "arbitrary", "arbitrary") \
        if prefetch == "cross_pass" \
        else ("parallel", "parallel", "arbitrary")
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=CompilerParams(dimension_semantics=semantics),
    )(*scalars, *operands)


def _legacy_spmm_call(a_blocks, slot_idx, m_idx, k_idx, seg_start, seg_write,
                      accum_prev, valid, b_dense, a_scales, out_shape,
                      lane_len, n_lanes, n_tiles_n, bm, bk, row_blk,
                      contract_blk, bn, unroll, transpose_lhs, masked,
                      quant, interpret):
    """BlockSpec auto-pipeline baseline (operand re-fetch decided by the
    index-map revisiting rule; per-block scales on the scalar-prefetch
    path, rowwise scale rows on per-item VMEM windows).  Kept for
    benchmarking the explicit DMA pipeline against and for schedules built
    without fetch flags."""
    # index maps absorb the variable scalar-prefetch tail (*rest) so the
    # optional a_scales operand doesn't change their arity
    def a_map(g):
        return lambda l, j, s, slot, *rest: (
            slot[l * lane_len + s * unroll + g], 0, 0)

    def b_map(g):
        return lambda l, j, s, slot, m, k, *rest: (
            k[l * lane_len + s * unroll + g], j)

    def s_map(g):
        return lambda l, j, s, slot, *rest: (
            slot[l * lane_len + s * unroll + g], 0)

    in_specs = (
        [pl.BlockSpec((1, bm, bk), a_map(g)) for g in range(unroll)]
        + [pl.BlockSpec((contract_blk, bn), b_map(g))
           for g in range(unroll)])
    if quant == "rowwise":
        in_specs += [pl.BlockSpec((1, bm), s_map(g)) for g in range(unroll)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=8 if quant == "block" else 7,
        grid=(n_lanes, n_tiles_n, lane_len // unroll),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (row_blk, bn),
            lambda l, j, s, slot, m, *rest: (
                m[l * lane_len + s * unroll], j)),
        scratch_shapes=[pltpu.VMEM((row_blk, bn), jnp.float32)],
    )
    kernel = _make_legacy_kernel(lane_len, unroll, transpose_lhs, masked,
                                 quant)
    prefetch = (slot_idx, m_idx, k_idx, seg_start, seg_write, accum_prev,
                valid) + ((a_scales,) if quant == "block" else ())
    operands = [a_blocks] * unroll + [b_dense] * unroll
    if quant == "rowwise":
        operands += [a_scales] * unroll
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(*prefetch, *operands)
