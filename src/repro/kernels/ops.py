"""Public jit'd wrappers around the Pallas kernels.

``INTERPRET`` auto-selects Pallas interpret mode on CPU (this container) and
compiled mode on TPU.  Schedule construction (numpy, per sparsity pattern)
happens once in :func:`plan_spmm` / :func:`plan_spgemm`; the returned plans
hold device arrays and are reusable across calls — static weight-sparsity
patterns amortize exactly as DESIGN.md §2 argues.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import BSR
from repro.core.schedule import (build_spgemm_schedule, build_spmm_schedule,
                                 spgemm_schedule_traffic, spmm_schedule_traffic)
from . import ref
from .flash_attention import flash_attention
from .moe_gemm import build_moe_chunks, moe_gemm
from .rg_lru import rg_lru
from .segment_spgemm import segment_spgemm
from .segment_spmm import segment_spmm


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


INTERPRET = _default_interpret()


# ---------------------------------------------------------------------------
# SpMM plan (sparse-weight layers)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SpmmPlan:
    """Frozen Segment schedule + schedule-ordered blocks for BSR(A) @ B."""

    blocks: jax.Array        # (n_items, bm, bk) schedule order
    m_idx: jax.Array
    k_idx: jax.Array
    seg_start: jax.Array
    seg_write: jax.Array
    accum_prev: jax.Array
    grid_m: int
    grid_k: int
    block_shape: tuple
    policy: str
    traffic: dict            # revisiting-model traffic estimate
    row_mask: jax.Array = None  # (grid_m,) 1.0 where the block row has work

    def __call__(self, b_dense, *, bn: int = 512, interpret: Optional[bool] = None,
                 out_dtype=jnp.float32):
        interpret = INTERPRET if interpret is None else interpret
        n = b_dense.shape[1]
        bn = min(bn, n)
        out = segment_spmm(
            self.blocks, self.m_idx, self.k_idx, self.seg_start,
            self.seg_write, self.accum_prev, b_dense,
            grid_m=self.grid_m, bn=bn, interpret=interpret, out_dtype=out_dtype)
        # block rows with no nonzero A blocks are never visited by the grid —
        # their output is undefined (may be NaN); zero them via where.
        bm = self.block_shape[0]
        live = jnp.repeat(self.row_mask > 0, bm)[:, None]
        return jnp.where(live, out, jnp.zeros((), out.dtype))


def plan_spmm(a: BSR, policy: str = "segment", n_cols_hint: int = 1024,
              fold_len: Optional[int] = None) -> SpmmPlan:
    sched = build_spmm_schedule(a, policy=policy, fold_len=fold_len)
    # accum_prev: a segment head whose m was already written must merge
    seen = set()
    accum_prev = np.zeros(sched.n_items, dtype=np.int32)
    for i in np.nonzero(sched.seg_start)[0]:
        m = int(sched.m[i])
        accum_prev[i] = 1 if m in seen else 0
        seen.add(m)
    bm, bk = a.block_shape
    row_mask = np.zeros(sched.n_m_blocks, dtype=np.float32)
    row_mask[np.unique(sched.m)] = 1.0
    return SpmmPlan(
        blocks=jnp.asarray(a.blocks[sched.a_idx]),
        m_idx=jnp.asarray(sched.m), k_idx=jnp.asarray(sched.k),
        seg_start=jnp.asarray(sched.seg_start),
        seg_write=jnp.asarray(sched.seg_write),
        accum_prev=jnp.asarray(accum_prev),
        grid_m=sched.n_m_blocks, grid_k=sched.n_k_blocks,
        block_shape=a.block_shape, policy=policy,
        traffic=spmm_schedule_traffic(sched, bm, bk, n_cols_hint),
        row_mask=jnp.asarray(row_mask))


# ---------------------------------------------------------------------------
# SpGEMM plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SpgemmPlan:
    a_blocks: jax.Array
    b_blocks: jax.Array
    a_idx: jax.Array
    b_idx: jax.Array
    c_idx: jax.Array
    seg_start: jax.Array
    seg_write: jax.Array
    accum_prev: jax.Array
    c_brow: np.ndarray
    c_bcol: np.ndarray
    n_c_blocks: int
    policy: str
    traffic: dict

    def __call__(self, *, interpret: Optional[bool] = None, out_dtype=jnp.float32):
        interpret = INTERPRET if interpret is None else interpret
        return segment_spgemm(
            self.a_blocks, self.b_blocks, self.a_idx, self.b_idx, self.c_idx,
            self.seg_start, self.seg_write, self.accum_prev,
            n_c_blocks=self.n_c_blocks, interpret=interpret,
            out_dtype=out_dtype)


def plan_spgemm(a: BSR, b: BSR, policy: str = "segment",
                fold_len: Optional[int] = None) -> SpgemmPlan:
    sched = build_spgemm_schedule(a, b, policy=policy, fold_len=fold_len)
    seen = set()
    accum_prev = np.zeros(sched.n_items, dtype=np.int32)
    for i in np.nonzero(sched.seg_start)[0]:
        ci = int(sched.c_idx[i])
        accum_prev[i] = 1 if ci in seen else 0
        seen.add(ci)
    bm, bk = a.block_shape
    bn = b.block_shape[1]
    return SpgemmPlan(
        a_blocks=jnp.asarray(a.blocks), b_blocks=jnp.asarray(b.blocks),
        a_idx=jnp.asarray(sched.a_idx), b_idx=jnp.asarray(sched.b_idx),
        c_idx=jnp.asarray(sched.c_idx),
        seg_start=jnp.asarray(sched.seg_start),
        seg_write=jnp.asarray(sched.seg_write),
        accum_prev=jnp.asarray(accum_prev),
        c_brow=sched.c_brow, c_bcol=sched.c_bcol,
        n_c_blocks=sched.n_c_blocks, policy=policy,
        traffic=spgemm_schedule_traffic(sched, bm, bk, bn))


# ---------------------------------------------------------------------------
# Attention / recurrences / MoE
# ---------------------------------------------------------------------------


def flash_mha(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              bq: int = 128, bkv: int = 128, interpret: Optional[bool] = None):
    """GQA flash attention. q: (B, Tq, H, D), k/v: (B, Tk, Hkv, D)."""
    interpret = INTERPRET if interpret is None else interpret
    b, tq, h, d = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qh = q.transpose(0, 2, 1, 3).reshape(b * h, tq, d)
    kh = k.transpose(0, 2, 1, 3).reshape(b * h, tk, d)
    vh = v.transpose(0, 2, 1, 3).reshape(b * h, tk, d)
    # pad Tq/Tk (at the end) to block multiples; real queries keep their
    # absolute positions via the explicit offset, padded keys are masked by
    # kv_len, padded query rows are sliced off.
    bq_eff = min(bq, max(8, 1 << max(tq - 1, 0).bit_length()))
    bkv_eff = min(bkv, max(128, 1 << max(tk - 1, 0).bit_length()))
    pad_q = (-tq) % bq_eff
    pad_k = (-tk) % bkv_eff
    if pad_q:
        qh = jnp.pad(qh, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kh = jnp.pad(kh, ((0, 0), (0, pad_k), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, pad_k), (0, 0)))
    out = flash_attention(qh, kh, vh, causal=causal, window=window,
                          offset=tk - tq, kv_len=tk,
                          bq=bq_eff, bkv=bkv_eff, interpret=interpret)
    out = out[:, :tq, :]
    return out.reshape(b, h, tq, d).transpose(0, 2, 1, 3)


def rg_lru_scan(x, a_gate, x_gate, a_param, h0=None, *, ct: int = 128,
                interpret: Optional[bool] = None):
    interpret = INTERPRET if interpret is None else interpret
    if h0 is None:
        h0 = jnp.zeros((x.shape[0], x.shape[2]), jnp.float32)
    return rg_lru(x, a_gate, x_gate, a_param, h0, ct=min(ct, x.shape[1]),
                  interpret=interpret)


def moe_apply(x, w_up, w_down, router_logits, *, top_k: int = 1,
              chunk_rows: int = 128, capacity_factor: float = 1.25,
              activation=jax.nn.silu, interpret: Optional[bool] = None):
    """Full MoE FFN: route → Segment-sort → grouped GEMMs → unsort-combine.

    x: (T, d_model); w_up: (E, d_model, d_ff); w_down: (E, d_ff, d_model).
    Returns (T, d_model).
    """
    interpret = INTERPRET if interpret is None else interpret
    t, d_model = x.shape
    n_exp = w_up.shape[0]
    top_vals, top_idx = jax.lax.top_k(router_logits, top_k)      # (T, top_k)
    gates = jax.nn.softmax(top_vals, axis=-1)
    out = jnp.zeros((t, d_model), jnp.float32)
    for j in range(top_k):
        expert = top_idx[:, j]
        order, slot, chunk_expert, keep, n_chunks, cap_rows = build_moe_chunks(
            expert, n_exp, chunk_rows, capacity_factor)
        cap_total = n_exp * cap_rows
        # scatter tokens (sorted by expert) into the padded chunk buffer;
        # dropped tokens land on the trash row which is cut before the GEMM
        buf = jnp.zeros((cap_total + 1, d_model), x.dtype)
        buf = buf.at[slot].set(jnp.where(keep[:, None], x[order], 0))
        buf = buf[:-1]
        h = moe_gemm(buf, w_up, chunk_expert, chunk_rows=chunk_rows,
                     interpret=interpret)
        h = activation(h).astype(x.dtype)
        y = moe_gemm(h, w_down, chunk_expert, chunk_rows=chunk_rows,
                     interpret=interpret)
        # gather back: sorted position s ↔ original token order[s]
        vals = jnp.where(keep[:, None],
                         y[jnp.minimum(slot, cap_total - 1)], 0.0)
        y_tok = jnp.zeros((t, d_model), jnp.float32).at[order].set(vals)
        out = out + y_tok * gates[:, j][:, None]
    return out


__all__ = [
    "INTERPRET", "SpmmPlan", "SpgemmPlan", "plan_spmm", "plan_spgemm",
    "flash_mha", "rg_lru_scan", "moe_apply", "ref",
]
