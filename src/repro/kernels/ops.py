"""Public jit'd wrappers around the Pallas kernels.

Plan construction moved to :mod:`repro.api` — :func:`plan_spmm` /
:func:`plan_spgemm` remain as thin deprecation shims that delegate to
``repro.api.plan_matmul`` and return the unified :class:`SegmentPlan`
(call-compatible with the old ``SpmmPlan``/``SpgemmPlan``).

``INTERPRET`` is likewise deprecated: backend selection (compiled /
interpret / reference) now lives in :mod:`repro.api.backends`; the module
global is kept only so old call sites keep working and mirrors the default
backend at import time.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.api.plan import SegmentPlan
from repro.api.planner import plan_matmul
from repro.core.formats import BSR
from . import ref
from .flash_attention import flash_attention
from .moe_gemm import build_moe_chunks, moe_gemm
from .rg_lru import rg_lru


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


INTERPRET = _default_interpret()   # deprecated: see repro.api.backends

# Deprecated aliases — both old plan classes are now the one SegmentPlan.
SpmmPlan = SegmentPlan
SpgemmPlan = SegmentPlan


def _deprecated(old: str) -> None:
    warnings.warn(f"repro.kernels.ops.{old} is deprecated; use "
                  f"repro.api.plan_matmul", DeprecationWarning, stacklevel=3)


def plan_spmm(a: BSR, policy: str = "segment", n_cols_hint: int = 1024,
              fold_len: Optional[int] = None) -> SegmentPlan:
    """Deprecated shim for :func:`repro.api.plan_matmul` (SpMM)."""
    _deprecated("plan_spmm")
    return plan_matmul(a, policy=policy, n_cols_hint=n_cols_hint,
                       fold_len=fold_len)


def plan_spgemm(a: BSR, b: BSR, policy: str = "segment",
                fold_len: Optional[int] = None) -> SegmentPlan:
    """Deprecated shim for :func:`repro.api.plan_matmul` (SpGEMM)."""
    _deprecated("plan_spgemm")
    return plan_matmul(a, b, policy=policy, fold_len=fold_len)


# ---------------------------------------------------------------------------
# Attention / recurrences / MoE
# ---------------------------------------------------------------------------


def flash_mha(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              bq: int = 128, bkv: int = 128, interpret: Optional[bool] = None):
    """GQA flash attention. q: (B, Tq, H, D), k/v: (B, Tk, Hkv, D).

    Grouped queries are folded into the q axis — the ``rep = H/Hkv`` query
    heads of one KV head run as ``rep`` stacked ``Tq``-long groups against a
    single K/V copy (``q_period`` position wrap in the kernel), so each K/V
    head is read from HBM once instead of ``rep`` times (the old path
    materialized ``jnp.repeat`` copies of K and V).
    """
    interpret = INTERPRET if interpret is None else interpret
    b, tq, h, d = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    # pad Tq/Tk (at the end) to block multiples; real queries keep their
    # absolute positions via the explicit offset, padded keys are masked by
    # kv_len, padded query rows are sliced off.
    bq_eff = min(bq, max(8, 1 << max(tq - 1, 0).bit_length()))
    bkv_eff = min(bkv, max(128, 1 << max(tk - 1, 0).bit_length()))
    pad_q = (-tq) % bq_eff
    pad_k = (-tk) % bkv_eff
    tq_pad = tq + pad_q
    kh = k.transpose(0, 2, 1, 3).reshape(b * hkv, tk, d)
    vh = v.transpose(0, 2, 1, 3).reshape(b * hkv, tk, d)
    if pad_k:
        kh = jnp.pad(kh, ((0, 0), (0, pad_k), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, pad_k), (0, 0)))
    # (B, Tq, H, D) → (B, Hkv, rep, Tq_pad, D) → (B·Hkv, rep·Tq_pad, D):
    # query heads of one KV head stack along the q axis (head h maps to KV
    # head h // rep, matching jnp.repeat(..., axis=2) semantics).
    qh = q.transpose(0, 2, 1, 3).reshape(b, hkv, rep, tq, d)
    if pad_q:
        qh = jnp.pad(qh, ((0, 0), (0, 0), (0, 0), (0, pad_q), (0, 0)))
    qh = qh.reshape(b * hkv, rep * tq_pad, d)
    out = flash_attention(qh, kh, vh, causal=causal, window=window,
                          offset=tk - tq, kv_len=tk,
                          bq=bq_eff, bkv=bkv_eff,
                          q_period=tq_pad if rep > 1 else None,
                          interpret=interpret)
    out = out.reshape(b, hkv, rep, tq_pad, d)[:, :, :, :tq]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, tq, h, d)


def rg_lru_scan(x, a_gate, x_gate, a_param, h0=None, *, ct: int = 128,
                interpret: Optional[bool] = None):
    interpret = INTERPRET if interpret is None else interpret
    if h0 is None:
        h0 = jnp.zeros((x.shape[0], x.shape[2]), jnp.float32)
    return rg_lru(x, a_gate, x_gate, a_param, h0, ct=min(ct, x.shape[1]),
                  interpret=interpret)


def moe_apply(x, w_up, w_down, router_logits, *, top_k: int = 1,
              chunk_rows: int = 128, capacity_factor: float = 1.25,
              activation=jax.nn.silu, interpret: Optional[bool] = None):
    """Full MoE FFN: route → Segment-sort → grouped GEMMs → unsort-combine.

    x: (T, d_model); w_up: (E, d_model, d_ff); w_down: (E, d_ff, d_model).
    Returns (T, d_model).
    """
    interpret = INTERPRET if interpret is None else interpret
    t, d_model = x.shape
    n_exp = w_up.shape[0]
    top_vals, top_idx = jax.lax.top_k(router_logits, top_k)      # (T, top_k)
    gates = jax.nn.softmax(top_vals, axis=-1)
    out = jnp.zeros((t, d_model), jnp.float32)
    for j in range(top_k):
        expert = top_idx[:, j]
        order, slot, chunk_expert, keep, n_chunks, cap_rows = build_moe_chunks(
            expert, n_exp, chunk_rows, capacity_factor)
        cap_total = n_exp * cap_rows
        # scatter tokens (sorted by expert) into the padded chunk buffer;
        # dropped tokens land on the trash row which is cut before the GEMM
        buf = jnp.zeros((cap_total + 1, d_model), x.dtype)
        buf = buf.at[slot].set(jnp.where(keep[:, None], x[order], 0))
        buf = buf[:-1]
        h = moe_gemm(buf, w_up, chunk_expert, chunk_rows=chunk_rows,
                     interpret=interpret)
        h = activation(h).astype(x.dtype)
        y = moe_gemm(h, w_down, chunk_expert, chunk_rows=chunk_rows,
                     interpret=interpret)
        # gather back: sorted position s ↔ original token order[s]
        vals = jnp.where(keep[:, None],
                         y[jnp.minimum(slot, cap_total - 1)], 0.0)
        y_tok = jnp.zeros((t, d_model), jnp.float32).at[order].set(vals)
        out = out + y_tok * gates[:, j][:, None]
    return out


__all__ = [
    "INTERPRET", "SpmmPlan", "SpgemmPlan", "plan_spmm", "plan_spgemm",
    "flash_mha", "rg_lru_scan", "moe_apply", "ref",
]
