"""Fused attention (flash-style online softmax) — Pallas TPU.

Standard IO-aware attention with GQA, causal and local-window masking.  The
local-window variant shares the block-schedule machinery philosophy of the
Segment dataflow: fully-masked KV blocks are *skipped structurally* (the
banded block pattern is static given window size), so compute scales with
the band, not the full T² — which is what makes ``long_500k`` decoding
feasible for the hybrid architectures.

Layout: q (BH, Tq, D), k/v (BH, Tk, D) — GQA head replication is resolved in
``ops.flash_mha``.  Grid ``(BH, n_q, n_kv)`` with KV innermost; running max /
denominator / accumulator live in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, offset, kv_len, bq, bkv, n_kv, q_period):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level skip: with causal/window masking many KV blocks are fully
    # masked — do no work for them (structural block sparsity)
    q_row = qi * bq
    if q_period is not None:
        # GQA grouping: the q axis stacks `rep` query copies of length
        # q_period; positions repeat per copy (q blocks never straddle a
        # copy — q_period % bq == 0 is asserted at call time).
        q_row = jax.lax.rem(q_row, q_period)
    q_lo = offset + q_row                     # first absolute q position
    q_hi = q_lo + bq - 1
    k_lo = ki * bkv
    k_hi = k_lo + bkv - 1
    live = k_lo < kv_len                      # padded KV tail is dead
    if causal:
        live = jnp.logical_and(live, k_lo <= q_hi)
    if window is not None:
        live = jnp.logical_and(live, k_hi > q_lo - window)

    @pl.when(live)
    def _body():
        s = jax.lax.dot_general(
            q_ref[0].astype(jnp.float32), k_ref[0].astype(jnp.float32),
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (bq, bkv)
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "offset", "kv_len", "bq", "bkv", "q_period",
    "interpret", "out_dtype"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    offset=None, kv_len=None, bq: int = 128, bkv: int = 128,
                    q_period=None, interpret: bool = False, out_dtype=None):
    """q: (BH, Tq, D); k/v: (BH, Tk, D). Returns (BH, Tq, D).

    ``offset``: absolute position of q[0] (default Tk - Tq: queries are the
    final positions of the context).  ``kv_len``: number of live keys
    (positions ≥ kv_len are padding and masked out).  ``q_period``: the q
    axis holds several stacked query groups of this length sharing the K/V
    rows (GQA grouping — positions repeat every ``q_period`` rows; must be
    a multiple of ``bq``).
    """
    bh, tq, d = q.shape
    tk = k.shape[1]
    bq = min(bq, tq)
    bkv = min(bkv, tk)
    if tq % bq or tk % bkv:
        raise ValueError(f"Tq={tq}/Tk={tk} must be multiples of the tile "
                         f"sizes bq={bq}/bkv={bkv}")
    if q_period is not None and (q_period % bq or tq % q_period):
        raise ValueError(f"q_period={q_period} must be a multiple of bq={bq} "
                         f"and divide Tq={tq}")
    n_q, n_kv = tq // bq, tk // bkv
    offset = (tk - (tq if q_period is None else q_period)) \
        if offset is None else offset
    kv_len = tk if kv_len is None else kv_len
    scale = 1.0 / np.sqrt(d)
    out_dtype = out_dtype or q.dtype

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, offset=offset,
        kv_len=kv_len, bq=bq, bkv=bkv, n_kv=n_kv, q_period=q_period)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((bh, tq, d), out_dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(q, k, v)
