"""Segment-scheduled grouped expert GEMM (MoE) — Pallas TPU.

MoE expert compute is the *data-dependent* instance of the paper's dynamic
dataflow: routing produces a (token-group × expert) block-sparse structure
known only at runtime.  The Segment treatment, inside jit:

* **SELECTA** ≙ sort tokens by expert (``build_moe_chunks``): consecutive
  chunks share the expert weight block, which then stays resident in VMEM
  across grid steps (row-wise reuse of the stationary operand);
* **folding** ≙ oversized expert groups are split into fixed-size chunks and
  padded groups masked — load is balanced at chunk, not expert, granularity.

Grid: ``(n_chunks, n_tiles_n)``; chunk→expert mapping is scalar-prefetched.
The weight tile for expert e, N-tile j is re-fetched only when (e, j)
changes — with chunks sorted by expert this is once per expert per N tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _kernel(chunk_expert, x, w, out):
    out[...] = jax.lax.dot_general(
        x[...].astype(jnp.float32), w[0].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(out.dtype)


@functools.partial(jax.jit, static_argnames=("chunk_rows", "bn", "interpret",
                                             "out_dtype"))
def moe_gemm(x_sorted, w, chunk_expert, *, chunk_rows: int = 128,
             bn: int = 512, interpret: bool = False, out_dtype=jnp.float32):
    """Grouped GEMM over expert-sorted tokens.

    Args:
      x_sorted: (n_chunks * chunk_rows, d_in) tokens sorted by expert and
        padded to whole chunks (invalid rows must be zero).
      w: (E, d_in, d_out) expert weights.
      chunk_expert: (n_chunks,) int32 expert id per chunk (sorted ascending —
        the SELECTA grouping).
    Returns:
      (n_chunks * chunk_rows, d_out) activations in the sorted order.
    """
    t, d_in = x_sorted.shape
    n_chunks = chunk_expert.shape[0]
    if t != n_chunks * chunk_rows:
        raise ValueError(
            f"x_sorted has {t} rows but chunk_expert describes "
            f"{n_chunks} chunks of {chunk_rows} rows — pad the sorted "
            f"tokens to whole chunks")
    e, d_in_w, d_out = w.shape
    if d_in_w != d_in:
        raise ValueError(f"expert weights contract over d_in={d_in_w} but "
                         f"tokens have d_in={d_in}")
    bn = min(bn, d_out)
    if d_out % bn:
        raise ValueError(f"d_out={d_out} must be a multiple of the N tile "
                         f"bn={bn}")
    n_tiles_n = d_out // bn

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_chunks, n_tiles_n),
        in_specs=[
            pl.BlockSpec((chunk_rows, d_in), lambda c, j, ce: (c, 0)),
            pl.BlockSpec((1, d_in, bn), lambda c, j, ce: (ce[c], 0, j)),
        ],
        out_specs=pl.BlockSpec((chunk_rows, bn), lambda c, j, ce: (c, j)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, d_out), out_dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
    )(chunk_expert, x_sorted, w)


def build_moe_chunks(expert_of_token, n_experts: int, chunk_rows: int = 128,
                     capacity_factor: float = 1.25):
    """In-jit SELECTA for MoE: sort token ids by expert, pad each expert's
    group to whole chunks, emit (sort_idx, chunk_expert, valid mask).

    All shapes are static: ``n_chunks = ceil(T * capacity / chunk_rows)``
    with per-expert capacity ``cap = ceil(T * capacity_factor / E / rows) *
    rows``.  Overflowing tokens are dropped (standard MoE capacity
    semantics); the mask marks live rows.
    """
    t = expert_of_token.shape[0]
    cap_rows = int(np.ceil(t * capacity_factor / n_experts / chunk_rows)) * chunk_rows
    chunks_per_e = cap_rows // chunk_rows
    n_chunks = n_experts * chunks_per_e

    order = jnp.argsort(expert_of_token)                  # stable sort by expert
    sorted_e = expert_of_token[order]
    # position of each token within its expert group
    pos_in_e = jnp.arange(t) - jnp.searchsorted(sorted_e, sorted_e, side="left")
    keep = pos_in_e < cap_rows
    slot = sorted_e * cap_rows + pos_in_e                  # destination row
    slot = jnp.where(keep, slot, n_experts * cap_rows)     # overflow → trash row
    chunk_expert = jnp.repeat(jnp.arange(n_experts, dtype=jnp.int32), chunks_per_e)
    return order, slot, chunk_expert, keep, n_chunks, cap_rows
