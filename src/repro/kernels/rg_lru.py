"""RG-LRU linear recurrence (Griffin / RecurrentGemma) — Pallas TPU.

The recurrence ``h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)`` is the
memory-bound hot loop of the hybrid architecture's recurrent blocks.  The
kernel fuses gate math + scan per (batch row × time chunk), carrying the
hidden state in VMEM scratch across sequential time-chunk grid steps — one
HBM read per input element, one write per output element.

Note: the Segment dataflow is *inapplicable* here (attention-free dense
recurrence — see DESIGN.md §Arch-applicability); this kernel exists because
the architecture pool requires the layer to be fast, not because the paper's
technique maps onto it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _kernel(x_ref, ag_ref, xg_ref, ap_ref, h0_ref, o_ref, hT_ref, h_ref, *,
            ct, n_chunks, c):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    # fused gate math for the whole chunk (VPU elementwise)
    log_a = (-c * jax.nn.softplus(ap_ref[...].astype(jnp.float32))
             * jax.nn.sigmoid(ag_ref[0].astype(jnp.float32)))
    a = jnp.exp(log_a)                                   # (ct, D)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    xb = beta * (jax.nn.sigmoid(xg_ref[0].astype(jnp.float32))
                 * x_ref[0].astype(jnp.float32))

    def step(t, h):
        h = a[t] * h + xb[t]
        o_ref[0, t] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, ct, step, h_ref[0])
    h_ref[...] = h[None]

    @pl.when(ti == n_chunks - 1)
    def _finish():
        hT_ref[...] = h_ref[...].astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ct", "c", "interpret"))
def rg_lru(x, a_gate, x_gate, a_param, h0, *, ct: int = 128, c: float = 8.0,
           interpret: bool = False):
    """x/a_gate/x_gate: (B, T, D); a_param: (D,); h0: (B, D).

    Returns (out (B, T, D), h_T (B, D)).
    """
    b, t, d = x.shape
    ct = min(ct, t)
    if t % ct:
        raise ValueError(f"sequence length T={t} must be a multiple of the "
                         f"chunk length ct={ct}")
    n_chunks = t // ct

    kernel = functools.partial(_kernel, ct=ct, n_chunks=n_chunks, c=c)
    out, h_t = pl.pallas_call(
        kernel,
        grid=(b, n_chunks),
        in_specs=[
            pl.BlockSpec((1, ct, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, ct, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, ct, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, ct, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, d), x.dtype),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(x, a_gate, x_gate, a_param, h0)
    return out, h_t
