"""Sharded, async, atomic checkpointing (numpy-backed, orbax-free).

Layout::

    <dir>/step_<N>/
        manifest.json      # step, leaf paths, shapes, dtypes
        arrays.npz         # one entry per pytree leaf
    <dir>/step_<N>.tmp/    # staging; atomically renamed on commit

Properties the runtime relies on:

* **atomic commit** — a checkpoint either exists completely or not at all
  (rename(2) semantics), so a crash mid-save never corrupts restart state;
* **async** — saving runs on a background thread off the training critical
  path (the arrays are device_get'd synchronously — cheap on CPU, bounded
  by D2H on real hardware — then written asynchronously);
* **mesh-independent restore** — arrays are stored unsharded; restore
  device_puts them under *any* mesh's NamedShardings, which is what
  elastic re-meshing needs (runtime/elastic.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(state):
    leaves, treedef = jax.tree_util.tree_flatten(state)
    return leaves, treedef


def _key(i: int) -> str:
    return f"leaf_{i:05d}"


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, state: Any, *, wait: bool = False) -> None:
        leaves, _ = _flatten(state)
        host_leaves = []
        for l in leaves:
            a = np.asarray(jax.device_get(l))
            if a.dtype.name in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
                a = a.astype(np.float32)   # npz-safe; restore casts back
            host_leaves.append(a)
        self.wait()          # one outstanding async save at a time
        self._thread = threading.Thread(
            target=self._write, args=(step, host_leaves), daemon=True)
        self._thread.start()
        if wait:
            self.wait()

    def _write(self, step: int, host_leaves) -> None:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{_key(i): a for i, a in enumerate(host_leaves)})
        manifest = {
            "step": step,
            "n_leaves": len(host_leaves),
            "shapes": [list(a.shape) for a in host_leaves],
            "dtypes": [str(a.dtype) for a in host_leaves],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)        # atomic commit
        self._gc()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore -----------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, state_like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``state_like``; optionally place
        each leaf with the given shardings pytree (any mesh)."""
        path = os.path.join(self.directory, f"step_{step:08d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            leaves, treedef = _flatten(state_like)
            loaded = [z[_key(i)] for i in range(len(leaves))]
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_leaves(shardings)
            loaded = [jax.device_put(a.astype(l.dtype), s)
                      for a, l, s in zip(loaded, leaves, sh_leaves)]
        else:
            loaded = [jax.device_put(a.astype(l.dtype)) for a, l in zip(loaded, leaves)]
        return jax.tree_util.tree_unflatten(treedef, loaded)
