"""Architecture registry: one module per assigned architecture."""
import dataclasses

from .base import SHAPES, ModelConfig, ShapeConfig
from . import (command_r_plus_104b, granite_3_8b, internvl2_2b,
               llama4_maverick_400b_a17b, phi3_5_moe_42b_a6_6b,
               phi3_mini_3_8b, qwen1_5_4b, recurrentgemma_9b, rwkv6_1_6b,
               whisper_tiny)

REGISTRY = {m.CONFIG.name: m.CONFIG for m in (
    internvl2_2b, whisper_tiny, phi3_mini_3_8b, qwen1_5_4b, granite_3_8b,
    command_r_plus_104b, recurrentgemma_9b, llama4_maverick_400b_a17b,
    phi3_5_moe_42b_a6_6b, rwkv6_1_6b)}

ARCH_IDS = list(REGISTRY)

# long_500k requires sub-quadratic context handling: only constant-state /
# windowed archs run it (see DESIGN.md §Arch-applicability).
LONG_CONTEXT_ARCHS = ("recurrentgemma-9b", "rwkv6-1.6b")


def get_config(name: str) -> ModelConfig:
    return REGISTRY[name]


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test scale: same family/topology, tiny dims."""
    pattern_len = len(cfg.layer_pattern) or 1
    return dataclasses.replace(
        cfg,
        n_layers=max(2, pattern_len + 1) if cfg.layer_pattern else 2,
        d_model=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv=min(max(cfg.n_kv, 0), 2) if cfg.n_heads else 0,
        head_dim=16 if cfg.head_dim else None,
        d_ff=128,
        vocab=512,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        enc_layers=min(cfg.enc_layers, 2),
        dec_layers=min(cfg.dec_layers, 2),
        local_window=32,
        n_frontend_tokens=min(cfg.n_frontend_tokens, 8),
        attn_chunk=64,
        remat=False)


def cell_is_live(arch: str, shape: str) -> bool:
    """Which (arch × shape) cells run (40 total, 32 live)."""
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True
