"""InternVL2-2B backbone: InternViT frontend (stub) + InternLM2 LM.
[arXiv:2404.16821; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm", n_layers=24, d_model=2048,
    n_heads=16, n_kv=8, d_ff=8192, vocab=92553,
    frontend="patch", n_frontend_tokens=256)
