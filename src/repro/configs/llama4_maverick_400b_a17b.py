"""Llama-4 Maverick 400B-A17B: MoE 128 experts top-1, interleaved with
dense layers (the 400B-total / 17B-active figures correspond to alternating
dense/MoE blocks, as in the official architecture).
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe", n_layers=48,
    d_model=5120, n_heads=40, n_kv=8, d_ff=8192, vocab=202048,
    n_experts=128, top_k=1, layer_pattern=("attn", "moe"))
