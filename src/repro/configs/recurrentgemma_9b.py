"""RecurrentGemma-9B: RG-LRU + local attention, 1:2 pattern (2 recurrent
blocks then 1 local-attention block). [arXiv:2402.19427]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
    n_heads=16, n_kv=1, d_ff=12288, vocab=256000, head_dim=256,
    layer_pattern=("rec", "rec", "local"), local_window=2048)
