"""Model + shape configuration dataclasses (one <arch>.py per assigned
architecture imports and instantiates these)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | enc_dec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                 # 0 for attention-free families
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25
    # hybrid (RecurrentGemma): repeating layer pattern
    layer_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "local")
    local_window: int = 2048
    # enc-dec (whisper)
    enc_layers: int = 0
    dec_layers: int = 0
    # modality frontend stub
    frontend: str = "none"       # none | patch | frame
    n_frontend_tokens: int = 0
    # the paper's technique: block-sparse FFN weights
    ffn_block_sparse: bool = False
    ffn_block: int = 64
    ffn_density: float = 0.25
    # misc
    dtype: str = "bfloat16"
    remat: bool = True
    attn_chunk: int = 1024
    seq_shard: bool = False   # sequence-parallel activations (Megatron SP):
                              # layer-boundary residuals shard T on `model`
    kv_cache_dtype: str = "bfloat16"   # "int8" = quantized KV (beyond-paper:
                              # halves the decode memory-bound roofline term)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 (Megatron-style TP padding)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    def layer_kind(self, i: int) -> str:
        """Block kind for layer i: attn | moe | rec | local | rwkv."""
        if self.family == "ssm":
            return "rwkv"
        if self.layer_pattern:
            return self.layer_pattern[i % len(self.layer_pattern)]
        if self.n_experts:
            return "moe"
        return "attn"

    def param_count(self) -> int:
        """Approximate total parameters (for roofline MODEL_FLOPS)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd = self.hd
        per_layer = 0
        n_layers = self.n_layers if not self.enc_layers else (
            self.enc_layers + self.dec_layers)
        for i in range(n_layers):
            kind = self.layer_kind(i)
            attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv * hd) \
                + (self.n_heads * hd) * d
            if kind == "moe":
                per_layer += attn + self.n_experts * 3 * d * ff + d * self.n_experts
            elif kind == "rec":
                per_layer += 4 * d * d + 3 * d * ff  # rglru block + mlp
            elif kind == "rwkv":
                per_layer += 5 * d * d + 2 * d * ff
            elif kind == "local":
                per_layer += attn + 3 * d * ff
            else:
                per_layer += attn + 3 * d * ff
        emb = v * d * (1 if self.tie_embeddings else 2)
        return per_layer + emb

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if not self.n_experts:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        total = self.param_count()
        moe_layers = sum(1 for i in range(self.n_layers)
                         if self.layer_kind(i) == "moe")
        inactive = moe_layers * (self.n_experts - self.top_k) * 3 * d * ff
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                    # train | prefill | decode
    seq_len: int
    global_batch: int
    accum_steps: int = 1         # gradient-accumulation microbatches (train)


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}
