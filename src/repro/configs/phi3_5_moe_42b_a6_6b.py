"""Phi-3.5-MoE 42B-A6.6B: 16 experts top-2. [hf:microsoft/Phi-3.5-MoE-instruct]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv=8, d_ff=6400, vocab=32064, n_experts=16, top_k=2)
