"""RWKV-6 (Finch) 1.6B: attention-free, data-dependent decay.
[arXiv:2404.05892]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm", n_layers=24, d_model=2048,
    n_heads=32, n_kv=0, d_ff=7168, vocab=65536)
