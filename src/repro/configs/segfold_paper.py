"""The paper's own hardware configuration (Table II) as a config object —
used by the simulator benchmarks."""
from repro.sim.segfold_sim import SegFoldConfig

PAPER_HW = SegFoldConfig()           # 16×16 PEs, W=32, 4-wide multicast,
                                     # 1.5 MiB cache, HBM2 @ 256 B/cycle
