"""Whisper-tiny backbone: enc-dec, conv frontend stubbed to precomputed
frame embeddings. [arXiv:2212.04356]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="enc_dec", n_layers=8, d_model=384,
    n_heads=6, n_kv=6, d_ff=1536, vocab=51865,
    enc_layers=4, dec_layers=4, frontend="frame", n_frontend_tokens=1500)
