"""Command-R+ 104B: dense GQA(kv=8), no bias. [hf:CohereForAI/c4ai-command-r-v01]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense", n_layers=64, d_model=12288,
    n_heads=96, n_kv=8, d_ff=33792, vocab=256000)
