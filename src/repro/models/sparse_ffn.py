"""Sparse-weight linear layers backed by the Segment SpMM kernel.

Weights are stored block-sparse (BSR) and driven entirely through
:mod:`repro.api`: the layer holds a :class:`~repro.api.SegmentPlan` built
with ``with_grad=True`` (so the plan carries the transposed schedule for the
backward pass) and the trainable parameters are the plan's block values in
original BSR storage order.  Forward and backward both run through
:func:`repro.api.apply_plan` — the one ``custom_vjp`` shared with serving:

* ``dx = Wᵀ @ dy``  — another Segment SpMM under the transposed schedule
  (built once, static);
* ``dW_blocks[i] = dy[m_i] @ x[k_i]ᵀ`` — a block-sampled dense-dense product
  (SDDMM at block granularity), pure jnp gather + matmul.

This is the paper's technique as a *first-class trainable layer*: prune a
dense weight to blocks, keep the schedule fixed (static sparsity amortizes
the scheduling cost, DESIGN.md §2), train the surviving blocks.  The plan is
a registered pytree, so layers jit/vmap/shard without the identity-hash
``_Static`` wrapper this module used to define.

For serving, :meth:`SparseLinear.quantize` / :meth:`SparseMLP.quantize`
freeze trained blocks into int8/fp8 payloads with per-block fp32 scales —
the kernels dequantize at the fp32 accumulator, cutting the weight-fetch
bytes the Segment schedule's traffic model counts by ~4×.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import SegmentPlan, apply_plan, plan_matmul
from repro.core.formats import BSR, QUANT_DTYPES


@dataclasses.dataclass
class SparseLinear:
    """W (d_out × d_in) block-sparse; apply computes x @ Wᵀ via W @ xᵀ."""

    plan: SegmentPlan        # with_grad plan; lhs_blocks = init values
    d_out: int
    d_in: int

    @staticmethod
    def create(key, d_in, d_out, *, block=64, density=0.25,
               policy: str = "segment", dtype=jnp.float32):
        if d_in % block or d_out % block:
            raise ValueError(f"d_in={d_in} and d_out={d_out} must be "
                             f"multiples of block={block}: the Segment grid "
                             f"is exact and would pad the output otherwise")
        rng = np.random.default_rng(np.asarray(jax.random.key_data(key))[-1])
        w = BSR.random(rng, (d_out, d_in), (block, block), density,
                       dtype=np.float32)
        plan = plan_matmul(w, policy=policy, with_grad=True)
        layer = SparseLinear(plan=plan, d_out=d_out, d_in=d_in)
        # trainable values live in the params dict, in original BSR block
        # order (the plan's storage layout — ``plan.a_brow``/``a_bcol`` give
        # each block's coordinates); the plan copy keeps the init values
        # only as a template.
        params = {"blocks": plan.lhs_blocks.astype(dtype)}
        return layer, params

    def quantize(self, params, dtype: str = "int8"):
        """Freeze trained fp32 blocks into a quantized inference layer.

        Rebuilds the plan with ``quantize=dtype`` over the same pattern —
        the payload + per-block scales become the new param leaves (in the
        same BSR storage order), the kernels dequantize at the fp32
        accumulator, and gradients to the weights stop (x-gradients still
        flow, so the layer composes under ``jax.grad`` of downstream
        losses).  The source plan's full planner configuration — lanes,
        unroll, backend, ``pipeline`` and the tuned ``bn_hint`` — is
        carried over.  ``fold_len`` is the one knob a plan does not record;
        a fold-built plan (any ``accum_prev`` item set) raises rather than
        silently re-planning without the fold.  Returns ``(layer, params)``
        like :meth:`create`.
        """
        blocks = np.asarray(params["blocks"])
        if (self.plan.quantized or "scales" in params
                or np.dtype(blocks.dtype) in QUANT_DTYPES.values()):
            raise ValueError(
                "layer is already quantized — re-quantizing would treat the "
                f"{blocks.dtype} payload as fp32 weights and silently drop "
                "the per-block scales; quantize from the fp32 layer+params")
        if self.plan.accum_prev is not None and np.any(
                np.asarray(self.plan.accum_prev)):
            raise ValueError(
                "cannot quantize a layer built from a fold_len plan: the "
                "fold length is not recorded on the plan, so re-planning "
                "would silently drop the fold schedule — build the fp32 "
                "layer without fold_len, or re-plan manually with "
                "plan_matmul(..., fold_len=..., quantize=...)")
        w = BSR(shape=(self.d_out, self.d_in),
                block_shape=self.plan.block_shape,
                brow=np.asarray(self.plan.a_brow),
                bcol=np.asarray(self.plan.a_bcol),
                blocks=blocks.astype(np.float32))
        plan = plan_matmul(w, policy=self.plan.policy, with_grad=True,
                           quantize=dtype, n_lanes=self.plan.n_lanes,
                           unroll=self.plan.unroll, backend=self.plan.backend,
                           pipeline=self.plan.pipeline,
                           bn_hint=self.plan.bn_hint)
        layer = SparseLinear(plan=plan, d_out=self.d_out, d_in=self.d_in)
        return layer, {"blocks": plan.lhs_blocks, "scales": plan.lhs_scales}

    def apply(self, params, x2d):
        """x2d: (T, d_in) → (T, d_out)."""
        plan = self.plan.with_values(params["blocks"],
                                     lhs_scales=params.get("scales"))
        yT = apply_plan(plan, x2d.T)
        return yT.T


@dataclasses.dataclass
class SparseMLP:
    """SwiGLU MLP with block-sparse up/gate/down projections."""

    up: SparseLinear
    gate: SparseLinear
    down: SparseLinear

    @staticmethod
    def create(key, d_model, d_ff, *, block=64, density=0.25, dtype=jnp.float32):
        k1, k2, k3 = jax.random.split(key, 3)
        up, p_up = SparseLinear.create(k1, d_model, d_ff, block=block,
                                       density=density, dtype=dtype)
        gate, p_gate = SparseLinear.create(k2, d_model, d_ff, block=block,
                                           density=density, dtype=dtype)
        down, p_down = SparseLinear.create(k3, d_ff, d_model, block=block,
                                           density=density, dtype=dtype)
        layer = SparseMLP(up=up, gate=gate, down=down)
        return layer, {"up": p_up, "gate": p_gate, "down": p_down}

    def quantize(self, params, dtype: str = "int8"):
        """Quantized inference copy of the MLP (all three projections)."""
        up, p_up = self.up.quantize(params["up"], dtype)
        gate, p_gate = self.gate.quantize(params["gate"], dtype)
        down, p_down = self.down.quantize(params["down"], dtype)
        layer = SparseMLP(up=up, gate=gate, down=down)
        return layer, {"up": p_up, "gate": p_gate, "down": p_down}

    def apply(self, params, x):
        shape = x.shape
        x2 = x.reshape(-1, shape[-1])
        h = (jax.nn.silu(self.gate.apply(params["gate"], x2))
             * self.up.apply(params["up"], x2))
        y = self.down.apply(params["down"], h.astype(x.dtype))
        return y.reshape(*shape[:-1], -1).astype(x.dtype)
