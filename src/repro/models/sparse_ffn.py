"""Sparse-weight linear layers backed by the Segment SpMM kernel.

Weights are stored block-sparse (BSR); the forward pass runs the
Segment-scheduled Pallas SpMM (``repro.kernels.segment_spmm``) and training
works through a custom VJP:

* ``dx = Wᵀ @ dy``  — another Segment SpMM under the transposed schedule
  (built once, static);
* ``dW_blocks[i] = dy[m_i] @ x[k_i]ᵀ`` — a block-sampled dense-dense product
  (SDDMM at block granularity), pure jnp gather + matmul.

This is the paper's technique as a *first-class trainable layer*: prune a
dense weight to blocks, keep the schedule fixed (static sparsity amortizes
the scheduling cost, DESIGN.md §2), train the surviving blocks.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import BSR
from repro.core.schedule import build_spmm_schedule
from repro.kernels.ops import INTERPRET
from repro.kernels.segment_spmm import segment_spmm


class _Static:
    """Hashable identity wrapper so schedules ride nondiff_argnums."""

    def __init__(self, **kw):
        self.__dict__.update(kw)

    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other


def _make_sched_static(a: BSR, policy: str):
    sched = build_spmm_schedule(a, policy=policy)
    seen, accum = set(), np.zeros(sched.n_items, np.int32)
    for i in np.nonzero(sched.seg_start)[0]:
        m = int(sched.m[i])
        accum[i] = 1 if m in seen else 0
        seen.add(m)
    row_mask = np.zeros(sched.n_m_blocks, np.float32)
    row_mask[np.unique(sched.m)] = 1.0
    return _Static(
        m=jnp.asarray(sched.m), k=jnp.asarray(sched.k),
        seg_start=jnp.asarray(sched.seg_start),
        seg_write=jnp.asarray(sched.seg_write),
        accum=jnp.asarray(accum),
        perm=sched.a_idx,                      # original-order → schedule-order
        grid_m=sched.n_m_blocks, grid_k=sched.n_k_blocks,
        bm=a.block_shape[0], bk=a.block_shape[1],
        row_mask=jnp.asarray(row_mask))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _sparse_matmul(fwd_s, bwd_s, blocks, x):
    """y = W @ x with W = BSR(blocks under fwd_s schedule). x: (K, N)."""
    return _sparse_matmul_fwd_impl(fwd_s, blocks, x)


def _sparse_matmul_fwd_impl(s, blocks, x):
    out = segment_spmm(
        blocks[s.perm], s.m, s.k, s.seg_start, s.seg_write, s.accum, x,
        grid_m=s.grid_m, bn=min(512, x.shape[1]), interpret=INTERPRET,
        out_dtype=jnp.float32)
    live = jnp.repeat(s.row_mask > 0, s.bm)[:, None]
    return jnp.where(live, out, jnp.zeros((), out.dtype)).astype(x.dtype)


def _sparse_matmul_fwd(fwd_s, bwd_s, blocks, x):
    return _sparse_matmul(fwd_s, bwd_s, blocks, x), (blocks, x)


def _sparse_matmul_bwd(fwd_s, bwd_s, res, dy):
    blocks, x = res
    # dx = Wᵀ @ dy: block i of Wᵀ is blockᵀ j of W with coords swapped;
    # bwd_s.perm maps the transposed schedule directly into W's block list.
    blocks_t = blocks.transpose(0, 2, 1)
    out = segment_spmm(
        blocks_t[bwd_s.perm], bwd_s.m, bwd_s.k, bwd_s.seg_start,
        bwd_s.seg_write, bwd_s.accum, dy,
        grid_m=bwd_s.grid_m, bn=min(512, dy.shape[1]), interpret=INTERPRET,
        out_dtype=jnp.float32)
    live = jnp.repeat(bwd_s.row_mask > 0, bwd_s.bm)[:, None]
    dx = jnp.where(live, out, jnp.zeros((), out.dtype)).astype(x.dtype)
    # dW_blocks[i] = dy[m_i·bm:(m_i+1)·bm] @ x[k_i·bk:(k_i+1)·bk]ᵀ (block SDDMM)
    bm, bk = fwd_s.bm, fwd_s.bk
    dyb = dy.reshape(fwd_s.grid_m, bm, -1)
    xb = x.reshape(fwd_s.grid_k, bk, -1)
    dW_sched = jnp.einsum("imn,ikn->imk", dyb[fwd_s.m], xb[fwd_s.k])
    perm = jnp.asarray(fwd_s.perm)
    inv = jnp.zeros_like(perm).at[perm].set(jnp.arange(perm.shape[0]))
    dW = dW_sched[inv].astype(blocks.dtype)
    return dW, dx


_sparse_matmul.defvjp(_sparse_matmul_fwd, _sparse_matmul_bwd)


@dataclasses.dataclass
class SparseLinear:
    """W (d_out × d_in) block-sparse; apply computes x @ Wᵀ via W @ xᵀ."""

    fwd_s: _Static
    bwd_s: _Static
    d_out: int
    d_in: int

    @staticmethod
    def create(key, d_in, d_out, *, block=64, density=0.25,
               policy: str = "segment", dtype=jnp.float32):
        rng = np.random.default_rng(np.asarray(jax.random.key_data(key))[-1])
        w = BSR.random(rng, (d_out, d_in), (block, block), density, dtype=np.float32)
        wt = BSR(shape=(d_in, d_out), block_shape=(block, block),
                 brow=w.bcol.copy(), bcol=w.brow.copy(),
                 blocks=w.blocks.transpose(0, 2, 1))
        wt = wt.row_major_order()
        layer = SparseLinear(
            fwd_s=_make_sched_static(w, policy),
            bwd_s=_make_sched_static(wt, policy), d_out=d_out, d_in=d_in)
        # the transposed schedule permutes the *transposed-matrix* block list;
        # rebuild its perm to index W's own block order (coords swapped)
        key_w = {(int(r), int(c)): i for i, (r, c) in enumerate(zip(w.brow, w.bcol))}
        map_t_to_w = np.asarray([key_w[(int(c), int(r))]
                                 for r, c in zip(wt.brow, wt.bcol)], np.int64)
        layer.bwd_s.perm = map_t_to_w[layer.bwd_s.perm]
        params = {"blocks": jnp.asarray(w.blocks, dtype)}
        return layer, params

    def apply(self, params, x2d):
        """x2d: (T, d_in) → (T, d_out)."""
        yT = _sparse_matmul(self.fwd_s, self.bwd_s, params["blocks"], x2d.T)
        return yT.T


@dataclasses.dataclass
class SparseMLP:
    """SwiGLU MLP with block-sparse up/gate/down projections."""

    up: SparseLinear
    gate: SparseLinear
    down: SparseLinear

    @staticmethod
    def create(key, d_model, d_ff, *, block=64, density=0.25, dtype=jnp.float32):
        k1, k2, k3 = jax.random.split(key, 3)
        up, p_up = SparseLinear.create(k1, d_model, d_ff, block=block,
                                       density=density, dtype=dtype)
        gate, p_gate = SparseLinear.create(k2, d_model, d_ff, block=block,
                                           density=density, dtype=dtype)
        down, p_down = SparseLinear.create(k3, d_ff, d_model, block=block,
                                           density=density, dtype=dtype)
        layer = SparseMLP(up=up, gate=gate, down=down)
        return layer, {"up": p_up, "gate": p_gate, "down": p_down}

    def apply(self, params, x):
        shape = x.shape
        x2 = x.reshape(-1, shape[-1])
        h = (jax.nn.silu(self.gate.apply(params["gate"], x2))
             * self.up.apply(params["up"], x2))
        y = self.down.apply(params["down"], h.astype(x.dtype))
        return y.reshape(*shape[:-1], -1).astype(x.dtype)
