"""Sparse-weight linear layers backed by the Segment SpMM kernel.

Weights are stored block-sparse (BSR) and driven entirely through
:mod:`repro.api`: the layer holds a :class:`~repro.api.SegmentPlan` built
with ``with_grad=True`` (so the plan carries the transposed schedule for the
backward pass) and the trainable parameters are the plan's block values in
original BSR storage order.  Forward and backward both run through
:func:`repro.api.apply_plan` — the one ``custom_vjp`` shared with serving:

* ``dx = Wᵀ @ dy``  — another Segment SpMM under the transposed schedule
  (built once, static);
* ``dW_blocks[i] = dy[m_i] @ x[k_i]ᵀ`` — a block-sampled dense-dense product
  (SDDMM at block granularity), pure jnp gather + matmul.

This is the paper's technique as a *first-class trainable layer*: prune a
dense weight to blocks, keep the schedule fixed (static sparsity amortizes
the scheduling cost, DESIGN.md §2), train the surviving blocks.  The plan is
a registered pytree, so layers jit/vmap/shard without the identity-hash
``_Static`` wrapper this module used to define.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import SegmentPlan, apply_plan, plan_matmul
from repro.core.formats import BSR


@dataclasses.dataclass
class SparseLinear:
    """W (d_out × d_in) block-sparse; apply computes x @ Wᵀ via W @ xᵀ."""

    plan: SegmentPlan        # with_grad plan; lhs_blocks = init values
    d_out: int
    d_in: int

    @staticmethod
    def create(key, d_in, d_out, *, block=64, density=0.25,
               policy: str = "segment", dtype=jnp.float32):
        if d_in % block or d_out % block:
            raise ValueError(f"d_in={d_in} and d_out={d_out} must be "
                             f"multiples of block={block}: the Segment grid "
                             f"is exact and would pad the output otherwise")
        rng = np.random.default_rng(np.asarray(jax.random.key_data(key))[-1])
        w = BSR.random(rng, (d_out, d_in), (block, block), density,
                       dtype=np.float32)
        plan = plan_matmul(w, policy=policy, with_grad=True)
        layer = SparseLinear(plan=plan, d_out=d_out, d_in=d_in)
        # trainable values live in the params dict, in original BSR block
        # order (the plan's storage layout — ``plan.a_brow``/``a_bcol`` give
        # each block's coordinates); the plan copy keeps the init values
        # only as a template.
        params = {"blocks": plan.lhs_blocks.astype(dtype)}
        return layer, params

    def apply(self, params, x2d):
        """x2d: (T, d_in) → (T, d_out)."""
        yT = apply_plan(self.plan.with_values(params["blocks"]), x2d.T)
        return yT.T


@dataclasses.dataclass
class SparseMLP:
    """SwiGLU MLP with block-sparse up/gate/down projections."""

    up: SparseLinear
    gate: SparseLinear
    down: SparseLinear

    @staticmethod
    def create(key, d_model, d_ff, *, block=64, density=0.25, dtype=jnp.float32):
        k1, k2, k3 = jax.random.split(key, 3)
        up, p_up = SparseLinear.create(k1, d_model, d_ff, block=block,
                                       density=density, dtype=dtype)
        gate, p_gate = SparseLinear.create(k2, d_model, d_ff, block=block,
                                           density=density, dtype=dtype)
        down, p_down = SparseLinear.create(k3, d_ff, d_model, block=block,
                                           density=density, dtype=dtype)
        layer = SparseMLP(up=up, gate=gate, down=down)
        return layer, {"up": p_up, "gate": p_gate, "down": p_down}

    def apply(self, params, x):
        shape = x.shape
        x2 = x.reshape(-1, shape[-1])
        h = (jax.nn.silu(self.gate.apply(params["gate"], x2))
             * self.up.apply(params["up"], x2))
        y = self.down.apply(params["down"], h.astype(x.dtype))
        return y.reshape(*shape[:-1], -1).astype(x.dtype)
