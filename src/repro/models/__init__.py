"""Model zoo: the 10 assigned architectures on a unified transformer stack."""
from .model import abstract_params, build_model, cache_specs, input_specs
from .transformer import Transformer

__all__ = ["abstract_params", "build_model", "cache_specs", "input_specs",
           "Transformer"]
