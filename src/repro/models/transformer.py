"""Unified transformer family: one implementation, ten architectures.

Families (``ModelConfig.family``):
* ``dense`` / ``vlm`` / ``audio-as-decoder`` — GQA attention + SwiGLU (or
  block-sparse Segment) FFN, scanned over layers;
* ``moe``    — GQA attention + Segment-dispatched MoE FFN;
* ``hybrid`` — RecurrentGemma: repeating (rec, rec, local-attention) units;
* ``ssm``    — RWKV-6 time-mix/channel-mix;
* ``enc_dec``— Whisper backbone: bidirectional encoder over frame embeddings
  (frontend stubbed per spec) + causal decoder with cross-attention.

Params are pytrees with layer-stacked leaves; layer iteration is
``lax.scan`` (+ optional remat) so the HLO stays compact for the 512-chip
dry-run even at 64 layers.
"""
from __future__ import annotations

import copy
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.formats import QUANT_DTYPES, quantize_blocks
from repro.sharding import act_constrain
from . import layers, moe, recurrent
from .sparse_ffn import SparseMLP


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _sparse_mlp_params(key, sm: SparseMLP, dtype):
    """Fresh trainable blocks for the *shared* sparse schedule (all layers
    prune to the same block pattern; only values differ)."""
    def pb(k, lin):
        n = lin.plan.n_blocks
        bm, bk = lin.plan.block_shape
        return {"blocks": jax.random.normal(k, (n, bm, bk), dtype)
                / np.sqrt(lin.d_in)}
    k1, k2, k3 = jax.random.split(key, 3)
    return {"up": pb(k1, sm.up), "gate": pb(k2, sm.gate),
            "down": pb(k3, sm.down)}


def _is_sparse_mlp_params(p) -> bool:
    """True for a block dict whose ``mlp`` subtree holds SparseMLP leaves
    (``up``/``gate``/``down`` each carrying ``blocks``) rather than dense
    SwiGLU weights."""
    mlp = p.get("mlp") if isinstance(p, dict) else None
    return (isinstance(mlp, dict)
            and all(isinstance(mlp.get(k), dict) and "blocks" in mlp[k]
                    for k in ("up", "gate", "down")))


def _quantize_mlp_params(mlp, dtype: str):
    """Quantize one (layer-stacked) SparseMLP param subtree: each
    projection's fp32 ``blocks`` leaf — any leading stack axes, then
    ``(n_blocks, bm, bk)`` — becomes a payload + per-block (or per-block-row
    for ``*.rowwise`` modes) fp32 ``scales`` leaf with the same stacking."""
    out = {}
    for proj in ("up", "gate", "down"):
        leaf = mlp[proj]
        blocks = np.asarray(leaf["blocks"])
        if ("scales" in leaf
                or np.dtype(blocks.dtype) in QUANT_DTYPES.values()):
            raise ValueError(
                f"params['...']['mlp']['{proj}'] is already quantized "
                f"({blocks.dtype}) — quantize from the fp32 model+params")
        *stack, n, bm, bk = blocks.shape
        q = quantize_blocks(blocks.reshape(-1, bm, bk).astype(np.float32),
                            dtype)
        out[proj] = {
            "blocks": jnp.asarray(q.payload.reshape(blocks.shape)),
            "scales": jnp.asarray(q.scales.reshape(
                tuple(stack) + (n,) + q.scales.shape[1:])),
        }
    return out


# ---------------------------------------------------------------------------
# per-kind block init / apply
# ---------------------------------------------------------------------------


def _block_init(cfg: ModelConfig, key, kind: str, sparse_mlp: Optional[SparseMLP]):
    dt = jnp.float32
    d = cfg.d_model
    p: Dict[str, Any] = {"norm1": layers.rmsnorm_init(d), "norm2": layers.rmsnorm_init(d)}
    k1, k2 = jax.random.split(key)
    if kind in ("attn", "attn_bidir", "local", "cross"):
        p["attn"] = layers.attention_init(k1, d, cfg.n_heads, cfg.n_kv, cfg.hd,
                                          qkv_bias=cfg.qkv_bias, dtype=dt)
        if kind == "cross":
            p["norm_x"] = layers.rmsnorm_init(d)
            p["xattn"] = layers.attention_init(
                jax.random.fold_in(k1, 1), d, cfg.n_heads, cfg.n_kv, cfg.hd,
                qkv_bias=cfg.qkv_bias, dtype=dt)
        if sparse_mlp is not None:
            p["mlp"] = _sparse_mlp_params(k2, sparse_mlp, dt)
        else:
            p["mlp"] = layers.swiglu_init(k2, d, cfg.d_ff, dtype=dt)
    elif kind == "moe":
        p["attn"] = layers.attention_init(k1, d, cfg.n_heads, cfg.n_kv, cfg.hd,
                                          qkv_bias=cfg.qkv_bias, dtype=dt)
        p["moe"] = moe.moe_init(k2, d, cfg.d_ff, cfg.n_experts, dtype=dt)
    elif kind == "rec":
        p["rec"] = recurrent.rglru_block_init(k1, d, dtype=dt)
        p["mlp"] = layers.swiglu_init(k2, d, cfg.d_ff, dtype=dt)
    elif kind == "rwkv":
        p = {"norm1": layers.rmsnorm_init(d), "norm2": layers.rmsnorm_init(d),
             "rwkv": recurrent.rwkv_block_init(k1, d, cfg.n_heads or 32,
                                               cfg.d_ff, dtype=dt)}
    else:
        raise ValueError(kind)
    return p


def _block_apply(cfg: ModelConfig, p, x, kind: str, *, positions,
                 sparse_mlp: Optional[SparseMLP], enc_out=None,
                 cache=None, cache_pos=None):
    """Returns (x, aux_loss, new_cache)."""
    if cfg.seq_shard and cache is None:
        x = act_constrain(x, "seq")
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    if kind in ("attn", "attn_bidir", "local", "cross"):
        window = cfg.local_window if kind == "local" else None
        h, kv = layers.attention_apply(
            p["attn"], layers.rmsnorm_apply(p["norm1"], x, cfg.norm_eps),
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
            positions=positions, causal=(kind != "attn_bidir"), window=window,
            rope_theta=cfg.rope_theta,
            cache=cache.get("kv") if cache else None, cache_pos=cache_pos,
            chunk=cfg.attn_chunk, ring=(kind == "local" and cache is not None))
        x = x + h
        if kv is not None:
            new_cache["kv"] = kv
        if kind == "cross":
            hx, xkv = layers.attention_apply(
                p["xattn"], layers.rmsnorm_apply(p["norm_x"], x, cfg.norm_eps),
                n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
                positions=positions, causal=False, rope_theta=0.0,
                kv_ctx=enc_out, chunk=cfg.attn_chunk)
            x = x + hx
        n2 = layers.rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
        if sparse_mlp is not None:
            x = x + sparse_mlp.apply(p["mlp"], n2)
        else:
            x = x + layers.swiglu_apply(p["mlp"], n2)
    elif kind == "moe":
        h, kv = layers.attention_apply(
            p["attn"], layers.rmsnorm_apply(p["norm1"], x, cfg.norm_eps),
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
            positions=positions, causal=True, rope_theta=cfg.rope_theta,
            cache=cache.get("kv") if cache else None, cache_pos=cache_pos,
            chunk=cfg.attn_chunk)
        x = x + h
        if kv is not None:
            new_cache["kv"] = kv
        h, aux = moe.moe_apply(
            p["moe"], layers.rmsnorm_apply(p["norm2"], x, cfg.norm_eps),
            top_k=cfg.top_k, capacity_factor=cfg.moe_capacity_factor)
        x = x + h
    elif kind == "rec":
        h, st = recurrent.rglru_block_apply(
            p["rec"], layers.rmsnorm_apply(p["norm1"], x, cfg.norm_eps),
            state=cache.get("rec") if cache else None)
        x = x + h
        new_cache["rec"] = st
        x = x + layers.swiglu_apply(
            p["mlp"], layers.rmsnorm_apply(p["norm2"], x, cfg.norm_eps))
    elif kind == "rwkv":
        st = cache.get("rwkv") if cache else recurrent.rwkv_block_state(
            x.shape[0], cfg.d_model, cfg.n_heads or 32, x.dtype)
        h, st_tm = recurrent.rwkv_time_mix(
            p["rwkv"], layers.rmsnorm_apply(p["norm1"], x, cfg.norm_eps),
            cfg.n_heads or 32, {"shift": st["shift"], "S": st["S"]})
        x = x + h
        h, cm_shift = recurrent.rwkv_channel_mix(
            p["rwkv"], layers.rmsnorm_apply(p["norm2"], x, cfg.norm_eps),
            st["cm_shift"])
        x = x + h
        new_cache["rwkv"] = {"shift": st_tm["shift"], "S": st_tm["S"],
                             "cm_shift": cm_shift}
    else:
        raise ValueError(kind)
    return x, aux, new_cache


def _block_cache_init(cfg: ModelConfig, kind: str, b: int, t_max: int, dt):
    def kv(t_len):
        if cfg.kv_cache_dtype == "int8":
            return {"k": jnp.zeros((b, t_len, cfg.n_kv, cfg.hd), jnp.int8),
                    "v": jnp.zeros((b, t_len, cfg.n_kv, cfg.hd), jnp.int8),
                    "k_s": jnp.zeros((b, t_len, cfg.n_kv), jnp.float32),
                    "v_s": jnp.zeros((b, t_len, cfg.n_kv), jnp.float32)}
        return {"k": jnp.zeros((b, t_len, cfg.n_kv, cfg.hd), dt),
                "v": jnp.zeros((b, t_len, cfg.n_kv, cfg.hd), dt)}
    if kind in ("attn", "cross", "moe"):
        return {"kv": kv(t_max)}
    if kind == "local":
        return {"kv": kv(min(t_max, cfg.local_window))}
    if kind == "rec":
        return {"rec": recurrent.rglru_block_state(b, cfg.d_model, dt)}
    if kind == "rwkv":
        return {"rwkv": recurrent.rwkv_block_state(b, cfg.d_model,
                                                   cfg.n_heads or 32, dt)}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------


class Transformer:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.sparse_mlp: Optional[SparseMLP] = None
        if cfg.ffn_block_sparse:
            # one shared schedule (same pruning pattern every layer)
            self.sparse_mlp, self._sparse_proto = SparseMLP.create(
                jax.random.PRNGKey(17), cfg.d_model, cfg.d_ff,
                block=cfg.ffn_block, density=cfg.ffn_density)
        # layer grouping for scans
        if cfg.family == "enc_dec":
            self.groups = [("enc", "attn_bidir", cfg.enc_layers),
                           ("dec", "cross", cfg.dec_layers)]
        elif cfg.layer_pattern:
            n_units = cfg.n_layers // len(cfg.layer_pattern)
            rem = cfg.n_layers - n_units * len(cfg.layer_pattern)
            self.groups = [("units", tuple(cfg.layer_pattern), n_units)]
            if rem:
                self.groups.append(("tail", tuple(cfg.layer_pattern[:rem]), 1))
        else:
            kind = cfg.layer_kind(0)
            self.groups = [("layers", kind, cfg.n_layers)]

    # -- init ---------------------------------------------------------------
    def init(self, key):
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        params: Dict[str, Any] = {
            "embed": layers.embedding_init(keys[0], cfg.padded_vocab, cfg.d_model),
            "final_norm": layers.rmsnorm_init(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = layers.embedding_init(keys[1], cfg.padded_vocab,
                                                      cfg.d_model)
        if cfg.frontend != "none":
            params["frontend"] = layers.dense_init(
                keys[2], cfg.d_model, cfg.d_model)
        kidx = 3
        for gi, (name, kinds, n) in enumerate(self.groups):
            gkey = keys[min(kidx + gi, 7)]

            def one(k):
                if isinstance(kinds, tuple):       # hybrid unit
                    sub = {}
                    for j, kd in enumerate(kinds):
                        sub[f"b{j}"] = _block_init(cfg, jax.random.fold_in(k, j),
                                                   kd, self.sparse_mlp)
                    return sub
                return _block_init(cfg, k, kinds, self.sparse_mlp)

            lkeys = jax.random.split(gkey, n)
            params[name] = jax.vmap(one)(lkeys)
        return params

    # -- quantized serving ----------------------------------------------------
    def quantize(self, params, dtype: str = "int8"):
        """Freeze a trained block-sparse-FFN model for quantized serving.

        Returns ``(model, params)``: a copy of this model whose shared
        :class:`SparseMLP` plans store ``dtype`` payloads (``"int8"``,
        ``"fp8"``, or the per-block-row ``"int8.rowwise"``/
        ``"fp8.rowwise"`` modes), and the matching param tree with every
        layer's fp32 FFN ``blocks`` leaves replaced by quantized payload +
        fp32 ``scales`` leaves in the same layer stacking.  Attention,
        norm, and embedding params pass through unchanged.  The Segment
        kernels dequantize at the fp32 accumulator, so decode runs on the
        low-precision weight fetch the traffic model prices (~4× fewer A
        bytes) without a dequantized weight copy ever materializing.
        """
        if self.sparse_mlp is None:
            raise ValueError(
                "Transformer.quantize requires a block-sparse FFN model "
                "(ModelConfig.ffn_block_sparse=True); dense SwiGLU weights "
                "have no Segment plan to quantize")
        model = copy.copy(self)
        model.sparse_mlp, model._sparse_proto = self.sparse_mlp.quantize(
            self._sparse_proto, dtype)

        new_params = dict(params)
        for (name, kinds, _) in self.groups:
            g = params[name]
            if isinstance(kinds, tuple):
                new_g = {}
                for j in range(len(kinds)):
                    sub = g[f"b{j}"]
                    if _is_sparse_mlp_params(sub):
                        sub = dict(sub)
                        sub["mlp"] = _quantize_mlp_params(sub["mlp"], dtype)
                    new_g[f"b{j}"] = sub
                new_params[name] = new_g
            elif _is_sparse_mlp_params(g):
                new_g = dict(g)
                new_g["mlp"] = _quantize_mlp_params(g["mlp"], dtype)
                new_params[name] = new_g
        return model, new_params

    # -- scanned stacks -------------------------------------------------------
    def _run_group(self, params_g, x, kinds, *, positions, enc_out=None,
                   caches=None, cache_pos=None, collect_cache=False):
        cfg = self.cfg

        def body(carry, inp):
            x, aux = carry
            p_l = inp[0]
            cache_l = inp[1] if caches is not None else None
            if isinstance(kinds, tuple):
                new_c = {}
                for j, kd in enumerate(kinds):
                    sub_c = cache_l[f"b{j}"] if cache_l is not None else None
                    x, a, nc = _block_apply(
                        cfg, p_l[f"b{j}"], x, kd, positions=positions,
                        sparse_mlp=self.sparse_mlp, enc_out=enc_out,
                        cache=sub_c, cache_pos=cache_pos)
                    new_c[f"b{j}"] = nc
                    aux = aux + a
            else:
                x, a, new_c = _block_apply(
                    cfg, p_l, x, kinds, positions=positions,
                    sparse_mlp=self.sparse_mlp, enc_out=enc_out,
                    cache=cache_l, cache_pos=cache_pos)
                aux = aux + a
            return (x, aux), (new_c if collect_cache else 0)

        body_fn = body
        if cfg.remat and caches is None:
            body_fn = jax.checkpoint(body, prevent_cse=False)
        xs = (params_g,) if caches is None else (params_g, caches)
        # NOTE (decode on CPU backend): XLA's bf16-dot emulation hoists f32
        # converts of the per-layer KV-cache slices out of this scan and
        # carries full f32 cache copies in the while tuple. This is a
        # CPU-only artifact (TPU bf16 dots are native); the dry-run measures
        # and subtracts it — see launch/dryrun.py `cpu_artifact_bytes`.
        (x, aux), new_caches = jax.lax.scan(
            body_fn, (x, jnp.zeros((), jnp.float32)), xs)
        return x, aux, (new_caches if collect_cache else None)

    # -- forward (train / prefill logits) -------------------------------------
    def forward(self, params, tokens, vis_embeds=None, enc_embeds=None):
        """tokens: (B, T_text). vis_embeds: (B, Nv, D) for vlm/audio decoder
        prefixes; enc_embeds: (B, T_enc, D) for enc_dec."""
        cfg = self.cfg
        dt = _dtype(cfg)
        x = layers.embedding_apply(params["embed"], tokens).astype(dt)
        if vis_embeds is not None:
            v = layers.dense_apply(params["frontend"], vis_embeds.astype(dt))
            x = jnp.concatenate([v, x], axis=1)
        x = act_constrain(x, "hidden")
        b, t, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(t), (b, t))
        aux_total = jnp.zeros((), jnp.float32)

        enc_out = None
        if cfg.family == "enc_dec":
            e = layers.dense_apply(params["frontend"], enc_embeds.astype(dt))
            ep = jnp.broadcast_to(jnp.arange(e.shape[1]), (b, e.shape[1]))
            enc_out, aux, _ = self._run_group(
                params["enc"], e, "attn_bidir", positions=ep)
            aux_total += aux
            x, aux, _ = self._run_group(params["dec"], x, "cross",
                                        positions=positions, enc_out=enc_out)
            aux_total += aux
        else:
            for (name, kinds, n) in self.groups:
                x, aux, _ = self._run_group(params[name], x, kinds,
                                            positions=positions)
                aux_total += aux
        x = layers.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
        head = params.get("lm_head", params["embed"])
        logits = act_constrain(layers.lm_head_apply(head, x), "logits")
        return logits, aux_total

    def loss_fn(self, params, batch):
        """batch: dict(tokens, targets[, vis_embeds, enc_embeds, mask])."""
        logits, aux = self.forward(
            params, batch["tokens"], vis_embeds=batch.get("vis_embeds"),
            enc_embeds=batch.get("enc_embeds"))
        targets = batch["targets"]
        n_prefix = logits.shape[1] - targets.shape[1]
        if n_prefix > 0:
            logits = logits[:, n_prefix:]
        loss = layers.cross_entropy(logits, targets, batch.get("mask"))
        return loss + 0.01 * aux, {"loss": loss, "aux": aux}

    # -- serving ---------------------------------------------------------------
    def init_cache(self, batch_size: int, max_len: int):
        cfg = self.cfg
        dt = _dtype(cfg)

        def stack(kinds, n):
            if isinstance(kinds, tuple):
                one = {f"b{j}": _block_cache_init(cfg, kd, batch_size, max_len, dt)
                       for j, kd in enumerate(kinds)}
            else:
                one = _block_cache_init(cfg, kinds, batch_size, max_len, dt)
            return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), one)

        return {name: stack(kinds, n) for (name, kinds, n) in self.groups
                if name != "enc"}

    def decode_step(self, params, cache, token, pos, enc_out=None, *,
                    logit_idx=None):
        """token: (B, T) int32 (T=1 decode, T>1 chunked prefill); pos:
        absolute position of token[:, 0] — a shared scalar int32 (lockstep
        decode) or a per-row (B,) int32 vector (continuous batching: every
        slot sits at its own position).

        ``logit_idx``: optional per-row (B,) int32 index into the T axis —
        the logits are gathered at each row's *last valid* token instead of
        ``T-1`` (mixed-length chunked prefill: a row whose prompt ends
        mid-chunk must not sample its first token from padding).

        Returns (logits (B, vocab), new_cache)."""
        cfg = self.cfg
        dt = _dtype(cfg)
        x = layers.embedding_apply(params["embed"], token).astype(dt)
        x = act_constrain(x, "hidden")
        b, t, _ = x.shape
        pos = jnp.asarray(pos, jnp.int32)
        if pos.ndim == 0:
            positions = jnp.broadcast_to((pos + jnp.arange(t))[None, :], (b, t))
        else:
            positions = pos[:, None] + jnp.arange(t)[None, :]
        positions = positions.astype(jnp.int32)
        new_cache = {}
        for (name, kinds, n) in self.groups:
            if name == "enc":
                continue
            x, _, nc = self._run_group(
                params[name], x, kinds, positions=positions, enc_out=enc_out,
                caches=cache[name], cache_pos=pos, collect_cache=True)
            new_cache[name] = nc
        x = layers.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
        # gather each row's output position *before* the lm_head so the
        # (B, T, vocab) prefill logits never materialize
        if logit_idx is None:
            x = x[:, -1:]
        else:
            idx = jnp.broadcast_to(jnp.asarray(logit_idx, jnp.int32), (b,))
            x = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        head = params.get("lm_head", params["embed"])
        logits = layers.lm_head_apply(head, x)
        return logits[:, 0], new_cache
