"""Mixture-of-Experts layer — Segment-scheduled dispatch.

Routing produces the data-dependent block-sparse structure the Segment
dataflow targets (DESIGN.md §4): tokens sort by expert (SELECTA's
shared-operand grouping), oversized groups fold into fixed-capacity buffers
(spatial folding → load balance), and the expert GEMM runs either as

* the **train path**: a batched einsum over (B, E, cap, d) dispatch buffers —
  pure jnp, differentiable, identical FLOPs to a grouped GEMM; or
* the **serve path**: the Pallas grouped kernel (:mod:`repro.kernels.moe_gemm`).

Sharding: dispatch is *per batch row* — the token dim of each dispatch is
local to its dp shard (capacity is enforced per dp-group, the standard
production semantics), so no global gathers/scatters cross devices; the
expert dim is constrained to the model axis (expert parallelism).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.sharding import act_constrain
from . import layers


def moe_init(key, d_model, d_ff, n_experts, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = 1.0 / jnp.sqrt(d_model)
    s_ff = 1.0 / jnp.sqrt(d_ff)
    return {
        "router": layers.dense_init(k1, d_model, n_experts, dtype=dtype),
        "gate": jax.random.normal(k2, (n_experts, d_model, d_ff), dtype) * s_in,
        "up": jax.random.normal(k3, (n_experts, d_model, d_ff), dtype) * s_in,
        "down": jax.random.normal(k4, (n_experts, d_ff, d_model), dtype) * s_ff,
    }


def _dispatch_batched(x, expert, n_exp: int, cap: int):
    """Per-row expert dispatch. x: (B, T, D); expert: (B, T) int32.

    Returns (buf (B, E, cap, D), slot (B, T), keep (B, T)) where
    buf[b, e, c] holds the c-th token of row b routed to expert e (zeros
    beyond each expert's count; overflow beyond ``cap`` dropped)."""
    b, t, d = x.shape
    order = jnp.argsort(expert, axis=-1)                       # (B, T)
    sorted_e = jnp.take_along_axis(expert, order, axis=-1)
    pos_in_e = (jnp.arange(t)[None, :]
                - jax.vmap(lambda se: jnp.searchsorted(se, se, side="left"))(
                    sorted_e))
    keep_sorted = pos_in_e < cap
    slot_sorted = jnp.where(keep_sorted, sorted_e * cap + pos_in_e, n_exp * cap)
    x_sorted = jnp.take_along_axis(x, order[..., None], axis=1)
    buf = jnp.zeros((b, n_exp * cap + 1, d), x.dtype)
    buf = jax.vmap(lambda bu, sl, va: bu.at[sl].set(va))(
        buf, slot_sorted, jnp.where(keep_sorted[..., None], x_sorted, 0))
    # undo the sort for slot/keep so they index original token positions
    inv = jnp.argsort(order, axis=-1)
    slot = jnp.take_along_axis(slot_sorted, inv, axis=-1)
    keep = jnp.take_along_axis(keep_sorted, inv, axis=-1)
    return buf[:, :-1].reshape(b, n_exp, cap, d), slot, keep


def moe_apply(p, x, *, top_k: int, capacity_factor: float = 1.25,
              chunk_rows: int = 128):
    """x: (B, T, D) → (out (B, T, D), aux_loss scalar)."""
    b, t, d = x.shape
    n_exp = p["router"]["w"].shape[1]
    cap = max(1, int(np.ceil(t * capacity_factor / n_exp)))
    logits = layers.dense_apply(p["router"], x.astype(jnp.float32))  # (B,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(logits, top_k)
    gates = jax.nn.softmax(top_vals, axis=-1)                        # (B,T,k)

    # Switch-style load-balance auxiliary loss (over all tokens)
    me = probs.reshape(-1, n_exp).mean(axis=0)
    ce = jnp.zeros(n_exp).at[top_idx[..., 0].reshape(-1)].add(1.0) / (b * t)
    aux = n_exp * jnp.sum(me * ce)

    out = jnp.zeros((b, t, d), jnp.float32)
    for j in range(top_k):
        buf, slot, keep = _dispatch_batched(x, top_idx[..., j], n_exp, cap)
        eb = act_constrain(buf, "expert")                 # (B, E, cap, D)
        h = (jax.nn.silu(jnp.einsum("becd,edf->becf", eb,
                                    p["gate"].astype(x.dtype)))
             * jnp.einsum("becd,edf->becf", eb, p["up"].astype(x.dtype)))
        h = act_constrain(h, "expert")
        y = act_constrain(
            jnp.einsum("becf,efd->becd", h, p["down"].astype(x.dtype)),
            "expert")
        y = y.reshape(b, n_exp * cap, d)
        vals = jax.vmap(lambda yy, sl: yy[jnp.minimum(sl, yy.shape[0] - 1)])(
            y, slot)
        y_tok = jnp.where(keep[..., None], vals, 0.0)
        out = out + y_tok.astype(jnp.float32) * gates[..., j][..., None]
    return out.astype(x.dtype), aux
