"""Core neural layers (pure JAX, pytree params — no flax).

Conventions:
* params are nested dicts of jnp arrays; init fns take a PRNG key;
* activations default to bf16 compute with f32 norms/softmax/loss;
* attention is an IO-aware *chunked* (flash-style) jnp implementation that
  lowers to a lax.scan over KV blocks — memory-safe at 32k+ context and
  differentiable everywhere.  The Pallas kernels in ``repro.kernels`` are the
  TPU-optimized serving path; both are validated against the same oracle.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Dtype = jnp.dtype


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in, d_out, *, bias=False, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p, x):
    y = jnp.einsum("...d,df->...f", x, p["w"].astype(x.dtype))
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def sparse_dense_init(key, d_in, d_out, *, block=64, density=0.25,
                      policy="segment", dtype=jnp.float32):
    """Block-sparse drop-in for :func:`dense_init` via :mod:`repro.api`.

    Returns ``(plan, params)``: the static :class:`~repro.api.SegmentPlan`
    (pass it to :func:`sparse_dense_apply`; it is a pytree, safe to close
    over or thread through jit) and the trainable blocks in the plan's
    storage layout (original BSR block order).

    Both dims must be multiples of ``block`` — the Segment grid is exact,
    so a ragged edge would silently widen the output with untrained
    padding blocks.
    """
    from repro.api import plan_matmul
    from repro.core.formats import BSR
    if d_in % block or d_out % block:
        raise ValueError(f"d_in={d_in} and d_out={d_out} must be multiples "
                         f"of block={block}")
    rng = np.random.default_rng(np.asarray(jax.random.key_data(key))[-1])
    w = BSR.random(rng, (d_out, d_in), (block, block), density,
                   dtype=np.float32)
    plan = plan_matmul(w, policy=policy, with_grad=True)
    scale = 1.0 / np.sqrt(d_in)
    return plan, {"blocks": (plan.lhs_blocks * scale).astype(dtype)}


def sparse_dense_apply(plan, p, x):
    """``x: (..., d_in) → (..., d_out)`` through the Segment SpMM executor."""
    from repro.api import apply_plan
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    y = apply_plan(plan.with_values(p["blocks"]), x2.T).T
    return y.reshape(*shape[:-1], -1).astype(x.dtype)


def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float = 10000.0):
    """x: (..., T, H, D) rotated along D with positions (..., T)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., T, half)
    cos = jnp.cos(angles)[..., None, :]                          # (..., T, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked (flash-style) attention in pure jnp — lax.scan over KV blocks
# ---------------------------------------------------------------------------


def chunked_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                      kv_len=None, chunk=1024):
    """q: (B, Tq, H, D); k/v: (B, Tk, Hkv, D). Returns (B, Tq, H, D) f32-acc.

    Online-softmax over KV chunks: peak memory O(Tq·chunk) per head instead
    of O(Tq·Tk).  ``q_offset`` is the absolute position of q[0]; ``kv_len``
    masks padded keys.  Both accept a shared scalar or a per-row ``(B,)``
    vector (continuous batching: every slot at its own position).
    """
    b, tq, h, d = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    kv_len = tk if kv_len is None else kv_len
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,))
    chunk = min(chunk, tk)
    pad = (-tk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (tk + pad) // chunk
    scale = 1.0 / np.sqrt(d)
    qf = q.astype(jnp.float32) * scale
    q_off = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (b,))
    q_pos = q_off[:, None] + jnp.arange(tq)[None, :]          # (B, Tq)

    # reshape kv to (n_chunks, B, chunk, Hkv, D) for scan
    ks = k.reshape(b, n_chunks, chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, n_chunks, chunk, hkv, d).transpose(1, 0, 2, 3, 4)

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        ci, k_c, v_c = inp
        if rep > 1:
            k_c = jnp.repeat(k_c, rep, axis=2)
            v_c = jnp.repeat(v_c, rep, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, k_c.astype(jnp.float32))
        k_pos = ci * chunk + jnp.arange(chunk)
        mask = (k_pos[None, None, :] < kv_len[:, None, None])  # (B, 1, chunk)
        if causal:
            mask = mask & (k_pos[None, None, :] <= q_pos[..., None])
        if window is not None:
            mask = mask & (k_pos[None, None, :] > q_pos[..., None] - window)
        s = jnp.where(mask[:, None], s, -1e30)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, v_c.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, tq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, tq), jnp.float32)
    a0 = jnp.zeros((b, h, tq, d), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n_chunks), ks, vs))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (GQA + RoPE + optional bias / local window)
# ---------------------------------------------------------------------------


def attention_init(key, d_model, n_heads, n_kv, head_dim, *, qkv_bias=False,
                   dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d_model, n_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wk": dense_init(k2, d_model, n_kv * head_dim, bias=qkv_bias, dtype=dtype),
        "wv": dense_init(k3, d_model, n_kv * head_dim, bias=qkv_bias, dtype=dtype),
        "wo": dense_init(k4, n_heads * head_dim, d_model, dtype=dtype),
    }


def _decode_mask(b, tq, tk, *, q_offset, kv_len, causal, window):
    """(B, Tq, Tk) validity mask; ``q_offset``/``kv_len`` may be shared
    scalars or per-row ``(B,)`` vectors (per-slot positions)."""
    q_off = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (b,))
    kvl = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,))
    q_pos = q_off[:, None] + jnp.arange(tq)[None, :]          # (B, Tq)
    k_pos = jnp.arange(tk)
    mask = k_pos[None, None, :] < kvl[:, None, None]
    if causal:
        mask = mask & (k_pos[None, None, :] <= q_pos[..., None])
    if window is not None:
        mask = mask & (k_pos[None, None, :] > q_pos[..., None] - window)
    return mask


def _direct_attention(q, k, v, *, q_offset, kv_len, causal, window):
    """Unchunked masked attention (decode path, Tq ≤ 8).

    Keeps K/V in their cache dtype and accumulates in f32 via
    ``preferred_element_type`` — an explicit .astype(f32) on the per-layer
    cache slice gets hoisted out of the layer scan by XLA and materializes
    the *entire* stacked cache in f32."""
    b, tq, h, d = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    from repro.sharding import act_constrain
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(k.dtype), k,
                   preferred_element_type=jnp.float32) / np.sqrt(d)
    s = act_constrain(s, "scores_t")   # keep KV timeline sequence-sharded
    mask = _decode_mask(b, tq, tk, q_offset=q_offset, kv_len=kv_len,
                        causal=causal, window=window)
    s = jnp.where(mask[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def _direct_attention_q8(q, kq, ks, vq, vs, *, q_offset, kv_len, causal,
                         window):
    """Decode attention over an int8 KV cache with factored scales.

    q: (B,t,H,D); kq/vq: (B,T,Hkv,D) int8; ks/vs: (B,T,Hkv) f32.
    s = (q·kqᵀ) ⊙ ks  and  out = (p ⊙ vs)·vq — the int8 tensors feed the
    dots directly (native int8×bf16 on TPU), no dequantized copy."""
    from repro.sharding import act_constrain
    b, tq, h, d = q.shape
    tk, hkv = kq.shape[1], kq.shape[2]
    rep = h // hkv
    if rep > 1:
        kq = jnp.repeat(kq, rep, axis=2)
        vq = jnp.repeat(vq, rep, axis=2)
        ks = jnp.repeat(ks, rep, axis=2)
        vs = jnp.repeat(vs, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.bfloat16),
                   kq.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32) / np.sqrt(d)
    s = s * ks.transpose(0, 2, 1)[:, :, None, :]        # column-wise dequant
    s = act_constrain(s, "scores_t")
    mask = _decode_mask(b, tq, tk, q_offset=q_offset, kv_len=kv_len,
                        causal=causal, window=window)
    s = jnp.where(mask[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = p * vs.transpose(0, 2, 1)[:, :, None, :]         # fold v scales into p
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(jnp.bfloat16),
                     vq.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def ring_decode_attention(q, ck, cv, k_pos, pos, window):
    """Attention over a ring-buffer KV cache.

    q: (B,Tq,H,D); ck/cv: (B,W,Hkv,D); k_pos: (B,W) absolute position held
    by each ring slot (may differ per batch row — continuous batching);
    pos: (B,) absolute position of q[:, 0].  Each query attends only to
    slots in its own (q_pos-window, q_pos] — causal within a multi-token
    write, and slots still holding a previous occupant's junk (k_pos ahead
    of this row's timeline or negative) are masked out."""
    b, tq, h, d = q.shape
    hkv = ck.shape[2]
    rep = h // hkv
    if rep > 1:
        ck = jnp.repeat(ck, rep, axis=2)
        cv = jnp.repeat(cv, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   ck.astype(jnp.float32)) / np.sqrt(d)
    q_pos = pos[:, None] + jnp.arange(tq)[None, :]            # (B, Tq)
    valid = ((k_pos[:, None, :] <= q_pos[..., None])
             & (k_pos[:, None, :] > q_pos[..., None] - window)
             & (k_pos[:, None, :] >= 0))                       # (B, Tq, W)
    s = jnp.where(valid[:, None], s, -1e30)
    p_attn = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p_attn, cv.astype(jnp.float32))
    return out.astype(q.dtype)


def kv_cache_write(buf, new, pos):
    """Write ``new`` (B, t, …) into ``buf`` (B, T, …) at time-axis offset
    ``pos`` — a shared scalar (lockstep decode: one contiguous block write)
    or a per-row ``(B,)`` vector (continuous batching: every slot writes at
    its own position; vmapped dynamic-update, one row-local write each)."""
    if getattr(pos, "ndim", 0):
        return jax.vmap(
            lambda c, n, p: jax.lax.dynamic_update_slice_in_dim(c, n, p, 0)
        )(buf, new, pos)
    return jax.lax.dynamic_update_slice_in_dim(buf, new, pos, axis=1)


def attention_apply(p, x, *, n_heads, n_kv, head_dim, positions,
                    causal=True, window=None, rope_theta=10000.0,
                    kv_ctx=None, cache=None, cache_pos=None, chunk=1024,
                    ring=False):
    """Self-attention (or cross-attention when ``kv_ctx`` is given).

    ``cache``: optional dict(k, v) of (B, T_max, n_kv, hd) — decode mode:
    writes current kv at ``cache_pos`` and attends over the whole cache.
    ``cache_pos`` is a shared scalar or a per-row ``(B,)`` vector — the
    latter is the continuous-batching path where every slot sits at its own
    absolute position.  With ``ring=True`` the cache is a window-sized ring
    buffer (local attention decode: O(window) memory at any context
    length).  Returns (out, new_cache).
    """
    from repro.sharding import act_constrain
    b, t, _ = x.shape
    q = act_constrain(
        dense_apply(p["wq"], x).reshape(b, t, n_heads, head_dim), "heads")
    src = x if kv_ctx is None else kv_ctx
    k = act_constrain(
        dense_apply(p["wk"], src).reshape(b, src.shape[1], n_kv, head_dim),
        "heads")
    v = act_constrain(
        dense_apply(p["wv"], src).reshape(b, src.shape[1], n_kv, head_dim),
        "heads")
    if kv_ctx is None and rope_theta:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    new_cache = None
    if cache is not None and ring:
        w = cache["k"].shape[1]
        pos_v = jnp.broadcast_to(jnp.asarray(cache_pos, jnp.int32), (b,))
        # scatter each token into its ring slot (handles per-row positions
        # and writes that wrap around the ring, which a block
        # dynamic_update_slice would clamp at the edge)
        slot_idx = jnp.mod(pos_v[:, None] + jnp.arange(t)[None, :], w)
        rows = jnp.arange(b)[:, None]
        ck = cache["k"].at[rows, slot_idx].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[rows, slot_idx].set(v.astype(cache["v"].dtype))
        new_cache = {"k": ck, "v": cv}
        last = pos_v + (t - 1)
        idx = jnp.arange(w)
        k_pos = last[:, None] - jnp.mod(last[:, None] - idx[None, :], w)
        out = ring_decode_attention(q, ck, cv, k_pos, pos_v, window or w)
    elif cache is not None and "k_s" in cache:
        # int8-quantized KV cache (beyond-paper, see EXPERIMENTS §Perf):
        # per-position, per-head symmetric scales. Halves the decode
        # memory-bound roofline term (the KV read is the floor). Scales
        # factor OUT of both attention einsums — column-wise for QK^T,
        # folded into p for PV — so no dequantized cache copy is ever
        # materialized.
        def quant(x_):
            scale = jnp.max(jnp.abs(x_.astype(jnp.float32)), axis=-1) / 127.0
            scale = jnp.maximum(scale, 1e-8)
            q_ = jnp.clip(jnp.round(x_.astype(jnp.float32) / scale[..., None]),
                          -127, 127).astype(jnp.int8)
            return q_, scale
        kq, ks_new = quant(k)
        vq, vs_new = quant(v)
        ck = kv_cache_write(cache["k"], kq, cache_pos)
        cv = kv_cache_write(cache["v"], vq, cache_pos)
        cks = kv_cache_write(cache["k_s"], ks_new, cache_pos)
        cvs = kv_cache_write(cache["v_s"], vs_new, cache_pos)
        new_cache = {"k": ck, "v": cv, "k_s": cks, "v_s": cvs}
        if t > 8:
            # a guard, not an assert: serving stacks routinely run under
            # ``python -O``, which strips asserts — and a silently oversized
            # query here would attend with garbage positions, not crash
            raise ValueError(
                f"int8 KV cache path supports decode-sized queries (t <= 8), "
                f"got t={t}; chunk the prefill (Engine does this via "
                f"prefill_buckets) or use the fp32 cache for long queries")
        out = _direct_attention_q8(q, ck, cks, cv, cvs,
                                   q_offset=cache_pos, kv_len=cache_pos + t,
                                   causal=causal, window=window)
    elif cache is not None:
        # decode: insert at cache_pos (per-row or shared), attend over the
        # full cache masked to each row's own valid length
        ck = kv_cache_write(cache["k"], k.astype(cache["k"].dtype), cache_pos)
        cv = kv_cache_write(cache["v"], v.astype(cache["v"].dtype), cache_pos)
        new_cache = {"k": ck, "v": cv}
        if t <= 8:
            # single-token decode: direct masked attention — scores are
            # (B, H, t, T): tiny, and the T axis keeps its sequence-parallel
            # sharding (the chunked scan's reshape would force a reshard)
            out = _direct_attention(q, ck, cv, q_offset=cache_pos,
                                    kv_len=cache_pos + t, causal=causal,
                                    window=window)
        else:
            out = chunked_attention(q, ck, cv, causal=causal, window=window,
                                    q_offset=cache_pos, kv_len=cache_pos + t,
                                    chunk=chunk)
    else:
        out = chunked_attention(q, k, v, causal=causal and kv_ctx is None,
                                window=window, q_offset=0, chunk=chunk)
    out = out.reshape(b, t, n_heads * head_dim)
    return dense_apply(p["wo"], out), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "up": dense_init(k1, d_model, d_ff, dtype=dtype),
        "gate": dense_init(k2, d_model, d_ff, dtype=dtype),
        "down": dense_init(k3, d_ff, d_model, dtype=dtype),
    }


def swiglu_apply(p, x):
    from repro.sharding import act_constrain
    h = jax.nn.silu(act_constrain(dense_apply(p["gate"], x), "ffn")) \
        * act_constrain(dense_apply(p["up"], x), "ffn")
    return dense_apply(p["down"], h)


# ---------------------------------------------------------------------------
# embeddings & loss
# ---------------------------------------------------------------------------


def embedding_init(key, vocab, d_model, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, d_model), dtype) * 0.02}


def embedding_apply(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def lm_head_apply(p, x):
    """Tied or untied head: x (B,T,D) @ table^T → (B,T,V)."""
    return jnp.einsum("btd,vd->btv", x, p["table"].astype(x.dtype))


def cross_entropy(logits, targets, mask=None):
    """Mean token NLL, numerically stable, vocab-shard friendly.

    Uses one-hot contraction (psum-friendly when vocab is sharded) rather
    than take_along_axis (which would gather across shards); the f32 logits
    and the one-hot both carry explicit vocab-sharded constraints so the
    (B, T, V) intermediates never materialize unsharded.
    """
    from repro.sharding import act_constrain
    logits = act_constrain(logits.astype(jnp.float32), "logits")
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = act_constrain(
        jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32),
        "logits")
    true_logit = jnp.sum(logits * onehot, axis=-1)
    nll = lse - true_logit
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
