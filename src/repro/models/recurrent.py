"""Recurrent blocks: RG-LRU (Griffin/RecurrentGemma) and RWKV-6 (Finch).

These are the attention-free layers of the hybrid/SSM architectures.  The
Segment dataflow does not apply to the recurrences themselves (DESIGN.md
§Arch-applicability); training uses jnp scans, serving can use the fused
Pallas kernel (:mod:`repro.kernels.rg_lru`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (Griffin)
# ---------------------------------------------------------------------------

_CONV_W = 4


def rglru_block_init(key, d_model, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    d = d_model
    return {
        "in_x": layers.dense_init(ks[0], d, d, dtype=dtype),
        "in_g": layers.dense_init(ks[1], d, d, dtype=dtype),
        "conv": jax.random.normal(ks[2], (_CONV_W, d), dtype) * 0.2,
        "a_gate": layers.dense_init(ks[3], d, d, dtype=dtype),
        "x_gate": layers.dense_init(ks[4], d, d, dtype=dtype),
        "a_param": jax.random.uniform(ks[5], (d,), dtype, 0.5, 2.0),
        "out": layers.dense_init(jax.random.fold_in(key, 7), d, d, dtype=dtype),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv, width 4. x: (B,T,D), w: (4,D).
    state: (B, 3, D) trailing context for decode. Returns (y, new_state)."""
    b, t, d = x.shape
    if state is None:
        state = jnp.zeros((b, _CONV_W - 1, d), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + t] * w[i].astype(x.dtype) for i in range(_CONV_W))
    return y, xp[:, -(_CONV_W - 1):]


def rglru_block_apply(p, x, state=None, c: float = 8.0):
    """x: (B,T,D). state: dict(conv, h) for decode. → (out, new_state)."""
    xb = layers.dense_apply(p["in_x"], x)
    gb = layers.dense_apply(p["in_g"], x)
    conv_state = state["conv"] if state is not None else None
    xb, new_conv = _causal_conv(xb, p["conv"], conv_state)
    ag = layers.dense_apply(p["a_gate"], xb)
    xg = layers.dense_apply(p["x_gate"], xb)
    log_a = (-c * jax.nn.softplus(p["a_param"].astype(jnp.float32))[None, None]
             * jax.nn.sigmoid(ag.astype(jnp.float32)))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    gated = beta * (jax.nn.sigmoid(xg.astype(jnp.float32)) * xb.astype(jnp.float32))
    h0 = (state["h"] if state is not None
          else jnp.zeros((x.shape[0], x.shape[2]), jnp.float32))

    def step(h, inp):
        a_t, g_t = inp
        h = a_t * h + g_t
        return h, h

    hT, hs = jax.lax.scan(step, h0, (a.transpose(1, 0, 2), gated.transpose(1, 0, 2)))
    hs = hs.transpose(1, 0, 2).astype(x.dtype)
    out = layers.dense_apply(p["out"], hs * jax.nn.gelu(gb))
    return out, {"conv": new_conv, "h": hT}


def rglru_block_state(b, d_model, dtype=jnp.float32):
    return {"conv": jnp.zeros((b, _CONV_W - 1, d_model), dtype),
            "h": jnp.zeros((b, d_model), jnp.float32)}


# ---------------------------------------------------------------------------
# RWKV-6 (Finch) time-mix + channel-mix
# ---------------------------------------------------------------------------


def rwkv_block_init(key, d_model, n_heads, d_ff, dtype=jnp.float32):
    ks = jax.random.split(key, 12)
    d = d_model
    hd = d // n_heads
    lora = max(32, d // 16)
    return {
        "mix": jax.random.uniform(ks[0], (5, d), dtype, 0.0, 1.0),  # r,k,v,w,g
        "wr": layers.dense_init(ks[1], d, d, dtype=dtype),
        "wk": layers.dense_init(ks[2], d, d, dtype=dtype),
        "wv": layers.dense_init(ks[3], d, d, dtype=dtype),
        "wg": layers.dense_init(ks[4], d, d, dtype=dtype),
        "w_lora_a": jax.random.normal(ks[5], (d, lora), dtype) * 0.01,
        "w_lora_b": jax.random.normal(ks[6], (lora, d), dtype) * 0.01,
        "w_bias": jnp.zeros((d,), dtype) - 4.0,   # slow default decay
        "u": jax.random.normal(ks[7], (n_heads, hd), dtype) * 0.1,
        "wo": layers.dense_init(ks[8], d, d, dtype=dtype),
        "ln_x": layers.rmsnorm_init(d, dtype),
        # channel mix
        "cm_mix": jax.random.uniform(ks[9], (2, d), dtype, 0.0, 1.0),
        "cm_k": layers.dense_init(ks[10], d, d_ff, dtype=dtype),
        "cm_v": layers.dense_init(ks[11], d_ff, d, dtype=dtype),
    }


def _token_shift(x, prev):
    """shifted[t] = x[t-1]; prev fills t=0. x: (B,T,D), prev: (B,D)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def rwkv_time_mix(p, x, n_heads, state):
    """x: (B,T,D); state: dict(shift (B,D), S (B,H,hd,hd)). → (out, state)."""
    b, t, d = x.shape
    hd = d // n_heads
    xs = _token_shift(x, state["shift"])
    mix = p["mix"].astype(x.dtype)
    def mixed(i):
        return x * mix[i][None, None] + xs * (1 - mix[i])[None, None]
    r = layers.dense_apply(p["wr"], mixed(0)).reshape(b, t, n_heads, hd)
    k = layers.dense_apply(p["wk"], mixed(1)).reshape(b, t, n_heads, hd)
    v = layers.dense_apply(p["wv"], mixed(2)).reshape(b, t, n_heads, hd)
    g = layers.dense_apply(p["wg"], mixed(4))
    # data-dependent decay (Finch): low-rank modulation of the decay bias
    w_raw = (p["w_bias"].astype(jnp.float32)[None, None]
             + jnp.tanh(mixed(3).astype(jnp.float32) @ p["w_lora_a"].astype(jnp.float32))
             @ p["w_lora_b"].astype(jnp.float32))
    # decay in (0,1): w = exp(-softplus(w_raw)) — bounded, data-dependent
    log_w = -jax.nn.softplus(w_raw)
    log_w = log_w.reshape(b, t, n_heads, hd)

    u = p["u"].astype(jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, lw_t = inp                       # (B,H,hd)
        kv = jnp.einsum("bhi,bhj->bhij", k_t.astype(jnp.float32),
                        v_t.astype(jnp.float32))
        out = jnp.einsum("bhi,bhij->bhj", r_t.astype(jnp.float32),
                         S + u[None, :, :, None] * kv)
        S = jnp.exp(lw_t)[..., None] * S + kv
        return S, out

    S_T, outs = jax.lax.scan(
        step, state["S"],
        (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
         v.transpose(1, 0, 2, 3), log_w.transpose(1, 0, 2, 3)))
    outs = outs.transpose(1, 0, 2, 3).reshape(b, t, d).astype(x.dtype)
    outs = layers.rmsnorm_apply(p["ln_x"], outs) * jax.nn.silu(g)
    out = layers.dense_apply(p["wo"], outs)
    return out, {"shift": x[:, -1], "S": S_T}


def rwkv_channel_mix(p, x, state):
    xs = _token_shift(x, state)
    mix = p["cm_mix"].astype(x.dtype)
    xk = x * mix[0][None, None] + xs * (1 - mix[0])[None, None]
    h = jnp.square(jax.nn.relu(layers.dense_apply(p["cm_k"], xk)))
    return layers.dense_apply(p["cm_v"], h), x[:, -1]


def rwkv_block_state(b, d_model, n_heads, dtype=jnp.float32):
    hd = d_model // n_heads
    return {"shift": jnp.zeros((b, d_model), dtype),
            "S": jnp.zeros((b, n_heads, hd, hd), jnp.float32),
            "cm_shift": jnp.zeros((b, d_model), dtype)}
