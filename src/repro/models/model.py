"""Model construction + abstract input specs for every (arch × shape) cell."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from .transformer import Transformer


def build_model(cfg: ModelConfig) -> Transformer:
    return Transformer(cfg)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of the cell.

    The modality frontends are stubs per the assignment: ``vis_embeds`` /
    ``enc_embeds`` are precomputed patch/frame embeddings.
    """
    b = shape.global_batch
    t = shape.seq_len
    i32 = jnp.int32
    f32 = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    if shape.kind == "decode":
        return {
            "token": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
        }

    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "vlm":
        nv = cfg.n_frontend_tokens
        specs["vis_embeds"] = jax.ShapeDtypeStruct((b, nv, cfg.d_model), f32)
        t_text = t - nv
    elif cfg.family == "enc_dec":
        specs["enc_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_frontend_tokens, cfg.d_model), f32)
        t_text = t
    else:
        t_text = t
    specs["tokens"] = jax.ShapeDtypeStruct((b, t_text), i32)
    if shape.kind == "train":
        specs["targets"] = jax.ShapeDtypeStruct((b, t_text), i32)
    return specs


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Abstract decode-cache pytree (no allocation) via eval_shape."""
    model = build_model(cfg)
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))


def abstract_params(cfg: ModelConfig):
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
