"""Unified analytical cost model for the schedule search.

One candidate schedule's predicted wall time is::

    cost_us = traffic_bytes / bytes_per_us + steps * step_us

— a two-term roofline: the revisiting-model HBM bytes of the schedule
(lane-aware, pipeline-aware; see :func:`repro.core.schedule.lane_traffic_spmm`)
over an effective bandwidth, plus a per-grid-step overhead term that prices
grid launch/bookkeeping.  Imbalance and padding need no separate penalty
knob: pads occupy grid steps (``steps`` counts the *padded* lane length) and
move no bytes, so an imbalanced lane split pays exactly its idle steps.

``lane_parallel`` switches the step count's execution semantics:

* ``True`` — lanes occupy parallel grid dimensions that real hardware runs
  concurrently; a step costs one unit regardless of ``n_lanes``.
* ``False`` — the interpret backend (and any fully sequential executor)
  runs the whole grid serially; steps scale with ``n_lanes``.

``legacy_factor`` scales the whole cost for ``pipeline=False`` candidates:
the legacy auto-pipelined kernels execute the same schedule through a
different (slower, in interpret mode) data path, which bytes and steps
alone cannot express.

The two shipped defaults were fixed once against ``BENCH_kernels.json``
interpret timings (see ``benchmarks/kernel_bench.py::autotune_sweep``, which
re-fits and reports the coefficients on every run so drift is visible):
interpret wall time tracks bytes at a couple of KB/us with ~10 us of
emulation overhead per grid step; the TPU model uses ~800 GB/s HBM and
sub-microsecond step overhead.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Two-coefficient roofline cost model (see module docstring)."""

    bytes_per_us: float          # effective HBM bandwidth, bytes / microsecond
    step_us: float               # per-grid-step overhead, microseconds
    lane_parallel: bool = True   # False: lanes execute sequentially
    legacy_factor: float = 1.0   # cost multiplier for pipeline=False plans
    # fraction of one step's overhead that prefetch="cross_pass" saves at
    # each of the (n_tiles_n - 1) pass boundaries: real hardware overlaps
    # the boundary pipeline drain with the previous pass's tail compute
    # (1.0), while the sequential interpreter replays every copy inline
    # and saves nothing (0.0) — so prefetch never wins the interpret
    # objective on a phantom credit
    prefetch_step_credit: float = 0.0

    def steps(self, *, n_lanes: int, lane_len: int, unroll: int,
              n_tiles_n: int = 1) -> float:
        """Grid steps one kernel launch executes for this schedule shape.

        ``lane_len`` is the *padded* per-lane item count (a multiple of
        ``unroll``); each grid step retires ``unroll`` items of one lane
        for one N tile."""
        per_lane = (lane_len / max(1, unroll)) * max(1, n_tiles_n)
        return per_lane * (n_lanes if not self.lane_parallel else 1)

    def cost_us(self, *, traffic_bytes: float, n_lanes: int, lane_len: int,
                unroll: int, n_tiles_n: int = 1,
                pipelined: bool = True, prefetch: bool = False) -> float:
        base = (traffic_bytes / self.bytes_per_us
                + self.steps(n_lanes=n_lanes, lane_len=lane_len,
                             unroll=unroll, n_tiles_n=n_tiles_n)
                * self.step_us)
        if prefetch and pipelined and n_tiles_n > 1:
            # cross-pass prefetch hides one boundary drain per N-tile
            # transition (worth a step_us fraction set by the model)
            base -= (n_tiles_n - 1) * self.step_us * self.prefetch_step_credit
        return base if pipelined else base * self.legacy_factor


def calibrate(samples: Iterable[Tuple[float, float, float]],
              lane_parallel: bool = False) -> CostModel:
    """Fit ``(bytes_per_us, step_us)`` from measured ``(bytes, steps, us)``
    triples by non-negative least squares on ``us ≈ bytes/bw + steps·c``.

    Solves the 2×2 normal equations for ``(1/bw, c)`` and clamps each
    coefficient at a small positive floor — a degenerate sample set (all
    bytes equal, or all steps equal) must still yield a usable monotone
    model, not a division by zero or a negative bandwidth that would
    invert the ranking."""
    rows: Sequence[Tuple[float, float, float]] = [
        (float(b), float(s), float(t)) for b, s, t in samples]
    if not rows:
        raise ValueError("calibrate() needs at least one (bytes, steps, us) "
                         "sample")
    # normal equations for least squares on [bytes, steps] @ [inv_bw, c] = us
    sbb = sum(b * b for b, _, _ in rows)
    sss = sum(s * s for _, s, _ in rows)
    sbs = sum(b * s for b, s, _ in rows)
    sbt = sum(b * t for b, _, t in rows)
    sst = sum(s * t for _, s, t in rows)
    det = sbb * sss - sbs * sbs
    if abs(det) > 1e-12 * max(1.0, sbb) * max(1.0, sss):
        inv_bw = (sbt * sss - sst * sbs) / det
        c = (sst * sbb - sbt * sbs) / det
    else:
        # rank-deficient: attribute everything to whichever axis varies
        inv_bw = sbt / sbb if sbb > 0 else 0.0
        c = sst / sss if sss > 0 else 0.0
    inv_bw = max(inv_bw, 1e-12)
    c = max(c, 1e-9)
    return CostModel(bytes_per_us=1.0 / inv_bw, step_us=c,
                     lane_parallel=lane_parallel)


#: compiled-target model: ~800 GB/s effective HBM, 0.5 us per grid step,
#: lanes concurrent.  Not yet calibrated against real-device timings (no
#: accelerator in CI) — the coefficients set plausible relative weights.
DEFAULT_TPU = CostModel(bytes_per_us=8.0e5, step_us=0.5, lane_parallel=True,
                        prefetch_step_credit=1.0)

#: interpret-backend model, fixed against BENCH_kernels.json timings
#: (autotune_sweep refits and reports both coefficient sets every run):
#: the interpreter streams ~2 KB/us and pays ~10 us of emulation per grid
#: step, and the whole grid — lanes included — runs sequentially.  The
#: legacy auto-pipelined kernels (pipeline=False plans) emulate ~4x
#: slower still — 2*unroll BlockSpec streams cost far more than the
#: explicit pipeline's two ANY operands — so a legacy candidate must cut
#: modeled cost 4x before it can win the interpret objective.
DEFAULT_INTERPRET = CostModel(bytes_per_us=2.0e3, step_us=10.0,
                              lane_parallel=False, legacy_factor=4.0)
