"""Analytical schedule search + adaptive dataflow selection.

:func:`autotune_matmul` sweeps the knob grid — ``fold_len`` × ``n_lanes`` ×
``unroll`` × ``bn`` × ``pipeline`` × ``prefetch`` — across every registered
schedule policy
and scores each candidate with the unified :class:`~repro.tune.cost.CostModel`
(lane-aware revisiting-model traffic bytes + a per-grid-step overhead term;
imbalance and padding are priced structurally through the padded lane
length).  Nothing executes during the search: candidates are priced from the
host-side schedule arrays, statically rejected against the closed-form VMEM
budget (:func:`repro.analysis.budget.spmm_vmem_bytes`), and the ranked
winner is then built once and gated through
:func:`repro.analysis.verify_plan(level="full")` plus
:func:`repro.analysis.budget.check_plan_vmem` before it is declared — a
candidate that fails either static check falls through to the runner-up.

Dataflow selection rides on top: the registered static policies expose
closed-form ``cost_hint`` estimators (see
:func:`repro.sim.baselines.dataflow_estimates`), the dynamic ``segment``
policy is priced by building its schedule, and the analytic ``"inner"``
dataflow competes for comparison only — when it wins on paper the tuner
falls back to the best *dispatchable* policy and counts a
``dataflow_fallbacks`` tick in :func:`repro.api.plan_cache_stats`.

Winning schedules are cached by a pattern fingerprint (pattern bytes +
bucketed dense-N hint + search configuration), so repeat patterns pay zero
search cost; the cache empties together with the plan cache on
:func:`repro.api.clear_plan_cache`.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Optional, Tuple

import numpy as np

from repro.analysis.budget import (DEFAULT_VMEM_LIMIT_BYTES, check_plan_vmem,
                                   spgemm_vmem_bytes, spmm_vmem_bytes)
from repro.analysis.invariants import verify_plan
from repro.core.formats import BSR
from repro.core.policies import available_policies, get_policy
from repro.core.schedule import (build_spgemm_schedule, build_spmm_schedule,
                                 finalize_schedule, lane_select,
                                 lane_traffic_spgemm, lane_traffic_spmm,
                                 partition_lanes)
from repro.sim.baselines import dataflow_estimates

from .cost import DEFAULT_INTERPRET, DEFAULT_TPU, CostModel

#: plan ``block_dtype`` names → numpy-ish dtype names the VMEM formulas take
_VMEM_DTYPE = {"fp32": "float32", "int8": "int8", "fp8": "float8_e4m3fn"}


def _vmem_dtype(block_dtype: str) -> str:
    from repro.core.formats import quant_base_dtype
    return _VMEM_DTYPE[quant_base_dtype(block_dtype)]


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the knob grid: a (dataflow, schedule-shape) choice."""

    policy: str
    fold_len: Optional[int]
    n_lanes: int
    unroll: int
    bn: int
    pipeline: bool
    prefetch: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """Knob axes the search sweeps.  The default space always contains the
    planner's default point (``segment``, no fold, 1 lane, unroll 1,
    ``bn=512``, pipelined), so the winner can never be worse than the
    default under the model being optimized.  ``policies=None`` sweeps
    every registered policy."""

    fold_lens: Tuple[Optional[int], ...] = (None, 8)
    n_lanes: Tuple[int, ...] = (1, 2, 4)
    unrolls: Tuple[int, ...] = (1, 2)
    bns: Tuple[int, ...] = (128, 512)
    pipelines: Tuple[bool, ...] = (True, False)
    prefetches: Tuple[Optional[str], ...] = (None, "cross_pass")
    policies: Optional[Tuple[str, ...]] = None


@dataclasses.dataclass(frozen=True)
class Scored:
    """A feasible candidate with its model price."""

    candidate: Candidate
    cost_us: float
    traffic: Tuple[Tuple[str, float], ...]   # frozen lane_traffic dict
    lane_len: int                            # padded per-lane items
    n_tiles_n: int
    vmem_bytes: int

    @property
    def traffic_total(self) -> float:
        return dict(self.traffic)["total"]


@dataclasses.dataclass
class TuneResult:
    """Outcome of one schedule search (possibly served from the cache)."""

    best: Scored
    candidates: Tuple[Scored, ...]           # ranked, best first
    dataflow_scores: Dict[str, float]        # analytic bytes per dataflow
    dataflow_choice: str                     # analytically best dataflow
    dataflow_dispatched: str                 # ...the dispatchable one used
    objective: str
    n_rejected_vmem: int
    from_cache: bool = False

    def plan_kwargs(self) -> Dict[str, object]:
        """Keyword arguments that make :func:`repro.api.plan_matmul` build
        the winning schedule."""
        c = self.best.candidate
        return dict(policy=c.policy, fold_len=c.fold_len, n_lanes=c.n_lanes,
                    unroll=c.unroll, pipeline=c.pipeline, bn_hint=c.bn,
                    prefetch=c.prefetch)


#: fingerprint → TuneResult; cleared by repro.api.clear_plan_cache
_SEARCH_CACHE: Dict[str, TuneResult] = {}


def _pin(pins: Dict[str, object], key: str, axis: tuple) -> tuple:
    """An explicitly pinned knob collapses its axis to the pinned value."""
    if key in pins:
        return (pins[key],)
    return axis


def _resolve_model(objective, cost_model) -> Tuple[CostModel, str]:
    if cost_model is not None:
        return cost_model, "custom"
    if isinstance(objective, CostModel):
        return objective, "custom"
    if objective == "tpu":
        return DEFAULT_TPU, "tpu"
    if objective == "interpret":
        return DEFAULT_INTERPRET, "interpret"
    raise ValueError(f"objective must be 'tpu', 'interpret' or a CostModel, "
                     f"got {objective!r}")


def _search_key(kind: str, mats, n_bucket: Optional[int], with_grad: bool,
                block_dtype: str, space: SearchSpace, model: CostModel,
                objective: str, limit: int, pins: Dict[str, object]) -> str:
    from repro.api.planner import _pattern_bytes
    h = hashlib.sha1()
    h.update(repr((kind, n_bucket, with_grad, block_dtype,
                   dataclasses.astuple(space),
                   dataclasses.astuple(model),
                   objective, limit, tuple(sorted(pins.items())))).encode())
    for m in mats:
        _pattern_bytes(h, m)
    return h.hexdigest()


def _rank_key(s: Scored, policy_order: Tuple[str, ...]):
    """Total order on scored candidates: model cost, then traffic bytes,
    then every tie broken toward the planner's default point (segment
    first, fewer lanes, smaller unroll, no fold, no prefetch, pipelined,
    wider bn)."""
    c = s.candidate
    return (s.cost_us, s.traffic_total,
            policy_order.index(c.policy) if c.policy in policy_order
            else len(policy_order),
            c.n_lanes, c.unroll,
            c.fold_len is not None, c.fold_len or 0,
            c.prefetch is not None,
            not c.pipeline, -c.bn)


def _score_spmm(a: BSR, hint: int, block_dtype: str, model: CostModel,
                space: SearchSpace, limit: int, pins: Dict[str, object]):
    from repro.api.executor import pick_bn
    from repro.api.planner import _quantize_a_traffic
    bm, bk = a.block_shape
    policies = _pin(pins, "policy",
                    space.policies or available_policies())
    scored, rejected = [], 0
    for policy in policies:
        pol = get_policy(policy)
        folds = (_pin(pins, "fold_len", space.fold_lens)
                 if pol.supports_fold else (None,))
        for fold in folds:
            sched = build_spmm_schedule(a, policy=policy, fold_len=fold)
            fin = finalize_schedule(sched.seg_start, sched.m,
                                    n_slots=sched.n_m_blocks)
            for lanes in _pin(pins, "n_lanes", space.n_lanes):
                for un in _pin(pins, "unroll", space.unrolls):
                    layout = partition_lanes(
                        sched.m, lanes, unroll=un, policy=policy,
                        seg_start=sched.seg_start, seg_write=sched.seg_write,
                        accum_prev=fin.accum_prev)
                    lane_m = lane_select(layout, sched.m)
                    lane_k = lane_select(layout, sched.k)
                    ss = lane_select(layout, sched.seg_start, zero_pads=True)
                    valid = layout.valid.reshape(-1)
                    for pipe in _pin(pins, "pipeline", space.pipelines):
                        # cross-pass prefetch only exists on the explicit
                        # DMA pipeline; the legacy path sweeps prefetch=None
                        pfs = (_pin(pins, "prefetch", space.prefetches)
                               if pipe else (None,))
                        for pf in pfs:
                            traffic = _quantize_a_traffic(lane_traffic_spmm(
                                lane_m, lane_k, ss, valid, layout.n_lanes,
                                bm, bk, hint, unroll=un, pipeline=pipe,
                                prefetch=pf),
                                block_dtype, bm, bk)
                            for bn in _pin(pins, "bn", space.bns):
                                bn_eff, pad = pick_bn(max(1, hint), bn)
                                n_tiles = (max(1, hint) + pad) // bn_eff
                                vbytes = spmm_vmem_bytes(
                                    bm=bm, bk=bk, bn=bn_eff, unroll=un,
                                    block_dtype=_vmem_dtype(block_dtype),
                                    quantized=block_dtype != "fp32",
                                    rowwise=block_dtype.endswith(".rowwise"),
                                    pipelined=pipe)
                                if vbytes > limit:
                                    rejected += 1
                                    continue
                                cost = model.cost_us(
                                    traffic_bytes=traffic["total"],
                                    n_lanes=layout.n_lanes,
                                    lane_len=layout.lane_len, unroll=un,
                                    n_tiles_n=n_tiles, pipelined=pipe,
                                    prefetch=pf is not None)
                                scored.append(Scored(
                                    Candidate(policy, fold, lanes, un, bn,
                                              pipe, pf),
                                    cost, tuple(sorted(traffic.items())),
                                    layout.lane_len, n_tiles, vbytes))
    return scored, rejected, tuple(policies)


def _score_spgemm(a: BSR, b: BSR, block_dtype: str, model: CostModel,
                  space: SearchSpace, limit: int, pins: Dict[str, object]):
    from repro.api.planner import _quantize_spgemm_traffic
    bm, bk = a.block_shape
    bn = b.block_shape[1]   # SpGEMM's N tile is B's block width — not a knob
    policies = _pin(pins, "policy",
                    space.policies or available_policies())
    scored, rejected = [], 0
    for policy in policies:
        pol = get_policy(policy)
        folds = (_pin(pins, "fold_len", space.fold_lens)
                 if pol.supports_fold else (None,))
        for fold in folds:
            sched = build_spgemm_schedule(a, b, policy=policy, fold_len=fold)
            fin = finalize_schedule(sched.seg_start, sched.c_idx)
            for lanes in _pin(pins, "n_lanes", space.n_lanes):
                for un in _pin(pins, "unroll", space.unrolls):
                    layout = partition_lanes(
                        sched.c_idx, lanes, unroll=un, policy=policy,
                        seg_start=sched.seg_start, seg_write=sched.seg_write,
                        accum_prev=fin.accum_prev)
                    lane_a = lane_select(layout, sched.a_idx)
                    lane_b = lane_select(layout, sched.b_idx)
                    lane_c = lane_select(layout, sched.c_idx)
                    ss = lane_select(layout, sched.seg_start, zero_pads=True)
                    valid = layout.valid.reshape(-1)
                    for pipe in _pin(pins, "pipeline", space.pipelines):
                        traffic = _quantize_spgemm_traffic(lane_traffic_spgemm(
                            lane_a, lane_b, lane_c, ss, valid, layout.n_lanes,
                            bm, bk, bn, unroll=un, pipeline=pipe),
                            block_dtype, bm, bk, bn)
                        vbytes = spgemm_vmem_bytes(
                            bm=bm, bk=bk, bn=bn, unroll=un,
                            block_dtype=_vmem_dtype(block_dtype),
                            quant_a=block_dtype != "fp32",
                            quant_b=block_dtype != "fp32",
                            rowwise=block_dtype.endswith(".rowwise"),
                            pipelined=pipe)
                        if vbytes > limit:
                            rejected += 1
                            continue
                        cost = model.cost_us(
                            traffic_bytes=traffic["total"],
                            n_lanes=layout.n_lanes,
                            lane_len=layout.lane_len, unroll=un, n_tiles_n=1,
                            pipelined=pipe)
                        scored.append(Scored(
                            Candidate(policy, fold, lanes, un, bn, pipe,
                                      pins.get("prefetch") if pipe else None),
                            cost, tuple(sorted(traffic.items())),
                            layout.lane_len, 1, vbytes))
    return scored, rejected, tuple(policies)


def _dataflow_scores(kind: str, a: BSR, b: Optional[BSR], hint: int,
                     scored, policies: Tuple[str, ...]) -> Dict[str, float]:
    """Analytic bytes per dataflow at default knobs: closed-form estimates
    for the hint-carrying policies + ``"inner"``, overlaid with each swept
    policy's own default-knob (1 lane, unroll 1, no fold, pipelined)
    candidate — that is how the hint-less ``segment`` gets scored."""
    bm, bk = a.block_shape
    if kind == "spmm":
        est = dataflow_estimates("spmm", bm=bm, bk=bk, n_cols=hint,
                                 m=a.brow.astype(np.int64),
                                 k=a.bcol.astype(np.int64))
    else:
        sched = build_spgemm_schedule(a, b, policy=policies[0])
        est = dataflow_estimates(
            "spgemm", bm=bm, bk=bk, bn=b.block_shape[1],
            m=sched.m.astype(np.int64), n=sched.n.astype(np.int64),
            k=sched.k.astype(np.int64), c=sched.c_idx.astype(np.int64),
            a_idx=sched.a_idx.astype(np.int64),
            b_idx=sched.b_idx.astype(np.int64))
    scores = {name: float(e["total"]) for name, e in est.items()}
    for s in scored:
        c = s.candidate
        if (c.fold_len is None and c.n_lanes == 1 and c.unroll == 1
                and c.pipeline and c.prefetch is None):
            scores[c.policy] = s.traffic_total
    return scores


def autotune_matmul(a: BSR, b_or_shape=None, *,
                    space: Optional[SearchSpace] = None,
                    objective="tpu", cost_model: Optional[CostModel] = None,
                    n_cols_hint: Optional[int] = None, with_grad: bool = False,
                    quantize: Optional[str] = None,
                    vmem_limit_bytes: Optional[int] = None,
                    cache: bool = True,
                    pins: Optional[Dict[str, object]] = None) -> TuneResult:
    """Search the knob grid for the cheapest feasible schedule of ``a``'s
    pattern (× ``b``'s for SpGEMM) under the given cost model.

    Purely static: no candidate is ever executed.  Infeasible candidates
    are rejected by the closed-form VMEM budget; the ranked winner is built
    once and must pass ``verify_plan(level="full")`` plus the plan-level
    VMEM gate, else the runner-up is promoted.  ``pins`` maps knob names
    (``policy``/``fold_len``/``n_lanes``/``unroll``/``bn``/``pipeline``/
    ``prefetch``) to
    values the search must keep fixed.  Results are cached by pattern
    fingerprint (``cache=True``) so repeat patterns skip the sweep."""
    from repro.api import planner as _planner
    b, hint = _planner._rhs_to_hint(a, b_or_shape)
    if n_cols_hint is not None:
        hint = int(n_cols_hint)
    if b is not None and with_grad:
        raise NotImplementedError("with_grad is only supported for SpMM plans")
    model, obj_name = _resolve_model(objective, cost_model)
    limit = (DEFAULT_VMEM_LIMIT_BYTES if vmem_limit_bytes is None
             else vmem_limit_bytes)
    space = space or SearchSpace()
    pins = dict(pins or {})
    block_dtype = quantize if quantize is not None else "fp32"
    kind = "spgemm" if b is not None else "spmm"
    mats = (a, b) if b is not None else (a,)
    key = _search_key(kind, mats,
                      _planner._bucket_hint(hint) if b is None else None,
                      with_grad, block_dtype, space, model, obj_name, limit,
                      pins)
    if cache and key in _SEARCH_CACHE:
        _planner._STATS["search_cache_hits"] += 1
        return dataclasses.replace(_SEARCH_CACHE[key], from_cache=True)
    _planner._STATS["searched"] += 1

    if kind == "spmm":
        scored, rejected, policies = _score_spmm(a, hint, block_dtype, model,
                                                 space, limit, pins)
    else:
        scored, rejected, policies = _score_spgemm(a, b, block_dtype, model,
                                                   space, limit, pins)
    if not scored:
        raise ValueError(
            f"autotune_matmul: every candidate in the search space exceeds "
            f"the {limit}-byte VMEM budget ({rejected} rejected); widen the "
            f"space or raise vmem_limit_bytes")
    ranked = tuple(sorted(scored, key=lambda s: _rank_key(s, policies)))

    scores = _dataflow_scores(kind, a, b, hint, scored, policies)
    choice = min(scores, key=lambda n: (scores[n], n != "segment"))
    dispatchable = {s.candidate.policy for s in scored}
    if choice not in dispatchable:
        _planner._STATS["dataflow_fallbacks"] += 1
        dispatched = min((n for n in scores if n in dispatchable),
                         key=lambda n: (scores[n], n != "segment"))
    else:
        dispatched = choice

    # static winner gate: the best candidate must survive the full verifier
    # and the plan-level VMEM budget; a failure promotes the runner-up
    from repro.api.executor import pick_bn
    best = None
    for s in ranked:
        c = s.candidate
        plan = _planner.plan_matmul(
            a, b_or_shape, policy=c.policy, fold_len=c.fold_len,
            with_grad=with_grad, n_cols_hint=hint, n_lanes=c.n_lanes,
            unroll=c.unroll, cache=False, quantize=quantize,
            pipeline=c.pipeline, bn_hint=c.bn, prefetch=c.prefetch)
        try:
            verify_plan(plan, level="full").raise_if_findings()
            bn_eff, _ = pick_bn(max(1, hint), c.bn)
            check_plan_vmem(plan, bn=bn_eff, limit=limit,
                            label=f"autotune[{kind}]")
        except Exception:
            continue
        best = s
        break
    if best is None:
        raise ValueError("autotune_matmul: no candidate passed the static "
                         "verifier + VMEM gate")

    result = TuneResult(best=best, candidates=ranked,
                        dataflow_scores=scores, dataflow_choice=choice,
                        dataflow_dispatched=dispatched, objective=obj_name,
                        n_rejected_vmem=rejected)
    if cache:
        _SEARCH_CACHE[key] = result
    return result


def select_schedule(a: BSR, b: Optional[BSR] = None, *,
                    n_cols_hint: Optional[int] = None,
                    with_grad: bool = False, quantize: Optional[str] = None,
                    vmem_limit_bytes: Optional[int] = None,
                    pins: Optional[Dict[str, object]] = None,
                    objective="tpu",
                    space: Optional[SearchSpace] = None,
                    cost_model: Optional[CostModel] = None,
                    cache: bool = True) -> Candidate:
    """The planner's ``policy="auto"`` entry point: run (or replay from the
    search cache) the schedule search and return the winning
    :class:`Candidate` — the knobs ``plan_matmul`` should re-enter with."""
    res = autotune_matmul(a, b, space=space, objective=objective,
                          cost_model=cost_model, n_cols_hint=n_cols_hint,
                          with_grad=with_grad, quantize=quantize,
                          vmem_limit_bytes=vmem_limit_bytes, cache=cache,
                          pins=pins)
    return res.best.candidate
