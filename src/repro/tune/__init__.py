"""``repro.tune`` — analytical schedule search and dataflow selection.

Given a sparsity pattern, :func:`autotune_matmul` sweeps the planner's knob
grid against a unified analytical cost model — no candidate ever executes —
and returns a ranked, statically verified winner whose knobs
:func:`repro.api.plan_matmul` re-enters with (``policy="auto"`` does exactly
that).  See :mod:`repro.tune.search` for the mechanics and
:mod:`repro.tune.cost` for the model.

This package imports :mod:`repro.api`; the API layer only ever imports the
tuner lazily inside ``plan_matmul`` (``scripts/ci.sh`` lints the layering),
so plain planning never pays for the search machinery.
"""
from .cost import DEFAULT_INTERPRET, DEFAULT_TPU, CostModel, calibrate
from .search import (Candidate, Scored, SearchSpace, TuneResult,
                     autotune_matmul, select_schedule)

__all__ = [
    "CostModel", "calibrate", "DEFAULT_TPU", "DEFAULT_INTERPRET",
    "Candidate", "Scored", "SearchSpace", "TuneResult",
    "autotune_matmul", "select_schedule",
]
