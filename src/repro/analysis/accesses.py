"""Slot-granular ref-access IR for Pallas kernel jaxprs.

The symbolic half of ``repro.analysis``: where :mod:`jaxpr_lint` walks a
kernel jaxpr *syntactically* (ref-base granularity, no index values), this
module runs an abstract interpretation of the kernel over its whole grid
and extracts a typed access record per ``get``/``swap``/``dma_start``/
``dma_wait`` — which ref, which *slot* (the per-dimension index footprint),
under which ``pl.when`` guards, at which grid points.

The abstract domain is deliberately concrete: every scalar the Segment
kernels compute is a function of the grid coordinates and the
scalar-prefetch schedule arrays, and the schedule arrays are plan-time
constants (already certified by :mod:`repro.analysis.invariants`).  So the
interpreter carries each scalar as a *vector over all grid points* — exact
constant propagation per point, with ``TOP`` (``None``) for anything
data-dependent (tensor values, loop carries).  Downstream passes
(:mod:`ranges`, :mod:`races`, :mod:`budget`) reduce these vectors to
interval proofs, per-slot hazard simulations, and byte budgets.

Fixes the documented ref-base false negative of the syntactic linter: a
``(depth, …)`` ring buffer is no longer one opaque base — each access
carries its resolved slot per grid point.

Entry points:

* :func:`kernel_ir_from_eqn` — build a :class:`KernelIR` from one traced
  ``pallas_call`` equation plus the resolved scalar-prefetch arrays;
* :func:`find_kernel_invocations` — walk a host-level jaxpr, resolving the
  scalar-prefetch operands of every reachable ``pallas_call`` from the
  trace's constants (works through ``pjit`` / ``custom_vjp`` nesting);
* :func:`trace_kernel_irs` — trace a callable and return one IR per
  kernel.

Imports: jax + numpy only; this module must stay importable without the
planner (layering mirror of :mod:`jaxpr_lint`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax import tree_util

TOP = None          #: unknown abstract value (data-dependent / loop-carried)

#: hard cap on grid points per analyzed kernel — the interpreter is O(grid)
#: per scalar; analysis targets the CI-sized variant grid, not production
#: shapes (the schedule proofs are shape-generic through invariants.py).
MAX_GRID_POINTS = 1 << 20

READ_KINDS = ("read", "dma_src")
WRITE_KINDS = ("write", "dma_dst")


@dataclasses.dataclass(frozen=True)
class RefInfo:
    """One kernel operand/scratch ref, canonicalized."""

    role: str                    # prefetch | input | output | scratch | local
    index: int                   # position within the role
    name: str                    # e.g. "in0", "out0", "scratch2"
    shape: Tuple[int, ...]       # backing array shape (full, not block)
    dtype: str
    memspace: str                # smem | any | vmem | semaphore | blocked
    block_shape: Optional[Tuple[int, ...]] = None   # BlockSpec window


@dataclasses.dataclass(frozen=True)
class Dim:
    """Access footprint along one ref dimension.

    ``start`` is an int (static), a ``(G,)`` int64 vector (one value per
    grid point), or ``TOP``.  ``size`` is an int or ``TOP``.  ``full``
    marks a static whole-extent slice.
    """

    start: object
    size: object
    full: bool


@dataclasses.dataclass
class Access:
    """One ref access with its per-grid-point footprint and guard."""

    ref: RefInfo
    kind: str                        # read | write | dma_src | dma_dst | dma_wait
    dims: Tuple[Dim, ...]
    extent: Tuple[int, ...]          # ref shape the indexer was taken against
    mask: Optional[np.ndarray]       # bool (G,) guard; None when not certain
    certain: bool
    seq: int                         # kernel program order
    sem: Optional[RefInfo] = None    # owning DMA semaphore (dma_* kinds)
    sem_slot: object = TOP           # semaphore slot: int | (G,) vector | TOP
    in_loop: bool = False            # recorded inside scan/while (multiplicity
    #                                  and index not grid-resolved)

    def slot(self) -> object:
        """Leading-dimension slot of this footprint: int, (G,) vector,
        ``"all"`` for a full leading slice, or TOP."""
        if not self.dims:
            return "all"
        d = self.dims[0]
        if d.full:
            return "all"
        if d.size == 1 and d.start is not TOP:
            return d.start
        return TOP

    def rest_full(self) -> bool:
        return all(d.full for d in self.dims[1:])


@dataclasses.dataclass
class KernelIR:
    """The access IR of one traced kernel over its concrete grid."""

    name: str
    grid: Tuple[int, ...]
    semantics: Tuple[str, ...]           # per-axis dimension_semantics
    parallel_axes: Tuple[int, ...]
    coords: Tuple[np.ndarray, ...]       # (G,) int64 per grid axis, row-major
    refs: List[RefInfo]
    accesses: List[Access]
    #: blocked (non-ANY) input/output refs → per-axis block coords over the
    #: grid (int | (G,) vector | TOP), from the BlockSpec index maps
    block_coords: Dict[str, Tuple[object, ...]]
    #: same refs → per-axis number of blocks (bounds for the coords)
    block_bounds: Dict[str, Tuple[int, ...]]
    scalars: Dict[str, Optional[np.ndarray]]   # prefetch name → values

    @property
    def n_points(self) -> int:
        return int(np.prod(self.grid)) if self.grid else 1

    def point(self, p: int) -> Tuple[int, ...]:
        """Grid coordinates of flattened point ``p`` (row-major)."""
        return tuple(int(c[p]) for c in self.coords)

    @property
    def sequential_axes(self) -> Tuple[int, ...]:
        """Grid axes *not* declared parallel, outermost first — the axes
        Mosaic executes in program order within one parallel iteration."""
        return tuple(ax for ax in range(len(self.grid))
                     if ax not in self.parallel_axes)

    def may_mask(self, a: Access) -> np.ndarray:
        """Guard as a may-execute mask (unknown guards → everywhere)."""
        if a.certain and a.mask is not None:
            return a.mask
        return np.ones(self.n_points, bool)

    def must_mask(self, a: Access) -> np.ndarray:
        """Guard as a must-execute mask (unknown guards → nowhere)."""
        if a.certain and a.mask is not None:
            return a.mask
        return np.zeros(self.n_points, bool)


# ---------------------------------------------------------------------------
# small helpers shared with the syntactic linter (duplicated to keep this
# module import-independent of jaxpr_lint)
# ---------------------------------------------------------------------------


def _is_sem_aval(aval) -> bool:
    return aval is not None and "semaphore" in str(aval).lower()


def _is_ref_aval(aval) -> bool:
    return aval is not None and "Ref" in type(aval).__name__


def _is_var(v) -> bool:
    return hasattr(v, "aval") and not hasattr(v, "val")


def _memspace(aval) -> str:
    s = str(aval).lower()
    if "semaphore" in s:
        return "semaphore"
    for name in ("smem", "vmem", "any"):
        if f"<{name}>" in s:
            return name
    return "blocked"        # MemRef<None>{…}: a BlockSpec-windowed operand


def _subjaxprs(eqn):
    """Yield (jaxpr, consts) for every sub-jaxpr in one eqn's params."""
    for pv in eqn.params.values():
        vals = pv if isinstance(pv, (tuple, list)) else (pv,)
        for v in vals:
            inner = getattr(v, "jaxpr", None)
            if inner is not None and hasattr(inner, "eqns"):
                yield inner, tuple(getattr(v, "consts", ()))
            elif hasattr(v, "eqns"):
                yield v, ()


# ---------------------------------------------------------------------------
# scalar op table (vectorized over grid points)
# ---------------------------------------------------------------------------


def _trunc_div(a, b):
    a = np.asarray(a)
    b = np.asarray(b)
    if np.issubdtype(np.result_type(a, b), np.integer):
        q = np.abs(a) // np.maximum(np.abs(b), 1)
        return (np.sign(a) * np.sign(b) * q).astype(np.int64)
    return a / b


def _trunc_rem(a, b):
    # lax.rem is C-style (truncated) remainder; np.fmod matches
    return np.fmod(np.asarray(a), np.asarray(b))


_BINOPS = {
    "add": np.add, "sub": np.subtract, "mul": np.multiply,
    "max": np.maximum, "min": np.minimum,
    "div": _trunc_div, "rem": _trunc_rem,
    "and": np.bitwise_and, "or": np.bitwise_or, "xor": np.bitwise_xor,
    "eq": np.equal, "ne": np.not_equal, "lt": np.less, "le": np.less_equal,
    "gt": np.greater, "ge": np.greater_equal,
    "shift_left": np.left_shift,
    "shift_right_logical": np.right_shift,
    "shift_right_arithmetic": np.right_shift,
}

_UNOPS = {
    "neg": np.negative, "not": np.bitwise_not, "sign": np.sign,
    "abs": np.abs, "floor": np.floor, "ceil": np.ceil,
    "stop_gradient": lambda v: v, "copy": lambda v: v,
}

_CALL_PRIMS = ("pjit", "closed_call", "core_call", "custom_jvp_call",
               "custom_vjp_call", "custom_vjp_call_jaxpr", "remat",
               "checkpoint", "custom_lin")


class _Interp:
    """Vectorized abstract interpreter over one kernel jaxpr."""

    def __init__(self, ir: KernelIR, refmap: Dict[object, RefInfo]):
        self.ir = ir
        self.G = ir.n_points
        self.env: Dict[object, object] = {}
        self.alias: Dict[object, object] = {}
        self.refmap = refmap            # canonical var -> RefInfo
        self.seq = 0

    # -- value plumbing -----------------------------------------------------

    def read(self, v):
        if hasattr(v, "val"):                      # Literal
            val = np.asarray(v.val)
            return val if val.ndim == 0 else TOP
        return self.env.get(v, TOP)

    def bind(self, v, val) -> None:
        if val is not TOP:
            self.env[v] = val

    def canon(self, v):
        while v in self.alias:
            v = self.alias[v]
        return v

    def ref_of(self, v) -> Optional[RefInfo]:
        return self.refmap.get(self.canon(v))

    def _alias_refs(self, sub_invars, operands) -> None:
        for sv, ov in zip(sub_invars, operands):
            if _is_var(ov) and _is_ref_aval(getattr(ov, "aval", None)):
                self.alias[sv] = self.canon(ov)
            else:
                # Vars and Literals alike (jnp lowers e.g. ``%`` to a pjit
                # whose remainder jaxpr takes literal operands — dropping
                # them would poison every downstream slot with TOP)
                self.bind(sv, self.read(ov))

    # -- indexer decoding ---------------------------------------------------

    def _decode_indexer(self, transforms, aval):
        """(dims, extent) from a ref transform tuple (NDIndexer pytree)."""
        nd = None
        for t in (transforms or ()):
            if type(t).__name__ == "NDIndexer":
                nd = t
                break
        if nd is None:
            shape = tuple(getattr(aval, "shape", ()) or ())
            dims = tuple(Dim(0, s, True) for s in shape)
            return dims, shape
        extent = tuple(int(s) for s in nd.shape)
        dims = []
        for d, idx in enumerate(nd.indices):
            tname = type(idx).__name__
            if tname == "Slice":
                start = idx.start
                if _is_var(start) or hasattr(start, "val"):
                    start = self.read(start)
                size = idx.size
                if hasattr(size, "val"):
                    size = int(np.asarray(size.val))
                elif _is_var(size):
                    size = TOP
                stride = getattr(idx, "stride", 1)
                if _is_var(stride) or (size is not TOP and stride != 1):
                    # conservative span for strided slices
                    size = TOP if size is TOP else (size - 1) * stride + 1
                dims.append(Dim(_norm_start(start, self.G),
                                size if size is TOP else int(size),
                                _is_static_full(start, size, extent[d])))
            elif isinstance(idx, (int, np.integer)):
                dims.append(Dim(int(idx), 1, extent[d] == 1))
            elif getattr(getattr(idx, "aval", None), "shape",
                         None) == ():       # scalar Var or Literal
                val = self.read(idx)
                if isinstance(val, np.ndarray) and val.ndim == 0:
                    val = int(val)
                if isinstance(val, (int, np.integer)):
                    dims.append(Dim(int(val), 1,
                                    extent[d] == 1 and int(val) == 0))
                else:
                    dims.append(Dim(_norm_start(val, self.G), 1, False))
            else:                       # array indexer / anything else
                dims.append(Dim(TOP, TOP, False))
        return tuple(dims), extent

    # -- access recording ---------------------------------------------------

    def record(self, ref_var, transforms, kind, mask, certain, in_loop,
               sem=None, sem_slot=TOP) -> Optional[Access]:
        ref = self.ref_of(ref_var)
        if ref is None:
            aval = getattr(ref_var, "aval", None)
            ref = RefInfo("local", len(self.refmap), f"local{len(self.refmap)}",
                          tuple(getattr(aval, "shape", ()) or ()),
                          str(getattr(aval, "dtype", "?")), _memspace(aval))
            self.refmap[self.canon(ref_var)] = ref
        dims, extent = self._decode_indexer(transforms,
                                            getattr(ref_var, "aval", None))
        acc = Access(ref=ref, kind=kind, dims=dims, extent=extent,
                     mask=mask if certain else None, certain=certain,
                     seq=self.seq, sem=sem, sem_slot=sem_slot,
                     in_loop=in_loop)
        self.seq += 1
        self.ir.accesses.append(acc)
        return acc

    # -- primitive handlers -------------------------------------------------

    def _scalar_lookup(self, ref: RefInfo, dims) -> object:
        """Value of a scalar ``get`` from a resolved prefetch array."""
        arr = self.ir.scalars.get(ref.name)
        if arr is None or len(dims) != 1 or dims[0].size != 1 \
                or dims[0].start is TOP:
            return TOP
        idx = np.clip(dims[0].start, 0, len(arr) - 1)
        return np.asarray(arr)[idx]

    def _get(self, eqn, mask, certain, in_loop) -> None:
        tree = eqn.params.get("tree")
        transforms = _unflatten_transforms(tree, eqn.invars[1:])
        acc = self.record(eqn.invars[0], transforms, "read", mask, certain,
                          in_loop)
        out = eqn.outvars[0]
        if getattr(out.aval, "shape", None) == () and acc is not None \
                and acc.ref.role == "prefetch":
            self.bind(out, self._scalar_lookup(acc.ref, acc.dims))

    def _swap(self, eqn, mask, certain, in_loop) -> None:
        transforms = _unflatten_transforms(eqn.params.get("tree"),
                                           eqn.invars[2:])
        self.record(eqn.invars[0], transforms, "write", mask, certain,
                    in_loop)

    def _addupdate(self, eqn, mask, certain, in_loop) -> None:
        transforms = _unflatten_transforms(eqn.params.get("tree"),
                                           eqn.invars[2:])
        self.record(eqn.invars[0], transforms, "read", mask, certain, in_loop)
        self.record(eqn.invars[0], transforms, "write", mask, certain,
                    in_loop)

    def _dma_pairs(self, eqn):
        """[(ref_var, transforms)] parsed from a dma_start/dma_wait tree."""
        tree = eqn.params.get("tree")
        if tree is None:
            return []
        try:
            flat = tree_util.tree_unflatten(tree, eqn.invars)
        except Exception:
            return []
        items = list(flat) if isinstance(flat, (tuple, list)) else [flat]
        pairs = []
        i = 0
        while i < len(items):
            v = items[i]
            if _is_var(v) and _is_ref_aval(getattr(v, "aval", None)):
                transforms = ()
                if i + 1 < len(items) and isinstance(items[i + 1],
                                                     (tuple, list)):
                    transforms = tuple(items[i + 1])
                    i += 1
                pairs.append((v, transforms))
            i += 1
        return pairs

    def _dma(self, eqn, kind, mask, certain, in_loop) -> None:
        pairs = self._dma_pairs(eqn)
        sem_pair = None
        refs = []
        for v, tr in pairs:
            if _is_sem_aval(getattr(v, "aval", None)):
                if sem_pair is None:
                    sem_pair = (v, tr)
            else:
                refs.append((v, tr))
        sem = sem_slot = None
        if sem_pair is not None:
            sem = self.ref_of(sem_pair[0])
            sdims, _ = self._decode_indexer(sem_pair[1],
                                            getattr(sem_pair[0], "aval", None))
            sem_slot = sdims[0].start if (sdims and sdims[0].size == 1) \
                else ("all" if sdims and sdims[0].full else TOP)
        if kind == "dma_start":
            if len(refs) >= 2:
                self.record(refs[0][0], refs[0][1], "dma_src", mask, certain,
                            in_loop)
            if refs:
                v, tr = refs[-1]
                self.record(v, tr, "dma_dst", mask, certain, in_loop,
                            sem=sem, sem_slot=sem_slot)
        else:                           # dma_wait: attribute to the dst ref
            if refs:
                v, tr = refs[-1]
                self.record(v, tr, "dma_wait", mask, certain, in_loop,
                            sem=sem, sem_slot=sem_slot)

    def _cond(self, eqn, mask, certain, in_loop) -> None:
        pred = self.read(eqn.invars[0])
        branches = eqn.params.get("branches", ())
        branch_vals = []
        for k, br in enumerate(branches):
            sub = getattr(br, "jaxpr", br)
            self._alias_refs(sub.invars, eqn.invars[1:])
            if pred is TOP:
                sub_mask, sub_certain = mask, False
            else:
                pv = np.broadcast_to(np.asarray(pred), (self.G,))
                sub_mask = mask & (pv.astype(np.int64) == k)
                sub_certain = certain
            self.walk(sub, sub_mask, sub_certain, in_loop)
            branch_vals.append([self.read(v) for v in sub.outvars])
        # merge branch outputs where every branch yields a known scalar
        for i, out in enumerate(eqn.outvars):
            if getattr(out.aval, "shape", None) != () or pred is TOP:
                continue
            vals = [bv[i] if i < len(bv) else TOP for bv in branch_vals]
            if any(v is TOP for v in vals):
                continue
            pv = np.broadcast_to(np.asarray(pred), (self.G,)).astype(np.int64)
            sel = np.select([pv == k for k in range(len(vals))],
                            [np.broadcast_to(np.asarray(v), (self.G,))
                             for v in vals],
                            default=np.broadcast_to(np.asarray(vals[-1]),
                                                    (self.G,)))
            self.bind(out, sel)

    def _loop(self, eqn, mask, in_loop) -> None:
        """scan / while: walk bodies once with TOP carries (accesses are
        recorded with unknown multiplicity → guard marked uncertain)."""
        for sub, consts in _subjaxprs(eqn):
            # bind what aligns positionally (scan consts lead the invars)
            for sv, ov in zip(sub.invars, eqn.invars):
                if _is_var(ov) and _is_ref_aval(getattr(ov, "aval", None)):
                    self.alias[sv] = self.canon(ov)
            self.walk(sub, mask, False, True)

    def _call(self, eqn, mask, certain, in_loop) -> bool:
        for sub, consts in _subjaxprs(eqn):
            if len(sub.invars) != len(eqn.invars):
                continue
            self._alias_refs(sub.invars, eqn.invars)
            self.walk(sub, mask, certain, in_loop)
            for ov, sv in zip(eqn.outvars, sub.outvars):
                if getattr(ov.aval, "shape", None) == ():
                    self.bind(ov, self.read(sv))
            return True
        return False

    # -- the walk -----------------------------------------------------------

    def walk(self, jaxpr, mask, certain, in_loop=False) -> None:
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            if prim == "cond":
                self._cond(eqn, mask, certain, in_loop)
            elif prim == "get":
                self._get(eqn, mask, certain, in_loop)
            elif prim == "swap":
                self._swap(eqn, mask, certain, in_loop)
            elif prim == "addupdate":
                self._addupdate(eqn, mask, certain, in_loop)
            elif prim in ("dma_start", "dma_wait"):
                self._dma(eqn, prim, mask, certain, in_loop)
            elif prim == "program_id":
                self.bind(eqn.outvars[0],
                          self.ir.coords[eqn.params["axis"]])
            elif prim == "num_programs":
                self.bind(eqn.outvars[0],
                          np.int64(self.ir.grid[eqn.params["axis"]]))
            elif prim in ("scan", "while"):
                self._loop(eqn, mask, in_loop)
            elif prim == "convert_element_type":
                v = self.read(eqn.invars[0])
                if v is not TOP:
                    dt = np.dtype(eqn.params.get("new_dtype", "int64"))
                    self.bind(eqn.outvars[0], np.asarray(v).astype(dt))
            elif prim == "select_n":
                self._select_n(eqn)
            elif prim == "integer_pow":
                v = self.read(eqn.invars[0])
                if v is not TOP:
                    self.bind(eqn.outvars[0],
                              np.asarray(v) ** eqn.params["y"])
            elif prim in _BINOPS and self._scalar_out(eqn):
                a, b = (self.read(v) for v in eqn.invars[:2])
                if a is not TOP and b is not TOP:
                    self.bind(eqn.outvars[0], _BINOPS[prim](a, b))
            elif prim in _UNOPS and self._scalar_out(eqn):
                a = self.read(eqn.invars[0])
                if a is not TOP:
                    self.bind(eqn.outvars[0], _UNOPS[prim](a))
            elif prim in ("broadcast_in_dim", "reshape", "squeeze",
                          "expand_dims"):
                if self._scalar_out(eqn):
                    self.bind(eqn.outvars[0], self.read(eqn.invars[0]))
            elif prim in _CALL_PRIMS:
                self._call(eqn, mask, certain, in_loop)
            else:
                # unknown primitive: outputs stay TOP; still walk reachable
                # sub-jaxprs so no access goes unrecorded (conservatively
                # uncertain — we cannot interpret the calling convention)
                if not self._call(eqn, mask, certain, in_loop):
                    for sub, _ in _subjaxprs(eqn):
                        self.walk(sub, mask, False, in_loop)

    def _scalar_out(self, eqn) -> bool:
        return (len(eqn.outvars) == 1
                and getattr(eqn.outvars[0].aval, "shape", None) == ())

    def _select_n(self, eqn) -> None:
        if not self._scalar_out(eqn):
            return
        vals = [self.read(v) for v in eqn.invars]
        if any(v is TOP for v in vals):
            return
        pred, cases = vals[0], vals[1:]
        pv = np.broadcast_to(np.asarray(pred), (self.G,)).astype(np.int64)
        out = np.select([pv == k for k in range(len(cases))],
                        [np.broadcast_to(np.asarray(c), (self.G,))
                         for c in cases],
                        default=np.broadcast_to(np.asarray(cases[-1]),
                                                (self.G,)))
        self.bind(eqn.outvars[0], out)


def _norm_start(start, G):
    if start is TOP:
        return TOP
    arr = np.asarray(start)
    if arr.ndim == 0:
        return int(arr)
    return np.broadcast_to(arr, (G,)).astype(np.int64)


def _is_static_full(start, size, extent) -> bool:
    return (isinstance(start, (int, np.integer)) and int(start) == 0
            and size is not TOP and int(size) == int(extent))


def _unflatten_transforms(tree, leaves):
    if tree is None:
        return ()
    try:
        flat = tree_util.tree_unflatten(tree, list(leaves))
    except Exception:
        return ()
    return tuple(flat) if isinstance(flat, (tuple, list)) else (flat,)


# ---------------------------------------------------------------------------
# IR construction from a traced pallas_call equation
# ---------------------------------------------------------------------------


def _dimension_semantics(eqn, n_axes: int) -> Tuple[str, ...]:
    cp = eqn.params.get("compiler_params") or {}
    if isinstance(cp, dict):
        mosaic = cp.get("mosaic") or {}
        sem = mosaic.get("dimension_semantics") if isinstance(mosaic, dict) \
            else getattr(mosaic, "dimension_semantics", None)
    else:
        sem = getattr(cp, "dimension_semantics", None)
    if sem is None:
        return ("arbitrary",) * n_axes
    return tuple(str(s) for s in sem)


def kernel_ir_from_eqn(eqn, name: str = "<kernel>",
                       scalars: Optional[Sequence] = None) -> KernelIR:
    """Build the access IR of one traced ``pallas_call`` equation.

    ``scalars`` supplies the values of the scalar-prefetch operands in
    kernel-argument order (numpy arrays, or None per entry when unknown);
    :func:`find_kernel_invocations` resolves them automatically from the
    host trace.
    """
    gm = eqn.params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid) or (1,)
    G = int(np.prod(grid))
    if G > MAX_GRID_POINTS:
        raise ValueError(
            f"kernel {name!r}: grid {grid} has {G} points, beyond the "
            f"analyzer cap ({MAX_GRID_POINTS}); analyze a CI-sized variant "
            f"of the kernel instead (the proofs are schedule-generic)")
    kj = eqn.params["jaxpr"]
    kj = getattr(kj, "jaxpr", kj)
    n_idx = gm.num_index_operands
    n_in = gm.num_inputs
    n_out = gm.num_outputs
    semantics = _dimension_semantics(eqn, len(grid))
    if len(semantics) < len(grid):
        semantics = semantics + ("arbitrary",) * (len(grid) - len(semantics))
    coords = tuple(c.reshape(-1).astype(np.int64)
                   for c in np.indices(grid))

    scalar_vals: Dict[str, Optional[np.ndarray]] = {}
    refs: List[RefInfo] = []
    refmap: Dict[object, RefInfo] = {}
    bms = list(gm.block_mappings)
    for pos, var in enumerate(kj.invars):
        aval = getattr(var, "aval", None)
        shape = tuple(getattr(aval, "shape", ()) or ())
        dtype = str(getattr(aval, "dtype", "?"))
        if pos < n_idx:
            info = RefInfo("prefetch", pos, f"prefetch{pos}", shape, dtype,
                           _memspace(aval))
            val = None
            if scalars is not None and pos < len(scalars) \
                    and scalars[pos] is not None:
                val = np.asarray(scalars[pos])
            scalar_vals[info.name] = val
        elif pos < n_idx + n_in + n_out:
            io = pos - n_idx
            bm = bms[io] if io < len(bms) else None
            role = "input" if io < n_in else "output"
            idx = io if io < n_in else io - n_in
            block_shape = None
            array_shape = shape
            space = _memspace(aval)
            if bm is not None:
                block_shape = tuple(int(b) for b in bm.block_shape)
                sd = getattr(bm, "array_shape_dtype", None)
                if sd is not None:
                    array_shape = tuple(int(s) for s in sd.shape)
                    dtype = str(sd.dtype)
                space = _memspace(getattr(bm, "block_aval", aval))
            info = RefInfo(role, idx, f"{'in' if role == 'input' else 'out'}"
                           f"{idx}", array_shape, dtype, space, block_shape)
        else:
            k = pos - n_idx - n_in - n_out
            info = RefInfo("scratch", k, f"scratch{k}", shape, dtype,
                           _memspace(aval))
        refs.append(info)
        refmap[var] = info

    ir = KernelIR(name=name, grid=grid, semantics=semantics,
                  parallel_axes=tuple(i for i, s in enumerate(semantics)
                                      if s == "parallel"),
                  coords=coords, refs=refs, accesses=[],
                  block_coords={}, block_bounds={}, scalars=scalar_vals)

    interp = _Interp(ir, refmap)

    # BlockSpec index maps: evaluate each blocked operand's block coords
    # over the grid (the index-map jaxprs read the prefetch refs, so run
    # them through the same interpreter — their SMEM reads are recorded and
    # range-checked like any kernel access)
    prefetch_refs = [refs[i] for i in range(n_idx)]
    for io, bm in enumerate(bms):
        info = refs[n_idx + io]
        if info.memspace == "any" or bm is None or info.block_shape is None:
            continue
        imap = getattr(bm, "index_map_jaxpr", None)
        if imap is None:
            continue
        sub = getattr(imap, "jaxpr", imap)
        for cv, c in zip(getattr(sub, "constvars", ()),
                         getattr(imap, "consts", ())):
            if np.ndim(c) == 0:
                interp.bind(cv, np.asarray(c))
        n_axes = len(grid)
        for v, c in zip(sub.invars[:n_axes], coords):
            interp.bind(v, c)
        for v, pr in zip(sub.invars[n_axes:], prefetch_refs):
            interp.refmap[interp.canon(v)] = pr
        interp.walk(sub, np.ones(G, bool), True)
        out_coords = tuple(interp.read(v) if _is_var(v)
                           else int(np.asarray(v.val)) for v in sub.outvars)
        ir.block_coords[info.name] = tuple(
            _norm_start(c, G) for c in out_coords)
        ir.block_bounds[info.name] = tuple(
            -(-a // max(b, 1)) for a, b in zip(info.shape, info.block_shape))

    interp.walk(kj, np.ones(G, bool), True)
    return ir


# ---------------------------------------------------------------------------
# host-level kernel discovery with scalar-prefetch resolution
# ---------------------------------------------------------------------------


def find_kernel_invocations(closed, args=()) -> List[Tuple[str, object, list]]:
    """Collect ``(name, eqn, scalar_values)`` for every reachable
    ``pallas_call`` in a host-level jaxpr.

    Scalar-prefetch operand values are resolved by propagating the trace's
    constants (and the concrete ``args``) through the host jaxpr — plan
    schedule arrays are closed-over constants, so this recovers them even
    under ``pjit`` / ``custom_vjp`` nesting (the grad trace).  Unresolvable
    operands come back as ``None`` entries (analysis degrades to TOP).
    """
    env: Dict[object, np.ndarray] = {}
    out: List[Tuple[str, object, list]] = []

    def rd(v):
        if hasattr(v, "val"):
            return np.asarray(v.val)
        return env.get(v)

    def walk(j):
        for e in j.eqns:
            if e.primitive.name == "pallas_call":
                gm = e.params.get("grid_mapping")
                n_idx = getattr(gm, "num_index_operands", 0)
                info = e.params.get("name_and_src_info")
                name = (getattr(info, "name", None)
                        or e.params.get("name") or "<pallas_call>")
                out.append((str(name), e, [rd(v) for v in e.invars[:n_idx]]))
                continue
            if e.primitive.name in ("convert_element_type", "copy",
                                    "device_put", "reshape",
                                    "broadcast_in_dim", "squeeze"):
                val = rd(e.invars[0])
                if val is not None and e.primitive.name in (
                        "convert_element_type", "copy", "device_put"):
                    env[e.outvars[0]] = val
                continue
            for sub, consts in _subjaxprs(e):
                for cv, c in zip(getattr(sub, "constvars", ()), consts):
                    if hasattr(c, "shape"):
                        env[cv] = np.asarray(c)
                if len(sub.invars) == len(e.invars):
                    for sv, ov in zip(sub.invars, e.invars):
                        val = rd(ov)
                        if val is not None:
                            env[sv] = val
                walk(sub)
                for ov, sv in zip(e.outvars, sub.outvars):
                    val = rd(sv)
                    if val is not None:
                        env[ov] = val

    jaxpr = getattr(closed, "jaxpr", closed)
    for v, c in zip(getattr(jaxpr, "constvars", ()),
                    getattr(closed, "consts", ())):
        if hasattr(c, "shape"):
            env[v] = np.asarray(c)
    flat_args = tree_util.tree_leaves(args)
    for v, a in zip(jaxpr.invars, flat_args):
        if hasattr(a, "shape"):
            env[v] = np.asarray(a)
    walk(jaxpr)
    return out


def trace_kernel_irs(fn, *args, label: Optional[str] = None,
                     **kwargs) -> List[KernelIR]:
    """Trace ``fn(*args, **kwargs)`` and build one :class:`KernelIR` per
    reachable Pallas kernel.  Raises ``ValueError`` when the trace holds no
    ``pallas_call`` (a vacuous analysis gate is a bug, not a pass)."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    found = find_kernel_invocations(closed, args)
    if not found:
        raise ValueError(
            f"no pallas_call found while tracing "
            f"{label or getattr(fn, '__name__', fn)!r} — nothing to analyze")
    irs = []
    for name, eqn, scalars in found:
        irs.append(kernel_ir_from_eqn(
            eqn, name=f"{label}:{name}" if label else name, scalars=scalars))
    return irs
