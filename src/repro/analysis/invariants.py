"""Static plan verifier — prove a :class:`SegmentPlan`'s schedule invariants.

The Segment dataflow's correctness rests on a web of implicit contracts
between the host-side planner and the Pallas kernels: ``accum_prev``
read-modify-writes must follow a same-lane ``seg_write``, DMA fetch flags
must fire exactly where an operand index changes within a lane, ring-buffer
slots must advance one step per fetch and never let an in-flight copy land
on a slot whose previous tile is still being read, pads must move no data.
Each of these has already produced a real runtime bug (see CHANGES.md);
this module checks all of them *statically* on the host arrays, so an
unsound schedule — hand-built, custom-policy, or autotuner-synthesized —
is rejected before a kernel ever runs on it.

Entry points:

* :func:`verify_plan` — run the invariant catalog over a plan (and its
  nested ``grad_plan``), returning typed :class:`Finding` records;
* :func:`check_lane_accum` — the single implementation of the
  ``accum_prev`` write-before-read check, shared with
  ``repro.core.schedule.partition_lanes``;
* :func:`check_traffic_agreement` — the reusable form of the
  model-vs-fetch-flag count gate ``benchmarks/kernel_bench.py`` ships.

Levels: ``"fast"`` runs every structural check (vectorized / per-lane
host passes, no block values touched); ``"full"`` additionally recomputes
the traffic model — a deliberately *independent* implementation of the
fetch contract — and demands exact count agreement with the flags and the
plan's recorded traffic estimate.

This module imports only ``repro.core`` (never ``repro.api``): the
verifier sits between the scheduler and the planner in the layering, so
``core.schedule`` may call into it lazily and ``api.planner`` may hook it
eagerly without a cycle.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.formats import (QUANT_DTYPES, quant_base_dtype,
                                quant_is_rowwise)
from repro.core.schedule import (fetch_flags, lane_traffic_spgemm,
                                 lane_traffic_spmm)

#: Invariant catalog: id -> one-line contract statement.  Every id here has
#: a mutation-kill test in ``tests/test_analysis.py`` proving the verifier
#: catches its violation.
INVARIANTS: Dict[str, str] = {
    "shape-agreement":
        "every per-item schedule array has length n_items (seg_start's)",
    "lane-divisibility":
        "n_items divides into n_lanes equal lanes; lane_len divides by "
        "unroll; an explicit N divides by bn",
    "index-bounds":
        "block-slot / coordinate / ring-slot indices address existing "
        "storage (slots < 2*unroll)",
    "segment-structure":
        "lanes start with a seg_start item, owners change only at segment "
        "heads, no partial sum is dropped before its seg_write",
    "accum-prev-order":
        "every accum_prev=1 read-modify-write follows a seg_write to the "
        "same output tile earlier in the same lane",
    "pads-fetch-nothing":
        "valid=0 pad items carry no seg/accum flags and issue no fetches",
    "lane-first-fetch":
        "a lane's first item is real and fetches both operands (lane cuts "
        "never inherit residency)",
    "fetch-on-change":
        "fetch flags fire exactly where the operand index differs from the "
        "previous item within the lane",
    "slot-advance":
        "ring slots advance one slot per fetch (mod 2*unroll) and reused "
        "items read the resident slot",
    "ring-war":
        "a fetch never lands on a slot whose previous tile is still "
        "unconsumed under the issue-one-step-ahead discipline",
    "scale-agreement":
        "quantized payload dtype and per-block scale shapes/dtypes agree "
        "with the plan's block_dtype",
    "traffic-agreement":
        "the traffic model's independent fetch counts equal the fetch-flag "
        "sums and the plan's recorded traffic exactly (level='full')",
}

#: More-specific findings suppress less-specific ones at the same
#: (path, stream, item) coordinate — one corruption reports one invariant.
_STREAM_SPECIFICITY = ("pads-fetch-nothing", "lane-first-fetch",
                      "fetch-on-change", "slot-advance", "ring-war")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One invariant violation, addressable to a schedule coordinate.

    ``item`` is the flat lane-major schedule index (``lane * lane_len +
    step_in_lane``) where the violation anchors, or None for plan-global
    findings; ``stream`` names the operand stream (``"a"``/``"b"``) for
    fetch-pipeline findings; ``path`` distinguishes the forward plan from
    the nested backward schedule (``"plan"`` vs ``"plan.grad_plan"``).
    """

    invariant: str
    message: str
    severity: str = "error"
    lane: Optional[int] = None
    item: Optional[int] = None
    stream: Optional[str] = None
    path: str = "plan"

    def __str__(self) -> str:
        where = self.path
        if self.lane is not None:
            where += f" lane {self.lane}"
        if self.item is not None:
            where += f" item {self.item}"
        return f"[{self.invariant}] {where}: {self.message}"


@dataclasses.dataclass(frozen=True)
class VerifyResult:
    """Outcome of one :func:`verify_plan` run."""

    findings: Tuple[Finding, ...]
    level: str
    checked: Tuple[str, ...]

    @property
    def ok(self) -> bool:
        return not self.findings

    def raise_if_findings(self) -> "VerifyResult":
        if self.findings:
            raise PlanVerificationError(self)
        return self

    def summary(self) -> str:
        if self.ok:
            return (f"plan verifies clean at level={self.level!r} "
                    f"({len(self.checked)} invariants)")
        lines = [f"plan verification failed: {len(self.findings)} finding(s) "
                 f"at level={self.level!r}"]
        lines += [f"  {f}" for f in self.findings]
        return "\n".join(lines)


class PlanVerificationError(ValueError):
    """Raised by ``raise_if_findings`` / ``plan_matmul(..., verify=...)``."""

    def __init__(self, result: VerifyResult):
        self.result = result
        self.findings = result.findings
        super().__init__(result.summary())


def _host(x) -> Optional[np.ndarray]:
    return None if x is None else np.asarray(x)


# ---------------------------------------------------------------------------
# Shared accum_prev write-before-read check (the one implementation — the
# planner path and partition_lanes' validation both route through here)
# ---------------------------------------------------------------------------


def check_lane_accum(owner, seg_start, seg_write, accum_prev, valid,
                     n_lanes: int, item_ids=None,
                     path: str = "plan") -> List[Finding]:
    """``accum_prev`` write-before-read over flat lane-major arrays.

    Every ``accum_prev=1`` segment head read-modify-writes its output tile,
    so a ``seg_write`` to that tile must already have happened earlier in
    the *same* lane — otherwise the kernel reads an output buffer nothing
    ever wrote (silent garbage).  ``item_ids`` optionally maps each
    lane-major position back to the original schedule item for messages
    (pads as -1).  Vectorized per lane (np.minimum.at first-read vs
    first-write per owner); runs on every verified plan build.
    """
    owner = np.asarray(owner).reshape(-1)
    seg_start = np.asarray(seg_start).reshape(-1)
    seg_write = np.asarray(seg_write).reshape(-1)
    accum_prev = np.asarray(accum_prev).reshape(-1)
    valid = np.asarray(valid).astype(bool).reshape(-1)
    ids = None if item_ids is None else np.asarray(item_ids).reshape(-1)
    out: List[Finding] = []
    if not valid.any():
        return out
    lane_len = owner.size // n_lanes
    # one flattened (lane, owner) key space: first-read vs first-write per
    # tile per lane in two minimum.at passes, no per-lane Python loop
    n_owner = int(owner[valid].max()) + 1
    key = (np.arange(owner.size) // lane_len) * n_owner + owner
    reads = valid & (seg_start == 1) & (accum_prev == 1)
    writes = valid & (seg_write == 1)
    big = np.iinfo(np.int64).max
    first_read = np.full(n_lanes * n_owner, big)
    np.minimum.at(first_read, key[reads], np.nonzero(reads)[0])
    first_write = np.full(n_lanes * n_owner, big)
    np.minimum.at(first_write, key[writes], np.nonzero(writes)[0])
    bad = np.nonzero((first_read < big) & (first_write >= first_read))[0]
    for k in bad.tolist():
        li, tile = divmod(k, n_owner)
        g = int(first_read[k])
        orig = int(ids[g]) if ids is not None else None
        label = (f"schedule item {orig}" if orig is not None
                 else f"lane-major item {g}")
        out.append(Finding(
            "accum-prev-order",
            f"{label} (output tile {tile}, lane {li}) has accum_prev=1 "
            f"but no earlier seg_write to that tile in the same lane — "
            f"the kernel would read-modify-write an output buffer "
            f"nothing wrote; the item's segment chain must follow its "
            f"tile's first write within one lane",
            lane=li, item=g, path=path))
    return out


# ---------------------------------------------------------------------------
# Fetch-pipeline checks (flags / slots / ring liveness)
# ---------------------------------------------------------------------------


def _check_pads(arrays: Dict[str, Optional[np.ndarray]], valid: np.ndarray,
                lane_len: int, path: str) -> List[Finding]:
    out: List[Finding] = []
    pads = ~valid
    if not pads.any():
        return out
    for name in ("seg_start", "seg_write", "accum_prev"):
        arr = arrays.get(name)
        if arr is None:
            continue
        bad = np.nonzero(pads & (arr != 0))[0]
        if bad.size:
            i = int(bad[0])
            out.append(Finding(
                "pads-fetch-nothing",
                f"pad item (valid=0) carries {name}={int(arr[i])}; pads "
                f"must neither initialize nor write any output tile "
                f"({bad.size} item(s))",
                lane=i // lane_len, item=i, path=path))
    for stream in ("a", "b"):
        arr = arrays.get(f"{stream}_fetch")
        if arr is None:
            continue
        bad = np.nonzero(pads & (arr != 0))[0]
        if bad.size:
            i = int(bad[0])
            out.append(Finding(
                "pads-fetch-nothing",
                f"pad item (valid=0) has {stream}_fetch=1; pads re-address "
                f"the resident ring slot and must issue no DMA "
                f"({bad.size} item(s))",
                lane=i // lane_len, item=i, stream=stream, path=path))
    return out


def _check_lane_first(arrays, valid, n_lanes: int, lane_len: int,
                      path: str) -> List[Finding]:
    out: List[Finding] = []
    if lane_len == 0:
        return out
    v2 = valid.reshape(n_lanes, -1)
    for li in range(n_lanes):
        head = li * lane_len
        if not v2[li, 0]:
            continue   # pad-start lanes are segment-structure's finding
        for stream in ("a", "b"):
            f = arrays.get(f"{stream}_fetch")
            if f is not None and f[head] != 1:
                out.append(Finding(
                    "lane-first-fetch",
                    f"lane's first item has {stream}_fetch="
                    f"{int(f[head])}; lane cuts and pass restarts never "
                    f"inherit residency, so the first item must fetch",
                    lane=li, item=head, stream=stream, path=path))
    return out


def _check_segment_structure(owner, seg_start, seg_write, valid,
                             n_lanes: int, path: str) -> List[Finding]:
    out: List[Finding] = []
    if owner is None or seg_start is None or seg_write is None:
        return out
    o2 = owner.reshape(n_lanes, -1)
    ss2 = seg_start.reshape(n_lanes, -1)
    sw2 = seg_write.reshape(n_lanes, -1)
    v2 = valid.reshape(n_lanes, -1)
    lane_len = o2.shape[1]
    for li in range(n_lanes):
        pos = np.nonzero(v2[li])[0]
        if pos.size == 0:
            continue
        first, last = int(pos[0]), int(pos[-1])
        if not v2[li, 0]:
            out.append(Finding(
                "segment-structure",
                "lane starts with a pad item — pads only follow real work "
                "(the forward-fill has nothing to fill from)",
                lane=li, item=li * lane_len, path=path))
        if v2[li, 0] and ss2[li, first] != 1:
            out.append(Finding(
                "segment-structure",
                "lane's first item has seg_start=0 — the accumulator holds "
                "another lane's tail and would leak into this output tile",
                lane=li, item=li * lane_len + first, path=path))
        if sw2[li, last] != 1:
            out.append(Finding(
                "segment-structure",
                "lane's last item has seg_write=0 — the final segment's "
                "partial sum is never written back",
                lane=li, item=li * lane_len + last, path=path))
        prev, cur = pos[:-1], pos[1:]
        owner_change = o2[li][prev] != o2[li][cur]
        no_start = ss2[li][cur] != 1
        bad = np.nonzero(owner_change & no_start)[0]
        if bad.size:
            j = int(cur[bad[0]])
            out.append(Finding(
                "segment-structure",
                f"output tile changes {int(o2[li][prev[bad[0]]])} -> "
                f"{int(o2[li][j])} without seg_start=1 — the new tile "
                f"would accumulate into the previous tile's partial sum",
                lane=li, item=li * lane_len + j, path=path))
        dropped = (sw2[li][prev] == 0) & (ss2[li][cur] == 1)
        bad = np.nonzero(dropped)[0]
        if bad.size:
            i = int(prev[bad[0]])
            out.append(Finding(
                "segment-structure",
                "segment re-starts before the running partial sum was "
                "seg_write-written — the accumulated contributions are "
                "silently dropped",
                lane=li, item=li * lane_len + i, path=path))
    return out


def _check_fetch_on_change(arrays, valid, n_lanes: int, depth: int,
                           path: str) -> List[Finding]:
    out: List[Finding] = []
    lane_len = valid.size // n_lanes if n_lanes else 0
    for stream, idx_name in (("a", "a_stream"), ("b", "b_stream")):
        f = arrays.get(f"{stream}_fetch")
        idx = arrays.get(idx_name)
        if f is None or idx is None:
            continue
        want, _ = fetch_flags(idx, valid, n_lanes, depth=depth)
        bad = np.nonzero(f.astype(np.int32) != want)[0]
        if bad.size:
            i = int(bad[0])
            out.append(Finding(
                "fetch-on-change",
                f"{stream}_fetch={int(f[i])} but the {stream} operand index "
                f"{'changes' if want[i] else 'is unchanged'} from the "
                f"previous item in the lane — flags must fire exactly on "
                f"index change ({bad.size} item(s) disagree)",
                lane=i // lane_len, item=i, stream=stream, path=path))
    return out


def _check_slots(arrays, valid, n_lanes: int, depth: int,
                 path: str) -> List[Finding]:
    """Ring-slot advance contract + bound, per lane per stream."""
    out: List[Finding] = []
    for stream in ("a", "b"):
        f = arrays.get(f"{stream}_fetch")
        s = arrays.get(f"{stream}_slot")
        if f is None or s is None:
            continue
        bad = np.nonzero((s < 0) | (s >= depth))[0]
        if bad.size:
            i = int(bad[0])
            lane_len = valid.size // n_lanes
            out.append(Finding(
                "index-bounds",
                f"{stream}_slot={int(s[i])} outside the ring "
                f"[0, {depth}) (depth = 2*unroll)",
                lane=i // lane_len, item=i, stream=stream, path=path))
            continue
        f2 = f.reshape(n_lanes, -1)
        s2 = s.reshape(n_lanes, -1)
        v2 = valid.reshape(n_lanes, -1)
        lane_len = f2.shape[1]
        # vectorized precheck: the simulation below is equivalent to
        # "slot == (fetches-so-far - 1) % depth" at every fetch item and at
        # every valid item with a prior fetch in the lane — one cumsum pass
        # settles the overwhelmingly common clean case, and the per-item
        # simulation runs only to pinpoint the first offending item
        c = np.cumsum(f2 == 1, axis=1)
        constrained = (f2 == 1) | (v2 & (c > 0))
        if not (constrained & (s2 != (c - 1) % depth)).any():
            continue
        for li in range(n_lanes):
            resident = None
            fl, sl, vl = f2[li].tolist(), s2[li].tolist(), v2[li].tolist()
            for j in range(lane_len):
                if fl[j] == 1:
                    expect = 0 if resident is None else (resident + 1) % depth
                    if sl[j] != expect:
                        out.append(Finding(
                            "slot-advance",
                            f"fetch lands in {stream}_slot={int(sl[j])}, "
                            f"expected slot {expect} — the ring advances "
                            f"exactly one slot per fetch so a reused tile "
                            f"is always the most recently copied one",
                            lane=li, item=li * lane_len + j, stream=stream,
                            path=path))
                        break
                    resident = int(sl[j])
                elif vl[j] and resident is not None and sl[j] != resident:
                    out.append(Finding(
                        "slot-advance",
                        f"non-fetch item reads {stream}_slot="
                        f"{int(sl[j])} but the resident tile lives in "
                        f"slot {resident}",
                        lane=li, item=li * lane_len + j, stream=stream,
                        path=path))
                    break
    return out


def _check_ring_war(arrays, valid, n_lanes: int, depth: int, unroll: int,
                    path: str) -> List[Finding]:
    """WAR liveness: a fetch into a slot is issued one grid step ahead of
    its item's step (prologue at step 0), so the slot's *previous* tile
    must have had its last meaningful (valid) read strictly before that
    issue step.  Simulated on the actual slot values — independent of the
    cumsum contract ``slot-advance`` enforces, so hand-built rings of a
    different depth are still judged on the safety property itself."""
    out: List[Finding] = []
    for stream in ("a", "b"):
        f = arrays.get(f"{stream}_fetch")
        s = arrays.get(f"{stream}_slot")
        if f is None or s is None:
            continue
        if ((s < 0) | (s >= depth)).any():
            continue   # index-bounds already reported; simulation undefined
        f2 = f.reshape(n_lanes, -1)
        s2 = s.reshape(n_lanes, -1)
        v2 = valid.reshape(n_lanes, -1)
        lane_len = f2.shape[1]
        for li in range(n_lanes):
            # last_read[slot] = lane step of the most recent *valid* read of
            # the tile currently resident in that slot
            last_read: Dict[int, int] = {}
            occupied: Dict[int, bool] = {}
            fl, sl, vl = f2[li].tolist(), s2[li].tolist(), v2[li].tolist()
            for j in range(lane_len):
                if fl[j] == 1:
                    slot = sl[j]
                    issue_step = max(j // unroll - 1, 0)
                    if occupied.get(slot) and slot in last_read \
                            and last_read[slot] // unroll >= issue_step:
                        out.append(Finding(
                            "ring-war",
                            f"fetch into {stream}_slot={slot} is issued at "
                            f"grid step {issue_step} but the slot's "
                            f"previous tile is still read at step "
                            f"{last_read[slot] // unroll} — the in-flight "
                            f"copy would overwrite a tile in use "
                            f"(ring depth {depth}, unroll {unroll})",
                            lane=li, item=li * lane_len + j, stream=stream,
                            path=path))
                        break
                    occupied[slot] = True
                    last_read.pop(slot, None)
                if vl[j]:
                    last_read[sl[j]] = j
            else:
                continue
            break   # one finding per stream is enough
    return out


# ---------------------------------------------------------------------------
# Scale / traffic checks
# ---------------------------------------------------------------------------


def _check_scales(plan, path: str) -> List[Finding]:
    out: List[Finding] = []
    quant = getattr(plan, "block_dtype", "fp32") != "fp32"
    pairs = [("lhs", getattr(plan, "lhs_blocks", None),
              getattr(plan, "lhs_scales", None))]
    if getattr(plan, "kind", None) == "spgemm":
        pairs.append(("rhs", getattr(plan, "rhs_blocks", None),
                      getattr(plan, "rhs_scales", None)))
    for side, blocks, scales in pairs:
        if quant:
            rowwise = quant_is_rowwise(plan.block_dtype)
            want = QUANT_DTYPES[quant_base_dtype(plan.block_dtype)]
            if blocks is not None and np.dtype(blocks.dtype) != want:
                out.append(Finding(
                    "scale-agreement",
                    f"{side}_blocks dtype {np.dtype(blocks.dtype)} does not "
                    f"match block_dtype={plan.block_dtype!r} (payload "
                    f"{want})", path=path))
            if blocks is not None and scales is None:
                out.append(Finding(
                    "scale-agreement",
                    f"quantized plan carries {side}_blocks but no "
                    f"{side}_scales — dequantization is impossible",
                    path=path))
            if scales is not None:
                if np.dtype(scales.dtype) != np.float32:
                    out.append(Finding(
                        "scale-agreement",
                        f"{side}_scales dtype {np.dtype(scales.dtype)} "
                        f"must be float32", path=path))
                if blocks is not None:
                    # rowwise scales run over the block's *storage* rows
                    # (bm for lhs, bk for a SpGEMM rhs)
                    expect = ((int(blocks.shape[0]), int(blocks.shape[1]))
                              if rowwise else (int(blocks.shape[0]),))
                    if tuple(scales.shape) != expect:
                        gran = ("per block row" if rowwise
                                else "per stored block")
                        out.append(Finding(
                            "scale-agreement",
                            f"{side}_scales shape {tuple(scales.shape)} "
                            f"must be one fp32 scale {gran} {expect} for "
                            f"block_dtype={plan.block_dtype!r}",
                            path=path))
        else:
            if scales is not None:
                out.append(Finding(
                    "scale-agreement",
                    f"fp32 plan carries {side}_scales — scales without a "
                    f"quantized payload would silently rescale the result",
                    path=path))
            if blocks is not None and \
                    np.dtype(blocks.dtype) in QUANT_DTYPES.values():
                out.append(Finding(
                    "scale-agreement",
                    f"{side}_blocks has quantized payload dtype "
                    f"{np.dtype(blocks.dtype)} but block_dtype is 'fp32'",
                    path=path))
    return out


def check_scale_agreement(plan, path: str = "plan") -> List[Finding]:
    """The ``scale-agreement`` invariant alone — dtype/shape inspection
    only, no schedule-array work.  This is the per-realize check
    ``plan_matmul(verify=...)`` runs on every cache hit (the schedule
    template was already verified at build), so it must stay O(1)."""
    return _check_scales(plan, path)


def check_traffic_agreement(plan, path: str = "plan") -> List[Finding]:
    """Model-vs-flags fetch-count gate (the reusable form of the old
    ``kernel_bench`` inline assertion).

    Recomputes the traffic model's A/B fetch counts from the plan's index
    streams — :func:`repro.core.schedule._revisit_traffic` is a
    deliberately independent implementation of the change-detection
    contract the fetch flags compile — and demands exact equality with the
    fetch-flag sums and with the counts recorded in ``plan.traffic``.
    Counts are size-independent, so the model runs at unit tile sizes.

    The fetch flags always implement the *pipelined* per-item-adjacency
    contract (they are pipeline-independent plan leaves), so the flag
    comparison uses the pipelined model unconditionally; the recorded
    ``plan.traffic`` counts follow the plan's ``pipeline`` switch — a
    ``pipeline=False`` plan records legacy per-BlockSpec-stream pricing and
    is checked against that model.

    A ``prefetch="cross_pass"`` plan records a ``prefetch_fetches`` count
    (the copies the kernel overlaps with each pass boundary); the model is
    recomputed under the same mode and must agree exactly — cross-pass
    prefetch never changes *which* items fetch, so the a/b counts above are
    mode-independent by construction.
    """
    out: List[Finding] = []
    a_fetch = _host(getattr(plan, "a_fetch", None))
    b_fetch = _host(getattr(plan, "b_fetch", None))
    valid = _host(getattr(plan, "valid", None))
    seg_start = _host(getattr(plan, "seg_start", None))
    if a_fetch is None or b_fetch is None or valid is None \
            or seg_start is None:
        return out
    n_lanes, unroll = plan.n_lanes, plan.unroll
    pipelined = bool(getattr(plan, "pipeline", True))
    prefetch = getattr(plan, "prefetch", None)
    if plan.kind == "spmm":
        m = _host(plan.m_idx)
        k = _host(plan.k_idx)
        if m is None or k is None:
            return out
        model = lane_traffic_spmm(m, k, seg_start, valid.astype(bool),
                                  n_lanes, 1, 1, 1, unroll=unroll)
        rec_model = lane_traffic_spmm(
            m, k, seg_start, valid.astype(bool), n_lanes, 1, 1, 1,
            unroll=unroll, pipeline=pipelined, prefetch=prefetch)
    else:
        a_idx, b_idx, c_idx = (_host(plan.a_idx), _host(plan.b_idx),
                               _host(plan.c_idx))
        if a_idx is None or b_idx is None or c_idx is None:
            return out
        model = lane_traffic_spgemm(a_idx, b_idx, c_idx, seg_start,
                                    valid.astype(bool), n_lanes, 1, 1, 1,
                                    unroll=unroll)
        rec_model = lane_traffic_spgemm(
            a_idx, b_idx, c_idx, seg_start, valid.astype(bool), n_lanes,
            1, 1, 1, unroll=unroll, pipeline=pipelined, prefetch=prefetch)
    recorded = dict(getattr(plan, "traffic_items", ()) or ())
    for stream, flags in (("a", a_fetch), ("b", b_fetch)):
        n_model = int(model[f"{stream}_fetches"])
        n_flags = int(flags.sum())
        if n_model != n_flags:
            out.append(Finding(
                "traffic-agreement",
                f"traffic model predicts {n_model} {stream}-stream fetches "
                f"but the fetch flags sum to {n_flags} — the model and "
                f"fetch_flags implement the same change-detection contract "
                f"independently and must agree exactly",
                stream=stream, path=path))
        n_rec = recorded.get(f"{stream}_fetches")
        n_rec_model = int(rec_model[f"{stream}_fetches"])
        if n_rec is not None and int(n_rec) != n_rec_model:
            out.append(Finding(
                "traffic-agreement",
                f"plan.traffic records {int(n_rec)} {stream}-stream fetches "
                f"but the model recomputes {n_rec_model} "
                f"(pipeline={'on' if pipelined else 'off'} pricing) — the "
                f"recorded estimate is stale or was tampered with",
                stream=stream, path=path))
    n_pf_rec = recorded.get("prefetch_fetches")
    if n_pf_rec is not None:
        n_pf_model = int(rec_model.get("prefetch_fetches", 0))
        if int(n_pf_rec) != n_pf_model:
            out.append(Finding(
                "traffic-agreement",
                f"plan.traffic records {int(n_pf_rec)} overlapped prefetch "
                f"fetches but the model recomputes {n_pf_model} under "
                f"prefetch={prefetch!r} — the recorded estimate is stale "
                f"or the mode changed without re-pricing",
                stream="prefetch", path=path))
    return out


# ---------------------------------------------------------------------------
# verify_plan — the catalog runner
# ---------------------------------------------------------------------------


def _verify_one(plan, level: str, only: Optional[Sequence[str]],
                bn: Optional[int], n_cols: Optional[int],
                path: str) -> Tuple[List[Finding], List[str]]:
    run = (lambda inv: only is None or inv in only)
    findings: List[Finding] = []
    checked: List[str] = []

    seg_start = _host(getattr(plan, "seg_start", None))
    if seg_start is None:
        if run("shape-agreement"):
            checked.append("shape-agreement")
            findings.append(Finding(
                "shape-agreement",
                "plan carries no seg_start array — the schedule length is "
                "undefined", path=path))
        return findings, checked
    n_items = int(seg_start.shape[0])
    n_lanes = max(int(getattr(plan, "n_lanes", 1)), 1)
    unroll = max(int(getattr(plan, "unroll", 1)), 1)
    depth = 2 * unroll

    spgemm = getattr(plan, "kind", "spmm") == "spgemm"
    arrays: Dict[str, Optional[np.ndarray]] = {
        "seg_start": seg_start,
        "seg_write": _host(getattr(plan, "seg_write", None)),
        "accum_prev": _host(getattr(plan, "accum_prev", None)),
        "valid": _host(getattr(plan, "valid", None)),
        "a_fetch": _host(getattr(plan, "a_fetch", None)),
        "b_fetch": _host(getattr(plan, "b_fetch", None)),
        "a_slot": _host(getattr(plan, "a_slot", None)),
        "b_slot": _host(getattr(plan, "b_slot", None)),
    }
    if spgemm:
        arrays["a_idx"] = _host(getattr(plan, "a_idx", None))
        arrays["b_idx"] = _host(getattr(plan, "b_idx", None))
        arrays["c_idx"] = _host(getattr(plan, "c_idx", None))
        owner = arrays["c_idx"]
        arrays["a_stream"] = arrays["a_idx"]
        arrays["b_stream"] = arrays["b_idx"]
    else:
        arrays["m_idx"] = _host(getattr(plan, "m_idx", None))
        arrays["k_idx"] = _host(getattr(plan, "k_idx", None))
        arrays["slot_idx"] = _host(getattr(plan, "slot_idx", None))
        owner = arrays["m_idx"]
        arrays["a_stream"] = arrays["slot_idx"]
        arrays["b_stream"] = arrays["k_idx"]

    if run("shape-agreement"):
        checked.append("shape-agreement")
        for name, arr in arrays.items():
            if name.endswith("_stream") or arr is None:
                continue
            if arr.shape != (n_items,):
                findings.append(Finding(
                    "shape-agreement",
                    f"{name} has shape {arr.shape}, expected ({n_items},) "
                    f"to match the schedule's n_items (seg_start length)",
                    path=path))
        if findings:
            return findings, checked   # lengths disagree: nothing else is safe

    if run("lane-divisibility"):
        checked.append("lane-divisibility")
        if n_items % n_lanes != 0:
            findings.append(Finding(
                "lane-divisibility",
                f"n_items={n_items} is not divisible by n_lanes={n_lanes}; "
                f"lanes must be equal length (pad via partition_lanes)",
                path=path))
            return findings, checked   # lane reshapes below would crash
        lane_len = n_items // n_lanes
        if lane_len % unroll != 0:
            findings.append(Finding(
                "lane-divisibility",
                f"lane length {lane_len} is not divisible by "
                f"unroll={unroll}", path=path))
            return findings, checked
        if bn is not None and n_cols is not None and n_cols % bn != 0:
            findings.append(Finding(
                "lane-divisibility",
                f"dense width N={n_cols} is not divisible by bn={bn} "
                f"(pad N or pick a divisor; see repro.api.pick_bn)",
                path=path))
    lane_len = n_items // n_lanes if n_items % n_lanes == 0 else n_items

    valid = arrays["valid"]
    valid = (np.ones(n_items, dtype=bool) if valid is None
             else valid.astype(bool))
    if n_items == 0:
        # degenerate empty schedule (e.g. an all-masked symbolic spgemm
        # pattern): the executor short-circuits before any kernel runs, so
        # an empty plan is vacuously sound.
        for inv in ("index-bounds", "segment-structure", "accum-prev-order",
                    "pads-fetch-nothing", "lane-first-fetch",
                    "fetch-on-change", "slot-advance", "ring-war"):
            if run(inv):
                checked.append(inv)
        if run("scale-agreement"):
            checked.append("scale-agreement")
            findings.extend(_check_scales(plan, path))
        return findings, checked

    # one pass serves both invariants it reports (ring bound -> index-bounds,
    # advance contract -> slot-advance)
    slot_findings = (_check_slots(arrays, valid, n_lanes, depth, path)
                     if run("index-bounds") or run("slot-advance") else [])

    if run("index-bounds"):
        checked.append("index-bounds")
        bounds = []
        if spgemm:
            for name, attr in (("a_idx", "a_brow"), ("b_idx", "b_brow")):
                ref = getattr(plan, attr, None)
                if arrays[name] is not None and ref is not None:
                    bounds.append((name, arrays[name], int(ref.shape[0])))
            if arrays["c_idx"] is not None:
                bounds.append(("c_idx", arrays["c_idx"],
                               int(getattr(plan, "n_out_blocks", 0))))
        else:
            ref = getattr(plan, "a_brow", None)
            if arrays["slot_idx"] is not None and ref is not None:
                bounds.append(("slot_idx", arrays["slot_idx"],
                               int(ref.shape[0])))
            grid = getattr(plan, "grid", None)
            if grid is not None:
                if arrays["m_idx"] is not None:
                    bounds.append(("m_idx", arrays["m_idx"], int(grid[0])))
                if arrays["k_idx"] is not None:
                    bounds.append(("k_idx", arrays["k_idx"], int(grid[1])))
        for name, arr, hi in bounds:
            bad = np.nonzero((arr < 0) | (arr >= hi))[0]
            if bad.size:
                i = int(bad[0])
                findings.append(Finding(
                    "index-bounds",
                    f"{name}={int(arr[i])} outside [0, {hi})",
                    lane=i // lane_len, item=i, path=path))
        findings.extend(f for f in slot_findings
                        if f.invariant == "index-bounds")

    if run("segment-structure"):
        checked.append("segment-structure")
        findings.extend(_check_segment_structure(
            owner, arrays["seg_start"], arrays["seg_write"], valid,
            n_lanes, path))

    if run("accum-prev-order") and owner is not None \
            and arrays["accum_prev"] is not None:
        checked.append("accum-prev-order")
        findings.extend(check_lane_accum(
            owner, arrays["seg_start"], arrays["seg_write"],
            arrays["accum_prev"], valid, n_lanes, path=path))

    if run("pads-fetch-nothing"):
        checked.append("pads-fetch-nothing")
        findings.extend(_check_pads(arrays, valid, lane_len, path))

    if run("lane-first-fetch"):
        checked.append("lane-first-fetch")
        findings.extend(
            f for f in _check_lane_first(arrays, valid, n_lanes, lane_len,
                                         path)
            if f.invariant == "lane-first-fetch")

    if run("fetch-on-change"):
        checked.append("fetch-on-change")
        findings.extend(_check_fetch_on_change(arrays, valid, n_lanes,
                                               depth, path))

    if run("slot-advance"):
        checked.append("slot-advance")
        findings.extend(f for f in slot_findings
                        if f.invariant == "slot-advance")

    if run("ring-war"):
        checked.append("ring-war")
        findings.extend(_check_ring_war(arrays, valid, n_lanes, depth,
                                        unroll, path))

    if run("scale-agreement"):
        checked.append("scale-agreement")
        findings.extend(_check_scales(plan, path))

    if level == "full" and run("traffic-agreement"):
        checked.append("traffic-agreement")
        findings.extend(check_traffic_agreement(plan, path=path))

    return findings, checked


def _suppress(findings: List[Finding]) -> List[Finding]:
    """Keep the most specific finding per (path, stream, item) coordinate.

    One targeted corruption should report one invariant: a pad marked as
    fetching also breaks the fetch-recompute and slot contracts, but the
    pad violation is the root cause.  Count-level ``traffic-agreement``
    findings are dropped for a stream whose per-item contract already
    failed (the count mismatch is a consequence, not new information).
    """
    rank = {inv: i for i, inv in enumerate(_STREAM_SPECIFICITY)}
    best: Dict[Tuple[str, Optional[str], Optional[int]], int] = {}
    broken_streams = set()
    for f in findings:
        if f.invariant in rank:
            key = (f.path, f.stream, f.item)
            r = rank[f.invariant]
            if key not in best or r < best[key]:
                best[key] = r
            broken_streams.add((f.path, f.stream))
            if f.stream is None:
                broken_streams.update({(f.path, "a"), (f.path, "b")})
    out = []
    for f in findings:
        if f.invariant in rank:
            key = (f.path, f.stream, f.item)
            if rank[f.invariant] > best.get(key, rank[f.invariant]):
                continue
            # a broken upstream item also explains downstream slot/ring
            # findings on the same stream at later items
            if f.invariant in ("slot-advance", "ring-war"):
                upstream = [g for g in findings
                            if g.path == f.path and g.stream == f.stream
                            and g.invariant in rank
                            and rank[g.invariant] < rank[f.invariant]]
                if upstream:
                    continue
        elif f.invariant == "traffic-agreement" \
                and (f.path, f.stream) in broken_streams:
            continue
        out.append(f)
    return out


def verify_plan(plan, level: str = "fast", *,
                invariants: Optional[Sequence[str]] = None,
                bn: Optional[int] = None,
                n_cols: Optional[int] = None) -> VerifyResult:
    """Run the invariant catalog over a plan (and its ``grad_plan``).

    Args:
      plan: a :class:`~repro.api.plan.SegmentPlan` (realized or a
        value-free template plan — block values are never read, only
        shapes/dtypes).
      level: ``"fast"`` runs every structural check; ``"full"`` adds the
        independent traffic-model recomputation (``traffic-agreement``).
      invariants: optionally restrict the run to a subset of catalog ids
        (e.g. ``("ring-war",)`` to judge the liveness property in
        isolation — ``slot-advance``'s exact cumsum contract subsumes it
        on planner-built rings).
      bn / n_cols: optional execution-time tile width and dense N; when
        both are given their divisibility is checked too.

    Returns a :class:`VerifyResult`; call ``raise_if_findings()`` to turn
    findings into a :class:`PlanVerificationError`.
    """
    if level not in ("fast", "full"):
        raise ValueError(f"level must be 'fast' or 'full', got {level!r}")
    if invariants is not None:
        unknown = set(invariants) - set(INVARIANTS)
        if unknown:
            raise ValueError(f"unknown invariant id(s) {sorted(unknown)}; "
                             f"catalog: {sorted(INVARIANTS)}")
    findings, checked = _verify_one(plan, level, invariants, bn, n_cols,
                                    "plan")
    grad = getattr(plan, "grad_plan", None)
    if grad is not None:
        gf, gc = _verify_one(grad, level, invariants, bn, n_cols,
                             "plan.grad_plan")
        findings.extend(gf)
        checked.extend(c for c in gc if c not in checked)
    return VerifyResult(findings=tuple(_suppress(findings)), level=level,
                        checked=tuple(checked))
