"""Race, ring-buffer WAR, and semaphore-balance proofs over the access IR.

Three rules, all driven by the slot-granular :class:`~.accesses.KernelIR`:

``parallel-race``
    Per axis declared ``parallel`` in ``dimension_semantics``, prove no two
    iterations write (or read-and-write) overlapping regions:

    * **blocked outputs** — the region a grid point touches is its BlockSpec
      index-map coordinate tuple; all writers of a region must share one
      parallel-coordinate signature, and every reader of a written region
      must share the writer's signature (the ``accum_prev``
      read-modify-write path is legal exactly because the planner pins
      folded continuations to the writer's lane);
    * **scratch refs** — Mosaic revisits scratch across sequential steps but
      gives no ordering across parallel iterations, so at every *parallel
      entry point* (a grid point whose row-major predecessor differs in a
      parallel coordinate) each scratch read must be covered by an earlier
      same-point write of its slot.  A kernel that accumulates into scratch
      across the parallel axis (the classic cross-lane bug) fails here.

``ring-slot-war``
    Kernel-side strengthening of ``invariants.py``'s schedule-side
    ``ring-war`` simulation: per *pass-local* sequential chain (a
    parallel-signature chain split at every pass boundary — see
    :func:`~.order.pass_local_chains`), a per-(ref, slot) in-flight
    counter driven by ``dma_start``/``dma_wait`` events; any read of a
    ring slot whose copy is still in flight is a write-after-read /
    read-under-copy hazard.  This is the slot-granular check the syntactic
    linter's documented ref-base false negative could not express.
    In-flight state that legitimately crosses a pass boundary (the
    cross-pass prefetch contract) is owned by :mod:`~.order`'s
    ``cross-pass-war`` rule, so this rule resets at the boundary; for
    kernels with at most one sequential axis the two framings coincide.

``sem-balance``
    Path-sensitive semaphore balance: DMA starts and waits are counted per
    (semaphore, slot) along every ``pl.when`` path — the guard masks are
    resolved per grid point, so a wait present on only one branch of a
    ``pl.when`` shows up as a start/wait imbalance on the other branch's
    points.  Data-dependent guards the interpreter cannot resolve yield an
    explicit "unprovable" finding rather than a silent pass.

All three rules treat unknown guards conservatively (may-execute for
hazard-producing events, must-execute required for hazard-discharging
ones), so a clean report is a proof over the analyzed grid.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from .accesses import TOP, Access, KernelIR, READ_KINDS, WRITE_KINDS
from .jaxpr_lint import LintFinding
from .order import pass_local_chains

RULE_RACE = "parallel-race"
RULE_RING = "ring-slot-war"
RULE_SEM = "sem-balance"

#: catalog of the symbolic analyzer's rules (the syntactic linter keeps its
#: own ``RULES``); ``index-range`` lives in :mod:`ranges`, ``vmem-budget``
#: in :mod:`budget`.
ANALYZER_RULES = {
    "index-range": "proven out-of-bounds pl.ds / dynamic-slice footprint",
    RULE_RACE: "parallel-axis iterations overlap on an output/scratch ref",
    RULE_RING: "ring-buffer slot read while its DMA copy is in flight",
    RULE_SEM: "DMA start/wait unbalanced along some pl.when path",
    "vmem-budget": "scratch + operand block windows exceed the VMEM limit",
}


def _slot_at(val, p: int):
    if val is TOP:
        return TOP
    if isinstance(val, str):            # "all": full leading slice
        return val
    if isinstance(val, np.ndarray):
        return int(val[p])
    return int(val)


def _parallel_sig(ir: KernelIR, p: int) -> Tuple[int, ...]:
    return tuple(int(ir.coords[ax][p]) for ax in ir.parallel_axes)


def _chains(ir: KernelIR) -> List[np.ndarray]:
    """Grid points grouped by parallel signature, each in row-major
    (sequential execution) order.  With no parallel axis the whole grid is
    one sequential chain."""
    G = ir.n_points
    if not ir.parallel_axes:
        return [np.arange(G)]
    sig = np.zeros(G, dtype=np.int64)
    for ax in ir.parallel_axes:
        sig = sig * ir.grid[ax] + ir.coords[ax]
    order = np.argsort(sig, kind="stable")
    chains = []
    sorted_sig = sig[order]
    start = 0
    for i in range(1, G + 1):
        if i == G or sorted_sig[i] != sorted_sig[start]:
            chains.append(np.sort(order[start:i]))
            start = i
    return chains


def _entry_points(ir: KernelIR) -> np.ndarray:
    """Flat indices whose row-major predecessor has a different parallel
    signature (the first point Mosaic may schedule with cold scratch)."""
    G = ir.n_points
    if not ir.parallel_axes:
        return np.array([0], dtype=np.int64)
    entries = [0]
    for p in range(1, G):
        if _parallel_sig(ir, p) != _parallel_sig(ir, p - 1):
            entries.append(p)
    return np.asarray(entries, dtype=np.int64)


# ---------------------------------------------------------------------------
# parallel-race
# ---------------------------------------------------------------------------


def _region_key(ir: KernelIR, acc: Access, p: int):
    """Hashable region identifier for an output access at point ``p``:
    the BlockSpec coordinate tuple for blocked refs, the explicit
    footprint (start, size) tuple otherwise.  ``None`` = unresolvable."""
    coords = ir.block_coords.get(acc.ref.name)
    if coords is not None:
        key = []
        for c in coords:
            if c is TOP:
                return None
            key.append(int(c[p]) if isinstance(c, np.ndarray) else int(c))
        return tuple(key)
    key = []
    for d in acc.dims:
        if d.full:
            key.append(("full",))
            continue
        if d.start is TOP or d.size is TOP:
            return None
        s = int(d.start[p]) if isinstance(d.start, np.ndarray) \
            else int(d.start)
        key.append((s, int(d.size)))
    return tuple(key)


def _check_output_regions(ir: KernelIR, findings: List[LintFinding]) -> None:
    out_refs = {r.name for r in ir.refs if r.role == "output"}
    if not out_refs:
        return
    # region -> (writer sigs, reader sigs, unprovable?)
    regions: Dict[Tuple, Dict[str, set]] = {}
    flagged = set()
    for acc in ir.accesses:
        if acc.ref.name not in out_refs:
            continue
        is_write = acc.kind in WRITE_KINDS
        is_read = acc.kind in READ_KINDS
        if not (is_write or is_read):
            continue
        mask = ir.may_mask(acc)
        for p in np.nonzero(mask)[0]:
            key = _region_key(ir, acc, int(p))
            if key is None:
                if acc.ref.name not in flagged:
                    flagged.add(acc.ref.name)
                    findings.append(LintFinding(
                        rule=RULE_RACE,
                        message=(f"cannot resolve the region {acc.kind} on "
                                 f"{acc.ref.name} touches — parallel-axis "
                                 f"disjointness unprovable"),
                        kernel=ir.name))
                continue
            slot = regions.setdefault((acc.ref.name,) + key,
                                      {"w": set(), "r": set()})
            sig = _parallel_sig(ir, int(p))
            if is_write:
                slot["w"].add(sig)
            if is_read:
                slot["r"].add(sig)
    for (name, *key), slot in regions.items():
        if name in flagged:
            continue
        if len(slot["w"]) > 1:
            flagged.add(name)
            findings.append(LintFinding(
                rule=RULE_RACE,
                message=(f"output {name} region {tuple(key)} is written by "
                         f"{len(slot['w'])} distinct parallel iterations "
                         f"{sorted(slot['w'])}"),
                kernel=ir.name))
        elif slot["w"] and not slot["r"] <= slot["w"]:
            flagged.add(name)
            others = sorted(slot["r"] - slot["w"])
            findings.append(LintFinding(
                rule=RULE_RACE,
                message=(f"output {name} region {tuple(key)} written by "
                         f"parallel iteration {sorted(slot['w'])[0]} but "
                         f"read by {others}"),
                kernel=ir.name))


def _covers(write: Access, read: Access, p: int) -> bool:
    """Does ``write`` at point ``p`` fully initialize what ``read`` reads?"""
    if all(d.full for d in write.dims):
        return True
    if not write.dims or not read.dims:
        return False
    ws = _slot_at(write.slot(), p)
    rs = _slot_at(read.slot(), p)
    if ws is TOP or rs is TOP:
        return False
    if ws != "all" and rs != "all" and ws != rs:
        return False
    if ws == "all" and rs != "all":
        pass                     # full leading slice covers any slot
    elif ws != "all" and rs == "all":
        return False             # slot write cannot cover a full read
    return write.rest_full()


def _check_scratch_entries(ir: KernelIR, findings: List[LintFinding]) -> None:
    scratch = {r.name for r in ir.refs
               if r.role == "scratch" and r.memspace not in ("semaphore",)}
    if not scratch:
        return
    entries = _entry_points(ir)
    if not ir.parallel_axes:
        entries = entries[:1]        # only the cold start matters
    flagged = set()
    for name in scratch:
        reads = [a for a in ir.accesses
                 if a.ref.name == name and a.kind in READ_KINDS]
        writes = [a for a in ir.accesses
                  if a.ref.name == name and a.kind in WRITE_KINDS]
        for acc in reads:
            may = ir.may_mask(acc)
            for p in entries:
                p = int(p)
                if not may[p]:
                    continue
                covered = any(
                    w.seq < acc.seq and ir.must_mask(w)[p]
                    and _covers(w, acc, p) for w in writes)
                if not covered and name not in flagged:
                    flagged.add(name)
                    findings.append(LintFinding(
                        rule=RULE_RACE,
                        message=(f"scratch {name} may be read at parallel "
                                 f"entry point grid{ir.point(p)} before any "
                                 f"same-iteration write — value leaks "
                                 f"across a parallel axis"),
                        kernel=ir.name))
                    break
            if name in flagged:
                break
    return


def check_parallel_races(ir: KernelIR) -> List[LintFinding]:
    """The ``parallel-race`` rule (vacuous without parallel axes)."""
    findings: List[LintFinding] = []
    if not ir.parallel_axes:
        return findings
    _check_output_regions(ir, findings)
    _check_scratch_entries(ir, findings)
    return findings


# ---------------------------------------------------------------------------
# ring-slot-war
# ---------------------------------------------------------------------------


def check_ring_war(ir: KernelIR) -> List[LintFinding]:
    """Per-slot in-flight tracking along each pass-local sequential chain:
    reading a ring-buffer slot whose DMA copy has started but not been
    waited on is a read-under-copy hazard.  State resets at every pass
    boundary — cross-boundary residency is the prefetch contract that
    :func:`~.order.check_cross_pass_war` proves."""
    findings: List[LintFinding] = []
    dma_refs = {a.ref.name for a in ir.accesses if a.kind == "dma_dst"}
    if not dma_refs:
        return findings
    events = [a for a in ir.accesses
              if a.ref.name in dma_refs
              and a.kind in ("dma_dst", "dma_wait", "read")]
    events.sort(key=lambda a: a.seq)
    flagged = set()
    unprovable = set()
    for chain in pass_local_chains(ir):
        inflight: Dict[Tuple[str, int], int] = {}
        for p in chain:
            p = int(p)
            for acc in events:
                if not ir.may_mask(acc)[p]:
                    continue
                slot = _slot_at(acc.slot(), p)
                if slot is TOP:
                    if acc.ref.name not in unprovable:
                        unprovable.add(acc.ref.name)
                        findings.append(LintFinding(
                            rule=RULE_RING,
                            message=(f"cannot resolve the ring slot of a "
                                     f"{acc.kind} on {acc.ref.name} — WAR "
                                     f"safety unprovable"),
                            kernel=ir.name))
                    continue
                slots = ([s for s in range(acc.ref.shape[0] or 1)]
                         if slot == "all" else [slot])
                for s in slots:
                    key = (acc.ref.name, s)
                    if acc.kind == "dma_dst":
                        # dma_wait discharges, so only count certain starts
                        if ir.must_mask(acc)[p] or not acc.certain:
                            inflight[key] = inflight.get(key, 0) + 1
                    elif acc.kind == "dma_wait":
                        if ir.must_mask(acc)[p]:
                            inflight[key] = max(0, inflight.get(key, 0) - 1)
                    else:                       # read
                        if inflight.get(key, 0) > 0 and key not in flagged:
                            flagged.add(key)
                            findings.append(LintFinding(
                                rule=RULE_RING,
                                message=(f"slot {s} of {acc.ref.name} read "
                                         f"at grid{ir.point(p)} while its "
                                         f"DMA copy is still in flight"),
                                kernel=ir.name))
    return findings


# ---------------------------------------------------------------------------
# sem-balance
# ---------------------------------------------------------------------------


def check_sem_balance(ir: KernelIR) -> List[LintFinding]:
    """Exact per-(semaphore, slot) start/wait counting along each
    sequential chain, with every ``pl.when`` guard resolved per grid point.
    Guards the interpreter cannot resolve produce an explicit
    "unprovable" finding."""
    findings: List[LintFinding] = []
    events = [a for a in ir.accesses
              if a.kind in ("dma_dst", "dma_wait") and a.sem is not None]
    if not events:
        return findings
    events.sort(key=lambda a: a.seq)
    unprovable = set()
    reported = set()
    for acc in events:
        bad = (not acc.certain) or acc.in_loop or acc.sem_slot is TOP
        if bad and acc.sem.name not in unprovable:
            unprovable.add(acc.sem.name)
            why = ("guard is data-dependent" if not acc.certain
                   else "slot is unresolved" if acc.sem_slot is TOP
                   else "op sits inside a loop body")
            findings.append(LintFinding(
                rule=RULE_SEM,
                message=(f"semaphore {acc.sem.name}: balance unprovable — "
                         f"{why} on a "
                         f"{'start' if acc.kind == 'dma_dst' else 'wait'}"),
                kernel=ir.name))
    for chain in _chains(ir):
        counts: Dict[Tuple[str, int], int] = {}
        for p in chain:
            p = int(p)
            for acc in events:
                if acc.sem.name in unprovable:
                    continue
                if acc.mask is None or not acc.mask[p]:
                    continue
                slot = _slot_at(acc.sem_slot, p)
                slots = ([s for s in range(acc.sem.shape[0] or 1)]
                         if slot == "all" else [slot])
                for s in slots:
                    key = (acc.sem.name, s)
                    if acc.kind == "dma_dst":
                        counts[key] = counts.get(key, 0) + 1
                    else:
                        if counts.get(key, 0) == 0:
                            if key not in reported:
                                reported.add(key)
                                findings.append(LintFinding(
                                    rule=RULE_SEM,
                                    message=(f"semaphore {acc.sem.name} slot "
                                             f"{s}: wait at grid"
                                             f"{ir.point(p)} has no matching "
                                             f"DMA start on this path"),
                                    kernel=ir.name))
                        else:
                            counts[key] -= 1
        for (name, s), c in counts.items():
            if c > 0 and (name, s, "leftover") not in reported:
                reported.add((name, s, "leftover"))
                findings.append(LintFinding(
                    rule=RULE_SEM,
                    message=(f"semaphore {name} slot {s}: {c} DMA start(s) "
                             f"never waited on along some pl.when path"),
                    kernel=ir.name))
    return findings
