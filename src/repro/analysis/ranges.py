"""Index-range proofs over the kernel access IR.

Reduces the per-grid-point index vectors of :mod:`accesses` to interval
facts and reports every access footprint it can *prove* out of bounds of
its ref extent.  Because the underlying domain is exact per-point constant
propagation (not a widening interval lattice), a reported violation is a
real out-of-bounds access at a concrete grid point — there are no range
false positives.  Unknown (TOP) indices are not reported here; they simply
carry no proof either way (the race/semaphore passes degrade to
"unprovable" findings on the accesses that matter for soundness).

Guard masks are honored: an index that would run off the end of a schedule
array at the final grid step is fine when the access is provably guarded by
``pl.when(s + 1 < n_steps)`` — the min/max reduction only ranges over the
points where the access can actually execute.  Accesses with *uncertain*
guards (data-dependent predicates, loop bodies) are conservatively checked
over every grid point, which is sound for a "proven violation" rule: a
violation is only reported if the index is out of bounds at some point
where the access may run, and an uncertain guard may run anywhere.

Block-index maps are range-checked too: the block coordinate of every
``BlockSpec``-windowed operand must stay within ``ceil(dim / block_dim)``
for each axis, over the whole grid.

Rule id: ``index-range``.
"""
from __future__ import annotations

from typing import List

import numpy as np

from .accesses import TOP, Access, KernelIR
from .jaxpr_lint import LintFinding

RULE = "index-range"


def _span_violation(ir: KernelIR, acc: Access, d: int):
    """(lo, hi, extent) of a proven per-dim violation, else None."""
    dim = acc.dims[d]
    if dim.start is TOP:
        return None
    size = dim.size if dim.size is not TOP else 1
    extent = acc.extent[d] if d < len(acc.extent) else None
    if extent is None:
        return None
    mask = ir.may_mask(acc)
    if isinstance(dim.start, np.ndarray):
        if not mask.any():
            return None
        starts = dim.start[mask]
        lo, hi = int(starts.min()), int(starts.max())
    else:
        lo = hi = int(dim.start)
    if lo < 0 or hi + size > extent:
        return lo, hi + size - 1, extent
    return None


def check_ranges(ir: KernelIR) -> List[LintFinding]:
    """Prove every decoded access footprint in bounds; report violations."""
    findings: List[LintFinding] = []
    seen = set()
    for acc in ir.accesses:
        for d in range(len(acc.dims)):
            if acc.dims[d].full:
                continue
            hit = _span_violation(ir, acc, d)
            if hit is None:
                continue
            lo, hi, extent = hit
            key = (acc.ref.name, d, acc.kind)
            if key in seen:
                continue
            seen.add(key)
            findings.append(LintFinding(
                rule=RULE,
                message=(f"{acc.kind} on {acc.ref.name} dim {d}: index span "
                         f"[{lo}, {hi}] exceeds extent {extent}"),
                kernel=ir.name))

    # block-index maps: coords must stay within the per-axis block counts
    for name, coords in ir.block_coords.items():
        bounds = ir.block_bounds.get(name, ())
        for d, (c, nb) in enumerate(zip(coords, bounds)):
            if c is TOP:
                continue
            arr = np.asarray(c)
            lo, hi = int(arr.min()), int(arr.max())
            if lo < 0 or hi >= nb:
                key = (name, d, "block")
                if key in seen:
                    continue
                seen.add(key)
                findings.append(LintFinding(
                    rule=RULE,
                    message=(f"index map of {name} dim {d}: block coord span "
                             f"[{lo}, {hi}] outside [0, {nb})"),
                    kernel=ir.name))
    return findings
