"""Static VMEM budgeting for the Pallas kernel variants.

Two faces of the same accounting:

* :func:`kernel_vmem_bytes` — derived from a traced kernel's
  :class:`~.accesses.KernelIR`: VMEM ``scratch_shapes`` at full size plus
  every BlockSpec-windowed operand at block size × 2 (Mosaic
  double-buffers blocked operands across grid steps); ``ANY``-space
  operands stay in HBM and SMEM prefetch / DMA semaphores are not VMEM.
* :func:`spmm_vmem_bytes` / :func:`spgemm_vmem_bytes` — closed-form
  formulas over the plan knobs (block shape, ``bn``, ``unroll``, dtypes),
  used by the planner's plan-time gate where no kernel has been traced
  yet.  ``tests/test_kernel_analysis.py`` pins the two faces equal
  byte-for-byte on every shipped variant, so the formulas cannot drift
  from the kernels the way the old hand-maintained docstring did.

The per-core limit default follows the TPU VMEM size (~16 MiB/core); a
knob combination that cannot fit raises :class:`VmemBudgetError` — a named
error at plan time, not an OOM at launch.

Rule id: ``vmem-budget``.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from .accesses import KernelIR
from .jaxpr_lint import LintFinding

RULE = "vmem-budget"

#: per-core VMEM capacity the budget is checked against by default (TPU
#: cores carry ~16 MiB of VMEM; see the accelerator notes in docs/API.md)
DEFAULT_VMEM_LIMIT_BYTES = 16 * 2 ** 20

#: Mosaic double-buffers BlockSpec-windowed operands across grid steps
_BLOCK_BUFFERS = 2

_ITEMSIZE_FALLBACK = {
    "bfloat16": 2,
    "float8_e4m3fn": 1, "float8_e4m3": 1, "float8_e5m2": 1,
    "float8_e4m3fnuz": 1, "float8_e5m2fnuz": 1,
}


class VmemBudgetError(ValueError):
    """A kernel variant's VMEM working set exceeds the per-core limit."""


def _itemsize(dtype) -> int:
    s = str(dtype)
    try:
        return int(np.dtype(s).itemsize)
    except TypeError:
        pass
    if s in _ITEMSIZE_FALLBACK:
        return _ITEMSIZE_FALLBACK[s]
    raise ValueError(f"unknown dtype for VMEM accounting: {dtype!r}")


def kernel_vmem_bytes(ir: KernelIR) -> Dict[str, int]:
    """Per-ref VMEM bytes of one traced kernel, plus a ``"total"`` entry."""
    out: Dict[str, int] = {}
    total = 0
    for ref in ir.refs:
        if ref.role == "scratch" and ref.memspace == "vmem":
            b = int(np.prod(ref.shape, dtype=np.int64)) * _itemsize(ref.dtype)
        elif (ref.role in ("input", "output") and ref.memspace == "blocked"
              and ref.block_shape is not None):
            b = (int(np.prod(ref.block_shape, dtype=np.int64))
                 * _itemsize(ref.dtype) * _BLOCK_BUFFERS)
        else:
            continue
        out[ref.name] = b
        total += b
    out["total"] = total
    return out


def check_vmem_budget(ir: KernelIR,
                      limit: int = DEFAULT_VMEM_LIMIT_BYTES
                      ) -> List[LintFinding]:
    """The ``vmem-budget`` rule: a finding when the traced kernel's working
    set exceeds ``limit`` bytes."""
    budget = kernel_vmem_bytes(ir)
    if budget["total"] <= limit:
        return []
    parts = ", ".join(f"{k}={v}" for k, v in sorted(budget.items())
                      if k != "total")
    return [LintFinding(
        rule=RULE,
        message=(f"VMEM working set {budget['total']} bytes exceeds the "
                 f"{limit}-byte per-core limit ({parts})"),
        kernel=ir.name)]


# ---------------------------------------------------------------------------
# closed-form budgets over the plan knobs (mirrors of the kernel layouts in
# kernels/segment_spmm.py and kernels/segment_spgemm.py — pinned equal to
# the traced totals by tests/test_kernel_analysis.py)
# ---------------------------------------------------------------------------


def spmm_vmem_bytes(*, bm: int, bk: int, bn: int, unroll: int,
                    transpose_lhs: bool = False,
                    block_dtype="float32", rhs_dtype="float32",
                    out_dtype="float32", quantized: bool = False,
                    rowwise: bool = False, pipelined: bool = True) -> int:
    """VMEM bytes of one ``segment_spmm`` kernel instance.

    Pipelined: ``acc(row·bn·4) + out window(row·bn·2) + A ring
    (2·unroll·bm·bk) + B ring (2·unroll·contract·bn)`` plus, when
    quantized, the per-step scale window — ``(1, unroll)`` fp32 per-block,
    ``(1, unroll, bm)`` in rowwise mode.  Legacy: the BlockSpec
    auto-pipeline double-buffers ``unroll`` A tiles and ``unroll`` B
    stripes instead of the explicit rings (per-block scales ride the SMEM
    prefetch path there — no VMEM — but rowwise scale rows are ``unroll``
    windowed ``(1, bm)`` VMEM operands).
    """
    row_blk, contract_blk = (bk, bm) if transpose_lhs else (bm, bk)
    a_item = _itemsize(block_dtype)
    b_item = _itemsize(rhs_dtype)
    scale_elems = bm if rowwise else 1   # rowwise runs over storage rows
    total = row_blk * bn * 4                                     # acc
    total += row_blk * bn * _itemsize(out_dtype) * _BLOCK_BUFFERS  # out win
    if pipelined:
        depth = 2 * unroll
        total += depth * bm * bk * a_item                        # A ring
        total += depth * contract_blk * bn * b_item              # B ring
        if quantized:
            total += unroll * scale_elems * 4 * _BLOCK_BUFFERS   # scale win
    else:
        total += unroll * (1 * bm * bk) * a_item * _BLOCK_BUFFERS
        total += unroll * (contract_blk * bn) * b_item * _BLOCK_BUFFERS
        if quantized and rowwise:
            total += unroll * (1 * bm) * 4 * _BLOCK_BUFFERS
    return total


def spgemm_vmem_bytes(*, bm: int, bk: int, bn: int, unroll: int,
                      block_dtype="float32", rhs_dtype=None,
                      out_dtype="float32", quant_a: bool = False,
                      quant_b: bool = False, rowwise: bool = False,
                      pipelined: bool = True) -> int:
    """VMEM bytes of one ``segment_spgemm`` kernel instance (same
    accounting as :func:`spmm_vmem_bytes`, block×block operand streams;
    rowwise scale windows span A's ``bm`` rows and B's ``bk`` rows)."""
    a_item = _itemsize(block_dtype)
    b_item = _itemsize(rhs_dtype if rhs_dtype is not None else block_dtype)
    a_scale = bm if rowwise else 1
    b_scale = bk if rowwise else 1
    total = bm * bn * 4                                          # acc
    total += 1 * bm * bn * _itemsize(out_dtype) * _BLOCK_BUFFERS   # out win
    if pipelined:
        depth = 2 * unroll
        total += depth * bm * bk * a_item
        total += depth * bk * bn * b_item
        total += (int(quant_a) * a_scale
                  + int(quant_b) * b_scale) * unroll * 4 * _BLOCK_BUFFERS
    else:
        total += unroll * (1 * bm * bk) * a_item * _BLOCK_BUFFERS
        total += unroll * (1 * bk * bn) * b_item * _BLOCK_BUFFERS
        if rowwise:
            total += (int(quant_a) * a_scale + int(quant_b) * b_scale) \
                * unroll * 4 * _BLOCK_BUFFERS
    return total


#: plan ``block_dtype`` names → payload bytes per element (the plan stores
#: the short quantization mode, not a numpy dtype string)
_PLAN_DTYPE_BYTES = {"fp32": 4, "int8": 1, "fp8": 1,
                     "int8.rowwise": 1, "fp8.rowwise": 1}


def _plan_block_dtype(plan) -> str:
    name = str(getattr(plan, "block_dtype", "fp32") or "fp32")
    name = name.split(".", 1)[0]   # strip a scale-granularity suffix
    return {"fp32": "float32", "int8": "int8",
            "fp8": "float8_e4m3fn"}.get(name, name)


def plan_vmem_bytes(plan, *, bn: int = 512, pipelined: Optional[bool] = None
                    ) -> int:
    """Worst-case VMEM bytes across the kernel instances a ``SegmentPlan``
    will launch through the executor: the forward kernel plus, when the
    plan carries a gradient schedule, the transposed backward kernel.

    ``bn`` is the executor's N-tile width *after* ``pick_bn`` clamping —
    pass the effective value, not the raw knob.
    """
    bm, bk = plan.block_shape
    dt = _plan_block_dtype(plan)
    quantized = plan.lhs_scales is not None
    rowwise = quantized and getattr(plan.lhs_scales, "ndim", 1) == 2
    unroll = max(1, int(plan.unroll or 1))
    if pipelined is None:
        # a plan built with pipeline=False carries the fetch-flag leaves
        # (their contract is pipeline-independent) but executes the legacy
        # BlockSpec path — budget what the executor will actually launch
        pipelined = (plan.a_fetch is not None
                     and bool(getattr(plan, "pipeline", True)))
    if plan.kind == "spgemm":
        bn_eff = (plan.rhs_blocks.shape[2] if plan.rhs_blocks is not None
                  else bk)
        rhs_dt = (str(plan.rhs_blocks.dtype) if plan.rhs_blocks is not None
                  else dt)
        total = spgemm_vmem_bytes(
            bm=bm, bk=bk, bn=bn_eff, unroll=unroll, block_dtype=dt,
            rhs_dtype=rhs_dt,
            quant_a=quantized, quant_b=plan.rhs_scales is not None,
            rowwise=rowwise, pipelined=pipelined)
    else:
        total = spmm_vmem_bytes(bm=bm, bk=bk, bn=bn, unroll=unroll,
                                transpose_lhs=plan.transpose_lhs,
                                block_dtype=dt, quantized=quantized,
                                rowwise=rowwise, pipelined=pipelined)
    grad = plan.grad_plan
    if grad is not None:
        total = max(total, plan_vmem_bytes(grad, bn=bn, pipelined=pipelined))
    return total


def check_plan_vmem(plan, *, bn: int = 512,
                    limit: int = DEFAULT_VMEM_LIMIT_BYTES,
                    label: str = "plan") -> int:
    """Raise :class:`VmemBudgetError` when a plan's worst kernel instance
    cannot fit in ``limit`` bytes of VMEM; returns the computed bytes."""
    total = plan_vmem_bytes(plan, bn=bn)
    if total > limit:
        bm, bk = plan.block_shape
        raise VmemBudgetError(
            f"{label}: kernel VMEM working set {total} bytes exceeds the "
            f"{limit}-byte limit (block ({bm}, {bk}), bn={bn}, "
            f"unroll={getattr(plan, 'unroll', 1)}, "
            f"dtype={getattr(plan, 'block_dtype', 'float32')}); choose a "
            f"smaller bn/unroll/block or raise vmem_limit_bytes")
    return total
