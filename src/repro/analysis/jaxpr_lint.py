"""Static Pallas kernel hazard linter over traced jaxprs.

The Segment kernels hand-schedule their DMA pipeline — async copies into
ring-buffered VMEM scratch, gated by scalar-prefetch fetch flags, waited on
per-slot semaphores — and two hazard classes have already bitten at
runtime (CHANGES.md): reading ``pl.program_id`` *inside* a ``pl.when``
branch (interpret mode evaluates both arms, so the read observes a grid
position the guard excluded), and consuming a VMEM destination before its
DMA wait.  Neither is caught by the type system or by a passing parity
test on a lucky schedule; both are visible in the kernel's jaxpr.

This module traces kernel-bearing callables with :func:`jax.make_jaxpr`
(pure tracing — nothing is compiled or lowered, so it runs on any host),
digs the ``pallas_call`` kernel jaxprs out, and walks them for a small
rule catalog:

* ``program-id-in-when`` — a ``program_id`` read nested under a ``cond``
  (what ``pl.when`` lowers to);
* ``dma-start-without-wait`` — a semaphore with ``dma_start`` issues but
  no ``dma_wait`` anywhere in the kernel (the copy's completion is never
  observed, so slot reuse races the hardware);
* ``read-before-wait`` — the first ``get`` of a DMA destination buffer
  precedes every ``dma_wait`` on that buffer in kernel program order
  (cond branches walked in order).

The walk is ref-base-granular: a ``(depth, …)`` ring buffer is one base,
so per-slot false negatives are possible, but the discipline the shipped
kernels follow (issue step ``s+1``, wait, then read) is exactly what the
rules check.  ``python -m repro.analysis.jaxpr_lint`` lints the shipped
SpMM/SpGEMM kernel variants and exits 1 on any finding — the CI gate.

Imports: ``repro.api`` / ``repro.kernels`` are imported *lazily* inside
:func:`lint_segment_kernels` only — this module must stay importable from
anywhere in the layering (tests lint toy kernels without touching the
planner).
"""
from __future__ import annotations

import dataclasses
import sys
from typing import Dict, List, Optional, Set, Tuple

import jax

RULES: Dict[str, str] = {
    "program-id-in-when":
        "pl.program_id must be read once at the kernel top level, never "
        "inside a pl.when branch (interpret mode evaluates both arms)",
    "dma-start-without-wait":
        "every semaphore that gates make_async_copy starts needs a "
        "matching wait before its slot can be reused",
    "read-before-wait":
        "a VMEM DMA destination may only be read after a dma_wait on it "
        "in kernel program order",
}


@dataclasses.dataclass(frozen=True)
class LintFinding:
    """One hazard flagged in a kernel jaxpr."""

    rule: str
    message: str
    kernel: str = "<kernel>"
    severity: str = "error"

    def __str__(self) -> str:
        return f"[{self.rule}] kernel {self.kernel!r}: {self.message}"


def _is_sem(var) -> bool:
    aval = getattr(var, "aval", None)
    return aval is not None and "semaphore" in str(aval).lower()


def _is_ref(var) -> bool:
    aval = getattr(var, "aval", None)
    return (aval is not None and "Ref" in type(aval).__name__
            and not _is_sem(var))


def _is_var(v) -> bool:
    # Literals carry .val; proper jaxpr variables do not
    return hasattr(v, "aval") and not hasattr(v, "val")


def _iter_subjaxprs(value):
    """Yield every (Closed)Jaxpr reachable from one eqn param value."""
    vals = value if isinstance(value, (tuple, list)) else (value,)
    for v in vals:
        inner = getattr(v, "jaxpr", None)
        if inner is not None and hasattr(inner, "eqns"):
            yield inner          # ClosedJaxpr
        elif hasattr(v, "eqns"):
            yield v              # bare Jaxpr


class _KernelWalk:
    """Linearized walk of one kernel jaxpr with ref canonicalization.

    ``base`` maps sub-jaxpr invars back to the outer variable they alias
    (cond branch invars ↔ cond operands), so reads/waits on a buffer are
    attributed to one canonical base no matter how deep the branch.
    """

    def __init__(self, kernel_name: str):
        self.kernel = kernel_name
        self.findings: List[LintFinding] = []
        self.base: Dict[object, object] = {}
        self.sem_starts: Dict[object, int] = {}
        self.sem_waits: Dict[object, int] = {}
        self.dma_dst: Set[object] = set()
        self.waited: Set[object] = set()
        self.read_before_wait: Set[object] = set()

    def canon(self, v):
        while v in self.base:
            v = self.base[v]
        return v

    def _alias(self, sub_invars, operands):
        for bv, ov in zip(sub_invars, operands):
            if _is_var(ov):
                self.base[bv] = self.canon(ov)

    def walk(self, jaxpr, when_depth: int = 0) -> None:
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name == "cond":
                for br in eqn.params.get("branches", ()):
                    sub = getattr(br, "jaxpr", br)
                    self._alias(sub.invars, eqn.invars[1:])
                    self.walk(sub, when_depth + 1)
                continue
            if name == "program_id":
                if when_depth >= 1:
                    self.findings.append(LintFinding(
                        "program-id-in-when",
                        f"program_id(axis={eqn.params.get('axis')}) read "
                        f"inside a pl.when branch (cond nesting depth "
                        f"{when_depth}) — hoist the read to the kernel top "
                        f"level and close over the value",
                        kernel=self.kernel))
                continue
            if name == "dma_start":
                refs = [self.canon(v) for v in eqn.invars
                        if _is_var(v) and _is_ref(v)]
                sems = [self.canon(v) for v in eqn.invars
                        if _is_var(v) and _is_sem(v)]
                for s in sems:
                    self.sem_starts[s] = self.sem_starts.get(s, 0) + 1
                if refs:
                    dst = refs[-1]   # (src_ref, ..., dst_ref, ..., sem)
                    self.dma_dst.add(dst)
                    self.waited.discard(dst)   # a fresh copy is in flight
                continue
            if name == "dma_wait":
                for v in eqn.invars:
                    if not _is_var(v):
                        continue
                    if _is_sem(v):
                        s = self.canon(v)
                        self.sem_waits[s] = self.sem_waits.get(s, 0) + 1
                    elif _is_ref(v):
                        self.waited.add(self.canon(v))
                continue
            if name == "get" and eqn.invars and _is_var(eqn.invars[0]):
                b = self.canon(eqn.invars[0])
                if b in self.dma_dst and b not in self.waited \
                        and b not in self.read_before_wait:
                    self.read_before_wait.add(b)
                    self.findings.append(LintFinding(
                        "read-before-wait",
                        "VMEM DMA destination is read before any dma_wait "
                        "on it in kernel program order — the buffer may "
                        "still hold the previous tile (or garbage) when "
                        "the MXU consumes it",
                        kernel=self.kernel))
                continue
            # generic recursion (run_scoped, pjit-in-kernel, loops):
            # sub-jaxpr invars alias the eqn operands where they line up
            for pv in eqn.params.values():
                for sub in _iter_subjaxprs(pv):
                    if len(sub.invars) == len(eqn.invars):
                        self._alias(sub.invars, eqn.invars)
                    self.walk(sub, when_depth)

    def finish(self) -> List[LintFinding]:
        for s, n in self.sem_starts.items():
            if self.sem_waits.get(s, 0) == 0:
                self.findings.append(LintFinding(
                    "dma-start-without-wait",
                    f"semaphore sees {n} dma_start(s) but no dma_wait "
                    f"anywhere in the kernel — completion is never "
                    f"observed, so ring-slot reuse races the copy engine",
                    kernel=self.kernel))
        return self.findings


def lint_kernel_jaxpr(jaxpr, kernel_name: str = "<kernel>"
                      ) -> List[LintFinding]:
    """Run the rule catalog over one already-extracted kernel jaxpr."""
    w = _KernelWalk(kernel_name)
    w.walk(jaxpr)
    return w.finish()


def find_pallas_kernels(jaxpr) -> List[Tuple[str, object]]:
    """Collect ``(name, kernel_jaxpr)`` for every pallas_call reachable."""
    out: List[Tuple[str, object]] = []

    def rec(j):
        for eqn in j.eqns:
            if eqn.primitive.name == "pallas_call":
                kj = eqn.params.get("jaxpr")
                info = eqn.params.get("name_and_src_info")
                name = (getattr(info, "name", None)
                        or eqn.params.get("name") or "<pallas_call>")
                if kj is not None:
                    out.append((str(name), getattr(kj, "jaxpr", kj)))
            for pv in eqn.params.values():
                for sub in _iter_subjaxprs(pv):
                    rec(sub)

    rec(getattr(jaxpr, "jaxpr", jaxpr))
    return out


def lint_callable(fn, *args, label: Optional[str] = None,
                  **kwargs) -> List[LintFinding]:
    """Trace ``fn(*args, **kwargs)`` and lint every Pallas kernel inside.

    Tracing never compiles or lowers — safe on hosts with no accelerator
    (the CI gate runs this on CPU).  Raises ``ValueError`` when the trace
    contains no ``pallas_call`` at all: linting nothing silently would
    make the CI stage vacuous.
    """
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    kernels = find_pallas_kernels(closed)
    if not kernels:
        raise ValueError(
            f"no pallas_call found while tracing "
            f"{label or getattr(fn, '__name__', fn)!r} — nothing to lint")
    findings: List[LintFinding] = []
    for name, kj in kernels:
        findings.extend(lint_kernel_jaxpr(
            kj, kernel_name=f"{label}:{name}" if label else name))
    return findings


# ---------------------------------------------------------------------------
# Shipped-kernel entry point (the CI gate)
# ---------------------------------------------------------------------------


def lint_segment_kernels(verbose: bool = False) -> List[LintFinding]:
    """Lint every shipped Segment kernel variant the executor can emit.

    Builds tiny plans and traces the real executor paths: SpMM pipelined
    (fp32 + quantized + the transposed backward schedule via the custom
    VJP) and SpGEMM pipelined, plus both kernels' legacy BlockSpec
    auto-pipeline fallback (fetch arrays withheld).  ``repro.api`` is
    imported lazily here — the linter core must not depend on the planner.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.api import apply_plan, execute_plan, plan_matmul
    from repro.core.formats import BSR
    from repro.kernels.segment_spgemm import segment_spgemm
    from repro.kernels.segment_spmm import segment_spmm

    findings: List[LintFinding] = []
    a = BSR.random(np.random.default_rng(0), (128, 128), (32, 32), 0.5)
    b = BSR.random(np.random.default_rng(1), (128, 128), (32, 32), 0.5)
    x = jnp.zeros((128, 64), jnp.float32)

    plan = plan_matmul(a, policy="segment", n_lanes=2, unroll=2,
                       with_grad=True, cache=False)
    qplan = plan_matmul(a, policy="segment", n_lanes=2, unroll=2,
                        quantize="int8", cache=False)
    gplan = plan_matmul(a, b, policy="segment", n_lanes=2, unroll=2,
                        cache=False)

    traces = [
        ("spmm-pipelined",
         lambda: jax.make_jaxpr(
             lambda xx: execute_plan(plan, xx, bn=64,
                                     backend="interpret"))(x)),
        ("spmm-grad",
         lambda: jax.make_jaxpr(jax.grad(
             lambda xx: apply_plan(plan, xx, bn=64,
                                   backend="interpret").sum()))(x)),
        ("spmm-quantized",
         lambda: jax.make_jaxpr(
             lambda xx: execute_plan(qplan, xx, bn=64,
                                     backend="interpret"))(x)),
        ("spgemm-pipelined",
         lambda: jax.make_jaxpr(
             lambda: execute_plan(gplan, backend="interpret"))()),
        ("spmm-legacy",
         lambda: jax.make_jaxpr(lambda xx: segment_spmm(
             plan.lhs_blocks, plan.slot_idx, plan.m_idx, plan.k_idx,
             plan.seg_start, plan.seg_write, plan.accum_prev, plan.valid,
             xx, grid_m=plan.grid[0], n_lanes=plan.n_lanes, bn=64,
             unroll=plan.unroll, masked=plan.has_pads, interpret=True,
             pipeline=False))(x)),
        ("spgemm-legacy",
         lambda: jax.make_jaxpr(lambda: segment_spgemm(
             gplan.lhs_blocks, gplan.rhs_blocks, gplan.a_idx, gplan.b_idx,
             gplan.c_idx, gplan.seg_start, gplan.seg_write,
             gplan.accum_prev, gplan.valid, n_c_blocks=gplan.n_out_blocks,
             n_lanes=gplan.n_lanes, unroll=gplan.unroll,
             masked=gplan.has_pads, interpret=True, pipeline=False))()),
    ]
    for label, trace in traces:
        kernels = find_pallas_kernels(trace())
        if not kernels:
            raise ValueError(f"variant {label!r} traced to no pallas_call "
                             f"— the lint gate would be vacuous")
        for name, kj in kernels:
            fs = lint_kernel_jaxpr(kj, kernel_name=f"{label}:{name}")
            findings.extend(fs)
            if verbose:
                state = (f"{len(fs)} finding(s)" if fs else "clean")
                print(f"  lint {label}:{name}: {state}")
    return findings


# ---------------------------------------------------------------------------
# Symbolic analysis entry points (abstract interpretation; see accesses.py,
# ranges.py, races.py, budget.py — imported lazily so the syntactic linter
# stays importable on its own)
# ---------------------------------------------------------------------------


def _analyze_trace(closed, args, label: str, vmem_limit=None,
                   verbose: bool = False) -> List[LintFinding]:
    """Syntactic lint + every symbolic rule over one traced jaxpr."""
    from .accesses import find_kernel_invocations, kernel_ir_from_eqn
    from .budget import DEFAULT_VMEM_LIMIT_BYTES, check_vmem_budget
    from .order import check_order
    from .races import (check_parallel_races, check_ring_war,
                        check_sem_balance)
    from .ranges import check_ranges

    limit = DEFAULT_VMEM_LIMIT_BYTES if vmem_limit is None else vmem_limit
    kernels = find_pallas_kernels(closed)
    if not kernels:
        raise ValueError(f"no pallas_call found while tracing {label!r} "
                         f"— nothing to analyze")
    findings: List[LintFinding] = []
    for name, kj in kernels:
        findings.extend(lint_kernel_jaxpr(kj, kernel_name=f"{label}:{name}"))
    for name, eqn, scalars in find_kernel_invocations(closed, args):
        ir = kernel_ir_from_eqn(eqn, name=f"{label}:{name}", scalars=scalars)
        before = len(findings)
        findings.extend(check_ranges(ir))
        findings.extend(check_parallel_races(ir))
        findings.extend(check_ring_war(ir))
        findings.extend(check_sem_balance(ir))
        findings.extend(check_order(ir))
        findings.extend(check_vmem_budget(ir, limit))
        if verbose:
            n = len(findings) - before
            state = f"{n} finding(s)" if n else "proved clean"
            print(f"  analyze {ir.name}: grid={ir.grid} "
                  f"parallel={ir.parallel_axes} {state}")
    return findings


def analyze_callable(fn, *args, label: Optional[str] = None,
                     vmem_limit: Optional[int] = None,
                     **kwargs) -> List[LintFinding]:
    """Trace ``fn(*args, **kwargs)`` and run the syntactic linter plus the
    full symbolic rule set (index-range, parallel-race, ring-slot-war,
    sem-balance, vmem-budget, and the inter-pass ordering rules
    cross-pass-war / sem-carryover / prefetch-raw / dma-priority) on every
    Pallas kernel inside.

    Scalar-prefetch operands are resolved from the trace's constants and
    the concrete ``args``, so the proofs are exact over the traced grid.
    Raises ``ValueError`` when the trace holds no ``pallas_call``.
    """
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return _analyze_trace(closed, args,
                          label or getattr(fn, "__name__", str(fn)),
                          vmem_limit=vmem_limit)


def analyze_shipped_kernels(verbose: bool = False) -> List[LintFinding]:
    """The full static gate: syntactic lint + symbolic proofs over every
    shipped Pallas kernel × a knob grid.

    Covers the six Segment variants :func:`lint_segment_kernels` traces
    (pipelined fwd/grad/quantized, SpGEMM, both legacy fallbacks) plus
    extra (n_lanes, unroll) and fp8 knob points, and extends the gate to
    the non-Segment kernels — ``flash_attention`` (causal, and
    windowed+GQA to exercise the ``rem``-guarded skip path), ``moe_gemm``,
    and ``rg_lru`` — so their ``parallel`` axes get the same race proof.

    The ``prefetch="cross_pass"`` variants run at ``bn=32`` so the traced
    grid carries two N tiles — with a single tile the cross-pass tail
    guard is never true and the ordering proofs would be vacuous.  Every
    prefetch-enabled variant must prove clean under the inter-pass rules
    (cross-pass-war, sem-carryover, prefetch-raw, dma-priority) before CI
    lets it ship.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.api import apply_plan, execute_plan, plan_matmul
    from repro.core.formats import BSR
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.moe_gemm import build_moe_chunks, moe_gemm
    from repro.kernels.rg_lru import rg_lru
    from repro.kernels.segment_spgemm import segment_spgemm
    from repro.kernels.segment_spmm import segment_spmm

    a = BSR.random(np.random.default_rng(0), (128, 128), (32, 32), 0.5)
    b = BSR.random(np.random.default_rng(1), (128, 128), (32, 32), 0.5)
    x = jnp.zeros((128, 64), jnp.float32)

    def spmm(n_lanes, unroll, bn=64, **kw):
        p = plan_matmul(a, policy="segment", n_lanes=n_lanes, unroll=unroll,
                        cache=False, **kw)
        return p, lambda: jax.make_jaxpr(
            lambda xx: execute_plan(p, xx, bn=bn, backend="interpret"))(x)

    plan, _ = spmm(2, 2, with_grad=True)
    gplan = plan_matmul(a, b, policy="segment", n_lanes=2, unroll=2,
                        cache=False)
    gplan1 = plan_matmul(a, b, policy="segment", n_lanes=1, unroll=1,
                         cache=False)
    # cross-pass prefetch variants: bn=32 over the 64-wide rhs → two N
    # tiles, so the traced grid actually contains the tail-issue pass
    # boundary the ordering rules certify
    pf_plan, _ = spmm(2, 2, bn=32, with_grad=True, prefetch="cross_pass")
    gplan_pf = plan_matmul(a, b, policy="segment", n_lanes=2, unroll=2,
                           cache=False, prefetch="cross_pass")

    q = jnp.zeros((2, 256, 64), jnp.float32)
    kv = jnp.zeros((2, 256, 64), jnp.float32)
    xt = jnp.zeros((2, 256, 16), jnp.float32)
    h0 = jnp.zeros((2, 16), jnp.float32)
    ap = jnp.zeros((16,), jnp.float32)
    n_experts = 4
    chunk_expert = jnp.arange(n_experts, dtype=jnp.int32)
    xs = jnp.zeros((n_experts * 128, 32), jnp.float32)
    w = jnp.zeros((n_experts, 32, 64), jnp.float32)

    traces = [
        ("spmm-pipelined",
         lambda: jax.make_jaxpr(
             lambda xx: execute_plan(plan, xx, bn=64,
                                     backend="interpret"))(x), (x,)),
        ("spmm-grad",
         lambda: jax.make_jaxpr(jax.grad(
             lambda xx: apply_plan(plan, xx, bn=64,
                                   backend="interpret").sum()))(x), (x,)),
        ("spmm-quantized-int8", spmm(2, 2, quantize="int8")[1], (x,)),
        ("spmm-quantized-fp8", spmm(1, 1, quantize="fp8")[1], (x,)),
        ("spmm-lanes1", spmm(1, 1)[1], (x,)),
        ("spmm-lanes4", spmm(4, 2)[1], (x,)),
        ("spmm-prefetch",
         lambda: jax.make_jaxpr(
             lambda xx: execute_plan(pf_plan, xx, bn=32,
                                     backend="interpret"))(x), (x,)),
        ("spmm-prefetch-grad",
         lambda: jax.make_jaxpr(jax.grad(
             lambda xx: apply_plan(pf_plan, xx, bn=32,
                                   backend="interpret").sum()))(x), (x,)),
        ("spmm-prefetch-quant-int8",
         spmm(2, 2, bn=32, quantize="int8", prefetch="cross_pass")[1], (x,)),
        ("spmm-prefetch-lanes1",
         spmm(1, 1, bn=32, prefetch="cross_pass")[1], (x,)),
        ("spgemm-pipelined",
         lambda: jax.make_jaxpr(
             lambda: execute_plan(gplan, backend="interpret"))(), ()),
        ("spgemm-lanes1",
         lambda: jax.make_jaxpr(
             lambda: execute_plan(gplan1, backend="interpret"))(), ()),
        ("spgemm-prefetch",
         lambda: jax.make_jaxpr(
             lambda: execute_plan(gplan_pf, backend="interpret"))(), ()),
        ("spmm-legacy",
         lambda: jax.make_jaxpr(lambda xx: segment_spmm(
             plan.lhs_blocks, plan.slot_idx, plan.m_idx, plan.k_idx,
             plan.seg_start, plan.seg_write, plan.accum_prev, plan.valid,
             xx, grid_m=plan.grid[0], n_lanes=plan.n_lanes, bn=64,
             unroll=plan.unroll, masked=plan.has_pads, interpret=True,
             pipeline=False))(x), (x,)),
        ("spgemm-legacy",
         lambda: jax.make_jaxpr(lambda: segment_spgemm(
             gplan.lhs_blocks, gplan.rhs_blocks, gplan.a_idx, gplan.b_idx,
             gplan.c_idx, gplan.seg_start, gplan.seg_write,
             gplan.accum_prev, gplan.valid, n_c_blocks=gplan.n_out_blocks,
             n_lanes=gplan.n_lanes, unroll=gplan.unroll,
             masked=gplan.has_pads, interpret=True, pipeline=False))(), ()),
        ("flash-causal",
         lambda: jax.make_jaxpr(lambda qq, kk, vv: flash_attention(
             qq, kk, vv, causal=True, interpret=True))(q, kv, kv),
         (q, kv, kv)),
        ("flash-window-gqa",
         lambda: jax.make_jaxpr(lambda qq, kk, vv: flash_attention(
             qq, kk, vv, causal=True, window=128, q_period=128,
             interpret=True))(q, kv, kv), (q, kv, kv)),
        ("moe-gemm",
         lambda: jax.make_jaxpr(lambda xx, ww, ce: moe_gemm(
             xx, ww, ce, chunk_rows=128, bn=64,
             interpret=True))(xs, w, chunk_expert), (xs, w, chunk_expert)),
        ("rg-lru",
         lambda: jax.make_jaxpr(lambda *args: rg_lru(
             *args, ct=128, interpret=True))(xt, xt, xt, ap, h0),
         (xt, xt, xt, ap, h0)),
    ]
    findings: List[LintFinding] = []
    for label, trace, args in traces:
        findings.extend(_analyze_trace(trace(), args, label,
                                       verbose=verbose))
    return findings


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    verbose = "-q" not in argv
    if "--syntactic" in argv:
        print("linting shipped Segment kernel variants "
              f"({len(RULES)} rules: {', '.join(sorted(RULES))})")
        findings = lint_segment_kernels(verbose=verbose)
    else:
        from .order import ORDER_RULES
        from .races import ANALYZER_RULES
        rules = sorted(set(RULES) | set(ANALYZER_RULES) | set(ORDER_RULES))
        print("analyzing shipped Pallas kernels "
              f"({len(rules)} rules: {', '.join(rules)})")
        findings = analyze_shipped_kernels(verbose=verbose)
    if findings:
        print(f"FAIL: {len(findings)} hazard(s)")
        for f in findings:
            print(f"  {f}")
        return 1
    print("OK: all kernel variants analyze clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
