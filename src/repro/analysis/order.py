"""Whole-execution happens-before model + inter-pass ordering proofs.

The :mod:`races` rules prove safety *within* one pass — one sweep of the
innermost sequential axis under a fixed parallel signature (for the SpMM
pipeline, one (lane, N-tile) sweep).  Cross-pass DMA prefetch breaks that
frame on purpose: a copy issued during pass *i*'s tail is discharged by
pass *i+1*'s first wait, so ring-slot residency and semaphore state now
cross the pass boundary.  This module lifts the per-grid-point access IR
(:class:`~.accesses.KernelIR`) into a happens-before model over the whole
execution:

* **program edges** — grid points under one parallel signature execute in
  row-major sequential-axis order (one *chain* per signature);
* **parallel incomparability** — points in different chains are unordered;
  nothing here may be assumed about cross-lane timing (that is
  :func:`races.check_parallel_races`' department);
* **pass structure** — within a chain, the coordinates of every sequential
  axis *except the innermost* name the pass; the boundary between ordinals
  is where the pre-prefetch pipeline drains and where prefetch state now
  survives;
* **DMA edges** — a ``dma_start`` happens-before the ``dma_wait`` that
  discharges its (semaphore, slot); a ring slot's reuse is ordered by the
  FIFO of outstanding copies into it.

Four rules consume the model (:data:`ORDER_RULES`):

``cross-pass-war``
    An in-flight copy never lands on a ring slot a later-ordered grid
    point of an *earlier* pass still reads.  Per chain, a FIFO of
    outstanding starts per (ref, slot) is replayed; a read whose slot has
    an outstanding start from a different pass is the clobber hazard the
    prefetch mode makes possible.  Same-pass read-under-copy stays with
    :func:`races.check_ring_war` (which runs pass-locally).

``sem-carryover``
    Per-(semaphore, slot) balance holds at every pass boundary, not just
    at kernel exit: a start issued while a start from an earlier pass is
    still outstanding on the same (sem, slot) means the carried-over copy
    was never discharged where the next pass expected it.
    :func:`races.check_sem_balance` only checks whole-chain totals, which
    a doubled start + doubled wait keeps balanced.

``prefetch-raw``
    A pass's first consumption waits on the copy that actually filled its
    slot: the (semaphore, slot) descriptor of the ``dma_wait`` that
    discharges a ring slot must match the descriptor of the ``dma_start``
    that last filled it, even when that start was issued from the previous
    pass's tail.  A wait that reconstructs the wrong descriptor
    synchronizes with the wrong copy — RAW on the prefetched data.

``dma-priority``
    The DMA issue order the ROADMAP prescribes: at every grid point where
    copies into two differently-sized destinations are both issued, the
    bulkier copy (the B row tile) is issued before the smaller one (the A
    tile), so large transfers never queue behind small ones.  Asserted
    statically here so the kernels' issue-phase ordering cannot silently
    regress.

All rules treat unknown guards conservatively (may-execute for
hazard-producing events, must-execute for hazard-discharging ones), and
skip silently where :mod:`races` already emits the "unprovable" finding
for the same unresolved slot — one finding per root cause.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .accesses import TOP, Access, KernelIR
from .jaxpr_lint import LintFinding

RULE_XWAR = "cross-pass-war"
RULE_CARRY = "sem-carryover"
RULE_PRAW = "prefetch-raw"
RULE_PRIO = "dma-priority"

#: catalog of the inter-pass ordering rules (the symbolic analyzer keeps
#: ``ANALYZER_RULES`` in :mod:`races`, the syntactic linter ``RULES`` in
#: :mod:`jaxpr_lint`).
ORDER_RULES = {
    RULE_XWAR: "in-flight copy lands on a slot an earlier pass still reads",
    RULE_CARRY: "per-(sem, slot) balance violated at a pass boundary",
    RULE_PRAW: "first consumption waits on a copy other than its filler",
    RULE_PRIO: "small DMA issued before a bulkier one at the same point",
}

_NO_SEQ = np.iinfo(np.int64).max


# ---------------------------------------------------------------------------
# the happens-before model
# ---------------------------------------------------------------------------
# _slot_at/_chains are duplicated from races.py (races imports *this*
# module for pass-local chains, so the dependency must point one way).


def _slot_at(val, p: int):
    if val is TOP:
        return TOP
    if isinstance(val, str):            # "all": full leading slice
        return val
    if isinstance(val, np.ndarray):
        return int(val[p])
    return int(val)


def _chains(ir: KernelIR) -> List[np.ndarray]:
    """Grid points grouped by parallel signature, each in row-major
    (sequential execution) order.  With no parallel axis the whole grid is
    one sequential chain."""
    G = ir.n_points
    if not ir.parallel_axes:
        return [np.arange(G)]
    sig = np.zeros(G, dtype=np.int64)
    for ax in ir.parallel_axes:
        sig = sig * ir.grid[ax] + ir.coords[ax]
    order = np.argsort(sig, kind="stable")
    chains = []
    sorted_sig = sig[order]
    start = 0
    for i in range(1, G + 1):
        if i == G or sorted_sig[i] != sorted_sig[start]:
            chains.append(np.sort(order[start:i]))
            start = i
    return chains


def pass_index(ir: KernelIR) -> np.ndarray:
    """Row-major pass ordinal of every grid point: the flattened
    coordinates of every sequential axis *except the innermost*.  A grid
    with at most one sequential axis is a single pass (all zeros)."""
    out = np.zeros(ir.n_points, dtype=np.int64)
    for ax in ir.sequential_axes[:-1]:
        out = out * ir.grid[ax] + ir.coords[ax]
    return out


@dataclasses.dataclass
class HappensBefore:
    """The partial order :func:`build_order` derives from a kernel IR.

    Two grid points are ordered iff they share a chain (same parallel
    signature); within a chain the order is the row-major sequential
    sweep, and ``passes`` names each point's pass ordinal along it.
    """

    ir: KernelIR
    chains: List[np.ndarray]     # one row-major point array per parallel sig
    passes: np.ndarray           # (G,) int64 pass ordinal per grid point
    n_passes: int                # distinct pass ordinals (1 = no pass axis)

    def ordered(self, p: int, q: int) -> bool:
        """True iff ``p`` happens-before ``q`` (same chain, earlier)."""
        if p == q:
            return False
        for chain in self.chains:
            in_chain = set(int(x) for x in chain)
            if p in in_chain:
                return q in in_chain and p < q
        return False


def build_order(ir: KernelIR) -> HappensBefore:
    """Lift the per-grid-point IR into the whole-execution model."""
    passes = pass_index(ir)
    return HappensBefore(ir=ir, chains=_chains(ir), passes=passes,
                         n_passes=int(passes.max()) + 1 if passes.size else 1)


def pass_local_chains(ir: KernelIR) -> List[np.ndarray]:
    """Parallel-signature chains split further at every pass boundary.

    This is the frame the *intra*-pass rules (:func:`races.check_ring_war`)
    run in: in-flight/residency state legitimately crosses a pass boundary
    only through the cross-pass prefetch contract, which the rules in this
    module own — so the pass-local rules reset their state at the boundary
    and the two layers partition the hazard space without overlap.  For a
    grid with at most one sequential axis this is exactly the per-signature
    chain split (no behavior change for non-prefetch kernels).
    """
    passes = pass_index(ir)
    out: List[np.ndarray] = []
    for chain in _chains(ir):
        pc = passes[chain]
        start = 0
        for i in range(1, len(chain) + 1):
            if i == len(chain) or pc[i] != pc[start]:
                out.append(chain[start:i])
                start = i
    return out


# ---------------------------------------------------------------------------
# event selection helpers
# ---------------------------------------------------------------------------


def _ring_events(ir: KernelIR) -> List[Access]:
    """dma_dst / dma_wait / read events on every ref that is ever a DMA
    destination, in kernel program order."""
    dma_refs = {a.ref.name for a in ir.accesses if a.kind == "dma_dst"}
    events = [a for a in ir.accesses
              if a.ref.name in dma_refs
              and a.kind in ("dma_dst", "dma_wait", "read")]
    events.sort(key=lambda a: a.seq)
    return events


def _sem_events(ir: KernelIR) -> List[Access]:
    events = [a for a in ir.accesses
              if a.kind in ("dma_dst", "dma_wait") and a.sem is not None]
    events.sort(key=lambda a: a.seq)
    return events


def _sem_unprovable(acc: Access) -> bool:
    """The events :func:`races.check_sem_balance` already reports as
    unprovable — skipped silently here (one finding per root cause)."""
    return (not acc.certain) or acc.in_loop or acc.sem_slot is TOP


def _expand(slot, shape) -> List[int]:
    if slot == "all":
        return list(range(shape[0] if shape else 1))
    return [slot]


# ---------------------------------------------------------------------------
# cross-pass-war
# ---------------------------------------------------------------------------


def check_cross_pass_war(ir: KernelIR,
                         hb: Optional[HappensBefore] = None
                         ) -> List[LintFinding]:
    """An in-flight copy never lands on a slot an earlier pass still
    reads: per chain, replay a FIFO of outstanding starts per (ref, slot);
    a read whose slot carries an outstanding start from a *different* pass
    is the cross-boundary clobber.  (Same-pass read-under-copy is
    :func:`races.check_ring_war`'s finding.)"""
    findings: List[LintFinding] = []
    hb = hb or build_order(ir)
    if hb.n_passes <= 1:
        return findings
    events = _ring_events(ir)
    if not events:
        return findings
    flagged = set()
    for chain in hb.chains:
        # (ref, slot) -> FIFO of pass ordinals of outstanding starts.  The
        # FIFO matters: a wait discharges the *oldest* copy into the slot,
        # so a legal start/wait/start interleave never strands the first
        # start behind the second's discharge.
        outstanding: Dict[Tuple[str, int], List[int]] = {}
        for p in chain:
            p = int(p)
            pass_p = int(hb.passes[p])
            for acc in events:
                if not ir.may_mask(acc)[p]:
                    continue
                slot = _slot_at(acc.slot(), p)
                if slot is TOP:
                    continue        # races.ring-slot-war reports unprovable
                for s in _expand(slot, acc.ref.shape):
                    key = (acc.ref.name, s)
                    q = outstanding.setdefault(key, [])
                    if acc.kind == "dma_dst":
                        if ir.must_mask(acc)[p] or not acc.certain:
                            q.append(pass_p)
                    elif acc.kind == "dma_wait":
                        if ir.must_mask(acc)[p] and q:
                            q.pop(0)
                    else:                           # read
                        stale = [pp for pp in q if pp != pass_p]
                        if stale and key not in flagged:
                            flagged.add(key)
                            findings.append(LintFinding(
                                rule=RULE_XWAR,
                                message=(
                                    f"slot {s} of {acc.ref.name} read at "
                                    f"grid{ir.point(p)} (pass {pass_p}) "
                                    f"while a copy issued in pass "
                                    f"{stale[0]} is still in flight — the "
                                    f"cross-pass prefetch lands on a slot "
                                    f"a later-ordered point still reads"),
                                kernel=ir.name))
    return findings


# ---------------------------------------------------------------------------
# sem-carryover
# ---------------------------------------------------------------------------


def check_sem_carryover(ir: KernelIR,
                        hb: Optional[HappensBefore] = None
                        ) -> List[LintFinding]:
    """Per-(sem, slot) balance at every pass boundary: a start issued
    while a start from an *earlier pass* is still outstanding on the same
    (semaphore, slot) means the carried-over copy was never discharged
    where the next pass expected it.  Whole-chain totals (what
    :func:`races.check_sem_balance` proves) stay balanced in exactly this
    failure, which is why the boundary-granular rule exists."""
    findings: List[LintFinding] = []
    hb = hb or build_order(ir)
    if hb.n_passes <= 1:
        return findings
    events = _sem_events(ir)
    if not events:
        return findings
    reported = set()
    for chain in hb.chains:
        outstanding: Dict[Tuple[str, int], List[int]] = {}
        for p in chain:
            p = int(p)
            pass_p = int(hb.passes[p])
            for acc in events:
                if _sem_unprovable(acc):
                    continue        # races.sem-balance reports these
                if acc.mask is None or not acc.mask[p]:
                    continue
                slot = _slot_at(acc.sem_slot, p)
                for s in _expand(slot, acc.sem.shape):
                    key = (acc.sem.name, s)
                    q = outstanding.setdefault(key, [])
                    if acc.kind == "dma_dst":
                        carried = [pp for pp in q if pp != pass_p]
                        if carried and key not in reported:
                            reported.add(key)
                            findings.append(LintFinding(
                                rule=RULE_CARRY,
                                message=(
                                    f"semaphore {acc.sem.name} slot {s}: "
                                    f"start at grid{ir.point(p)} (pass "
                                    f"{pass_p}) while a start from pass "
                                    f"{carried[0]} is still outstanding — "
                                    f"per-(sem, slot) balance does not "
                                    f"hold at the pass boundary"),
                                kernel=ir.name))
                        q.append(pass_p)
                    else:                           # dma_wait
                        if q:
                            q.pop(0)
    return findings


# ---------------------------------------------------------------------------
# prefetch-raw
# ---------------------------------------------------------------------------


def check_prefetch_raw(ir: KernelIR,
                       hb: Optional[HappensBefore] = None
                       ) -> List[LintFinding]:
    """A pass's first consumption waits on the copy that actually filled
    its slot: per chain, remember which (semaphore, slot) descriptor last
    filled each (ref, slot); a later-pass wait discharging the slot with a
    *different* descriptor synchronizes with the wrong copy (RAW on the
    prefetched data).  A wait on a never-filled slot is skipped silently —
    the balance rules own that shape."""
    findings: List[LintFinding] = []
    hb = hb or build_order(ir)
    if hb.n_passes <= 1:
        return findings
    events = [a for a in _ring_events(ir)
              if a.kind in ("dma_dst", "dma_wait") and a.sem is not None]
    if not events:
        return findings
    reported = set()
    for chain in hb.chains:
        # (ref, slot) -> (sem name, sem slot, pass) of the filling start
        fill: Dict[Tuple[str, int], Tuple[str, int, int]] = {}
        for p in chain:
            p = int(p)
            pass_p = int(hb.passes[p])
            for acc in events:
                slot = _slot_at(acc.slot(), p)
                sem_slot = _slot_at(acc.sem_slot, p)
                if slot is TOP or slot == "all" or sem_slot is TOP \
                        or sem_slot == "all":
                    continue
                if acc.kind == "dma_dst":
                    if not ir.may_mask(acc)[p]:
                        continue
                    fill[(acc.ref.name, slot)] = (acc.sem.name, sem_slot,
                                                  pass_p)
                else:                               # dma_wait
                    if not ir.must_mask(acc)[p]:
                        continue
                    key = (acc.ref.name, slot)
                    got = fill.get(key)
                    if got is None:
                        continue
                    sem_name, filled_slot, filled_pass = got
                    if filled_pass == pass_p:
                        continue    # same-pass pairing: races' department
                    if (sem_name, filled_slot) != (acc.sem.name, sem_slot) \
                            and key not in reported:
                        reported.add(key)
                        findings.append(LintFinding(
                            rule=RULE_PRAW,
                            message=(
                                f"slot {slot} of {acc.ref.name}: wait at "
                                f"grid{ir.point(p)} (pass {pass_p}) "
                                f"discharges with semaphore "
                                f"{acc.sem.name}[{sem_slot}] but the copy "
                                f"that filled it (pass {filled_pass}) "
                                f"started on {sem_name}[{filled_slot}] — "
                                f"the first consumption does not wait on "
                                f"its filler"),
                            kernel=ir.name))
    return findings


# ---------------------------------------------------------------------------
# dma-priority
# ---------------------------------------------------------------------------


def _copy_bytes(acc: Access) -> int:
    """Bytes one start into this destination moves: the product of the
    resolved footprint sizes (full or unresolved dims count their whole
    extent) times the element size."""
    total = np.dtype(acc.ref.dtype).itemsize
    if not acc.dims:
        for n in acc.extent:
            total *= int(n)
        return total
    for i, d in enumerate(acc.dims):
        if d.full or d.size is TOP:
            total *= int(acc.extent[i])
        else:
            total *= int(d.size)
    return total


def check_dma_priority(ir: KernelIR) -> List[LintFinding]:
    """Bulky copies are issued before small ones: for every pair of DMA
    destinations with different per-copy sizes, at every grid point where
    both may issue, the first (lowest-seq) issue of the bulkier ref must
    precede the first issue of the smaller one.  Equal sizes are
    unconstrained (no priority to enforce)."""
    findings: List[LintFinding] = []
    starts = [a for a in ir.accesses if a.kind == "dma_dst"]
    by_ref: Dict[str, List[Access]] = {}
    for a in starts:
        by_ref.setdefault(a.ref.name, []).append(a)
    if len(by_ref) < 2:
        return findings
    G = ir.n_points
    info = {}
    for name, accs in by_ref.items():
        first = np.full(G, _NO_SEQ, dtype=np.int64)
        for a in accs:
            may = ir.may_mask(a)
            first = np.where(may, np.minimum(first, a.seq), first)
        info[name] = (max(_copy_bytes(a) for a in accs), first)
    reported = set()
    for big, (big_bytes, big_first) in info.items():
        for small, (small_bytes, small_first) in info.items():
            if big == small or big_bytes <= small_bytes:
                continue
            both = (big_first < _NO_SEQ) & (small_first < _NO_SEQ)
            bad = both & (small_first < big_first)
            if bad.any() and (big, small) not in reported:
                reported.add((big, small))
                p = int(np.nonzero(bad)[0][0])
                findings.append(LintFinding(
                    rule=RULE_PRIO,
                    message=(
                        f"DMA issue order at grid{ir.point(p)}: the "
                        f"{small_bytes}-byte copy into {small} is issued "
                        f"before the {big_bytes}-byte copy into {big} — "
                        f"bulky row-tile copies must go first so they "
                        f"never queue behind small transfers"),
                    kernel=ir.name))
    return findings


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------


def check_order(ir: KernelIR) -> List[LintFinding]:
    """Run all four ordering rules over one kernel IR."""
    hb = build_order(ir)
    findings: List[LintFinding] = []
    findings.extend(check_cross_pass_war(ir, hb))
    findings.extend(check_sem_carryover(ir, hb))
    findings.extend(check_prefetch_raw(ir, hb))
    findings.extend(check_dma_priority(ir))
    return findings
