"""Static analysis passes over Segment plans and Pallas kernels.

Two independent gates, both pure-host (nothing compiles or runs on an
accelerator):

* :mod:`repro.analysis.invariants` — the plan verifier.
  :func:`verify_plan` proves a :class:`~repro.api.plan.SegmentPlan`'s
  schedule against the named invariant catalog (``INVARIANTS``) and
  returns typed :class:`Finding` records; it is the planner's default
  soundness check and the rejection oracle the ROADMAP autotuner needs.
* :mod:`repro.analysis.jaxpr_lint` — the kernel hazard linter.
  :func:`lint_callable` traces Pallas kernels to jaxprs and flags DMA /
  ``pl.when`` hazards (``RULES``).

Layering: this package imports ``repro.core`` only.  ``repro.api`` sits
above it (the ``verify=`` hooks), and ``core.schedule`` reaches down
lazily for the shared ``check_lane_accum`` implementation.
"""
from .invariants import (INVARIANTS, Finding, PlanVerificationError,
                         VerifyResult, check_lane_accum,
                         check_scale_agreement, check_traffic_agreement,
                         verify_plan)
from .jaxpr_lint import (RULES, LintFinding, find_pallas_kernels,
                         lint_callable, lint_kernel_jaxpr,
                         lint_segment_kernels)

__all__ = [
    "INVARIANTS", "Finding", "PlanVerificationError", "VerifyResult",
    "check_lane_accum", "check_scale_agreement", "check_traffic_agreement",
    "verify_plan",
    "RULES", "LintFinding", "find_pallas_kernels", "lint_callable",
    "lint_kernel_jaxpr", "lint_segment_kernels",
]
