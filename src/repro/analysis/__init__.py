"""Static analysis passes over Segment plans and Pallas kernels.

Two independent gates, both pure-host (nothing compiles or runs on an
accelerator):

* :mod:`repro.analysis.invariants` — the plan verifier.
  :func:`verify_plan` proves a :class:`~repro.api.plan.SegmentPlan`'s
  schedule against the named invariant catalog (``INVARIANTS``) and
  returns typed :class:`Finding` records; it is the planner's default
  soundness check and the rejection oracle the ROADMAP autotuner needs.
* :mod:`repro.analysis.jaxpr_lint` — the kernel hazard linter.
  :func:`lint_callable` traces Pallas kernels to jaxprs and flags DMA /
  ``pl.when`` hazards syntactically (``RULES``); :func:`analyze_callable`
  adds the symbolic rule set (``ANALYZER_RULES``) on top.
* :mod:`repro.analysis.accesses` / :mod:`ranges` / :mod:`races` /
  :mod:`budget` — the symbolic dataflow analyzer: an abstract
  interpretation of each kernel jaxpr over its whole grid yielding a
  slot-granular access IR (:class:`KernelIR`), from which index-range,
  parallel-race, ring-slot WAR, semaphore-balance, and VMEM-budget
  proofs are derived.  :class:`VmemBudgetError` is the named plan-time
  error the planner's ``vmem_limit_bytes`` gate raises.
* :mod:`repro.analysis.order` — the inter-pass ordering analyzer.
  :func:`build_order` lifts the access IR into a whole-execution
  happens-before model (:class:`HappensBefore`: sequential program edges,
  parallel incomparability, pass structure, DMA start→wait edges), from
  which the ``ORDER_RULES`` proofs are derived — ``cross-pass-war``,
  ``sem-carryover``, ``prefetch-raw``, and ``dma-priority`` — the rules
  that certify the kernels' ``prefetch="cross_pass"`` mode hazard-free
  before CI lets it execute.

Layering: this package imports ``repro.core`` only.  ``repro.api`` sits
above it (the ``verify=`` hooks), and ``core.schedule`` reaches down
lazily for the shared ``check_lane_accum`` implementation.
"""
from .accesses import (Access, Dim, KernelIR, RefInfo, kernel_ir_from_eqn,
                       trace_kernel_irs)
from .budget import (DEFAULT_VMEM_LIMIT_BYTES, VmemBudgetError,
                     check_plan_vmem, check_vmem_budget, kernel_vmem_bytes,
                     plan_vmem_bytes, spgemm_vmem_bytes, spmm_vmem_bytes)
from .invariants import (INVARIANTS, Finding, PlanVerificationError,
                         VerifyResult, check_lane_accum,
                         check_scale_agreement, check_traffic_agreement,
                         verify_plan)
from .jaxpr_lint import (RULES, LintFinding, analyze_callable,
                         analyze_shipped_kernels, find_pallas_kernels,
                         lint_callable, lint_kernel_jaxpr,
                         lint_segment_kernels)
from .order import (ORDER_RULES, HappensBefore, build_order,
                    check_cross_pass_war, check_dma_priority, check_order,
                    check_prefetch_raw, check_sem_carryover,
                    pass_local_chains)
from .races import (ANALYZER_RULES, check_parallel_races, check_ring_war,
                    check_sem_balance)
from .ranges import check_ranges

__all__ = [
    "INVARIANTS", "Finding", "PlanVerificationError", "VerifyResult",
    "check_lane_accum", "check_scale_agreement", "check_traffic_agreement",
    "verify_plan",
    "RULES", "LintFinding", "find_pallas_kernels", "lint_callable",
    "lint_kernel_jaxpr", "lint_segment_kernels",
    "ANALYZER_RULES", "Access", "Dim", "KernelIR", "RefInfo",
    "analyze_callable", "analyze_shipped_kernels", "kernel_ir_from_eqn",
    "trace_kernel_irs", "check_ranges", "check_parallel_races",
    "check_ring_war", "check_sem_balance",
    "ORDER_RULES", "HappensBefore", "build_order", "check_order",
    "check_cross_pass_war", "check_sem_carryover", "check_prefetch_raw",
    "check_dma_priority", "pass_local_chains",
    "DEFAULT_VMEM_LIMIT_BYTES", "VmemBudgetError", "check_plan_vmem",
    "check_vmem_budget", "kernel_vmem_bytes", "plan_vmem_bytes",
    "spgemm_vmem_bytes", "spmm_vmem_bytes",
]
