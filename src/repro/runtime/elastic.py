"""Elastic scaling: rebuild the mesh after node loss and reshard state.

Recovery path at scale (DESIGN.md §5):

1. a node fails → the job controller detects it and relaunches with the
   surviving host set;
2. :func:`make_elastic_mesh` builds the largest valid (data, model) mesh
   from the surviving devices (model parallelism is preserved — TP degree
   is fixed by layer shapes; the data axis shrinks);
3. the latest atomic checkpoint is restored *onto the new mesh* — the
   checkpoint stores unsharded arrays, so restore is just device_put under
   the new NamedShardings;
4. the data pipeline is stateless-deterministic, so the global batch
   simply re-partitions over the surviving data ranks (smaller dp → more
   grad-accumulation steps keeps the effective batch constant).

Straggler mitigation note: because any host can recompute any (step,
shard), a slow host's shard can be speculatively duplicated on an idle one
and the first result wins — the hook for that policy is the deterministic
pipeline; the runtime keeps it policy-level (no kernel changes needed).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint.checkpoint import CheckpointManager
from repro.sharding import make_shardings, params_pspecs


def make_elastic_mesh(devices: Optional[Sequence] = None,
                      model_parallel: int = 1,
                      axis_names=("data", "model")) -> Mesh:
    """Largest (data, model) mesh from the available devices."""
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    assert n >= model_parallel, (n, model_parallel)
    dp = n // model_parallel
    use = devices[: dp * model_parallel]
    arr = np.array(use).reshape(dp, model_parallel)
    return Mesh(arr, axis_names)


def restore_onto_mesh(ckpt: CheckpointManager, step: int, state_like,
                      mesh: Mesh):
    """Restore a checkpoint under a (possibly different) mesh's shardings."""
    params_like = state_like[0]
    pspecs = params_pspecs(params_like)
    params_sh = make_shardings(mesh, pspecs, jax.tree.map(lambda x: x, params_like))
    # opt state: (step scalar, m, v) share the param specs
    from jax.sharding import NamedSharding, PartitionSpec as P
    opt_like = state_like[1]
    opt_sh = type(opt_like)(step=NamedSharding(mesh, P()),
                            m=params_sh, v=params_sh)
    return ckpt.restore(step, state_like, shardings=(params_sh, opt_sh))


def rescale_accum(global_batch: int, old_dp: int, new_dp: int,
                  old_accum: int) -> Tuple[int, int]:
    """Accum steps keeping the effective global batch ≥ the target after a
    dp change.

    Ceil-divides: the old floor division silently *shrank* the effective
    batch whenever the new dp degree didn't divide the per-step token
    count (64 tokens, dp 8→6: floor kept accum=1 → effective 48).  Rounding
    up can only overshoot, never starve the optimizer of tokens, and the
    overshoot is surfaced: returns ``(new_accum, effective_batch)`` with
    ``effective_batch = new_accum * new_dp * per_device_batch`` so the
    caller can log/compensate (e.g. rescale the LR) instead of discovering
    a silently different batch in the loss curves.
    """
    per_device = max(1, global_batch // (old_dp * old_accum))
    new_accum = max(1, -(-global_batch // (new_dp * per_device)))
    return new_accum, new_accum * new_dp * per_device
