"""Gradient compression for cross-pod data parallelism.

At 1000+-node scale the gradient all-reduce over DCN is the scaling
bottleneck; these utilities compress it:

* ``int8``: per-leaf symmetric int8 quantization (4× traffic cut), with
  **error feedback** — the quantization residual is carried into the next
  step so the compression bias vanishes in expectation (SGD w/ EF theory);
* ``topk``: magnitude top-k sparsification (send values+indices), also with
  error feedback.

``compressed_psum`` is written for use *inside shard_map* over the dp axis:
quantize locally → all-reduce the low-precision payload → dequantize.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def topk_mask(g: jax.Array, frac: float) -> jax.Array:
    flat = jnp.abs(g.reshape(-1))
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(g) >= thresh).astype(g.dtype)


def compressed_psum(grads, axis_name: str, error_fb, method: str = "int8",
                    topk_frac: float = 0.1):
    """All-reduce ``grads`` over ``axis_name`` with compression + error
    feedback.  Returns (mean_grads, new_error_fb).  Call inside shard_map.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, ef):
        g = g.astype(jnp.float32) + ef
        if method == "int8":
            q, scale = quantize_int8(g)
            sent = dequantize_int8(q, scale)
        elif method == "topk":
            sent = g * topk_mask(g, topk_frac)
        else:
            sent = g
        new_ef = g - sent
        reduced = jax.lax.psum(sent, axis_name) / n
        return reduced, new_ef

    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error_fb)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    mean = jax.tree_util.tree_unflatten(tree, [o[0] for o in outs])
    new_ef = jax.tree_util.tree_unflatten(tree, [o[1] for o in outs])
    return mean, new_ef


def init_error_fb(params_like, n_dev: int):
    """Per-device error-feedback state: one residual copy per dp rank.

    The residual is *device-local* state (each rank quantizes its own
    shard's gradient), so it is carried with a leading dp axis of size
    ``n_dev`` and sharded over the dp mesh axis — returning it through a
    replicated ``P()`` out_spec under ``check_rep=False`` silently keeps
    only one device's residual and the EF correction never converges.
    """
    return jax.tree.map(
        lambda p: jnp.zeros((n_dev,) + jnp.shape(p), jnp.float32),
        params_like)


def make_compressed_dp_step(loss_fn, opt, mesh, dp_axis: str = "data",
                            method: str = "int8"):
    """A data-parallel train step whose gradient all-reduce is compressed.

    State: (params, opt_state, error_fb). Batch is sharded on ``dp_axis``;
    params replicated (pure DP — the demonstration configuration);
    ``error_fb`` comes from :func:`init_error_fb` — per-device residuals
    with a leading dp axis, carried sharded over ``dp_axis`` so every
    rank's residual survives the round trip.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    def spmd(params, opt_state, error_fb, batch):
        ef_local = jax.tree.map(lambda e: e[0], error_fb)   # drop dp axis
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, new_ef = compressed_psum(grads, dp_axis, ef_local, method)
        loss = jax.lax.pmean(loss, dp_axis)
        new_params, new_opt, om = opt.update(grads, opt_state, params)
        new_ef = jax.tree.map(lambda e: e[None], new_ef)    # restore dp axis
        return new_params, new_opt, new_ef, loss

    def leading_dp_spec(leaf):
        # batch and error_fb both carry dp as their leading axis
        return P(dp_axis, *([None] * (leaf.ndim - 1)))

    def step(state, batch):
        params, opt_state, error_fb = state
        specs_b = jax.tree.map(leading_dp_spec, batch)
        specs_e = jax.tree.map(leading_dp_spec, error_fb)
        # P() prefixes cover whole subtrees (params pytree, AdamWState)
        fn = shard_map(
            spmd, mesh=mesh,
            in_specs=(P(), P(), specs_e, specs_b),
            out_specs=(P(), P(), specs_e, P()),
            check_rep=False)
        new_params, new_opt, new_ef, loss = fn(params, opt_state, error_fb,
                                               batch)
        return (new_params, new_opt, new_ef), loss

    return jax.jit(step)
