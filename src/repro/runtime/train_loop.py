"""Training runtime: sharded train step, grad accumulation, fault tolerance.

The train step lowered here is also what the multi-pod dry-run compiles:

    state = (params fp32 [FSDP+TP sharded], AdamW m/v [same], step)
    step:  scan over `accum_steps` microbatches → mean grads → clip → AdamW

Fault tolerance:
* async atomic checkpoints every ``ckpt_every`` (checkpoint/),
* ``resume="auto"`` restarts from the latest commit,
* the data pipeline is a pure function of the step → replaying after
  restart or re-mesh is exact (no data loss / duplication),
* ``failure_hook`` lets tests inject a crash at a chosen step (the restart
  test exercises the full save→crash→restore→bitwise-continue path),
* elastic re-mesh lives in runtime/elastic.py (restore onto a smaller mesh).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.pipeline import SyntheticDataset
from repro.optim import AdamW, cosine_with_warmup
from repro.sharding import (batch_pspecs, constrain_like_params,
                            make_shardings, params_pspecs)


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    peak_lr: float = 3e-4
    warmup: int = 10
    accum_steps: int = 1
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    resume: str = "auto"          # auto | none
    grad_compression: Optional[str] = None   # None | int8 | topk


def make_train_step(model, opt: AdamW, accum_steps: int,
                    mesh: Optional[Mesh] = None, accum_dtype=jnp.float32,
                    fsdp="data"):
    """Build the jitted (state, batch) → (state, metrics) step.

    ``accum_dtype=bf16`` halves the gradient-accumulation buffer for
    state-dominated giants (llama4-class); loss scale is unaffected because
    microbatch grads are averaged, not summed, into the buffer."""

    def loss_fn(params, microbatch):
        loss, metrics = model.loss_fn(params, microbatch)
        return loss, metrics

    def step_fn(state, batch):
        params, opt_state = state

        if accum_steps > 1:
            def split(x):
                return x.reshape(accum_steps, x.shape[0] // accum_steps,
                                 *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc(carry, mb):
                gsum, lsum = carry
                (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                grads = constrain_like_params(grads, fsdp)  # FSDP reduce-scatter
                gsum = jax.tree.map(
                    lambda a, g: (a.astype(jnp.float32)
                                  + g.astype(jnp.float32) / accum_steps
                                  ).astype(accum_dtype), gsum, grads)
                return (gsum, lsum + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (gsum, lsum), _ = jax.lax.scan(acc, (zeros, 0.0), micro)
            grads = gsum
            loss = lsum / accum_steps
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
            grads = constrain_like_params(grads, fsdp)

        new_params, new_opt, om = opt.update(grads, opt_state, params)
        metrics = {"loss": loss, **om}
        return (new_params, new_opt), metrics

    if mesh is None:
        return jax.jit(step_fn, donate_argnums=(0,))
    return step_fn  # caller jits with explicit shardings


class Trainer:
    def __init__(self, model, model_cfg, shape_cfg, tcfg: TrainerConfig,
                 mesh: Optional[Mesh] = None, seed: int = 0):
        self.model = model
        self.model_cfg = model_cfg
        self.shape_cfg = shape_cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.data = SyntheticDataset(model_cfg, shape_cfg, seed=seed + 1)
        self.opt = AdamW(lr=cosine_with_warmup(tcfg.peak_lr, tcfg.warmup,
                                               tcfg.steps))
        key = jax.random.PRNGKey(seed)
        params = model.init(key)
        opt_state = self.opt.init(params)
        self.state = (params, opt_state)
        self.start_step = 0
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir)
                     if tcfg.ckpt_dir else None)
        if self.ckpt and tcfg.resume == "auto":
            latest = self.ckpt.latest_step()
            if latest is not None:
                self.state = self.ckpt.restore(latest, self.state)
                self.start_step = latest
        self._step_fn = make_train_step(model, self.opt, tcfg.accum_steps,
                                        mesh)
        if mesh is not None:
            from repro.optim import AdamWState
            params = self.state[0]
            pspecs = params_pspecs(params)
            p_sh = make_shardings(mesh, pspecs, params)
            opt_sh = AdamWState(
                step=NamedSharding(mesh, P()),
                m=make_shardings(mesh, pspecs, self.state[1].m),
                v=make_shardings(mesh, pspecs, self.state[1].v))
            # pin outputs to the same shardings as inputs: the state is
            # donated and fed straight back in, so compiler-chosen output
            # shardings would mismatch in_shardings on the second call.
            self._step_fn = jax.jit(
                self._step_fn, donate_argnums=(0,),
                in_shardings=((p_sh, opt_sh), None),
                out_shardings=((p_sh, opt_sh), None))

    def run(self, failure_hook: Optional[Callable[[int], None]] = None
            ) -> Dict[str, Any]:
        history = []
        for step in range(self.start_step, self.tcfg.steps):
            batch = jax.tree.map(jnp.asarray, self.data.batch(step))
            self.state, metrics = self._step_fn(self.state, batch)
            if step % self.tcfg.log_every == 0 or step == self.tcfg.steps - 1:
                history.append({"step": step,
                                "loss": float(metrics["loss"]),
                                "grad_norm": float(metrics["grad_norm"])})
            if self.ckpt and (step + 1) % self.tcfg.ckpt_every == 0:
                self.ckpt.save(step + 1, self.state)
            if failure_hook is not None:
                failure_hook(step)   # may raise to simulate a crash
        if self.ckpt:
            self.ckpt.save(self.tcfg.steps, self.state, wait=True)
        return {"history": history, "final_loss": history[-1]["loss"]}
