"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

Optional parallelism mode (the production dry-run uses DP×TP per spec):
layers are partitioned into S stages, each stage's params live on one
stage rank, and microbatches flow through a ``ppermute`` ring inside
``shard_map``.  Wall-clock = (n_micro + S - 1) ticks — classic GPipe fill/
drain; bubble fraction (S-1)/(n_micro+S-1).

This module implements *inference/forward* pipelining (the pattern that
matters for the collective schedule); training composes it with grad
accumulation outside.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(stage_fn: Callable, stage_params, x, *, mesh: Mesh,
                     n_micro: int, axis: str = "stage"):
    """Run ``x`` through S pipelined stages.

    Args:
      stage_fn: (params_one_stage, activation (mb, ...)) → activation.
      stage_params: pytree with leading dim S, sharded P(axis) on dim 0.
      x: (n_micro, mb, ...) input microbatches (replicated).
    Returns:
      (n_micro, mb, ...) outputs of the final stage (replicated).
    """
    s = mesh.shape[axis]
    ticks = n_micro + s - 1
    perm_fwd = [(i, i + 1) for i in range(s - 1)]

    def spmd(params_local, x_all):
        sid = jax.lax.axis_index(axis)
        p_one = jax.tree.map(lambda a: a[0], params_local)
        mb_shape = x_all.shape[1:]

        def tick(carry, t):
            buf = carry
            # stage 0 pulls microbatch t (clamped); others take the ring buf
            feed_idx = jnp.clip(t, 0, n_micro - 1)
            inp0 = jax.lax.dynamic_index_in_dim(x_all, feed_idx, 0,
                                                keepdims=False)
            inp = jnp.where(sid == 0, inp0, buf)
            out = stage_fn(p_one, inp)
            live = (t >= sid) & (t - sid < n_micro)
            out = jnp.where(live, out, jnp.zeros_like(out))
            nxt = jax.lax.ppermute(out, axis, perm_fwd)
            # final stage emits its result at ticks [s-1, s-1+n_micro)
            emit = jnp.where((sid == s - 1) & live, out, jnp.zeros_like(out))
            return nxt, emit

        _, emits = jax.lax.scan(tick, jnp.zeros(mb_shape, x_all.dtype),
                                jnp.arange(ticks))
        # emits: (ticks, mb, ...) — only the last stage's window is nonzero;
        # psum over the stage axis broadcasts it to every rank
        emits = jax.lax.psum(emits, axis)
        return jax.lax.dynamic_slice_in_dim(emits, s - 1, n_micro, 0)

    fn = shard_map(
        spmd, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False)
    return fn(stage_params, x)


def split_layers_into_stages(stacked_params, n_stages: int):
    """Reshape layer-stacked params (L, ...) → (S, L/S, ...)."""
    def re(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return a.reshape(n_stages, l // n_stages, *a.shape[1:])
    return jax.tree.map(re, stacked_params)
