"""Continuous-batching serving engine: slot-level admission, per-slot
positions, immediate retirement.

The paper's thesis — fine-grained *dynamic* work assignment beats static
lockstep scheduling for utilization and load balance — applied at the
request level.  The old ``Server`` formed lockstep groups: pad every
prompt to the group max, decode ``max(max_new_tokens)`` steps, retire the
whole group at once.  That shape was slow (head-of-line blocking,
over-decode) and *wrong*: a single shared scalar position meant every
request shorter than the group max sampled its first token from padding
and decoded every subsequent token at a shifted position.

:class:`Engine` is a continuous batcher that fixes the bug by
construction:

* **slots** — a fixed number of batch rows backed by one persistent KV
  cache allocated at engine construction.  A request occupies exactly one
  slot from admission to retirement, and every slot tracks its own
  absolute position: the decode dispatch passes a per-row ``(B,)``
  position vector to ``model.decode_step``, so no row ever reads another
  row's timeline or padding.
* **admission** — whenever a slot is free and the queue is non-empty, the
  next request is prefilled into that slot: chunked, length-bucketed, and
  jitted, so steady-state serving executes a *fixed set of compiled
  shapes* (one decode shape + one per prefill bucket) with no retracing
  across arrivals.  The first prefill chunk zeroes the slot's cache row,
  wiping any state left by the previous occupant (attention junk is
  position-masked anyway, but recurrent-state rows must be reset).
* **retirement** — a request leaves its slot the moment it emits
  ``eos_token`` or reaches its own ``max_new_tokens``; the slot is handed
  to the next queued request immediately.  No lockstep groups, no
  over-decode to a group max.

Free slots ride along in the batched decode with ``pos=0`` and a dummy
token; their writes land in rows that the next admission's fresh prefill
resets/overwrites, and attention masking keeps them invisible.  (For MoE
models the rows are not perfectly independent — expert capacity is
batch-global — so batched MoE decode is faithful to *batched* MoE
serving, not to one-request-at-a-time routing.)

Kernel backend selection goes through :mod:`repro.api.backends`: an
engine constructed with ``backend="interpret"`` (CPU correctness runs) or
``backend="pallas"`` (TPU) traces its jitted step functions under that
backend, so Segment-plan layers in the model bake the right execution
mode in.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.backends import resolve_backend, use_backend


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                 # (T,) int32
    max_new_tokens: int = 16
    eos_token: Optional[int] = None    # retire early on this token (kept in
                                       # the output, vLLM-style)
    out_tokens: Optional[np.ndarray] = None
    rid: int = -1                      # assigned by Engine.submit


@dataclasses.dataclass
class _Slot:
    """Host-side per-slot decode state."""
    request: Request
    pos: int                           # tokens in cache == next write index
    last_tok: int                      # token to feed at the next step
    out: List[int] = dataclasses.field(default_factory=list)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


class Engine:
    """Greedy continuous-batching generation over a fixed slot count.

    ``prefill_buckets`` (descending chunk sizes; each a multiple of the
    smallest) defines the compiled prefill shapes: a prompt is fed through
    the largest bucket that fits the remaining tokens, and the final
    partial chunk is zero-padded up to the smallest bucket — the padded
    region is position-masked out of attention and never advances the
    slot's position.  Models with recurrent state (hybrid/ssm families)
    force ``(1,)``: a recurrent scan has no mask lane, so padded tokens
    would corrupt the carried state.
    """

    def __init__(self, model, params, *, slots: int = 4, max_len: int = 512,
                 backend: Optional[str] = None,
                 prefill_buckets: Tuple[int, ...] = (64, 16),
                 quantize: Optional[str] = None):
        if getattr(model.cfg, "family", None) == "enc_dec":
            raise NotImplementedError(
                "enc_dec serving needs encoder output plumbing; the engine "
                "currently serves decoder-only families")
        if quantize is not None:
            # freeze the block-sparse FFN weights for low-precision decode:
            # the engine's jitted step functions then trace over quantized
            # plans (int8/fp8 payload + fp32-scale leaves) and every weight
            # fetch in the Segment kernels moves ~4x fewer bytes
            model, params = model.quantize(params, quantize)
        self.quantize = quantize
        self.model = model
        self.params = params
        self.slots = int(slots)
        self.max_len = int(max_len)
        self.backend = resolve_backend(backend)

        buckets = tuple(sorted({int(c) for c in prefill_buckets}, reverse=True))
        if not buckets or buckets[-1] < 1:
            raise ValueError(f"bad prefill_buckets {prefill_buckets!r}")
        if any(c % buckets[-1] for c in buckets):
            raise ValueError(
                f"prefill_buckets {buckets} must all be multiples of the "
                f"smallest bucket (chunk starts must stay bucket-aligned)")
        if self._has_recurrent_state():
            buckets = (1,)   # padding would pollute the carried state
        elif getattr(model.cfg, "kv_cache_dtype", "bfloat16") == "int8":
            # the factored-scale int8 attention path is decode-sized only
            buckets = tuple(c for c in buckets if c <= 8) or (8,)
        if self._has_kind("local"):
            # a chunk wider than the ring would scatter duplicate slot
            # indices in one write (undefined survivor order)
            w = int(model.cfg.local_window)
            buckets = tuple(c for c in buckets if c <= w) or (max(1, min(w, 8)),)
        self.prefill_buckets = buckets
        # cache rounded up so a final padded chunk never writes past the end
        # (a clamped dynamic_update_slice would silently corrupt the tail)
        self._cache_len = _round_up(self.max_len, buckets[-1])
        self.cache = model.init_cache(self.slots, self._cache_len)

        self._queue: Deque[Request] = collections.deque()
        self._slots: List[Optional[_Slot]] = [None] * self.slots
        self._next_rid = 0
        self.completed = 0
        # trace counters: incremented by the traced python bodies, i.e. only
        # when jit actually (re)compiles — the retrace regression tests
        # assert these stay flat across request arrivals/retirements
        self.decode_traces = 0
        self.prefill_traces = 0
        self._decode = jax.jit(self._decode_fn)
        self._prefill = jax.jit(self._prefill_fn, static_argnames=("fresh",))

    # -- model introspection -------------------------------------------------

    def _has_kind(self, *wanted: str) -> bool:
        for (_, kinds, _) in getattr(self.model, "groups", ()):
            kinds = kinds if isinstance(kinds, tuple) else (kinds,)
            if any(k in wanted for k in kinds):
                return True
        return False

    def _has_recurrent_state(self) -> bool:
        return self._has_kind("rec", "rwkv")

    # -- jitted step functions ----------------------------------------------

    def _decode_fn(self, params, cache, tok, pos):
        """tok (S, 1), pos (S,) — one batched decode step at per-slot
        positions; returns (greedy next token (S,), new cache)."""
        self.decode_traces += 1
        with use_backend(self.backend):
            logits, cache = self.model.decode_step(params, cache, tok, pos)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    def _prefill_fn(self, params, cache, slot, tok, pos, last_idx, fresh):
        """Prefill one chunk of one slot: slice the slot's cache row out,
        run the chunk at absolute offset ``pos``, write the row back.

        ``last_idx`` indexes the chunk's last *valid* token — the returned
        greedy token is sampled there, never from padding.  ``fresh``
        (static) zeroes the row first: admission wipes the previous
        occupant's recurrent state / ring buffer."""
        self.prefill_traces += 1
        row = jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1), cache)
        if fresh:
            row = jax.tree.map(jnp.zeros_like, row)
        with use_backend(self.backend):
            logits, row = self.model.decode_step(params, row, tok, pos,
                                                 logit_idx=last_idx)
        cache = jax.tree.map(
            lambda full, r: jax.lax.dynamic_update_slice_in_dim(
                full, r, slot, axis=1),
            cache, row)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    # -- request lifecycle ---------------------------------------------------

    def submit(self, request: Request) -> Request:
        """Validate and enqueue. Raises ``ValueError`` if the request could
        not fit the cache — the old server silently clamped the cache write
        index and corrupted the tail instead."""
        prompt = np.asarray(request.prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if request.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{request.max_new_tokens}")
        total = prompt.size + request.max_new_tokens
        if total > self.max_len:
            raise ValueError(
                f"request needs {prompt.size} prompt + "
                f"{request.max_new_tokens} new = {total} positions but "
                f"max_len={self.max_len}; longer contexts need a larger "
                f"engine (or chunk the request)")
        request.prompt = prompt
        request.rid = self._next_rid
        self._next_rid += 1
        self._queue.append(request)
        return request

    def _chunk_schedule(self, length: int) -> List[int]:
        """Bucket sizes covering ``length`` prompt tokens (the last chunk
        may be zero-padded; starts stay aligned to the smallest bucket)."""
        chunks, done = [], 0
        while done < length:
            rem = length - done
            c = next((c for c in self.prefill_buckets if c <= rem),
                     self.prefill_buckets[-1])
            chunks.append(c)
            done += c
        return chunks

    def _admit(self, s: int, req: Request) -> None:
        prompt = req.prompt
        length = int(prompt.shape[0])
        done = 0
        tok_dev = None
        for i, c in enumerate(self._chunk_schedule(length)):
            n = min(c, length - done)
            buf = np.zeros((1, c), np.int32)
            buf[0, :n] = prompt[done:done + n]
            tok_dev, self.cache = self._prefill(
                self.params, self.cache, jnp.int32(s), jnp.asarray(buf),
                jnp.int32(done), jnp.asarray([n - 1], jnp.int32),
                fresh=(i == 0))
            done += n
        # only the final chunk's token matters — one host sync per admission
        tok = int(np.asarray(tok_dev)[0])
        slot = _Slot(request=req, pos=length, last_tok=tok, out=[tok])
        self._slots[s] = slot
        if self._finished(slot):
            self._retire(s)

    def _finished(self, slot: _Slot) -> bool:
        r = slot.request
        return (len(slot.out) >= r.max_new_tokens
                or (r.eos_token is not None and slot.out
                    and slot.out[-1] == r.eos_token))

    def _retire(self, s: int) -> None:
        slot = self._slots[s]
        slot.request.out_tokens = np.asarray(slot.out, np.int32)
        self._slots[s] = None
        self.completed += 1

    # -- the serving loop ----------------------------------------------------

    def admit_pending(self) -> int:
        """Prefill queued requests into free slots; returns slots filled."""
        filled = 0
        for s in range(self.slots):
            if self._slots[s] is None and self._queue:
                self._admit(s, self._queue.popleft())
                filled += 1
        return filled

    def step(self) -> int:
        """Admit into free slots, then run one batched decode step.
        Returns the number of live slots that advanced."""
        self.admit_pending()
        live = [s for s in range(self.slots) if self._slots[s] is not None]
        if not live:
            return 0
        tok = np.zeros((self.slots, 1), np.int32)
        pos = np.zeros((self.slots,), np.int32)
        for s in live:
            tok[s, 0] = self._slots[s].last_tok
            pos[s] = self._slots[s].pos
        nxt, self.cache = self._decode(self.params, self.cache,
                                       jnp.asarray(tok), jnp.asarray(pos))
        nxt = np.asarray(nxt)
        for s in live:
            slot = self._slots[s]
            slot.pos += 1                       # last_tok now sits in cache
            slot.last_tok = int(nxt[s])
            slot.out.append(slot.last_tok)
            if self._finished(slot):
                self._retire(s)
        return len(live)

    def run(self) -> None:
        """Drain the queue and all occupied slots."""
        while self._queue or any(s is not None for s in self._slots):
            self.step()

    def generate(self, requests: List[Request]) -> List[Request]:
        """Submit + drain; fills each request's ``out_tokens`` in place."""
        for r in requests:
            self.submit(r)
        self.run()
        return requests

    # -- introspection -------------------------------------------------------

    @property
    def compiled_shapes(self) -> Dict[str, int]:
        """Trace counts per step function — flat after warmup."""
        return {"decode": self.decode_traces, "prefill": self.prefill_traces}


class Server(Engine):
    """Back-compat surface of the old lockstep batcher.

    Same constructor keywords (``batch_slots``); ``generate`` now runs the
    continuous-batching engine, so mixed-length batches decode correctly
    (the lockstep version sampled short prompts' first tokens from
    padding) and mixed ``max_new_tokens`` no longer over-decode.
    """

    def __init__(self, model, params, *, batch_slots: int = 4,
                 max_len: int = 512, backend: Optional[str] = None, **kw):
        super().__init__(model, params, slots=batch_slots, max_len=max_len,
                         backend=backend, **kw)
